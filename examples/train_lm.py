"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing, preemption handling, and deterministic restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--params 100m]

On this CPU container the default is a ~10M model / 120 steps so the run
finishes in minutes; pass --params 100m --steps 300 for the full-size run
(the model definition and training stack are identical).
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models.transformer import LMConfig, init_params  # noqa: E402
from repro.train import loop as train_loop  # noqa: E402
from repro.train import failure, optimizer as opt_mod  # noqa: E402
from repro.data.synthetic import LMTokenStream  # noqa: E402

SIZES = {
    # ~10M: CPU-friendly; ~100M: the assignment's end-to-end size
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv=2, d_ff=1024,
                vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=2304,
                 vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--params", choices=list(SIZES), default="10m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = LMConfig(name=f"lm-{args.params}", dtype=jnp.float32,
                   **SIZES[args.params])
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = opt_mod.AdamWConfig(lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    step_fn = jax.jit(train_loop.make_lm_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))
    stream = LMTokenStream(cfg.vocab, seed=0)

    def make_batch(step):
        return {"tokens": jnp.asarray(stream.batch(step, args.batch,
                                                   args.seq))}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")
    monitor = failure.StragglerMonitor()
    (params, opt_state), last, preempted = failure.run_restartable(
        step_fn, make_batch, (params, opt_state), n_steps=args.steps,
        ckpt_dir=ckpt_dir, ckpt_every=50, monitor=monitor)
    print(f"finished at step {last} (preempted={preempted}); "
          f"checkpoints in {ckpt_dir}")
    if monitor.flagged:
        print(f"straggler steps flagged: {monitor.flagged[:5]}")


if __name__ == "__main__":
    main()
