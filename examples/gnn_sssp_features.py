"""The paper's technique as a first-class framework feature: EIC SSSP
distances as GNN positional features (anchor-distance encoding).

Runs the EIC engine from K anchor vertices, attaches the K-dim distance
profile to each node's features, and trains a GIN classifier — showing the
graph substrate (CSR, segment message passing) is shared between the SSSP
core and the GNN model zoo.

    PYTHONPATH=src python examples/gnn_sssp_features.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import SolveSpec, Solver  # noqa: E402
from repro.data.generators import kronecker  # noqa: E402
from repro.models.gnn import gin  # noqa: E402
from repro.models.gnn.common import GraphBatch  # noqa: E402
from repro.train import loop as train_loop, optimizer as opt_mod  # noqa: E402


def anchor_distance_features(g, k_anchors: int = 8, seed: int = 0):
    """K-dim shortest-path profile per node (exp-decayed, inf -> 0).

    One batched SolveSpec runs all K anchors as a single fused vmapped
    computation instead of K sequential engine calls."""
    rng = np.random.default_rng(seed)
    anchors = rng.choice(np.where(g.deg > 0)[0], k_anchors, replace=False)
    solver = Solver.open(g)
    res = solver.solve(SolveSpec.tree([int(a) for a in anchors]))
    d = np.asarray(res.dist)                         # [K, N]
    feats = np.where(np.isfinite(d), np.exp(-d), 0.0).T
    return feats.astype(np.float32), anchors


def main():
    g = kronecker(10, 8, seed=3)
    feats, anchors = anchor_distance_features(g, k_anchors=8)
    print(f"graph |V|={g.n} |E|={g.m//2}; anchors={list(anchors)}")

    # labels: nearest anchor (a task the distance features solve exactly,
    # and raw structure alone cannot)
    labels = feats.argmax(1).astype(np.int32)

    gb = GraphBatch(node_feat=jnp.asarray(feats),
                    senders=jnp.asarray(g.src), receivers=jnp.asarray(g.dst),
                    edge_feat=None, graph_ids=jnp.zeros(g.n, jnp.int32),
                    n_graphs=1, labels=jnp.asarray(labels))
    cfg = gin.GINConfig(d_in=8, d_hidden=32, n_layers=3, n_classes=8)
    params = gin.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = opt_mod.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                                  master_weights=False)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    step = jax.jit(train_loop.make_gnn_train_step(gin.forward, cfg, opt_cfg))
    for i in range(60):
        params, opt_state, metrics = step(params, opt_state, gb)
        if i % 10 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.4f}")
    logits = gin.forward(cfg, params, gb)
    acc = float((jnp.argmax(logits, -1) == gb.labels).mean())
    print(f"final nearest-anchor accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
