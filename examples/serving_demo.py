"""Serving demo: multi-graph registry + async scheduler under Zipf traffic.

    PYTHONPATH=src python examples/serving_demo.py [--scale 10] [--queries 32]

Registers a road grid and a Kronecker graph, starts the background
scheduler worker, streams a Zipf-skewed mixed query load (p2p / bounded /
k-nearest / tree) through it, and prints per-kind samples plus the
serving counters.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.data.generators import kronecker, road_grid  # noqa: E402
from repro.data.traffic import make_traffic  # noqa: E402
from repro.serve.registry import GraphRegistry  # noqa: E402
from repro.serve.scheduler import QueryScheduler  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    n = 1 << args.scale
    graphs = {
        "social": kronecker(args.scale, 8, seed=2),      # hottest
        "road": road_grid(int(np.sqrt(n)), seed=5),
    }
    registry = GraphRegistry(capacity=len(graphs))
    for gid, g in graphs.items():
        registry.register(gid, g)
        print(f"registered {gid!r}: |V|={g.n} |E|={g.m // 2}")

    scheduler = QueryScheduler(registry, max_batch=args.max_batch)
    scheduler.start()
    traffic = make_traffic(graphs, args.queries, seed=0)
    t0 = time.perf_counter()
    futs = [(item, scheduler.submit(item.query, priority=item.priority))
            for item in traffic]
    results = [(item, fut.result(timeout=600)) for item, fut in futs]
    elapsed = time.perf_counter() - t0
    scheduler.stop()

    shown = set()
    for item, res in results:
        q = item.query
        if q.kind in shown:
            continue
        shown.add(q.kind)
        if q.kind == "p2p":
            hops = len(res.path) - 1 if res.path else None
            print(f"[{q.gid}] p2p {q.source}->{q.target}: "
                  f"dist={res.distance:.4f} hops={hops} "
                  f"({res.latency_s * 1e3:.0f} ms)")
        elif q.kind == "bounded":
            print(f"[{q.gid}] bounded src={q.source} D={q.bound:.2f}: "
                  f"{int(np.isfinite(res.dist).sum())} vertices in range")
        elif q.kind == "knear":
            v, d = res.nearest[-1]
            print(f"[{q.gid}] knear src={q.source} k={q.k}: "
                  f"k-th neighbor {v} at {d:.4f}")
        else:
            print(f"[{q.gid}] tree src={q.source}: "
                  f"{res.metrics['reachable']} reachable, "
                  f"nSync={res.metrics['nSync']:.2f}")

    lats = np.array([res.latency_s for _, res in results])
    stats = scheduler.stats()
    print(f"\n{len(results)} queries in {elapsed:.2f}s "
          f"({len(results) / elapsed:.1f} q/s, incl. jit warmup)")
    print(f"latency p50={np.percentile(lats, 50) * 1e3:.0f} ms "
          f"p99={np.percentile(lats, 99) * 1e3:.0f} ms; "
          f"occupancy={stats['occupancy']:.2f} over "
          f"{stats['n_batches']} batches; "
          f"registry hit rate={stats['registry']['hit_rate']:.2f}")


if __name__ == "__main__":
    main()
