"""Serving demo: multi-device router + per-device schedulers under Zipf
traffic.

    PYTHONPATH=src python examples/serving_demo.py [--scale 10] [--queries 32]

    # with a forced CPU device mesh:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/serving_demo.py

Registers a road grid and a Kronecker graph, plans placement from the
expected traffic shares, warms every replica engine, starts the
background workers (one per device), streams a Zipf-skewed mixed query
load (p2p / bounded / k-nearest / tree) through the router, and prints
per-kind samples plus placement and serving counters.

At exit it prints the serving plane's metrics snapshot (the one
registry/scheduler/router ``MetricsRegistry``), then runs one *traced*
solve on the hottest graph and writes its per-round solve trace as a
Perfetto/Chrome-trace JSON (``--trace-out``, default
``serving_demo_trace.json`` — load it at https://ui.perfetto.dev).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.api import EngineConfig  # noqa: E402
from repro.data.generators import kronecker, road_grid  # noqa: E402
from repro.data.traffic import make_traffic  # noqa: E402
from repro.serve.registry import GraphRegistry  # noqa: E402
from repro.serve.router import QueryRouter  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--rate-qps", type=float, default=None,
                    help="open-loop arrival pacing (default: closed loop)")
    ap.add_argument("--trace-out", default="serving_demo_trace.json",
                    help="write a traced solve's Perfetto JSON here")
    args = ap.parse_args()

    n = 1 << args.scale
    graphs = {
        "social": kronecker(args.scale, 8, seed=2),      # hottest
        "road": road_grid(int(np.sqrt(n)), seed=5),
    }
    # one EngineConfig drives the registry and the router (multi-graph
    # serving keeps the registry/router stack; single-graph sessions can
    # use Solver.open(g, EngineConfig(tier="routed")) instead)
    cfg = EngineConfig(max_batch=args.max_batch,
                       registry_capacity=4 * len(graphs))
    registry = GraphRegistry(config=cfg)
    for gid, g in graphs.items():
        registry.register(gid, g)
        print(f"registered {gid!r}: |V|={g.n} |E|={g.m // 2}")

    router = QueryRouter(registry, config=cfg)
    print(f"router over {router.n_devices} device(s)")
    traffic = make_traffic(graphs, args.queries, seed=0,
                           rate_qps=args.rate_qps)
    shares = {}
    for item in traffic:
        shares[item.query.gid] = shares.get(item.query.gid, 0) + 1
    placement = router.plan_placement(shares)
    print(f"placement: {placement}")
    t0 = time.perf_counter()
    router.warmup(kinds=("p2p", "bounded", "knear", "tree"))
    print(f"warmup (builds + jit compiles): "
          f"{time.perf_counter() - t0:.1f}s")

    router.start()
    t0 = time.perf_counter()
    futs = []
    for item in traffic:
        if args.rate_qps is not None:       # open-loop pacing
            lag = item.arrival_s - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
        futs.append((item, router.submit(item.query,
                                         priority=item.priority)))
    results = [(item, fut.result(timeout=600)) for item, fut in futs]
    elapsed = time.perf_counter() - t0
    router.stop()

    shown = set()
    for item, res in results:
        q = item.query
        if q.kind in shown:
            continue
        shown.add(q.kind)
        where = f"@{res.served_by}"
        if q.kind == "p2p":
            hops = len(res.path) - 1 if res.path else None
            print(f"[{q.gid}{where}] p2p {q.source}->{q.target}: "
                  f"dist={res.distance:.4f} hops={hops} "
                  f"({res.latency_s * 1e3:.0f} ms)")
        elif q.kind == "bounded":
            print(f"[{q.gid}{where}] bounded src={q.source} "
                  f"D={q.bound:.2f}: "
                  f"{int(np.isfinite(res.dist).sum())} vertices in range")
        elif q.kind == "knear":
            v, d = res.nearest[-1]
            print(f"[{q.gid}{where}] knear src={q.source} k={q.k}: "
                  f"k-th neighbor {v} at {d:.4f}")
        else:
            print(f"[{q.gid}{where}] tree src={q.source}: "
                  f"{res.metrics['reachable']} reachable, "
                  f"nSync={res.metrics['nSync']:.2f}")

    lats = np.array([res.latency_s for _, res in results])
    stats = router.stats()
    print(f"\n{len(results)} queries in {elapsed:.2f}s "
          f"({len(results) / elapsed:.1f} q/s, warmed)")
    print(f"latency p50={np.percentile(lats, 50) * 1e3:.0f} ms "
          f"p99={np.percentile(lats, 99) * 1e3:.0f} ms; "
          f"occupancy={stats['occupancy']:.2f} over "
          f"{stats['n_batches']} batches on {stats['n_devices']} devices; "
          f"replications={stats['n_replications']}; "
          f"registry hit rate={stats['registry']['hit_rate']:.2f}")
    per_dev = {s["name"]: s["n_done"] for s in stats["schedulers"]
               if s["n_done"]}
    print(f"queries per scheduler: {per_dev}")

    # the same numbers, through the observability plane: one metrics
    # registry covers the engine registry, every scheduler, and the router
    print("\nmetrics snapshot (non-zero series):")
    for name, entry in sorted(registry.metrics.snapshot().items()):
        if entry["type"] == "histogram":
            if entry["count"]:
                print(f"  {name}: count={entry['count']} "
                      f"p50={entry['p50'] * 1e3:.1f}ms "
                      f"p99={entry['p99'] * 1e3:.1f}ms")
        elif entry["value"]:
            print(f"  {name}: {entry['value']}")

    # one traced solve on the hottest graph -> Perfetto JSON of its
    # per-round stepping behavior (solve/step/round/invocation tracks)
    from repro.api import Solver, SolveSpec  # noqa: E402
    from repro.obs import write_perfetto  # noqa: E402

    hot = max(shares, key=shares.get)
    with Solver.open(graphs[hot], EngineConfig(trace=True)) as solver:
        res = solver.solve(SolveSpec.tree(0))
    write_perfetto(res.trace, args.trace_out, name=f"sssp:{hot}")
    print(f"\ntraced solve on {hot!r}: {res.trace.n_records} rounds, "
          f"{int(res.metrics.n_relax)} relaxations -> {args.trace_out}")


if __name__ == "__main__":
    main()
