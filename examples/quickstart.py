"""Quickstart: EIC SSSP on a Graph500 Kronecker graph through the
declarative solver facade (``repro.api``).

    PYTHONPATH=src python examples/quickstart.py [--scale 12]

``Solver.open`` owns layout building and engine-tier resolution; every
query is a ``SolveSpec`` (tree / p2p / bounded / knear) and every result
a ``SolveResult`` with lazy path reconstruction.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.api import SolveSpec, Solver  # noqa: E402
from repro.core.baselines import dijkstra_host, bellman_ford  # noqa: E402
from repro.data.generators import kronecker  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=16)
    args = ap.parse_args()

    print(f"generating Graph500 Kronecker graph: scale={args.scale} "
          f"edge_factor={args.edge_factor}")
    g = kronecker(args.scale, args.edge_factor, seed=1)
    # random source (paper methodology; hub sources inflate the first window)
    src = int(np.random.default_rng(0).choice(np.where(g.deg > 0)[0]))
    print(f"|V|={g.n} |E|={g.m // 2} source={src} (max degree {g.deg.max()})")

    solver = Solver.open(g)                       # default: single device
    spec = SolveSpec.tree(src)
    t0 = time.perf_counter()
    solver.solve(spec).block_until_ready()
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = solver.solve(spec).block_until_ready()
    t_run = time.perf_counter() - t0

    nm = res.normalized()
    print(f"\nEIC heuristic SSSP: {t_run*1e3:.1f} ms "
          f"(+{t_compile - t_run:.1f}s compile, once)")
    print(f"  nFrontier={nm['nFrontier']:.3f}  (paper: 1.01-1.10 — "
          f"~all extended paths are shortest paths)")
    print(f"  nSync    ={nm['nSync']:.2f} x log2|V| (paper: 1.55-6.13)")
    print(f"  nTrav    ={nm['nTrav']:.2f} edges/vertex vs |E|/|V|="
          f"{g.m/2/g.n:.1f} (paper: < half the edges)")
    print(f"  steps={nm['n_steps']} rounds={nm['n_rounds']} "
          f"reachable={nm['reachable']}")

    dref, _ = dijkstra_host(g, src)
    dist = np.asarray(res.dist)
    ok = np.allclose(np.where(np.isfinite(dist), dist, -1),
                     np.where(np.isfinite(dref), dref, -1), rtol=1e-4)
    print(f"\ncorrectness vs Dijkstra oracle: {'OK' if ok else 'MISMATCH'}")

    # an early-exit point-to-point query on the same session (the layout
    # and jit cache are already warm); the target distance is bitwise
    # equal to the full tree's, at a fraction of the stepping rounds
    tgt = int(np.flatnonzero(np.isfinite(dist))[-1])
    p2p = solver.solve(SolveSpec.p2p(src, tgt)).block_until_ready()
    path = p2p.paths()
    print(f"p2p {src}->{tgt}: dist={p2p.distance():.4f} "
          f"hops={len(path) - 1 if path else None} "
          f"rounds={int(np.asarray(p2p.metrics.n_rounds))} "
          f"(tree ran {nm['n_rounds']})")

    t0 = time.perf_counter()
    bf_dist, _, bf_m = bellman_ford(solver.device_graph, src)
    jax.block_until_ready(bf_dist)
    _ = time.perf_counter() - t0
    t0 = time.perf_counter()
    bf_dist, _, bf_m = bellman_ford(solver.device_graph, src)
    jax.block_until_ready(bf_dist)
    t_bf = time.perf_counter() - t0
    eic_trav = int(np.asarray(res.metrics.n_trav)) \
        + int(np.asarray(res.metrics.n_pull_trav))
    print(f"Bellman-Ford baseline: {t_bf*1e3:.1f} ms "
          f"({int(bf_m.n_trav)} traversals vs EIC {eic_trav})")


if __name__ == "__main__":
    main()
