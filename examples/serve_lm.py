"""Serving example: batched prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--batch 4] [--gen 32]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models.transformer import (LMConfig, decode_step, init_params,
                                      prefill)  # noqa: E402
from repro.data.synthetic import LMTokenStream  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = LMConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=4,
                   n_kv=2, d_ff=1024, vocab=8192, dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    stream = LMTokenStream(cfg.vocab, seed=1)
    prompts = jnp.asarray(stream.batch(0, args.batch, args.prompt_len))

    s_cache = args.prompt_len + args.gen
    prefill_j = jax.jit(lambda p, t: prefill(cfg, p, t, s_cache))
    decode_j = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    t0 = time.perf_counter()
    cache, logits = prefill_j(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms (incl. compile)")

    toks = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode_j(params, cache, toks)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    tps = args.batch * (args.gen - 1) / dt
    print(f"decode: {args.gen - 1} steps x {args.batch} seqs = "
          f"{tps:.0f} tok/s (CPU, interpret-grade)")
    gen = jnp.stack(out, 1)
    print(f"generated shape: {gen.shape}; first row: {gen[0][:16]}")


if __name__ == "__main__":
    main()
