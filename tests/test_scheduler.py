"""Admission scheduler: priority/FIFO order, deadlines, padding, ecc
batching, load shedding, rounds feedback, double-buffered worker."""
import time

import numpy as np
import pytest

from repro.core.sssp import sssp
from repro.data.generators import road_grid
from repro.serve.queries import Query
from repro.serve.registry import GraphRegistry
from repro.serve.scheduler import (DeadlineExceeded, QueryScheduler,
                                   QueueFull)


SIDE = 12


@pytest.fixture()
def registry():
    reg = GraphRegistry(capacity=2)
    reg.register("road", road_grid(SIDE, seed=5))
    return reg


def test_priority_then_fifo_ordering(registry):
    sch = QueryScheduler(registry, max_batch=1)
    done_order = []

    def track(tag):
        return lambda fut: done_order.append(tag)

    for tag, prio in [("a0", 0), ("b1", 1), ("c0", 0), ("d2", 2), ("e1", 1)]:
        fut = sch.submit(Query(gid="road", source=0), priority=prio)
        fut.add_done_callback(track(tag))
    sch.drain()
    # highest priority first; FIFO within a priority level
    assert done_order == ["d2", "b1", "e1", "a0", "c0"]


def test_padded_slots_never_leak(registry):
    sch = QueryScheduler(registry, max_batch=8)
    srcs = [5, 17, 40]
    futs = [sch.submit(Query(gid="road", source=s)) for s in srcs]
    assert sch.step()
    stats = sch.stats()
    assert stats["n_done"] == 3 and stats["n_batches"] == 1
    assert stats["occupancy"] == pytest.approx(3 / 8)
    dg = registry.engine("road").g
    for s, fut in zip(srcs, futs):
        res = fut.result(timeout=0)
        d_ref, p_ref, _ = sssp(dg, s)
        # each response is its own source's tree, not the padding slot's
        np.testing.assert_array_equal(res.dist, np.asarray(d_ref))
        np.testing.assert_array_equal(res.parent, np.asarray(p_ref))


def test_cancelled_future_with_deadline_does_not_break_step(registry):
    sch = QueryScheduler(registry, max_batch=2)
    doomed = sch.submit(Query(gid="road", source=1), deadline_s=0.0)
    assert doomed.cancel()
    ok = sch.submit(Query(gid="road", source=2))
    time.sleep(0.01)
    sch.drain()                    # must not raise InvalidStateError
    assert ok.result(timeout=0).dist is not None


def test_admit_window_validation(registry):
    with pytest.raises(ValueError):
        QueryScheduler(registry, admit_window=0)


def test_deadline_expiry(registry):
    sch = QueryScheduler(registry, max_batch=2)
    doomed = sch.submit(Query(gid="road", source=1), deadline_s=0.0)
    alive = sch.submit(Query(gid="road", source=2), deadline_s=60.0)
    time.sleep(0.01)
    sch.drain()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=0)
    assert alive.result(timeout=0).dist is not None
    assert sch.stats()["n_expired"] == 1


def test_ecc_batch_grouping(registry):
    """Companion slots are ecc-nearest to the head, not FIFO-next."""
    ecc = registry.engine("road").ecc_hint
    order = np.argsort(ecc)
    near_a, near_b = int(order[0]), int(order[1])     # close to landmark
    far = int(order[-1])                              # opposite periphery
    assert ecc[far] - ecc[near_a] > ecc[near_b] - ecc[near_a]
    sch = QueryScheduler(registry, max_batch=2)
    f_near_a = sch.submit(Query(gid="road", source=near_a))
    f_far = sch.submit(Query(gid="road", source=far))
    f_near_b = sch.submit(Query(gid="road", source=near_b))
    assert sch.step()
    # head (near_a) rides with its ecc-neighbor, skipping the FIFO-next far
    assert f_near_a.done() and f_near_b.done()
    assert not f_far.done()
    sch.drain()
    assert f_far.done()


def test_fifo_companions_without_ecc_batching(registry):
    sch = QueryScheduler(registry, max_batch=2, ecc_batching=False)
    corner_a, corner_b = 0, SIDE * SIDE - 1
    center = SIDE * (SIDE // 2) + SIDE // 2
    f1 = sch.submit(Query(gid="road", source=corner_a))
    f2 = sch.submit(Query(gid="road", source=center))
    f3 = sch.submit(Query(gid="road", source=corner_b))
    assert sch.step()
    assert f1.done() and f2.done() and not f3.done()
    sch.drain()


def test_engine_failure_fails_batch_not_scheduler(registry):
    sch = QueryScheduler(registry, max_batch=2)
    bad = sch.submit(Query(gid="unregistered", source=0))
    good = sch.submit(Query(gid="road", source=3))
    sch.drain()
    with pytest.raises(KeyError):
        bad.result(timeout=0)
    assert good.result(timeout=0).dist is not None


def test_unknown_gid_overflow_group_does_not_kill_scheduler(registry):
    # > max_batch same-key tickets trigger the ecc-grouping engine lookup
    # during selection; an unknown gid must fail the futures, not step()
    sch = QueryScheduler(registry, max_batch=2)
    futs = [sch.submit(Query(gid="unregistered", source=0))
            for _ in range(3)]
    ok = sch.submit(Query(gid="road", source=1))
    sch.drain()
    for f in futs:
        with pytest.raises(KeyError):
            f.result(timeout=0)
    assert ok.result(timeout=0).dist is not None


def test_out_of_range_vertices_fail_loudly(registry):
    n = SIDE * SIDE
    sch = QueryScheduler(registry, max_batch=2)
    bad_src = sch.submit(Query(gid="road", source=n + 5))
    bad_tgt = sch.submit(Query(gid="road", source=0, kind="p2p", target=n))
    good = sch.submit(Query(gid="road", source=0))
    sch.drain()
    with pytest.raises(ValueError):
        bad_src.result(timeout=0)
    with pytest.raises(ValueError):
        bad_tgt.result(timeout=0)
    assert good.result(timeout=0).dist is not None
    with pytest.raises(ValueError):
        Query(gid="road", source=-1)
    with pytest.raises(ValueError):
        Query(gid="road", source=0, kind="knear", k=0)


def test_finalized_arrays_expose_only_settled_values(registry):
    sch = QueryScheduler(registry, max_batch=2)
    f_p2p = sch.submit(Query(gid="road", source=0, kind="p2p", target=30))
    f_k = sch.submit(Query(gid="road", source=0, kind="knear", k=5))
    sch.drain()
    r = f_p2p.result(timeout=0)
    # every finite entry is settled: nothing beyond the target's distance
    assert np.isfinite(r.distance)
    finite = np.isfinite(r.dist)
    assert np.all(r.dist[finite] <= r.distance)
    assert np.all(r.parent[~finite] == -1)
    rk = f_k.result(timeout=0)
    assert int(np.isfinite(rk.dist).sum()) == 5 + 1   # k nearest + source


def test_bounded_queue_rejects_at_submit_time(registry):
    sch = QueryScheduler(registry, max_batch=2, max_pending=2)
    f1 = sch.submit(Query(gid="road", source=0))
    f2 = sch.submit(Query(gid="road", source=1))
    with pytest.raises(QueueFull):
        sch.submit(Query(gid="road", source=2))
    assert sch.stats()["rejected"] == 1
    # shedding is submit-time back-pressure: draining frees capacity
    sch.drain()
    assert f1.result(timeout=0).dist is not None
    assert f2.result(timeout=0).dist is not None
    f3 = sch.submit(Query(gid="road", source=2))
    sch.drain()
    assert f3.result(timeout=0).dist is not None
    with pytest.raises(ValueError):
        QueryScheduler(registry, max_pending=0)


def test_measured_rounds_feed_back_into_batch_hint(registry):
    sch = QueryScheduler(registry, max_batch=2, feedback_gamma=0.5)
    eng = registry.engine("road")
    before = eng.batch_hint.copy()
    srcs = [5, 17]
    futs = [sch.submit(Query(gid="road", source=s)) for s in srcs]
    assert sch.step()
    rounds = [futs[i].result(timeout=0).metrics["n_rounds"]
              for i in range(2)]
    for s, r in zip(srcs, rounds):
        assert eng.batch_hint[s] == pytest.approx(
            0.5 * before[s] + 0.5 * r)
    # feedback off leaves hints untouched
    sch2 = QueryScheduler(registry, max_batch=2, feedback=False)
    after = eng.batch_hint.copy()
    sch2.submit(Query(gid="road", source=40))
    sch2.drain()
    np.testing.assert_array_equal(eng.batch_hint, after)


def test_double_buffered_worker_pipelines_batches(registry):
    """The background worker keeps one batch in flight while finalizing
    the previous one; many small batches must all resolve correctly."""
    sch = QueryScheduler(registry, max_batch=2, ecc_batching=False)
    dg = registry.engine("road").g
    sch.start()
    try:
        srcs = list(range(0, 24))
        futs = [sch.submit(Query(gid="road", source=s)) for s in srcs]
        for s, fut in zip(srcs, futs):
            res = fut.result(timeout=300)
            d_ref, _, _ = sssp(dg, s)
            np.testing.assert_array_equal(res.dist, np.asarray(d_ref))
    finally:
        sch.stop()
    st = sch.stats()
    assert st["n_done"] == 24 and st["pending"] == 0 \
        and st["inflight"] == 0


def test_background_worker(registry):
    sch = QueryScheduler(registry, max_batch=2)
    sch.start()
    try:
        futs = [sch.submit(Query(gid="road", source=s, kind="p2p", target=t))
                for s, t in [(0, 5), (7, 100), (30, 31)]]
        for fut in futs:
            res = fut.result(timeout=120)
            assert res.distance is not None
            assert res.latency_s >= 0
            if np.isfinite(res.distance):
                assert res.path[0] == res.query.source
                assert res.path[-1] == res.query.target
    finally:
        sch.stop()
    assert sch.stats()["pending"] == 0


def test_ecc_batch_grouping_holds_on_benchmark_graphs():
    """Batch formation under the multi-landmark hints still groups the
    head with its hint-nearest companion on the benchmark graph shapes
    (scaled down), not with the FIFO-next outlier."""
    from repro.data.generators import kronecker, uniform_random
    graphs = [("Road", road_grid(16, seed=5)),
              ("gr8_8", kronecker(8, 8, seed=2)),
              ("Urand", uniform_random(256, 16 * 256, seed=6))]
    grouped = 0
    for name, g in graphs:
        reg = GraphRegistry(capacity=1)
        reg.register("g", g)
        hint = reg.engine("g").batch_hint
        order = np.argsort(hint, kind="stable")
        near_a, near_b = int(order[0]), int(order[1])
        far = int(order[-1])
        if hint[far] - hint[near_a] <= hint[near_b] - hint[near_a]:
            continue                 # flat hints: nothing to distinguish
        sch = QueryScheduler(reg, max_batch=2)
        f_near_a = sch.submit(Query(gid="g", source=near_a))
        f_far = sch.submit(Query(gid="g", source=far))
        f_near_b = sch.submit(Query(gid="g", source=near_b))
        assert sch.step(), name
        assert f_near_a.done() and f_near_b.done(), name
        assert not f_far.done(), name
        sch.drain()
        grouped += 1
    assert grouped >= 2              # the suite shapes actually exercised it
