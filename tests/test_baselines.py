"""Baseline SSSP implementations agree with the Dijkstra oracle."""
import numpy as np
import pytest

from repro.core.baselines import bellman_ford, delta_stepping, dijkstra_host
from repro.data.generators import kronecker, road_grid


@pytest.fixture(scope="module", params=["kron", "road"])
def graph(request):
    if request.param == "kron":
        return kronecker(10, 8, seed=11)
    return road_grid(24, seed=12)


def test_bellman_ford(graph):
    src = int(np.argmax(graph.deg))
    dist, _, m = bellman_ford(graph.to_device(), src)
    dref, _ = dijkstra_host(graph, src)
    np.testing.assert_allclose(
        np.where(np.isfinite(dist), dist, -1),
        np.where(np.isfinite(dref), dref, -1), rtol=1e-4, atol=1e-5)
    assert int(m.n_rounds) > 0


@pytest.mark.parametrize("delta", [0.1, 0.3, 1.0])
def test_delta_stepping(graph, delta):
    src = int(np.argmax(graph.deg))
    dist, _, m = delta_stepping(graph.to_device(), src, delta)
    dref, _ = dijkstra_host(graph, src)
    np.testing.assert_allclose(
        np.where(np.isfinite(dist), dist, -1),
        np.where(np.isfinite(dref), dref, -1), rtol=1e-4, atol=1e-5)
