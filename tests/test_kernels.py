"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.graph import bucket_edges
from repro.kernels.edge_relax.ops import (edge_relax, edge_relax_ref,
                                          relax_bucket, schedule_tiles)
from repro.kernels.flash_attn.ops import flash_attention, flash_attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag, embedding_bag_ref


# --- edge_relax -------------------------------------------------------------

def _bucketize(src, dst, w, *, n_dst_blocks, block_v, tile_e):
    """Tile-align a random slab for the ragged kernel grid."""
    se, de, we, td, tf, bne, _ = bucket_edges(
        src, dst, w, n_dst_blocks=n_dst_blocks, block_v=block_v,
        tile_e=tile_e)
    return (jnp.asarray(se), jnp.asarray(de), jnp.asarray(we),
            jnp.asarray(td), jnp.asarray(tf), jnp.asarray(bne))


def _run_both(dist, front, src, dst, w, lb, ub, *, bv, n_dst_blocks,
              tile_e):
    se, de, we, td, tf, bne = _bucketize(
        src, dst, w, n_dst_blocks=n_dst_blocks, block_v=bv, tile_e=tile_e)
    out_v, out_w, n_tiles = edge_relax(
        jnp.asarray(dist), jnp.asarray(front), se, de, we, td, tf, bne,
        lb, ub, block_v=bv, tile_e=tile_e, n_dst_blocks=n_dst_blocks)
    # the oracle is dense over the same (bucketed) slab — the compacted
    # schedule must not change any result
    ref_v, ref_w = edge_relax_ref(
        jnp.asarray(dist), jnp.asarray(front), se, de, we, lb, ub,
        block_v=bv, n_dst_blocks=n_dst_blocks)
    np.testing.assert_allclose(np.asarray(out_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(out_w), np.asarray(ref_w))
    assert 1 <= int(n_tiles) <= td.shape[0]
    return out_v, out_w, int(n_tiles), td.shape[0]


@pytest.mark.parametrize("bs,bv,e", [(256, 256, 500), (512, 512, 2000),
                                     (128, 512, 64), (512, 128, 1)])
@pytest.mark.parametrize("window", [(0.0, np.inf), (0.3, 0.9)])
def test_edge_relax_shapes(bs, bv, e, window):
    rng = np.random.default_rng(bs + e)
    dist = np.where(rng.random(bs) < 0.6,
                    rng.random(bs).astype(np.float32), np.inf)
    front = (rng.random(bs) < 0.4).astype(np.int8)
    src = rng.integers(0, bs, e).astype(np.int32)
    dst = rng.integers(0, bv, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    lb, ub = window
    _run_both(dist, front, src, dst, w, lb, ub, bv=bv, n_dst_blocks=1,
              tile_e=512)


@pytest.mark.parametrize("bv,n_dst_blocks,tile_e", [(128, 3, 64),
                                                    (64, 5, 128),
                                                    (256, 2, 256)])
def test_edge_relax_multi_dst_block(bv, n_dst_blocks, tile_e):
    """Destinations spanning >1 block must all be computed through the
    per-bucket tile ranges, and winners must match the deterministic
    min-src tiebreak of the reference."""
    rng = np.random.default_rng(bv * n_dst_blocks)
    bs = 200
    e = 3000
    n_out = bv * n_dst_blocks
    dist = np.where(rng.random(bs) < 0.7,
                    rng.random(bs).astype(np.float32), np.inf)
    front = (rng.random(bs) < 0.6).astype(np.int8)
    src = rng.integers(0, bs, e).astype(np.int32)
    dst = rng.integers(0, n_out, e).astype(np.int32)
    # duplicate candidates force winner tie-breaks
    w = (rng.integers(1, 8, e) / 8.0).astype(np.float32)
    out_v, out_w, _, _ = _run_both(dist, front, src, dst, w, 0.1, 1.4,
                                   bv=bv, n_dst_blocks=n_dst_blocks,
                                   tile_e=tile_e)
    assert out_v.shape == (n_out,) and out_w.shape == (n_out,)
    # every dst block must receive candidates (not just block 0)
    finite_per_block = np.isfinite(np.asarray(out_v)).reshape(
        n_dst_blocks, bv).sum(axis=1)
    assert (finite_per_block > 0).all(), finite_per_block


def test_edge_relax_frontier_compaction_skips_tiles():
    """A narrow frontier schedules only the touched tiles (plus the
    forced per-bucket first tiles) — and still matches the dense oracle."""
    bv, n_dst_blocks, tile_e = 64, 4, 32
    bs = 128
    rng = np.random.default_rng(7)
    e = 2000
    src = rng.integers(0, bs, e).astype(np.int32)
    dst = rng.integers(0, bv * n_dst_blocks, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    dist = rng.random(bs).astype(np.float32)
    # exactly one frontier source
    front = np.zeros(bs, np.int8)
    front[17] = 1
    _, _, n_active, nt = _run_both(dist, front, src, dst, w, 0.0, np.inf,
                                   bv=bv, n_dst_blocks=n_dst_blocks,
                                   tile_e=tile_e)
    assert n_active < nt        # the compacted schedule skipped tiles
    # empty frontier degenerates to the forced first tiles only
    _, _, n_empty, _ = _run_both(dist, np.zeros(bs, np.int8), src, dst, w,
                                 0.0, np.inf, bv=bv,
                                 n_dst_blocks=n_dst_blocks, tile_e=tile_e)
    assert n_empty <= n_dst_blocks


def test_relax_bucket_ref_path_matches_kernel():
    """use_kernel=False (the jnp fallback) is bitwise-identical and
    reports the same schedule size."""
    bv, nb, tile_e = 64, 3, 32
    rng = np.random.default_rng(3)
    e = 700
    bs = 64
    src = rng.integers(0, bs, e).astype(np.int32)
    dst = rng.integers(0, bv * nb, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    dist = rng.random(bs).astype(np.float32)
    front = (rng.random(bs) < 0.3).astype(np.int8)
    se, de, we, td, tf, bne = _bucketize(src, dst, w, n_dst_blocks=nb,
                                         block_v=bv, tile_e=tile_e)
    outs = [relax_bucket(jnp.asarray(dist), jnp.asarray(front), se, de,
                         we, td, tf, bne, 0.1, 0.9, block_v=bv,
                         n_dst_blocks=nb, tile_e=tile_e, use_kernel=uk)
            for uk in (True, False)]
    np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                  np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                  np.asarray(outs[1][1]))
    assert int(outs[0][2]) == int(outs[1][2])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_edge_relax_property(seed):
    rng = np.random.default_rng(seed)
    bs = int(rng.integers(8, 300))
    bv = int(rng.integers(8, 300))
    nb = int(rng.integers(1, 4))
    tile_e = int(2 ** rng.integers(3, 8))
    e = int(rng.integers(1, 800))
    dist = np.where(rng.random(bs) < 0.7,
                    (rng.random(bs) * 3).astype(np.float32), np.inf)
    front = (rng.random(bs) < 0.5).astype(np.int8)
    src = rng.integers(0, bs, e).astype(np.int32)
    dst = rng.integers(0, bv * nb, e).astype(np.int32)
    w = (rng.random(e) * 2).astype(np.float32)
    lb = float(rng.random() * 2)
    ub = lb + float(rng.random() * 2) + 1e-3
    _run_both(dist, front, src, dst, w, lb, ub, bv=bv, n_dst_blocks=nb,
              tile_e=tile_e)


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 4, 2, 200, 32), (1, 8, 8, 130, 64), (2, 2, 1, 64, 128),
    (1, 4, 4, 257, 16),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 31),
                                           (False, 0)])
def test_flash_attention_shapes(b, h, hkv, s, d, causal, window):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, s, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 128, 64))).astype(dtype)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64))).astype(dtype)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64))).astype(dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --- embedding bag ------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(64, 16, 4, 3), (300, 32, 8, 7),
                                     (1000, 64, 2, 20)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_shapes(v, d, b, l, mode, weighted):
    rng = np.random.default_rng(v + l)
    table = jnp.asarray(rng.normal(0, 1, (v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, v, (b, l)).astype(np.int32))
    w = jnp.asarray(rng.random((b, l)).astype(np.float32)) if weighted \
        else None
    out = embedding_bag(table, ids, w, mode=mode)
    ref = embedding_bag_ref(table, ids, w, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
