"""Serving-plane observability: one metrics registry, deterministic time.

The acceptance contract: a single ``SsspService.metrics_snapshot()`` (or
its Prometheus exposition) covers the engine registry, every scheduler,
and the router, with latency histograms whose p50/p99 are exact under an
injected fake clock — no sleeps, no wall-clock flake.  The legacy
``stats()`` dicts and counter attributes must keep working as pure
read-throughs of the same series.
"""
import json

import numpy as np
import pytest

from repro.data.generators import kronecker
from repro.serve.queries import Query
from repro.serve.registry import GraphRegistry
from repro.serve.scheduler import DeadlineExceeded, QueryScheduler
from repro.serve.sssp_service import SsspRequest, SsspService
from repro.obs.export import parse_prometheus


class FakeClock:
    """Monotonic fake time: call to read, ``advance`` to move."""

    def __init__(self, start: float = 100.0):
        self.now = start

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += dt


@pytest.fixture(scope="module")
def graph():
    return kronecker(8, 4, seed=0)


def _scheduler(graph, clock, **kw):
    reg = GraphRegistry(capacity=2)
    reg.register("g", graph)
    return QueryScheduler(reg, max_batch=4, ecc_batching=False,
                         clock=clock, **kw)


def test_deterministic_latency_histogram(graph):
    clock = FakeClock()
    sch = _scheduler(graph, clock)
    for i in range(4):
        sch.submit(Query(gid="g", source=i))
    clock.advance(2.0)
    assert sch.step()
    # all 4 latencies are exactly 2.0s -> the (1.0, 2.5] default bucket;
    # histogram_quantile interpolation is then fully determined:
    #   pXX = 1.0 + 1.5 * (q * 4) / 4
    h = sch._h_latency
    assert h.count == 4
    assert h.sum == pytest.approx(8.0)
    assert h.percentile(0.50) == pytest.approx(1.0 + 1.5 * 0.50)
    assert h.percentile(0.99) == pytest.approx(1.0 + 1.5 * 0.99)
    snap = sch.metrics.snapshot()
    entry = snap['sssp_query_latency_seconds{scheduler="default"}']
    assert entry["count"] == 4
    assert entry["p50"] == pytest.approx(1.75)
    assert entry["p99"] == pytest.approx(2.485)


def test_deadline_expiry_on_fake_clock(graph):
    clock = FakeClock()
    sch = _scheduler(graph, clock)
    doomed = sch.submit(Query(gid="g", source=0), deadline_s=1.0)
    alive = sch.submit(Query(gid="g", source=1), deadline_s=60.0)
    clock.advance(5.0)           # past the first deadline only
    assert sch.step()
    assert isinstance(doomed.exception(), DeadlineExceeded)
    assert alive.exception() is None and alive.result().dist is not None
    assert sch.n_expired == 1
    assert sch.n_done == 1
    snap = sch.metrics.snapshot()
    assert snap['sssp_scheduler_expired_total{scheduler="default"}'][
        "value"] == 1
    # queue fully drained -> gauges back to zero
    assert snap['sssp_scheduler_pending{scheduler="default"}']["value"] == 0
    assert snap['sssp_scheduler_inflight{scheduler="default"}']["value"] == 0


def test_submit_now_override(graph):
    # per-call _now beats the constructor clock (deterministic repro of
    # one query's timeline without faking the whole scheduler)
    clock = FakeClock(start=50.0)
    sch = _scheduler(graph, clock)
    fut = sch.submit(Query(gid="g", source=0), deadline_s=1.0, _now=10.0)
    # scheduler time (50) is already past 10 + 1 -> expired on dispatch
    assert sch.step() is False   # the only ticket expired, nothing ran
    assert isinstance(fut.exception(), DeadlineExceeded)


def test_stats_dict_reads_through_metrics(graph):
    clock = FakeClock()
    sch = _scheduler(graph, clock)
    for i in range(6):
        sch.submit(Query(gid="g", source=i))
    sch.drain()
    st = sch.stats()
    assert st["n_batches"] == sch.n_batches == sch._c_batches.value
    assert st["n_done"] == 6
    assert st["registry"]["builds"] == sch.registry.stats.builds == 1
    assert st["occupancy"] == pytest.approx(
        st["n_done"] / (st["n_batches"] * sch.max_batch))


def test_service_single_snapshot_covers_all_layers(graph):
    clock = FakeClock()
    svc = SsspService(graph, max_batch=4, clock=clock)
    for i in range(8):
        svc.submit(SsspRequest(rid=i, source=i))
        clock.advance(0.125)
    svc.run()
    snap = svc.metrics_snapshot()
    bases = {name.split("{", 1)[0] for name in snap}
    # registry + scheduler series through the one registry
    assert {"sssp_registry_hits_total", "sssp_registry_builds_total",
            "sssp_scheduler_batches_total",
            "sssp_scheduler_queries_done_total",
            "sssp_query_latency_seconds"} <= bases
    assert snap['sssp_scheduler_queries_done_total{scheduler="default"}'][
        "value"] == 8
    lat = snap['sssp_query_latency_seconds{scheduler="default"}']
    assert lat["count"] == 8
    assert np.isfinite(lat["p50"]) and np.isfinite(lat["p99"])
    assert lat["p50"] <= lat["p99"]
    # exposition round-trips through the strict parser
    parsed = parse_prometheus(svc.metrics_exposition())
    assert parsed[
        'sssp_scheduler_queries_done_total{scheduler="default"}'] == 8
    assert parsed['sssp_query_latency_seconds_bucket'
                  '{le="+Inf",scheduler="default"}'] == 8


def test_service_routed_snapshot_includes_router(graph):
    import jax
    svc = SsspService(graph, max_batch=4, devices=jax.devices()[:1])
    for i in range(4):
        svc.submit(SsspRequest(rid=i, source=i))
    svc.run()
    snap = svc.metrics_snapshot()
    bases = {name.split("{", 1)[0] for name in snap}
    assert "sssp_router_routed_total" in bases
    assert snap["sssp_router_routed_total"]["value"] == 4
    # router legacy attributes read the same series
    assert svc.router.n_routed == 4
    assert svc.router.stats()["n_routed"] == 4


def test_service_jsonl_dump(graph, tmp_path):
    svc = SsspService(graph, max_batch=2)
    svc.submit(SsspRequest(rid=0, source=0))
    svc.run()
    path = tmp_path / "serve_metrics.jsonl"
    snap = svc.dump_metrics_jsonl(path, run="unit")
    rec = json.loads(path.read_text().strip())
    assert rec["run"] == "unit"
    assert rec["metrics"] == json.loads(json.dumps(snap))
    done = 'sssp_scheduler_queries_done_total{scheduler="default"}'
    assert rec["metrics"][done]["value"] == 1


def test_queue_full_counts_rejection(graph):
    clock = FakeClock()
    sch = _scheduler(graph, clock, max_pending=2)
    from repro.serve.scheduler import QueueFull
    sch.submit(Query(gid="g", source=0))
    sch.submit(Query(gid="g", source=1))
    with pytest.raises(QueueFull):
        sch.submit(Query(gid="g", source=2))
    assert sch.n_rejected == 1
    sch.drain()
    assert sch.n_done == 2
