"""Tile-range construction for the blocked layout (core/graph.py).

The CSR-of-tiles index is what the ragged kernel grid trusts blindly, so
its invariants are unit-tested directly: tiles never straddle destination
blocks, empty buckets own zero tiles, `tile_first` marks exactly the
schedulable entry of every non-empty bucket, and shard slices line up
with `shard_graph`'s vertex ownership.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.graph import (BlockedGraph, bucket_edges, build_blocked,
                              shard_block_v, slice_for_shard)
from repro.data.generators import kronecker, road_grid


def _check_bucket_invariants(se, de, we, td, tf, bne, tp, *, n_dst_blocks,
                             block_v, tile_e):
    nt = td.shape[0]
    assert se.shape == de.shape == we.shape == (nt * tile_e,)
    assert tf.shape == (nt,)
    assert bne.shape == (n_dst_blocks,)
    assert tp.shape == (n_dst_blocks + 1,)
    # every real (finite-w) edge sits in a tile owned by its dst block
    real = np.isfinite(we)
    tile_of = np.arange(nt * tile_e) // tile_e
    np.testing.assert_array_equal(td[tile_of[real]],
                                  de[real] // block_v)
    # tile_dst is non-decreasing over the real tile range (out-spec
    # revisiting requires dst-sorted tiles)
    nt_real = int(tp[-1])
    assert (np.diff(td[:max(nt_real, 1)]) >= 0).all()
    # CSR expansion matches tile_dst
    for b in range(n_dst_blocks):
        assert (td[tp[b]:tp[b + 1]] == b).all()
    # tile_first marks the first tile of every non-empty bucket + tile 0
    expect_first = np.zeros(nt, bool)
    expect_first[tp[:-1][bne]] = True
    expect_first[0] = True
    np.testing.assert_array_equal(tf, expect_first)


def test_bucket_edges_empty_and_single_tile_buckets():
    block_v, tile_e, nb = 4, 4, 4
    # bucket 0: 5 edges (2 tiles), bucket 2: 1 edge (single tile),
    # buckets 1 and 3: empty
    dst = np.array([0, 1, 2, 3, 0, 8], np.int32)
    src = np.arange(6, dtype=np.int32)
    w = np.ones(6, np.float32)
    out = bucket_edges(src, dst, w, n_dst_blocks=nb, block_v=block_v,
                      tile_e=tile_e)
    se, de, we, td, tf, bne, tp = out
    _check_bucket_invariants(*out, n_dst_blocks=nb, block_v=block_v,
                             tile_e=tile_e)
    np.testing.assert_array_equal(bne, [True, False, True, False])
    np.testing.assert_array_equal(tp, [0, 2, 2, 3, 3])   # empty buckets: 0 tiles
    assert td.shape == (3,)
    # padding slots never activate a tile
    assert np.isinf(we[~np.isfinite(we)]).all()
    assert (~np.isfinite(we)).sum() == 3 * tile_e - 6


def test_bucket_edges_all_empty_slab():
    out = bucket_edges(np.zeros(0, np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.float32), n_dst_blocks=3, block_v=8,
                       tile_e=4)
    se, de, we, td, tf, bne, tp = out
    assert td.shape == (1,)                 # grid is never empty
    assert tf[0] and not bne.any()
    assert np.isinf(we).all()


def test_bucket_edges_uniform_padding_and_overflow():
    src = np.zeros(10, np.int32)
    dst = np.arange(10, dtype=np.int32)
    w = np.ones(10, np.float32)
    out = bucket_edges(src, dst, w, n_dst_blocks=2, block_v=8, tile_e=4,
                       n_tiles=7)
    se, de, we, td, tf, bne, tp = out
    assert td.shape == (7,)
    # surplus pad tiles repeat the last real block id (no back-revisit)
    nt_real = int(tp[-1])
    assert (td[nt_real:] == td[nt_real - 1]).all()
    with pytest.raises(ValueError, match="n_tiles"):
        bucket_edges(src, dst, w, n_dst_blocks=2, block_v=8, tile_e=4,
                     n_tiles=1)


def test_build_blocked_invariants():
    g = kronecker(9, 8, seed=3)
    bg = build_blocked(g, block_v=128, tile_e=64)
    assert isinstance(bg, BlockedGraph)
    assert bg.n_blocks == bg.n_dst_blocks == -(-g.n // 128)
    assert bg.src_base == 0
    total_real = 0
    for slab in bg.slabs:
        we = np.asarray(slab.w)
        total_real += int(np.isfinite(we).sum())
        td = np.asarray(slab.tile_dst)
        real = np.isfinite(we)
        tile_of = np.arange(we.shape[0]) // bg.tile_e
        np.testing.assert_array_equal(
            td[tile_of[real]], np.asarray(slab.dst)[real] // bg.block_v)
    assert total_real == g.m                # no edge lost or duplicated
    # the ragged layout's static tile count undercuts the dense grid
    ragged = sum(s.tile_dst.shape[0] for s in bg.slabs)
    assert ragged < bg.dense_grid_tiles


def test_shard_block_v():
    assert shard_block_v(256, 512) == 256
    assert shard_block_v(256, 128) == 128
    assert shard_block_v(100, 64) == 50     # snapped to a divisor
    assert shard_block_v(7, 4) == 1
    with pytest.raises(ValueError):
        shard_block_v(0, 4)


def test_slice_for_shard_partitions_edges():
    g = road_grid(20, seed=2)
    p = 4
    block = -(-g.n // p)
    total = 0
    for q in range(p):
        bg = slice_for_shard(g, q, p, block_v=64, tile_e=32)
        assert bg.src_base == q * block
        assert bg.n_blocks * bg.block_v == block
        assert bg.n_dst_blocks * bg.block_v == block * p
        lo = q * block
        for sb, slab in enumerate(bg.slabs):
            we = np.asarray(slab.w)
            real = np.isfinite(we)
            total += int(real.sum())
            # block-local sources stay inside their src block
            sl = np.asarray(slab.src_local)[real]
            assert ((0 <= sl) & (sl < bg.block_v)).all()
            # ... and the global ids they encode are owned by this shard
            gsrc = sl + lo + sb * bg.block_v
            assert ((gsrc >= lo) & (gsrc < lo + block)).all()
    assert total == g.m


def test_slice_for_shard_uniform_tiles():
    g = kronecker(8, 6, seed=5)
    bgs = [slice_for_shard(g, q, 2, block_v=64, tile_e=64, n_tiles=32)
           for q in range(2)]
    for bg in bgs:
        for slab in bg.slabs:
            assert slab.tile_dst.shape == (32,)
            assert slab.w.shape == (32 * 64,)
