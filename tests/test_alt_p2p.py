"""ALT goal-directed p2p: the exactness gate and the artifact lifecycle.

The ALT pruning contract is *bitwise exactness*: a p2p solve with
landmark lower bounds must return the same ``dist[target]`` and the same
reconstructed parent chain as the unpruned solve — pruning may only drop
candidates that provably cannot improve d(s, t).  These tests enforce
that across the full 9-graph benchmark suite (scale-reduced) on the
segment_min, blocked_pallas and fused-megakernel backends, between the
unidirectional and bidirectional p2p modes, and (in a subprocess with 8
forced host devices) through the sharded shard_map engine.

Lifecycle coverage: the registry's per-gid LandmarkSet cache must share
one build across engine variants, rebuild on re-``register`` (spec
generation bump) and on changed build parameters; the TunedStore
fingerprint must fold the ALT parameters so a winner tuned under one
landmark set never silently applies under another; and the admissibility
invariant lb[v] <= d(v, t) is property-tested (hypothesis when
installed, a seeded sweep always).
"""
import os
import subprocess
import sys
import zlib

import numpy as np
import pytest

from repro.api import EngineConfig, SolveSpec, Solver
from repro.core.baselines import dijkstra_host
from repro.core.landmarks import (LandmarkSet, build_landmarks, hop_bfs,
                                  select_landmarks)
from repro.core.relax import alt_lower_bounds
from repro.core.sssp import sssp
from repro.data.generators import kronecker, road_grid, uniform_random
from repro.serve.queries import reconstruct_path

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SCALE = 8   # 256 vertices: the full 9-graph structure at test size


def benchmark_graphs():
    """The benchmark suite's 9 structural analogues, scale-reduced
    (mirrors ``benchmarks.common.benchmark_graphs``)."""
    n = 1 << SCALE
    side = int(np.sqrt(n))
    return {
        "gr_4": kronecker(SCALE, 4, seed=1),
        "gr_8": kronecker(SCALE, 8, seed=2),
        "gr_16": kronecker(SCALE, 16, seed=3),
        "gr_32": kronecker(SCALE, 32, seed=4),
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 16 * n, seed=6),
        "Web": kronecker(SCALE, 30, seed=7),
        "Twitter": kronecker(SCALE, 22, seed=8),
        "Kron": kronecker(SCALE, 32, seed=9),
    }


def pick_pair(g, seed=0):
    """A (source, target) pair with both endpoints non-isolated and, when
    possible, actually connected (a reachable target is what exercises
    pruning; an unreachable one only exercises the no-path case)."""
    rng = np.random.default_rng(seed)
    nz = np.where(np.asarray(g.deg) > 0)[0]
    s = int(rng.choice(nz))
    row_ptr = np.asarray(g.row_ptr, np.int64)
    dst = np.asarray(g.dst, np.int64)
    hop = hop_bfs(row_ptr, dst, int(g.n), s)
    reach = np.where(hop > 0)[0]
    t = int(rng.choice(reach if reach.size else nz[nz != s]))
    return s, t


def assert_p2p_identical(dist_a, parent_a, dist_b, parent_b, s, t, label):
    """The ALT exactness contract: d(s,t) bitwise + same parent chain."""
    da = np.asarray(dist_a)
    db = np.asarray(dist_b)
    assert da[t].tobytes() == db[t].tobytes(), \
        f"{label}: d(s,t) {da[t]} != {db[t]}"
    pa = reconstruct_path(np.asarray(parent_a), s, t)
    pb = reconstruct_path(np.asarray(parent_b), s, t)
    assert pa == pb, f"{label}: path {pa} != {pb}"


# ---------------------------------------------------------------------------
# the 9-graph bitwise gate, across relaxation backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,fused_rounds", [
    ("segment_min", 0),
    ("blocked_pallas", 0),
    ("blocked_pallas", 4),     # the fused-megakernel path prunes in-kernel
])
def test_alt_pruned_bitwise_parity_all_graphs(backend, fused_rounds):
    total_pruned = relax_alt = relax_ref = 0
    for name, g in benchmark_graphs().items():
        dg = g.to_device()
        s, t = pick_pair(g, seed=zlib.crc32(name.encode()) % 1000)
        lm = build_landmarks(dg, n_landmarks=4, strategy="farthest")
        kw = dict(backend=backend, fused_rounds=fused_rounds,
                  goal="p2p", goal_param=t)
        d0, p0, m0 = sssp(dg, s, **kw)
        d1, p1, m1 = sssp(dg, s, landmarks=lm, **kw)
        assert_p2p_identical(d0, p0, d1, p1, s, t,
                             f"{name}/{backend}/fused={fused_rounds}")
        # the unpruned run never touches the prune path; the ALT run's
        # skipped updates shrink the frontier, so it can also *exit*
        # earlier — work only compares in aggregate, exactness per query
        assert int(m0.n_pruned) == 0
        total_pruned += int(m1.n_pruned)
        relax_alt += int(m1.n_relax)
        relax_ref += int(m0.n_relax)
    # the suite as a whole must exercise the prune path and save work
    assert total_pruned > 0
    assert relax_alt < relax_ref


def test_alt_bidirectional_bitwise_parity_all_graphs():
    """Bidirectional meet-in-the-middle vs unidirectional (both ALT) vs
    the unpruned reference — one exactness contract for all three."""
    cfg_bi = EngineConfig(use_alt=True, p2p_mode="bidirectional",
                          n_landmarks=4)
    for name, g in benchmark_graphs().items():
        dg = g.to_device()
        s, t = pick_pair(g, seed=zlib.crc32(name.encode()) % 1000 + 7)
        lm = build_landmarks(dg, n_landmarks=4, strategy="farthest")
        d0, p0, m0 = sssp(dg, s, goal="p2p", goal_param=t)
        d1, p1, m1 = sssp(dg, s, goal="p2p", goal_param=t, landmarks=lm)
        d2, p2, m2 = sssp(dg, s, goal="p2p", goal_param=t, landmarks=lm,
                          config=cfg_bi)
        assert_p2p_identical(d0, p0, d1, p1, s, t, f"{name}/uni")
        assert_p2p_identical(d0, p0, d2, p2, s, t, f"{name}/bidi")


def test_alt_reduces_work_on_road_and_kron():
    """The issue's acceptance floor: ALT cuts relaxations (or rounds) by
    >= 1.5x on the Road and Kron analogues, bitwise-identically."""
    for g, seed in [(road_grid(24, seed=5), 3), (kronecker(10, 8, seed=2),
                                                 4)]:
        dg = g.to_device()
        s, t = pick_pair(g, seed=seed)
        lm = build_landmarks(dg, n_landmarks=8, strategy="farthest")
        d0, p0, m0 = sssp(dg, s, goal="p2p", goal_param=t)
        d1, p1, m1 = sssp(dg, s, goal="p2p", goal_param=t, landmarks=lm)
        assert_p2p_identical(d0, p0, d1, p1, s, t, "work-reduction")
        relax_ratio = int(m0.n_relax) / max(int(m1.n_relax), 1)
        round_ratio = int(m0.n_rounds) / max(int(m1.n_rounds), 1)
        assert max(relax_ratio, round_ratio) >= 1.5, \
            (relax_ratio, round_ratio)
        assert int(m1.n_pruned) > 0


def test_alt_strategies_and_directed_graphs():
    """max_degree selection and a directed (asymmetric) graph: pruning
    stays exact, and the directed build records sym=False (no reverse
    difference, no landmark-seeded upper bound)."""
    g = kronecker(SCALE, 8, seed=2)
    dg = g.to_device()
    s, t = pick_pair(g, seed=11)
    for strategy in ["farthest", "max_degree"]:
        lm = build_landmarks(dg, n_landmarks=4, strategy=strategy)
        assert lm.params() == (4, strategy)
        d0, p0, _ = sssp(dg, s, goal="p2p", goal_param=t)
        d1, p1, _ = sssp(dg, s, goal="p2p", goal_param=t, landmarks=lm)
        assert_p2p_identical(d0, p0, d1, p1, s, t, strategy)
    # break symmetry: double one vertex's outgoing weights (the reverse
    # edges live in other rows and keep theirs; scaling a whole row
    # preserves the within-row ascending-weight invariant)
    import dataclasses
    w = np.asarray(g.w, np.float32).copy()
    v = int(np.argmax(np.asarray(g.deg)))
    row_ptr = np.asarray(g.row_ptr, np.int64)
    w[row_ptr[v]:row_ptr[v + 1]] *= 2.0
    gd = dataclasses.replace(g, w=w)
    dgd = gd.to_device()
    lmd = build_landmarks(dgd, n_landmarks=4, strategy="farthest")
    assert not lmd.sym
    d0, p0, _ = sssp(dgd, s, goal="p2p", goal_param=t)
    d1, p1, _ = sssp(dgd, s, goal="p2p", goal_param=t, landmarks=lmd)
    assert_p2p_identical(d0, p0, d1, p1, s, t, "directed")


# ---------------------------------------------------------------------------
# admissibility property: lb[v] <= d(v, t)
# ---------------------------------------------------------------------------

def _check_admissible(g, t, n_landmarks=4, strategy="farthest"):
    dg = g.to_device()
    lm = build_landmarks(dg, n_landmarks=n_landmarks, strategy=strategy)
    ad = lm.alt_data
    lb = np.asarray(alt_lower_bounds(ad.D, t, ad.delta, ad.sym))
    # oracle d(v, t): symmetric graphs via the tree from t; the exact
    # float64 Dijkstra oracle keeps engine rounding out of the reference
    dref, _ = dijkstra_host(g, t)
    dref = np.asarray(dref, np.float64)
    finite = np.isfinite(dref)
    # the slack-deflated bound must sit at-or-below the true distance
    # (up to one f32 ulp of the comparison itself)
    viol = lb[finite] > dref[finite] * (1 + 1e-6) + 1e-6
    assert not viol.any(), \
        (np.where(viol)[0][:5], lb[finite][viol][:5],
         dref[finite][viol][:5])
    # where t is unreachable from v, an infinite bound is allowed and
    # correct; a finite bound is also fine (0 is always admissible)


def test_alt_admissibility_seeded_sweep():
    """Always-on property sweep (hypothesis-free): random graph shapes,
    seeds, strategies and targets."""
    rng = np.random.default_rng(0)
    for i in range(8):
        n = int(rng.integers(32, 256))
        m = int(rng.integers(2 * n, 8 * n))
        g = uniform_random(n, m, seed=int(rng.integers(1 << 16)))
        t = int(rng.integers(n))
        _check_admissible(g, t, n_landmarks=int(rng.integers(1, 6)),
                          strategy=["farthest", "max_degree"][i % 2])


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16 - 1), t=st.integers(0, 127),
           k=st.integers(1, 8))
    def test_alt_admissibility_hypothesis(seed, t, k):
        # fixed (n, m) so every example reuses the same compiled solves
        g = uniform_random(128, 1024, seed=seed)
        _check_admissible(g, t, n_landmarks=k)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_alt_admissibility_hypothesis():
        pass


# ---------------------------------------------------------------------------
# facade: session landmarks, mixed-kind solve_many
# ---------------------------------------------------------------------------

def test_solver_facade_alt_parity_and_pruning():
    g = road_grid(20, seed=5)
    s, t = pick_pair(g, seed=2)
    plain = Solver.open(g)
    alt = Solver.open(g, EngineConfig(use_alt=True, n_landmarks=4))
    assert alt.landmarks is not None
    assert plain.landmarks is None
    r0 = plain.solve(SolveSpec.p2p(s, t))
    r1 = alt.solve(SolveSpec.p2p(s, t))
    assert_p2p_identical(r0.dist, r0.parent, r1.dist, r1.parent, s, t,
                         "facade")
    assert int(np.asarray(r1.metrics.n_pruned)) > 0
    assert int(np.asarray(r0.metrics.n_pruned)) == 0
    # non-p2p goals never consume the bounds: full-tree parity
    t0 = plain.solve(SolveSpec.tree(s))
    t1 = alt.solve(SolveSpec.tree(s))
    assert np.array_equal(np.asarray(t0.dist), np.asarray(t1.dist))
    assert np.array_equal(np.asarray(t0.parent), np.asarray(t1.parent))


def test_solve_many_mixed_goal_kinds():
    """One submission wave mixing every goal kind solves as
    plan-compatible sub-batches, each result bitwise-equal to its
    individual solve."""
    g = kronecker(9, 8, seed=2)
    solver = Solver.open(g, EngineConfig(use_alt=True, n_landmarks=4))
    s, t = pick_pair(g, seed=5)
    specs = [
        SolveSpec.p2p(s, t),
        SolveSpec.tree((s + 1) % g.n),
        SolveSpec.knear(s, 5),
        SolveSpec.bounded(s, 2.0),
        SolveSpec.p2p([s, (s + 2) % g.n], [t, (t + 3) % g.n]),
    ]
    many = solver.solve_many(specs)
    assert len(many) == len(specs)
    for spec, got in zip(specs, many):
        ref = solver.solve(spec)
        assert np.array_equal(np.asarray(got.dist), np.asarray(ref.dist)), \
            spec.kind
        assert np.array_equal(np.asarray(got.parent),
                              np.asarray(ref.parent)), spec.kind
        for f in ("n_rounds", "n_relax", "n_pruned"):
            assert np.array_equal(np.asarray(getattr(got.metrics, f)),
                                  np.asarray(getattr(ref.metrics, f))), \
                (spec.kind, f)


# ---------------------------------------------------------------------------
# registry lifecycle: shared cache, staleness, invalidation
# ---------------------------------------------------------------------------

def test_registry_landmark_cache_and_invalidation():
    from repro.serve.registry import GraphRegistry

    g1 = road_grid(16, seed=5)
    g2 = road_grid(16, seed=6)
    reg = GraphRegistry(capacity=4, config=EngineConfig(
        use_alt=True, n_landmarks=4))
    reg.register("g", g1)
    lm_a = reg.landmark_set("g")
    lm_b = reg.landmark_set("g")
    assert lm_a is lm_b                      # one build, shared
    assert lm_a.generation == reg.generation("g")
    # changed build parameters rebuild (params mismatch)
    lm_c = reg.landmark_set("g", n_landmarks=2)
    assert lm_c is not lm_a and lm_c.n_landmarks == 2
    # re-register bumps the spec generation: the cached set is stale
    reg.register("g", g2)
    lm_d = reg.landmark_set("g")
    assert lm_d is not lm_a
    assert lm_d.generation == reg.generation("g") > lm_a.generation
    # the engine built under use_alt prunes and stays exact vs unpruned
    s, t = pick_pair(g2, seed=9)
    eng = reg.engine("g")
    d1, p1, m1 = eng.run_batch(np.asarray([s]), goal="p2p",
                               goal_params=np.asarray([t]))
    d0, p0, m0 = sssp(g2.to_device(), s, goal="p2p", goal_param=t)
    assert_p2p_identical(d0, p0, np.asarray(d1)[0], np.asarray(p1)[0],
                         s, t, "registry-engine")
    assert int(np.asarray(m1.n_pruned).sum()) > 0


def test_ecc_hints_reuse_landmark_choices():
    """The registry's eccentricity hints ride the LandmarkSet's picks
    (one BFS family, not two)."""
    from repro.serve.registry import estimate_eccentricity

    g = road_grid(16, seed=5)
    dg = g.to_device()
    lm = build_landmarks(dg, n_landmarks=4, strategy="max_degree")
    row_ptr = np.asarray(g.row_ptr, np.int64)
    dst = np.asarray(g.dst, np.int64)
    ecc_lm = estimate_eccentricity(g, landmarks=lm.landmarks)
    # replay the hint formula from the shared hop_bfs over the SAME
    # vantage points: max over reaching landmarks of ecc(L) + hop
    ecc = np.full(g.n, -1, np.int64)
    worst = 1
    for root in lm.landmarks:
        hop = hop_bfs(row_ptr, dst, int(g.n), int(root))
        h_max = int(hop.max())
        ecc = np.where(hop >= 0, np.maximum(ecc, h_max + hop), ecc)
        worst = max(worst, 2 * h_max + 1)
    expect = np.where(ecc >= 0, ecc, worst).astype(np.float32)
    assert np.array_equal(np.asarray(ecc_lm), expect)


# ---------------------------------------------------------------------------
# tuned-store fingerprint: ALT parameters invalidate
# ---------------------------------------------------------------------------

def test_tuned_store_alt_fingerprint(tmp_path):
    from repro.tune.store import TunedStore, graph_fingerprint

    g = kronecker(8, 8, seed=2)
    base = EngineConfig()
    alt_a = EngineConfig(use_alt=True, n_landmarks=4)
    alt_b = EngineConfig(use_alt=True, n_landmarks=8)
    # ALT-off configs leave the fingerprint unchanged (pre-ALT stores
    # stay valid); ALT params move it
    f0 = graph_fingerprint(g)
    assert graph_fingerprint(g, base) == f0
    assert graph_fingerprint(g, alt_a) != f0
    assert graph_fingerprint(g, alt_a) != graph_fingerprint(g, alt_b)

    store = TunedStore(tmp_path / "tuned.json")
    store.put("g", g, alt_a, objective=1.0)
    assert store.get("g", g, alt_a) is not None
    # a winner tuned under ALT reads as stale for ALT-off serving and
    # for a different landmark set — never a silent overlay
    assert store.get("g", g, base) is None
    assert store.get("g", g) is None
    assert store.get("g", g, alt_b) is None
    assert store.apply("g", g, base) == base


# ---------------------------------------------------------------------------
# sharded tier: 8 real shards in a subprocess, bitwise vs single-device
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.core.distributed import shard_blocked, shard_graph, \
    sssp_distributed, sssp_distributed_batch
from repro.core.landmarks import build_landmarks
from repro.core.sssp import sssp, sssp_batch
from repro.serve.queries import reconstruct_path

mesh = jax.make_mesh((8,), ("graph",))
from repro.core.landmarks import hop_bfs
from repro.data.generators import kronecker, road_grid
total_pruned = 0
for name, g in [("kron", kronecker(9, 8, seed=1)),
                ("road", road_grid(20, seed=2))]:
    # deterministic connected pair: max-degree source, farthest target
    s = int(np.argmax(np.asarray(g.deg)))
    hop = hop_bfs(np.asarray(g.row_ptr, np.int64),
                  np.asarray(g.dst, np.int64), int(g.n), s)
    t = int(np.argmax(hop))
    dg = g.to_device()
    lm = build_landmarks(dg, n_landmarks=4, strategy="farthest")
    d0, p0, m0 = sssp(dg, s, goal="p2p", goal_param=t)
    d0, p0 = np.asarray(d0), np.asarray(p0)
    _, _, m1 = sssp(dg, s, goal="p2p", goal_param=t, landmarks=lm)
    ref_path = reconstruct_path(p0, s, t)
    sg = shard_graph(g, 8)
    bl = shard_blocked(sg, block_v=128, tile_e=128)
    for ver, be in [("v1", "segment_min"), ("v2", "segment_min"),
                    ("v3", "segment_min"), ("v2", "blocked")]:
        kw = {"blocked": bl} if be == "blocked" else {}
        d, p, m = sssp_distributed(sg, s, mesh, ("graph",), version=ver,
                                   backend=be, goal="p2p", goal_param=t,
                                   landmarks=lm, **kw)
        d = np.asarray(d)[:g.n]; p = np.asarray(p)[:g.n]
        assert d[t].tobytes() == d0[t].tobytes(), (name, ver, be)
        assert reconstruct_path(p, s, t) == ref_path, (name, ver, be)
        # logical-metric parity with the single-device *pruned* engine:
        # the sharded tiers prune through the same shared primitives
        assert int(m.n_relax) == int(m1.n_relax), (name, ver, be)
        assert int(m.n_pruned) == int(m1.n_pruned), (name, ver, be)
        total_pruned += int(m.n_pruned)
    # batched sharded p2p with landmarks vs the single-device batch
    srcs = np.asarray([s, (s + 5) % g.n], np.int32)
    tgts = np.asarray([t, (t + 11) % g.n], np.int32)
    db, pb, mb = sssp_distributed_batch(sg, srcs, mesh, ("graph",),
                                        version="v2", goal="p2p",
                                        goal_params=tgts, landmarks=lm)
    dr, pr, mr = sssp_batch(dg, srcs, goal="p2p", goal_params=tgts,
                            landmarks=lm)
    for i, tt in enumerate(tgts):
        assert np.asarray(db)[i, int(tt)].tobytes() \
            == np.asarray(dr)[i, int(tt)].tobytes(), i
    assert np.array_equal(np.asarray(mb.n_pruned), np.asarray(mr.n_pruned))
assert total_pruned > 0, total_pruned
print("ALT_SHARDED_OK", total_pruned)
"""


@pytest.mark.slow
def test_alt_sharded_8shard_bitwise_parity():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "ALT_SHARDED_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
