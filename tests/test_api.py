"""The solver facade: one EngineConfig + SolveSpec pair must drive all
four goal kinds on the single-device, sharded, and routed paths with
bitwise dist/parent (+ logical metric) parity against the pre-facade
entry points — and the deprecated ``sssp_*`` shims must warn while
staying bitwise-identical to the facade."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro.api import EngineConfig, SolveSpec, Solver
from repro.core.config import FacadeDeprecationWarning
from repro.core.sssp import LOGICAL_METRIC_FIELDS, sssp, sssp_batch
from repro.data.generators import kronecker, road_grid

SIDE = 12


@pytest.fixture(scope="module")
def road():
    return road_grid(SIDE, seed=2)


def all_kind_specs(n, single=True):
    """One spec per goal kind (scalar or batch shape)."""
    if single:
        return [SolveSpec.tree(0), SolveSpec.p2p(0, n - 1),
                SolveSpec.bounded(0, 2.5), SolveSpec.knear(0, 5)]
    return [SolveSpec.tree([0, 5]), SolveSpec.p2p([0, 5], [n - 1, 30]),
            SolveSpec.bounded([0, 5], [2.5, 1.5]),
            SolveSpec.knear([0, 5], [5, 3])]


def engine_reference(dg, spec):
    """The pre-facade engine call equivalent to ``spec``."""
    if spec.batched:
        return sssp_batch(dg, list(spec.sources), goal=spec.kind,
                          goal_params=spec.slot_params())
    return sssp(dg, spec.sources, goal=spec.kind,
                goal_param=spec.goal_param)


def assert_bitwise(res, ref, msg=""):
    d_ref, p_ref, m_ref = ref
    np.testing.assert_array_equal(np.asarray(res.dist), np.asarray(d_ref),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(res.parent),
                                  np.asarray(p_ref), err_msg=msg)
    for f in LOGICAL_METRIC_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(res.metrics, f)),
                                      np.asarray(getattr(m_ref, f)),
                                      err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# SolveSpec / SolveResult semantics
# ---------------------------------------------------------------------------

def test_solvespec_validation():
    with pytest.raises(ValueError):
        SolveSpec(sources=0, kind="nope")
    with pytest.raises(ValueError):
        SolveSpec.p2p(0, None)                      # missing param
    with pytest.raises(ValueError):
        SolveSpec(sources=0, kind="tree", target=3)  # foreign param
    with pytest.raises(ValueError):
        SolveSpec.p2p(0, -1)
    with pytest.raises(ValueError):
        SolveSpec.knear(0, 0)
    with pytest.raises(ValueError):
        SolveSpec.bounded(0, -1.0)
    with pytest.raises(ValueError):
        SolveSpec.p2p([0, 1], [2])                  # length mismatch
    with pytest.raises(ValueError):
        SolveSpec.p2p(0, [1, 2])                    # per-source on scalar
    with pytest.raises(ValueError):
        SolveSpec.tree([])
    # normalization: sequences become tuples, scalars stay scalars
    spec = SolveSpec.p2p([0, 1], 7)
    assert spec.sources == (0, 1) and spec.batched
    assert spec.slot_params() == [7, 7]
    assert not SolveSpec.tree(3).batched


def test_solve_result_tuple_compat_and_lazy_shaping(road):
    solver = Solver.open(road)
    res = solver.solve(SolveSpec.p2p(0, 100))
    dist, parent, metrics = res                      # legacy unpacking
    assert np.asarray(dist).shape == (road.n,)
    assert res.distance() == float(np.asarray(dist)[100])
    path = res.paths()
    assert path[0] == 0 and path[-1] == 100
    # every hop is a real parent edge
    par = np.asarray(parent)
    assert all(par[path[i + 1]] == path[i] for i in range(len(path) - 1))
    nm = res.normalized()
    assert nm["n_rounds"] == int(np.asarray(metrics.n_rounds))
    # batch shaping: per-slot paths/distances/metrics
    rb = solver.solve(SolveSpec.p2p([0, 5], [100, 30]))
    assert rb.distance(slot=1) == float(np.asarray(rb.dist)[1, 30])
    paths = rb.paths()
    assert paths[0][-1] == 100 and paths[1][-1] == 30
    # explicit targets accept any sequence type (and validate length)
    assert rb.paths(np.array([100, 30])) == paths
    with pytest.raises(ValueError):
        rb.paths([100, 30, 7])
    assert rb.normalized(slot=0)["reachable"] > 0
    kn = solver.solve(SolveSpec.knear(0, 3))
    assert len(kn.nearest()) == 3


# ---------------------------------------------------------------------------
# parity: single-device tier
# ---------------------------------------------------------------------------

def test_single_tier_parity_all_kinds(road):
    dg = road.to_device()
    solver = Solver.open(road)
    for spec in all_kind_specs(road.n) + all_kind_specs(road.n,
                                                        single=False):
        assert_bitwise(solver.solve(spec), engine_reference(dg, spec),
                       msg=f"{spec.kind}/batched={spec.batched}")


def test_single_tier_blocked_backend_parity(road):
    dg = road.to_device()
    solver = Solver.open(road, EngineConfig(backend="blocked_pallas",
                                            block_v=64, tile_e=64))
    for spec in (SolveSpec.tree(0), SolveSpec.p2p([0, 5], [100, 30])):
        res = solver.solve(spec)
        ref = engine_reference(dg, spec)             # segment_min reference
        np.testing.assert_array_equal(np.asarray(res.dist),
                                      np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(res.parent),
                                      np.asarray(ref[1]))
        assert np.all(np.asarray(res.metrics.n_tiles_scanned) > 0)


# ---------------------------------------------------------------------------
# parity: sharded tier (1-shard in-process; 8-shard in a subprocess below)
# ---------------------------------------------------------------------------

def test_sharded_tier_parity_all_kinds(road):
    dg = road.to_device()
    solver = Solver.open(road, EngineConfig(tier="sharded"))
    assert solver.resolved.n_shards == len(jax.devices())
    for spec in all_kind_specs(road.n) + [SolveSpec.tree([0, 5])]:
        assert_bitwise(solver.solve(spec), engine_reference(dg, spec),
                       msg=f"sharded/{spec.kind}")


def test_sharded_tier_blocked_backend_parity(road):
    dg = road.to_device()
    solver = Solver.open(road, EngineConfig(tier="sharded",
                                            shard_backend="blocked",
                                            block_v=64, tile_e=64))
    spec = SolveSpec.p2p([0, 5], [100, 30])
    res = solver.solve(spec)
    ref = engine_reference(dg, spec)
    np.testing.assert_array_equal(np.asarray(res.dist), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(res.parent),
                                  np.asarray(ref[1]))
    assert np.all(np.asarray(res.metrics.n_tiles_scanned) > 0)


# ---------------------------------------------------------------------------
# parity: routed serving tier
# ---------------------------------------------------------------------------

def test_routed_tier_parity_all_kinds(road):
    """The facade's routed path must serve byte-identical answers to the
    pre-facade registry/router stack (same finalized masking)."""
    from repro.serve.queries import Query
    from repro.serve.registry import GraphRegistry
    from repro.serve.router import QueryRouter

    with Solver.open(road, EngineConfig(tier="routed",
                                        max_batch=2)) as solver:
        reg = GraphRegistry(capacity=4)
        reg.register("g", road)
        router = QueryRouter(reg, max_batch=2)
        for spec in all_kind_specs(road.n):
            res = solver.solve(spec)
            kw = {"p2p": {"target": spec.target},
                  "bounded": {"bound": spec.bound},
                  "knear": {"k": spec.k}}.get(spec.kind, {})
            fut = router.submit(Query(gid="g", source=spec.sources,
                                      kind=spec.kind, **kw))
            router.drain()
            ref = fut.result(timeout=60)
            np.testing.assert_array_equal(np.asarray(res.dist), ref.dist,
                                          err_msg=spec.kind)
            np.testing.assert_array_equal(np.asarray(res.parent),
                                          ref.parent, err_msg=spec.kind)
            assert res.served_by is not None
        # batch specs fan out one query per source and stack the answers
        rb = solver.solve(SolveSpec.tree([0, 5, 9]))
        assert np.asarray(rb.dist).shape == (3, road.n)
        d_ref, _, _ = sssp(road.to_device(), 9)
        np.testing.assert_array_equal(np.asarray(rb.dist)[2],
                                      np.asarray(d_ref))
        # batched metrics need an explicit slot on every tier
        with pytest.raises(ValueError):
            rb.normalized()
        assert rb.normalized(slot=1)["reachable"] > 0
        assert solver.router.stats()["n_done"] >= 7


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_deprecated_wrappers_warn_and_match_facade(road):
    from repro.core.sssp import sssp_bounded, sssp_knear, sssp_p2p
    dg = road.to_device()
    solver = Solver.open(road)
    for shim, spec in [
            (lambda: sssp_p2p(dg, 0, 100), SolveSpec.p2p(0, 100)),
            (lambda: sssp_bounded(dg, 0, 2.5), SolveSpec.bounded(0, 2.5)),
            (lambda: sssp_knear(dg, 0, 5), SolveSpec.knear(0, 5))]:
        with pytest.warns(FacadeDeprecationWarning):
            d_old, p_old, m_old = shim()
        assert_bitwise(solver.solve(spec), (d_old, p_old, m_old),
                       msg=spec.kind)


# ---------------------------------------------------------------------------
# 8-shard distributed parity (subprocess: the main process keeps 1 device)
# ---------------------------------------------------------------------------

SCRIPT_8SHARD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
from repro.api import EngineConfig, SolveSpec, Solver
from repro.core.sssp import LOGICAL_METRIC_FIELDS
from repro.data.generators import kronecker, road_grid

for name, g in [("kron", kronecker(9, 8, seed=1)),
                ("road", road_grid(20, seed=2))]:
    ref = Solver.open(g)                      # single-device reference
    for cfg_name, cfg in [
            ("segment_min", EngineConfig(tier="sharded")),
            ("blocked", EngineConfig(tier="sharded",
                                     shard_backend="blocked",
                                     block_v=128, tile_e=128)),
            ("v3", EngineConfig(tier="sharded", shard_version="v3"))]:
        sh = Solver.open(g, cfg)
        assert sh.resolved.n_shards == 8, sh.resolved
        for spec in [SolveSpec.tree(int(np.argmax(g.deg))),
                     SolveSpec.p2p(0, g.n - 1),
                     SolveSpec.bounded(0, 2.0),
                     SolveSpec.knear(0, 8),
                     SolveSpec.tree([0, 5])]:
            a = sh.solve(spec)
            b = ref.solve(spec)
            assert np.array_equal(np.asarray(a.dist), np.asarray(b.dist)), \
                (name, cfg_name, spec.kind)
            assert np.array_equal(np.asarray(a.parent),
                                  np.asarray(b.parent)), \
                (name, cfg_name, spec.kind)
            for f in LOGICAL_METRIC_FIELDS:
                assert np.array_equal(np.asarray(getattr(a.metrics, f)),
                                      np.asarray(getattr(b.metrics, f))), \
                    (name, cfg_name, spec.kind, f)
        print(f"{name}/{cfg_name}: OK")
print("FACADE_8SHARD_OK")
"""


@pytest.mark.slow
def test_facade_8shard_parity_subprocess():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT_8SHARD, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "FACADE_8SHARD_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"


# ---------------------------------------------------------------------------
# service facade rides the same config
# ---------------------------------------------------------------------------

def test_sssp_service_accepts_engine_config(road):
    from repro.serve.sssp_service import SsspRequest, SsspService
    svc = SsspService(road, config=EngineConfig(max_batch=4))
    reqs = [svc.submit(SsspRequest(rid=i, source=s))
            for i, s in enumerate((0, 5, 9))]
    svc.run()
    d_ref, _, _ = sssp(road.to_device(), 5)
    np.testing.assert_array_equal(reqs[1].dist, np.asarray(d_ref))


def test_batched_result_shaping_requires_slot(road):
    solver = Solver.open(road)
    rp = solver.solve(SolveSpec.p2p([0, 5], [100, 30]))
    with pytest.raises(ValueError, match="slot"):
        rp.distance()
    rk = solver.solve(SolveSpec.knear([0, 5], [3, 4]))
    with pytest.raises(ValueError, match="slot"):
        rk.nearest()
    assert len(rk.nearest(slot=1)) == 4
