"""Unit tests for the serving metrics registry and its exporters.

These are pure host-side tests (no solver involved): counter/gauge/
histogram semantics, the get-or-create identity and conflict rules,
histogram percentile interpolation against hand-computed values, the
Prometheus text exposition round-trip, and the JSONL snapshot dump.
"""
import json
import math
import threading

import pytest

from repro.obs.export import (parse_prometheus, to_prometheus,
                              write_jsonl_snapshot)
from repro.obs.metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry)


# ----------------------------------------------------------------------
# counters / gauges
# ----------------------------------------------------------------------

def test_counter_basics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", help="requests")
    assert c.value == 0
    c.inc()
    c.inc(5)
    assert c.value == 6
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 6


def test_gauge_basics():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(7)
    assert g.value == 7
    g.inc(2)
    g.dec()
    assert g.value == 8


def test_get_or_create_identity_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("hits_total", labels={"dev": "0"})
    b = reg.counter("hits_total", labels={"dev": "0"})
    c = reg.counter("hits_total", labels={"dev": "1"})
    assert a is b
    assert a is not c
    a.inc()
    assert b.value == 1 and c.value == 0
    snap = reg.snapshot()
    assert snap['hits_total{dev="0"}']["value"] == 1
    assert snap['hits_total{dev="1"}']["value"] == 0


def test_type_conflict_rejected():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        reg.histogram("x_total")


def test_invalid_names_rejected():
    reg = MetricsRegistry()
    for bad in ("1leading_digit", "has space", "has-dash", ""):
        with pytest.raises(ValueError):
            reg.counter(bad)
    with pytest.raises(ValueError):
        reg.counter("ok_total", labels={"bad-label": "v"})


# ----------------------------------------------------------------------
# histograms
# ----------------------------------------------------------------------

def test_histogram_count_sum_and_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(105.0)
    snap = reg.snapshot()["lat"]
    # cumulative per-bucket counts, +Inf implicit
    assert snap["buckets"]["1"] == 1
    assert snap["buckets"]["2"] == 2
    assert snap["buckets"]["4"] == 3
    assert snap["buckets"]["+Inf"] == 4


def test_histogram_percentile_interpolation():
    # 100 observations uniform in (0, 1]: within the single (0.0, 1.0]
    # bucket the estimate interpolates linearly, exactly like
    # histogram_quantile
    h = Histogram("lat", "", {}, threading.Lock(), buckets=(1.0, 2.0))
    for i in range(100):
        h.observe((i + 1) / 100.0)
    assert h.percentile(0.5) == pytest.approx(0.5)
    assert h.percentile(0.9) == pytest.approx(0.9)
    # all mass in one bucket whose lower bound is 0 -> p99 still inside it
    assert 0.0 < h.percentile(0.99) <= 1.0


def test_histogram_percentile_empty_and_overflow():
    h = Histogram("lat", "", {}, threading.Lock(), buckets=(1.0,))
    assert math.isnan(h.percentile(0.5))
    h.observe(50.0)     # lands in +Inf: reports the finite lower bound
    assert h.percentile(0.5) == pytest.approx(1.0)


def test_histogram_rejects_bad_buckets():
    lock = threading.Lock()
    with pytest.raises(ValueError):
        Histogram("lat", "", {}, lock, buckets=())
    with pytest.raises(ValueError):
        Histogram("lat", "", {}, lock, buckets=(2.0, 1.0))


def test_default_latency_buckets_are_increasing():
    assert list(DEFAULT_LATENCY_BUCKETS) == sorted(DEFAULT_LATENCY_BUCKETS)
    assert len(set(DEFAULT_LATENCY_BUCKETS)) == len(DEFAULT_LATENCY_BUCKETS)


def test_thread_safety_under_contention():
    reg = MetricsRegistry()
    c = reg.counter("c_total")
    h = reg.histogram("h", buckets=(1.0, 2.0))

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(0.5)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    assert h.count == 8000


# ----------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("sssp_requests_total", help="total requests",
                labels={"scheduler": "dev0"}).inc(3)
    reg.gauge("sssp_pending", labels={"scheduler": "dev0"}).set(2)
    h = reg.histogram("sssp_latency_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    return reg


def test_prometheus_round_trip():
    reg = _populated_registry()
    text = to_prometheus(reg.snapshot())
    assert "# TYPE sssp_requests_total counter" in text
    assert "# TYPE sssp_latency_seconds histogram" in text
    parsed = parse_prometheus(text)
    assert parsed['sssp_requests_total{scheduler="dev0"}'] == 3
    assert parsed['sssp_pending{scheduler="dev0"}'] == 2
    assert parsed['sssp_latency_seconds_bucket{le="0.1"}'] == 1
    assert parsed['sssp_latency_seconds_bucket{le="+Inf"}'] == 2
    assert parsed["sssp_latency_seconds_count"] == 2
    assert parsed["sssp_latency_seconds_sum"] == pytest.approx(0.55)


def test_prometheus_parser_is_strict():
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line at all!\n")
    with pytest.raises(ValueError):
        parse_prometheus("a_total 1\na_total 2\n")   # duplicate sample


def test_jsonl_snapshot(tmp_path):
    reg = _populated_registry()
    path = tmp_path / "metrics.jsonl"
    write_jsonl_snapshot(reg.snapshot(), path, meta={"run": "t1"})
    write_jsonl_snapshot(reg.snapshot(), path)
    lines = path.read_text().strip().split("\n")
    assert len(lines) == 2
    rec = json.loads(lines[0])
    assert rec["run"] == "t1"
    assert rec["ts"] > 0
    name = 'sssp_requests_total{scheduler="dev0"}'
    assert rec["metrics"][name]["value"] == 3
