"""The multi-round fused relaxation megakernel (kernels/edge_relax).

Three layers of parity, all bitwise:

* ``schedule_tiles``'s segmented prefix-sum scatter against a
  reimplementation of the argsort compaction it replaced (property-style
  sweep over empty / single-tile / full-frontier buckets and random
  mixes);
* the Pallas megakernel paths (``relax_fused`` / ``relax_partials``)
  against their jnp reference twins, including the in-kernel counter
  vectors and under ``vmap`` (the batched engine's usage);
* the fused blocked engine end-to-end against the unfused blocked and
  segment_min engines — dist, parent and every logical metric counter —
  plus the perf acceptance pair: kernel invocations per solve drop while
  the compacted tile schedule does not grow.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import relax
from repro.core.config import ConfigError
from repro.core.graph import build_blocked
from repro.core.sssp import LOGICAL_METRIC_FIELDS, sssp, sssp_batch
from repro.data.generators import kronecker, road_grid
from repro.kernels.edge_relax import ops
from repro.kernels.edge_relax.edge_relax import schedule_tiles


def _oracle_schedule(active):
    """The pre-refactor compaction: stable argsort moves active tiles to
    the front (preserving dst-sorted layout order), then the last active
    tile is repeated over the inactive slots."""
    order = np.argsort(~active, kind="stable").astype(np.int32)
    n = int(active.sum())
    sched = order.copy()
    if n:
        sched[n:] = sched[n - 1]
    else:
        sched[:] = 0
    return sched, n


def _schedule_case(rng, nt, tile_e, block_v, frontier=None, tile_first=None,
                   pad_frac=0.3):
    src_local = rng.integers(0, block_v, nt * tile_e).astype(np.int32)
    w = rng.uniform(0.1, 2.0, nt * tile_e).astype(np.float32)
    w[rng.random(nt * tile_e) < pad_frac] = np.inf   # padding slots
    if frontier is None:
        frontier = (rng.random(block_v) < 0.4)
    if tile_first is None:
        tile_first = (rng.random(nt) < 0.2)
    return (frontier.astype(np.int8), src_local, w,
            np.asarray(tile_first, bool))


def test_schedule_prefix_sum_matches_argsort_oracle():
    block_v, tile_e = 16, 4
    rng = np.random.default_rng(0)
    cases = []
    # empty frontier, no forced tiles -> nothing scheduled
    cases.append(_schedule_case(rng, 6, tile_e, block_v,
                                frontier=np.zeros(block_v, bool),
                                tile_first=np.zeros(6, bool)))
    # empty frontier, forced first tiles only
    tf = np.zeros(8, bool)
    tf[[0, 5]] = True
    cases.append(_schedule_case(rng, 8, tile_e, block_v,
                                frontier=np.zeros(block_v, bool),
                                tile_first=tf))
    # exactly one active tile (single-tile bucket)
    fr = np.zeros(block_v, bool)
    fr[3] = True
    src = np.full(8 * tile_e, 5, np.int32)
    src[:tile_e] = 3
    w = np.full(8 * tile_e, np.inf, np.float32)
    w[:tile_e] = 1.0
    cases.append((fr.astype(np.int8), src, w, np.zeros(8, bool)))
    # full frontier -> every non-padding tile active
    cases.append(_schedule_case(rng, 7, tile_e, block_v,
                                frontier=np.ones(block_v, bool),
                                pad_frac=0.0))
    # random mixes
    for nt in (1, 2, 5, 13):
        cases.append(_schedule_case(rng, nt, tile_e, block_v))
    for fr, src_local, w, tf in cases:
        nt = tf.shape[0]
        sched, sched_n = schedule_tiles(jnp.asarray(fr),
                                        jnp.asarray(src_local),
                                        jnp.asarray(w), jnp.asarray(tf),
                                        tile_e)
        touched = (fr[src_local] > 0) & np.isfinite(w)
        active = touched.reshape(nt, tile_e).any(axis=1) | tf
        ref_sched, ref_n = _oracle_schedule(active)
        assert int(sched_n) == ref_n
        np.testing.assert_array_equal(np.asarray(sched), ref_sched)


def _mid_solve_state(g, bg, seed=0):
    """A plausible mid-solve state over the padded vertex range: some
    settled vertices, a partial frontier, the rest unreached."""
    rng = np.random.default_rng(seed)
    n_out = bg.n_blocks * bg.block_v
    dist = np.full(n_out, np.inf, np.float32)
    seeds = rng.choice(g.n, min(30, g.n // 2), replace=False)
    dist[seeds] = rng.uniform(0.0, 3.0, seeds.size).astype(np.float32)
    parent = np.full(n_out, -1, np.int32)
    parent[seeds] = rng.integers(0, g.n, seeds.size)
    frontier = np.zeros(n_out, bool)
    frontier[seeds[: seeds.size // 2]] = True
    return jnp.asarray(dist), jnp.asarray(parent), jnp.asarray(frontier)


def test_fused_kernel_matches_ref():
    g = road_grid(12, seed=2)
    bg = build_blocked(g.to_device(), block_v=64, tile_e=64)
    fs = relax.fused_slab(bg)
    dist, parent, frontier = _mid_solve_state(g, bg)
    lb, ub = jnp.float32(0.5), jnp.float32(2.5)
    out = {}
    for use_kernel in (True, False):
        out[use_kernel] = ops.relax_fused(
            dist, parent, frontier, bg.deg, fs.src, fs.dst, fs.w,
            fs.tile_dst, fs.tile_first, lb, ub, block_v=bg.block_v,
            tile_e=bg.tile_e, fused_rounds=3, use_kernel=use_kernel)
    for a, b, what in zip(out[True], out[False],
                          ("dist", "parent", "frontier", "counters")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)
    # the counter fold is exact: at least one round ran and counted work
    cnt = np.asarray(out[True][3])
    assert cnt[list(ops.FUSED_COUNTERS).index("n_rounds")] >= 1
    assert cnt[list(ops.FUSED_COUNTERS).index("n_tiles")] > 0


def test_fused_kernel_vmap_matches_loop():
    g = road_grid(12, seed=2)
    bg = build_blocked(g.to_device(), block_v=64, tile_e=64)
    fs = relax.fused_slab(bg)
    states = [_mid_solve_state(g, bg, seed=s) for s in range(3)]
    dists = jnp.stack([s[0] for s in states])
    parents = jnp.stack([s[1] for s in states])
    fronts = jnp.stack([s[2] for s in states])
    lbs = jnp.asarray([0.0, 0.5, 1.0], jnp.float32)   # incl. the lb<=0 clamp
    ubs = jnp.asarray([1.5, 2.5, 3.0], jnp.float32)

    def one(d, p, f, lb, ub):
        return ops.relax_fused(d, p, f, bg.deg, fs.src, fs.dst, fs.w,
                               fs.tile_dst, fs.tile_first, lb, ub,
                               block_v=bg.block_v, tile_e=bg.tile_e,
                               fused_rounds=3)

    batched = jax.vmap(one)(dists, parents, fronts, lbs, ubs)
    for i in range(3):
        single = one(dists[i], parents[i], fronts[i], lbs[i], ubs[i])
        for a, b, what in zip(single, batched,
                              ("dist", "parent", "frontier", "counters")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[i],
                                          err_msg=f"slot {i}: {what}")
    # slot 0 entered with lb<=0 (bootstrap): the clamp must hold it to 1
    n_rounds_i = list(ops.FUSED_COUNTERS).index("n_rounds")
    assert int(np.asarray(batched[3])[0, n_rounds_i]) == 1


def test_partials_kernel_matches_ref():
    g = road_grid(12, seed=2)
    bg = build_blocked(g.to_device(), block_v=64, tile_e=64)
    fs = relax.fused_slab(bg)
    dist, parent, frontier = _mid_solve_state(g, bg, seed=1)
    paths = relax.leaf_pruned(frontier, dist, bg.deg).astype(jnp.int8)
    lb, ub = jnp.float32(0.2), jnp.float32(2.0)
    out = {}
    for use_kernel in (True, False):
        out[use_kernel] = ops.relax_partials(
            dist, paths, parent, fs.src, fs.dst, fs.w, fs.tile_dst,
            fs.tile_first, lb, ub, block_v=bg.block_v, tile_e=bg.tile_e,
            n_dst_blocks=bg.n_dst_blocks, use_kernel=use_kernel)
    for a, b, what in zip(out[True], out[False],
                          ("best", "winner", "counters")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=what)


def test_fused_engine_end_to_end_parity():
    """The tentpole acceptance on representative graphs: the fused
    blocked engine is bitwise-identical (dist/parent/logical metrics) to
    the unfused blocked and segment_min engines, launches >= 2x fewer
    kernels on the round-heavy graph, and never grows the compacted
    tile schedule."""
    for name, g, need_2x in [("road", road_grid(16, seed=2), True),
                             ("kron", kronecker(8, 8, seed=1), False)]:
        src = int(np.argmax(g.deg))
        dg = g.to_device()
        d_sm, p_sm, m_sm = sssp(dg, src)
        runs = {}
        for fr in (0, 4):
            d, p, m = sssp(dg, src, backend="blocked_pallas",
                           fused_rounds=fr, block_v=64, tile_e=64)
            np.testing.assert_array_equal(np.asarray(d), np.asarray(d_sm),
                                          err_msg=f"{name}/fused={fr}")
            np.testing.assert_array_equal(np.asarray(p), np.asarray(p_sm),
                                          err_msg=f"{name}/fused={fr}")
            for f in LOGICAL_METRIC_FIELDS:
                assert int(getattr(m, f)) == int(getattr(m_sm, f)), \
                    (name, fr, f)
            runs[fr] = m
        inv0 = float(runs[0].n_invocations)
        inv4 = float(runs[4].n_invocations)
        assert inv4 < inv0, name
        if need_2x:
            assert inv4 * 2 <= inv0, (name, inv0, inv4)
        assert float(runs[4].n_tiles_scanned) \
            == float(runs[0].n_tiles_scanned), name


def test_fused_engine_batch_parity():
    g = road_grid(16, seed=2)
    dg = g.to_device()
    srcs = np.array([0, 17, 200], np.int32)
    d0, p0, m0 = sssp_batch(dg, srcs, backend="blocked_pallas",
                            block_v=64, tile_e=64)
    d4, p4, m4 = sssp_batch(dg, srcs, backend="blocked_pallas",
                            fused_rounds=4, block_v=64, tile_e=64)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d4))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p4))
    np.testing.assert_array_equal(np.asarray(m0.n_rounds),
                                  np.asarray(m4.n_rounds))
    assert (np.asarray(m4.n_invocations)
            < np.asarray(m0.n_invocations)).all()


def test_fused_rounds_needs_blocked_backend():
    g = road_grid(8, seed=2).to_device()
    with pytest.raises(ConfigError):
        sssp(g, 0, backend="segment_min", fused_rounds=2)
