"""Unit tests for the dynamic-stepping / traversal-optimization formulas."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import stats, stepping, traversal
from repro.core.graph import build_csr, RATIO_NUM
from repro.data.generators import kronecker


@pytest.fixture(scope="module")
def graph():
    return kronecker(10, 8, seed=3)


def test_sum_d_matches_numpy(graph):
    g = graph.to_device()
    rng = np.random.default_rng(0)
    dist = rng.random(graph.n).astype(np.float32)
    dist[rng.random(graph.n) < 0.3] = np.inf
    for x in [0.0, 0.3, 0.7, 1.5]:
        got = int(stats.sum_d(jnp.asarray(dist), g.deg, jnp.float32(x)))
        want = int(graph.deg[dist >= x].sum())
        assert got == want


def test_sum_d_grid_matches_pointwise(graph):
    g = graph.to_device()
    rng = np.random.default_rng(1)
    dist = rng.random(graph.n).astype(np.float32) * 2
    grid = jnp.linspace(0.0, 2.0, 64)
    got = np.asarray(stats.sum_d_grid(jnp.asarray(dist), g.deg, grid))
    want = np.array([int(graph.deg[dist >= float(x)].sum()) for x in grid])
    np.testing.assert_array_equal(got, want)


def test_high_d_balances_degree_mass(graph):
    """highD splits VS(x) into halves of ~equal total degree."""
    g = graph.to_device()
    dist = jnp.zeros(graph.n)
    hd = float(stats.high_d(dist, g.deg, jnp.float32(0.0)))
    deg = graph.deg
    below = deg[deg < hd].sum()
    total = deg.sum()
    # bucketed approximation: within a factor ~2 of an exact split
    assert 0.2 < below / total < 0.8, (hd, below / total)


def test_max_w_quantiles(graph):
    g = graph.to_device()
    for r in [0.0, 0.25, 0.5, 0.9, 1.0]:
        got = float(stats.max_w_of(g.rtow, jnp.float32(r)))
        want = float(np.quantile(graph.w, r))
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
    frac = (graph.w <= float(stats.max_w_of(g.rtow, jnp.float32(0.5)))).mean()
    assert abs(frac - 0.5) < 0.02


@settings(max_examples=50, deadline=None)
@given(st.floats(0.01, 0.99), st.floats(1.0, 1e5))
def test_ratio_formula_bounds(p, hd):
    """Eq (2): ratio in (0, 1), decreasing in highD."""
    r = float(stepping.ratio(jnp.float32(p), jnp.float32(hd)))
    assert 0.0 < r < 1.0
    r2 = float(stepping.ratio(jnp.float32(p), jnp.float32(hd * 2)))
    assert r2 <= r + 1e-6


def test_gap_full_width_for_low_degree():
    """Eq (3): highD <= alpha => gap = maxW(G, 1) (Road regime).

    A path graph has degree <= 2 (the paper's Road has highD(0)=3); a 2-D
    lattice's interior degree is 4, which correctly does NOT trigger the
    full-width branch."""
    rng = np.random.default_rng(0)
    n = 256
    u = np.arange(n - 1)
    v = np.arange(1, n)
    g = build_csr(n, u, v, rng.random(n - 1) + 0.1).to_device()
    dist = jnp.zeros(n)
    gap = float(stepping.gap(dist, g.deg, g.rtow, g.n_edges2,
                             jnp.float32(0.0)))
    np.testing.assert_allclose(gap, float(g.rtow[-1]), rtol=1e-6)


def test_profit_terms_signs(graph):
    g = graph.to_device()
    dist = jnp.asarray(
        np.random.default_rng(2).random(graph.n).astype(np.float32))
    lb, y = jnp.float32(0.5), jnp.float32(0.8)
    grid = jnp.linspace(0.0, 0.5, 32)
    sd_grid = stats.sum_d_grid(dist, g.deg, grid)
    sd_lb = stats.sum_d(dist, g.deg, lb)
    pushed, long_, pulled = traversal.profit_terms(
        grid, lb, y, sd_grid, sd_lb, g.n_edges2, g.rtow[-1])
    assert np.all(np.asarray(pushed) >= -1e-6)
    assert np.all(np.asarray(long_) >= -1e-6)
    assert np.all(np.asarray(pulled) >= -1e-6)
    # pushed mass grows as x decreases (more settled band pushed)
    p = np.asarray(pushed)
    assert p[0] >= p[-1] - 1e-3


@settings(max_examples=40, deadline=None)
@given(st.floats(0.0, 1e6), st.floats(0.0, 1e6), st.floats(0.25, 4.0))
def test_gap_positive_on_degenerate_luts(sd, hd, mult):
    """w_floor clamp: every window is strictly positive — static and
    adaptive (``mult``-rescaled) alike — even on degenerate weight LUTs
    (all-zero, all-duplicate, and zero-heavy quantile tables)."""
    two_e = jnp.float32(1000.0)
    for lut in (np.zeros(64), np.full(64, 0.5),
                np.concatenate([np.zeros(63), [2.0]])):
        rtow = jnp.asarray(lut, jnp.float32)
        g0 = float(stepping.gap_from_stats(jnp.float32(sd), jnp.float32(hd),
                                           rtow, two_e))
        ga = float(stepping.gap_from_stats(jnp.float32(sd), jnp.float32(hd),
                                           rtow, two_e,
                                           mult=jnp.float32(mult)))
        # both inherit the >= max(w_floor, 1e-12) clamp: a shrunken
        # adaptive window can never hit zero and stall the solve loop
        assert g0 >= 1e-12, (lut[:3], sd, hd)
        assert ga >= 1e-12, (lut[:3], sd, hd, mult)


def test_gap_mult_one_matches_static(graph):
    """mult=1 reproduces the static window bitwise (the adaptive policy
    starts from the static program's exact widths)."""
    g = graph.to_device()
    dist = jnp.asarray(
        np.random.default_rng(5).random(graph.n).astype(np.float32))
    for x in [0.0, 0.3, 0.9]:
        g_static = stepping.gap(dist, g.deg, g.rtow, g.n_edges2,
                                jnp.float32(x))
        g_mult = stepping.gap(dist, g.deg, g.rtow, g.n_edges2,
                              jnp.float32(x), mult=jnp.float32(1.0))
        assert np.float32(g_static) == np.float32(g_mult)


def test_adaptive_update_clamps_and_snapshots():
    """Feedback clamps hold under extreme counters, and the counter
    snapshots always advance to the observed values."""
    pol = stepping.DEFAULT_ADAPTIVE
    ps = stepping.policy_init(stepping.SteppingParams())
    # hammer the "too wide" signal: mult must stop at mult_min
    for r in range(1, 30):
        ps = stepping.adaptive_update(ps, jnp.int32(100 * r),
                                      jnp.int32(1000 * r), jnp.int32(0))
    assert float(ps.mult) == pytest.approx(pol.mult_min)
    assert float(ps.alpha) >= pol.alpha_min
    assert float(ps.beta) >= pol.beta_min
    assert int(ps.last_rounds) == 100 * 29
    # hammer "too narrow": mult must stop at mult_max
    ps2 = stepping.policy_init(stepping.SteppingParams())
    for r in range(1, 30):
        ps2 = stepping.adaptive_update(ps2, jnp.int32(r),
                                       jnp.int32(10 * r), jnp.int32(10 * r))
    assert float(ps2.mult) == pytest.approx(pol.mult_max)
    assert float(ps2.alpha) <= pol.alpha_max
    assert float(ps2.beta) <= pol.beta_max


def test_compute_st_within_bounds(graph):
    g = graph.to_device()
    dist = jnp.asarray(
        np.random.default_rng(3).random(graph.n).astype(np.float32))
    st_ = float(traversal.compute_st(dist, g.deg, g.rtow, g.n_edges2,
                                     jnp.float32(0.2), jnp.float32(0.5)))
    assert 0.0 <= st_ <= 0.5 + 1e-6
