"""Correctness + paper-fidelity tests for the EIC SSSP engine."""
import numpy as np
import jax
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.baselines import dijkstra_host
from repro.core.graph import build_csr
from repro.core.sssp import sssp, normalized_metrics
from repro.data.generators import kronecker, road_grid, uniform_random


def _check_against_oracle(g, src):
    dg = g.to_device()
    dist, parent, metrics = sssp(dg, int(src))
    dist = np.asarray(dist)
    parent = np.asarray(parent)
    dref, _ = dijkstra_host(g, int(src))
    a = np.where(np.isfinite(dist), dist, -1.0)
    b = np.where(np.isfinite(dref), dref, -1.0)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    return dist, parent, metrics


@pytest.mark.parametrize("maker,kwargs", [
    (kronecker, dict(scale=10, edge_factor=8, seed=1)),
    (kronecker, dict(scale=12, edge_factor=4, seed=2)),
    (uniform_random, dict(n=2000, m=16000, seed=3)),
    (road_grid, dict(side=40, seed=4)),
])
def test_matches_dijkstra(maker, kwargs):
    g = maker(**kwargs)
    src = int(np.argmax(g.deg))
    _check_against_oracle(g, src)


def test_parent_tree_consistency():
    g = kronecker(10, 8, seed=5)
    src = int(np.argmax(g.deg))
    dist, parent, _ = _check_against_oracle(g, src)
    # every reached vertex's parent edge must certify its distance
    reach = np.isfinite(dist)
    adj = {}
    for s, d, w in zip(g.src, g.dst, g.w):
        adj[(int(s), int(d))] = min(adj.get((int(s), int(d)), np.inf),
                                    float(w))
    for v in np.where(reach)[0]:
        if v == src:
            assert parent[v] == src
            continue
        p = int(parent[v])
        assert p >= 0 and np.isfinite(dist[p])
        w = adj[(p, int(v))]
        np.testing.assert_allclose(dist[v], dist[p] + w, rtol=1e-4,
                                   atol=1e-5)


def test_triangle_inequality_certificate():
    """dist is optimal iff no edge can relax further (and source = 0)."""
    g = uniform_random(1500, 12000, seed=7)
    src = int(np.argmax(g.deg))
    dist, _, _ = _check_against_oracle(g, src)
    du = dist[g.src]
    dv = dist[g.dst]
    mask = np.isfinite(du)
    assert np.all(dv[mask] <= du[mask] + g.w[mask] + 1e-4)


def test_disconnected_graph_terminates():
    # two components; source in one -> other stays unreachable
    rng = np.random.default_rng(0)
    u1 = rng.integers(0, 50, 200)
    v1 = rng.integers(0, 50, 200)
    u2 = rng.integers(50, 100, 200)
    v2 = rng.integers(50, 100, 200)
    u = np.concatenate([u1, u2])
    v = np.concatenate([v1, v2])
    keep = u != v
    g = build_csr(100, u[keep], v[keep], rng.random(keep.sum()) + 0.01)
    dist, _, _ = sssp(g.to_device(), 0)
    dist = np.asarray(dist)
    assert np.all(~np.isfinite(dist[50:]))
    dref, _ = dijkstra_host(g, 0)
    np.testing.assert_allclose(np.where(np.isfinite(dist), dist, -1),
                               np.where(np.isfinite(dref), dref, -1),
                               rtol=1e-4)


def test_paper_metric_bands_low_diameter():
    """Paper §4.3/§4.4: nFrontier close to 1, nSync a few x log2(V),
    nTrav < (|E|/|V|)/2 on low-diameter graphs with enough skippable
    edges.  Sources are random (paper methodology: 64 random vertices) —
    hub-sourcing inflates the pre-bootstrap first window."""
    g = kronecker(14, 8, seed=1)
    dg = g.to_device()
    rng = np.random.default_rng(0)
    srcs = rng.choice(np.where(g.deg > 0)[0], 3, replace=False)
    nms = []
    for src in srcs:
        dist, _, metrics = sssp(dg, int(src))
        nms.append(normalized_metrics(g.deg, np.asarray(dist),
                                      jax.tree.map(np.asarray, metrics)))
    nm = {k: float(np.mean([m[k] for m in nms])) for k in nms[0]}
    assert nm["nFrontier"] < 1.20, nm
    assert nm["nSync"] < 8.0, nm
    e_over_v = g.m / 2 / g.n
    assert nm["nTrav"] < e_over_v / 2, (nm, e_over_v)


def test_leaf_pruning_counts():
    """Leaves are never extended: a star graph extends only the center."""
    n = 64
    u = np.zeros(n - 1, np.int64)
    v = np.arange(1, n, dtype=np.int64)
    g = build_csr(n, u, v, np.random.default_rng(0).random(n - 1) + 0.1)
    dist, _, metrics = sssp(g.to_device(), 0)
    assert np.isfinite(np.asarray(dist)).all()
    # center pop only (source), leaves pruned
    assert int(metrics.n_extended) == 1


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_random_graphs_match_oracle(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 150))
    m = int(rng.integers(n, 6 * n))
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    if keep.sum() == 0:
        return
    w = rng.random(keep.sum()) * float(rng.uniform(0.5, 10)) + 1e-3
    g = build_csr(n, u[keep], v[keep], w)
    nz = np.where(g.deg > 0)[0]
    if nz.size == 0:
        return
    src = int(nz[rng.integers(0, nz.size)])
    _check_against_oracle(g, src)
