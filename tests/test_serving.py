"""Serving engine: continuous batching produces per-request outputs that
match single-request greedy decoding."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def _greedy_reference(cfg, params, prompt, n_new, prompt_pad=8):
    pad = (-len(prompt)) % prompt_pad
    toks = jnp.asarray(np.pad(prompt, (pad, 0))[None, :])
    out = []
    cache, logits = T.prefill(cfg, params, toks, 64)
    cur = jnp.argmax(logits[0]).astype(jnp.int32)[None]
    out.append(int(cur[0]))
    for _ in range(n_new - 1):
        logits, cache = T.decode_step(cfg, params, cache, cur)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(cur[0]))
    return out


def test_continuous_batching_matches_single():
    cfg = T.LMConfig(name="serve-t", n_layers=2, d_model=64, n_heads=4,
                     n_kv=2, d_ff=96, vocab=97, head_dim=16,
                     dtype=jnp.float32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, max_batch=3, s_cache=64, prompt_pad=8)
    prompts = [rng.integers(0, 97, rng.integers(4, 12)).astype(np.int32)
               for _ in range(5)]
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r in reqs:
        ref = _greedy_reference(cfg, params, r.prompt, 6)
        assert r.out == ref, (r.rid, r.out, ref)
