"""Optimizer / checkpoint / fault-tolerance substrate tests."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt
from repro.train import failure
from repro.train import optimizer as opt_mod


def test_adamw_converges_quadratic():
    cfg = opt_mod.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              total_steps=200, min_lr_frac=1.0)
    params = {"x": jnp.asarray([5.0, -3.0]), "y": jnp.asarray(2.0)}
    state = opt_mod.adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.sum(p["x"] ** 2) + (p["y"] - 1.0) ** 2

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt_mod.adamw_update(params, g, state, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_master_weights_bf16():
    cfg = opt_mod.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                              min_lr_frac=1.0)
    params = {"w": jnp.asarray([1.0, 2.0], jnp.bfloat16)}
    state = opt_mod.adamw_init(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.asarray([1e-3, 1e-3], jnp.bfloat16)}
    p1 = params
    for _ in range(20):
        p1, state, _ = opt_mod.adamw_update(p1, g, state, cfg)
    # tiny updates accumulate in fp32 master even when bf16 would stall
    assert float(state["master"]["w"][0]) < 1.0
    assert p1["w"].dtype == jnp.bfloat16


def test_grad_clipping():
    cfg = opt_mod.AdamWConfig(clip_norm=1.0, warmup_steps=0)
    g = {"a": jnp.full((100,), 100.0)}
    params = {"a": jnp.zeros((100,))}
    state = opt_mod.adamw_init(params, cfg)
    _, _, metrics = opt_mod.adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4), jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, manifest = ckpt.restore(str(tmp_path), target_tree=tree)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_restart_byte_identical(tmp_path):
    """Train 10 steps straight == train 5, checkpoint, restore, train 5."""
    cfg = opt_mod.AdamWConfig(lr=0.05, warmup_steps=0, min_lr_frac=1.0,
                              weight_decay=0.0)

    def make_batch(step):
        rng = np.random.default_rng(step)
        return jnp.asarray(rng.normal(0, 1, (4,)).astype(np.float32))

    def step_fn(params, opt_state, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch) ** 2)
        g = jax.grad(loss)(params)
        params, opt_state, _ = opt_mod.adamw_update(params, g, opt_state,
                                                    cfg)
        return params, opt_state, {"loss": loss(params)}

    p0 = {"w": jnp.zeros(4)}
    s0 = opt_mod.adamw_init(p0, cfg)
    # straight run
    p, s = p0, s0
    for i in range(10):
        p, s, _ = step_fn(p, s, make_batch(i))
    # interrupted run
    p2, s2 = p0, s0
    for i in range(5):
        p2, s2, _ = step_fn(p2, s2, make_batch(i))
    ckpt.save(str(tmp_path), 5, (p2, s2))
    (p3, s3), _ = ckpt.restore(str(tmp_path), target_tree=(p2, s2))
    for i in range(5, 10):
        p3, s3, _ = step_fn(p3, s3, make_batch(i))
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(p3["w"]),
                               rtol=1e-6)


def test_run_restartable_resumes(tmp_path):
    cfg = opt_mod.AdamWConfig(lr=0.05, warmup_steps=0)

    def make_batch(step):
        return jnp.float32(step)

    def step_fn(params, opt_state, batch):
        g = {"w": params["w"] - batch}
        params, opt_state, m = opt_mod.adamw_update(params, g, opt_state,
                                                    cfg)
        return params, opt_state, {"loss": jnp.float32(0.0), **m}

    p0 = {"w": jnp.zeros(())}
    s0 = opt_mod.adamw_init(p0, cfg)
    state, last, pre = failure.run_restartable(
        step_fn, make_batch, (p0, s0), n_steps=6, ckpt_dir=str(tmp_path),
        ckpt_every=2, log_every=0, log_fn=lambda *_: None)
    assert last == 6 and not pre
    # resume continues from the stored checkpoint
    state2, last2, _ = failure.run_restartable(
        step_fn, make_batch, (p0, s0), n_steps=8, ckpt_dir=str(tmp_path),
        ckpt_every=2, log_every=0, log_fn=lambda *_: None)
    assert last2 == 8


def test_straggler_monitor():
    mon = failure.StragglerMonitor(window=16, threshold=2.0)
    for i in range(10):
        assert not mon.record(i, 0.1)
    assert mon.record(10, 0.5)
    assert mon.flagged and mon.flagged[0][0] == 10


def test_gradient_compression_error_feedback():
    from repro.parallel.compress import quantize, dequantize
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.1, (1000,)).astype(np.float32))
    q, scale = quantize(g)
    deq = dequantize(q, scale)
    # int8 quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.51
    # error feedback drives cumulative error to zero on a constant gradient
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, scale = quantize(g + err)
        deq = dequantize(q, scale)
        err = (g + err) - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(scale))
