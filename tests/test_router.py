"""Multi-device router: placement, stickiness, replication, tiers, parity.

Routing logic is exercised on any host by passing a repeated device list
(two schedulers over one physical device); the ``multidevice``-marked
parity test needs a real mesh — run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multidevice job does).
"""
import numpy as np
import pytest
import jax

from repro.core.sssp import sssp
from repro.data.generators import kronecker, road_grid, uniform_random
from repro.serve.queries import Query
from repro.serve.registry import GraphRegistry, ShardedGraphEngine
from repro.serve.router import QueryRouter
from repro.serve.scheduler import QueueFull

SIDE = 12


def two_graph_registry(**kw):
    reg = GraphRegistry(capacity=8, **kw)
    reg.register("road", road_grid(SIDE, seed=5))
    reg.register("kron", kronecker(7, 6, seed=2))
    return reg


def dup_devices(k=2):
    """k logical schedulers over the host's first device — routing logic
    is device-count independent."""
    return [jax.devices()[0]] * k


def test_placement_stickiness_and_spread():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2)
    futs = [router.submit(Query(gid="road", source=s)) for s in (0, 5, 9)]
    futs += [router.submit(Query(gid="kron", source=s)) for s in (1, 2)]
    router.drain()
    road_by = {f.result(timeout=0).served_by for f in futs[:3]}
    kron_by = {f.result(timeout=0).served_by for f in futs[3:]}
    # one sticky scheduler per graph, and the two graphs spread apart
    assert len(road_by) == 1 and len(kron_by) == 1
    assert road_by != kron_by
    st = router.stats()
    assert st["n_routed"] == 5 and st["n_done"] == 5
    assert set(st["placement"]) == {"road", "kron"}


def test_replicas_route_to_least_loaded():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2)
    router.plan_placement({"road": 1.0})     # both devices host road
    assert sorted(router.stats()["placement"]["road"]) == ["dev0", "dev1"]
    futs = [router.submit(Query(gid="road", source=s))
            for s in (0, 1, 2, 3)]
    router.drain()
    # with every queue empty at submit time, load alternates 0/1
    served = [f.result(timeout=0).served_by for f in futs]
    assert set(served) == {"dev0", "dev1"}


def test_hot_graph_replication_triggers():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         replicate_factor=2.0, replicate_min_depth=4)
    # a burst on one graph with no serving in between piles depth on its
    # sticky device until the router replicates it onto the idle one
    futs = [router.submit(Query(gid="road", source=s % 100))
            for s in range(12)]
    st = router.stats()
    assert st["n_replications"] >= 1
    assert len(st["placement"]["road"]) == 2
    router.drain()
    assert {f.result(timeout=0).served_by for f in futs} \
        == {"dev0", "dev1"}


def test_sharded_tier_served_by_mesh_scheduler():
    road = road_grid(SIDE, seed=5)
    reg = GraphRegistry(capacity=4, shard_threshold_n=100)
    reg.register("big", road)                # 144 >= 100 -> sharded
    reg.register("small", kronecker(6, 4, seed=2))   # 64 < 100 -> single
    assert reg.tier("big") == "sharded" and reg.tier("small") == "single"
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2)
    f_big = router.submit(Query(gid="big", source=0, kind="p2p",
                                target=100))
    f_small = router.submit(Query(gid="small", source=1))
    router.drain()
    res = f_big.result(timeout=0)
    assert res.served_by == "mesh"
    assert isinstance(reg.peek("big"), ShardedGraphEngine)
    assert f_small.result(timeout=0).served_by != "mesh"
    # sharded-tier answer matches the single-device engine bitwise
    d_ref, _, _ = sssp(road.to_device(), 0, goal="p2p", goal_param=100)
    assert np.float32(res.distance).tobytes() \
        == np.asarray(d_ref)[100].tobytes()
    settled = np.isfinite(np.asarray(res.dist))
    np.testing.assert_array_equal(np.asarray(res.dist)[settled],
                                  np.asarray(d_ref)[settled])


def test_router_load_shedding_is_per_device():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         max_pending=2)
    for s in (0, 1):
        router.submit(Query(gid="road", source=s))
    with pytest.raises(QueueFull):
        router.submit(Query(gid="road", source=2))   # road's device full
    # the other device still admits
    router.submit(Query(gid="kron", source=0))
    assert router.stats()["rejected"] == 1
    router.drain()


def test_warmup_builds_replicas_and_prepays_compiles():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2)
    router.plan_placement({"road": 3.0, "kron": 1.0})
    rows = router.warmup(kinds=("tree", "p2p"))
    # road is replicated on both schedulers, kron on one: 3 engines x 2
    # kinds
    assert len(rows) == 6
    assert {r["scheduler"] for r in rows if r["gid"] == "road"} \
        == {"dev0", "dev1"}
    builds = reg.stats.builds
    fut = router.submit(Query(gid="road", source=0, kind="p2p", target=9))
    router.drain()
    assert fut.result(timeout=0).distance is not None
    assert reg.stats.builds == builds        # traffic paid no build


def test_unknown_gid_fails_future_not_router():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2)
    bad = router.submit(Query(gid="nope", source=0))
    ok = router.submit(Query(gid="road", source=1))
    router.drain()
    with pytest.raises(KeyError):
        bad.result(timeout=0)
    assert ok.result(timeout=0).dist is not None


SCALE = 8


def benchmark_suite():
    """The 9-graph benchmark suite shape, scaled down for tests."""
    n = 1 << SCALE
    side = int(np.sqrt(n))
    return {
        f"gr{SCALE}_4": kronecker(SCALE, 4, seed=1),
        f"gr{SCALE}_8": kronecker(SCALE, 8, seed=2),
        f"gr{SCALE}_16": kronecker(SCALE, 16, seed=3),
        f"gr{SCALE}_32": kronecker(SCALE, 32, seed=4),
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 16 * n, seed=6),
        "Web": kronecker(SCALE, 30, seed=7),
        "Twitter": kronecker(SCALE, 22, seed=8),
        "Kron": kronecker(SCALE, 32, seed=9),
    }


@pytest.mark.multidevice
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device mesh (set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_router_bitwise_parity_on_all_benchmark_graphs():
    """Router-served results == single-device engine results, bitwise,
    on all nine benchmark graphs (the multi-device acceptance check)."""
    graphs = benchmark_suite()
    reg = GraphRegistry(capacity=len(graphs) + 1)
    for gid, g in graphs.items():
        reg.register(gid, g)
    router = QueryRouter(reg, max_batch=2)
    rng = np.random.default_rng(0)
    futs = []
    for gid, g in graphs.items():
        nz = np.where(g.deg > 0)[0]
        s, t = (int(v) for v in rng.choice(nz, 2, replace=False))
        futs.append((gid, "tree", s, None,
                     router.submit(Query(gid=gid, source=s))))
        futs.append((gid, "p2p", s, t,
                     router.submit(Query(gid=gid, source=s, kind="p2p",
                                         target=t))))
    router.start()
    results = [(gid, kind, s, t, f.result(timeout=600))
               for gid, kind, s, t, f in futs]
    router.stop()
    served_on = set()
    for gid, kind, s, t, res in results:
        served_on.add(res.served_by)
        d_ref, p_ref, _ = sssp(graphs[gid].to_device(), s)
        d_ref, p_ref = np.asarray(d_ref), np.asarray(p_ref)
        if kind == "tree":
            np.testing.assert_array_equal(res.dist, d_ref, err_msg=gid)
            np.testing.assert_array_equal(res.parent, p_ref, err_msg=gid)
        else:
            # p2p masks tentative entries; the target's distance (and the
            # whole settled prefix) must be bitwise-equal
            assert np.float32(res.distance).tobytes() \
                == d_ref[t].tobytes(), gid
            settled = np.isfinite(np.asarray(res.dist))
            np.testing.assert_array_equal(np.asarray(res.dist)[settled],
                                          d_ref[settled], err_msg=gid)
    # the suite actually exercised several devices
    assert len(served_on) >= 2


def test_reregister_rebuilds_placed_replicas_eagerly():
    """Replica consistency: a re-register() must not leave placed
    replicas to serve their next query from a cold build — the router's
    invalidation hook rebuilds them at the new generation immediately."""
    g1 = road_grid(SIDE, seed=5)
    g2 = road_grid(SIDE, seed=9)
    reg = GraphRegistry(capacity=8)
    reg.register("road", g1)
    router = QueryRouter(reg, devices=dup_devices(2))
    f = router.submit(Query(gid="road", source=0))
    router.drain()
    assert f.result().dist is not None
    builds0 = reg.stats.builds
    reg.register("road", g2)
    # the placed replica was rebuilt in the registering thread
    assert router.stats()["n_rebuilds"] == 1
    assert reg.stats.builds == builds0 + 1
    eng = reg.peek("road", device=router.devices[0])
    assert eng is not None and eng.generation == 2
    # the next query hits the warm rebuilt engine and serves the new spec
    hits0 = reg.stats.hits
    f2 = router.submit(Query(gid="road", source=0))
    router.drain()
    d_ref, _, _ = sssp(g2.to_device(), 0)
    np.testing.assert_array_equal(f2.result().dist, np.asarray(d_ref))
    assert reg.stats.hits > hits0
    # unplaced gids rebuild nothing
    reg.register("fresh", road_grid(SIDE, seed=3))
    reg.register("fresh", road_grid(SIDE, seed=4))
    assert router.stats()["n_rebuilds"] == 1


def test_reregister_rebuilds_served_sharded_engine():
    g1 = road_grid(SIDE, seed=5)
    g2 = road_grid(SIDE, seed=9)
    reg = GraphRegistry(capacity=8, shard_threshold_n=100)
    reg.register("big", g1)
    router = QueryRouter(reg, devices=dup_devices(2))
    f = router.submit(Query(gid="big", source=0))
    router.drain()
    assert f.result().dist is not None
    reg.register("big", g2)
    assert router.stats()["n_rebuilds"] == 1
    f2 = router.submit(Query(gid="big", source=0))
    router.drain()
    d_ref, _, _ = sssp(g2.to_device(), 0)
    np.testing.assert_array_equal(f2.result().dist, np.asarray(d_ref))


@pytest.mark.multidevice
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device mesh (set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_sharded_tier_blocked_backend_serves_bitwise():
    """The sharded serving tier with backend="blocked" (per-shard blocked
    slabs inside shard_map) over the whole mesh: bitwise parity with the
    single-device engine through the router path."""
    g = kronecker(9, 8, seed=2)
    reg = GraphRegistry(capacity=4, shard_threshold_n=1,
                        shard_backend="blocked", block_v=64, tile_e=64)
    reg.register("big", g)
    router = QueryRouter(reg, max_batch=2)
    srcs = [3, 99]
    futs = [router.submit(Query(gid="big", source=s)) for s in srcs]
    router.start()
    results = [f.result(timeout=600) for f in futs]
    router.stop()
    eng = reg.engine("big")
    assert isinstance(eng, ShardedGraphEngine)
    assert eng.backend == "blocked"
    dg = g.to_device()
    for s, res in zip(srcs, results):
        d_ref, p_ref, _ = sssp(dg, s)
        np.testing.assert_array_equal(res.dist, np.asarray(d_ref))
        np.testing.assert_array_equal(res.parent, np.asarray(p_ref))


def test_replica_decay_shrinks_cold_placement():
    """A replica whose share of its gid's traffic stays ~0 for
    decay_windows consecutive routing windows is torn down once its
    plan_placement protection has lapsed (the surviving replica is the
    one that carried the traffic)."""
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         decay_window=8, decay_windows=2, decay_share=0.0)
    router.plan_placement({"road": 1.0})     # road on both devices
    router.plan_placement({"kron": 1.0})     # kron on both devices
    assert sorted(router.stats()["placement"]["road"]) == ["dev0", "dev1"]
    assert sorted(router.stats()["placement"]["kron"]) == ["dev0", "dev1"]
    # window 1: submit pairs before draining so the queue-depth
    # tie-break spreads each pair across both replicas — the planned
    # replicas carry real traffic, which lapses their decay protection
    for s in range(4):
        router.submit(Query(gid="road", source=s))
        router.submit(Query(gid="road", source=s + 50))
        router.drain()
    # windows 2-3: drain after every submit, the queues are empty at
    # each routing decision, ties break to dev0, and dev1's share of
    # road traffic stays 0 through both windows
    for s in range(16):
        router.submit(Query(gid="road", source=s % 100))
        router.drain()
    st = router.stats()
    assert st["n_decays"] >= 1
    assert st["placement"]["road"] == ["dev0"]
    # an entirely-cold gid keeps its placement: decay reacts to skew
    # within a gid's traffic, not to the gid being idle
    assert sorted(st["placement"]["kron"]) == ["dev0", "dev1"]
    # traffic keeps serving from the surviving replica
    fut = router.submit(Query(gid="road", source=3))
    router.drain()
    assert fut.result(timeout=0).served_by == "dev0"


def test_planned_replicas_protected_from_decay():
    """plan_placement pre-placements are exempt from share-based decay
    until their forecast traffic actually arrives: a provisioned replica
    that never carries a query is not torn down."""
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         decay_window=8, decay_windows=2, decay_share=0.0)
    router.plan_placement({"road": 1.0})
    # every query lands on dev0 (queues drained, ties break low): dev1's
    # planned replica sits at 0 share for four windows and survives
    for s in range(32):
        router.submit(Query(gid="road", source=s % 100))
        router.drain()
    st = router.stats()
    assert st["n_decays"] == 0
    assert sorted(st["placement"]["road"]) == ["dev0", "dev1"]


def test_decay_min_traffic_gates_decay():
    """Below ``decay_min_traffic`` total window traffic a skewed window
    does not decay replicas; once the gate is met the same skew does."""
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         decay_window=8, decay_windows=1, decay_share=0.0,
                         decay_min_traffic=9)
    # a non-planned two-replica placement (as hot replication leaves it)
    with router._lock:
        router._placement["road"] = [0, 1]
        router._n_placed[0] += 1
        router._n_placed[1] += 1
    for s in range(8):                       # skewed, but 8 < 9: gated
        router.submit(Query(gid="road", source=s))
        router.drain()
    assert router.stats()["n_decays"] == 0
    assert sorted(router.stats()["placement"]["road"]) == ["dev0", "dev1"]
    router.decay_min_traffic = 1
    for s in range(8):                       # same skew, gate met
        router.submit(Query(gid="road", source=s))
        router.drain()
    st = router.stats()
    assert st["n_decays"] == 1
    assert st["placement"]["road"] == ["dev0"]


def test_replica_decay_disabled_with_zero_window():
    reg = two_graph_registry()
    router = QueryRouter(reg, devices=dup_devices(2), max_batch=2,
                         decay_window=0)
    router.plan_placement({"road": 1.0})
    for s in range(12):
        router.submit(Query(gid="road", source=s))
        router.drain()
    st = router.stats()
    assert st["n_decays"] == 0
    assert sorted(st["placement"]["road"]) == ["dev0", "dev1"]
