"""Early-exit query goals (p2p / bounded / knear) vs full-tree SSSP."""
import numpy as np
import pytest

from repro.core.sssp import sssp, sssp_batch
from repro.data.generators import kronecker, road_grid, uniform_random

SCALE = 8


def benchmark_suite():
    """The 9-graph benchmark suite shape, scaled down for tests."""
    n = 1 << SCALE
    side = int(np.sqrt(n))
    return {
        f"gr{SCALE}_4": kronecker(SCALE, 4, seed=1),
        f"gr{SCALE}_8": kronecker(SCALE, 8, seed=2),
        f"gr{SCALE}_16": kronecker(SCALE, 16, seed=3),
        f"gr{SCALE}_32": kronecker(SCALE, 32, seed=4),
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 16 * n, seed=6),
        "Web": kronecker(SCALE, 30, seed=7),
        "Twitter": kronecker(SCALE, 22, seed=8),
        "Kron": kronecker(SCALE, 32, seed=9),
    }


def test_p2p_matches_full_tree_on_all_benchmark_graphs():
    rng = np.random.default_rng(0)
    for name, g in benchmark_suite().items():
        dg = g.to_device()
        nz = np.where(g.deg > 0)[0]
        s, t = (int(v) for v in rng.choice(nz, 2, replace=False))
        d_full, p_full, m_full = sssp(dg, s)
        d_p2p, p_p2p, m_p2p = sssp(dg, s, goal="p2p", goal_param=t)
        d_full, d_p2p = np.asarray(d_full), np.asarray(d_p2p)
        # bitwise-equal target distance (and parent, when reachable)
        assert d_p2p[t].tobytes() == d_full[t].tobytes(), name
        if np.isfinite(d_full[t]):
            assert int(np.asarray(p_p2p)[t]) == int(np.asarray(p_full)[t]), \
                name
        assert int(m_p2p.n_rounds) <= int(m_full.n_rounds), name


def test_p2p_saves_rounds_on_road():
    g = road_grid(20, seed=5)
    dg = g.to_device()
    # nearby target on a huge-diameter graph: the window sweep stops early
    d_full, _, m_full = sssp(dg, 0)
    d_p2p, _, m_p2p = sssp(dg, 0, goal="p2p", goal_param=42)
    assert np.asarray(d_p2p)[42] == np.asarray(d_full)[42]
    assert int(m_p2p.n_rounds) < int(m_full.n_rounds)


def test_bounded_settles_everything_within_bound():
    g = kronecker(SCALE, 8, seed=2)
    dg = g.to_device()
    s = int(np.argmax(g.deg))
    d_full, _, m_full = sssp(dg, s)
    d_full = np.asarray(d_full)
    bound = float(np.percentile(d_full[np.isfinite(d_full)], 40))
    d_b, _, m_b = sssp(dg, s, goal="bounded", goal_param=bound)
    d_b = np.asarray(d_b)
    within = d_full <= bound
    np.testing.assert_array_equal(d_b[within], d_full[within])
    assert int(m_b.n_rounds) <= int(m_full.n_rounds)


def test_knear_returns_k_smallest_final_distances():
    g = kronecker(SCALE, 8, seed=2)
    dg = g.to_device()
    s = int(np.argmax(g.deg))
    k = 12
    d_full, _, _ = sssp(dg, s)
    d_k, _, _ = sssp(dg, s, goal="knear", goal_param=k)
    d_full, d_k = np.asarray(d_full), np.asarray(d_k)
    # the k+1 smallest values (source included) are settled and exact
    np.testing.assert_array_equal(np.sort(d_k)[:k + 1],
                                  np.sort(d_full)[:k + 1])


def test_batched_goal_params_per_slot():
    g = road_grid(16, seed=5)
    dg = g.to_device()
    d_full, _, _ = sssp(dg, 0)
    d_full = np.asarray(d_full)
    tgts = np.array([3, 40, 100, 255], np.int32)
    dist, _, metrics = sssp_batch(dg, np.zeros(4, np.int32), goal="p2p",
                                  goal_params=tgts)
    dist = np.asarray(dist)
    for i, t in enumerate(tgts):
        assert dist[i, t].tobytes() == d_full[t].tobytes()
    # nearer targets in the same batch terminate in fewer rounds
    rounds = np.asarray(metrics.n_rounds)
    assert rounds[0] <= rounds[-1]


def test_goal_validation():
    g = road_grid(8, seed=0)
    dg = g.to_device()
    with pytest.raises(ValueError):
        sssp(dg, 0, goal="nope", goal_param=1)
    with pytest.raises(ValueError):
        sssp(dg, 0, goal="p2p")            # missing parameter
    with pytest.raises(ValueError):
        sssp_batch(dg, [0, 1], goal="p2p", goal_params=[1])  # shape mismatch
    with pytest.raises(ValueError):
        sssp(dg, 0, goal="p2p", goal_param=dg.n + 3)   # o-o-b clamps in jit
    with pytest.raises(ValueError):
        sssp_batch(dg, [0, 1], goal="p2p", goal_params=[1, -2])
