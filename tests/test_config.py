"""EngineConfig construction + resolve(): the error paths must fire
loudly, host-side, before any layout build or tracing."""
import numpy as np
import pytest

from repro.api import SolveSpec, Solver
from repro.core.config import ConfigError, EngineConfig, as_resolved
from repro.core.graph import build_blocked
from repro.data.generators import road_grid


def test_context_free_validation():
    with pytest.raises(ConfigError):
        EngineConfig(tier="bogus")
    with pytest.raises(ConfigError):
        EngineConfig(shard_version="v9")
    with pytest.raises(ConfigError):
        EngineConfig(backend="nope")
    with pytest.raises(ConfigError):
        EngineConfig(shard_backend="nope")
    with pytest.raises(ConfigError):
        EngineConfig(devices=())
    with pytest.raises(ConfigError):
        EngineConfig(max_batch=0)
    with pytest.raises(ConfigError):
        EngineConfig(alpha=0.0)
    with pytest.raises(ConfigError):
        EngineConfig(tile_e=0)


def test_auto_tier_resolution_by_thresholds():
    cfg = EngineConfig(shard_threshold_n=100)
    assert cfg.resolve(n=144, m=500, n_devices=2).tier == "sharded"
    assert cfg.resolve(n=64, m=500, n_devices=2).tier == "single"
    cfg_m = EngineConfig(shard_threshold_m=400)
    assert cfg_m.resolve(n=64, m=500, n_devices=2).tier == "sharded"
    # auto without thresholds: single, no graph size needed
    assert EngineConfig().resolve(n_devices=1).tier == "single"
    # auto *with* thresholds needs the size to decide
    with pytest.raises(ConfigError):
        cfg.resolve(n_devices=2)


def test_fused_rounds_resolution():
    # the blocked single-device backend carries fused_rounds through
    r = EngineConfig(backend="blocked_pallas", fused_rounds=4).resolve(
        n=10, m=10, n_devices=1)
    assert r.fused_rounds == 4 and r.tier == "single"
    # ... but segment_min has no megakernel to fuse into
    with pytest.raises(ConfigError):
        EngineConfig(backend="segment_min", fused_rounds=4).resolve(
            n=10, m=10, n_devices=1)
    # sharded tier: both backends accept it (waves vs grouped rounds)
    for sb in ("segment_min", "blocked"):
        r = EngineConfig(tier="sharded", shard_backend=sb,
                         fused_rounds=4).resolve(n=10, m=10, n_devices=2)
        assert r.fused_rounds == 4


def test_from_loose_gate():
    cfg = EngineConfig(backend="blocked_pallas")
    # config alone passes through untouched
    assert EngineConfig.from_loose(cfg, "engine", backend=None,
                                   alpha=None) is cfg
    # config + any set loose kwarg is ambiguous -> loud error
    with pytest.raises(ConfigError, match="through config="):
        EngineConfig.from_loose(cfg, "engine", backend="segment_min")
    # loose kwargs layer over the entry point's defaults
    c = EngineConfig.from_loose(None, "engine",
                                defaults={"shard_backend": "segment_min"},
                                alpha=2.0, backend=None)
    assert c.alpha == 2.0 and c.shard_backend == "segment_min"
    # a set loose kwarg overrides the default
    c = EngineConfig.from_loose(None, "engine",
                                defaults={"shard_backend": "segment_min"},
                                shard_backend="blocked")
    assert c.shard_backend == "blocked"
    # unknown loose options fail like a bad keyword argument
    with pytest.raises(TypeError, match="unknown engine options"):
        EngineConfig.from_loose(None, "engine", bogus=1)
    # relax-backend objects are canonicalized to their registry name
    from repro.core.relax import get_backend
    c = EngineConfig.from_loose(None, "engine",
                                backend=get_backend("blocked"))
    assert c.backend == "blocked_pallas"


def test_conflicting_backend_tier_combos():
    # shard options on a single-tier engine
    with pytest.raises(ConfigError):
        EngineConfig(shard_backend="blocked").resolve(n=10, m=10,
                                                      n_devices=1)
    with pytest.raises(ConfigError):
        EngineConfig(fused_rounds=4).resolve(n=10, m=10, n_devices=1)
    # blocked geometry without any blocked backend
    with pytest.raises(ConfigError):
        EngineConfig(block_v=64).resolve(n=10, m=10, n_devices=1)
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", use_kernel=True).resolve(
            n=10, m=10, n_devices=1)
    # v3-only knob on another version
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", compact_capacity=8).resolve(
            n=10, m=10, n_devices=1)
    # thresholds contradict an explicit single/sharded tier
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", shard_threshold_n=5).resolve(
            n=10, m=10, n_devices=1)
    # backend and shard_backend that disagree on the sharded tier
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", backend="blocked_pallas",
                     shard_backend="segment_min").resolve(n=10, m=10,
                                                          n_devices=1)
    # single tier cannot span several devices
    with pytest.raises(ConfigError):
        EngineConfig(tier="single", devices=(0, 1)).resolve(
            n=10, m=10, n_devices=2)
    # more pinned devices than visible
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", devices=(0, 1, 2)).resolve(
            n=10, m=10, n_devices=2)


def test_resolve_canonicalizes_and_derives_shard_backend():
    r = EngineConfig(backend="blocked_pallas", tier="sharded",
                     block_v=64).resolve(n=10, m=10, n_devices=2)
    assert r.backend == "blocked_pallas"
    assert r.shard_backend == "blocked"      # derived, no explicit field
    assert r.n_shards == 2
    assert r.layout_opts()["block_v"] == 64
    # resolved engines pass through as_resolved unchanged
    assert as_resolved(r) is r
    with pytest.raises(ConfigError):
        as_resolved("segment_min")
    # require() guards entry points
    with pytest.raises(ConfigError):
        r.require("single")
    assert r.require("sharded", "routed") is r


def test_engine_entry_points_reject_config_plus_loose_kwargs():
    from repro.core.distributed import shard_graph, sssp_distributed
    from repro.core.sssp import sssp
    import jax
    g = road_grid(8, seed=0)
    cfg = EngineConfig().resolve(n=g.n, m=g.m, n_devices=1)
    with pytest.raises(ConfigError):
        sssp(g.to_device(), 0, config=cfg, backend="segment_min")
    with pytest.raises(ConfigError):
        sssp(g.to_device(), 0, config=cfg, alpha=2.0)
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    shard_cfg = EngineConfig(tier="sharded")
    with pytest.raises(ConfigError):
        sssp_distributed(sg, 0, mesh, ("graph",), config=shard_cfg,
                         version="v1")


def test_layer_constructors_reject_config_plus_loose_kwargs():
    from repro.serve.registry import GraphRegistry
    from repro.serve.router import QueryRouter
    from repro.serve.sssp_service import SsspService
    g = road_grid(8, seed=0)
    cfg = EngineConfig(max_batch=4)
    with pytest.raises(ConfigError):
        GraphRegistry(config=cfg, backend="blocked_pallas")
    reg = GraphRegistry(config=cfg)
    with pytest.raises(ConfigError):
        QueryRouter(reg, config=cfg, max_batch=2)
    with pytest.raises(ConfigError):
        SsspService(g, config=cfg, max_batch=2)
    # the config path works and carries the batch width through
    svc = SsspService(g, config=cfg)
    assert svc.max_batch == 4


def test_blocked_backend_rejects_unpadded_or_foreign_layouts():
    g = road_grid(12, seed=2)
    cfg = EngineConfig(backend="blocked_pallas")
    # a flat-edge-list layout is not a blocked layout
    with pytest.raises(ConfigError):
        Solver.open(g, cfg, layout=g.to_device())
    # a blocked layout built for a *different* graph (wrong n / padding)
    other = build_blocked(road_grid(10, seed=1), block_v=64, tile_e=64)
    with pytest.raises(ConfigError):
        Solver.open(g, cfg, layout=other)
    # a shard slice (src_base != 0, partial source range) is rejected too
    from repro.core.graph import slice_for_shard
    slab = slice_for_shard(g, 1, 2, block_v=32, tile_e=32)
    with pytest.raises(ConfigError):
        Solver.open(g, cfg, layout=slab)
    # geometry disagreement between config and layout
    bl = build_blocked(g, block_v=64, tile_e=64)
    with pytest.raises(ConfigError):
        Solver.open(g, EngineConfig(backend="blocked_pallas", tile_e=128),
                    layout=bl)
    # and the segment_min backend cannot consume a BlockedGraph
    with pytest.raises(ConfigError):
        Solver.open(g, EngineConfig(), layout=bl)
    # the valid pairing still opens and solves
    s = Solver.open(g, EngineConfig(backend="blocked_pallas"), layout=bl)
    assert np.isfinite(s.solve(SolveSpec.p2p(0, 100)).distance())


def test_out_of_range_solvespec_sources_raise_before_tracing():
    g = road_grid(8, seed=0)
    s = Solver.open(g)
    with pytest.raises(ValueError, match="out of range"):
        s.solve(SolveSpec.tree(g.n + 5))
    with pytest.raises(ValueError, match="out of range"):
        s.solve(SolveSpec.tree([0, g.n]))
    with pytest.raises(ValueError, match="out of range"):
        s.solve(SolveSpec.p2p(0, g.n + 1))
    with pytest.raises(ValueError, match="out of range"):
        s.solve(SolveSpec.p2p([0, 1], [1, g.n]))


def test_device_indices_resolve_and_range_check():
    import jax
    from repro.core.config import resolve_devices
    assert resolve_devices(None) is None
    assert resolve_devices((0,)) == [jax.devices()[0]]
    with pytest.raises(ConfigError):
        resolve_devices((999,))
    # a bad index fails in resolve(), not as an IndexError mid-build
    with pytest.raises(ConfigError):
        EngineConfig(tier="sharded", devices=(999,)).resolve(n=10, m=10)
    # config-pinned integer devices drive the service's router path
    from repro.serve.sssp_service import SsspRequest, SsspService
    g = road_grid(8, seed=0)
    svc = SsspService(g, config=EngineConfig(devices=(0,), max_batch=2))
    req = svc.submit(SsspRequest(rid=0, source=1))
    svc.run()
    assert req.error is None and req.dist is not None


def test_engine_variant_knobs_ride_config_into_serving_engines():
    """Nothing a resolve()-accepted config declares is silently dropped:
    fused_rounds/compact_capacity/max_iters reach the built engines."""
    from repro.serve.registry import GraphRegistry
    g = road_grid(8, seed=0)
    reg = GraphRegistry(config=EngineConfig(
        shard_threshold_n=1, shard_version="v3", fused_rounds=2,
        compact_capacity=16, max_iters=777))
    reg.register("big", g)                       # 64 >= 1 -> sharded
    eng = reg.engine("big")
    assert eng.tier == "sharded"
    assert eng.fused_rounds == 2 and eng.capacity == 16
    assert eng.max_iters == 777
    reg2 = GraphRegistry(config=EngineConfig(max_iters=555))
    reg2.register("small", g)
    assert reg2.engine("small").max_iters == 555
    # and the symmetric single-tier rejection for the v3-only knob
    with pytest.raises(ConfigError):
        EngineConfig(shard_version="v3", compact_capacity=16).resolve(
            n=10, m=10, n_devices=1)


def test_segment_min_rejects_foreign_device_graph_layouts():
    g = road_grid(12, seed=2)
    # same n, different graph: the edge list IS the layout, so this
    # would silently answer over the wrong edges — reject host-side
    other = road_grid(12, seed=9).to_device()
    with pytest.raises(ConfigError):
        Solver.open(g, EngineConfig(), layout=other)
    with pytest.raises(ConfigError):
        Solver.open(g, EngineConfig(), layout="not a layout")
    # the graph's own device form is the valid layout
    s = Solver.open(g, EngineConfig(), layout=g.to_device())
    assert np.isfinite(s.solve(SolveSpec.p2p(0, 100)).distance())


def test_serving_config_rejects_capacity_off_v3():
    from repro.serve.registry import GraphRegistry
    with pytest.raises(ConfigError):
        GraphRegistry(config=EngineConfig(shard_version="v2",
                                          compact_capacity=64,
                                          shard_threshold_n=1))


def test_auto_tier_config_with_thresholds_holds_shard_options():
    """A deployment config (auto tier + thresholds + shard options) must
    not fail data-dependently on graphs below the threshold — the shard
    fields are held for the graphs that cross it."""
    g = road_grid(8, seed=0)                     # n=64, far below
    cfg = EngineConfig(shard_threshold_n=100_000,
                       shard_backend="blocked", block_v=64, tile_e=64)
    r = cfg.resolve(n=g.n, m=g.m, n_devices=1)
    assert r.tier == "single"
    s = Solver.open(g, cfg)
    assert np.isfinite(s.solve(SolveSpec.p2p(0, 30)).distance())
    # without thresholds the same shard options are dead weight -> loud
    with pytest.raises(ConfigError):
        EngineConfig(shard_backend="blocked").resolve(n=g.n, m=g.m,
                                                      n_devices=1)


def test_loose_blocked_backend_keeps_segment_min_sharded_tier():
    """Pre-facade behavior preserved: the loose-kwargs paths' default
    shard_backend='segment_min' is an explicit choice — a blocked
    single-device backend must not silently derive a blocked sharded
    tier through the synthesized config."""
    from repro.serve.registry import GraphRegistry
    from repro.serve.sssp_service import SsspService
    g = road_grid(8, seed=0)
    reg = GraphRegistry(backend="blocked_pallas", block_v=64, tile_e=64,
                        shard_threshold_n=1)
    reg.register("g", g)
    eng = reg.engine("g")
    assert eng.tier == "sharded" and eng.backend == "segment_min"
    assert reg.config.effective_shard_backend == "segment_min"
    import jax
    svc = SsspService(g, backend="blocked_pallas", block_v=64, tile_e=64,
                      shard_threshold_n=1, devices=jax.devices())
    assert svc.config.effective_shard_backend == "segment_min"
    # a user config that wants the blocked sharded tier says so
    assert EngineConfig(backend="blocked_pallas",
                        shard_threshold_n=1).effective_shard_backend \
        == "blocked"
