"""Transformer-specific behaviour: decode==forward, blockwise==dense,
MoE dispatch correctness, tied embeddings."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import transformer as T


def _cfg(**kw):
    base = dict(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                d_ff=96, vocab=128, head_dim=16, dtype=jnp.float32)
    base.update(kw)
    return T.LMConfig(**base)


def test_decode_matches_forward():
    cfg = _cfg(qk_norm=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    cache, lg = T.prefill(cfg, params, toks, 20)
    full, _ = T.forward(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    cur = jnp.argmax(lg, -1).astype(jnp.int32)
    seq = toks
    for _ in range(4):
        lg, cache = T.decode_step(cfg, params, cache, cur)
        seq = jnp.concatenate([seq, cur[:, None]], 1)
        ref, _ = T.forward(cfg, params, seq)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                                   rtol=2e-3, atol=2e-3)
        cur = jnp.argmax(lg, -1).astype(jnp.int32)


def test_swa_ring_buffer_decode():
    cfg = _cfg(attn_window=8)
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    cache, lg = T.prefill(cfg, params, toks, 8)  # ring buffer == window
    cur = jnp.argmax(lg, -1).astype(jnp.int32)
    seq = toks
    for _ in range(6):
        lg, cache = T.decode_step(cfg, params, cache, cur)
        seq = jnp.concatenate([seq, cur[:, None]], 1)
        ref, _ = T.forward(cfg, params, seq)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                                   rtol=2e-3, atol=2e-3)
        cur = jnp.argmax(lg, -1).astype(jnp.int32)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000))
def test_blockwise_attention_property(seed):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 3))
    s = int(rng.integers(16, 300))
    kv = int(rng.choice([1, 2, 4]))
    hg = int(rng.integers(1, 3))
    hd = int(rng.choice([8, 16, 32]))
    window = int(rng.choice([0, 0, max(4, s // 3)]))
    cfg = _cfg(n_heads=kv * hg, n_kv=kv, head_dim=hd, attn_window=window)
    q = jnp.asarray(rng.normal(0, 1, (b, s, kv, hg, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    dense = T._sdpa_dense(cfg, q, k, v, pos, pos, True)
    blk = T._sdpa_blockwise(cfg, q, k, v, pos, pos, True, block_q=64,
                            block_k=48)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(blk),
                               rtol=1e-4, atol=1e-5)


def test_moe_matches_dense_loop():
    """With ample capacity, sort-dispatch MoE == explicit per-token loop."""
    cfg = _cfg(moe=True, n_experts=8, top_k=2, n_shared=1, d_ff=32,
               capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(5))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 6, cfg.d_model))
    y, aux = T.moe_block(cfg, lp, x)

    # dense reference: route every token through its top-k experts
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ lp["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ lp["e_gate"][e]) * (xt[t] @ lp["e_up"][e])
            acc = acc + gate[t, j] * (h @ lp["e_down"][e])
        # shared expert
        h = jax.nn.silu(xt[t] @ lp["s_gate"]) * (xt[t] @ lp["s_up"])
        acc = acc + h @ lp["s_down"]
        ref.append(acc)
    ref = jnp.stack(ref).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens():
    cfg = _cfg(moe=True, n_experts=4, top_k=1, n_shared=0,
               capacity_factor=0.26)
    params = T.init_params(cfg, jax.random.PRNGKey(8))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 128, cfg.d_model))
    y, _ = T.moe_block(cfg, lp, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_tied_embeddings():
    cfg = _cfg(tied_embed=True)
    params = T.init_params(cfg, jax.random.PRNGKey(10))
    assert "lm_head" not in params
    toks = jax.random.randint(jax.random.PRNGKey(11), (1, 8), 0, cfg.vocab)
    logits, _ = T.forward(cfg, params, toks)
    assert logits.shape == (1, 8, cfg.vocab)
    assert cfg.param_count() == sum(x.size for x in jax.tree.leaves(params))


def test_microbatch_grad_accumulation_equivalence():
    """microbatches=k gives the same update as full-batch (mean loss)."""
    from repro.train import loop as train_loop, optimizer as opt_mod
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(12))
    opt_cfg = opt_mod.AdamWConfig(lr=1e-2, warmup_steps=0)
    opt = opt_mod.adamw_init(params, opt_cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(13), (8, 16),
                                          0, cfg.vocab)}
    s1 = train_loop.make_lm_train_step(cfg, opt_cfg, microbatches=1)
    s4 = train_loop.make_lm_train_step(cfg, opt_cfg, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)
