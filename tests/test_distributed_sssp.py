"""Distributed SSSP (shard_map) vs oracle and vs the single-device engine —
runs in a subprocess with 8 forced host devices (the main test process
keeps 1 device).  With 8 real shards, v1/v2/v3 must still be bitwise
identical to the single-device engine — dist, parent and every metric
counter — because all engines dispatch relaxation through the shared
primitives in core/relax.py (fused bucket waves are exempt from metric
parity: they intentionally relax local edges extra times)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.data.generators import kronecker, road_grid
from repro.core.distributed import shard_graph, sssp_distributed
from repro.core.sssp import sssp
from repro.core.baselines import dijkstra_host

mesh = jax.make_mesh((8,), ("graph",))
failures = []
for name, g in [("kron", kronecker(9, 8, seed=1)),
                ("road", road_grid(20, seed=2))]:
    sg = shard_graph(g, 8)
    src = int(np.argmax(g.deg))
    dref, _ = dijkstra_host(g, src)
    d1, p1, m1 = sssp(g.to_device(), src)
    d1, p1 = np.asarray(d1), np.asarray(p1)
    for ver, fused in [("v1", 0), ("v2", 0), ("v2", 8), ("v3", 0)]:
        dist, parent, metrics = sssp_distributed(sg, src, mesh, ("graph",),
                                                 version=ver,
                                                 fused_rounds=fused)
        dist = np.asarray(dist)[:g.n]
        parent = np.asarray(parent)[:g.n]
        ok = np.allclose(np.where(np.isfinite(dist), dist, -1),
                         np.where(np.isfinite(dref), dref, -1),
                         rtol=1e-4, atol=1e-5)
        same = True if fused else (np.array_equal(dist, d1) and
                                   np.array_equal(parent, p1))
        mdiff = [] if fused else [
            f for f in m1._fields
            if int(getattr(m1, f)) != int(getattr(metrics, f))]
        print(f"{name}/{ver}/fused={fused}: ok={ok} parity={same} "
              f"metric_diffs={mdiff} exchanges={int(metrics.n_rounds)}")
        if not ok or not same or mdiff:
            failures.append((name, ver, fused, mdiff))
assert not failures, failures
print("DISTRIBUTED_OK")

# goal-aware early exit + the batch entry point (the sharded serving
# tier's interface) keep bitwise parity with the single-device engine
from repro.core.distributed import sssp_distributed_batch
from repro.core.sssp import sssp_batch, sssp_p2p

g = road_grid(20, seed=2)
sg = shard_graph(g, 8)
dg = g.to_device()
rng = np.random.default_rng(0)
nz = np.where(g.deg > 0)[0]
srcs = rng.choice(nz, 3, replace=False).astype(np.int32)
tgts = rng.choice(nz, 3, replace=False).astype(np.int32)
d_b, p_b, m_b = sssp_distributed_batch(sg, srcs, mesh, ("graph",),
                                       version="v2", goal="p2p",
                                       goal_params=tgts)
d_r, p_r, m_r = sssp_batch(dg, srcs, goal="p2p", goal_params=tgts)
for i, t in enumerate(tgts):
    assert np.asarray(d_b)[i, int(t)].tobytes() \
        == np.asarray(d_r)[i, int(t)].tobytes(), i
assert np.array_equal(np.asarray(m_b.n_rounds), np.asarray(m_r.n_rounds))
s, t = int(srcs[0]), int(tgts[0])
ds, _, ms = sssp_p2p(dg, s, t)
for ver in ["v1", "v2", "v3"]:
    d, p, m = sssp_distributed(sg, s, mesh, ("graph",), version=ver,
                               goal="p2p", goal_param=t)
    assert np.asarray(d)[t].tobytes() == np.asarray(ds)[t].tobytes(), ver
    assert int(m.n_rounds) == int(ms.n_rounds), (ver, int(m.n_rounds))
print("GOALS_OK")
"""


@pytest.mark.slow
def test_distributed_matches_oracle():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "DISTRIBUTED_OK" in proc.stdout and "GOALS_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"


def test_distributed_goal_batch_single_shard():
    """Fast in-process coverage (1-shard mesh) of the goal-aware batch
    entry point: parity with the single-device batched engine."""
    import numpy as np
    import jax

    from repro.core.distributed import (shard_graph, sssp_distributed,
                                        sssp_distributed_batch)
    from repro.core.sssp import sssp_batch
    from repro.data.generators import road_grid

    g = road_grid(12, seed=2)
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    srcs = np.array([0, 5], np.int32)
    tgts = np.array([100, 30], np.int32)
    dist, parent, metrics = sssp_distributed_batch(
        sg, srcs, mesh, ("graph",), goal="p2p", goal_params=tgts)
    d_ref, p_ref, m_ref = sssp_batch(g.to_device(), srcs, goal="p2p",
                                     goal_params=tgts)
    n = g.n
    np.testing.assert_array_equal(np.asarray(dist)[:, :n],
                                  np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(parent)[:, :n],
                                  np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(metrics.n_rounds),
                                  np.asarray(m_ref.n_rounds))
    # bounded goal on the single-source entry point
    d_b, _, _ = sssp_distributed(sg, 0, mesh, ("graph",), goal="bounded",
                                 goal_param=2.5)
    from repro.core.sssp import sssp_bounded
    d_bref, _, _ = sssp_bounded(g.to_device(), 0, 2.5)
    np.testing.assert_array_equal(np.asarray(d_b)[:n], np.asarray(d_bref))
    # o-o-b p2p targets are rejected against the real vertex count (a jit
    # gather would clamp silently; padding vertices never settle)
    with pytest.raises(ValueError):
        sssp_distributed(sg, 0, mesh, ("graph",), goal="p2p",
                         goal_param=n + 1)
