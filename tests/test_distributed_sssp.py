"""Distributed SSSP (shard_map) vs oracle and vs the single-device engine —
runs in a subprocess with 8 forced host devices (the main test process
keeps 1 device).  With 8 real shards, v1/v2/v3 — under both the
segment_min and the blocked per-shard relaxation backends — must still
be bitwise identical to the single-device engine: dist, parent and every
logical metric counter, because all engines dispatch relaxation through
the shared primitives in core/relax.py.  ``fused_rounds`` is
backend-dependent: segment_min bucket-fusion waves are exempt from
parity (they intentionally relax local edges extra times), while the
blocked backend's grouped complete rounds keep FULL bitwise parity —
each grouped round includes its whole collective exchange (the physical
n_tiles_* counters are layout-specific and excluded everywhere)."""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.data.generators import kronecker, road_grid
from repro.core.distributed import shard_blocked, shard_graph, sssp_distributed
from repro.core.sssp import LOGICAL_METRIC_FIELDS, sssp
from repro.core.baselines import dijkstra_host

mesh = jax.make_mesh((8,), ("graph",))
failures = []
for name, g in [("kron", kronecker(9, 8, seed=1)),
                ("road", road_grid(20, seed=2))]:
    sg = shard_graph(g, 8)
    bl = shard_blocked(sg, block_v=128, tile_e=128)
    src = int(np.argmax(g.deg))
    dref, _ = dijkstra_host(g, src)
    d1, p1, m1 = sssp(g.to_device(), src)
    d1, p1 = np.asarray(d1), np.asarray(p1)
    for ver, fused, be in [("v1", 0, "segment_min"), ("v2", 0, "segment_min"),
                           ("v2", 8, "segment_min"), ("v3", 0, "segment_min"),
                           ("v1", 0, "blocked"), ("v2", 0, "blocked"),
                           ("v2", 4, "blocked"), ("v3", 0, "blocked"),
                           ("v3", 4, "blocked")]:
        kw = {"blocked": bl} if be == "blocked" else {}
        dist, parent, metrics = sssp_distributed(sg, src, mesh, ("graph",),
                                                 version=ver,
                                                 fused_rounds=fused,
                                                 backend=be, **kw)
        dist = np.asarray(dist)[:g.n]
        parent = np.asarray(parent)[:g.n]
        ok = np.allclose(np.where(np.isfinite(dist), dist, -1),
                         np.where(np.isfinite(dref), dref, -1),
                         rtol=1e-4, atol=1e-5)
        # only segment_min's bucket-fusion waves break parity; the blocked
        # backend's grouped rounds are exact replays of the unfused body
        exempt = bool(fused) and be == "segment_min"
        same = True if exempt else (np.array_equal(dist, d1) and
                                    np.array_equal(parent, p1))
        mdiff = [] if exempt else [
            f for f in LOGICAL_METRIC_FIELDS
            if int(getattr(m1, f)) != int(getattr(metrics, f))]
        tiles_ok = be == "segment_min" or \
            0 < int(metrics.n_tiles_scanned) < int(metrics.n_tiles_dense)
        print(f"{name}/{ver}/fused={fused}/{be}: ok={ok} parity={same} "
              f"metric_diffs={mdiff} tiles_ok={tiles_ok} "
              f"exchanges={int(metrics.n_rounds)}")
        if not ok or not same or mdiff or not tiles_ok:
            failures.append((name, ver, fused, be, mdiff))
assert not failures, failures
print("DISTRIBUTED_OK")

# goal-aware early exit + the batch entry point (the sharded serving
# tier's interface) keep bitwise parity with the single-device engine
from repro.core.distributed import sssp_distributed_batch
from repro.core.sssp import sssp_batch, sssp

g = road_grid(20, seed=2)
sg = shard_graph(g, 8)
dg = g.to_device()
rng = np.random.default_rng(0)
nz = np.where(g.deg > 0)[0]
srcs = rng.choice(nz, 3, replace=False).astype(np.int32)
tgts = rng.choice(nz, 3, replace=False).astype(np.int32)
d_b, p_b, m_b = sssp_distributed_batch(sg, srcs, mesh, ("graph",),
                                       version="v2", goal="p2p",
                                       goal_params=tgts)
d_r, p_r, m_r = sssp_batch(dg, srcs, goal="p2p", goal_params=tgts)
for i, t in enumerate(tgts):
    assert np.asarray(d_b)[i, int(t)].tobytes() \
        == np.asarray(d_r)[i, int(t)].tobytes(), i
assert np.array_equal(np.asarray(m_b.n_rounds), np.asarray(m_r.n_rounds))
s, t = int(srcs[0]), int(tgts[0])
ds, _, ms = sssp(dg, s, goal="p2p", goal_param=t)
for ver in ["v1", "v2", "v3"]:
    d, p, m = sssp_distributed(sg, s, mesh, ("graph",), version=ver,
                               goal="p2p", goal_param=t)
    assert np.asarray(d)[t].tobytes() == np.asarray(ds)[t].tobytes(), ver
    assert int(m.n_rounds) == int(ms.n_rounds), (ver, int(m.n_rounds))
print("GOALS_OK")
"""


@pytest.mark.slow
def test_distributed_matches_oracle():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "DISTRIBUTED_OK" in proc.stdout and "GOALS_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"


def test_distributed_goal_batch_single_shard():
    """Fast in-process coverage (1-shard mesh) of the goal-aware batch
    entry point: parity with the single-device batched engine."""
    import numpy as np
    import jax

    from repro.core.distributed import (shard_graph, sssp_distributed,
                                        sssp_distributed_batch)
    from repro.core.sssp import sssp, sssp_batch
    from repro.data.generators import road_grid

    g = road_grid(12, seed=2)
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    srcs = np.array([0, 5], np.int32)
    tgts = np.array([100, 30], np.int32)
    dist, parent, metrics = sssp_distributed_batch(
        sg, srcs, mesh, ("graph",), goal="p2p", goal_params=tgts)
    d_ref, p_ref, m_ref = sssp_batch(g.to_device(), srcs, goal="p2p",
                                     goal_params=tgts)
    n = g.n
    np.testing.assert_array_equal(np.asarray(dist)[:, :n],
                                  np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(parent)[:, :n],
                                  np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(metrics.n_rounds),
                                  np.asarray(m_ref.n_rounds))
    # bounded goal on the single-source entry point
    d_b, _, _ = sssp_distributed(sg, 0, mesh, ("graph",), goal="bounded",
                                 goal_param=2.5)
    d_bref, _, _ = sssp(g.to_device(), 0, goal="bounded",
                        goal_param=2.5)
    np.testing.assert_array_equal(np.asarray(d_b)[:n], np.asarray(d_bref))
    # o-o-b p2p targets are rejected against the real vertex count (a jit
    # gather would clamp silently; padding vertices never settle)
    with pytest.raises(ValueError):
        sssp_distributed(sg, 0, mesh, ("graph",), goal="p2p",
                         goal_param=n + 1)


def test_distributed_blocked_goal_batch_single_shard():
    """Fast in-process coverage of the blocked backend on the batch +
    goal entry point (the sharded serving tier's interface)."""
    from repro.core.distributed import (shard_blocked, shard_graph,
                                        sssp_distributed_batch)
    from repro.core.sssp import sssp_batch
    from repro.data.generators import road_grid

    g = road_grid(12, seed=2)
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    bl = shard_blocked(sg, block_v=64, tile_e=64)
    srcs = np.array([0, 5], np.int32)
    tgts = np.array([100, 30], np.int32)
    dist, parent, metrics = sssp_distributed_batch(
        sg, srcs, mesh, ("graph",), goal="p2p", goal_params=tgts,
        backend="blocked", blocked=bl)
    d_ref, p_ref, m_ref = sssp_batch(g.to_device(), srcs, goal="p2p",
                                     goal_params=tgts)
    n = g.n
    np.testing.assert_array_equal(np.asarray(dist)[:, :n],
                                  np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(parent)[:, :n],
                                  np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(metrics.n_rounds),
                                  np.asarray(m_ref.n_rounds))
    assert (np.asarray(metrics.n_tiles_scanned) > 0).all()


@pytest.mark.multidevice
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device mesh (set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_blocked_backend_parity_on_all_benchmark_graphs():
    """The acceptance sweep: distributed v2 with backend="blocked" — both
    unfused and with ``fused_rounds=4`` grouped rounds — on the whole
    nine-graph benchmark suite (scaled down), bitwise dist/parent/
    logical-metric parity against the single-device engine, with the
    frontier-compacted schedule visibly undercutting the dense scan."""
    from repro.core.distributed import (shard_blocked, shard_graph,
                                        sssp_distributed)
    from repro.core.sssp import LOGICAL_METRIC_FIELDS, sssp
    from repro.data.generators import kronecker, road_grid, uniform_random

    scale = 9
    n = 1 << scale
    side = int(np.sqrt(n))
    graphs = {
        f"gr{scale}_4": kronecker(scale, 4, seed=1),
        f"gr{scale}_8": kronecker(scale, 8, seed=2),
        f"gr{scale}_16": kronecker(scale, 16, seed=3),
        f"gr{scale}_32": kronecker(scale, 32, seed=4),
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 16 * n, seed=6),
        "Web": kronecker(scale, 30, seed=7),
        "Twitter": kronecker(scale, 22, seed=8),
        "Kron": kronecker(scale, 32, seed=9),
    }
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("graph",))
    for name, g in graphs.items():
        sg = shard_graph(g, n_dev)
        bl = shard_blocked(sg, block_v=64, tile_e=64)
        src = int(np.argmax(g.deg))
        d1, p1, m1 = sssp(g.to_device(), src)
        for fused in (0, 4):
            dist, parent, metrics = sssp_distributed(
                sg, src, mesh, ("graph",), version="v2", backend="blocked",
                fused_rounds=fused, blocked=bl)
            tag = f"{name}/fused={fused}"
            np.testing.assert_array_equal(np.asarray(dist)[:g.n],
                                          np.asarray(d1), err_msg=tag)
            np.testing.assert_array_equal(np.asarray(parent)[:g.n],
                                          np.asarray(p1), err_msg=tag)
            for f in LOGICAL_METRIC_FIELDS:
                assert int(getattr(metrics, f)) == int(getattr(m1, f)), \
                    (tag, f)
            assert 0 < int(metrics.n_tiles_scanned) \
                < int(metrics.n_tiles_dense), tag

    # the sharded serving tier over the same backend: representative
    # graphs through ShardedGraphEngine.run_batch (the tier's interface)
    from repro.serve.registry import ShardedGraphEngine
    for name in [f"gr{scale}_8", "Road", "Urand"]:
        g = graphs[name]
        eng = ShardedGraphEngine(name, g, 3.0, 0.9, backend="blocked",
                                 block_v=64, tile_e=64)
        srcs = [int(np.argmax(g.deg)), 1]
        dist, parent, _ = eng.run_batch(srcs)
        for slot, s in enumerate(srcs):
            d1, p1, _ = sssp(g.to_device(), s)
            np.testing.assert_array_equal(np.asarray(dist[slot]),
                                          np.asarray(d1), err_msg=name)
            np.testing.assert_array_equal(np.asarray(parent[slot]),
                                          np.asarray(p1), err_msg=name)
