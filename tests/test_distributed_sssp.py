"""Distributed SSSP (shard_map) vs oracle and vs the single-device engine —
runs in a subprocess with 8 forced host devices (the main test process
keeps 1 device).  With 8 real shards, v1/v2/v3 must still be bitwise
identical to the single-device engine — dist, parent and every metric
counter — because all engines dispatch relaxation through the shared
primitives in core/relax.py (fused bucket waves are exempt from metric
parity: they intentionally relax local edges extra times)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.data.generators import kronecker, road_grid
from repro.core.distributed import shard_graph, sssp_distributed
from repro.core.sssp import sssp
from repro.core.baselines import dijkstra_host

mesh = jax.make_mesh((8,), ("graph",))
failures = []
for name, g in [("kron", kronecker(9, 8, seed=1)),
                ("road", road_grid(20, seed=2))]:
    sg = shard_graph(g, 8)
    src = int(np.argmax(g.deg))
    dref, _ = dijkstra_host(g, src)
    d1, p1, m1 = sssp(g.to_device(), src)
    d1, p1 = np.asarray(d1), np.asarray(p1)
    for ver, fused in [("v1", 0), ("v2", 0), ("v2", 8), ("v3", 0)]:
        dist, parent, metrics = sssp_distributed(sg, src, mesh, ("graph",),
                                                 version=ver,
                                                 fused_rounds=fused)
        dist = np.asarray(dist)[:g.n]
        parent = np.asarray(parent)[:g.n]
        ok = np.allclose(np.where(np.isfinite(dist), dist, -1),
                         np.where(np.isfinite(dref), dref, -1),
                         rtol=1e-4, atol=1e-5)
        same = True if fused else (np.array_equal(dist, d1) and
                                   np.array_equal(parent, p1))
        mdiff = [] if fused else [
            f for f in m1._fields
            if int(getattr(m1, f)) != int(getattr(metrics, f))]
        print(f"{name}/{ver}/fused={fused}: ok={ok} parity={same} "
              f"metric_diffs={mdiff} exchanges={int(metrics.n_rounds)}")
        if not ok or not same or mdiff:
            failures.append((name, ver, fused, mdiff))
assert not failures, failures
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_matches_oracle():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "DISTRIBUTED_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
