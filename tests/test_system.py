"""End-to-end behaviour tests for the paper's system.

One flow through the whole stack: generate a Graph500 graph, preprocess
(weight-sorted CSR + RtoW LUT), run the heuristic SSSP algorithm, check
exactness + the paper's metric bands, then run the distributed engine on a
trivial 1-device mesh and require bit-identical distances.
"""
import numpy as np
import jax
import pytest

from repro.core.baselines import dijkstra_host
from repro.core.distributed import shard_graph, sssp_distributed
from repro.core.sssp import sssp, normalized_metrics
from repro.data.generators import kronecker


def test_end_to_end_paper_pipeline():
    g = kronecker(11, 8, seed=42)
    dg = g.to_device()
    rng = np.random.default_rng(7)
    src = int(rng.choice(np.where(g.deg > 0)[0]))

    # the paper's algorithm, jitted
    dist, parent, metrics = sssp(dg, src)
    dist = np.asarray(dist)

    # exactness vs host Dijkstra
    dref, _ = dijkstra_host(g, src)
    np.testing.assert_allclose(
        np.where(np.isfinite(dist), dist, -1),
        np.where(np.isfinite(dref), dref, -1), rtol=1e-4, atol=1e-5)

    # paper metric sanity (low-diameter Kronecker graph)
    nm = normalized_metrics(g.deg, dist, jax.tree.map(np.asarray, metrics))
    assert nm["nFrontier"] < 1.5
    assert nm["nTrav"] < g.m / 2 / g.n  # fewer traversals than Dijkstra

    # distributed engine (1-device mesh degenerate case) agrees bitwise
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    ddist, _, _ = sssp_distributed(sg, src, mesh, ("graph",), version="v2")
    np.testing.assert_array_equal(np.asarray(ddist)[:g.n], dist)
