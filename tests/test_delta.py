"""Streaming graph updates (repro.delta): patch + repair, bitwise.

Two contracts, both *bitwise*:

* **Patch parity** — ``patch_host`` must produce the exact
  :class:`~repro.core.graph.HostGraph` that ``build_csr`` would build
  from the edited directed edge list (same CSR order, same f32 weight
  bytes, same quantile LUT), and ``patch_blocked`` /``patch_sharded``
  must reproduce a from-scratch re-bucket / re-shard of the patched
  graph, byte for byte, across the 9-graph benchmark suite.
* **Repair parity** — ``repair`` from a previous solve's (dist, parent)
  must converge to dist/parent bitwise-identical to a from-scratch solve
  on the patched graph, on every backend (segment_min / blocked / fused
  megakernel), on the decrease-only fast path, and (in a subprocess with
  8 forced host devices) through ``repair_distributed`` v1/v2/v3.

Serving lifecycle: ``GraphRegistry.apply_delta`` patches cached engines
in place (no generation bump — a router's replicas are reused, its
rebuild counter stays flat), repairs the bounded result cache bitwise,
keeps ALT landmark sets as *stale* (forward-only bounds) within the
staleness budget and drops them beyond it, and the TunedStore's
``allow_stale`` keeps budgeted overlays applying.  Random edit batches
are property-tested (hypothesis when installed, a seeded sweep always).
"""
import dataclasses
import os
import subprocess
import sys
import zlib

import numpy as np
import pytest

from repro.api import EngineConfig, SolveSpec, Solver
from repro.core import landmarks as landmarks_mod
from repro.core.distributed import shard_graph
from repro.core.graph import build_csr
from repro.core.sssp import prepare_layout, sssp
from repro.data.generators import kronecker, road_grid, uniform_random
from repro.delta import (EdgeDelta, patch_blocked, patch_host,
                         patch_sharded, repair, repair_state)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SCALE = 8   # 256 vertices: the full 9-graph structure at test size


def benchmark_graphs():
    n = 1 << SCALE
    side = int(np.sqrt(n))
    return {
        "gr_4": kronecker(SCALE, 4, seed=1),
        "gr_8": kronecker(SCALE, 8, seed=2),
        "gr_16": kronecker(SCALE, 16, seed=3),
        "gr_32": kronecker(SCALE, 32, seed=4),
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 16 * n, seed=6),
        "Web": kronecker(SCALE, 30, seed=7),
        "Twitter": kronecker(SCALE, 22, seed=8),
        "Kron": kronecker(SCALE, 32, seed=9),
    }


def unique_undirected(hg):
    """Indices of one representative (u < v) slot per undirected edge,
    deduplicated on (u, v) — parallel duplicates share a directed target
    and may not be removed/reweighted independently."""
    und = np.nonzero(hg.src < hg.dst)[0]
    key = hg.src[und].astype(np.int64) * int(hg.n) + hg.dst[und]
    _, first = np.unique(key, return_index=True)
    return und[np.sort(first)]


def make_delta(hg, rng, n_edits=8, add=True):
    """n_edits removals + n_edits reweights (+ n_edits additions)."""
    und = unique_undirected(hg)
    pick = rng.choice(und, size=min(2 * n_edits, und.size), replace=False)
    rem = pick[:n_edits]
    rw = pick[n_edits:]
    removes = [(int(hg.src[e]), int(hg.dst[e])) for e in rem]
    rews = [(int(hg.src[e]), int(hg.dst[e]),
             float(np.float32(rng.uniform(0.05, 2.0)))) for e in rw]
    adds = []
    while add and len(adds) < n_edits:
        u, v = int(rng.integers(hg.n)), int(rng.integers(hg.n))
        if u != v:
            adds.append((u, v, float(np.float32(rng.uniform(0.05, 2.0)))))
    return EdgeDelta(add=adds, remove=removes, reweight=rews)


def ref_presort(hg, delta):
    """Independent reconstruction of the edited directed edge list (the
    patch-parity oracle feeds it to build_csr un-symmetrized)."""
    s = hg.src.astype(np.int64)
    d = hg.dst.astype(np.int64)
    w = hg.w.astype(np.float32).copy()
    rp = hg.row_ptr.astype(np.int64)
    au, av, aw = delta.add
    ru, rv = delta.remove
    wu, wv, ww = delta.reweight
    au, av, aw = (np.concatenate([au, av]), np.concatenate([av, au]),
                  np.concatenate([aw, aw]))
    ru, rv = np.concatenate([ru, rv]), np.concatenate([rv, ru])
    wu, wv, ww = (np.concatenate([wu, wv]), np.concatenate([wv, wu]),
                  np.concatenate([ww, ww]))

    def slot(u, v):
        lo, hi = int(rp[u]), int(rp[u + 1])
        return lo + int(np.nonzero(d[lo:hi] == v)[0][0])

    for u, v, nw in zip(wu, wv, ww):
        w[slot(int(u), int(v))] = nw
    keep = np.ones(s.size, bool)
    for u, v in zip(ru, rv):
        keep[slot(int(u), int(v))] = False
    return (np.concatenate([s[keep], au]), np.concatenate([d[keep], av]),
            np.concatenate([w[keep], aw]).astype(np.float32))


def assert_host_bitwise(a, b, label):
    bad = [f for f, eq in [
        ("src", np.array_equal(a.src, b.src)),
        ("dst", np.array_equal(a.dst, b.dst)),
        ("w", np.asarray(a.w, np.float32).tobytes()
         == np.asarray(b.w, np.float32).tobytes()),
        ("row_ptr", np.array_equal(a.row_ptr, b.row_ptr)),
        ("deg", np.array_equal(a.deg, b.deg)),
        ("rtow", np.asarray(a.rtow).tobytes()
         == np.asarray(b.rtow).tobytes()),
        ("max_w", a.max_w == b.max_w)] if not eq]
    assert not bad, (label, bad)


def assert_blocked_bitwise(a, b, label):
    bad = []
    for f in ("n", "block_v", "n_blocks", "n_dst_blocks", "src_base",
              "tile_e", "dense_grid_tiles"):
        if getattr(a, f) != getattr(b, f):
            bad.append(f"{f}: {getattr(a, f)} != {getattr(b, f)}")
    if not np.array_equal(np.asarray(a.deg), np.asarray(b.deg)):
        bad.append("deg")
    for i, (sa, sb) in enumerate(zip(a.slabs, b.slabs)):
        for f in ("src_local", "dst", "w", "tile_dst", "tile_first",
                  "bucket_nonempty"):
            xa = np.asarray(getattr(sa, f))
            xb = np.asarray(getattr(sb, f))
            if xa.shape != xb.shape or xa.tobytes() != xb.tobytes():
                bad.append(f"slab{i}.{f}")
    assert not bad, (label, bad)


def assert_solve_bitwise(d_a, p_a, d_b, p_b, label):
    assert np.asarray(d_a).tobytes() == np.asarray(d_b).tobytes(), \
        f"{label}: dist differs"
    assert np.asarray(p_a).tobytes() == np.asarray(p_b).tobytes(), \
        f"{label}: parent differs"


# ---------------------------------------------------------------------------
# patch parity: host CSR, blocked layout, sharded tables — 9 graphs
# ---------------------------------------------------------------------------

def test_patch_bitwise_all_graphs():
    for name, hg in benchmark_graphs().items():
        rng = np.random.default_rng(zlib.crc32(name.encode()) % 1000)
        delta = make_delta(hg, rng)
        new_host, applied = patch_host(hg, delta)
        s2, d2, w2 = ref_presort(hg, delta)
        ref = build_csr(hg.n, s2, d2, w2.astype(np.float64),
                        symmetrize=False)
        assert_host_bitwise(new_host, ref, f"{name}/host")
        assert applied.n_edits == 2 * delta.n_edits

        lay_old = prepare_layout(hg.to_device(), "blocked")
        lay_new, nh2, _ = patch_blocked(lay_old, delta, host=hg)
        assert_host_bitwise(nh2, ref, f"{name}/host-via-blocked")
        lay_ref = prepare_layout(new_host.to_device(), "blocked")
        assert_blocked_bitwise(lay_new, lay_ref, f"{name}/blocked")

        sg_new, _, _ = patch_sharded(shard_graph(hg, 8), delta, host=hg)
        sg_ref = shard_graph(new_host, 8)
        for f in ("src", "dst", "w", "deg", "rtow"):
            xa = np.asarray(getattr(sg_new, f))
            xb = np.asarray(getattr(sg_ref, f))
            if xa.shape == xb.shape:
                assert xa.tobytes() == xb.tobytes(), \
                    (name, "sharded", f)
            else:
                # e_max grew on one side only: the finite (real) slots
                # must still match per shard, in CSR order
                assert f in ("src", "dst", "w"), (name, "sharded-shape", f)
                fa = np.isfinite(np.asarray(sg_new.w))
                fb = np.isfinite(np.asarray(sg_ref.w))
                for q in range(xa.shape[0]):
                    assert np.array_equal(xa[q][fa[q]], xb[q][fb[q]]), \
                        (name, "sharded-finite", f, q)


def test_patch_host_rejects_bad_edits():
    hg = kronecker(SCALE, 8, seed=2)
    e = unique_undirected(hg)[0]
    u, v = int(hg.src[e]), int(hg.dst[e])
    with pytest.raises(ValueError):
        patch_host(hg, EdgeDelta(remove=[(u, v), (u, v)]))  # dup target
    with pytest.raises(ValueError):
        patch_host(hg, EdgeDelta(remove=[(hg.n + 7, 0)]))   # out of range
    with pytest.raises(ValueError):
        EdgeDelta(add=[(0, 1, -1.0)])                       # w <= 0
    with pytest.raises(ValueError):
        EdgeDelta(add=[(0, 1, float("inf"))])
    assert not EdgeDelta()
    assert EdgeDelta(remove=[(u, v)]).n_edits == 1


# ---------------------------------------------------------------------------
# repair parity: every single-device backend, 9 graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,fused_rounds", [
    ("segment_min", 0),
    ("blocked", 0),
    ("blocked", 4),          # repair through the fused megakernel
])
def test_repair_bitwise_parity_all_graphs(backend, fused_rounds):
    n_nontrivial = 0
    for name, hg in benchmark_graphs().items():
        rng = np.random.default_rng(zlib.crc32(name.encode()) % 1000 + 3)
        delta = make_delta(hg, rng)
        new_host, applied = patch_host(hg, delta)
        src_v = int(np.argmax(hg.deg))
        d0, p0, _ = sssp(hg.to_device(), src_v)
        g_new = new_host.to_device()
        d_full, p_full, m_full = sssp(g_new, src_v)
        layout = (prepare_layout(g_new, "blocked") if backend == "blocked"
                  else g_new)
        d_r, p_r, m_r, st = repair(layout, new_host, d0, p0, applied,
                                   backend="segment_min"
                                   if backend == "segment_min" else
                                   "blocked", fused_rounds=fused_rounds)
        assert_solve_bitwise(d_r, p_r, d_full, p_full,
                             f"{name}/{backend}/fused{fused_rounds}")
        n_nontrivial += int(st.n_seeds > 0)
    # the sweep must actually exercise reseeded repairs, not no-ops
    assert n_nontrivial >= 7


def test_repair_decrease_only_fast_path():
    """Decrease-only deltas skip invalidation entirely (the old state is
    still a valid upper bound) and still land on the exact fixpoint."""
    for hg in (kronecker(9, 8, seed=2), road_grid(24, seed=5)):
        src_v = int(np.argmax(hg.deg))
        d0, p0, _ = sssp(hg.to_device(), src_v)
        und = unique_undirected(hg)[:6]
        delta = EdgeDelta(reweight=[
            (int(hg.src[e]), int(hg.dst[e]),
             float(np.float32(hg.w[e]) * 0.5)) for e in und])
        new_host, applied = patch_host(hg, delta)
        assert applied.decrease_only and applied.safe_stale is False
        g_new = new_host.to_device()
        d_f, p_f, _ = sssp(g_new, src_v)
        d_r, p_r, m_r, st = repair(g_new, new_host, d0, p0, applied)
        assert st.fast_path and st.n_invalid == 0
        assert_solve_bitwise(d_r, p_r, d_f, p_f, "fast-path")


def test_repair_non_tree_edit_is_noop_shaped():
    """Removing a non-tree edge can only leave distances unchanged; the
    repair must notice (no invalidation) and still verify bitwise."""
    hg = road_grid(16, seed=5)
    src_v = int(np.argmax(hg.deg))
    d0, p0, _ = sssp(hg.to_device(), src_v)
    p0_np = np.asarray(p0)
    # find an undirected edge neither direction of which is a tree edge
    for e in unique_undirected(hg):
        u, v = int(hg.src[e]), int(hg.dst[e])
        if p0_np[v] != u and p0_np[u] != v:
            break
    else:                                        # pragma: no cover
        pytest.skip("no non-tree edge")
    new_host, applied = patch_host(hg, EdgeDelta(remove=[(u, v)]))
    d_i, p_i, frontier, stats = repair_state(new_host, np.asarray(d0),
                                             p0_np, applied)
    assert stats.n_invalid == 0
    d_f, p_f, _ = sssp(new_host.to_device(), src_v)
    d_r, p_r, _, _ = repair(new_host.to_device(), new_host,
                            d0, p0, applied)
    assert_solve_bitwise(d_r, p_r, d_f, p_f, "non-tree-remove")
    assert_solve_bitwise(d_r, p_r, d0, p0, "non-tree-remove-unchanged")


# ---------------------------------------------------------------------------
# property sweep: random edit batches (hypothesis when installed)
# ---------------------------------------------------------------------------

def _roundtrip(hg, delta, src_v):
    new_host, applied = patch_host(hg, delta)
    s2, d2, w2 = ref_presort(hg, delta)
    ref = build_csr(hg.n, s2, d2, w2.astype(np.float64), symmetrize=False)
    assert_host_bitwise(new_host, ref, "sweep/host")
    d0, p0, _ = sssp(hg.to_device(), src_v)
    g_new = new_host.to_device()
    d_f, p_f, _ = sssp(g_new, src_v)
    d_r, p_r, _, _ = repair(g_new, new_host, d0, p0, applied)
    assert_solve_bitwise(d_r, p_r, d_f, p_f, "sweep/repair")


def test_delta_seeded_sweep():
    """Always-on random-batch sweep (hypothesis-free)."""
    hg = kronecker(SCALE, 8, seed=2)
    src_v = int(np.argmax(hg.deg))
    for i in range(6):
        rng = np.random.default_rng(100 + i)
        _roundtrip(hg, make_delta(hg, rng, n_edits=int(rng.integers(1, 14)),
                                  add=bool(i % 2)), src_v)


if HAVE_HYPOTHESIS:
    _HG = kronecker(SCALE, 8, seed=2)
    _SRC = int(np.argmax(_HG.deg))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 16 - 1), n_edits=st.integers(1, 16),
           add=st.booleans())
    def test_delta_hypothesis_sweep(seed, n_edits, add):
        # fixed graph so every example reuses the same compiled solves
        rng = np.random.default_rng(seed)
        _roundtrip(_HG, make_delta(_HG, rng, n_edits=n_edits, add=add),
                   _SRC)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_delta_hypothesis_sweep():
        pass


# ---------------------------------------------------------------------------
# serving: apply_delta patches engines, repairs caches, keeps replicas
# ---------------------------------------------------------------------------

def _budget_delta(hg, frac):
    """An increase/remove-only (safe_stale) batch of ~frac * m edits."""
    und = unique_undirected(hg)
    n_edits = max(int(frac * hg.m / 2), 1)
    pick = und[:n_edits]
    return EdgeDelta(reweight=[(int(hg.src[e]), int(hg.dst[e]),
                                float(np.float32(hg.w[e]) * 1.5))
                               for e in pick])


def test_registry_apply_delta_patches_and_repairs(tmp_path):
    from repro.serve.registry import GraphRegistry

    hg = kronecker(9, 8, seed=2)
    src_v = int(np.argmax(hg.deg))
    # remove + increase only: safe_stale, so landmarks survive as stale
    und = unique_undirected(hg)
    delta = EdgeDelta(
        remove=[(int(hg.src[e]), int(hg.dst[e])) for e in und[:4]],
        reweight=[(int(hg.src[e]), int(hg.dst[e]),
                   float(np.float32(hg.w[e]) * 1.4)) for e in und[4:8]])
    reg = GraphRegistry(config=EngineConfig(use_alt=True, n_landmarks=4),
                        landmark_dir=tmp_path)
    reg.register("g", hg)
    reg.engine("g", backend="segment_min")
    reg.engine("g", backend="blocked")
    lm = reg.landmark_set("g")
    assert not lm.stale
    d0, p0, _ = sssp(hg.to_device(), src_v)
    reg.cache_result("g", src_v, np.asarray(d0), np.asarray(p0))

    fired = []
    reg.add_invalidation_listener(lambda gid, gen: fired.append(gid))
    gen_before = reg.generation("g")
    report = reg.apply_delta("g", delta)
    assert not fired, "apply_delta must not fire invalidation listeners"
    assert reg.generation("g") == gen_before
    assert report["engines_patched"] == 2
    assert report["results_repaired"] == 1
    assert report["landmarks"] == "stale"

    new_host, _ = patch_host(hg, delta)
    d_f, p_f, _ = sssp(new_host.to_device(), src_v)
    for be in ("segment_min", "blocked"):
        eng = reg.engine("g", backend=be)
        dd, pp, _ = eng.run_batch([src_v])
        assert_solve_bitwise(np.asarray(dd)[0], np.asarray(pp)[0],
                             d_f, p_f, f"engine/{be}")
    dc, pc = reg.cached_result("g", src_v)
    assert_solve_bitwise(dc, pc, d_f, p_f, "result-cache")
    # the stale set serves forward-only (sym drops to 0) yet stays exact
    lm2 = reg.landmark_set("g")
    assert lm2.stale and float(np.asarray(lm2.alt_data.sym)) == 0.0
    assert reg._delta_counters["repaired"].value == 1
    assert reg._delta_counters["layout_patches"].value == 2


def test_registry_staleness_budget_drops_landmarks():
    from repro.serve.registry import GraphRegistry

    hg = kronecker(9, 8, seed=2)
    cfg = EngineConfig(use_alt=True, n_landmarks=4,
                       delta_staleness_budget=0.05)
    reg = GraphRegistry(config=cfg)
    reg.register("g", hg)
    reg.engine("g")
    reg.landmark_set("g")
    # within budget: kept (stale); cumulative overrun: dropped
    r1 = reg.apply_delta("g", _budget_delta(hg, 0.02))
    assert r1["landmarks"] == "stale"
    host2 = r1["host"]
    r2 = reg.apply_delta("g", _budget_delta(host2, 0.08))
    assert r2["landmarks"] == "dropped"
    assert r2["delta_frac"] > cfg.delta_staleness_budget
    # an unsafe (decrease) delta drops immediately, budget or not
    reg.register("h", hg)
    reg.engine("h")
    reg.landmark_set("h")
    e = unique_undirected(hg)[0]
    dec = EdgeDelta(reweight=[(int(hg.src[e]), int(hg.dst[e]),
                               float(np.float32(hg.w[e]) * 0.5))])
    assert reg.apply_delta("h", dec)["landmarks"] == "dropped"


def test_landmark_disk_cache_roundtrip(tmp_path):
    from repro.serve.registry import GraphRegistry

    hg = road_grid(16, seed=5)
    # save/load round-trip preserves the artifact bitwise
    lm = landmarks_mod.build_landmarks(hg.to_device(), n_landmarks=4,
                                       strategy="farthest")
    path = tmp_path / "lm.npz"
    landmarks_mod.save(lm, path)
    lm2 = landmarks_mod.load(path)
    assert np.array_equal(lm.landmarks, lm2.landmarks)
    assert np.asarray(lm.D).tobytes() == np.asarray(lm2.D).tobytes()
    assert (lm2.strategy, lm2.sym, lm2.max_hops) \
        == (lm.strategy, lm.sym, lm.max_hops)
    assert lm2.generation == -1 and not lm2.stale

    cfg = EngineConfig(use_alt=True, n_landmarks=4)
    reg1 = GraphRegistry(config=cfg, landmark_dir=tmp_path)
    reg1.register("g", hg)
    a = reg1.landmark_set("g")
    assert reg1._lm_disk["saves"].value == 1
    # cold start: same graph -> loaded from disk, not rebuilt
    reg2 = GraphRegistry(config=cfg, landmark_dir=tmp_path)
    reg2.register("g", hg)
    b = reg2.landmark_set("g")
    assert reg2._lm_disk["loads"].value == 1
    assert np.asarray(a.D).tobytes() == np.asarray(b.D).tobytes()
    # a delta moves the graph fingerprint -> the old file never matches
    new_host, _ = patch_host(hg, _budget_delta(hg, 0.02))
    reg3 = GraphRegistry(config=cfg, landmark_dir=tmp_path)
    reg3.register("g", new_host)
    reg3.landmark_set("g")
    assert reg3._lm_disk["loads"].value == 0


def test_tuned_store_allow_stale(tmp_path):
    from repro.tune.store import TunedStore

    hg = kronecker(SCALE, 8, seed=2)
    store = TunedStore(tmp_path / "tuned.json")
    cfg = EngineConfig(alpha=2.5, beta=0.8)
    store.put("g", hg, cfg, objective=1.0)
    new_host, _ = patch_host(hg, _budget_delta(hg, 0.02))
    # the patched graph's fingerprint moved: strict lookup refuses,
    # budgeted lookup keeps serving the slightly-mistuned winner
    assert store.get("g", new_host, cfg) is None
    got = store.get("g", new_host, cfg, allow_stale=True)
    assert got is not None and got.alpha == 2.5
    assert store.apply("g", new_host, EngineConfig()).alpha \
        == EngineConfig().alpha
    assert store.apply("g", new_host, EngineConfig(),
                       allow_stale=True).alpha == 2.5


def test_router_reuses_patched_replicas():
    """The satellite fix: apply_delta must NOT rebuild per-replica
    engines — one patch serves every placement, n_rebuilds stays 0."""
    import jax

    from repro.serve.queries import Query
    from repro.serve.registry import GraphRegistry
    from repro.serve.router import QueryRouter

    hg = kronecker(SCALE, 8, seed=2)
    src_v = int(np.argmax(hg.deg))
    reg = GraphRegistry(capacity=8, config=EngineConfig())
    reg.register("g", hg)
    # duplicated device = 2 replicas on single-device hosts
    router = QueryRouter(reg, devices=[jax.devices()[0]] * 2,
                         replicate_min_depth=1, replicate_factor=1.0)
    router.warmup(["g"])
    rng = np.random.default_rng(2)
    delta = make_delta(hg, rng, n_edits=3, add=False)
    report = reg.apply_delta("g", delta)
    assert report["engines_patched"] >= 1
    assert router.n_rebuilds == 0
    fut = router.submit(Query(gid="g", source=src_v, kind="tree"))
    router.drain()
    res = fut.result(timeout=120)
    new_host, _ = patch_host(hg, delta)
    d_f, p_f, _ = sssp(new_host.to_device(), src_v)
    assert_solve_bitwise(res.dist, res.parent, d_f, p_f, "routed")
    assert router.n_rebuilds == 0


def test_service_apply_delta():
    from repro.serve.sssp_service import SsspRequest, SsspService

    hg = kronecker(SCALE, 8, seed=2)
    src_v = int(np.argmax(hg.deg))
    svc = SsspService(hg)
    rng = np.random.default_rng(3)
    delta = make_delta(hg, rng, n_edits=3)
    report = svc.apply_delta(delta)
    assert report["engines_patched"] == 1
    req = svc.submit(SsspRequest(rid=0, source=src_v))
    svc.run()
    new_host, _ = patch_host(hg, delta)
    d_f, p_f, _ = sssp(new_host.to_device(), src_v)
    assert_solve_bitwise(req.dist, req.parent, d_f, p_f, "service")


def test_solver_submit_async_and_delta():
    hg = kronecker(SCALE, 8, seed=2)
    src_v = int(np.argmax(hg.deg))
    with Solver.open(hg, EngineConfig(tier="routed")) as s:
        res = s.submit(SolveSpec.tree(src_v)).result(timeout=120)
        d_ref, p_ref, _ = sssp(hg.to_device(), src_v)
        assert_solve_bitwise(res.dist, res.parent, d_ref, p_ref, "submit")
        # batched spec: slots may serve from different fused batches
        rb = s.submit(SolveSpec.tree([src_v, (src_v + 1) % hg.n]))
        assert rb.result(timeout=120).dist.shape[0] == 2
        rng = np.random.default_rng(4)
        delta = make_delta(hg, rng, n_edits=3, add=False)
        s.apply_delta(delta)
        new_host, _ = patch_host(hg, delta)
        d_f, p_f, _ = sssp(new_host.to_device(), src_v)
        res2 = s.submit(SolveSpec.tree(src_v)).result(timeout=120)
        assert_solve_bitwise(res2.dist, res2.parent, d_f, p_f,
                             "post-delta-submit")
        assert s.router.n_rebuilds == 0
    # non-routed tiers refuse loudly (immutable prebuilt layouts)
    single = Solver.open(hg)
    with pytest.raises(Exception):
        single.submit(SolveSpec.tree(src_v))
    with pytest.raises(Exception):
        single.apply_delta(EdgeDelta())


def test_delta_staleness_budget_validation():
    from repro.core.config import ConfigError

    assert EngineConfig().delta_staleness_budget == 0.05
    EngineConfig(delta_staleness_budget=0.0)
    EngineConfig(delta_staleness_budget=1.0)
    with pytest.raises(ConfigError):
        EngineConfig(delta_staleness_budget=1.5)
    with pytest.raises(ConfigError):
        EngineConfig(delta_staleness_budget=-0.1)


# ---------------------------------------------------------------------------
# sharded tier: 8 real shards in a subprocess — patch + repair parity
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.core.distributed import (repair_distributed, shard_graph,
                                    sssp_distributed)
from repro.core.sssp import sssp
from repro.data.generators import kronecker, road_grid
from repro.delta import EdgeDelta, patch_host, patch_sharded_with, \
    repair_state

mesh = jax.make_mesh((8,), ("graph",))
for name, hg in [("kron", kronecker(9, 8, seed=1)),
                 ("road", road_grid(20, seed=2))]:
    src_v = int(np.argmax(hg.deg))
    und = np.nonzero(hg.src < hg.dst)[0]
    key = hg.src[und].astype(np.int64) * hg.n + hg.dst[und]
    _, fi = np.unique(key, return_index=True)
    und = und[np.sort(fi)]
    delta = EdgeDelta(
        remove=[(int(hg.src[e]), int(hg.dst[e])) for e in und[:4]],
        reweight=[(int(hg.src[e]), int(hg.dst[e]),
                   float(np.float32(hg.w[e]) * 1.4)) for e in und[4:8]])
    d0, p0, _ = sssp(hg.to_device(), src_v)
    new_host, applied = patch_host(hg, delta)
    # sharded patch parity: patched tables == resharded patched host
    sg_new = patch_sharded_with(shard_graph(hg, 8), new_host, applied)
    sg_ref = shard_graph(new_host, 8)
    for f in ("deg", "rtow"):
        assert np.asarray(getattr(sg_new, f)).tobytes() \
            == np.asarray(getattr(sg_ref, f)).tobytes(), (name, f)
    # distributed from-scratch reference on the patched tables
    d_f, p_f, m_f = sssp_distributed(sg_new, src_v, mesh, ("graph",),
                                     version="v2")
    d1, p1, _ = sssp(new_host.to_device(), src_v)
    n = hg.n
    assert np.asarray(d_f)[:n].tobytes() == np.asarray(d1).tobytes(), name
    assert np.asarray(p_f)[:n].tobytes() == np.asarray(p1).tobytes(), name
    # repair from the pre-delta solve, every engine version
    d_i, p_i, frontier, st = repair_state(new_host, np.asarray(d0),
                                          np.asarray(p0), applied)
    for ver in ("v1", "v2", "v3"):
        d_r, p_r, m_r = repair_distributed(sg_new, d_i, p_i, frontier,
                                           mesh, ("graph",), version=ver)
        assert np.asarray(d_r)[:n].tobytes() \
            == np.asarray(d1).tobytes(), (name, ver, "dist")
        assert np.asarray(p_r)[:n].tobytes() \
            == np.asarray(p1).tobytes(), (name, ver, "parent")
        # the repair must do measurably less relaxation work than the
        # from-scratch distributed solve on non-trivial deltas
        assert int(m_r.n_relax) <= int(m_f.n_relax), (name, ver)
print("DELTA_SHARDED_OK")
"""


@pytest.mark.slow
def test_delta_sharded_8shard_bitwise_parity():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert "DELTA_SHARDED_OK" in proc.stdout, \
        f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
