"""Per-round solve traces: parity, no-op, and export invariants.

The tentpole contract, tested per backend:

* **counter parity** — a traced solve's per-round counter deltas, summed
  over the trace and added to the engine's metric init (``n_extended``
  starts at 1 for the source pop), reproduce the final ``SsspMetrics``
  field bitwise;
* **bitwise no-op** — dist/parent/metrics of a traced solve are bitwise
  identical to the untraced solve (the ring only reads solver state);
* **ring overflow** — a small-capacity ring keeps the newest records and
  reports the drop, never corrupting retained records;
* **export invariants** — every ``metrics_dict`` field is present and
  finite for every backend x ``fused_rounds`` combination, and the
  Perfetto export is loadable JSON with one round span per record.

Distributed (v1/v2/v3 over 8 shards) parity lives in the multidevice
subprocess test at the bottom, mirroring test_distributed_sssp.py.
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import EngineConfig, SolveSpec, Solver
from repro.core.config import ConfigError
from repro.core.sssp import (LOGICAL_METRIC_FIELDS, PHYSICAL_METRIC_FIELDS,
                             metrics_dict, sssp)
from repro.data.generators import kronecker
from repro.obs import (SolveTrace, TRACE_COLUMNS, TRACE_COUNTER_COLUMNS,
                       materialize_trace, trace_to_perfetto)

# (config kwargs, label) — every single-device engine variant
BACKENDS = [
    ({"backend": "segment_min"}, "segment_min"),
    ({"backend": "blocked_pallas", "interpret": True}, "blocked"),
    ({"backend": "blocked_pallas", "interpret": True, "fused_rounds": 4},
     "blocked_fused4"),
]


@pytest.fixture(scope="module")
def graph():
    return kronecker(8, 4, seed=0)


def assert_counter_parity(trace, metrics):
    """initial + summed per-round deltas == final, bitwise per field."""
    assert trace.dropped == 0, "parity needs the full record set"
    sums = trace.counter_sums()
    for f in LOGICAL_METRIC_FIELDS:
        init = 1 if f == "n_extended" else 0
        assert init + sums[f] == int(getattr(metrics, f)), f
    for f in PHYSICAL_METRIC_FIELDS:
        assert sums[f] == float(getattr(metrics, f)), f


@pytest.mark.parametrize("kw,label", BACKENDS, ids=[b[1] for b in BACKENDS])
def test_traced_solve_parity_and_noop(graph, kw, label):
    src = int(np.argmax(graph.deg))
    with Solver.open(graph, EngineConfig(**kw)) as plain:
        ref = plain.solve(SolveSpec.tree(src))
    assert ref.trace is None        # tracing is strictly opt-in
    with Solver.open(graph, EngineConfig(trace=True, **kw)) as traced:
        res = traced.solve(SolveSpec.tree(src))
    # bitwise no-op on the solver outputs
    np.testing.assert_array_equal(np.asarray(res.dist), np.asarray(ref.dist))
    np.testing.assert_array_equal(np.asarray(res.parent),
                                  np.asarray(ref.parent))
    for f in ref.metrics._fields:
        assert np.asarray(res.metrics._asdict()[f]) \
            == np.asarray(ref.metrics._asdict()[f]), f
    # counter parity + record shape
    trace = res.trace
    assert isinstance(trace, SolveTrace)
    assert trace.n_records > 1
    assert_counter_parity(trace, res.metrics)
    recs = trace.records()
    assert len(recs) == trace.n_records
    assert set(recs[0]) == set(TRACE_COLUMNS)
    iters = trace.columns["iter"]
    assert (np.diff(iters) == 1).all() and iters[0] == 0
    # the source starts alone on the frontier; every record saw >= 1 live
    # vertex (the loop exits rather than recording an empty iteration)
    assert trace.columns["frontier"][0] == 1
    assert (trace.columns["frontier"] >= 1).all()
    assert trace.summary()["n_records"] == trace.n_records


def test_trace_ring_overflow(graph):
    src = int(np.argmax(graph.deg))
    with Solver.open(graph, EngineConfig(trace=True)) as solver:
        full = solver.solve(SolveSpec.tree(src)).trace
    cap = 4
    assert full.n_records > cap     # the test needs a real overflow
    with Solver.open(graph,
                     EngineConfig(trace=True, trace_capacity=cap)) as solver:
        small = solver.solve(SolveSpec.tree(src)).trace
    assert small.capacity == cap
    assert small.n_records == cap
    assert small.n_recorded == full.n_records
    assert small.dropped == full.n_records - cap
    # the ring keeps the *newest* records, in order
    np.testing.assert_array_equal(small.columns["iter"],
                                  full.columns["iter"][-cap:])
    for c in TRACE_COLUMNS:
        np.testing.assert_array_equal(small.columns[c],
                                      full.columns[c][-cap:])


def test_traced_batch_per_slot(graph):
    srcs = [int(i) for i in np.argsort(-graph.deg)[:3]]
    with Solver.open(graph, EngineConfig(trace=True)) as solver:
        res = solver.solve(SolveSpec.tree(srcs))
    assert isinstance(res.trace, list) and len(res.trace) == len(srcs)
    for slot in range(len(srcs)):
        m = type(res.metrics)(*(np.asarray(v)[slot]
                                for v in res.metrics))
        assert_counter_parity(res.trace[slot], m)


def test_trace_direct_engine_entry(graph):
    # the engine entry point returns the raw device ring for callers that
    # bypass the facade
    g = graph.to_device()
    src = int(np.argmax(graph.deg))
    out = sssp(g, src, config=EngineConfig(trace=True))
    assert len(out) == 4
    trace = materialize_trace(out[3])
    assert_counter_parity(trace, out[2])


def test_trace_config_validation():
    with pytest.raises(ConfigError):
        EngineConfig(trace_capacity=0)
    # the routed serving tier reports aggregate metrics, not solve traces
    with pytest.raises(ConfigError):
        EngineConfig(tier="routed", trace=True).resolve()
    # non-routed tiers accept the knob
    assert EngineConfig(trace=True).resolve().trace_cap == 256
    assert EngineConfig().resolve().trace_cap == 0


@pytest.mark.parametrize("kw,label", BACKENDS, ids=[b[1] for b in BACKENDS])
def test_metrics_dict_export_invariants(graph, kw, label):
    """Satellite: every metrics field exports present + finite, typed."""
    src = int(np.argmax(graph.deg))
    with Solver.open(graph, EngineConfig(**kw)) as solver:
        res = solver.solve(SolveSpec.tree(src))
    d = metrics_dict(res.metrics)
    assert set(d) == set(res.metrics._fields)
    for f in LOGICAL_METRIC_FIELDS:
        assert isinstance(d[f], int), f
    for f in PHYSICAL_METRIC_FIELDS:
        assert isinstance(d[f], float) and math.isfinite(d[f]), f
    assert d["n_rounds"] > 0 and d["n_relax"] > 0
    if kw.get("fused_rounds"):
        assert d["n_invocations"] >= 1
        assert d["n_invocations"] < d["n_rounds"]   # fusion amortizes


def test_perfetto_export_loads(graph, tmp_path):
    src = int(np.argmax(graph.deg))
    with Solver.open(graph, EngineConfig(trace=True)) as solver:
        res = solver.solve(SolveSpec.tree(src))
    doc = trace_to_perfetto(res.trace, name="unit")
    # JSON round-trip (what ui.perfetto.dev actually ingests)
    doc = json.loads(json.dumps(doc))
    events = doc["traceEvents"]
    assert events, "empty trace document"
    spans = [e for e in events if e.get("ph") == "X"]
    rounds = [e for e in spans if e["tid"] == 2]
    assert len(rounds) == res.trace.n_records
    for e in spans:
        assert e["dur"] >= 1        # zero-width spans are invisible
        assert set(e) >= {"name", "ph", "ts", "dur", "pid", "tid"}
    # the step track tiles the solve: one span per transition, plus a
    # trailing partial span when records follow the last transition
    n_steps = res.trace.summary()["n_steps"]
    steps = [e for e in spans if e["tid"] == 1]
    assert len(steps) in (n_steps, n_steps + 1)
    assert sum(e["dur"] for e in steps) == sum(e["dur"] for e in rounds)


# ----------------------------------------------------------------------
# distributed parity (8 forced host devices, subprocess)
# ----------------------------------------------------------------------

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np, jax
from repro.core.distributed import shard_blocked, shard_graph, sssp_distributed
from repro.core.sssp import LOGICAL_METRIC_FIELDS, PHYSICAL_METRIC_FIELDS
from repro.data.generators import kronecker
from repro.obs import materialize_trace

mesh = jax.make_mesh((8,), ("graph",))
g = kronecker(9, 8, seed=1)
sg = shard_graph(g, 8)
bl = shard_blocked(g, 8, block_v=128, tile_e=128)
src = int(np.argmax(g.deg))
failures = []
from repro.core.config import EngineConfig
for version, backend, fr in [("v1", "segment_min", 0),
                             ("v2", "segment_min", 0),
                             ("v3", "segment_min", 0),
                             ("v2", "blocked", 4)]:
    tag = f"{version}/{backend}/fused{fr}"
    kw = dict(version=version, backend=backend, fused_rounds=fr,
              blocked=bl if backend == "blocked" else None)
    ref = sssp_distributed(sg, src, mesh, ("graph",), **kw)
    out = sssp_distributed(sg, src, mesh, ("graph",),
                           config=EngineConfig(
                               tier="sharded", shard_version=version,
                               shard_backend=backend, fused_rounds=fr,
                               trace=True),
                           blocked=bl if backend == "blocked" else None)
    if len(out) != 4:
        failures.append(f"{tag}: no trace returned"); continue
    d0, p0, m0 = ref[0], ref[1], ref[2]
    d1, p1, m1 = out[0], out[1], out[2]
    if not np.array_equal(np.asarray(d0), np.asarray(d1)):
        failures.append(f"{tag}: dist changed under tracing")
    if not np.array_equal(np.asarray(p0), np.asarray(p1)):
        failures.append(f"{tag}: parent changed under tracing")
    for f in m0._fields:
        if np.asarray(getattr(m0, f)) != np.asarray(getattr(m1, f)):
            failures.append(f"{tag}: metric {f} changed under tracing")
    tr = materialize_trace(out[3])
    if tr.dropped:
        failures.append(f"{tag}: unexpected ring overflow")
    sums = tr.counter_sums()
    for f in LOGICAL_METRIC_FIELDS:
        init = 1 if f == "n_extended" else 0
        if init + sums[f] != int(getattr(m1, f)):
            failures.append(
                f"{tag}: {f} parity {init + sums[f]} != "
                f"{int(getattr(m1, f))}")
    for f in PHYSICAL_METRIC_FIELDS:
        if sums[f] != float(getattr(m1, f)):
            failures.append(f"{tag}: {f} physical parity broke")
    print(f"OK {tag}: {tr.n_records} records")
if failures:
    print("FAILURES:\n" + "\n".join(failures)); sys.exit(1)
print("ALL_OK")
"""


@pytest.mark.multidevice
@pytest.mark.slow
def test_distributed_trace_parity_8dev():
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT, src_dir],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout
