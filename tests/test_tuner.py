"""Tests for the trace-driven auto-tuner (repro.tune) and its plumbing:
policy parity, search determinism + parity gating, TunedStore staleness,
and the serving registry's tuned-build path."""
import json

import numpy as np
import pytest

from repro.api import SolveSpec, Solver
from repro.core.config import ConfigError, EngineConfig
from repro.data.generators import kronecker
from repro.tune import (TunedStore, graph_fingerprint, trace_objective,
                        tune)
from repro.tune import search as tsearch


@pytest.fixture(scope="module")
def graph():
    return kronecker(8, 6, seed=4)


@pytest.fixture(scope="module")
def reference(graph):
    res = Solver.open(graph).solve(SolveSpec.tree(0))
    return np.asarray(res.dist), np.asarray(res.parent)


# ---------------------------------------------------------------------------
# adaptive policy: engine-level parity
# ---------------------------------------------------------------------------

def test_adaptive_policy_bitwise_parity(graph, reference):
    """policy='adaptive' reschedules windows but returns bitwise-identical
    dist/parent (windows are pure scheduling)."""
    d_ref, p_ref = reference
    res = Solver.open(graph, EngineConfig(policy="adaptive")) \
        .solve(SolveSpec.tree(0))
    np.testing.assert_array_equal(np.asarray(res.dist), d_ref)
    np.testing.assert_array_equal(np.asarray(res.parent), p_ref)


def test_unknown_policy_rejected():
    with pytest.raises(ConfigError, match="policy"):
        EngineConfig(policy="annealed")


# ---------------------------------------------------------------------------
# search: determinism + parity gate
# ---------------------------------------------------------------------------

def _fake_evaluate(n, *, break_alpha=None):
    """Deterministic stand-in for tsearch._evaluate: the objective is a
    pure function of (alpha, beta, policy) with its optimum inside the
    search space; ``break_alpha`` makes that config return *different*
    dist arrays (a deliberately-broken candidate)."""

    def fake(graph, config, sources, weights, trace_capacity):
        dist = np.zeros((len(sources), n), np.float32)
        parent = np.full((len(sources), n), -1, np.int32)
        if break_alpha is not None and config.alpha == break_alpha:
            dist = dist + 1.0
        obj = (abs(config.alpha - 6.0) + abs(config.beta - 0.7)
               + (0.5 if config.policy == "adaptive" else 0.0) + 1.0)
        return dist, parent, obj

    return fake


def test_tuner_seed_determinism(graph, monkeypatch):
    monkeypatch.setattr(tsearch, "_evaluate", _fake_evaluate(int(graph.n)))
    a = tune(graph, budget=20, seed=7, restarts=2)
    b = tune(graph, budget=20, seed=7, restarts=2)
    assert [r["config"] for r in a.trajectory] \
        == [r["config"] for r in b.trajectory]
    assert a.best_config == b.best_config
    assert a.best_objective == b.best_objective
    # the fake objective's optimum is reachable by coordinate descent
    assert a.best_config.alpha == 6.0
    assert a.best_config.beta == 0.7
    assert a.best_config.policy == "static"
    assert a.improved and a.reduction > 0


def test_tuner_rejects_parity_breaking_candidate(graph, monkeypatch):
    """A candidate with a *better* objective but different dist arrays
    must be rejected and counted, never accepted."""
    fake = _fake_evaluate(int(graph.n), break_alpha=6.0)
    monkeypatch.setattr(tsearch, "_evaluate", fake)
    res = tune(graph, budget=20, seed=0)
    assert res.n_parity_rejects >= 1
    assert res.best_config.alpha != 6.0
    broken = [r for r in res.trajectory if r["config"]["alpha"] == 6.0]
    assert broken and not any(r["accepted"] for r in broken)
    assert not any(r["parity"] for r in broken)


def test_tuner_budget_cap(graph, monkeypatch):
    monkeypatch.setattr(tsearch, "_evaluate", _fake_evaluate(int(graph.n)))
    res = tune(graph, budget=5, seed=0, restarts=3)
    assert res.n_evals <= 5


def test_tuner_real_solve_improves_and_persists(graph, tmp_path):
    """A tiny real tune: the winner ties-or-beats the default objective,
    every accepted candidate passed the bitwise gate, and the store entry
    round-trips with objective bookkeeping."""
    store = TunedStore(tmp_path / "tuned.json")
    jsonl = tmp_path / "tuner.jsonl"
    res = tune(graph, budget=5, seed=0, restarts=0, n_sources=2,
               store=store, gid="g8", jsonl_path=str(jsonl))
    assert res.n_evals <= 5
    assert res.best_objective <= res.baseline_objective
    assert res.n_parity_rejects == 0
    assert store.get("g8", graph) == res.best_config
    entry = store.entry("g8")
    assert entry["objective"] == pytest.approx(res.best_objective)
    assert entry["baseline"] == pytest.approx(res.baseline_objective)
    lines = [json.loads(s) for s in jsonl.read_text().splitlines()]
    cands = [l for l in lines if l.get("kind") == "tuner_candidate"]
    assert len(cands) == res.n_evals
    assert any(l.get("meta", {}).get("kind") == "tuner_summary"
               or l.get("kind") == "tuner_summary" for l in lines)


def test_trace_objective_counts_rounds(graph):
    cfg = EngineConfig(trace=True, trace_capacity=512)
    res = Solver.open(graph, cfg).solve(SolveSpec.tree(0))
    obj = trace_objective(res.trace)
    sums = res.trace.counter_sums()
    assert obj >= float(sums["n_rounds"])


# ---------------------------------------------------------------------------
# TunedStore
# ---------------------------------------------------------------------------

def test_store_round_trip_and_stale_fingerprint(graph, tmp_path):
    path = tmp_path / "tuned.json"
    store = TunedStore(path)
    cfg = EngineConfig(alpha=9.0, beta=0.95, policy="adaptive")
    store.put("kg", graph, cfg, objective=10.0, baseline=20.0)
    # a fresh handle re-reads from disk
    assert TunedStore(path).get("kg", graph) == cfg
    assert TunedStore(path).get("kg") == cfg          # no-graph lookup
    # a different graph -> stale fingerprint -> None / untouched apply
    other = kronecker(8, 6, seed=9)
    assert graph_fingerprint(other) != graph_fingerprint(graph)
    assert TunedStore(path).get("kg", other) is None
    base = EngineConfig()
    assert TunedStore(path).apply("kg", other, base) == base
    # matching fingerprint -> perf fields overlaid, serving knobs kept
    applied = TunedStore(path).apply("kg", graph,
                                     EngineConfig(max_batch=16))
    assert applied.alpha == 9.0 and applied.policy == "adaptive"
    assert applied.max_batch == 16
    # invalidate drops the entry durably
    assert store.invalidate("kg")
    assert not store.invalidate("kg")
    assert TunedStore(path).get("kg", graph) is None


def test_store_corrupt_file_degrades_to_empty(graph, tmp_path):
    path = tmp_path / "tuned.json"
    path.write_text("{not json")
    store = TunedStore(path)
    assert store.get("kg", graph) is None
    store.put("kg", graph, EngineConfig(alpha=5.0))      # recovers
    assert TunedStore(path).get("kg", graph).alpha == 5.0


def test_store_apply_falls_back_on_invalid_overlay(graph, tmp_path):
    """An overlay the target config can't carry (fused_rounds on a
    single-tier segment_min engine) degrades to the params-only overlay
    instead of failing the build."""
    store = TunedStore(tmp_path / "tuned.json")
    tuned = EngineConfig(backend="blocked_pallas", alpha=7.0,
                         fused_rounds=4)
    store.put("kg", graph, tuned)
    base = EngineConfig()           # segment_min: fused_rounds invalid
    applied = store.apply("kg", graph, base,
                          n=int(graph.n), m=int(graph.m))
    assert applied.alpha == 7.0
    assert applied.fused_rounds == 0


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_registry_builds_from_tuned_store(graph, reference, tmp_path):
    from repro.serve.registry import GraphRegistry

    d_ref, p_ref = reference
    store = TunedStore(tmp_path / "tuned.json")
    store.put("kg", graph, EngineConfig(alpha=12.0, beta=0.99,
                                        policy="adaptive"))
    reg = GraphRegistry(config=EngineConfig(), tuned=store)
    reg.register("kg", graph)
    eng = reg.engine("kg")
    assert eng.alpha == 12.0 and eng.policy == "adaptive"
    assert reg._tuned_builds.value == 1
    dist, parent, _ = eng.run_batch([0])
    np.testing.assert_array_equal(np.asarray(dist)[0], d_ref)
    np.testing.assert_array_equal(np.asarray(parent)[0], p_ref)
    # a gid without an entry builds with the registry defaults
    reg.register("plain", graph)
    assert reg.engine("plain").alpha == EngineConfig().alpha
    assert reg._tuned_builds.value == 1


def test_solver_open_tuned_overlay(graph, reference, tmp_path):
    d_ref, p_ref = reference
    path = tmp_path / "tuned.json"
    TunedStore(path).put("kg", graph,
                         EngineConfig(alpha=12.0, policy="adaptive"))
    s = Solver.open(graph, tuned=str(path), gid="kg")   # path accepted too
    assert s.config.alpha == 12.0 and s.config.policy == "adaptive"
    res = s.solve(SolveSpec.tree(0))
    np.testing.assert_array_equal(np.asarray(res.dist), d_ref)
    np.testing.assert_array_equal(np.asarray(res.parent), p_ref)
    # stale entry (different graph) leaves the config untouched
    other = kronecker(8, 6, seed=9)
    s2 = Solver.open(other, tuned=str(path), gid="kg")
    assert s2.config == EngineConfig()
