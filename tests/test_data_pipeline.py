"""Data pipeline: generators, weight variants, sampler, determinism."""
import numpy as np
import pytest

from repro.data.generators import kronecker, road_grid, uniform_random
from repro.data.sampler import NeighborSampler
from repro.data.synthetic import LMTokenStream, RecsysStream
from repro.data.triplets import build_triplets
from repro.data.weights import discretize, converge, make_variant


def test_kronecker_shapes():
    g = kronecker(8, 4, seed=0)
    assert g.n == 256
    assert g.m <= 2 * 4 * 256
    assert (g.w > 0).all() and (g.w <= 1).all()
    # CSR rows sorted by weight (paper preprocessing)
    for u in range(0, g.n, 37):
        row = g.w[g.row_ptr[u]:g.row_ptr[u + 1]]
        assert np.all(np.diff(row) >= 0)


def test_generators_deliver_exact_edge_counts():
    # self-loop drops are resampled, not silently swallowed
    g = kronecker(7, 8, seed=3)
    assert g.m == 2 * 8 * 128
    g2 = uniform_random(100, 500, seed=4)
    assert g2.m == 2 * 500
    assert uniform_random(10, 0).m == 0          # empty graphs still build
    with pytest.raises(ValueError):
        uniform_random(1, 5)
    with pytest.raises(ValueError):
        kronecker(0, 8)                          # all draws are self loops


def test_bimodal_weights():
    g = kronecker(7, 8, seed=3, weights="bimodal")
    w = g.w
    low = w <= 0.15
    high = w >= 0.85
    assert (low | high).all()               # two narrow bands only
    assert 0.35 < low.mean() < 0.65         # roughly balanced modes
    with pytest.raises(ValueError):
        kronecker(7, 4, weights="nope")


def test_traffic_generator_zipf_mix():
    from repro.data.traffic import make_traffic

    graphs = {"hot": kronecker(7, 6, seed=1),
              "warm": road_grid(10, seed=2),
              "cold": uniform_random(128, 512, seed=3)}
    items = make_traffic(graphs, 200, seed=0, deadline_s=5.0)
    assert len(items) == 200
    by_gid = {gid: 0 for gid in graphs}
    kinds = set()
    for it in items:
        q = it.query
        by_gid[q.gid] += 1
        kinds.add(q.kind)
        deg = graphs[q.gid].deg
        assert deg[q.source] > 0            # endpoints are never isolates
        if q.kind == "p2p":
            assert deg[q.target] > 0
        if q.kind == "knear":
            assert q.k >= 1
        if q.kind == "bounded":
            assert q.bound > 0
    # Zipf skew: first-registered graph takes the most traffic
    assert by_gid["hot"] > by_gid["warm"] > by_gid["cold"]
    assert kinds == {"p2p", "bounded", "knear", "tree"}
    # deterministic per seed
    again = make_traffic(graphs, 200, seed=0, deadline_s=5.0)
    assert again == items


def test_weight_variants():
    w = np.random.default_rng(0).random(10000)
    for power in [1, 2, 4, 10]:
        d = discretize(w, power)
        assert d.min() >= 1 and d.max() <= 2 ** power - 1
    for pivot in [0.1, 0.5, 0.9]:
        c = converge(w, pivot)
        assert (c >= 0).all() and (c <= 1).all()
        # half of the new weights are below the pivot (paper §4.2)
        assert abs((c < pivot).mean() - 0.5) < 0.05


def test_make_variant_graph():
    g = kronecker(8, 4, seed=1)
    gv = make_variant(g, power=3)
    assert gv.m == g.m
    assert gv.max_w <= 7
    gv2 = make_variant(g, pivot=0.3)
    assert 0 <= gv2.w.min() and gv2.w.max() <= 1


def test_neighbor_sampler_fanout():
    g = kronecker(10, 8, seed=2)
    s = NeighborSampler(g.row_ptr, g.dst, fanouts=(15, 10), seed=0)
    seeds = np.where(g.deg > 0)[0][:64]
    batch = s.sample(seeds)
    assert len(batch.blocks) == 2
    b0 = batch.blocks[0]
    assert b0.senders.shape[0] == 64 * 15
    # sampled neighbors are real neighbors
    for i in range(0, 64 * 15, 97):
        if not b0.edge_mask[i]:
            continue
        src_g = b0.src_nodes[b0.senders[i]]
        dst_g = b0.dst_nodes[b0.receivers[i]]
        row = g.dst[g.row_ptr[dst_g]:g.row_ptr[dst_g + 1]]
        assert src_g in row


def test_triplets_share_pivot_vertex():
    g = uniform_random(50, 200, seed=3)
    tkj, tji, mask = build_triplets(g.src, g.dst, cap=4)
    idx = np.where(mask)[0]
    # edge (k->j) feeds edge (j->i): receiver of kj == sender of ji, k != i
    assert np.all(g.dst[tkj[idx]] == g.src[tji[idx]])
    assert np.all(g.src[tkj[idx]] != g.dst[tji[idx]])


def test_streams_deterministic():
    s = LMTokenStream(1000, seed=5)
    a = s.batch(3, 4, 32)
    b = s.batch(3, 4, 32)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, s.batch(4, 4, 32))
    r = RecsysStream(1000, 10, seed=5)
    np.testing.assert_array_equal(r.batch(2, 8)["hist"],
                                  r.batch(2, 8)["hist"])
