"""Data pipeline: generators, weight variants, sampler, determinism."""
import numpy as np
import pytest

from repro.data.generators import kronecker, road_grid, uniform_random
from repro.data.sampler import NeighborSampler
from repro.data.synthetic import LMTokenStream, RecsysStream
from repro.data.triplets import build_triplets
from repro.data.weights import discretize, converge, make_variant


def test_kronecker_shapes():
    g = kronecker(8, 4, seed=0)
    assert g.n == 256
    assert g.m <= 2 * 4 * 256
    assert (g.w > 0).all() and (g.w <= 1).all()
    # CSR rows sorted by weight (paper preprocessing)
    for u in range(0, g.n, 37):
        row = g.w[g.row_ptr[u]:g.row_ptr[u + 1]]
        assert np.all(np.diff(row) >= 0)


def test_weight_variants():
    w = np.random.default_rng(0).random(10000)
    for power in [1, 2, 4, 10]:
        d = discretize(w, power)
        assert d.min() >= 1 and d.max() <= 2 ** power - 1
    for pivot in [0.1, 0.5, 0.9]:
        c = converge(w, pivot)
        assert (c >= 0).all() and (c <= 1).all()
        # half of the new weights are below the pivot (paper §4.2)
        assert abs((c < pivot).mean() - 0.5) < 0.05


def test_make_variant_graph():
    g = kronecker(8, 4, seed=1)
    gv = make_variant(g, power=3)
    assert gv.m == g.m
    assert gv.max_w <= 7
    gv2 = make_variant(g, pivot=0.3)
    assert 0 <= gv2.w.min() and gv2.w.max() <= 1


def test_neighbor_sampler_fanout():
    g = kronecker(10, 8, seed=2)
    s = NeighborSampler(g.row_ptr, g.dst, fanouts=(15, 10), seed=0)
    seeds = np.where(g.deg > 0)[0][:64]
    batch = s.sample(seeds)
    assert len(batch.blocks) == 2
    b0 = batch.blocks[0]
    assert b0.senders.shape[0] == 64 * 15
    # sampled neighbors are real neighbors
    for i in range(0, 64 * 15, 97):
        if not b0.edge_mask[i]:
            continue
        src_g = b0.src_nodes[b0.senders[i]]
        dst_g = b0.dst_nodes[b0.receivers[i]]
        row = g.dst[g.row_ptr[dst_g]:g.row_ptr[dst_g + 1]]
        assert src_g in row


def test_triplets_share_pivot_vertex():
    g = uniform_random(50, 200, seed=3)
    tkj, tji, mask = build_triplets(g.src, g.dst, cap=4)
    idx = np.where(mask)[0]
    # edge (k->j) feeds edge (j->i): receiver of kj == sender of ji, k != i
    assert np.all(g.dst[tkj[idx]] == g.src[tji[idx]])
    assert np.all(g.src[tkj[idx]] != g.dst[tji[idx]])


def test_streams_deterministic():
    s = LMTokenStream(1000, seed=5)
    a = s.batch(3, 4, 32)
    b = s.batch(3, 4, 32)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, s.batch(4, 4, 32))
    r = RecsysStream(1000, 10, seed=5)
    np.testing.assert_array_equal(r.batch(2, 8)["hist"],
                                  r.batch(2, 8)["hist"])
