"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates a REDUCED config of the same family and runs one
forward/train step on CPU, asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer
from repro.models.gnn import common as gnn_common
from repro.models.gnn import dimenet as dimenet_mod
from repro.train import loop as train_loop, optimizer as opt_mod
from repro.data.synthetic import gnn_node_classification, RecsysStream
from repro.data.triplets import build_triplets

LM_ARCHS = ["deepseek-moe-16b", "granite-moe-3b-a800m", "qwen3-0.6b",
            "phi4-mini-3.8b", "granite-34b", "qwen3-0.6b-swa"]
GNN_ARCHS = ["gin-tu", "pna", "gatedgcn", "dimenet"]


def _finite(tree):
    return all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    opt_cfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    step = train_loop.make_lm_train_step(cfg, opt_cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    params2, opt2, metrics = jax.jit(step)(params, opt_state,
                                           {"tokens": toks})
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
    # logits shape
    logits, _ = transformer.forward(cfg, params2, toks)
    assert logits.shape == (2, 32, cfg.vocab)
    assert _finite(logits)


@pytest.mark.parametrize("arch", LM_ARCHS[:5])
def test_lm_smoke_decode(arch):
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    s_cache = cfg.attn_window if cfg.attn_window else 16
    cache, logits = transformer.prefill(cfg, params, toks[:, :8], s_cache)
    assert logits.shape == (2, cfg.vocab)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = transformer.decode_step(cfg, params, cache, nxt)
    assert logits2.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


def _tiny_graph(d_in, with_pos=False, with_triplets=False):
    d = gnn_node_classification(60, 200, d_in, n_classes=4, seed=3,
                                with_pos=True)
    gb = gnn_common.GraphBatch(
        node_feat=jnp.asarray(d["node_feat"]),
        senders=jnp.asarray(d["senders"]),
        receivers=jnp.asarray(d["receivers"]), edge_feat=None,
        graph_ids=jnp.zeros(60, jnp.int32), n_graphs=1,
        labels=jnp.asarray(d["labels"]),
        pos=jnp.asarray(d["pos"]) if with_pos else None)
    if with_triplets:
        tkj, tji, tmask = build_triplets(d["senders"], d["receivers"], cap=4)
        gb = gb._replace(triplet_kj=jnp.asarray(tkj),
                         triplet_ji=jnp.asarray(tji),
                         triplet_mask=jnp.asarray(tmask))
    return gb


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    from repro.launch.cells import GNN_FWD
    gmod, fwd = GNN_FWD[mod.MODEL]
    gb = _tiny_graph(cfg.d_in, with_pos=(arch == "dimenet"),
                     with_triplets=(arch == "dimenet"))
    params = gmod.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = opt_mod.AdamWConfig(lr=1e-3, master_weights=False)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    if arch == "dimenet":
        gb = gb._replace(labels=jnp.ones((1,), jnp.float32))
        step = train_loop.make_gnn_regression_step(fwd, cfg, opt_cfg)
    else:
        step = train_loop.make_gnn_train_step(fwd, cfg, opt_cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt_state, gb)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2)
    out = fwd(cfg, params2, gb)
    expected = (gb.n_graphs, getattr(cfg, "n_out", None) or cfg.n_classes) \
        if getattr(cfg, "graph_level", False) else (60, cfg.n_classes)
    assert out.shape == expected
    assert _finite(out)


def test_mind_smoke_train_and_serve():
    mod = configs.get("mind")
    cfg = mod.smoke_config()
    from repro.models.recsys import mind as mind_mod
    params = mind_mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = RecsysStream(cfg.n_items, cfg.hist_len).batch(0, 8)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt_cfg = opt_mod.AdamWConfig(lr=1e-3, master_weights=False)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    step = train_loop.make_mind_train_step(cfg, opt_cfg)
    params2, _, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    ints = mind_mod.serve_interests(cfg, params2, batch)
    assert ints.shape == (8, cfg.n_interests, cfg.embed_dim)
    scores = mind_mod.retrieval_scores(cfg, params2, ints[0],
                                       jnp.arange(cfg.n_items))
    assert scores.shape == (cfg.n_items,)
    assert bool(jnp.isfinite(scores).all())


def test_registry_covers_40_cells():
    cells = list(configs.all_cells())
    skips = configs.SKIPPED
    # 10 archs x 4 shapes = 40 assigned cells; 5 documented long_500k skips
    assert len(cells) + len(skips) == 40
    assert len({a for a, _ in cells}) == 10


def test_loss_decreases_lm():
    """A few steps of training on structured data must reduce the loss."""
    from repro.data.synthetic import LMTokenStream
    cfg = configs.get("qwen3-0.6b").smoke_config()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    opt_state = opt_mod.adamw_init(params, opt_cfg)
    step = jax.jit(train_loop.make_lm_train_step(cfg, opt_cfg))
    stream = LMTokenStream(cfg.vocab, seed=0)
    losses = []
    for i in range(30):
        batch = {"tokens": jnp.asarray(stream.batch(i, 8, 64))}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]
