"""Multi-graph registry: LRU eviction/rebuild, build futures, tiers,
warmup, stats, ecc/feedback hints."""
import threading
import time

import numpy as np
import pytest

from repro.core.sssp import sssp
from repro.data.generators import kronecker, road_grid
from repro.serve.registry import (GraphEngine, GraphRegistry,
                                  ShardedGraphEngine, estimate_eccentricity)


def test_engine_caching_and_lru_eviction_rebuild():
    reg = GraphRegistry(capacity=1)
    road = road_grid(12, seed=5)
    kron = kronecker(7, 6, seed=2)
    reg.register("road", road)
    reg.register("kron", kron)
    assert set(reg.gids) == {"road", "kron"}

    e1 = reg.engine("road")
    assert reg.engine("road") is e1               # cache hit
    assert reg.stats.hits == 1 and reg.stats.builds == 1

    reg.engine("kron")                            # evicts road (capacity 1)
    assert reg.cached_keys() == (("kron", "segment_min", None),)
    assert reg.stats.evictions == 1

    e2 = reg.engine("road")                       # transparent rebuild
    assert e2 is not e1
    assert reg.stats.builds == 3
    # rebuilt engine answers identically
    d_ref, _, _ = sssp(road.to_device(), 0)
    dist, _, _ = e2.run_batch([0, 0])
    np.testing.assert_array_equal(dist[0], np.asarray(d_ref))


def test_registry_keys_per_backend_and_factory_spec():
    reg = GraphRegistry(capacity=4, block_v=128, tile_e=128)
    builds = []

    def factory():
        builds.append(1)
        return road_grid(12, seed=5)

    reg.register("road", factory)
    e_seg = reg.engine("road", "segment_min")
    e_blk = reg.engine("road", "blocked_pallas")
    assert e_seg is not e_blk
    assert len(builds) == 2                       # one HostGraph per engine
    assert set(reg.cached_keys()) == {("road", "segment_min", None),
                                      ("road", "blocked_pallas", None)}
    # both backends serve bitwise-identical results
    d1, _, _ = e_seg.run_batch([3, 7])
    d2, _, _ = e_blk.run_batch([3, 7])
    np.testing.assert_array_equal(d1, d2)


def test_register_replaces_and_validates():
    reg = GraphRegistry(capacity=2)
    reg.register("g", road_grid(12, seed=5))
    reg.engine("g")
    reg.register("g", road_grid(12, seed=6))      # new spec drops old engine
    assert reg.cached_keys() == ()
    with pytest.raises(TypeError):
        reg.register("bad", object())
    with pytest.raises(KeyError):
        reg.engine("missing")
    with pytest.raises(ValueError):
        GraphRegistry(capacity=0)


def test_cold_build_does_not_serialize_other_lookups():
    """Per-key build futures (ROADMAP follow-up): while one thread pays a
    slow cold build, lookups of an *already-built* engine return
    immediately instead of queueing behind the registry lock."""
    reg = GraphRegistry(capacity=4)
    reg.register("fast", road_grid(8, seed=5))
    reg.engine("fast")                               # built up front

    entered = threading.Event()

    def slow_factory():
        entered.set()
        time.sleep(0.8)
        return road_grid(8, seed=6)

    reg.register("slow", slow_factory)
    builder = threading.Thread(target=lambda: reg.engine("slow"))
    builder.start()
    assert entered.wait(timeout=5)                   # build in progress
    t0 = time.perf_counter()
    assert reg.engine("fast") is not None
    waited = time.perf_counter() - t0
    builder.join()
    assert waited < 0.4, f"built-engine lookup waited {waited:.2f}s " \
                         "on another key's build"


def test_concurrent_same_key_lookups_share_one_build():
    reg = GraphRegistry(capacity=2)
    builds = []

    def factory():
        builds.append(1)
        time.sleep(0.3)
        return road_grid(8, seed=5)

    reg.register("g", factory)
    out = []
    threads = [threading.Thread(target=lambda: out.append(reg.engine("g")))
               for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1                          # deduped
    assert out[0] is out[1] is out[2]
    assert reg.stats.builds == 1 and reg.stats.build_waits == 2


def test_reregister_mid_build_serves_new_spec_not_stale_engine():
    """A lookup after ``register()`` replaced the spec must not attach to
    the old spec's in-flight build future."""
    reg = GraphRegistry(capacity=2)
    entered = threading.Event()
    release = threading.Event()

    def slow_old():
        entered.set()
        release.wait(timeout=5)
        return road_grid(8, seed=5)          # n = 64

    reg.register("g", slow_old)
    old = []
    builder = threading.Thread(target=lambda: old.append(reg.engine("g")))
    builder.start()
    assert entered.wait(timeout=5)           # old build in flight
    reg.register("g", road_grid(10, seed=6))  # n = 100
    release.set()
    eng = reg.engine("g")                    # post-replacement lookup
    builder.join()
    assert eng.n == 100                      # served the new spec
    assert reg.peek("g").n == 100            # stale engine never cached
    assert old[0].n == 64                    # pre-replacement waiter kept
    #                                          its (then-correct) result


def test_failed_build_raises_everywhere_and_allows_retry():
    reg = GraphRegistry(capacity=2)
    boom = [True]

    def factory():
        if boom[0]:
            raise RuntimeError("transient build failure")
        return road_grid(8, seed=5)

    reg.register("g", factory)
    with pytest.raises(RuntimeError):
        reg.engine("g")
    boom[0] = False
    assert reg.engine("g") is not None               # retried cleanly


def test_tier_dispatch_and_sharded_parity():
    road = road_grid(12, seed=5)                     # n=144
    reg = GraphRegistry(capacity=4, shard_threshold_n=100)
    reg.register("big", road)
    reg.register("small", kronecker(6, 4, seed=2))   # n=64
    reg.register("forced", kronecker(6, 4, seed=2), tier="sharded")
    assert reg.tier("big") == "sharded"
    assert reg.tier("small") == "single"
    assert reg.tier("forced") == "sharded"
    with pytest.raises(ValueError):
        reg.register("bad_tier", road, tier="mesh")
    big = reg.engine("big")
    assert isinstance(big, ShardedGraphEngine)
    assert isinstance(reg.engine("small"), GraphEngine)
    # both tiers share the run_batch contract and agree bitwise
    dist, parent, _ = big.run_batch([0, 7])
    assert dist.shape == (2, road.n)                 # padding sliced off
    for slot, s in enumerate((0, 7)):
        d_ref, p_ref, _ = sssp(road.to_device(), s)
        np.testing.assert_array_equal(np.asarray(dist[slot]),
                                      np.asarray(d_ref))
        np.testing.assert_array_equal(np.asarray(parent[slot]),
                                      np.asarray(p_ref))


def test_warmup_prepays_builds_and_compiles():
    reg = GraphRegistry(capacity=4)
    reg.register("road", road_grid(10, seed=5))
    rows = reg.warmup(kinds=("tree", "p2p"), batch_sizes=(2,))
    assert [r["kind"] for r in rows] == ["tree", "p2p"]
    assert rows[0]["build_s"] > 0 and rows[1]["build_s"] == 0
    assert all(r["batch"] == 2 and r["tier"] == "single" for r in rows)
    # warmed: the same (kind, batch) executes without a fresh compile
    eng = reg.engine("road")
    t0 = time.perf_counter()
    out = eng.run_batch([1, 2], goal="p2p", goal_params=[3, 4])
    np.asarray(out[0])
    assert time.perf_counter() - t0 < rows[1]["compile_s"]
    with pytest.raises(ValueError):
        reg.warmup(kinds=("nope",))


def test_feedback_blends_measured_rounds_into_batch_hint():
    reg = GraphRegistry(capacity=2)
    reg.register("road", road_grid(10, seed=5))
    eng = reg.engine("road")
    base = eng.batch_hint.copy()
    np.testing.assert_array_equal(base, eng.ecc_hint)   # prior = BFS hint
    eng.record_rounds([3, 7], [40.0, 10.0], gamma=0.5)
    assert eng.batch_hint[3] == pytest.approx(0.5 * base[3] + 0.5 * 40.0)
    assert eng.batch_hint[7] == pytest.approx(0.5 * base[7] + 0.5 * 10.0)
    untouched = np.ones(base.shape, bool)
    untouched[[3, 7]] = False
    np.testing.assert_array_equal(eng.batch_hint[untouched],
                                  base[untouched])
    # the BFS prior itself is unchanged (hints are a separate buffer)
    np.testing.assert_array_equal(eng.ecc_hint, base)


def test_eccentricity_hint_ordering():
    side = 12
    g = road_grid(side, seed=5)
    ecc = estimate_eccentricity(g)
    assert ecc.shape == (side * side,)
    # grid corners are estimated more eccentric than the landmark region
    landmark = int(np.argmax(g.deg))
    corners = [0, side - 1, side * (side - 1), side * side - 1]
    assert all(ecc[c] > ecc[landmark] for c in corners)
    # hoisted degree array is numpy (not recomputed per batch)
    reg = GraphRegistry(capacity=1)
    reg.register("g", g)
    eng = reg.engine("g")
    assert isinstance(eng.deg, np.ndarray)
    np.testing.assert_array_equal(eng.ecc_hint, ecc)


def test_generation_counter_and_listeners():
    reg = GraphRegistry(capacity=4)
    g1 = road_grid(10, seed=5)
    g2 = road_grid(10, seed=9)
    events = []
    reg.add_invalidation_listener(lambda gid, gen: events.append((gid, gen)))
    reg.register("road", g1)
    assert reg.generation("road") == 1
    assert events == []                     # first registration: no replicas
    eng1 = reg.engine("road")
    assert eng1.generation == 1
    reg.register("road", g2)                # re-register bumps + notifies
    assert reg.generation("road") == 2
    assert events == [("road", 2)]
    eng2 = reg.engine("road")
    assert eng2 is not eng1 and eng2.generation == 2
    d_ref, _, _ = sssp(g2.to_device(), 0)
    np.testing.assert_array_equal(np.asarray(eng2.run_batch([0, 0])[0][0]),
                                  np.asarray(d_ref))
    with pytest.raises(KeyError):
        reg.generation("nope")


def test_sharded_tier_backend_keys_and_blocked_parity():
    """The sharded tier keys engines by the sharded backend name: blocked
    lookups build a blocked whole-mesh engine, default lookups share the
    registry's shard_backend, and both serve bitwise-equal results."""
    road = road_grid(12, seed=5)
    reg = GraphRegistry(capacity=4, shard_threshold_n=100,
                        block_v=64, tile_e=64)
    reg.register("big", road)
    seg = reg.engine("big")
    blk = reg.engine("big", "blocked")
    via_alias = reg.engine("big", "blocked_pallas")
    assert seg is not blk and blk is via_alias
    assert seg.backend == "segment_min" and blk.backend == "blocked"
    assert blk.blocked is not None
    assert set(reg.cached_keys()) == {("big", "segment_min", "sharded"),
                                      ("big", "blocked", "sharded")}
    d_s, p_s, _ = seg.run_batch([0, 7])
    d_b, p_b, m_b = blk.run_batch([0, 7])
    np.testing.assert_array_equal(np.asarray(d_s), np.asarray(d_b))
    np.testing.assert_array_equal(np.asarray(p_s), np.asarray(p_b))
    assert (np.asarray(m_b.n_tiles_scanned) > 0).all()
    # a registry defaulted to the blocked shard backend serves it on None
    reg2 = GraphRegistry(capacity=2, shard_threshold_n=100,
                         shard_backend="blocked", block_v=64, tile_e=64)
    reg2.register("big", road)
    assert reg2.engine("big").backend == "blocked"


def test_multi_landmark_eccentricity_dominates_single_landmark():
    """The default hint is the max over k max-degree landmarks' hop-BFS
    estimates — pointwise >= the single-landmark estimate (whose landmark
    is in the set), and still ordering the grid periphery above the hub
    region."""
    g = road_grid(14, seed=5)
    e1 = estimate_eccentricity(g, n_landmarks=1)
    ek = estimate_eccentricity(g)                 # default: 4 landmarks
    assert ek.shape == e1.shape
    assert np.all(ek >= e1)
    # on a degree-skewed graph the extra vantage points genuinely add
    # information (on the uniform road grid the top-degree landmarks sit
    # adjacent, so the estimates coincide — covered by >= above)
    gk = kronecker(8, 8, seed=2)
    assert np.any(estimate_eccentricity(gk)
                  > estimate_eccentricity(gk, n_landmarks=1))
    with pytest.raises(ValueError):
        estimate_eccentricity(g, n_landmarks=0)
    # a graph smaller than k landmarks still works
    tiny = road_grid(2, seed=0)
    assert estimate_eccentricity(tiny, n_landmarks=16).shape == (4,)


def test_multi_landmark_keeps_ordering_on_disconnected_graphs():
    """A foreign component's landmark contributes nothing to a vertex it
    cannot reach — the per-component ordering survives instead of being
    swamped by a flat disconnection constant."""
    from repro.core.graph import build_csr
    a = kronecker(7, 8, seed=3)
    m = a.src < a.dst
    eu = np.concatenate([a.src[m], a.src[m] + a.n])
    ev = np.concatenate([a.dst[m], a.dst[m] + a.n])
    ew = np.concatenate([a.w[m], a.w[m]])
    g = build_csr(2 * a.n, eu, ev, ew)     # two identical components
    ek = estimate_eccentricity(g)          # landmarks land in both copies
    for lo, hi in ((0, a.n), (a.n, 2 * a.n)):
        comp = ek[lo:hi][np.asarray(g.deg[lo:hi]) > 0]
        assert len(set(comp.tolist())) > 1
