"""Multi-graph registry: LRU eviction/rebuild, stats, ecc hints."""
import numpy as np
import pytest

from repro.core.sssp import sssp
from repro.data.generators import kronecker, road_grid
from repro.serve.registry import GraphRegistry, estimate_eccentricity


def test_engine_caching_and_lru_eviction_rebuild():
    reg = GraphRegistry(capacity=1)
    road = road_grid(12, seed=5)
    kron = kronecker(7, 6, seed=2)
    reg.register("road", road)
    reg.register("kron", kron)
    assert set(reg.gids) == {"road", "kron"}

    e1 = reg.engine("road")
    assert reg.engine("road") is e1               # cache hit
    assert reg.stats.hits == 1 and reg.stats.builds == 1

    reg.engine("kron")                            # evicts road (capacity 1)
    assert reg.cached_keys() == (("kron", "segment_min"),)
    assert reg.stats.evictions == 1

    e2 = reg.engine("road")                       # transparent rebuild
    assert e2 is not e1
    assert reg.stats.builds == 3
    # rebuilt engine answers identically
    d_ref, _, _ = sssp(road.to_device(), 0)
    dist, _, _ = e2.run_batch([0, 0])
    np.testing.assert_array_equal(dist[0], np.asarray(d_ref))


def test_registry_keys_per_backend_and_factory_spec():
    reg = GraphRegistry(capacity=4, block_v=128, tile_e=128)
    builds = []

    def factory():
        builds.append(1)
        return road_grid(12, seed=5)

    reg.register("road", factory)
    e_seg = reg.engine("road", "segment_min")
    e_blk = reg.engine("road", "blocked_pallas")
    assert e_seg is not e_blk
    assert len(builds) == 2                       # one HostGraph per engine
    assert set(reg.cached_keys()) == {("road", "segment_min"),
                                      ("road", "blocked_pallas")}
    # both backends serve bitwise-identical results
    d1, _, _ = e_seg.run_batch([3, 7])
    d2, _, _ = e_blk.run_batch([3, 7])
    np.testing.assert_array_equal(d1, d2)


def test_register_replaces_and_validates():
    reg = GraphRegistry(capacity=2)
    reg.register("g", road_grid(12, seed=5))
    reg.engine("g")
    reg.register("g", road_grid(12, seed=6))      # new spec drops old engine
    assert reg.cached_keys() == ()
    with pytest.raises(TypeError):
        reg.register("bad", object())
    with pytest.raises(KeyError):
        reg.engine("missing")
    with pytest.raises(ValueError):
        GraphRegistry(capacity=0)


def test_eccentricity_hint_ordering():
    side = 12
    g = road_grid(side, seed=5)
    ecc = estimate_eccentricity(g)
    assert ecc.shape == (side * side,)
    # grid corners are estimated more eccentric than the landmark region
    landmark = int(np.argmax(g.deg))
    corners = [0, side - 1, side * (side - 1), side * side - 1]
    assert all(ecc[c] > ecc[landmark] for c in corners)
    # hoisted degree array is numpy (not recomputed per batch)
    reg = GraphRegistry(capacity=1)
    reg.register("g", g)
    eng = reg.engine("g")
    assert isinstance(eng.deg, np.ndarray)
    np.testing.assert_array_equal(eng.ecc_hint, ecc)
