"""SSSP serving endpoint: slot-batched queries match direct engine calls."""
import numpy as np
import pytest

from repro.core.baselines import dijkstra_host
from repro.core.sssp import sssp
from repro.data.generators import kronecker
from repro.serve.sssp_service import SsspRequest, SsspService


def test_service_batches_and_matches_engine():
    g = kronecker(9, 8, seed=1)
    svc = SsspService(g, max_batch=3)
    rng = np.random.default_rng(0)
    srcs = rng.choice(np.where(g.deg > 0)[0], 7, replace=False)
    reqs = [svc.submit(SsspRequest(rid=i, source=int(s)))
            for i, s in enumerate(srcs)]
    steps = svc.run()
    assert steps == 3                     # ceil(7 / 3) batches
    assert all(r.done for r in reqs)
    dg = g.to_device()
    for r in reqs:
        dist, parent, _ = sssp(dg, r.source)
        np.testing.assert_array_equal(r.dist, np.asarray(dist))
        np.testing.assert_array_equal(r.parent, np.asarray(parent))
        assert r.metrics["nFrontier"] >= 0
        dref, _ = dijkstra_host(g, r.source)
        np.testing.assert_allclose(
            np.where(np.isfinite(r.dist), r.dist, -1.0),
            np.where(np.isfinite(dref), dref, -1.0), rtol=1e-4, atol=1e-5)


def test_service_partial_batch_and_backend_selection():
    g = kronecker(8, 6, seed=2)
    svc = SsspService(g, max_batch=4, backend="blocked_pallas",
                      block_v=128, tile_e=128)
    req = svc.submit(SsspRequest(rid=0, source=int(np.argmax(g.deg))))
    assert svc.step()                     # 1 request in a 4-slot batch
    assert not svc.step()                 # queue drained -> no-op
    dist, parent, _ = sssp(g.to_device(), req.source)
    np.testing.assert_array_equal(req.dist, np.asarray(dist))
    np.testing.assert_array_equal(req.parent, np.asarray(parent))


def test_service_rejects_bad_graph():
    with pytest.raises(TypeError):
        SsspService(object())


def test_failed_request_does_not_wedge_service():
    g = kronecker(8, 6, seed=2)
    svc = SsspService(g, max_batch=2)
    bad = svc.submit(SsspRequest(rid=0, source=g.n + 5))   # out of range
    good = svc.submit(SsspRequest(rid=1, source=0))
    svc.run()
    assert isinstance(bad.error, ValueError) and not bad.done
    assert good.done and good.error is None
    # the service keeps serving after a failure
    later = svc.submit(SsspRequest(rid=2, source=1))
    svc.run()
    assert later.done
