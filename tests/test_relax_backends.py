"""Relaxation-backend parity (core/relax.py).

Every backend — and the distributed engines built from the same shared
primitives — must produce *identical* dist/parent trees and identical
logical-traversal metrics: all tie-breaks resolve toward the smallest
source id, so the results are bitwise-equal, not merely allclose.  The
physical tile counters (n_tiles_*) describe the blocked layout's work
and are excluded from cross-backend parity (LOGICAL_METRIC_FIELDS).
"""
import numpy as np
import jax
import pytest

from repro.core import relax
from repro.core.baselines import dijkstra_host
from repro.core.distributed import (shard_blocked, shard_graph,
                                    sssp_distributed)
from repro.core.sssp import LOGICAL_METRIC_FIELDS, sssp, sssp_batch
from repro.data.generators import kronecker, road_grid, uniform_random

GRAPHS = [
    ("kron", lambda: kronecker(10, 8, seed=11)),
    ("road", lambda: road_grid(28, seed=12)),
    ("urand", lambda: uniform_random(1500, 12000, seed=13)),
]


def _asnp(out):
    dist, parent, metrics = out
    return (np.asarray(dist), np.asarray(parent),
            jax.tree.map(np.asarray, metrics))


def _assert_same(a, b, what):
    np.testing.assert_array_equal(a[0], b[0], err_msg=f"{what}: dist")
    np.testing.assert_array_equal(a[1], b[1], err_msg=f"{what}: parent")
    for f in LOGICAL_METRIC_FIELDS:
        assert int(getattr(a[2], f)) == int(getattr(b[2], f)), (
            what, f, int(getattr(a[2], f)), int(getattr(b[2], f)))


def test_registry():
    assert set(relax.available_backends()) >= {"segment_min",
                                               "blocked_pallas"}
    assert relax.get_backend("segment_min").name == "segment_min"
    # "blocked" aliases the blocked layout (the distributed engines' name
    # for it) without appearing as a separate canonical backend
    assert relax.get_backend("blocked").name == "blocked_pallas"
    assert "blocked" not in relax.available_backends()
    be = relax.get_backend(relax.get_backend("segment_min"))
    assert be.name == "segment_min"
    with pytest.raises(ValueError, match="unknown relax backend"):
        relax.get_backend("nope")


@pytest.mark.parametrize("name,make", GRAPHS)
def test_backend_parity(name, make):
    """segment_min vs blocked_pallas (interpret mode, multi-dst-block
    layout): identical dist/parent/metrics, and both match Dijkstra."""
    g = make()
    dg = g.to_device()
    src = int(np.argmax(g.deg))
    ref = _asnp(sssp(dg, src, backend="segment_min"))
    # block_v < n forces a multi-block grid in the kernel
    blocked = _asnp(sssp(dg, src, backend="blocked_pallas", block_v=256,
                         tile_e=256))
    _assert_same(ref, blocked, f"{name}: segment_min vs blocked_pallas")
    # physical tile metrics: the blocked layout reports its scanned and
    # dense-comparator tile counts; segment_min has no tiles
    assert int(ref[2].n_tiles_scanned) == 0
    assert 0 < int(blocked[2].n_tiles_scanned) \
        < int(blocked[2].n_tiles_dense)
    dref, _ = dijkstra_host(g, src)
    np.testing.assert_allclose(
        np.where(np.isfinite(ref[0]), ref[0], -1.0),
        np.where(np.isfinite(dref), dref, -1.0), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name,make", GRAPHS)
def test_distributed_engine_parity(name, make):
    """The shard_map engines (v1 replicated, v2 sharded, v3 compacted)
    dispatch through the same relax primitives and must match the
    single-device engine exactly — dist, parent and every metric counter.
    (Multi-shard parity runs in test_distributed_sssp's 8-device
    subprocess; here the mesh is the in-process single device.)"""
    g = make()
    src = int(np.argmax(g.deg))
    ref = _asnp(sssp(g.to_device(), src, backend="segment_min"))
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    for version in ["v1", "v2", "v3"]:
        out = sssp_distributed(sg, src, mesh, ("graph",), version=version)
        dist, parent, metrics = _asnp(out)
        got = (dist[:g.n], parent[:g.n], metrics)
        _assert_same(ref, got, f"{name}: segment_min vs {version}")


@pytest.mark.parametrize("name,make", GRAPHS)
def test_distributed_blocked_backend_parity(name, make):
    """backend="blocked" on the distributed engines: per-shard
    slice_for_shard slabs relax through the tile-indexed bucket path and
    must match the single-device engine bitwise — dist, parent, and all
    logical counters — while reporting real tile metrics.  (Multi-shard
    blocked parity runs in test_distributed_sssp's 8-device subprocess.)"""
    g = make()
    src = int(np.argmax(g.deg))
    ref = _asnp(sssp(g.to_device(), src, backend="segment_min"))
    mesh = jax.make_mesh((1,), ("graph",))
    sg = shard_graph(g, 1)
    blocked = shard_blocked(sg, block_v=256, tile_e=256)
    for version in ["v1", "v2", "v3"]:
        out = sssp_distributed(sg, src, mesh, ("graph",), version=version,
                               backend="blocked", blocked=blocked)
        dist, parent, metrics = _asnp(out)
        got = (dist[:g.n], parent[:g.n], metrics)
        _assert_same(ref, got, f"{name}: segment_min vs {version}/blocked")
        assert 0 < int(metrics.n_tiles_scanned) \
            < int(metrics.n_tiles_dense)


def test_distributed_blocked_rejects_bad_args():
    g = road_grid(10, seed=1)
    sg = shard_graph(g, 1)
    mesh = jax.make_mesh((1,), ("graph",))
    bl = shard_blocked(sg, block_v=64, tile_e=64)
    with pytest.raises(ValueError, match="unknown relax backend"):
        sssp_distributed(sg, 0, mesh, ("graph",), backend="nope")
    with pytest.raises(ValueError, match="segment_min"):
        sssp_distributed(sg, 0, mesh, ("graph",), backend="segment_min",
                         blocked=bl)
    # a 2-shard layout against a 1-shard graph is a shard-count mismatch
    bl2 = shard_blocked(g, 2, block_v=64, tile_e=64)
    with pytest.raises(ValueError, match="shards"):
        sssp_distributed(sg, 0, mesh, ("graph",), backend="blocked",
                         blocked=bl2)


@pytest.mark.parametrize("backend,opts", [
    ("segment_min", {}),
    ("blocked_pallas", {"block_v": 256, "tile_e": 256}),
])
def test_sssp_batch_matches_per_source_loop(backend, opts):
    g = kronecker(10, 8, seed=21)
    dg = g.to_device()
    rng = np.random.default_rng(0)
    srcs = rng.choice(np.where(g.deg > 0)[0], 5, replace=False)
    D, P, M = sssp_batch(dg, srcs, backend=backend, **opts)
    D, P = np.asarray(D), np.asarray(P)
    M = jax.tree.map(np.asarray, M)
    for i, s in enumerate(srcs):
        one = _asnp(sssp(dg, int(s), backend=backend, **opts))
        batched = (D[i], P[i], jax.tree.map(lambda x: x[i], M))
        _assert_same(one, batched, f"source {int(s)} (slot {i})")
