"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (benchmarks/artifacts/dryrun/...) and derives

    compute term    = HLO_FLOPs_per_device / peak_FLOPs        (s)
    memory term     = HLO_bytes_per_device / HBM_bw            (s)
    collective term = collective_bytes_per_device / link_bw    (s)

The SPMD HLO module is the per-device program, so cost_analysis() numbers
are already per chip.  MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode)
with N = active params for MoE; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/redundancy waste.  Usage:

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

# TPU v5e-like hardware constants (assignment-specified)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def model_flops_per_device(art: dict) -> float | None:
    meta = art.get("meta", {})
    n_dev = 1
    for v in art.get("mesh_shape", {}).values():
        n_dev *= v
    tokens = meta.get("tokens")
    n_active = meta.get("active_params") or meta.get("params")
    if tokens is None or n_active is None:
        return None
    shape = art.get("shape", "")
    if shape.startswith("train"):
        return 6.0 * n_active * tokens / n_dev
    # prefill/decode/serve: forward only
    return 2.0 * n_active * tokens / n_dev


def analyze(art: dict) -> dict:
    cost = art.get("cost", {})
    coll = art.get("collectives", {})
    # cost_analysis (and the HLO text) count scan/while bodies ONCE; the
    # layer stack / microbatch loop / triplet chunks are scans, so scale by
    # the static trip product recorded at cell-build time.  This slightly
    # overcounts the once-per-step tail (embedding, optimizer) — noted in
    # EXPERIMENTS.md §Methodology.
    mult = max(int(art.get("meta", {}).get("scan_mult", 1)), 1)
    flops = cost.get("flops", 0.0) * mult
    byts = cost.get("bytes accessed", 0.0) * mult
    cbytes = float(coll.get("total", 0)) * mult
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": cbytes / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    bound_s = terms[dom]
    mf = model_flops_per_device(art)
    out = {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": (mf / flops) if (mf and flops) else None,
        "roofline_fraction": (terms["compute_s"] / bound_s) if bound_s else None,
        "mem_temp_gb": art.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "mem_args_gb": art.get("arg_bytes_per_device", 0) / 1e9,
        "collect_ring_gb": coll.get("ring_bytes", 0) * (
            max(int(art.get("meta", {}).get("scan_mult", 1)), 1)) / 1e9,
        "n_while": art.get("n_while_loops", 0),
        "scan_mult": max(int(art.get("meta", {}).get("scan_mult", 1)), 1),
    }
    return out


def load_all(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            rows.append(analyze(art))
    return rows


def to_markdown(rows):
    hdr = ("| arch | shape | compute(ms) | memory(ms) | collective(ms) | "
           "bound | MODEL/HLO | peak-frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        rf = f"{r['roofline_fraction']:.2f}" if r["roofline_fraction"] else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {ur} | {rf} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(args.mesh)
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r['arch']}/{r['shape']}: comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms -> {r['dominant']} "
                  f"(useful={r['useful_ratio'] or float('nan'):.2f})"
                  if r['useful_ratio'] else
                  f"{r['arch']}/{r['shape']}: comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms -> {r['dominant']}")
    out = args.json_out or os.path.join(
        os.path.dirname(__file__), "artifacts", f"roofline_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows -> {out}")


if __name__ == "__main__":
    main()
