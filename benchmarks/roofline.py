"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (benchmarks/artifacts/dryrun/...) and derives

    compute term    = HLO_FLOPs_per_device / peak_FLOPs        (s)
    memory term     = HLO_bytes_per_device / HBM_bw            (s)
    collective term = collective_bytes_per_device / link_bw    (s)

The SPMD HLO module is the per-device program, so cost_analysis() numbers
are already per chip.  MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode)
with N = active params for MoE; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/redundancy waste.  Usage:

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

# TPU v5e-like hardware constants (assignment-specified)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def fused_relax_roofline(scale: int = 10, deg: int = 8,
                         fused_rounds: int = 4, block_v: int = 256,
                         tile_e: int = 256, reps: int = 3) -> dict:
    """Roofline terms for the fused relaxation megakernel (measured).

    Unlike the dry-run artifacts above, this drives the actual
    ``edge_relax_fused`` megakernel (interpret mode on CPU) through a
    whole blocked-backend solve and derives per-invocation traffic from
    the kernel's own counters — the same numbers the in-kernel metrics
    fold produces, so nothing is recomputed host-side:

      edge bytes  = n_tiles_scanned * tile_e * 16   (src/dst/w reads +
                    the dist gather, 4 B per edge slot)
      state bytes = n_rounds * n_out * 21           (dist/parent
                    read+write + frontier read+write + deg read)
      FLOPs       = 2 * nTrav + 2 * n_tiles_scanned * tile_e
                    (add + window compare on in-window edges, plus the
                    scheduled compare-plane min per edge slot)

    ``achieved_*`` divides those totals by measured wall time;
    ``peak_frac_*`` compares against the v5e-like constants at the top
    of this module (tiny on a CPU interpreter — the point is the
    instrumentation, which carries unchanged to a real TPU run).
    ``rounds_per_invocation`` is the fusion win itself (1.0 ≡ unfused).
    """
    import time

    import jax
    import numpy as np

    from repro.core.sssp import sssp
    from repro.data.generators import kronecker

    g = kronecker(scale, deg, seed=2)
    dg = g.to_device()
    source = int(np.argmax(np.asarray(g.deg)))

    def solve():
        d, p, m = sssp(dg, source, backend="blocked_pallas",
                       fused_rounds=fused_rounds, block_v=block_v,
                       tile_e=tile_e)
        jax.block_until_ready(d)
        return m

    m = solve()                                   # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        m = solve()
    time_s = (time.perf_counter() - t0) / reps

    inv = max(float(m.n_invocations), 1.0)
    rounds = float(m.n_rounds)
    tiles = float(m.n_tiles_scanned)
    n_out = -(-g.n // block_v) * block_v
    edge_bytes = tiles * tile_e * 16.0
    state_bytes = rounds * n_out * 21.0
    byts = edge_bytes + state_bytes
    flops = 2.0 * float(m.n_trav) + 2.0 * tiles * tile_e
    return {
        "arch": "edge_relax_fused", "shape": f"kron{scale}x{deg}",
        "mesh": "single",
        "fused_rounds": fused_rounds,
        "time_s": time_s,
        "time_s_per_invocation": time_s / inv,
        "rounds_per_invocation": rounds / inv,
        "invocations_per_solve": inv,
        "bytes_per_invocation": byts / inv,
        "flops_per_invocation": flops / inv,
        "achieved_bw": byts / time_s,
        "achieved_flops": flops / time_s,
        "peak_frac_bw": byts / time_s / HBM_BW,
        "peak_frac_flops": flops / time_s / PEAK_FLOPS,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": 0.0,
        "useful_ratio": None,
        "roofline_fraction": ((flops / PEAK_FLOPS) /
                              max(flops / PEAK_FLOPS, byts / HBM_BW)),
        "dominant": ("memory" if byts / HBM_BW > flops / PEAK_FLOPS
                     else "compute"),
    }


def model_flops_per_device(art: dict) -> float | None:
    meta = art.get("meta", {})
    n_dev = 1
    for v in art.get("mesh_shape", {}).values():
        n_dev *= v
    tokens = meta.get("tokens")
    n_active = meta.get("active_params") or meta.get("params")
    if tokens is None or n_active is None:
        return None
    shape = art.get("shape", "")
    if shape.startswith("train"):
        return 6.0 * n_active * tokens / n_dev
    # prefill/decode/serve: forward only
    return 2.0 * n_active * tokens / n_dev


def analyze(art: dict) -> dict:
    cost = art.get("cost", {})
    coll = art.get("collectives", {})
    # cost_analysis (and the HLO text) count scan/while bodies ONCE; the
    # layer stack / microbatch loop / triplet chunks are scans, so scale by
    # the static trip product recorded at cell-build time.  This slightly
    # overcounts the once-per-step tail (embedding, optimizer) — noted in
    # EXPERIMENTS.md §Methodology.
    mult = max(int(art.get("meta", {}).get("scan_mult", 1)), 1)
    flops = cost.get("flops", 0.0) * mult
    byts = cost.get("bytes accessed", 0.0) * mult
    cbytes = float(coll.get("total", 0)) * mult
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": cbytes / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    bound_s = terms[dom]
    mf = model_flops_per_device(art)
    out = {
        "arch": art["arch"], "shape": art["shape"], "mesh": art["mesh"],
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": (mf / flops) if (mf and flops) else None,
        "roofline_fraction": (terms["compute_s"] / bound_s) if bound_s else None,
        "mem_temp_gb": art.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "mem_args_gb": art.get("arg_bytes_per_device", 0) / 1e9,
        "collect_ring_gb": coll.get("ring_bytes", 0) * (
            max(int(art.get("meta", {}).get("scan_mult", 1)), 1)) / 1e9,
        "n_while": art.get("n_while_loops", 0),
        "scan_mult": max(int(art.get("meta", {}).get("scan_mult", 1)), 1),
    }
    return out


def load_all(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            rows.append(analyze(art))
    return rows


def to_markdown(rows):
    hdr = ("| arch | shape | compute(ms) | memory(ms) | collective(ms) | "
           "bound | MODEL/HLO | peak-frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        rf = f"{r['roofline_fraction']:.2f}" if r["roofline_fraction"] else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {ur} | {rf} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--fused", action="store_true",
                    help="also measure the fused relaxation megakernel "
                         "(runs a real solve; needs PYTHONPATH=src)")
    ap.add_argument("--fused-scale", type=int, default=10)
    args = ap.parse_args()
    rows = load_all(args.mesh)
    if args.fused:
        r = fused_relax_roofline(scale=args.fused_scale)
        rows.append(r)
        print(f"# fused_relax kron{args.fused_scale}: "
              f"{r['rounds_per_invocation']:.2f} rounds/invocation, "
              f"{r['bytes_per_invocation']:.3g} B + "
              f"{r['flops_per_invocation']:.3g} FLOP per invocation, "
              f"achieved {r['achieved_bw']:.3g} B/s "
              f"({r['peak_frac_bw']:.2e} of HBM peak) / "
              f"{r['achieved_flops']:.3g} FLOP/s "
              f"({r['peak_frac_flops']:.2e} of peak) -> {r['dominant']}")
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r['arch']}/{r['shape']}: comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms -> {r['dominant']} "
                  f"(useful={r['useful_ratio'] or float('nan'):.2f})"
                  if r['useful_ratio'] else
                  f"{r['arch']}/{r['shape']}: comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms -> {r['dominant']}")
    out = args.json_out or os.path.join(
        os.path.dirname(__file__), "artifacts", f"roofline_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows -> {out}")


if __name__ == "__main__":
    main()
