"""Shared benchmark machinery: the scaled graph suite + metric collection.

The paper evaluates on 9 benchmark graphs (scale-26 Graph500 + GAPBS) and
64 weight variants.  This container is a single CPU core, so the suite is
scale-reduced (default scale 14, ~16k vertices / ~260k edges) but keeps the
*structure*: four Graph500 Kronecker densities, a Urand analogue, a
Road analogue, and skewed Kron analogues of Web/Twitter/Kron; the variant
graphs remap weights with the paper's Eqs. (7)/(8).  ``--scale`` raises the
size when more time is available.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.api import EngineConfig, SolveSpec, Solver
from repro.core.baselines import bellman_ford, delta_stepping, dijkstra_host
from repro.core.sssp import normalized_metrics
from repro.data.generators import kronecker, road_grid, uniform_random
from repro.data.weights import make_variant


def benchmark_graphs(scale: int = 14):
    """The 9-graph suite (paper Table 1 analogues, scale-reduced)."""
    n = 1 << scale
    side = int(np.sqrt(n))
    return {
        f"gr{scale}_4": lambda: kronecker(scale, 4, seed=1),
        f"gr{scale}_8": lambda: kronecker(scale, 8, seed=2),
        f"gr{scale}_16": lambda: kronecker(scale, 16, seed=3),
        f"gr{scale}_32": lambda: kronecker(scale, 32, seed=4),
        "Road": lambda: road_grid(side, seed=5),
        "Urand": lambda: uniform_random(n, 16 * n, seed=6),
        "Web": lambda: kronecker(scale, 30, seed=7),
        "Twitter": lambda: kronecker(scale, 22, seed=8),
        "Kron": lambda: kronecker(scale, 32, seed=9),
    }


def variant_graphs(scale: int = 13, full: bool = False):
    """Weight-variant suite (paper §4.2): power/pivot remaps."""
    base = kronecker(scale, 8, seed=21)
    powers = [1, 2, 3, 4, 6, 8, 10] if full else [1, 2, 4, 10]
    pivots = ([0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] if full
              else [0.1, 0.5, 0.9])
    out = {}
    for p in powers:
        out[f"gr{scale}_8_pow{p}"] = lambda p=p: make_variant(base, power=p)
    for pv in pivots:
        out[f"gr{scale}_8_piv{pv}"] = lambda pv=pv: make_variant(base,
                                                                 pivot=pv)
    return out


def pick_sources(g, n_sources: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    nz = np.where(g.deg > 0)[0]
    return rng.choice(nz, min(n_sources, nz.size), replace=False)


def run_eic(g, sources, alpha=3.0, beta=0.9, backend="segment_min",
            fused_rounds=0):
    """Average EIC metrics + wall time over sources (compile excluded).

    ``fused_rounds`` (blocked backend only) groups that many relaxation
    rounds into one megakernel invocation — same logical metrics, fewer
    ``n_invocations``.
    """
    solver = Solver.open(g, EngineConfig(backend=backend, alpha=alpha,
                                         beta=beta,
                                         fused_rounds=fused_rounds))
    # warm-up / compile
    solver.solve(SolveSpec.tree(int(sources[0]))).block_until_ready()
    t_total, mets = 0.0, []
    for s in sources:
        t0 = time.perf_counter()
        res = solver.solve(SolveSpec.tree(int(s))).block_until_ready()
        t_total += time.perf_counter() - t0
        mets.append(res.normalized())
    avg = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    avg["time_s"] = t_total / len(sources)
    return avg


def run_eic_batch(g, sources, alpha=3.0, beta=0.9, backend="segment_min"):
    """One fused multi-source batch (batched SolveSpec); per-source wall
    time."""
    solver = Solver.open(g, EngineConfig(backend=backend, alpha=alpha,
                                         beta=beta))
    spec = SolveSpec.tree([int(s) for s in sources])
    solver.solve(spec).block_until_ready()       # warm-up / compile
    t0 = time.perf_counter()
    res = solver.solve(spec).block_until_ready()
    elapsed = time.perf_counter() - t0
    mets = [res.normalized(slot=i) for i in range(spec.n_slots)]
    avg = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    avg["time_s"] = elapsed / spec.n_slots
    avg["batch"] = spec.n_slots
    return avg


def run_p2p_vs_tree(g, pairs, alpha=3.0, beta=0.9, backend="segment_min"):
    """Early-exit head-to-head: p2p queries vs full trees on the same
    (source, target) pairs — raw rounds (nSync) saved and bitwise target
    distance parity (the serving acceptance check)."""
    solver = Solver.open(g, EngineConfig(backend=backend, alpha=alpha,
                                         beta=beta))
    s0, t0 = pairs[0]
    solver.solve(SolveSpec.tree(int(s0))).block_until_ready()
    solver.solve(SolveSpec.p2p(int(s0), int(t0))).block_until_ready()
    rounds_tree, rounds_p2p = [], []
    t_tree = t_p2p = 0.0
    bitwise_equal = True
    for s, t in pairs:
        t0_ = time.perf_counter()
        full = solver.solve(SolveSpec.tree(int(s))).block_until_ready()
        t_tree += time.perf_counter() - t0_
        t0_ = time.perf_counter()
        p2p = solver.solve(SolveSpec.p2p(int(s), int(t))).block_until_ready()
        t_p2p += time.perf_counter() - t0_
        bitwise_equal &= (np.asarray(p2p.dist)[t].tobytes()
                          == np.asarray(full.dist)[t].tobytes())
        rounds_tree.append(int(full.metrics.n_rounds))
        rounds_p2p.append(int(p2p.metrics.n_rounds))
    n = len(pairs)
    return {
        "rounds_tree": float(np.mean(rounds_tree)),
        "rounds_p2p": float(np.mean(rounds_p2p)),
        "round_ratio": float(np.sum(rounds_p2p) / max(np.sum(rounds_tree), 1)),
        "bitwise_equal": bool(bitwise_equal),
        "time_s_tree": t_tree / n,
        "time_s": t_p2p / n,
    }


def run_p2p_alt(g, pairs, *, n_landmarks=8, strategy="farthest",
                backend="segment_min", modes=("tree", "p2p", "alt",
                                              "bidi")):
    """Goal-directed p2p ladder on one graph: full tree -> early-exit
    p2p -> p2p + ALT pruning -> bidirectional ALT, same (source, target)
    pairs throughout.

    Every rung must return the bitwise-identical d(s, t) and parent
    chain (the ALT exactness contract); the ladder reports the work
    counters that motivate each rung — rounds (nSync), relaxations, and
    the ALT rungs' pruned-candidate counts — plus the landmark build
    cost (amortized across every p2p query the graph ever serves).
    """
    from repro.core.landmarks import build_landmarks
    from repro.serve.queries import reconstruct_path

    t0 = time.perf_counter()
    dg = g.to_device()
    lm = build_landmarks(dg, n_landmarks=n_landmarks, strategy=strategy)
    jax.block_until_ready(lm.D)
    build_s = time.perf_counter() - t0

    solvers = {
        "tree": Solver.open(g, EngineConfig(backend=backend)),
        "p2p": Solver.open(g, EngineConfig(backend=backend)),
        "alt": Solver.open(g, EngineConfig(
            backend=backend, use_alt=True, n_landmarks=n_landmarks,
            landmark_strategy=strategy)),
        "bidi": Solver.open(g, EngineConfig(
            backend=backend, use_alt=True, n_landmarks=n_landmarks,
            landmark_strategy=strategy, p2p_mode="bidirectional")),
    }
    out = {"build_s": build_s, "n_landmarks": n_landmarks,
           "bitwise_equal": True}
    ref = {}
    for mode in modes:
        solver = solvers[mode]
        spec0 = (SolveSpec.tree(int(pairs[0][0])) if mode == "tree" else
                 SolveSpec.p2p(int(pairs[0][0]), int(pairs[0][1])))
        solver.solve(spec0).block_until_ready()     # warm-up / compile
        rounds, relax, pruned, t_total = [], [], [], 0.0
        for s, t in pairs:
            spec = (SolveSpec.tree(int(s)) if mode == "tree" else
                    SolveSpec.p2p(int(s), int(t)))
            t0 = time.perf_counter()
            res = solver.solve(spec).block_until_ready()
            t_total += time.perf_counter() - t0
            rounds.append(int(res.metrics.n_rounds))
            relax.append(int(res.metrics.n_relax))
            pruned.append(int(res.metrics.n_pruned))
            key = (int(s), int(t))
            got = (np.asarray(res.dist)[int(t)].tobytes(),
                   reconstruct_path(np.asarray(res.parent), int(s),
                                    int(t)))
            if key in ref:
                out["bitwise_equal"] &= got == ref[key]
            else:
                ref[key] = got
        out[f"rounds_{mode}"] = float(np.mean(rounds))
        out[f"relax_{mode}"] = float(np.mean(relax))
        out[f"pruned_{mode}"] = float(np.mean(pruned))
        out[f"time_s_{mode}"] = t_total / len(pairs)
    out["time_s"] = out.get("time_s_alt", out["time_s_p2p"])
    for mode in modes:
        if mode == "p2p":
            continue
        out[f"relax_ratio_{mode}"] = (out["relax_p2p"] /
                                      max(out[f"relax_{mode}"], 1.0))
        out[f"round_ratio_{mode}"] = (out["rounds_p2p"] /
                                      max(out[f"rounds_{mode}"], 1.0))
    return out


def run_serving_traffic(graphs, traffic, *, devices=None, max_batch=8,
                        capacity=None, backend=None, warm_kinds=None,
                        max_pending=None, open_loop=False,
                        jsonl_path=None, jsonl_meta=None):
    """Serve a traffic list through a :class:`QueryRouter` and measure it.

    ``devices`` selects the serving plane width (default: every local
    device; pass ``jax.devices()[:1]`` for the 1-device scaling
    baseline).  Warmup (engine builds + per-(graph, kind, batch) jit
    compiles) runs before the timed region and is reported separately —
    the timed qps is the steady-state serving rate.

    ``open_loop`` paces each submission to its ``TrafficItem.arrival_s``
    (generate the traffic with ``make_traffic(..., rate_qps=...)``) so
    the measured p50/p99 are *tail latency at that offered load* instead
    of closed-loop drain behaviour; submissions shed by a bounded queue
    (``QueueFull``) are counted, not retried, as an open-loop client
    would.  The result gains ``offered_qps`` and ``shed``.

    ``jsonl_path`` appends one line to that JSONL stream: the serving
    plane's full metrics snapshot with the run's shed/latency summary
    (and any ``jsonl_meta``) as the line's meta — the same exportable
    telemetry format the observability plane and the tuner write.
    """
    from repro.serve.registry import GraphRegistry
    from repro.serve.router import QueryRouter
    from repro.serve.scheduler import QueueFull

    n_dev = len(devices) if devices is not None else len(jax.devices())
    if capacity is None:
        # room for one engine per (graph, device) replica
        capacity = (len(graphs) + 1) * max(n_dev, 1)
    cfg = EngineConfig(backend=backend or "segment_min",
                       max_batch=max_batch, max_pending=max_pending)
    registry = GraphRegistry(capacity=capacity, config=cfg)
    for gid, g in graphs.items():
        registry.register(gid, g)
    router = QueryRouter(registry, devices=devices, config=cfg)
    if warm_kinds is None:
        warm_kinds = tuple(dict.fromkeys(it.query.kind for it in traffic))
    # capacity planning: replicate by the traffic's per-graph share (a
    # real deployment would use yesterday's traffic; the warmup below
    # then pre-pays every replica's build + compiles)
    weights = {}
    for it in traffic:
        weights[it.query.gid] = weights.get(it.query.gid, 0) + 1
    router.plan_placement(weights)
    t0 = time.perf_counter()
    warm_rows = router.warmup(kinds=warm_kinds, batch_sizes=(max_batch,))
    warmup_s = time.perf_counter() - t0
    # snapshot so the reported hit rate covers only the serving phase
    # (warmup's one miss+build per replica shares the same stats object)
    pre_hits, pre_misses = registry.stats.hits, registry.stats.misses
    router.start()
    shed = 0
    t0 = time.perf_counter()
    futs = []
    for it in traffic:
        if open_loop:
            delay = t0 + it.arrival_s - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
        try:
            futs.append((it, router.submit(it.query, priority=it.priority,
                                           deadline_s=it.deadline_s)))
        except QueueFull:
            shed += 1           # open-loop clients drop, don't retry
    results = [(it, f.result(timeout=1200)) for it, f in futs]
    elapsed = time.perf_counter() - t0
    router.stop()
    lats = np.array([r.latency_s for _, r in results])
    stats = router.stats()
    d_hits = registry.stats.hits - pre_hits
    d_misses = registry.stats.misses - pre_misses
    out = {
        "qps": len(results) / elapsed,
        "elapsed_s": elapsed,
        "time_s": float(lats.mean()),
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_ms": float(np.percentile(lats, 99) * 1e3),
        "occupancy": stats["occupancy"],
        "warmup_s": warmup_s,
        "n_devices": n_dev,
        "serving_hit_rate": (d_hits / (d_hits + d_misses)
                             if d_hits + d_misses else 1.0),
        "stats": stats,
        "results": results,
        "warm_rows": warm_rows,
        "shed": shed,
    }
    if open_loop:
        span = max(traffic[-1].arrival_s, 1e-9) if traffic else 1e-9
        out["offered_qps"] = len(traffic) / span
    if jsonl_path:
        from repro.obs.export import write_jsonl_snapshot
        meta = dict(jsonl_meta or {})
        meta.update(qps=out["qps"], p50_ms=out["p50_ms"],
                    p99_ms=out["p99_ms"], shed=shed,
                    occupancy=out["occupancy"], n_devices=n_dev)
        if open_loop:
            meta["offered_qps"] = out["offered_qps"]
        write_jsonl_snapshot(router.metrics.snapshot(), jsonl_path,
                             meta=meta)
    return out


def check_p2p_parity(graphs, results, sample=12):
    """Bitwise-compare served p2p distances against the single-device
    engine (the serving acceptance check).  Returns ``(ok, checked)`` so
    'no p2p queries in the sample' is distinguishable from a mismatch."""
    checked = 0
    ok = True
    solvers = {}
    for item, res in results:
        q = item.query
        if q.kind != "p2p":
            continue
        if q.gid not in solvers:
            solvers[q.gid] = Solver.open(graphs[q.gid])
        ref = solvers[q.gid].solve(SolveSpec.p2p(q.source, q.target))
        ok &= (np.float32(res.distance).tobytes()
               == np.asarray(ref.dist)[q.target].tobytes())
        checked += 1
        if checked >= sample:
            break
    return ok, checked


def run_distributed(g, sources, alpha=3.0, beta=0.9, version="v2",
                    backend="segment_min", **blocked_opts):
    """Sharded-tier facade over every available local device.

    ``backend="blocked"`` makes the solver pre-build the per-shard
    blocked layout once (``blocked_opts`` size it) and relax through it.
    """
    solver = Solver.open(g, EngineConfig(
        tier="sharded", shard_backend=backend, shard_version=version,
        alpha=alpha, beta=beta, **blocked_opts))
    solver.solve(SolveSpec.tree(int(sources[0]))).block_until_ready()
    t_total, mets = 0.0, []
    for s in sources:
        t0 = time.perf_counter()
        res = solver.solve(SolveSpec.tree(int(s))).block_until_ready()
        t_total += time.perf_counter() - t0
        mets.append(res.normalized())
    avg = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    avg["time_s"] = t_total / len(sources)
    avg["n_devices"] = solver.resolved.n_shards
    return avg


def make_edit_batch(g, frac: float, seed: int = 0):
    """A reproducible mixed edit batch touching ``frac`` of the graph's
    undirected edges: one third weight increases (x1.3), one third
    decreases (x0.7), one third removals — the streaming-update
    benchmark's workload shape.  Picks are deduplicated on the
    undirected key so symmetrized duplicates never collide."""
    from repro.delta import EdgeDelta

    src = np.asarray(g.src, np.int64)
    dst = np.asarray(g.dst, np.int64)
    w = np.asarray(g.w, np.float32)
    und = np.nonzero(src < dst)[0]
    key = src[und] * int(g.n) + dst[und]
    _, first = np.unique(key, return_index=True)
    und = und[np.sort(first)]
    n_edits = max(int(frac * und.size), 1)
    rng = np.random.default_rng(seed)
    pick = rng.choice(und, size=min(n_edits, und.size), replace=False)
    n3 = max(pick.size // 3, 1)
    rw = [(int(src[e]), int(dst[e]), float(np.float32(w[e]) * 1.3))
          for e in pick[:n3]]
    rw += [(int(src[e]), int(dst[e]), float(np.float32(w[e]) * 0.7))
           for e in pick[n3:2 * n3]]
    rem = [(int(src[e]), int(dst[e])) for e in pick[2 * n3:]]
    return EdgeDelta(remove=rem, reweight=rw)


def run_delta_repair(g, fracs=(0.01, 0.0025), seed=0):
    """Streaming-update benchmark on one graph: per edit-batch fraction,
    patch the graph + blocked layout in place and repair the previous
    solve, against a from-scratch recompute on the patched graph.

    Reports, per fraction: patch/repair/recompute wall times, the
    relaxation counts of repair vs recompute (``relax_reduction`` is the
    headline — repaired work / full work), the invalidated-vertex and
    reseeded-frontier sizes, whether the patched blocked layout was
    produced by the in-place fast path, and the bitwise dist+parent
    parity verdict (repair must be indistinguishable from recompute).
    """
    from repro.core.sssp import prepare_layout, sssp
    from repro.delta import patch_blocked_with, patch_host, repair

    src_v = int(np.argmax(np.asarray(g.deg)))
    dg = g.to_device()
    d0, p0, _ = sssp(dg, src_v)
    jax.block_until_ready(d0)
    lay0 = prepare_layout(dg, "blocked")
    out = []
    for frac in fracs:
        delta = make_edit_batch(g, frac, seed=seed)
        t0 = time.perf_counter()
        new_host, applied = patch_host(g, delta)
        t_host = time.perf_counter() - t0
        t0 = time.perf_counter()
        patch_blocked_with(lay0, g, new_host, applied)
        t_layout = time.perf_counter() - t0
        g_new = new_host.to_device()
        # compile outside the timed region (first trace on new shapes)
        d_f, p_f, m_f = sssp(g_new, src_v)
        jax.block_until_ready(d_f)
        t0 = time.perf_counter()
        d_f, p_f, m_f = sssp(g_new, src_v)
        jax.block_until_ready(d_f)
        t_full = time.perf_counter() - t0
        d_r, p_r, m_r, st = repair(g_new, new_host, d0, p0, applied)
        jax.block_until_ready(d_r)
        t0 = time.perf_counter()
        d_r, p_r, m_r, st = repair(g_new, new_host, d0, p0, applied)
        jax.block_until_ready(d_r)
        t_repair = time.perf_counter() - t0
        bitwise = (np.asarray(d_r).tobytes() == np.asarray(d_f).tobytes()
                   and np.asarray(p_r).tobytes()
                   == np.asarray(p_f).tobytes())
        out.append({
            "frac": frac,
            "n_edits": applied.n_edits // 2,
            "n_invalid": int(st.n_invalid),
            "n_seeds": int(st.n_seeds),
            "fast_path": bool(st.fast_path),
            "patch_host_s": t_host,
            "patch_layout_s": t_layout,
            "time_s": t_repair,
            "time_s_full": t_full,
            "relax_repair": int(m_r.n_relax),
            "relax_full": int(m_f.n_relax),
            "relax_reduction": int(m_f.n_relax) / max(int(m_r.n_relax), 1),
            "rounds_repair": int(m_r.n_rounds),
            "rounds_full": int(m_f.n_rounds),
            "bitwise_equal": bool(bitwise),
        })
    return out


def run_baseline(kind, g, sources, delta=None):
    dg = g.to_device()
    fn = {
        "bf": lambda s: bellman_ford(dg, int(s)),
        "delta": lambda s: delta_stepping(dg, int(s), delta),
    }[kind]
    d0, _, _ = fn(sources[0])
    jax.block_until_ready(d0)
    t_total, mets = 0.0, []
    for s in sources:
        t0 = time.perf_counter()
        dist, parent, metrics = fn(s)
        jax.block_until_ready(dist)
        t_total += time.perf_counter() - t0
        mets.append(normalized_metrics(g.deg, np.asarray(dist),
                                       jax.tree.map(np.asarray, metrics)))
    avg = {k: float(np.mean([m[k] for m in mets])) for k in mets[0]}
    avg["time_s"] = t_total / len(sources)
    return avg


def run_dijkstra_host(g, sources):
    t0 = time.perf_counter()
    for s in sources:
        dijkstra_host(g, int(s))
    return {"time_s": (time.perf_counter() - t0) / len(sources)}


def dd_skewness(g):
    from repro.core import stats
    import jax.numpy as jnp
    hd0 = float(stats.high_d(jnp.zeros(g.n), jnp.asarray(g.deg),
                             jnp.float32(0.0)))
    return float(np.log2(max(g.deg.max(), 1) / max(hd0, 1)))
