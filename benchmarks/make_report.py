"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.make_report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import analyze

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def dryrun_table(mesh: str) -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        a = json.load(open(path))
        if not a.get("ok"):
            rows.append(f"| {a['arch']} | {a['shape']} | FAILED | | | | |")
            continue
        mem = a.get("memory", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        args = a.get("arg_bytes_per_device", 0) / 1e9
        coll = a.get("collectives", {})
        fits = "yes" if (temp + args) <= 16.0 else "NO"
        rows.append(
            f"| {a['arch']} | {a['shape']} | "
            f"{a['cost'].get('flops', 0):.2e} | "
            f"{a['cost'].get('bytes accessed', 0):.2e} | "
            f"{coll.get('total', 0)/1e9:.2f} | "
            f"{temp:.2f}+{args:.2f} | {fits} |")
    hdr = ("| arch | shape | HLO FLOPs/dev | HLO bytes/dev | coll GB/dev | "
           "mem temp+args GB | fits 16GB |")
    return "\n".join([hdr, "|" + "---|" * 7] + rows)


def roofline_table(mesh: str) -> str:
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "bound | MODEL/HLO | note |",
             "|" + "---|" * 8]
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        a = json.load(open(path))
        if not a.get("ok"):
            continue
        r = analyze(a)
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        note = {
            "compute": "MXU-bound: fuse/relayout wins only",
            "memory": "HBM-bound: raise arithmetic intensity (fusion, bf16)",
            "collective": "ICI-bound: reshard/overlap collectives",
        }[r["dominant"]]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {ur} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    if args.section in ("dryrun", "both"):
        print(f"### Dry-run ({args.mesh} mesh)\n")
        print(dryrun_table(args.mesh))
        print()
    if args.section in ("roofline", "both"):
        print(f"### Roofline ({args.mesh} mesh)\n")
        print(roofline_table(args.mesh))


if __name__ == "__main__":
    main()
