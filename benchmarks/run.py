"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 14] [--sources 4]
        [--full-variants] [--sections fig4,fig5,fig6,table3]

Prints ``name,us_per_call,derived`` CSV rows (one per graph x metric) and
writes benchmarks/artifacts/paper_metrics.json for EXPERIMENTS.md.

Sections:
  fig4   — nFrontier / nSync on the benchmark suite (paper Fig. 4a/4b)
           + the weight-variant suite (Fig. 4c/4d)
  fig5   — nTrav vs |E|/|V| and DD_skewness (paper Fig. 5)
  fig6   — wall time vs edge traversals (paper Fig. 6)
  table3 — EIC vs Bellman-Ford / Δ-stepping / host Dijkstra (paper
           Table 3 / Fig. 7): times, speedups, nFrontier, nSync
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def emit(rows, name, time_s, **derived):
    d = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                 for k, v in derived.items())
    print(f"{name},{time_s * 1e6:.1f},{d}")
    rows.append({"name": name, "us_per_call": time_s * 1e6, **derived})


def fig4_fig5_fig6(rows, scale, n_sources, full_variants):
    print("# fig4/fig5/fig6: EIC metrics on benchmark + variant graphs")
    suites = [("bench", common.benchmark_graphs(scale))]
    suites.append(("variant", common.variant_graphs(max(scale - 1, 10),
                                                    full=full_variants)))
    for suite, graphs in suites:
        for name, make in graphs.items():
            g = make()
            srcs = common.pick_sources(g, n_sources)
            m = common.run_eic(g, srcs)
            e_over_v = g.m / 2 / g.n
            emit(rows, f"eic/{suite}/{name}", m["time_s"],
                 nFrontier=m["nFrontier"], nSync=m["nSync"],
                 nTrav=m["nTrav"], nTrav_push=m["nTrav_push"],
                 nTrav_pull=m["nTrav_pull"], steps=m["n_steps"],
                 E_over_V=e_over_v, dd_skew=common.dd_skewness(g),
                 trav_reduction=e_over_v - m["nTrav"])


def table3(rows, scale, n_sources):
    print("# table3/fig7: comparison vs baselines")
    graphs = common.benchmark_graphs(scale)
    for name in ["Twitter", "Kron", "Web", "Urand", "Road",
                 f"gr{scale}_16"]:
        if name not in graphs:
            continue
        g = graphs[name]()
        srcs = common.pick_sources(g, n_sources)
        eic = common.run_eic(g, srcs)
        bf = common.run_baseline("bf", g, srcs)
        best_delta, best = None, None
        for delta in [0.1 * float(g.max_w), 0.5 * float(g.max_w),
                      float(g.max_w)]:
            d = common.run_baseline("delta", g, srcs, delta=delta)
            if best is None or d["time_s"] < best["time_s"]:
                best, best_delta = d, delta
        dj = common.run_dijkstra_host(g, srcs[:2])
        best_comp = min(bf["time_s"], best["time_s"])
        emit(rows, f"table3/{name}/eic", eic["time_s"],
             nFrontier=eic["nFrontier"], nSync=eic["nSync"],
             nTrav=eic["nTrav"],
             speedup_vs_best=best_comp / eic["time_s"])
        emit(rows, f"table3/{name}/bellman_ford", bf["time_s"],
             nFrontier=bf["nFrontier"], nSync=bf["nSync"],
             nTrav=bf["nTrav"])
        emit(rows, f"table3/{name}/delta_stepping", best["time_s"],
             nFrontier=best["nFrontier"], nSync=best["nSync"],
             nTrav=best["nTrav"], delta=best_delta)
        emit(rows, f"table3/{name}/dijkstra_host", dj["time_s"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--sources", type=int, default=3)
    ap.add_argument("--full-variants", action="store_true")
    ap.add_argument("--sections", default="fig4,table3")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    rows = []
    sections = set(args.sections.split(","))
    print("name,us_per_call,derived")
    if sections & {"fig4", "fig5", "fig6"}:
        fig4_fig5_fig6(rows, args.scale, args.sources, args.full_variants)
    if "table3" in sections:
        table3(rows, args.scale, args.sources)
    with open(os.path.join(ART, "paper_metrics.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to benchmarks/artifacts/paper_metrics.json")


if __name__ == "__main__":
    main()
