"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 14] [--sources 4]
        [--backend segment_min|blocked_pallas] [--batch 4]
        [--full-variants]
        [--sections fig4,fig5,fig6,table3,backends,roofline,serving,p2p,
         delta,tuner]
        [--open-loop]

Prints ``name,us_per_call,derived`` CSV rows (one per graph x metric) and
writes benchmarks/artifacts/paper_metrics.json for EXPERIMENTS.md.

Sections:
  fig4     — nFrontier / nSync on the benchmark suite (paper Fig. 4a/4b)
             + the weight-variant suite (Fig. 4c/4d)
  fig5     — nTrav vs |E|/|V| and DD_skewness (paper Fig. 5)
  fig6     — wall time vs edge traversals (paper Fig. 6)
  table3   — EIC vs Bellman-Ford / Δ-stepping / host Dijkstra (paper
             Table 3 / Fig. 7): times, speedups, nFrontier, nSync
  backends — relaxation-backend head-to-head on the same graphs/sources:
             segment_min vs blocked_pallas (interpret mode on CPU) vs the
             distributed engine with both per-shard backends
             (segment_min / blocked), plus the fused multi-source
             sssp_batch at ``--batch`` sources per call.  Blocked rows
             report tiles_per_round / tile_reduction from the kernel's
             frontier-compaction metrics (the skipped-tile win) and run
             both unfused and ``fused_rounds=4`` (the multi-round
             megakernel), reporting invocations_per_solve /
             invocation_reduction / tile_regression
  roofline — fused-megakernel roofline smoke row: achieved vs peak
             bytes/FLOPs per invocation and rounds-per-invocation,
             measured from a real blocked solve (benchmarks/roofline.py
             hosts the model; ``--fused`` there runs it standalone)
  serving  — the multi-device serving plane under Zipf-skewed
             multi-graph traffic (router -> per-device schedulers ->
             registry tiers; mixed p2p/bounded/knear/tree queries):
             queries/s for the 1-device vs whole-mesh router configs and
             their scaling, p50/p99 latency, occupancy, warmup cost,
             bitwise p2p parity, a sharded-tier (shard_map) serving row,
             plus the p2p early-exit vs full-tree round comparison.
             Run under XLA_FLAGS=--xla_force_host_platform_device_count=8
             for a CPU device mesh.  With ``--open-loop``, submissions
             are paced by the traffic's Poisson ``arrival_s`` at several
             fractions of the measured closed-loop capacity and the
             section reports p50/p99 tail latency vs offered load; each
             load point also appends its shed/latency curve + the
             serving plane's metrics snapshot to
             benchmarks/artifacts/serving_open_loop.jsonl (the same
             JSONL snapshot stream the tuner writes).
  p2p      — goal-directed point-to-point ladder on the benchmark suite
             (incl. Road and the kron analogues): full tree vs early-exit
             p2p vs p2p + ALT landmark pruning vs bidirectional
             meet-in-the-middle, same (source, target) pairs.  Every rung
             is bitwise-exact (same d(s,t) + parent chain); rows report
             rounds / relaxations / pruned candidates per rung, the
             relax/round reduction ratios of the ALT rungs, and the
             one-off landmark build cost.  Committed as
             benchmarks/baselines/BENCH_p2p.json via --json
  delta    — streaming graph updates (repro.delta) on the benchmark
             suite: per edit-batch fraction (1% and 0.25% of undirected
             edges, mixed increase/decrease/remove), in-place
             patch_host + blocked-layout patch timings and incremental
             repair vs from-scratch recompute on the patched graph —
             relaxation counts, the relax_reduction headline, the
             invalidated/reseeded set sizes, and the bitwise dist+parent
             parity verdict (repair must be indistinguishable from a
             recompute).  Committed as
             benchmarks/baselines/BENCH_delta.json via --json; the
             acceptance floor is >= 3x relax_reduction at the 0.25%
             batch on Road and the kron analogue
  tuner    — the per-graph EngineConfig auto-tuner (repro.tune) on three
             graph families: default vs tuned trace objective, the
             reduction, bitwise dist/parent parity of the winner, and
             the evaluation counts.  Winners persist to
             benchmarks/artifacts/tuned.json; the search trajectory
             streams to benchmarks/artifacts/tuner.jsonl.

``--backend`` selects the relaxation backend used by the paper-metric
sections (fig4/5/6, table3); the ``backends`` section always sweeps all
of them head-to-head.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def emit(rows, name, time_s, **derived):
    d = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                 for k, v in derived.items())
    print(f"{name},{time_s * 1e6:.1f},{d}")
    rows.append({"name": name, "us_per_call": time_s * 1e6, **derived})


def fig4_fig5_fig6(rows, scale, n_sources, full_variants, backend):
    print("# fig4/fig5/fig6: EIC metrics on benchmark + variant graphs")
    suites = [("bench", common.benchmark_graphs(scale))]
    suites.append(("variant", common.variant_graphs(max(scale - 1, 10),
                                                    full=full_variants)))
    for suite, graphs in suites:
        for name, make in graphs.items():
            g = make()
            srcs = common.pick_sources(g, n_sources)
            m = common.run_eic(g, srcs, backend=backend)
            e_over_v = g.m / 2 / g.n
            emit(rows, f"eic/{suite}/{name}", m["time_s"],
                 nFrontier=m["nFrontier"], nSync=m["nSync"],
                 nTrav=m["nTrav"], nTrav_push=m["nTrav_push"],
                 nTrav_pull=m["nTrav_pull"], steps=m["n_steps"],
                 E_over_V=e_over_v, dd_skew=common.dd_skewness(g),
                 trav_reduction=e_over_v - m["nTrav"])


def table3(rows, scale, n_sources, backend):
    print("# table3/fig7: comparison vs baselines")
    graphs = common.benchmark_graphs(scale)
    for name in ["Twitter", "Kron", "Web", "Urand", "Road",
                 f"gr{scale}_16"]:
        if name not in graphs:
            continue
        g = graphs[name]()
        srcs = common.pick_sources(g, n_sources)
        eic = common.run_eic(g, srcs, backend=backend)
        bf = common.run_baseline("bf", g, srcs)
        best_delta, best = None, None
        for delta in [0.1 * float(g.max_w), 0.5 * float(g.max_w),
                      float(g.max_w)]:
            d = common.run_baseline("delta", g, srcs, delta=delta)
            if best is None or d["time_s"] < best["time_s"]:
                best, best_delta = d, delta
        dj = common.run_dijkstra_host(g, srcs[:2])
        best_comp = min(bf["time_s"], best["time_s"])
        emit(rows, f"table3/{name}/eic", eic["time_s"],
             nFrontier=eic["nFrontier"], nSync=eic["nSync"],
             nTrav=eic["nTrav"],
             speedup_vs_best=best_comp / eic["time_s"])
        emit(rows, f"table3/{name}/bellman_ford", bf["time_s"],
             nFrontier=bf["nFrontier"], nSync=bf["nSync"],
             nTrav=bf["nTrav"])
        emit(rows, f"table3/{name}/delta_stepping", best["time_s"],
             nFrontier=best["nFrontier"], nSync=best["nSync"],
             nTrav=best["nTrav"], delta=best_delta)
        emit(rows, f"table3/{name}/dijkstra_host", dj["time_s"])


def backends(rows, scale, n_sources, batch):
    """Relaxation-backend head-to-head (see core/relax.py).

    Blocked rows report the kernel's own per-round tile metrics —
    ``tiles_per_round`` (active tiles the compacted schedule ran) and
    ``tile_reduction`` (the dense ``(n_dst_blocks, n_tiles)`` scan cost
    over it) — straight from ``SsspMetrics``, not recomputed host-side.
    """
    print("# backends: segment_min vs blocked_pallas (unfused/fused) vs"
          f" distributed (+ sssp_batch x{batch})")
    graphs = common.benchmark_graphs(scale)
    for name in [f"gr{scale}_8", "Road", "Urand"]:
        if name not in graphs:
            continue
        g = graphs[name]()
        srcs = common.pick_sources(g, max(n_sources, 2))
        base = None
        inv_unfused = tiles_unfused = None
        for be, fr in [("segment_min", 0), ("blocked_pallas", 0),
                       ("blocked_pallas", 4)]:
            m = common.run_eic(g, srcs, backend=be, fused_rounds=fr)
            if base is None:        # `or` would treat a 0.0 timing as unset
                base = m["time_s"]
            extra = {}
            if m["n_tiles_scanned"]:
                rounds = max(m["n_rounds"], 1)
                extra = {
                    "tiles_per_round": m["n_tiles_scanned"] / rounds,
                    "tile_reduction":
                        m["n_tiles_dense"] / max(m["n_tiles_scanned"], 1),
                }
            if m.get("n_invocations"):
                extra["invocations_per_solve"] = m["n_invocations"]
                if fr == 0:
                    inv_unfused = m["n_invocations"]
                    tiles_unfused = m["n_tiles_scanned"]
                elif inv_unfused:
                    # the fused-megakernel acceptance pair: launches drop,
                    # the compacted tile schedule does not grow
                    extra["invocation_reduction"] = (inv_unfused /
                                                     m["n_invocations"])
                    extra["tile_regression"] = (m["n_tiles_scanned"] /
                                                max(tiles_unfused, 1))
            label = f"{be}_fused{fr}" if fr else be
            emit(rows, f"backends/{name}/{label}", m["time_s"],
                 nTrav=m["nTrav"], nSync=m["nSync"],
                 rel_time=m["time_s"] / base, **extra)
        for dbe, fr in [("segment_min", 0), ("blocked", 0), ("blocked", 4)]:
            d = common.run_distributed(g, srcs, version="v2", backend=dbe,
                                       fused_rounds=fr)
            extra = {}
            if d["n_tiles_scanned"]:
                extra = {"tile_reduction": d["n_tiles_dense"] /
                         max(d["n_tiles_scanned"], 1)}
            if d.get("n_invocations"):
                extra["invocations_per_solve"] = d["n_invocations"]
            label = (f"distributed_v2_{dbe}_fused{fr}" if fr
                     else f"distributed_v2_{dbe}")
            emit(rows, f"backends/{name}/{label}", d["time_s"],
                 nTrav=d["nTrav"], nSync=d["nSync"],
                 n_devices=d["n_devices"], rel_time=d["time_s"] / base,
                 **extra)
        bsrcs = common.pick_sources(g, batch, seed=1)
        b = common.run_eic_batch(g, bsrcs)
        emit(rows, f"backends/{name}/sssp_batch", b["time_s"],
             batch=b["batch"], nTrav=b["nTrav"],
             rel_time=b["time_s"] / base)


def roofline(rows, scale):
    """Fused-megakernel roofline smoke row (see benchmarks/roofline.py).

    One real blocked-backend solve at ``fused_rounds=4``; emits achieved
    vs peak bytes/FLOPs per kernel invocation and rounds-per-invocation
    derived from the kernel's in-kernel counters.
    """
    from benchmarks import roofline as rl

    print("# roofline: fused relaxation megakernel, measured")
    r = rl.fused_relax_roofline(scale=min(scale, 10))
    emit(rows, "roofline/fused_relax", r["time_s"],
         rounds_per_invocation=r["rounds_per_invocation"],
         invocations_per_solve=r["invocations_per_solve"],
         bytes_per_invocation=r["bytes_per_invocation"],
         flops_per_invocation=r["flops_per_invocation"],
         peak_frac_bw=r["peak_frac_bw"],
         peak_frac_flops=r["peak_frac_flops"],
         dominant=r["dominant"])


def serving_open_loop(rows, graphs, base_qps, batch, n_queries, seed,
                      load_fracs=(0.3, 0.6, 0.9)):
    """Open-loop mode: Poisson arrivals at fractions of the measured
    closed-loop capacity; reports p50/p99 tail latency vs offered load.

    Each load point's shed/latency curve also lands in the JSONL
    snapshot stream (``serving_open_loop.jsonl``) together with the
    serving plane's full metrics snapshot, so the curves are queryable
    alongside the other exported telemetry instead of only living in
    the per-section BENCH json."""
    from repro.data.traffic import make_traffic

    jsonl = os.path.join(ART, "serving_open_loop.jsonl")
    for frac in load_fracs:
        rate = max(base_qps * frac, 0.5)
        traffic = make_traffic(graphs, n_queries, seed=seed, rate_qps=rate)
        # bounded per-device queues so overload sheds (QueueFull) instead
        # of stretching the tail unboundedly — open-loop needs real
        # admission control for the p99-vs-load curve to mean anything
        r = common.run_serving_traffic(graphs, traffic, max_batch=batch,
                                       open_loop=True,
                                       max_pending=8 * batch,
                                       jsonl_path=jsonl,
                                       jsonl_meta={
                                           "kind": "serving_open_loop",
                                           "load_frac": frac,
                                           "n_queries": n_queries,
                                       })
        emit(rows, f"serving/open_loop/{frac:g}x", r["time_s"],
             offered_qps=r["offered_qps"], achieved_qps=r["qps"],
             p50_ms=r["p50_ms"], p99_ms=r["p99_ms"], shed=r["shed"],
             occupancy=r["occupancy"], n_queries=n_queries)


def p2p(rows, scale, n_pairs=4, n_landmarks=8):
    """Goal-directed p2p ladder (tree / p2p / +ALT / bidirectional) —
    see :func:`benchmarks.common.run_p2p_alt`.  The ALT rungs must stay
    bitwise-exact while cutting relaxations (the issue's acceptance
    floor is >= 1.5x on Road and the kron analogue)."""
    print(f"# p2p: tree vs p2p vs p2p+ALT vs bidirectional, "
          f"{n_pairs} pairs, {n_landmarks} landmarks")
    graphs = common.benchmark_graphs(scale)
    for name in ["Road", f"gr{scale}_8", f"gr{scale}_16", "Urand",
                 "Kron"]:
        if name not in graphs:
            continue
        g = graphs[name]()
        srcs = common.pick_sources(g, n_pairs, seed=1)
        tgts = common.pick_sources(g, n_pairs, seed=2)
        m = common.run_p2p_alt(g, list(zip(srcs, tgts)),
                               n_landmarks=n_landmarks)
        emit(rows, f"p2p/{name}", m["time_s"],
             bitwise_equal=int(m["bitwise_equal"]),
             rounds_tree=m["rounds_tree"], rounds_p2p=m["rounds_p2p"],
             rounds_alt=m["rounds_alt"], rounds_bidi=m["rounds_bidi"],
             relax_p2p=m["relax_p2p"], relax_alt=m["relax_alt"],
             relax_bidi=m["relax_bidi"], pruned_alt=m["pruned_alt"],
             pruned_bidi=m["pruned_bidi"],
             relax_ratio_alt=m["relax_ratio_alt"],
             round_ratio_alt=m["round_ratio_alt"],
             relax_ratio_bidi=m["relax_ratio_bidi"],
             landmark_build_s=m["build_s"],
             time_s_p2p=m["time_s_p2p"], time_s_alt=m["time_s_alt"],
             time_s_bidi=m["time_s_bidi"])


def delta(rows, scale, fracs=(0.01, 0.0025), seed=0):
    """Streaming-update section (see
    :func:`benchmarks.common.run_delta_repair`): per benchmark graph and
    edit-batch fraction, in-place patch + incremental repair vs
    from-scratch recompute, with bitwise parity."""
    graphs = common.benchmark_graphs(scale)
    print(f"# delta: edit batches {[f'{f:.2%}' for f in fracs]} on "
          f"{len(graphs)} graphs, patch+repair vs recompute")
    for name, make in graphs.items():
        g = make()
        for r in common.run_delta_repair(g, fracs=fracs, seed=seed):
            emit(rows, f"delta/{name}/frac{r['frac']:g}", r["time_s"],
                 n_edits=r["n_edits"], n_invalid=r["n_invalid"],
                 n_seeds=r["n_seeds"], fast_path=int(r["fast_path"]),
                 patch_host_ms=r["patch_host_s"] * 1e3,
                 patch_layout_ms=r["patch_layout_s"] * 1e3,
                 time_s_full=r["time_s_full"],
                 relax_repair=r["relax_repair"],
                 relax_full=r["relax_full"],
                 relax_reduction=r["relax_reduction"],
                 rounds_repair=r["rounds_repair"],
                 rounds_full=r["rounds_full"],
                 bitwise_equal=int(r["bitwise_equal"]))


def tuner(rows, scale, budget=14, seed=0):
    """Per-graph EngineConfig auto-tuner (``repro.tune``) on three graph
    families: default vs tuned trace objective + reduction, winner's
    bitwise parity (the tuner accepts only parity-identical candidates,
    so rejects are also reported), and the evaluation budget spent.

    Winners persist to ``benchmarks/artifacts/tuned.json``; the full
    search trajectory streams to ``benchmarks/artifacts/tuner.jsonl``
    (one line per candidate + a final metrics snapshot per graph).
    """
    import time

    from repro.data.generators import kronecker, road_grid, uniform_random
    from repro.tune import TunedStore, tune

    sc = min(scale, 10)
    n = 1 << sc
    side = int(np.sqrt(n))
    graphs = {
        f"kron{sc}": kronecker(sc, 8, seed=2),
        "road": road_grid(side, seed=5),
        "urand": uniform_random(n, 8 * n, seed=6),
    }
    store = TunedStore(os.path.join(ART, "tuned.json"))
    jsonl = os.path.join(ART, "tuner.jsonl")
    print(f"# tuner: {len(graphs)} graphs, budget={budget} evals each, "
          f"seed={seed}")
    for gid, g in graphs.items():
        t0 = time.perf_counter()
        res = tune(g, gid=gid, budget=budget, seed=seed, store=store,
                   jsonl_path=jsonl)
        best = res.best_config
        emit(rows, f"tuner/{gid}", time.perf_counter() - t0,
             baseline_objective=res.baseline_objective,
             tuned_objective=res.best_objective,
             reduction=res.reduction, improved=int(res.improved),
             n_evals=res.n_evals, accepted=res.n_accepted,
             parity_rejects=res.n_parity_rejects, invalid=res.n_invalid,
             alpha=best.alpha, beta=best.beta, policy=best.policy,
             fused_rounds=best.fused_rounds)


def serving(rows, scale, batch, n_queries=None, seed=0, open_loop=False):
    """Serving plane under Zipf-skewed multi-graph traffic.

    Runs the same traffic twice — through a 1-device router and through a
    router over every local device — and reports the aggregate
    queries/s scaling (the multi-device acceptance check; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get a CPU
    mesh), with served p2p distances bitwise-checked against the
    single-device engine.  A final row serves a graph through the
    sharded (shard_map) engine tier via the same ``SsspService``/router
    API and checks dist/parent parity.
    """
    import time

    import jax

    from repro.core.sssp import sssp
    from repro.data.generators import kronecker, road_grid, uniform_random
    from repro.data.traffic import make_traffic
    from repro.serve.sssp_service import SsspRequest, SsspService

    n = 1 << scale
    side = int(np.sqrt(n))
    # heterogeneous shapes (skewed / road / random), enough graphs that
    # placement can spread over a mesh; insertion order = Zipf popularity
    graphs = {
        f"gr{scale}_8": kronecker(scale, 8, seed=2),   # hottest (rank 0)
        "Road": road_grid(side, seed=5),
        "Urand": uniform_random(n, 8 * n, seed=6),
        f"gr{scale}_4": kronecker(scale, 4, seed=11),
        "Web": kronecker(scale, 30, seed=7),
        "Twitter": kronecker(scale, 22, seed=8),
    }
    if n_queries is None:   # explicit 0 is 0, not the default
        n_queries = max(96, 16 * batch)
    n_dev = len(jax.devices())
    print(f"# serving: {len(graphs)} graphs, {n_queries} Zipf queries, "
          f"max_batch={batch}, devices={n_dev}")
    traffic = make_traffic(graphs, n_queries, seed=seed)

    one = common.run_serving_traffic(graphs, traffic,
                                     devices=jax.devices()[:1],
                                     max_batch=batch)
    emit(rows, "serving/1dev", one["time_s"], qps=one["qps"],
         p50_ms=one["p50_ms"], p99_ms=one["p99_ms"],
         occupancy=one["occupancy"], warmup_s=one["warmup_s"],
         n_batches=one["stats"]["n_batches"], n_graphs=len(graphs),
         n_queries=n_queries,
         registry_hit_rate=one["serving_hit_rate"])
    best = one
    if n_dev > 1:
        many = common.run_serving_traffic(graphs, traffic, max_batch=batch)
        parity, n_checked = common.check_p2p_parity(graphs,
                                                    many["results"],
                                                    sample=12)
        emit(rows, "serving/router", many["time_s"], qps=many["qps"],
             rebuilds=many["stats"]["n_rebuilds"],
             n_devices=n_dev, scaling=many["qps"] / one["qps"],
             p2p_bitwise_equal=int(parity), p2p_checked=n_checked,
             p50_ms=many["p50_ms"], p99_ms=many["p99_ms"],
             occupancy=many["occupancy"], warmup_s=many["warmup_s"],
             n_batches=many["stats"]["n_batches"],
             replications=many["stats"]["n_replications"],
             rejected=many["stats"]["rejected"],
             registry_hit_rate=many["serving_hit_rate"])
        best = many

    if open_loop:
        # tail latency vs offered load, paced by the traffic's Poisson
        # arrival offsets (instead of closed-loop drain throughput)
        serving_open_loop(rows, graphs, best["qps"], batch, n_queries, seed)

    lat_by_gid = {}
    for item, res in best["results"]:
        lat_by_gid.setdefault(item.query.gid, []).append(res.latency_s)
    for gid, lats in sorted(lat_by_gid.items()):
        lats = np.asarray(lats)
        emit(rows, f"serving/{gid}", float(lats.mean()),
             n=lats.size,
             p50_ms=float(np.percentile(lats, 50) * 1e3),
             p99_ms=float(np.percentile(lats, 99) * 1e3))

    # sharded-tier acceptance: a graph forced over the shard threshold is
    # served through the same SsspService/router API by the shard_map
    # engine spanning the mesh — once per relax backend (segment_min and
    # the sparsity-aware blocked layout) — with dist/parent parity vs
    # single-device
    big_name = f"gr{scale}_8"
    big = graphs[big_name]
    dg = big.to_device()
    srcs = common.pick_sources(big, min(batch, 4), seed=3)
    for sbe in ["segment_min", "blocked"]:
        svc = SsspService(big, devices=jax.devices(),
                          config=common.EngineConfig(
                              max_batch=min(batch, 4), shard_threshold_n=1,
                              shard_backend=sbe))
        t0 = time.perf_counter()
        reqs = [svc.submit(SsspRequest(rid=i, source=int(s)))
                for i, s in enumerate(srcs)]
        svc.run()
        elapsed = time.perf_counter() - t0
        parity = True
        for r in reqs:
            d_ref, p_ref, _ = sssp(dg, r.source)
            parity &= (np.array_equal(r.dist, np.asarray(d_ref))
                       and np.array_equal(r.parent, np.asarray(p_ref)))
        emit(rows, f"serving/{big_name}/sharded_tier_{sbe}",
             elapsed / len(reqs), n_devices=n_dev, parity=int(parity),
             n_sources=len(reqs))

    # acceptance check: p2p early exit saves rounds on the Road graph and
    # returns bitwise-identical target distances
    road = graphs["Road"]
    srcs = common.pick_sources(road, 6, seed=1)
    tgts = common.pick_sources(road, 6, seed=2)
    cmp_ = common.run_p2p_vs_tree(road, list(zip(srcs, tgts)))
    emit(rows, "serving/Road/p2p_vs_tree", cmp_["time_s"],
         rounds_tree=cmp_["rounds_tree"], rounds_p2p=cmp_["rounds_p2p"],
         round_ratio=cmp_["round_ratio"],
         bitwise_equal=int(cmp_["bitwise_equal"]),
         speedup_vs_tree=cmp_["time_s_tree"] / max(cmp_["time_s"], 1e-12))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--sources", type=int, default=3)
    from repro.core.relax import available_backends
    ap.add_argument("--backend", default="segment_min",
                    choices=available_backends(),
                    help="relaxation backend for the paper-metric sections")
    ap.add_argument("--batch", type=int, default=4,
                    help="sources per fused sssp_batch call (backends "
                         "section)")
    ap.add_argument("--full-variants", action="store_true")
    ap.add_argument("--sections", default="fig4,table3,backends,serving")
    ap.add_argument("--queries", type=int, default=None,
                    help="query count for the serving section "
                         "(default: max(48, 8*batch))")
    ap.add_argument("--tune-budget", type=int, default=14,
                    help="tuner section: candidate evaluations per graph "
                         "(baseline included)")
    ap.add_argument("--open-loop", action="store_true",
                    help="serving section: pace submissions by the "
                         "traffic's Poisson arrival_s and report p50/p99 "
                         "tail latency vs offered load")
    ap.add_argument("--json", metavar="OUT", default=None, dest="json_out",
                    help="also write one BENCH_<section>.json per section "
                         "into this directory (per-row timings + derived "
                         "metrics, plus the run configuration)")
    args = ap.parse_args()
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.sources < 1:
        ap.error("--sources must be >= 1")
    if args.queries is not None and args.queries < 1:
        ap.error("--queries must be >= 1")
    if args.tune_budget < 1:
        ap.error("--tune-budget must be >= 1")

    os.makedirs(ART, exist_ok=True)
    rows = []
    by_section = {}
    sections = set(args.sections.split(","))
    print("name,us_per_call,derived")

    def run_section(sec, fn, *a, **kw):
        # attribute each section's rows so --json can split them per file
        start = len(rows)
        fn(rows, *a, **kw)
        by_section[sec] = rows[start:]

    if sections & {"fig4", "fig5", "fig6"}:
        run_section("fig4", fig4_fig5_fig6, args.scale, args.sources,
                    args.full_variants, args.backend)
    if "table3" in sections:
        run_section("table3", table3, args.scale, args.sources, args.backend)
    if "backends" in sections:
        run_section("backends", backends, args.scale, args.sources,
                    args.batch)
    if "roofline" in sections:
        run_section("roofline", roofline, args.scale)
    if "serving" in sections:
        run_section("serving", serving, args.scale, args.batch,
                    n_queries=args.queries, open_loop=args.open_loop)
    if "p2p" in sections:
        run_section("p2p", p2p, args.scale)
    if "delta" in sections:
        run_section("delta", delta, args.scale)
    if "tuner" in sections:
        run_section("tuner", tuner, args.scale,
                    budget=args.tune_budget)
    with open(os.path.join(ART, "paper_metrics.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to benchmarks/artifacts/paper_metrics.json")
    if args.json_out:
        import jax
        os.makedirs(args.json_out, exist_ok=True)
        cfg = {"scale": args.scale, "sources": args.sources,
               "backend": args.backend, "batch": args.batch,
               "platform": jax.devices()[0].platform,
               "n_devices": len(jax.devices())}
        for sec, srows in by_section.items():
            path = os.path.join(args.json_out, f"BENCH_{sec}.json")
            with open(path, "w") as f:
                json.dump({"section": sec, "config": cfg,
                           "n_rows": len(srows), "rows": srows}, f, indent=1)
            print(f"# wrote {len(srows)} rows to {path}")


if __name__ == "__main__":
    main()
