"""Pallas TPU kernel for the EIC windowed edge relaxation (paper Algo 2 l.10-17).

One grid step processes one *scheduled* edge tile against its destination
block:

    cand[e] = dist[src[e]] + w[e]          if frontier[src[e]] and
                                              lb <= cand[e] < ub
    out[j]  = min over e with dst[e] == j  of cand[e]
    win[j]  = min src[e] over the edges achieving out[j]   (deterministic
              parent recovery: smallest source id among the winners)

TPU adaptation (DESIGN.md §2/§5): the MPI CAS loop becomes a dense masked
min-reduction.  Edges arrive pre-bucketed by (src block, dst block) with
every bucket padded to a tile boundary — the
:class:`~repro.core.graph.BlockedGraph` layout — so each tile belongs to
exactly one destination block and the source-distance block and the
destination output block both fit in VMEM.  The scatter is expressed as a
broadcast-compare reduce over the (TILE_E x BLOCK_V) plane, which is
VPU-shaped (8x128 lanes), avoiding data-dependent writes entirely; tiles
revisiting the same output block are combined in-place (value min, winner
min on ties — associative and order-independent, so the accumulation is
deterministic).

**Sparsity-aware ragged grid.**  The grid is 1-D over the slab's tiles
(``grid=(n_tiles,)``), not the dense ``(n_dst_blocks, n_tiles)`` product:
the layout's CSR-of-tiles index (``tile_dst``, non-decreasing) already
restricts every destination block to its own tile range, so no tile is
ever scanned against a foreign block.  On top of the static ranges, a
**frontier-compaction prepass** (:func:`schedule_tiles`) computes an
active-tile bitmap from the round's frontier and compacts the active
tiles to the front of the schedule (stable, so the dst-sorted order —
which the revisiting output BlockSpec requires — is preserved).  Inactive
tail steps are pinned to the last active tile, so consecutive grid steps
see an unchanged block index: Pallas skips the re-fetch DMA and
``pl.when`` skips the compute.  Steps with narrow windows — the common
case under dynamic stepping — touch only the few tiles whose sources sit
in the frontier band.

The schedule, the per-step destination block, and the active count ride
in as scalar-prefetch operands (``PrefetchScalarGridSpec``), which is
what lets the input/output index maps follow a *traced* per-round
schedule while the grid itself stays static (jit/vmap-compatible).
Destination blocks with no tiles at all are never visited; their output
range is masked to +inf / INT_MAX after the call.

**Multi-round fused megakernel.**  :func:`edge_relax_fused` executes up
to ``fused_rounds`` complete windowed relaxation rounds in ONE Pallas
invocation over the whole (concatenated, global-source-id) edge slab.
The VMEM residency contract: ``dist``/``parent``/``frontier`` live in
the kernel's output refs for the entire invocation — every round reads
the previous round's state straight from VMEM, recomputes the
frontier-compacted tile schedule in-kernel (the same prefix-sum
compaction as :func:`schedule_tiles`), relaxes only the scheduled
tiles, commits improvements, and exits early once a round improves
nothing (the window has settled) — no XLA round-trip, no HBM bounce of
the O(V) state between rounds.  The logical counters (``n_trav``,
``n_relax``, updates, per-round tile counts) are folded into per-tile
partial sums over the compacted schedule — exact, because a tile left
out of the schedule has no frontier source with finite weight, so every
one of its edges fails the window test and contributes zero — which
eliminates the separate O(E) per-round metrics pass the unfused path
pays in ``core/relax.py``.

When ``fused_rounds`` helps vs hurts: fusing pays off when windows are
wide (many rounds per step, each reusing the resident state — typical
for the first steps on skewed-degree graphs) and costs nothing when
they are narrow (the kernel exits after one round).  It can *hurt* on
small graphs, where per-invocation fixed cost is negligible anyway and
the fused kernel's whole-slab residency (the full edge slab plus 2
O(V) carries must fit in VMEM at once) forfeits the per-source-block
streaming of the unfused path; keep ``fused_rounds=0`` there, or when
VMEM cannot hold slab + state.  The window bounds stay constant within
a step, which is what makes in-kernel round chaining exact; during the
bootstrap step (``lb <= 0``) the upper bound tightens after every
round, so the wrapper clamps the invocation to a single round there.

:func:`edge_relax_partials` is the single-round partials mode of the
same tile pass for the sharded engines: one invocation relaxes ALL of a
shard's source-block slabs (against the shard's local source slice) and
returns (min, winner) partials plus the in-kernel counter sums, ready
for the collective exchange — replacing one kernel launch per source
block and the flat O(E) metrics pass with a single launch per shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_E = 512
DEFAULT_BLOCK_V = 512
INT_MAX = jnp.iinfo(jnp.int32).max


def schedule_tiles(frontier_block, src_local, w, tile_first, tile_e: int):
    """Frontier-compaction prepass: compact the active tiles to the front.

    A tile is *active* when any of its edges has a frontier source and a
    finite weight (padding slots carry ``w=+inf``), or when it is the
    forced first tile of a non-empty (src-block, dst-block) bucket
    (``tile_first`` — those visits guarantee every non-empty destination
    block's output is initialized even on rounds where its bucket is
    entirely outside the frontier).

    Returns ``(sched, sched_n)``: ``sched[i]`` is the tile to run at grid
    step ``i`` — active tiles first, in layout (dst-sorted) order, then
    the last active tile repeated so inactive steps never change the
    block index — and ``sched_n`` the number of active tiles.
    """
    nt = tile_first.shape[0]
    touched = (frontier_block[src_local] > 0) & jnp.isfinite(w)
    active = touched.reshape(nt, tile_e).any(axis=1) | tile_first
    # segmented prefix-sum scatter: an active tile's exclusive rank is its
    # slot in the compacted schedule, so layout (dst-sorted) order is
    # preserved without the O(nt log nt) argsort — inactive tiles scatter
    # to a dropped out-of-range slot
    pos = jnp.cumsum(active.astype(jnp.int32)) - 1
    sched_n = pos[-1] + 1
    idx = jnp.arange(nt, dtype=jnp.int32)
    sched = jnp.zeros((nt,), jnp.int32).at[
        jnp.where(active, pos, nt)].set(idx, mode="drop")
    last = sched[jnp.maximum(sched_n - 1, 0)]
    sched = jnp.where(idx < sched_n, sched, last)
    return sched, sched_n


def _kernel(sched_ref, sd_ref, na_ref, lbub_ref, dist_ref, frontier_ref,
            src_ref, dst_ref, w_ref, *rest, block_v: int,
            alt: bool = False):
    if alt:
        alt_ref, val_ref, win_ref = rest
    else:
        val_ref, win_ref = rest
    i = pl.program_id(0)
    b = sd_ref[i]                               # this tile's dst block
    prev = jnp.maximum(i - 1, 0)
    is_first = (i == 0) | (sd_ref[i] != sd_ref[prev])

    @pl.when(is_first)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        win_ref[...] = jnp.full_like(win_ref, INT_MAX)

    @pl.when(i < na_ref[0])
    def _accumulate():
        lb = lbub_ref[0]
        ub = lbub_ref[1]
        src = src_ref[...]
        dst = dst_ref[...]
        w = w_ref[...]
        d_src = dist_ref[src]                   # VMEM gather (src block local)
        front = frontier_ref[src]
        cand = d_src + w
        ok = (front > 0) & (cand >= lb) & (cand < ub)
        if alt:
            # ALT goal-directed cut: a candidate whose admissible
            # remaining-distance bound already exceeds the best known
            # s->t length can never improve it (alt_ref is this dst
            # block's slice of the per-vertex bound array)
            loc = jnp.clip(dst - b * block_v, 0, block_v - 1)
            ok = ok & (cand + alt_ref[loc] <= lbub_ref[2])
        cand = jnp.where(ok, cand, jnp.inf)
        # dense scatter-min: [TILE_E, BLOCK_V] compare plane for dst block b
        cols = b * block_v + jax.lax.broadcasted_iota(
            jnp.int32, (src.shape[0], block_v), 1)
        hit = dst[:, None] == cols
        plane = jnp.where(hit, cand[:, None], jnp.inf)
        tile_min = jnp.min(plane, axis=0)       # [BLOCK_V]
        winners = jnp.where(hit & ok[:, None] & (cand[:, None] <= tile_min),
                            src[:, None], INT_MAX)
        tile_win = jnp.min(winners, axis=0)     # [BLOCK_V] block-local src

        prev_v = val_ref[...]
        prev_w = win_ref[...]
        better = tile_min < prev_v
        tie = tile_min == prev_v
        val_ref[...] = jnp.minimum(prev_v, tile_min)
        win_ref[...] = jnp.where(
            better, tile_win,
            jnp.where(tie, jnp.minimum(prev_w, tile_win), prev_w))


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "n_dst_blocks", "interpret"))
def edge_relax(dist_block, frontier_block, src_local, dst_local, w,
               tile_dst, tile_first, bucket_nonempty, lb, ub,
               alt_lb=None, prune_bound=None, *,
               block_v: int = DEFAULT_BLOCK_V, tile_e: int = DEFAULT_TILE_E,
               n_dst_blocks: int = 1, interpret: bool = True):
    """Relax one source-block edge slab against its active tile schedule.

    dist_block/frontier_block: [Bs] f32 / int8 (src block local).
    src_local/dst_local/w: [NT * tile_e] tile-aligned edge slab
    (``src_local`` is block-local, ``dst_local`` indexes the full
    ``n_dst_blocks * block_v`` destination range; padding edges carry
    w=+inf).  ``tile_dst`` [NT] is the CSR-of-tiles destination-block
    index (non-decreasing), ``tile_first`` [NT] the forced-active first
    tile of each non-empty bucket, ``bucket_nonempty`` [n_dst_blocks] the
    static has-edges mask (see :func:`repro.core.graph.bucket_edges`).

    Returns ``(vals, winners, n_tiles)``: per-destination min candidate
    and the block-local source id achieving it over the
    ``n_dst_blocks * block_v`` range (INT_MAX where no candidate; ties
    broken toward the smallest source id), plus the number of tiles the
    compacted schedule actually ran.
    """
    e = src_local.shape[0]
    if e % tile_e != 0 or e == 0:
        raise ValueError(f"slab length {e} is not tile-aligned "
                         f"(tile_e={tile_e}); bucket it with "
                         "repro.core.graph.bucket_edges")
    nt = e // tile_e
    sched, sched_n = schedule_tiles(frontier_block, src_local, w,
                                    tile_first, tile_e)
    sched_dst = tile_dst[sched]
    alt = alt_lb is not None
    scal = [jnp.float32(lb), jnp.float32(ub)]
    if alt:
        scal.append(jnp.float32(prune_bound))
    lbub = jnp.stack(scal)
    n_out = n_dst_blocks * block_v

    # lbub rides in the scalar-prefetch (SMEM) path with the schedule —
    # window bounds are genuinely scalars, which is what SMEM is for.
    in_specs = [
        pl.BlockSpec(dist_block.shape, lambda i, *_: (0,)),
        pl.BlockSpec(frontier_block.shape, lambda i, *_: (0,)),
        pl.BlockSpec((tile_e,), lambda i, s, *_: (s[i],)),
        pl.BlockSpec((tile_e,), lambda i, s, *_: (s[i],)),
        pl.BlockSpec((tile_e,), lambda i, s, *_: (s[i],)),
    ]
    operands = [sched, sched_dst, sched_n[None], lbub, dist_block,
                frontier_block.astype(jnp.int8), src_local, dst_local, w]
    if alt:
        # the bound slice follows the output index map: one dst block
        in_specs.append(pl.BlockSpec((block_v,), lambda i, s, d, *_:
                                     (d[i],)))
        operands.append(alt_lb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # sched, sched_dst, n_active, lbub
        grid=(nt,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((block_v,), lambda i, s, d, *_: (d[i],)),
                   pl.BlockSpec((block_v,), lambda i, s, d, *_: (d[i],))),
    )
    vals, wins = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v, alt=alt),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n_out,), jnp.float32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32)),
        interpret=interpret,
    )(*operands)
    # destination blocks without any tile are never visited by the grid:
    # mask their (uninitialized) output range to the no-candidate value
    visited = jnp.repeat(bucket_nonempty, block_v)
    return (jnp.where(visited, vals, jnp.inf),
            jnp.where(visited, wins, INT_MAX), sched_n)


# ---------------------------------------------------------------------------
# multi-round fused megakernel
# ---------------------------------------------------------------------------

# counter slots of the fused kernels' in-kernel metric accumulator
FUSED_COUNTERS = ("n_trav", "n_relax", "n_updates", "n_extended",
                  "n_rounds", "n_tiles", "n_exec", "n_pruned")
PARTIAL_COUNTERS = ("n_trav", "n_relax", "n_tiles", "n_pruned")


def _tile_pass(dist_src, paths_src, parent_src, src, dst, w, tdst, tfirst,
               lb, ub, n_out: int, *, block_v: int, tile_e: int, go,
               alt_lb=None, prune_bound=None):
    """One frontier-compacted pass over a whole edge slab (all buckets).

    Pure-value core shared by both fused kernel modes: computes the
    compacted tile schedule (in-kernel prefix-sum compaction, the
    broadcast-compare twin of :func:`schedule_tiles`'s scatter), then
    folds the scheduled tiles' scatter-min AND the logical counters into
    one loop.  ``dist_src``/``paths_src``/``parent_src`` span the slab's
    source-id range; ``src`` ids index that range directly (global for
    the single-device fused slab, shard-local for shard slabs), so the
    per-tile winner min is already the deterministic min-id tiebreak.
    ``go`` gates the tile loop (0 => schedule only, zero tiles run).

    With ``alt_lb`` (the per-vertex ALT bound over the full destination
    range) candidates with ``cand + alt_lb[dst] > prune_bound`` are
    dropped before the scatter-min; parent-excluded drops are counted so
    ``n_relax(unpruned) == n_relax(pruned) + n_pruned`` holds per round
    (mirroring :func:`repro.core.relax.alt_prune` — ``n_trav`` stays the
    in-window count, unaffected by pruning).

    Returns ``(val, win, n_trav, n_relax, n_pruned, sched_n)`` over
    ``n_out`` destinations; counters are exact (a tile outside the
    schedule has no frontier source with finite weight, so every edge in
    it fails the window test and contributes zero to every counter).
    """
    nt = tdst.shape[0]
    touched = (paths_src[src] > 0) & jnp.isfinite(w)
    active = touched.reshape(nt, tile_e).any(axis=1) | (tfirst > 0)
    pos = jnp.cumsum(active.astype(jnp.int32)) - 1
    sched_n = pos[nt - 1] + 1
    # prefix-sum compaction as a compare plane (no data-dependent writes
    # in-kernel): slot k holds the tile whose exclusive rank is k
    ksel = jax.lax.broadcasted_iota(jnp.int32, (nt, nt), 0)
    isel = jax.lax.broadcasted_iota(jnp.int32, (nt, nt), 1)
    hit = (pos[None, :] == ksel) & active[None, :]
    sched = jnp.min(jnp.where(hit, isel, nt), axis=1)

    def tile_body(k, carry):
        val, win, trav, rlx, prn = carry
        t = sched[k]
        b = tdst[t]
        lo = t * tile_e
        src_t = jax.lax.dynamic_slice(src, (lo,), (tile_e,))
        dst_t = jax.lax.dynamic_slice(dst, (lo,), (tile_e,))
        w_t = jax.lax.dynamic_slice(w, (lo,), (tile_e,))
        cand = dist_src[src_t] + w_t
        ok = (paths_src[src_t] > 0) & (cand >= lb) & (cand < ub)
        trav = trav + jnp.sum(ok.astype(jnp.int32))
        notpar = dst_t != parent_src[src_t]
        if alt_lb is not None:
            fail = cand + alt_lb[dst_t] > prune_bound
            prn = prn + jnp.sum((ok & notpar & fail).astype(jnp.int32))
            ok = ok & ~fail
        cand = jnp.where(ok, cand, jnp.inf)
        rlx = rlx + jnp.sum((ok & notpar).astype(jnp.int32))
        cols = b * block_v + jax.lax.broadcasted_iota(
            jnp.int32, (tile_e, block_v), 1)
        hit2 = dst_t[:, None] == cols
        plane = jnp.where(hit2, cand[:, None], jnp.inf)
        tile_min = jnp.min(plane, axis=0)
        winners = jnp.where(hit2 & ok[:, None] & (cand[:, None] <= tile_min),
                            src_t[:, None], INT_MAX)
        tile_win = jnp.min(winners, axis=0)
        off = b * block_v
        prev_v = jax.lax.dynamic_slice(val, (off,), (block_v,))
        prev_w = jax.lax.dynamic_slice(win, (off,), (block_v,))
        better = tile_min < prev_v
        tie = tile_min == prev_v
        val = jax.lax.dynamic_update_slice(
            val, jnp.minimum(prev_v, tile_min), (off,))
        win = jax.lax.dynamic_update_slice(
            win, jnp.where(better, tile_win,
                           jnp.where(tie, jnp.minimum(prev_w, tile_win),
                                     prev_w)), (off,))
        return val, win, trav, rlx, prn

    n_eff = jnp.where(go > 0, sched_n, 0)
    val0 = jnp.full((n_out,), jnp.inf, jnp.float32)
    win0 = jnp.full((n_out,), INT_MAX, jnp.int32)
    val, win, trav, rlx, prn = jax.lax.fori_loop(
        0, n_eff, tile_body,
        (val0, win0, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
    return val, win, trav, rlx, prn, sched_n


def _fused_kernel(*refs, block_v: int, tile_e: int, fused_cap: int,
                  alt: bool = False):
    """Up to ``fused_cap`` windowed rounds, state resident in output refs.

    With ``alt`` the prefetch path carries ``lbub = [lb, ub, prune_ub,
    infl]`` plus the target id, and the prune bound is recomputed from
    the *resident* dist at every round start as
    ``min(prune_ub, dist[tgt] * infl)`` — the exact bound the unfused
    path computes between kernel invocations, which is what keeps the
    fused/unfused pruning decisions (and ``n_pruned``) bitwise-equal.
    """
    if alt:
        (lbub_ref, maxr_ref, tgt_ref, dist_in, parent_in, front_in,
         deg_ref, src_ref, dst_ref, w_ref, tdst_ref, tfirst_ref, alt_ref,
         dist_out, parent_out, front_out, cnt_ref) = refs
    else:
        (lbub_ref, maxr_ref, dist_in, parent_in, front_in, deg_ref,
         src_ref, dst_ref, w_ref, tdst_ref, tfirst_ref,
         dist_out, parent_out, front_out, cnt_ref) = refs
    dist_out[...] = dist_in[...]
    parent_out[...] = parent_in[...]
    front_out[...] = front_in[...]
    cnt_ref[...] = jnp.zeros_like(cnt_ref)
    lb = lbub_ref[0]
    ub = lbub_ref[1]
    max_r = maxr_ref[0]
    deg = deg_ref[...]
    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    tdst = tdst_ref[...]
    tfirst = tfirst_ref[...]
    alt_lb = alt_ref[...] if alt else None
    n_out = deg.shape[0]

    def round_body(r, go):
        # rounds past the early exit are schedule-only no-ops (go=0)
        dist = dist_out[...]
        parent = parent_out[...]
        front = front_out[...]
        paths = ((front > 0) & ((dist <= 0.0) | (deg > 1))).astype(jnp.int32)
        bound = (jnp.minimum(lbub_ref[2], dist[tgt_ref[0]] * lbub_ref[3])
                 if alt else None)
        val, win, trav, rlx, prn, sched_n = _tile_pass(
            dist, paths, parent, src, dst, w, tdst, tfirst, lb, ub,
            n_out, block_v=block_v, tile_e=tile_e, go=go,
            alt_lb=alt_lb, prune_bound=bound)
        improved = val < dist
        any_imp = jnp.any(improved)

        @pl.when(go > 0)
        def _commit():
            dist_out[...] = jnp.where(improved, val, dist)
            parent_out[...] = jnp.where(improved, win, parent)
            front_out[...] = improved.astype(jnp.int32)
            cnt_ref[...] = cnt_ref[...] + jnp.stack([
                trav, rlx,
                jnp.sum(improved.astype(jnp.int32)),
                jnp.sum((improved & (deg > 1)).astype(jnp.int32)),
                jnp.any(front > 0).astype(jnp.int32),
                sched_n, jnp.int32(1), prn])

        return jnp.where(go > 0,
                         (any_imp & (r + 1 < max_r)).astype(jnp.int32),
                         jnp.int32(0))

    jax.lax.fori_loop(0, fused_cap, round_body, jnp.int32(1))


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "fused_rounds", "interpret"))
def edge_relax_fused(dist, parent, frontier, deg, src, dst, w, tile_dst,
                     tile_first, lb, ub, alt_lb=None, prune_ub=None,
                     prune_infl=None, prune_tgt=None, *,
                     block_v: int = DEFAULT_BLOCK_V,
                     tile_e: int = DEFAULT_TILE_E, fused_rounds: int = 4,
                     interpret: bool = True):
    """Run up to ``fused_rounds`` relaxation rounds in one invocation.

    ``dist``/``parent``/``frontier``/``deg`` span the padded vertex range
    ``[0, n_out)`` (source range == destination range — the single-device
    blocked layout); ``src``/``dst``/``w`` are the whole concatenated
    tile-aligned slab with *global* source ids, ``tile_dst``/``tile_first``
    its CSR-of-tiles index.  The invocation is clamped to one round while
    ``lb <= 0`` (the bootstrap step retightens ``ub`` between rounds).

    Returns ``(dist, parent, frontier, counts)`` after the last executed
    round; ``counts`` is the int32 ``FUSED_COUNTERS`` vector summed over
    executed rounds.
    """
    e = src.shape[0]
    if e % tile_e != 0 or e == 0:
        raise ValueError(f"slab length {e} is not tile-aligned "
                         f"(tile_e={tile_e})")
    if fused_rounds < 1:
        raise ValueError(f"fused_rounds must be >= 1, got {fused_rounds}")
    alt = alt_lb is not None
    scal = [jnp.float32(lb), jnp.float32(ub)]
    if alt:
        scal += [jnp.float32(prune_ub), jnp.float32(prune_infl)]
    lbub = jnp.stack(scal)
    # the bootstrap step tightens ub after every round — chaining rounds
    # in-kernel there would relax against a stale bound
    maxr = jnp.where(jnp.float32(lb) <= 0.0, 1, fused_rounds
                     ).astype(jnp.int32)
    n_out = dist.shape[0]
    nt = e // tile_e
    whole = lambda shape: pl.BlockSpec(shape, lambda i, *_: (0,))
    in_specs = ([whole((n_out,))] * 4 + [whole((e,))] * 3
                + [whole((nt,))] * 2)
    prefetch = [lbub, maxr[None]]
    operands = [dist, parent, frontier.astype(jnp.int32), deg,
                src, dst, w, tile_dst, tile_first.astype(jnp.int32)]
    if alt:
        prefetch.append(jnp.asarray(prune_tgt, jnp.int32)[None])
        in_specs.append(whole((n_out,)))
        operands.append(alt_lb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),   # lbub, maxr (+ tgt with ALT)
        grid=(1,),
        in_specs=in_specs,
        out_specs=(whole((n_out,)), whole((n_out,)), whole((n_out,)),
                   whole((8,))),
    )
    dist2, parent2, front2, cnt = pl.pallas_call(
        functools.partial(_fused_kernel, block_v=block_v, tile_e=tile_e,
                          fused_cap=fused_rounds, alt=alt),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n_out,), jnp.float32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32),
                   jax.ShapeDtypeStruct((8,), jnp.int32)),
        interpret=interpret,
    )(*prefetch, *operands)
    return dist2, parent2, front2, cnt


def _partials_kernel(*refs, block_v: int, tile_e: int, alt: bool = False):
    """Single-round partials over a shard's whole slab set."""
    if alt:
        (lbub_ref, dist_ref, paths_ref, parent_ref, src_ref, dst_ref,
         w_ref, tdst_ref, tfirst_ref, alt_ref, val_ref, win_ref,
         cnt_ref) = refs
        alt_lb, bound = alt_ref[...], lbub_ref[2]
    else:
        (lbub_ref, dist_ref, paths_ref, parent_ref, src_ref, dst_ref,
         w_ref, tdst_ref, tfirst_ref, val_ref, win_ref, cnt_ref) = refs
        alt_lb, bound = None, None
    lb = lbub_ref[0]
    ub = lbub_ref[1]
    val, win, trav, rlx, prn, sched_n = _tile_pass(
        dist_ref[...], paths_ref[...], parent_ref[...], src_ref[...],
        dst_ref[...], w_ref[...], tdst_ref[...], tfirst_ref[...], lb, ub,
        val_ref.shape[0], block_v=block_v, tile_e=tile_e, go=jnp.int32(1),
        alt_lb=alt_lb, prune_bound=bound)
    val_ref[...] = val
    win_ref[...] = win
    cnt_ref[...] = jnp.stack([trav, rlx, sched_n, prn])


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "n_dst_blocks", "interpret"))
def edge_relax_partials(dist_src, paths_src, parent_src, src, dst, w,
                        tile_dst, tile_first, lb, ub,
                        alt_lb=None, prune_bound=None, *,
                        block_v: int = DEFAULT_BLOCK_V,
                        tile_e: int = DEFAULT_TILE_E, n_dst_blocks: int = 1,
                        interpret: bool = True):
    """One invocation of the fused tile pass in partials mode.

    ``dist_src``/``paths_src``/``parent_src`` cover the slab's *local*
    source range; ``src`` ids index it directly (all of a shard's
    source-block slabs concatenated, ids offset by their block).  Returns
    ``(val, win, counts)``: per-destination (min, winner) partials over
    ``n_dst_blocks * block_v`` — winners are local source ids, lift them
    with the shard's owner-block offset — and the int32
    ``PARTIAL_COUNTERS`` vector (``n_trav``/``n_relax``/tile count).
    """
    e = src.shape[0]
    if e % tile_e != 0 or e == 0:
        raise ValueError(f"slab length {e} is not tile-aligned "
                         f"(tile_e={tile_e})")
    alt = alt_lb is not None
    scal = [jnp.float32(lb), jnp.float32(ub)]
    if alt:
        scal.append(jnp.float32(prune_bound))
    lbub = jnp.stack(scal)
    n_out = n_dst_blocks * block_v
    n_src = dist_src.shape[0]
    nt = e // tile_e
    whole = lambda shape: pl.BlockSpec(shape, lambda i, *_: (0,))
    in_specs = ([whole((n_src,))] * 3 + [whole((e,))] * 3
                + [whole((nt,))] * 2)
    operands = [dist_src, paths_src.astype(jnp.int32), parent_src, src,
                dst, w, tile_dst, tile_first.astype(jnp.int32)]
    if alt:
        in_specs.append(whole((n_out,)))
        operands.append(alt_lb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,      # lbub
        grid=(1,),
        in_specs=in_specs,
        out_specs=(whole((n_out,)), whole((n_out,)), whole((4,))),
    )
    return pl.pallas_call(
        functools.partial(_partials_kernel, block_v=block_v, tile_e=tile_e,
                          alt=alt),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n_out,), jnp.float32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32),
                   jax.ShapeDtypeStruct((4,), jnp.int32)),
        interpret=interpret,
    )(lbub, *operands)
