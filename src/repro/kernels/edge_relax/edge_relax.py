"""Pallas TPU kernel for the EIC windowed edge relaxation (paper Algo 2 l.10-17).

One grid step processes one (destination block x edge tile) pair:

    cand[e] = dist[src[e]] + w[e]          if frontier[src[e]] and
                                              lb <= cand[e] < ub
    out[j]  = min over e with dst[e] == j  of cand[e]
    win[j]  = min src[e] over the edges achieving out[j]   (deterministic
              parent recovery: smallest source id among the winners)

TPU adaptation (DESIGN.md §2/§5): the MPI CAS loop becomes a dense masked
min-reduction.  Edges arrive pre-bucketed by (src block, dst block) — the
:class:`~repro.core.graph.BlockedGraph` layout — so the source-distance
block and the destination output block both fit in VMEM.  The scatter is
expressed as a broadcast-compare reduce over the (TILE_E x BLOCK_V) plane,
which is VPU-shaped (8x128 lanes), avoiding data-dependent writes entirely;
the per-tile partial (min, argmin-src) pairs are combined across the grid's
edge-tile axis by the output BlockSpec revisiting scheme (value min, winner
min on ties — associative and order-independent, so the accumulation is
deterministic).

Grid: ``(n_dst_blocks, n_edge_tiles)``; for destination block ``b`` the
kernel masks edges to ``dst in [b*block_v, (b+1)*block_v)``, so every
destination block is computed (the seed kernel's ``grid=(1, n_tiles)`` only
ever produced block 0).  Edge tiles revisit the same output block, so the
kernel accumulates in-place (outputs initialized at +inf / INT_MAX on the
first visit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_E = 512
DEFAULT_BLOCK_V = 512
INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(dist_ref, frontier_ref, src_ref, dst_ref, w_ref, lbub_ref,
            val_ref, win_ref, *, block_v: int):
    b = pl.program_id(0)
    t = pl.program_id(1)
    lb = lbub_ref[0]
    ub = lbub_ref[1]
    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    d_src = dist_ref[src]                       # VMEM gather (src block local)
    front = frontier_ref[src]
    cand = d_src + w
    ok = (front > 0) & (cand >= lb) & (cand < ub)
    cand = jnp.where(ok, cand, jnp.inf)
    # dense scatter-min: [TILE_E, BLOCK_V] compare plane for dst block b
    cols = b * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (src.shape[0], block_v), 1)
    hit = dst[:, None] == cols
    plane = jnp.where(hit, cand[:, None], jnp.inf)
    tile_min = jnp.min(plane, axis=0)           # [BLOCK_V]
    winners = jnp.where(hit & ok[:, None] & (cand[:, None] <= tile_min),
                        src[:, None], INT_MAX)
    tile_win = jnp.min(winners, axis=0)         # [BLOCK_V] block-local src

    @pl.when(t == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        win_ref[...] = jnp.full_like(win_ref, INT_MAX)

    prev_v = val_ref[...]
    prev_w = win_ref[...]
    better = tile_min < prev_v
    tie = tile_min == prev_v
    val_ref[...] = jnp.minimum(prev_v, tile_min)
    win_ref[...] = jnp.where(
        better, tile_win,
        jnp.where(tie, jnp.minimum(prev_w, tile_win), prev_w))


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "n_dst_blocks", "interpret"))
def edge_relax(dist_block, frontier_block, src_local, dst_local, w,
               lb, ub, *, block_v: int = DEFAULT_BLOCK_V,
               tile_e: int = DEFAULT_TILE_E, n_dst_blocks: int = 1,
               interpret: bool = True):
    """Relax one source-block edge slab against ``n_dst_blocks`` dst blocks.

    dist_block/frontier_block: [Bs] f32 / int8 (src block local).
    src_local/dst_local/w: [E] edge slabs (``src_local`` is block-local,
    ``dst_local`` indexes the full ``n_dst_blocks * block_v`` destination
    range; padding edges carry w=+inf).  Returns ``(vals, winners)`` of
    shape [n_dst_blocks * block_v]: the per-destination min candidate and
    the block-local source id achieving it (INT_MAX where no candidate;
    ties broken toward the smallest source id).
    """
    e = src_local.shape[0]
    e_pad = -(-e // tile_e) * tile_e
    src_local = jnp.pad(src_local, (0, e_pad - e))
    dst_local = jnp.pad(dst_local, (0, e_pad - e))
    w = jnp.pad(w, (0, e_pad - e), constant_values=jnp.inf)
    n_tiles = e_pad // tile_e
    lbub = jnp.stack([jnp.float32(lb), jnp.float32(ub)])
    n_out = n_dst_blocks * block_v

    vals, wins = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=(n_dst_blocks, n_tiles),
        in_specs=[
            pl.BlockSpec(dist_block.shape, lambda b, t: (0,)),
            pl.BlockSpec(frontier_block.shape, lambda b, t: (0,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec(lbub.shape, lambda b, t: (0,)),
        ],
        out_specs=(pl.BlockSpec((block_v,), lambda b, t: (b,)),
                   pl.BlockSpec((block_v,), lambda b, t: (b,))),
        out_shape=(jax.ShapeDtypeStruct((n_out,), jnp.float32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32)),
        interpret=interpret,
    )(dist_block, frontier_block.astype(jnp.int8), src_local, dst_local,
      w, lbub)
    return vals, wins
