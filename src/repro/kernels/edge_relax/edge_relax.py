"""Pallas TPU kernel for the EIC windowed edge relaxation (paper Algo 2 l.10-17).

One grid step processes one *scheduled* edge tile against its destination
block:

    cand[e] = dist[src[e]] + w[e]          if frontier[src[e]] and
                                              lb <= cand[e] < ub
    out[j]  = min over e with dst[e] == j  of cand[e]
    win[j]  = min src[e] over the edges achieving out[j]   (deterministic
              parent recovery: smallest source id among the winners)

TPU adaptation (DESIGN.md §2/§5): the MPI CAS loop becomes a dense masked
min-reduction.  Edges arrive pre-bucketed by (src block, dst block) with
every bucket padded to a tile boundary — the
:class:`~repro.core.graph.BlockedGraph` layout — so each tile belongs to
exactly one destination block and the source-distance block and the
destination output block both fit in VMEM.  The scatter is expressed as a
broadcast-compare reduce over the (TILE_E x BLOCK_V) plane, which is
VPU-shaped (8x128 lanes), avoiding data-dependent writes entirely; tiles
revisiting the same output block are combined in-place (value min, winner
min on ties — associative and order-independent, so the accumulation is
deterministic).

**Sparsity-aware ragged grid.**  The grid is 1-D over the slab's tiles
(``grid=(n_tiles,)``), not the dense ``(n_dst_blocks, n_tiles)`` product:
the layout's CSR-of-tiles index (``tile_dst``, non-decreasing) already
restricts every destination block to its own tile range, so no tile is
ever scanned against a foreign block.  On top of the static ranges, a
**frontier-compaction prepass** (:func:`schedule_tiles`) computes an
active-tile bitmap from the round's frontier and compacts the active
tiles to the front of the schedule (stable, so the dst-sorted order —
which the revisiting output BlockSpec requires — is preserved).  Inactive
tail steps are pinned to the last active tile, so consecutive grid steps
see an unchanged block index: Pallas skips the re-fetch DMA and
``pl.when`` skips the compute.  Steps with narrow windows — the common
case under dynamic stepping — touch only the few tiles whose sources sit
in the frontier band.

The schedule, the per-step destination block, and the active count ride
in as scalar-prefetch operands (``PrefetchScalarGridSpec``), which is
what lets the input/output index maps follow a *traced* per-round
schedule while the grid itself stays static (jit/vmap-compatible).
Destination blocks with no tiles at all are never visited; their output
range is masked to +inf / INT_MAX after the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_E = 512
DEFAULT_BLOCK_V = 512
INT_MAX = jnp.iinfo(jnp.int32).max


def schedule_tiles(frontier_block, src_local, w, tile_first, tile_e: int):
    """Frontier-compaction prepass: compact the active tiles to the front.

    A tile is *active* when any of its edges has a frontier source and a
    finite weight (padding slots carry ``w=+inf``), or when it is the
    forced first tile of a non-empty (src-block, dst-block) bucket
    (``tile_first`` — those visits guarantee every non-empty destination
    block's output is initialized even on rounds where its bucket is
    entirely outside the frontier).

    Returns ``(sched, sched_n)``: ``sched[i]`` is the tile to run at grid
    step ``i`` — active tiles first, in layout (dst-sorted) order, then
    the last active tile repeated so inactive steps never change the
    block index — and ``sched_n`` the number of active tiles.
    """
    nt = tile_first.shape[0]
    touched = (frontier_block[src_local] > 0) & jnp.isfinite(w)
    active = touched.reshape(nt, tile_e).any(axis=1) | tile_first
    order = jnp.argsort(~active, stable=True).astype(jnp.int32)
    sched_n = jnp.sum(active.astype(jnp.int32))
    last = order[jnp.maximum(sched_n - 1, 0)]
    idx = jnp.arange(nt, dtype=jnp.int32)
    sched = jnp.where(idx < sched_n, order, last)
    return sched, sched_n


def _kernel(sched_ref, sd_ref, na_ref, lbub_ref, dist_ref, frontier_ref,
            src_ref, dst_ref, w_ref, val_ref, win_ref, *, block_v: int):
    i = pl.program_id(0)
    b = sd_ref[i]                               # this tile's dst block
    prev = jnp.maximum(i - 1, 0)
    is_first = (i == 0) | (sd_ref[i] != sd_ref[prev])

    @pl.when(is_first)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        win_ref[...] = jnp.full_like(win_ref, INT_MAX)

    @pl.when(i < na_ref[0])
    def _accumulate():
        lb = lbub_ref[0]
        ub = lbub_ref[1]
        src = src_ref[...]
        dst = dst_ref[...]
        w = w_ref[...]
        d_src = dist_ref[src]                   # VMEM gather (src block local)
        front = frontier_ref[src]
        cand = d_src + w
        ok = (front > 0) & (cand >= lb) & (cand < ub)
        cand = jnp.where(ok, cand, jnp.inf)
        # dense scatter-min: [TILE_E, BLOCK_V] compare plane for dst block b
        cols = b * block_v + jax.lax.broadcasted_iota(
            jnp.int32, (src.shape[0], block_v), 1)
        hit = dst[:, None] == cols
        plane = jnp.where(hit, cand[:, None], jnp.inf)
        tile_min = jnp.min(plane, axis=0)       # [BLOCK_V]
        winners = jnp.where(hit & ok[:, None] & (cand[:, None] <= tile_min),
                            src[:, None], INT_MAX)
        tile_win = jnp.min(winners, axis=0)     # [BLOCK_V] block-local src

        prev_v = val_ref[...]
        prev_w = win_ref[...]
        better = tile_min < prev_v
        tie = tile_min == prev_v
        val_ref[...] = jnp.minimum(prev_v, tile_min)
        win_ref[...] = jnp.where(
            better, tile_win,
            jnp.where(tie, jnp.minimum(prev_w, tile_win), prev_w))


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "n_dst_blocks", "interpret"))
def edge_relax(dist_block, frontier_block, src_local, dst_local, w,
               tile_dst, tile_first, bucket_nonempty, lb, ub, *,
               block_v: int = DEFAULT_BLOCK_V, tile_e: int = DEFAULT_TILE_E,
               n_dst_blocks: int = 1, interpret: bool = True):
    """Relax one source-block edge slab against its active tile schedule.

    dist_block/frontier_block: [Bs] f32 / int8 (src block local).
    src_local/dst_local/w: [NT * tile_e] tile-aligned edge slab
    (``src_local`` is block-local, ``dst_local`` indexes the full
    ``n_dst_blocks * block_v`` destination range; padding edges carry
    w=+inf).  ``tile_dst`` [NT] is the CSR-of-tiles destination-block
    index (non-decreasing), ``tile_first`` [NT] the forced-active first
    tile of each non-empty bucket, ``bucket_nonempty`` [n_dst_blocks] the
    static has-edges mask (see :func:`repro.core.graph.bucket_edges`).

    Returns ``(vals, winners, n_tiles)``: per-destination min candidate
    and the block-local source id achieving it over the
    ``n_dst_blocks * block_v`` range (INT_MAX where no candidate; ties
    broken toward the smallest source id), plus the number of tiles the
    compacted schedule actually ran.
    """
    e = src_local.shape[0]
    if e % tile_e != 0 or e == 0:
        raise ValueError(f"slab length {e} is not tile-aligned "
                         f"(tile_e={tile_e}); bucket it with "
                         "repro.core.graph.bucket_edges")
    nt = e // tile_e
    sched, sched_n = schedule_tiles(frontier_block, src_local, w,
                                    tile_first, tile_e)
    sched_dst = tile_dst[sched]
    lbub = jnp.stack([jnp.float32(lb), jnp.float32(ub)])
    n_out = n_dst_blocks * block_v

    # lbub rides in the scalar-prefetch (SMEM) path with the schedule —
    # window bounds are genuinely scalars, which is what SMEM is for.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # sched, sched_dst, n_active, lbub
        grid=(nt,),
        in_specs=[
            pl.BlockSpec(dist_block.shape, lambda i, s, d, n, b: (0,)),
            pl.BlockSpec(frontier_block.shape, lambda i, s, d, n, b: (0,)),
            pl.BlockSpec((tile_e,), lambda i, s, d, n, b: (s[i],)),
            pl.BlockSpec((tile_e,), lambda i, s, d, n, b: (s[i],)),
            pl.BlockSpec((tile_e,), lambda i, s, d, n, b: (s[i],)),
        ],
        out_specs=(pl.BlockSpec((block_v,), lambda i, s, d, n, b: (d[i],)),
                   pl.BlockSpec((block_v,), lambda i, s, d, n, b: (d[i],))),
    )
    vals, wins = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n_out,), jnp.float32),
                   jax.ShapeDtypeStruct((n_out,), jnp.int32)),
        interpret=interpret,
    )(sched, sched_dst, sched_n[None], lbub, dist_block,
      frontier_block.astype(jnp.int8), src_local, dst_local, w)
    # destination blocks without any tile are never visited by the grid:
    # mask their (uninitialized) output range to the no-candidate value
    visited = jnp.repeat(bucket_nonempty, block_v)
    return (jnp.where(visited, vals, jnp.inf),
            jnp.where(visited, wins, INT_MAX), sched_n)
