"""Pallas TPU kernel for the EIC windowed edge relaxation (paper Algo 2 l.10-17).

One grid step processes one (edge tile x destination block) pair:

    cand[e] = dist[src[e]] + w[e]          if frontier[src[e]] and
                                              lb <= cand[e] < ub
    out[j]  = min over e with dst[e] == j  of cand[e]

TPU adaptation (DESIGN.md §2/§5): the MPI CAS loop becomes a dense masked
min-reduction.  Edges arrive pre-bucketed by (src block, dst block) — the
2-D partition of the distributed engine — so the source-distance block and
the destination output block both fit in VMEM.  The scatter is expressed as
a broadcast-compare reduce over the (TILE_E x BLOCK_V) plane, which is
VPU-shaped (8x128 lanes), avoiding data-dependent writes entirely; the
per-tile partial mins are min-combined across the grid's edge-tile axis by
the output BlockSpec revisiting scheme.

Grid: (n_dst_blocks, n_edge_tiles); edge tiles revisit the same output
block, so the kernel accumulates min in-place (output initialized at +inf
on the first visit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_E = 512
DEFAULT_BLOCK_V = 512
NEG = jnp.float32(jnp.inf)


def _kernel(dist_ref, frontier_ref, src_ref, dst_ref, w_ref, lbub_ref,
            out_ref, *, block_v: int):
    t = pl.program_id(1)
    lb = lbub_ref[0]
    ub = lbub_ref[1]
    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    d_src = dist_ref[src]                       # VMEM gather (src block local)
    front = frontier_ref[src]
    cand = d_src + w
    ok = (front > 0) & (cand >= lb) & (cand < ub)
    cand = jnp.where(ok, cand, jnp.inf)
    # dense scatter-min: [TILE_E, BLOCK_V] compare plane
    cols = jax.lax.broadcasted_iota(jnp.int32, (src.shape[0], block_v), 1)
    plane = jnp.where(dst[:, None] == cols, cand[:, None], jnp.inf)
    tile_min = jnp.min(plane, axis=0)           # [BLOCK_V]

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    out_ref[...] = jnp.minimum(out_ref[...], tile_min)


@functools.partial(jax.jit, static_argnames=("block_v", "tile_e",
                                             "interpret"))
def edge_relax(dist_block, frontier_block, src_local, dst_local, w,
               lb, ub, *, block_v: int = DEFAULT_BLOCK_V,
               tile_e: int = DEFAULT_TILE_E, interpret: bool = True):
    """Relax one (src block, dst block) edge bucket.

    dist_block/frontier_block: [Bs] f32 / int8 (src block local).
    src_local/dst_local/w: [E] edge slabs (dst_local indexes the dst block;
    padding edges carry w=+inf).  Returns per-dst-block min candidates
    [n_dst_blocks * block_v] where n_dst_blocks = ceil(max_dst / block_v).
    """
    e = src_local.shape[0]
    e_pad = -(-e // tile_e) * tile_e
    src_local = jnp.pad(src_local, (0, e_pad - e))
    dst_local = jnp.pad(dst_local, (0, e_pad - e))
    w = jnp.pad(w, (0, e_pad - e), constant_values=jnp.inf)
    n_tiles = e_pad // tile_e
    lbub = jnp.stack([jnp.float32(lb), jnp.float32(ub)])

    out = pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=(1, n_tiles),
        in_specs=[
            pl.BlockSpec(dist_block.shape, lambda b, t: (0,)),
            pl.BlockSpec(frontier_block.shape, lambda b, t: (0,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec((tile_e,), lambda b, t: (t,)),
            pl.BlockSpec(lbub.shape, lambda b, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_v,), lambda b, t: (b,)),
        out_shape=jax.ShapeDtypeStruct((block_v,), jnp.float32),
        interpret=interpret,
    )(dist_block, frontier_block.astype(jnp.int8), src_local, dst_local,
      w, lbub)
    return out
