"""Pure-jnp oracle for the edge_relax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .edge_relax import INT_MAX


def edge_relax_ref(dist_block, frontier_block, src_local, dst_local, w,
                   lb, ub, *, block_v: int = 512, n_dst_blocks: int = 1):
    """Returns ``(vals, winners)`` matching the Pallas kernel contract:
    per-destination min candidate plus the smallest block-local source id
    achieving it (INT_MAX where no in-window candidate exists)."""
    n_out = n_dst_blocks * block_v
    cand = dist_block[src_local] + w
    ok = (frontier_block[src_local] > 0) & (cand >= lb) & (cand < ub)
    cand = jnp.where(ok, cand, jnp.inf)
    best = jax.ops.segment_min(cand, dst_local, num_segments=n_out)
    win = jnp.where(ok & (cand <= best[dst_local]), src_local, INT_MAX)
    winner = jax.ops.segment_min(win, dst_local, num_segments=n_out)
    return best, winner
