"""Pure-jnp oracles for the edge_relax kernels.

These are the validation twins of the Pallas kernels AND the arrays-only
fallbacks that actually run where the kernels cannot (``use_kernel=False``
— e.g. under interpret-mode ``shard_map``); every reduction resolves ties
exactly like the kernels (min value, then min source id), so the two
paths are bitwise-interchangeable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .edge_relax import INT_MAX


def edge_relax_ref(dist_block, frontier_block, src_local, dst_local, w,
                   lb, ub, alt_lb=None, prune_bound=None, *,
                   block_v: int = 512, n_dst_blocks: int = 1):
    """Returns ``(vals, winners)`` matching the Pallas kernel contract:
    per-destination min candidate plus the smallest block-local source id
    achieving it (INT_MAX where no in-window candidate exists).  With
    ``alt_lb`` the kernel's ALT cut is mirrored on the value path:
    candidates with ``cand + alt_lb[dst] > prune_bound`` never enter the
    scatter-min."""
    n_out = n_dst_blocks * block_v
    cand = dist_block[src_local] + w
    ok = (frontier_block[src_local] > 0) & (cand >= lb) & (cand < ub)
    if alt_lb is not None:
        ok = ok & (cand + alt_lb[dst_local] <= prune_bound)
    cand = jnp.where(ok, cand, jnp.inf)
    best = jax.ops.segment_min(cand, dst_local, num_segments=n_out)
    win = jnp.where(ok & (cand <= best[dst_local]), src_local, INT_MAX)
    winner = jax.ops.segment_min(win, dst_local, num_segments=n_out)
    return best, winner


def _slab_counters(pa_src, w, dst, p_src, ok, tile_first, tile_e: int,
                   fail=None):
    """The fused kernels' logical counters, computed slab-wide (exact:
    tiles outside the compacted schedule contribute zero to each).

    ``ok`` is the pre-prune in-window mask and ``fail`` the ALT cut
    (None without ALT): ``n_trav`` counts all of ``ok``, ``n_relax`` the
    parent-excluded survivors and ``n_pruned`` the parent-excluded cuts,
    so ``n_relax(unpruned) == n_relax(pruned) + n_pruned`` per round."""
    nt = w.shape[0] // tile_e
    touched = pa_src & jnp.isfinite(w)
    active = touched.reshape(nt, tile_e).any(axis=1) | (tile_first > 0)
    notpar = dst != p_src
    if fail is None:
        rlx = jnp.sum((ok & notpar).astype(jnp.int32))
        prn = jnp.int32(0)
    else:
        rlx = jnp.sum((ok & notpar & ~fail).astype(jnp.int32))
        prn = jnp.sum((ok & notpar & fail).astype(jnp.int32))
    return (jnp.sum(ok.astype(jnp.int32)), rlx,
            jnp.sum(active.astype(jnp.int32)), prn)


def edge_relax_fused_ref(dist, parent, frontier, deg, src, dst, w,
                         tile_dst, tile_first, lb, ub, alt_lb=None,
                         prune_ub=None, prune_infl=None, prune_tgt=None, *,
                         block_v: int = 512, tile_e: int = 512,
                         fused_rounds: int = 4):
    """Arrays-only twin of :func:`..edge_relax.edge_relax_fused`.

    Same contract bit-for-bit: up to ``fused_rounds`` windowed rounds
    (one while ``lb <= 0``), early exit when a round improves nothing,
    counters per ``FUSED_COUNTERS``.  The per-round segment-min over the
    whole slab equals the kernel's scheduled-tile accumulation because
    min is order-independent and unscheduled tiles only carry
    out-of-window candidates.
    """
    n_out = dist.shape[0]
    lb = jnp.float32(lb)
    ub = jnp.float32(ub)
    maxr = jnp.where(lb <= 0.0, 1, fused_rounds).astype(jnp.int32)

    def cond(c):
        return c[4] > 0

    def body(c):
        dist, parent, front, cnt, _go, r = c
        paths = (front > 0) & ((dist <= 0.0) | (deg > 1))
        pa_src = paths[src]
        cand = dist[src] + w
        ok = pa_src & (cand >= lb) & (cand < ub)
        fail = None
        if alt_lb is not None:
            # the per-round bound recompute the fused kernel performs
            # from its resident dist
            bound = jnp.minimum(jnp.float32(prune_ub),
                                dist[prune_tgt] * jnp.float32(prune_infl))
            fail = cand + alt_lb[dst] > bound
        trav, rlx, sched_n, prn = _slab_counters(
            pa_src, w, dst, parent[src], ok, tile_first, tile_e, fail)
        if fail is not None:
            ok = ok & ~fail
        cand = jnp.where(ok, cand, jnp.inf)
        best = jax.ops.segment_min(cand, dst, num_segments=n_out)
        win = jnp.where(ok & (cand <= best[dst]), src, INT_MAX)
        winner = jax.ops.segment_min(win, dst, num_segments=n_out)
        improved = best < dist
        cnt = cnt + jnp.stack([
            trav, rlx,
            jnp.sum(improved.astype(jnp.int32)),
            jnp.sum((improved & (deg > 1)).astype(jnp.int32)),
            jnp.any(front > 0).astype(jnp.int32),
            sched_n, jnp.int32(1), prn])
        go = (jnp.any(improved) & (r + 1 < maxr)).astype(jnp.int32)
        return (jnp.where(improved, best, dist),
                jnp.where(improved, winner, parent),
                improved.astype(jnp.int32), cnt, go, r + 1)

    init = (dist, parent, frontier.astype(jnp.int32),
            jnp.zeros((8,), jnp.int32), jnp.int32(1), jnp.int32(0))
    dist2, parent2, front2, cnt, _, _ = jax.lax.while_loop(cond, body, init)
    return dist2, parent2, front2, cnt


def edge_relax_partials_ref(dist_src, paths_src, parent_src, src, dst, w,
                            tile_dst, tile_first, lb, ub, alt_lb=None,
                            prune_bound=None, *, block_v: int = 512,
                            tile_e: int = 512, n_dst_blocks: int = 1):
    """Arrays-only twin of :func:`..edge_relax.edge_relax_partials`:
    one-shot (min, winner) partials over a whole slab set plus the
    ``PARTIAL_COUNTERS`` vector."""
    n_out = n_dst_blocks * block_v
    pa_src = paths_src[src] > 0
    cand = dist_src[src] + w
    ok = pa_src & (cand >= lb) & (cand < ub)
    fail = None
    if alt_lb is not None:
        fail = cand + alt_lb[dst] > prune_bound
    trav, rlx, sched_n, prn = _slab_counters(
        pa_src, w, dst, parent_src[src], ok, tile_first, tile_e, fail)
    if fail is not None:
        ok = ok & ~fail
    cand = jnp.where(ok, cand, jnp.inf)
    best = jax.ops.segment_min(cand, dst, num_segments=n_out)
    win = jnp.where(ok & (cand <= best[dst]), src, INT_MAX)
    winner = jax.ops.segment_min(win, dst, num_segments=n_out)
    cnt = jnp.stack([trav, rlx, sched_n, prn])
    return best, winner, cnt
