"""Pure-jnp oracle for the edge_relax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_relax_ref(dist_block, frontier_block, src_local, dst_local, w,
                   lb, ub, *, block_v: int = 512):
    cand = dist_block[src_local] + w
    ok = (frontier_block[src_local] > 0) & (cand >= lb) & (cand < ub)
    cand = jnp.where(ok, cand, jnp.inf)
    return jax.ops.segment_min(cand, dst_local, num_segments=block_v)
