"""jit'd public wrapper for the edge_relax Pallas kernel.

On this CPU container the kernel always runs with interpret=True (the body
executes in Python/XLA for validation); on TPU set interpret=False.
"""
from __future__ import annotations

from .edge_relax import edge_relax, schedule_tiles
from .ref import edge_relax_ref

__all__ = ["edge_relax", "edge_relax_ref", "relax_bucket", "schedule_tiles"]


def relax_bucket(dist_block, frontier_block, src_local, dst_local, w,
                 tile_dst, tile_first, bucket_nonempty, lb, ub, *,
                 block_v: int = 512, n_dst_blocks: int = 1,
                 tile_e: int = 512, use_kernel: bool = True,
                 interpret: bool = True):
    """Dispatch: Pallas kernel (TPU hot path) or jnp reference fallback.

    Both paths return ``(vals, winners, n_tiles)`` over the full
    ``n_dst_blocks * block_v`` destination range; ``n_tiles`` is the
    number of tiles the frontier-compacted schedule keeps this round
    (the reference path runs the same prepass so the tile metrics are
    backend-independent).
    """
    if use_kernel:
        return edge_relax(dist_block, frontier_block, src_local, dst_local,
                          w, tile_dst, tile_first, bucket_nonempty, lb, ub,
                          block_v=block_v, tile_e=tile_e,
                          n_dst_blocks=n_dst_blocks, interpret=interpret)
    vals, wins = edge_relax_ref(dist_block, frontier_block, src_local,
                                dst_local, w, lb, ub, block_v=block_v,
                                n_dst_blocks=n_dst_blocks)
    _, n_tiles = schedule_tiles(frontier_block, src_local, w, tile_first,
                                tile_e)
    return vals, wins, n_tiles
