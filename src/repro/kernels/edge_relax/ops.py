"""jit'd public wrappers for the edge_relax Pallas kernels.

On this CPU container the kernels always run with interpret=True (the body
executes in Python/XLA for validation); on TPU set interpret=False.
"""
from __future__ import annotations

from .edge_relax import (FUSED_COUNTERS, PARTIAL_COUNTERS, edge_relax,
                         edge_relax_fused, edge_relax_partials,
                         schedule_tiles)
from .ref import edge_relax_fused_ref, edge_relax_partials_ref, edge_relax_ref

__all__ = ["edge_relax", "edge_relax_ref", "edge_relax_fused",
           "edge_relax_fused_ref", "edge_relax_partials",
           "edge_relax_partials_ref", "relax_bucket", "relax_fused",
           "relax_partials", "schedule_tiles", "FUSED_COUNTERS",
           "PARTIAL_COUNTERS"]


def relax_bucket(dist_block, frontier_block, src_local, dst_local, w,
                 tile_dst, tile_first, bucket_nonempty, lb, ub, *,
                 block_v: int = 512, n_dst_blocks: int = 1,
                 tile_e: int = 512, use_kernel: bool = True,
                 interpret: bool = True, alt_lb=None, prune_bound=None):
    """Dispatch: Pallas kernel (TPU hot path) or jnp reference fallback.

    Both paths return ``(vals, winners, n_tiles)`` over the full
    ``n_dst_blocks * block_v`` destination range; ``n_tiles`` is the
    number of tiles the frontier-compacted schedule keeps this round
    (the reference path runs the same prepass so the tile metrics are
    backend-independent).
    """
    if use_kernel:
        return edge_relax(dist_block, frontier_block, src_local, dst_local,
                          w, tile_dst, tile_first, bucket_nonempty, lb, ub,
                          alt_lb, prune_bound, block_v=block_v,
                          tile_e=tile_e, n_dst_blocks=n_dst_blocks,
                          interpret=interpret)
    vals, wins = edge_relax_ref(dist_block, frontier_block, src_local,
                                dst_local, w, lb, ub, alt_lb, prune_bound,
                                block_v=block_v, n_dst_blocks=n_dst_blocks)
    _, n_tiles = schedule_tiles(frontier_block, src_local, w, tile_first,
                                tile_e)
    return vals, wins, n_tiles


def relax_fused(dist, parent, frontier, deg, src, dst, w, tile_dst,
                tile_first, lb, ub, *, block_v: int = 512,
                tile_e: int = 512, fused_rounds: int = 4,
                use_kernel: bool = True, interpret: bool = True,
                alt_lb=None, prune_ub=None, prune_infl=None,
                prune_tgt=None):
    """Dispatch for the multi-round fused megakernel (see
    :func:`..edge_relax.edge_relax_fused`); both paths are bitwise
    interchangeable, including the ``FUSED_COUNTERS`` vector."""
    if use_kernel:
        return edge_relax_fused(dist, parent, frontier, deg, src, dst, w,
                                tile_dst, tile_first, lb, ub, alt_lb,
                                prune_ub, prune_infl, prune_tgt,
                                block_v=block_v, tile_e=tile_e,
                                fused_rounds=fused_rounds,
                                interpret=interpret)
    return edge_relax_fused_ref(dist, parent, frontier, deg, src, dst, w,
                                tile_dst, tile_first, lb, ub, alt_lb,
                                prune_ub, prune_infl, prune_tgt,
                                block_v=block_v, tile_e=tile_e,
                                fused_rounds=fused_rounds)


def relax_partials(dist_src, paths_src, parent_src, src, dst, w, tile_dst,
                   tile_first, lb, ub, *, block_v: int = 512,
                   tile_e: int = 512, n_dst_blocks: int = 1,
                   use_kernel: bool = True, interpret: bool = True,
                   alt_lb=None, prune_bound=None):
    """Dispatch for the single-round whole-slab partials pass (see
    :func:`..edge_relax.edge_relax_partials`)."""
    if use_kernel:
        return edge_relax_partials(dist_src, paths_src, parent_src, src,
                                   dst, w, tile_dst, tile_first, lb, ub,
                                   alt_lb, prune_bound,
                                   block_v=block_v, tile_e=tile_e,
                                   n_dst_blocks=n_dst_blocks,
                                   interpret=interpret)
    return edge_relax_partials_ref(dist_src, paths_src, parent_src, src,
                                   dst, w, tile_dst, tile_first, lb, ub,
                                   alt_lb, prune_bound,
                                   block_v=block_v, tile_e=tile_e,
                                   n_dst_blocks=n_dst_blocks)
