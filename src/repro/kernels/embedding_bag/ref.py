"""Pure-jnp oracle for embedding_bag (take + weighted segment sum)."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids, weights=None, *, mode: str = "sum"):
    b, l = ids.shape
    vecs = jnp.take(table, ids.reshape(-1), axis=0).reshape(b, l, -1)
    vecs = vecs.astype(jnp.float32)
    if weights is None:
        weights = jnp.ones((b, l), jnp.float32)
    out = jnp.einsum("bld,bl->bd", vecs, weights.astype(jnp.float32))
    if mode == "mean":
        out = out / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return out
