"""Pallas TPU EmbeddingBag kernel — the recsys hot path (DESIGN.md §5).

TPU pattern: scalar-prefetched lookup indices drive the *BlockSpec index
map*, so each grid step DMAs exactly one embedding-table row block from
HBM into VMEM (the splash-attention block-table idiom; no dense one-hot,
no full-table streaming).  The grid iterates all B*L lookups; the output
bag block is revisited for the L lookups of one bag and accumulated
in-place (sum or weighted-sum; mean finalized on the last lookup).

Production note: on a 256-chip pod the table rows are sharded over the
``model`` axis; each shard runs this kernel over the lookups routed to it
(ids bucketing happens in repro/models/recsys via the same sort-dispatch
the MoE layer uses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(ids_ref, w_ref, row_ref, out_ref, *, l: int, mode: str):
    i = pl.program_id(0)
    li = i % l

    @pl.when(li == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    weight = w_ref[i]
    out_ref[...] += row_ref[...].astype(jnp.float32) * weight

    if mode == "mean":
        @pl.when(li == l - 1)
        def _fin():
            total = w_ref[pl.ds((i // l) * l, l)].sum()
            out_ref[...] = out_ref[...] / jnp.maximum(total, 1e-9)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag(table, ids, weights=None, *, mode: str = "sum",
                  interpret: bool = True):
    """table [V, D]; ids [B, L] int32; weights [B, L] or None -> [B, D]."""
    b, l = ids.shape
    v, d = table.shape
    flat_ids = ids.reshape(-1)
    if weights is None:
        weights = jnp.ones((b * l,), jnp.float32)
    else:
        weights = weights.reshape(-1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # ids, weights
        grid=(b * l,),
        in_specs=[
            # one table row per step, row index from the prefetched ids
            pl.BlockSpec((1, d), lambda i, ids_p, w_p: (ids_p[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_p, w_p: (i // l, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, l=l, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(flat_ids, weights, table)
    return out
