"""jit'd public wrapper for the embedding_bag kernel."""
from __future__ import annotations

from .embedding_bag import embedding_bag
from .ref import embedding_bag_ref

__all__ = ["embedding_bag", "embedding_bag_ref"]
