"""Pallas TPU flash attention (forward), FA-2 schedule — arXiv:2307.08691.

Grid (B, H, n_q_blocks, n_kv_blocks); the kv axis is innermost and
sequential, carrying the online-softmax state (running max m, running sum
l, weighted accumulator acc) in VMEM scratch.  Blocks are MXU-aligned
((block_q x head_dim) @ (head_dim x block_k) contractions).  GQA maps
query head h to kv head h // (H // H_kv) inside the k/v BlockSpec index
maps, so grouped heads stream the same kv tiles.

Used by the 32k prefill/serving path on TPU (interpret=True on this CPU
container, asserted against ref.py across shapes/dtypes in
tests/test_kernels.py).  Causal and sliding-window masks supported.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces (interpret mode accepts them too)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int, n_k: int, t_real: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < t_real          # padded keys never attend
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q [B,H,S,D]; k,v [B,Hkv,T,D] -> out [B,H,S,D] (GQA-aware)."""
    b, h, s, d = q.shape
    _, h_kv, t, _ = k.shape
    group = h // h_kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    s_pad = -(-s // bq) * bq
    t_pad = -(-t // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    n_q, n_k = s_pad // bq, t_pad // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=bq, block_k=bk, causal=causal,
        window=window, n_k=n_k, t_real=t)
    scratch = [_VMEM((bq,), jnp.float32), _VMEM((bq,), jnp.float32),
               _VMEM((bq, d), jnp.float32)] if _VMEM is not None else []

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s]
