"""Pure-jnp oracle for flash attention (dense fp32 softmax)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    b, h, s, d = q.shape
    _, h_kv, t, _ = k.shape
    group = h // h_kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32)
                      ).astype(q.dtype)
