"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

from .flash_attn import flash_attention
from .ref import flash_attention_ref

__all__ = ["flash_attention", "flash_attention_ref"]
