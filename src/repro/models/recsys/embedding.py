"""Sharded EmbeddingBag — the recsys hot path, built from scratch.

JAX has no ``nn.EmbeddingBag``; per the assignment this IS part of the
system: ``jnp.take`` over the (row-sharded) table + ``segment_sum`` (or
mean) over bag ids, with optional per-sample weights.  The table's rows are
sharded over the ``model`` mesh axis (EP-style); XLA turns the gather into
an all-to-all-limited collective — the Pallas ``embedding_bag`` kernel
(repro/kernels) covers the single-chip hot loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, ids, bag_ids, n_bags, weights=None, mode="sum"):
    """table [V, D]; ids [L] int32; bag_ids [L] int32 (sorted or not).

    Returns [n_bags, D].  ``weights`` [L] optional per-lookup scale.
    """
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None].astype(vecs.dtype)
    out = jax.ops.segment_sum(vecs, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(ids, vecs.dtype) if weights is None
            else weights.astype(vecs.dtype), bag_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt[:, None], 1e-9)
    return out


def embedding_bag_batched(table, ids, mask=None, mode="sum"):
    """Dense variant: ids [B, L] -> [B, D] (mask [B, L] for padding)."""
    vecs = jnp.take(table, ids.reshape(-1), axis=0)
    vecs = vecs.reshape(*ids.shape, table.shape[-1])
    if mask is not None:
        vecs = jnp.where(mask[..., None], vecs, 0.0)
    out = vecs.sum(-2)
    if mode == "mean":
        d = (mask.sum(-1, keepdims=True) if mask is not None
             else jnp.float32(ids.shape[-1]))
        out = out / jnp.maximum(d, 1.0)
    return out
