"""MIND: Multi-Interest Network with Dynamic routing — arXiv:1904.08030.

Assigned config: embed_dim=64, n_interests=4, capsule_iters=3,
interaction=multi-interest.

Pipeline:
  item table [V, D] (huge, row-sharded)  ->  behavior embeddings [B, L, D]
  -> B2I dynamic routing (capsule_iters rounds) -> interests [B, K, D]
  -> label-aware attention (training) / max-score retrieval (serving).

Training uses sampled-softmax with in-batch negatives (the production
standard when V ~ 1e7).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..layers import dense_init


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 10_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    pow_p: float = 2.0          # label-aware attention sharpness
    dtype: object = jnp.float32


def init_params(cfg: MINDConfig, key):
    k1, k2 = jax.random.split(key)
    return {
        "item_embed": (jax.random.normal(k1, (cfg.n_items, cfg.embed_dim),
                                         jnp.float32) * 0.02
                       ).astype(cfg.dtype),
        # shared bilinear map S for B2I routing
        "s_map": dense_init(k2, cfg.embed_dim, cfg.embed_dim, cfg.dtype),
    }


def multi_interest(cfg: MINDConfig, params, hist_ids, hist_mask):
    """B2I dynamic routing.  hist_ids [B, L] -> interests [B, K, D]."""
    b, l = hist_ids.shape
    k, d = cfg.n_interests, cfg.embed_dim
    e = jnp.take(params["item_embed"], hist_ids, axis=0)     # [B, L, D]
    e = jnp.where(hist_mask[..., None], e, 0.0)
    eh = jnp.einsum("bld,de->ble", e, params["s_map"])       # behavior caps

    # fixed (deterministic per-position) routing-logit init, as in the paper
    # ("randomly" initialized but frozen); a hash of position/slot keeps it
    # reproducible without threading an rng through serving.
    init_b = jnp.sin(jnp.arange(l, dtype=jnp.float32)[:, None] *
                     (1.0 + jnp.arange(k, dtype=jnp.float32)[None, :]))
    blog = jnp.broadcast_to(init_b, (b, l, k)).astype(jnp.float32)

    def squash(s):
        n2 = jnp.sum(s * s, -1, keepdims=True)
        return (n2 / (1 + n2)) * s / jnp.sqrt(n2 + 1e-9)

    interests = None
    for it in range(cfg.capsule_iters):
        w = jax.nn.softmax(blog, axis=-1)                    # over K
        w = jnp.where(hist_mask[..., None], w, 0.0)
        s = jnp.einsum("blk,bld->bkd", w, eh)
        interests = squash(s)
        if it < cfg.capsule_iters - 1:
            blog = blog + jnp.einsum("bkd,bld->blk", interests, eh)
    return interests.astype(cfg.dtype)                       # [B, K, D]


def label_aware_attention(cfg: MINDConfig, interests, target_e):
    """Paper Eq: v_u = sum_k softmax(pow(u_k^T e_t, p)) u_k."""
    logits = jnp.einsum("bkd,bd->bk", interests.astype(jnp.float32),
                        target_e.astype(jnp.float32))
    w = jax.nn.softmax(jnp.power(jnp.maximum(logits, 1e-9), cfg.pow_p), -1)
    return jnp.einsum("bk,bkd->bd", w.astype(interests.dtype), interests)


def train_loss(cfg: MINDConfig, params, batch):
    """Sampled softmax with in-batch negatives.

    batch: {"hist": [B, L], "hist_mask": [B, L], "target": [B]}.
    """
    interests = multi_interest(cfg, params, batch["hist"], batch["hist_mask"])
    tgt_e = jnp.take(params["item_embed"], batch["target"], axis=0)
    user = label_aware_attention(cfg, interests, tgt_e)       # [B, D]
    logits = jnp.einsum("bd,cd->bc", user.astype(jnp.float32),
                        tgt_e.astype(jnp.float32)) / math.sqrt(cfg.embed_dim)
    labels = jnp.arange(logits.shape[0])
    lse = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    loss = (lse - ll).mean()
    return loss, {"loss": loss}


def serve_interests(cfg: MINDConfig, params, batch):
    """Online inference: user interests [B, K, D]."""
    return multi_interest(cfg, params, batch["hist"], batch["hist_mask"])


def retrieval_scores(cfg: MINDConfig, params, interests, cand_ids):
    """Score 1 user's interests against a large candidate set.

    interests [K, D]; cand_ids [C] -> scores [C] (max over interests —
    batched dot, NOT a loop)."""
    cand = jnp.take(params["item_embed"], cand_ids, axis=0)   # [C, D]
    s = jnp.einsum("kd,cd->kc", interests.astype(jnp.float32),
                   cand.astype(jnp.float32))
    return jnp.max(s, axis=0)
