"""Decoder-only transformer LM (dense + MoE) in pure JAX.

Features required by the assigned architecture pool:
  * GQA / MQA / MHA (``n_kv``), explicit ``head_dim`` (Qwen3 uses 128 with
    d_model=1024), RoPE, optional qk-norm (Qwen3), SwiGLU or GELU MLP
    (granite-34b uses the 2-matrix GELU MLP of gpt_bigcode).
  * MoE layers with shared + routed experts, top-k routing, capacity-based
    sort dispatch (DeepSeekMoE, granite-MoE) and a load-balance aux loss.
  * Layer stack as ``lax.scan`` over stacked parameters (keeps HLO size and
    compile time O(1) in depth — essential for the 88-layer dry-run) with
    per-layer ``jax.checkpoint`` (remat).
  * Optional sliding-window attention (bonus ``qwen3-0.6b-swa`` config for
    the long-context cell) and sequence-sharded residual stream (Megatron
    SP) via sharding constraints injected by ``repro.parallel``.

Decode uses a KV cache ([L, B, S_cache, Kv, Dh] per K/V) updated at
per-sequence positions; RoPE is applied pre-cache (absolute positions), so a
ring buffer works for SWA decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import (apply_rope, dense_init, embed_init, gelu_mlp, rms_norm,
                     softmax_cross_entropy, swiglu)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    mlp: str = "swiglu"               # "swiglu" | "gelu"
    qk_norm: bool = False
    rope_theta: float = 1e4
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0                 # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # attention
    attn_window: int = 0              # 0 => full causal
    tied_embed: bool = False          # lm_head = embed.T (qwen3, phi4)
    # numerics
    dtype: Any = jnp.bfloat16
    # distribution
    seq_shard: bool = False           # Megatron-SP residual stream
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        d, hd, h, kv = self.d_model, self.hd, self.n_heads, self.n_kv
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.moe:
            e_ff = (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
            mlp = (self.n_experts + self.n_shared) * e_ff + d * self.n_experts
        else:
            mlp = (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        n_embed = (1 if self.tied_embed else 2) * self.vocab * d
        return (self.n_layers * per_layer + n_embed + d +
                (2 * self.n_layers * hd if self.qk_norm else 0))


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> dict:
    d, hd, h, kv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv
    L = cfg.n_layers
    keys = jax.random.split(key, 16)
    dt = cfg.dtype

    def stack(fn, key, *shape_args):
        ks = jax.random.split(key, L)
        return jnp.stack([fn(k, *shape_args) for k in ks])

    # attention — init per-layer then stack (cheap at init time; the arrays
    # are created once on host)
    layer = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
        "wq": stack(dense_init, keys[0], d, h * hd, dt),
        "wk": stack(dense_init, keys[1], d, kv * hd, dt),
        "wv": stack(dense_init, keys[2], d, kv * hd, dt),
        "wo": stack(lambda k, a, b, t: dense_init(k, a, b, t,
                    scale=1.0 / (a ** 0.5 * (2 * L) ** 0.5)),
                    keys[3], h * hd, d, dt),
    }
    if cfg.qk_norm:
        layer["q_norm"] = jnp.ones((L, hd), dt)
        layer["k_norm"] = jnp.ones((L, hd), dt)

    if cfg.moe:
        e = cfg.n_experts
        f = cfg.d_ff

        def estack(key, d_in, d_out, scale=None):
            ks = jax.random.split(key, L * e).reshape(L, e, 2)
            return jnp.stack([
                jnp.stack([dense_init(ks[l, i], d_in, d_out, dt, scale)
                           for i in range(e)]) for l in range(L)])

        layer["router"] = stack(lambda k, a, b, t: dense_init(k, a, b, t),
                                keys[4], d, e, jnp.float32)
        layer["e_up"] = estack(keys[5], d, f)
        layer["e_down"] = estack(keys[6], f, d,
                                 scale=1.0 / (f ** 0.5 * (2 * L) ** 0.5))
        if cfg.mlp == "swiglu":
            layer["e_gate"] = estack(keys[7], d, f)
        if cfg.n_shared:
            fs = f * cfg.n_shared
            layer["s_up"] = stack(dense_init, keys[8], d, fs, dt)
            layer["s_down"] = stack(lambda k, a, b, t: dense_init(
                k, a, b, t, scale=1.0 / (a ** 0.5 * (2 * L) ** 0.5)),
                keys[9], fs, d, dt)
            if cfg.mlp == "swiglu":
                layer["s_gate"] = stack(dense_init, keys[10], d, fs, dt)
    else:
        layer["w_up"] = stack(dense_init, keys[4], d, cfg.d_ff, dt)
        layer["w_down"] = stack(lambda k, a, b, t: dense_init(
            k, a, b, t, scale=1.0 / (a ** 0.5 * (2 * L) ** 0.5)),
            keys[5], cfg.d_ff, d, dt)
        if cfg.mlp == "swiglu":
            layer["w_gate"] = stack(dense_init, keys[6], d, cfg.d_ff, dt)

    out = {
        "embed": embed_init(keys[11], cfg.vocab, d, dt),
        "layers": layer,
        "ln_f": jnp.ones((d,), dt),
    }
    if not cfg.tied_embed:
        out["lm_head"] = dense_init(keys[12], d, cfg.vocab, dt)
    return out


def _logits(cfg: LMConfig, params, x, two_d: bool = False):
    if cfg.tied_embed:
        eq = "bd,vd->bv" if two_d else "bsd,vd->bsv"
        return jnp.einsum(eq, x, params["embed"])
    eq = "bd,dv->bv" if two_d else "bsd,dv->bsv"
    return jnp.einsum(eq, x, params["lm_head"])


# ---------------------------------------------------------------------------
# attention / mlp / moe blocks
# ---------------------------------------------------------------------------

def _constrain(x, spec: Optional[P]):
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # outside a mesh context (single-device smoke tests)


def _sdpa_dense(cfg: LMConfig, q, k_all, v_all, positions, t_pos, causal):
    """Materialized-scores attention (small S only / smoke tests)."""
    hd = cfg.hd
    scores = jnp.einsum("bskhd,btkd->bskht", q, k_all).astype(jnp.float32)
    scores = scores / (hd ** 0.5)
    qp = positions[:, :, None, None, None]
    tp = t_pos[:, None, None, None, :]
    mask = jnp.ones_like(scores, bool)
    if causal:
        mask &= tp <= qp
    if cfg.attn_window:
        mask &= tp > qp - cfg.attn_window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (padding) produce NaN; zero them
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    return jnp.einsum("bskht,btkd->bskhd", probs.astype(q.dtype), v_all)


def _sdpa_blockwise(cfg: LMConfig, q, k_all, v_all, positions, t_pos, causal,
                    block_q: int = 512, block_k: int = 1024):
    """Online-softmax blockwise attention (the XLA 'flash' fallback; the
    Pallas kernel in repro/kernels/flash_attn implements the same schedule
    for TPU).  Never materializes the S x T score matrix: peak extra memory
    is one (block_q x block_k) tile per head group."""
    b, s, kv, hg, hd = q.shape
    t = k_all.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq, nk = -(-s // bq), -(-t // bk)
    pad_q, pad_k = nq * bq - s, nk * bk - t
    qp = jnp.pad(positions, ((0, 0), (0, pad_q)))
    tp = jnp.pad(t_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    qb = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kb = jnp.pad(k_all, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vb = jnp.pad(v_all, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qb = qb.reshape(b, nq, bq, kv, hg, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = kb.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = vb.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    qp = qp.reshape(b, nq, bq).transpose(1, 0, 2)
    tp = tp.reshape(b, nk, bk).transpose(1, 0, 2)
    scale = 1.0 / (hd ** 0.5)

    def q_block(args):
        qi, qpi = args                            # [B,bq,KV,HG,HD], [B,bq]

        def kv_step(carry, kv_args):
            m, l, acc = carry
            ki, vi, tpi = kv_args
            sc = jnp.einsum("bskhd,btkd->bskht", qi, ki
                            ).astype(jnp.float32) * scale
            msk = tpi[:, None, None, None, :] >= 0
            if causal:
                msk &= tpi[:, None, None, None, :] <= \
                    qpi[:, :, None, None, None]
            if cfg.attn_window:
                msk &= tpi[:, None, None, None, :] > \
                    qpi[:, :, None, None, None] - cfg.attn_window
            sc = jnp.where(msk, sc, -jnp.inf)
            m2 = jnp.maximum(m, jnp.max(sc, axis=-1))
            m2s = jnp.where(jnp.isfinite(m2), m2, 0.0)
            p = jnp.exp(sc - m2s[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m2s, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l2 = l * corr + jnp.sum(p, axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bskht,btkd->bskhd", p.astype(qi.dtype), vi
            ).astype(jnp.float32)
            return (m2, l2, acc2), None

        m0 = jnp.full((b, bq, kv, hg), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, bq, kv, hg), jnp.float32)
        a0 = jnp.zeros((b, bq, kv, hg, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, tp))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (qb, qp))          # [nq, B, bq, KV, HG, HD]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, kv, hg, hd)
    return out[:, :s].astype(q.dtype)


def attention(cfg: LMConfig, lp: dict, x, positions, kv_positions=None,
              k_cache=None, v_cache=None, causal=True):
    """Attention dispatcher.  x: [B, S, D].  If k_cache/v_cache are given
    they are the *full* key/value set (decode); otherwise self-attention.
    Large S*T uses the blockwise online-softmax path (never materializes
    S x T scores)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = jnp.einsum("bsd,de->bse", x, lp["wq"]).reshape(b, s, kv, h // kv, hd)
    k = jnp.einsum("bsd,de->bse", x, lp["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,de->bse", x, lp["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    # RoPE (positions: [B, S])
    q = apply_rope(q.reshape(b, s, kv * (h // kv), hd), positions,
                   cfg.rope_theta).reshape(b, s, kv, h // kv, hd)
    k = apply_rope(k, positions, cfg.rope_theta)

    if k_cache is not None:
        k_all, v_all = k_cache, v_cache          # [B, T, KV, HD]
        t_pos = kv_positions                     # [B, T]
    else:
        k_all, v_all, t_pos = k, v, positions

    t = k_all.shape[1]
    if s * t > (1 << 21):
        out = _sdpa_blockwise(cfg, q, k_all, v_all, positions, t_pos, causal)
    else:
        out = _sdpa_dense(cfg, q, k_all, v_all, positions, t_pos, causal)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out, lp["wo"]), k, v


def moe_block(cfg: LMConfig, lp: dict, x):
    """Top-k routed experts with capacity-based sort dispatch + shared experts.

    Returns (y, aux_loss).  x: [B, S, D] -> flattened token dispatch.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * s, d)
    t = b * s
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), lp["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): e * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean((jax.nn.one_hot(idx, e).sum(1)), axis=0)
    aux = cfg.aux_loss_coef * e * jnp.sum(me * ce)

    cap = max(int(t * k / e * cfg.capacity_factor), 8)
    # sort token-choice pairs by expert; rank within expert via searchsorted
    flat_e = idx.reshape(-1)                               # [T*k]
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first
    keep = rank < cap
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, se, 0),
                 jnp.where(keep, rank, 0)].add(
        jnp.where(keep[:, None], xt[st_], 0.0))

    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
    if cfg.mlp == "swiglu":
        gph = jnp.einsum("ecd,edf->ecf", buf, lp["e_gate"])
        up = jnp.einsum("ecd,edf->ecf", buf, lp["e_up"])
        hidden = act(gph) * up
    else:
        hidden = act(jnp.einsum("ecd,edf->ecf", buf, lp["e_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, lp["e_down"])

    y_tok = out_buf[se, jnp.minimum(rank, cap - 1)]        # [T*k, D]
    y_tok = jnp.where(keep[:, None], y_tok, 0.0) * sg[:, None].astype(x.dtype)
    y = jax.ops.segment_sum(y_tok, st_, num_segments=t)

    if cfg.n_shared:
        if cfg.mlp == "swiglu":
            y = y + swiglu(xt, lp["s_gate"], lp["s_up"], lp["s_down"])
        else:
            y = y + gelu_mlp(xt, lp["s_up"], lp["s_down"])
    return y.reshape(b, s, d), aux


def mlp_block(cfg: LMConfig, lp: dict, x):
    if cfg.moe:
        return moe_block(cfg, lp, x)
    if cfg.mlp == "swiglu":
        return swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"]), 0.0
    return gelu_mlp(x, lp["w_up"], lp["w_down"]), 0.0


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, act_spec):
    def body(x, lp, positions):
        a, _, _ = attention(cfg, lp, rms_norm(x, lp["ln1"]), positions)
        x = _constrain(x + a, act_spec)
        m, aux = mlp_block(cfg, lp, rms_norm(x, lp["ln2"]))
        x = _constrain(x + m, act_spec)
        return x, aux
    return body


def forward(cfg: LMConfig, params: dict, tokens, act_spec: Optional[P] = None):
    """Training/prefill forward: tokens [B, S] -> logits [B, S, V]."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _constrain(x, act_spec)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    body = _layer_fwd(cfg, act_spec)

    def scan_body(carry, lp):
        x, aux = carry
        if cfg.remat:
            x2, a = jax.checkpoint(
                lambda x_, lp_: body(x_, lp_, positions),
                prevent_cse=False)(x, lp)
        else:
            x2, a = body(x, lp, positions)
        return (x2, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.float32(0.0)),
                               params["layers"])
    x = rms_norm(x, params["ln_f"])
    logits = _logits(cfg, params, x)
    return logits, aux / cfg.n_layers


def loss_fn(cfg: LMConfig, params: dict, batch: dict,
            act_spec: Optional[P] = None):
    logits, aux = forward(cfg, params, batch["tokens"], act_spec)
    loss = softmax_cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
    mask = batch.get("mask")
    if mask is not None:
        loss = (loss * mask[:, 1:]).sum() / jnp.maximum(mask[:, 1:].sum(), 1)
    else:
        loss = loss.mean()
    return loss + aux, {"loss": loss, "aux": aux}


# --- serving ---------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, s_cache: int):
    hd, kv, L = cfg.hd, cfg.n_kv, cfg.n_layers
    shape = (L, batch, s_cache, kv, hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def prefill(cfg: LMConfig, params: dict, tokens, s_cache: int,
            act_spec: Optional[P] = None, batch_chunks: int = 1):
    """Run the prompt, return (cache, last_logits).

    ``batch_chunks > 1`` processes the request batch in sequential groups
    (lax.map) — chunked prefill in the batch dimension, bounding the MoE
    dispatch buffers and attention working set to one group at a time.
    """
    if batch_chunks > 1:
        b, s = tokens.shape
        g = b // batch_chunks
        tok_g = tokens.reshape(batch_chunks, g, s)

        def one(tg):
            return prefill(cfg, params, tg, s_cache, act_spec, 1)

        cache_g, logits_g = jax.lax.map(one, tok_g)
        cache = {
            "k": jnp.moveaxis(cache_g["k"], 0, 1).reshape(
                cfg.n_layers, b, s_cache, cfg.n_kv, cfg.hd),
            "v": jnp.moveaxis(cache_g["v"], 0, 1).reshape(
                cfg.n_layers, b, s_cache, cfg.n_kv, cfg.hd),
            "pos": cache_g["pos"].reshape(b),
        }
        return cache, logits_g.reshape(b, -1)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _constrain(x, act_spec)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def scan_body(x, lp):
        a, k, v = attention(cfg, lp, rms_norm(x, lp["ln1"]), positions)
        x = _constrain(x + a, act_spec)
        m, _ = mlp_block(cfg, lp, rms_norm(x, lp["ln2"]))
        x = _constrain(x + m, act_spec)
        kk = k.reshape(b, s, cfg.n_kv, cfg.hd)
        vv = v.reshape(b, s, cfg.n_kv, cfg.hd)
        return x, (kk, vv)

    body = jax.checkpoint(scan_body, prevent_cse=False) if cfg.remat \
        else scan_body
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    logits = _logits(cfg, params, x[:, -1], two_d=True)
    pad = s_cache - s
    if pad < 0:
        raise ValueError("cache smaller than prompt")
    k_cache = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_cache = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_cache, "v": v_cache,
             "pos": jnp.full((b,), s, jnp.int32)}
    return cache, logits


def decode_step(cfg: LMConfig, params: dict, cache: dict, tok,
                act_spec: Optional[P] = None):
    """One decode step.  tok: [B] int32.  Returns (logits [B, V], cache)."""
    b = tok.shape[0]
    s_cache = cache["k"].shape[2]
    pos = cache["pos"]                                   # [B]
    x = params["embed"][tok][:, None, :].astype(cfg.dtype)   # [B, 1, D]
    kv_pos_base = jnp.arange(s_cache, dtype=jnp.int32)

    if cfg.attn_window and s_cache == cfg.attn_window:
        write_at = pos % s_cache                          # ring buffer
        # absolute position of each cache slot given the ring write pattern:
        # slots <= pos%S were (re)written this lap (incl. the new token),
        # slots beyond hold the previous lap; negatives (= never written in
        # lap 0) are masked out by the tp >= 0 test in the attention mask.
        laps = (pos[:, None] // s_cache) * s_cache + kv_pos_base[None, :]
        kv_positions = jnp.where(kv_pos_base[None, :] <= (pos[:, None] %
                                 s_cache), laps, laps - s_cache)
    else:
        write_at = pos
        kv_positions = jnp.broadcast_to(kv_pos_base, (b, s_cache))

    def scan_body(x, xs):
        lp, kc, vc = xs                                   # kc: [B, T, KV, HD]
        xn = rms_norm(x, lp["ln1"])
        # project new k/v, write into cache, attend over the full cache
        q = jnp.einsum("bsd,de->bse", xn, lp["wq"]).reshape(
            b, 1, cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.hd)
        k = jnp.einsum("bsd,de->bse", xn, lp["wk"]).reshape(b, 1, cfg.n_kv,
                                                            cfg.hd)
        v = jnp.einsum("bsd,de->bse", xn, lp["wv"]).reshape(b, 1, cfg.n_kv,
                                                            cfg.hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        q = apply_rope(q.reshape(b, 1, -1, cfg.hd), pos[:, None],
                       cfg.rope_theta).reshape(q.shape)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        kc = kc.at[jnp.arange(b), write_at].set(k[:, 0])
        vc = vc.at[jnp.arange(b), write_at].set(v[:, 0])
        scores = jnp.einsum("bskhd,btkd->bskht", q, kc).astype(jnp.float32)
        scores = scores / (cfg.hd ** 0.5)
        tp = kv_positions[:, None, None, None, :]
        qp = pos[:, None, None, None, None]
        mask = (tp <= qp) & (tp >= 0)
        if cfg.attn_window:
            mask &= tp > qp - cfg.attn_window
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = jnp.where(jnp.any(mask, -1, keepdims=True), probs, 0.0)
        out = jnp.einsum("bskht,btkd->bskhd", probs.astype(x.dtype), vc)
        out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
        x = x + jnp.einsum("bse,ed->bsd", out, lp["wo"])
        m, _ = mlp_block(cfg, lp, rms_norm(x, lp["ln2"]))
        return x + m, (kc, vc)

    x, (ks, vs) = jax.lax.scan(scan_body, x, (params["layers"], cache["k"],
                                              cache["v"]))
    x = rms_norm(x, params["ln_f"])
    logits = _logits(cfg, params, x[:, 0], two_d=True)
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache
