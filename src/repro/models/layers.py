"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are plain dict pytrees; every init function takes an explicit
``jax.random`` key and returns arrays in the requested dtype.  Compute is
performed in ``compute_dtype`` (bf16 by default) with fp32 normalization
statistics — the usual large-scale training recipe.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM codebases)."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    """RMSNorm with fp32 statistics."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float = 1e4):
    """Rotary embedding inverse frequencies [head_dim//2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """Apply rotary embedding.  x: [..., seq, n_heads, head_dim],
    positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv    # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, w_down):
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(u), w_down)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """Stable CE with fp32 logsumexp; labels [..., ] int; logits [..., V]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse ** 2
    return loss
