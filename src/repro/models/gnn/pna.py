"""PNA (Principal Neighbourhood Aggregation) — arXiv:2004.05718.

Four aggregators (mean/max/min/std) x three degree scalers (identity,
amplification, attenuation) -> 12-way concat -> linear.  Assigned config:
4 layers, d_hidden=75.  Layer 0 (d_in) separate; uniform layers scanned.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..layers import dense_init
from .common import GraphBatch, mlp_apply, mlp_init, seg_sum, shard0
from .sharded_ops import gather0, scatter_max0, scatter_min0, scatter_sum0


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 75
    n_classes: int = 16
    avg_log_deg: float = 2.0   # delta: E[log(d+1)] over the training graphs
    graph_level: bool = False
    dtype: object = jnp.float32
    remat: bool = False


def _layer_init(key, d_in, d_hidden, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_pre": dense_init(k1, 2 * d_in, d_hidden, dtype),
        "w_post": dense_init(k2, 12 * d_hidden + d_in, d_hidden, dtype),
    }


def init_params(cfg: PNAConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 2)
    layer0 = _layer_init(ks[0], cfg.d_in, cfg.d_hidden, cfg.dtype)
    rest = [_layer_init(ks[i], cfg.d_hidden, cfg.d_hidden, cfg.dtype)
            for i in range(1, cfg.n_layers)]
    head = mlp_init(ks[-1], [cfg.d_hidden, cfg.n_classes], cfg.dtype)
    return {"layer0": layer0,
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *rest),
            "head": head}


def _aggregate(ctx, msg, receivers, n, edge_mask, deg):
    """Fused: one scatter-sum carries [msg, msg^2]; one scatter-max carries
    [msg, -msg] (min = -max(-x)) — 2 full-size reduce partials per layer
    instead of 4 (halves the collective count and peak buffers)."""
    if edge_mask is not None:
        msg = jnp.where(edge_mask[:, None], msg, 0.0)
    d = msg.shape[-1]
    dt = msg.dtype  # keep the compute dtype — f32 scalars would promote
    denom = jnp.maximum(deg, 1.0).astype(dt)
    sums = scatter_sum0(ctx, jnp.concatenate([msg, msg * msg], -1),
                        receivers, n)
    mean = sums[:, :d] / denom
    sq = sums[:, d:] / denom
    std = jnp.sqrt(jnp.maximum(sq - mean * mean,
                               jnp.asarray(1e-8, dt)))
    big = jnp.asarray(3e30, dt)
    mm_in = jnp.concatenate([msg, -msg], -1)
    if edge_mask is not None:
        mm_in = jnp.where(edge_mask[:, None], mm_in, -big)
    mm = scatter_max0(ctx, mm_in, receivers, n)
    mx = jnp.clip(mm[:, :d], -big, big)
    mn = jnp.clip(-mm[:, d:], -big, big)
    return [mean, mx, mn, std]


def forward(cfg: PNAConfig, params, gb: GraphBatch):
    h = gb.node_feat.astype(cfg.dtype)
    n = h.shape[0]
    ones = jnp.ones((gb.receivers.shape[0], 1), jnp.float32)
    if gb.edge_mask is not None:
        ones = jnp.where(gb.edge_mask[:, None], ones, 0.0)
    deg = scatter_sum0(gb.shard_ctx, ones, gb.receivers, n)
    log_d = jnp.log1p(deg[:, 0])[:, None].astype(cfg.dtype)
    s_amp = log_d / jnp.asarray(cfg.avg_log_deg, cfg.dtype)
    s_att = jnp.asarray(cfg.avg_log_deg, cfg.dtype) / \
        jnp.maximum(log_d, jnp.asarray(1e-6, cfg.dtype))

    def layer(h, lp):
        msg_in = jnp.concatenate([gather0(gb.shard_ctx, h, gb.senders),
                                  gather0(gb.shard_ctx, h, gb.receivers)],
                                 -1)
        msg = jax.nn.relu(msg_in @ lp["w_pre"])
        aggs = _aggregate(gb.shard_ctx, msg, gb.receivers, n, gb.edge_mask,
                          deg)
        scaled = []
        for a in aggs:
            scaled += [a, a * s_amp, a * s_att]
        z = jnp.concatenate(scaled + [h], -1)
        return shard0(gb, jax.nn.relu(z @ lp["w_post"]))

    h = layer(h, params["layer0"])

    def body(h, lp):
        if cfg.remat:
            return jax.checkpoint(layer, prevent_cse=False)(h, lp), None
        return layer(h, lp), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    if cfg.graph_level:
        pooled = seg_sum(h, gb.graph_ids, gb.n_graphs)
        return mlp_apply(params["head"], pooled)
    return mlp_apply(params["head"], h)
