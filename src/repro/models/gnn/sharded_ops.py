"""Manually-sharded gather / segment-reduce primitives for full-batch GNNs.

XLA's SPMD partitioner cannot partition a gather/scatter with arbitrary
indices — it replicates the node operand, which at ogbn-products scale
(2.45M x 70 fp32 = 0.69 GB x ~90 live buffers) blows per-device HBM.
These primitives wrap the ops in ``shard_map`` so state stays sharded:

* ``gather0``      — all-gather the (small) node table once, index locally:
                     transient = one full node table per device.
* ``scatter_sum0`` — local full-size accumulation + ``psum_scatter``:
                     returns a node-sharded result, transient = one full
                     node table.
* ``scatter_max0/min0`` — same pattern via all_to_all reduce (the SSSP v2
                     exchange — the paper's engine reused for GNN
                     aggregation).

All are differentiable (collectives have registered transposes).  When
``gb.shard_ctx is None`` (single-device smoke tests) they reduce to plain
jnp ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _nshards(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def gather0(ctx, table, idx):
    """table [N, F] (dim0-sharded), idx [M] (dim0-sharded) -> [M, F]."""
    if ctx is None:
        return table[idx]
    mesh, axes = ctx

    def body(tbl, ix):
        full = jax.lax.all_gather(tbl, axes, tiled=True)
        return full[ix]

    spec2 = P(axes, *([None] * (table.ndim - 1)))
    return shard_map(body, mesh=mesh, in_specs=(spec2, P(axes)),
                     out_specs=P(axes, *([None] * (table.ndim - 1))),
                     check_rep=False)(table, idx)


def scatter_sum0(ctx, values, idx, n):
    """values [M, F] + idx [M] -> [n, F], all dim0-sharded."""
    if ctx is None:
        return jax.ops.segment_sum(values, idx, num_segments=n)
    mesh, axes = ctx

    def body(v, ix):
        full = jax.ops.segment_sum(v, ix, num_segments=n)
        return jax.lax.psum_scatter(full, axes, scatter_dimension=0,
                                    tiled=True)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axes, *([None] * (values.ndim - 1))),
                               P(axes)),
                     out_specs=P(axes, *([None] * (values.ndim - 1))),
                     check_rep=False)(values, idx)


def _scatter_extreme(ctx, values, idx, n, kind):
    """Reduce-scatter-{max,min} via a *hierarchical* per-axis all_to_all:
    one k-way exchange per mesh axis (outermost first) instead of a single
    P-way exchange — topology-aware (cross-pod traffic shrinks by the
    already-reduced factor) and far cheaper to lower for 512-way meshes."""
    if ctx is None:
        op = jax.ops.segment_max if kind == "max" else jax.ops.segment_min
        return op(values, idx, num_segments=n)
    mesh, axes = ctx

    def body(v, ix):
        op = jax.ops.segment_max if kind == "max" else jax.ops.segment_min
        part = op(v, ix, num_segments=n)              # [n, F] local partial
        for a in axes:                                 # row-major = P(axes)
            k = mesh.shape[a]
            rows = part.reshape(k, part.shape[0] // k, *part.shape[1:])
            recv = jax.lax.all_to_all(rows, a, split_axis=0, concat_axis=0,
                                      tiled=False)
            part = (jnp.max(recv, axis=0) if kind == "max"
                    else jnp.min(recv, axis=0))
        return part

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axes, *([None] * (values.ndim - 1))),
                               P(axes)),
                     out_specs=P(axes, *([None] * (values.ndim - 1))),
                     check_rep=False)(values, idx)


def scatter_max0(ctx, values, idx, n):
    return _scatter_extreme(ctx, values, idx, n, "max")


def scatter_min0(ctx, values, idx, n):
    return _scatter_extreme(ctx, values, idx, n, "min")
