"""GIN (Graph Isomorphism Network) — arXiv:1810.00826.

``h_v' = MLP((1 + eps) h_v + sum_{u in N(v)} h_u)`` with learnable eps
(GIN-eps).  Assigned config (gin-tu): 5 layers, d_hidden=64, sum aggregator.

Layer 0 (d_in -> d_hidden) is separate; the remaining uniform layers run as
``lax.scan`` over stacked parameters — constant activation memory in depth
(XLA reuses the scan body's collective buffers; a python loop over
shard_map layers does not — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import (GraphBatch, mlp_apply, mlp_init, masked_edges,
                     seg_sum, shard0)
from .sharded_ops import gather0, scatter_sum0


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 64
    n_classes: int = 16
    graph_level: bool = False
    dtype: object = jnp.float32
    remat: bool = False


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: GINConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 2)
    layer0 = {
        "mlp": mlp_init(ks[0], [cfg.d_in, cfg.d_hidden, cfg.d_hidden],
                        cfg.dtype),
        "eps": jnp.zeros((), cfg.dtype),
    }
    rest = [{
        "mlp": mlp_init(ks[i], [cfg.d_hidden, cfg.d_hidden, cfg.d_hidden],
                        cfg.dtype),
        "eps": jnp.zeros((), cfg.dtype),
    } for i in range(1, cfg.n_layers)]
    head = mlp_init(ks[-1], [cfg.d_hidden, cfg.n_classes], cfg.dtype)
    return {"layer0": layer0, "layers": _stack(rest), "head": head}


def forward(cfg: GINConfig, params, gb: GraphBatch):
    h = gb.node_feat.astype(cfg.dtype)
    n = h.shape[0]

    def layer(h, lp):
        msg = masked_edges(gb, gather0(gb.shard_ctx, h, gb.senders))
        agg = scatter_sum0(gb.shard_ctx, msg, gb.receivers, n)
        return shard0(gb, mlp_apply(lp["mlp"],
                                    (1.0 + lp["eps"]) * h + agg))

    h = layer(h, params["layer0"])

    def body(h, lp):
        if cfg.remat:
            return jax.checkpoint(layer, prevent_cse=False)(h, lp), None
        return layer(h, lp), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    if cfg.graph_level:
        pooled = seg_sum(h, gb.graph_ids, gb.n_graphs)
        return mlp_apply(params["head"], pooled)
    return mlp_apply(params["head"], h)
