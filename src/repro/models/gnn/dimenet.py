"""DimeNet (Directional Message Passing) — arXiv:2003.03123.

Assigned config: n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.

Faithful pieces:
  * Radial Bessel basis  e_RBF,n(d) = sqrt(2/c) * sin(n pi d / c) / d.
  * Spherical basis      a_SBF,ln(d, alpha) = j_l(z_ln d / c) * Y_l0(alpha)
    with closed-form spherical Bessel functions j_l (l <= 6) and Legendre
    Y_l0; the Bessel roots z_ln are found by host-side bisection at import
    (no scipy in this container).
  * Embedding block, interaction blocks with the **bilinear** triplet layer
    out[t, b] = sum_{s,h} sbf[t,s] * x_kj[t,h] * W[b,s,h], and per-block
    output heads summed into the final prediction (paper Fig. 2).

Triplet indices (edge k->j feeding edge j->i, k != i) are built host-side by
the data pipeline (repro/data/triplets.py) with a static per-edge cap.

Hardware-adaptation note (DESIGN.md §6): for the non-geometric assigned
shapes (Cora/ogbn-products) node positions are synthesized by the pipeline;
DimeNet consumes positions only through distances/angles, so the
architecture exercises the same triplet-gather kernel regime either way.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp

from ..layers import dense_init
from .common import GraphBatch, mlp_init, mlp_apply, seg_sum


# --- closed-form special functions ----------------------------------------

def _sph_jl(l: int, x):
    """Spherical Bessel j_l via upward recurrence (stable for x ~> l)."""
    x = jnp.maximum(x, 1e-6)
    j0 = jnp.sin(x) / x
    if l == 0:
        return j0
    j1 = jnp.sin(x) / x ** 2 - jnp.cos(x) / x
    if l == 1:
        return j1
    jm, jc = j0, j1
    for ll in range(1, l):
        jn = (2 * ll + 1) / x * jc - jm
        jm, jc = jc, jn
    return jc


def _legendre(l: int, x):
    if l == 0:
        return jnp.ones_like(x)
    if l == 1:
        return x
    pm, pc = jnp.ones_like(x), x
    for ll in range(1, l):
        pn = ((2 * ll + 1) * x * pc - ll * pm) / (ll + 1)
        pm, pc = pc, pn
    return pc


def _y_l0(l: int, cos_theta):
    return math.sqrt((2 * l + 1) / (4 * math.pi)) * _legendre(l, cos_theta)


def _bessel_roots(n_spherical: int, n_radial: int) -> np.ndarray:
    """First n_radial positive roots of j_l for l < n_spherical (bisection)."""
    def jl_np(l, x):
        with np.errstate(all="ignore"):
            j0 = np.sin(x) / x
            if l == 0:
                return j0
            j1 = np.sin(x) / x ** 2 - np.cos(x) / x
            if l == 1:
                return j1
            jm, jc = j0, j1
            for ll in range(1, l):
                jm, jc = jc, (2 * ll + 1) / x * jc - jm
            return jc

    roots = np.zeros((n_spherical, n_radial))
    for l in range(n_spherical):
        xs = np.linspace(l + 1e-3, (n_radial + l + 2) * np.pi, 20000)
        ys = jl_np(l, xs)
        sign = np.sign(ys)
        idx = np.where(sign[:-1] * sign[1:] < 0)[0][:n_radial]
        for k, i in enumerate(idx):
            lo, hi = xs[i], xs[i + 1]
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                if jl_np(l, np.array([lo]))[0] * jl_np(l, np.array([mid]))[0] <= 0:
                    hi = mid
                else:
                    lo = mid
            roots[l, k] = 0.5 * (lo + hi)
    return roots


_ROOTS_CACHE: dict = {}


def bessel_roots(n_spherical: int, n_radial: int) -> np.ndarray:
    key = (n_spherical, n_radial)
    if key not in _ROOTS_CACHE:
        _ROOTS_CACHE[key] = _bessel_roots(n_spherical, n_radial)
    return _ROOTS_CACHE[key]


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_in: int = 0              # 0 => embed from int node types; else project
    n_types: int = 95
    n_out: int = 1             # regression targets (graph-level)
    graph_level: bool = True
    n_classes: int = 1
    dtype: object = jnp.float32
    # process triplets in this many sequential chunks (0/1 = all at once);
    # the SBF basis and gathers are recomputed per chunk (remat), bounding
    # the T x (S + D) working set for the huge full-batch cells
    triplet_chunks: int = 1
    remat: bool = False


def rbf_basis(cfg: DimeNetConfig, d):
    """[E] -> [E, n_radial]."""
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d, 1e-6)[:, None]
    return (math.sqrt(2.0 / cfg.cutoff) *
            jnp.sin(n * math.pi * d / cfg.cutoff) / d)


def sbf_basis(cfg: DimeNetConfig, d, cos_theta):
    """([T], [T]) -> [T, n_spherical * n_radial]."""
    roots = jnp.asarray(bessel_roots(cfg.n_spherical, cfg.n_radial),
                        jnp.float32)
    d = jnp.maximum(d, 1e-6)[:, None]
    outs = []
    for l in range(cfg.n_spherical):
        radial = _sph_jl(l, roots[l][None, :] * d / cfg.cutoff)
        ang = _y_l0(l, cos_theta)[:, None]
        outs.append(radial * ang)
    return jnp.concatenate(outs, axis=-1)


def init_params(cfg: DimeNetConfig, key):
    d = cfg.d_hidden
    n_sbf = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    if cfg.d_in:
        embed = dense_init(ks[0], cfg.d_in, d, cfg.dtype)
    else:
        embed = (jax.random.normal(ks[0], (cfg.n_types, d)) * 0.02
                 ).astype(cfg.dtype)
    blocks = []
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[4 + i], 8)
        blocks.append({
            "w_kj": dense_init(kk[0], d, d, cfg.dtype),
            "w_ji": dense_init(kk[1], d, d, cfg.dtype),
            "sbf_lin": dense_init(kk[2], n_sbf, n_sbf, cfg.dtype),
            "bilinear": (jax.random.normal(kk[3],
                         (cfg.n_bilinear, n_sbf, d)) / math.sqrt(d)
                         ).astype(cfg.dtype),
            "w_bil_out": dense_init(kk[4], cfg.n_bilinear, d, cfg.dtype),
            "mlp": mlp_init(kk[5], [d, d], cfg.dtype),
            "rbf_out": dense_init(kk[6], cfg.n_radial, d, cfg.dtype),
            "out_mlp": mlp_init(kk[7], [d, d], cfg.dtype),
        })
    return {
        "embed": embed,
        "rbf_lin": dense_init(ks[1], cfg.n_radial, d, cfg.dtype),
        "edge_mlp": mlp_init(ks[2], [3 * d, d], cfg.dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "out_final": mlp_init(ks[3], [d, d, cfg.n_out], cfg.dtype),
    }


def forward(cfg: DimeNetConfig, params, gb: GraphBatch):
    """Graph regression (or node output if graph_level=False)."""
    n = gb.node_feat.shape[0] if gb.node_feat is not None else gb.pos.shape[0]
    pos = gb.pos.astype(jnp.float32)
    snd, rcv = gb.senders, gb.receivers
    vec = pos[rcv] - pos[snd]                  # edge j->i: x_i - x_j
    dist = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, -1), 1e-12))
    rbf = rbf_basis(cfg, dist)                               # [E, R]

    if cfg.d_in:
        h = gb.node_feat.astype(cfg.dtype) @ params["embed"]
    else:
        h = params["embed"][gb.node_feat.astype(jnp.int32).reshape(-1)]
    rbf_h = rbf @ params["rbf_lin"]
    m = mlp_apply(params["edge_mlp"],
                  jnp.concatenate([h[snd], h[rcv], rbf_h], -1),
                  act=jax.nn.silu, final_act=True)           # [E, D]

    # triplet geometry: edge_kj = (k->j), edge_ji = (j->i) share vertex j
    t_kj, t_ji = gb.triplet_kj, gb.triplet_ji
    t_mask = gb.triplet_mask
    e_count = snd.shape[0]

    def tri_sbf(kj, ji, msk):
        """Per-chunk SBF basis (recomputed — cheap elementwise geometry)."""
        v_ji_c = vec[ji]
        v_kj_c = pos[snd[kj]] - pos[rcv[kj]]   # x_k - x_j
        cos_t = jnp.sum(v_ji_c * v_kj_c, -1) / jnp.maximum(
            jnp.linalg.norm(v_ji_c, axis=-1) *
            jnp.linalg.norm(v_kj_c, axis=-1), 1e-9)
        cos_t = jnp.clip(cos_t, -1.0, 1.0)
        sbf = sbf_basis(cfg, dist[kj], cos_t)               # [Tc, S]
        if msk is not None:
            sbf = jnp.where(msk[:, None], sbf, 0.0)
        return sbf

    def tri_aggregate(bp, x_kj):
        """sum over triplets of the bilinear interaction -> [E, n_bilinear];
        chunked + rematerialized for the 10^8-triplet full-batch cells."""
        nch = max(cfg.triplet_chunks, 1)
        t_total = t_kj.shape[0]
        if nch <= 1 or t_total % nch != 0:
            sbf = tri_sbf(t_kj, t_ji, t_mask)
            sbf_p = sbf @ bp["sbf_lin"]
            tri = jnp.einsum("ts,td,bsd->tb", sbf_p, x_kj[t_kj],
                             bp["bilinear"])
            return seg_sum(tri, t_ji, e_count)

        tc = t_total // nch
        kj_c = t_kj.reshape(nch, tc)
        ji_c = t_ji.reshape(nch, tc)
        mk_c = (t_mask.reshape(nch, tc) if t_mask is not None
                else jnp.ones((nch, tc), bool))

        def chunk(acc, xs):
            kj, ji, msk = xs
            sbf = tri_sbf(kj, ji, msk)
            sbf_p = sbf @ bp["sbf_lin"]
            tri = jnp.einsum("ts,td,bsd->tb", sbf_p, x_kj[kj],
                             bp["bilinear"])
            return acc + seg_sum(tri, ji, e_count), None

        acc0 = jnp.zeros((e_count, cfg.n_bilinear), jnp.float32)
        acc, _ = jax.lax.scan(jax.checkpoint(chunk, prevent_cse=False),
                              acc0, (kj_c, ji_c, mk_c))
        return acc

    def block(m, out_acc, bp):
        x_kj = jax.nn.silu(m @ bp["w_kj"])
        x_ji = jax.nn.silu(m @ bp["w_ji"])
        agg = tri_aggregate(bp, x_kj)                        # [E, B]
        m_new = x_ji + agg @ bp["w_bil_out"]
        m = m + mlp_apply(bp["mlp"], m_new, act=jax.nn.silu, final_act=True)
        # per-block output head -> nodes
        node_contrib = seg_sum((rbf @ bp["rbf_out"]) * m, rcv, n)
        out_acc = out_acc + mlp_apply(bp["out_mlp"], node_contrib,
                                      act=jax.nn.silu, final_act=True)
        return m, out_acc

    def sbody(carry, bp):
        m, out_acc = carry
        if cfg.remat:
            m, out_acc = jax.checkpoint(block, prevent_cse=False)(
                m, out_acc, bp)
        else:
            m, out_acc = block(m, out_acc, bp)
        return (m, out_acc), None

    out_acc = jnp.zeros((n, cfg.d_hidden), jnp.float32)
    (m, out_acc), _ = jax.lax.scan(sbody, (m, out_acc), params["blocks"])

    node_out = mlp_apply(params["out_final"], out_acc, act=jax.nn.silu)
    if cfg.graph_level:
        return seg_sum(node_out, gb.graph_ids, gb.n_graphs)
    return node_out
