"""GatedGCN — arXiv:1711.07553 / benchmarking-gnns (arXiv:2003.00982).

Edge-gated message passing with explicit edge features:

    eta_ij  = sigma(A h_i + B h_j + C e_ij)
    e_ij'   = A h_i + B h_j + C e_ij            (edge update, pre-sigma)
    h_i'    = U h_i + sum_j eta_ij * (V h_j) / (sum_j eta_ij + eps)

Residual connections + LayerNorm (the benchmark uses BatchNorm; LN is the
JAX-friendly equivalent — noted in DESIGN.md).  Assigned config: 16 layers,
d_hidden=70, run as ``lax.scan`` over stacked layer parameters (constant
activation memory in depth).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..layers import dense_init, layer_norm
from .common import GraphBatch, mlp_apply, mlp_init, seg_sum, shard0
from .sharded_ops import gather0, scatter_sum0


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 70
    d_edge_in: int = 8
    n_classes: int = 16
    graph_level: bool = False
    dtype: object = jnp.float32
    remat: bool = False


def init_params(cfg: GatedGCNConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 5)
        layers.append({
            "A": dense_init(kk[0], d, d, cfg.dtype),
            "B": dense_init(kk[1], d, d, cfg.dtype),
            "C": dense_init(kk[2], d, d, cfg.dtype),
            "U": dense_init(kk[3], d, d, cfg.dtype),
            "V": dense_init(kk[4], d, d, cfg.dtype),
            "ln_h": jnp.ones((d,), cfg.dtype),
            "lb_h": jnp.zeros((d,), cfg.dtype),
            "ln_e": jnp.ones((d,), cfg.dtype),
            "lb_e": jnp.zeros((d,), cfg.dtype),
        })
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed_h": dense_init(ks[-3], cfg.d_in, d, cfg.dtype),
        "embed_e": dense_init(ks[-2], cfg.d_edge_in, d, cfg.dtype),
        "layers": stacked,
        "head": mlp_init(ks[-1], [d, cfg.n_classes], cfg.dtype),
    }


def forward(cfg: GatedGCNConfig, params, gb: GraphBatch):
    n = gb.node_feat.shape[0]
    h = shard0(gb, gb.node_feat.astype(cfg.dtype) @ params["embed_h"])
    if gb.edge_feat is not None:
        e = gb.edge_feat.astype(cfg.dtype) @ params["embed_e"]
    else:
        e = jnp.zeros((gb.senders.shape[0], cfg.d_hidden), cfg.dtype)
    e = shard0(gb, e)

    def layer(h, e, lp):
        hi = gather0(gb.shard_ctx, h, gb.receivers)
        hj = gather0(gb.shard_ctx, h, gb.senders)
        e_new = hi @ lp["A"] + hj @ lp["B"] + e @ lp["C"]
        eta = jax.nn.sigmoid(e_new)
        if gb.edge_mask is not None:
            eta = jnp.where(gb.edge_mask[:, None], eta, 0.0)
        num = scatter_sum0(gb.shard_ctx, eta * (hj @ lp["V"]),
                           gb.receivers, n)
        den = scatter_sum0(gb.shard_ctx, eta, gb.receivers, n) + 1e-6
        h2 = shard0(gb, h + jax.nn.relu(layer_norm(
            h @ lp["U"] + num / den, lp["ln_h"], lp["lb_h"])))
        e2 = shard0(gb, e + jax.nn.relu(layer_norm(e_new, lp["ln_e"],
                                                   lp["lb_e"])))
        return h2, e2

    def body(carry, lp):
        h, e = carry
        if cfg.remat:
            h, e = jax.checkpoint(layer, prevent_cse=False)(h, e, lp)
        else:
            h, e = layer(h, e, lp)
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    if cfg.graph_level:
        pooled = seg_sum(h, gb.graph_ids, gb.n_graphs)
        return mlp_apply(params["head"], pooled)
    return mlp_apply(params["head"], h)
