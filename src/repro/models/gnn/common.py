"""Shared GNN machinery: segment message passing, MLPs, graph batches.

JAX has no sparse-CSR message passing — per the assignment, the
message-passing primitive IS part of the system: gather by ``senders``,
transform, ``segment_sum/max/min`` by ``receivers``.  The same edge-index →
scatter machinery backs the SSSP relaxation engine (core/) and every GNN
here.

Graph batches are disjoint unions (molecule batches are flattened with node
offsets); ``graph_ids`` drives segment readouts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..layers import dense_init


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    node_feat: jnp.ndarray            # [N, F]
    senders: jnp.ndarray              # [E] int32
    receivers: jnp.ndarray            # [E] int32
    edge_feat: Optional[jnp.ndarray]  # [E, Fe] or None
    graph_ids: jnp.ndarray            # [N] int32 (graph membership)
    n_graphs: int = dataclasses.field(metadata={"static": True}, default=1)
    labels: Optional[jnp.ndarray] = None       # [N] or [G]
    pos: Optional[jnp.ndarray] = None           # [N, 3] (geometric models)
    edge_mask: Optional[jnp.ndarray] = None     # [E] bool (padding)
    triplet_kj: Optional[jnp.ndarray] = None    # [T] edge index (k->j)
    triplet_ji: Optional[jnp.ndarray] = None    # [T] edge index (j->i)
    triplet_mask: Optional[jnp.ndarray] = None  # [T] bool
    # static sharding context (mesh, axis-name tuple) for full-batch cells;
    # None on single-device smoke tests
    shard_ctx: Optional[tuple] = dataclasses.field(
        metadata={"static": True}, default=None)

    def _replace(self, **kw):
        return dataclasses.replace(self, **kw)


def shard0(gb: "GraphBatch", x):
    """Constrain dim-0 of x (edges/nodes/triplets) to the graph sharding."""
    if gb.shard_ctx is None:
        return x
    mesh, axes = gb.shard_ctx
    from jax.sharding import NamedSharding, PartitionSpec
    spec = PartitionSpec(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def seg_sum(x, ids, n):
    return jax.ops.segment_sum(x, ids, num_segments=n)


def seg_mean(x, ids, n):
    s = seg_sum(x, ids, n)
    c = seg_sum(jnp.ones((x.shape[0], 1), x.dtype), ids, n)
    return s / jnp.maximum(c, 1.0)


def seg_max(x, ids, n):
    return jax.ops.segment_max(x, ids, num_segments=n)


def seg_min(x, ids, n):
    return jax.ops.segment_min(x, ids, num_segments=n)


def seg_softmax(logits, ids, n):
    """Numerically-stable softmax over segments (edge-attention)."""
    m = seg_max(logits, ids, n)
    z = jnp.exp(logits - m[ids])
    s = seg_sum(z, ids, n)
    return z / jnp.maximum(s[ids], 1e-9)


def in_degree(receivers, n, edge_mask=None, dtype=jnp.float32):
    ones = jnp.ones_like(receivers, dtype)
    if edge_mask is not None:
        ones = jnp.where(edge_mask, ones, 0)
    return seg_sum(ones, receivers, n)


def mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [dense_init(ks[i], dims[i], dims[i + 1], dtype)
              for i in range(len(dims) - 1)],
        "b": [jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)],
    }


def mlp_apply(p, x, act=jax.nn.relu, final_act=False):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def masked_edges(gb: GraphBatch, x_e):
    if gb.edge_mask is not None:
        return jnp.where(gb.edge_mask[:, None], x_e, 0.0)
    return x_e


def node_ce_loss(logits, labels, mask=None):
    from ..layers import softmax_cross_entropy
    loss = softmax_cross_entropy(logits, labels)
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
