"""Trace-driven cost model for the auto-tuner.

One traced solve (``EngineConfig(trace=True)`` → ``SolveResult.trace``)
carries everything the tuner needs: per-round counter deltas whose sums
reproduce the final ``SsspMetrics`` exactly (the PR-7 parity contract).
The objective is a weighted sum over those counter sums:

* ``rounds`` — synchronized relaxation rounds (the latency driver on a
  device: one dispatch/sync barrier each);
* ``steps`` — step transitions (each costs the Function 1/2 statistics
  pass);
* ``invocations`` — kernel launches on the blocked/fused paths (weighted
  highest: launch overhead dominates small rounds);
* ``tiles`` — tiles scanned by the compacted blocked schedule (the DMA /
  compute volume);
* ``waste`` — relaxations that did not improve a distance
  (``n_relax - n_updates``; wide windows burn edge bandwidth here).

On ``segment_min`` engines the tile/invocation columns are zero and the
objective gracefully reduces to rounds + steps + waste.  Weights are a
frozen dataclass so a caller (or a future meta-tuner) can re-balance
them without touching the search.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

__all__ = ["ObjectiveWeights", "DEFAULT_WEIGHTS", "objective_from_counters",
           "trace_objective"]


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    rounds: float = 1.0
    steps: float = 0.5
    invocations: float = 4.0
    tiles: float = 1e-2
    waste: float = 1e-3


DEFAULT_WEIGHTS = ObjectiveWeights()


def objective_from_counters(c: Mapping,
                            weights: ObjectiveWeights = DEFAULT_WEIGHTS
                            ) -> float:
    """Scalar cost from a counter mapping (``SolveTrace.counter_sums()``
    or ``repro.core.sssp.metrics_dict``).  Missing keys count as zero so
    both shapes (and partial dicts in tests) are accepted."""
    waste = max(float(c.get("n_relax", 0)) - float(c.get("n_updates", 0)),
                0.0)
    return (weights.rounds * float(c.get("n_rounds", 0))
            + weights.steps * float(c.get("n_steps", 0))
            + weights.invocations * float(c.get("n_invocations", 0.0))
            + weights.tiles * float(c.get("n_tiles_scanned", 0.0))
            + weights.waste * waste)


def trace_objective(trace, weights: ObjectiveWeights = DEFAULT_WEIGHTS
                    ) -> float:
    """Cost of one traced solve (a :class:`~repro.obs.trace.SolveTrace`).

    Uses the trace's exact counter sums; a ring that overflowed lost its
    oldest records, so callers should size ``trace_capacity`` above the
    solve's round count (the tuner does).
    """
    return objective_from_counters(trace.counter_sums(), weights)
