"""Budgeted EngineConfig search: coordinate descent + random restarts.

:func:`tune` searches the perf-relevant :class:`EngineConfig` axes
(``alpha``/``beta``/``policy``/``fused_rounds``/blocked geometry/
``compact_capacity``) for one graph, scoring each candidate by the
trace objective (:mod:`repro.tune.objective`) of a few traced solves.

Correctness gate: a candidate is accepted **only** when its dist/parent
arrays are *bitwise identical* to the incumbent baseline's on every
probe source.  Windows are pure scheduling, so every valid candidate
should pass — the gate catches anything that doesn't (a miscompiled
geometry, a policy that changes a parent via an exact float tie) and
records it as a ``parity_reject`` instead of shipping it.

Determinism: the only randomness is a seeded ``numpy`` Generator (probe
sources + restart proposals); the search trajectory is a pure function
of ``(graph, base config, seed, budget, space)``.

The search exports its trajectory through the PR-7 observability plane:
per-candidate counters/gauge on a ``MetricsRegistry`` and, with
``jsonl_path=``, one ``tuner_candidate`` JSONL line per evaluation plus
a final snapshot line — the same stream the serving benchmarks write.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

from ..core.config import ConfigError, EngineConfig
from .objective import DEFAULT_WEIGHTS, ObjectiveWeights, trace_objective
from .store import TUNED_FIELDS, TunedStore

__all__ = ["TuneResult", "tune", "default_space"]

# generous default ring: probe solves must not overflow the trace ring or
# the objective under-counts early rounds
_TRACE_CAP = 4096

_BLOCKED_SINGLE = ("blocked", "blocked_pallas")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one :func:`tune` run (see fields; ``trajectory`` holds
    one dict per evaluated candidate, in evaluation order)."""
    gid: str
    best_config: EngineConfig
    best_objective: float
    baseline_objective: float
    n_evals: int
    n_accepted: int
    n_parity_rejects: int
    n_invalid: int
    seed: int
    trajectory: tuple

    @property
    def improved(self) -> bool:
        return self.best_objective < self.baseline_objective

    @property
    def reduction(self) -> float:
        """Fractional objective reduction vs the default config."""
        if self.baseline_objective <= 0:
            return 0.0
        return 1.0 - self.best_objective / self.baseline_objective


def default_space(base: EngineConfig, n: int) -> dict:
    """The searched axes for ``base`` on an ``n``-vertex graph.

    Axes that the base engine cannot carry (blocked geometry on a
    segment_min engine, ``compact_capacity`` off v3) are omitted up
    front; individual invalid combinations that survive are caught per
    candidate and counted as ``invalid``.
    """
    space = {
        "alpha": (1.5, 3.0, 6.0, 12.0),
        "beta": (0.5, 0.7, 0.9, 0.99),
        "policy": ("static", "adaptive"),
    }
    blocked_single = base.backend in _BLOCKED_SINGLE
    sharded = base.tier == "sharded"
    blocked_shard = sharded and base.effective_shard_backend == "blocked"
    if blocked_single or blocked_shard or sharded:
        space["fused_rounds"] = (0, 2, 4, 8)
    if blocked_single or blocked_shard:
        space["block_v"] = (None, max(64, min(256, n // 4)))
        space["tile_e"] = (None, 128, 512)
    if sharded and base.shard_version == "v3":
        space["compact_capacity"] = (0, 32, 128)
    return space


def _evaluate(graph, config: EngineConfig, sources,
              weights: ObjectiveWeights, trace_capacity: int):
    """Score ``config``: one traced tree solve per probe source.

    Returns ``(dist, parent, objective)`` with dist/parent stacked
    ``[S, n]`` host arrays for the parity gate.  Module-level so tests
    can monkeypatch a deliberately-broken evaluator.
    """
    from ..api import SolveSpec, Solver

    cfg = dataclasses.replace(config, trace=True,
                              trace_capacity=trace_capacity)
    dists, parents, obj = [], [], 0.0
    with Solver.open(graph, cfg) as s:
        for src in sources:
            res = s.solve(SolveSpec.tree(int(src)))
            dists.append(np.asarray(res.dist))
            parents.append(np.asarray(res.parent))
            obj += trace_objective(res.trace, weights)
    return np.stack(dists), np.stack(parents), obj


def _probe_sources(graph, n_sources: int, rng) -> list:
    """Deterministic probe set: the max-degree vertex (the hard solve)
    plus seeded uniform picks."""
    deg = np.asarray(graph.deg)
    n = deg.shape[0]
    srcs = [int(np.argmax(deg))]
    while len(srcs) < min(n_sources, n):
        c = int(rng.integers(0, n))
        if c not in srcs:
            srcs.append(c)
    return srcs


def tune(graph, base: Optional[EngineConfig] = None, *, gid: str = "default",
         budget: int = 24, seed: int = 0, restarts: int = 1,
         n_sources: int = 3, sources=None,
         weights: ObjectiveWeights = DEFAULT_WEIGHTS,
         space: Optional[dict] = None, store: Optional[TunedStore] = None,
         metrics=None, jsonl_path=None,
         trace_capacity: int = _TRACE_CAP) -> TuneResult:
    """Search the config space for ``graph`` within ``budget`` candidate
    evaluations (baseline included); returns the :class:`TuneResult`.

    Coordinate descent over :func:`default_space` (or ``space``), with
    ``restarts`` seeded random proposals when a sweep stops improving.
    Every accepted candidate is bitwise dist/parent-identical to the
    baseline.  With ``store=``, the winner is persisted under ``gid``
    (even when it ties the default: the entry records the tune
    happened).  ``metrics``/``jsonl_path`` export the trajectory through
    the observability plane.
    """
    base = base if base is not None else EngineConfig()
    n = int(np.asarray(graph.deg).shape[0])
    space = dict(space) if space is not None else default_space(base, n)
    rng = np.random.default_rng(seed)
    srcs = (list(map(int, sources)) if sources is not None
            else _probe_sources(graph, n_sources, rng))

    if metrics is None:
        from ..obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()
    c_cand = metrics.counter("sssp_tuner_candidates_total",
                             "Tuner candidate configs evaluated")
    c_acc = metrics.counter("sssp_tuner_accepted_total",
                            "Tuner candidates accepted as the new best")
    c_par = metrics.counter("sssp_tuner_parity_rejects_total",
                            "Tuner candidates rejected for dist/parent "
                            "parity mismatch")
    c_inv = metrics.counter("sssp_tuner_invalid_total",
                            "Tuner candidates rejected as invalid configs")
    g_best = metrics.gauge("sssp_tuner_best_objective",
                           "Best trace objective so far",
                           labels={"gid": gid})

    trajectory = []

    def log_row(row):
        trajectory.append(row)
        if jsonl_path:
            with open(jsonl_path, "a") as f:
                f.write(json.dumps({"kind": "tuner_candidate", "gid": gid,
                                    "seed": seed, "ts": time.time(), **row})
                        + "\n")

    # baseline = incumbent: its dist/parent are the parity reference
    ref_dist, ref_parent, base_obj = _evaluate(graph, base, srcs, weights,
                                               trace_capacity)
    c_cand.inc()
    g_best.set(base_obj)
    n_evals, n_par, n_inv = 1, 0, 0
    best, best_obj = base, base_obj
    log_row({"eval": 0, "origin": "baseline", "objective": base_obj,
             "accepted": True, "parity": True,
             "config": {f: getattr(base, f) for f in TUNED_FIELDS}})

    def try_candidate(cand: EngineConfig, origin: str) -> bool:
        """Evaluate one candidate; returns whether it became the best."""
        nonlocal n_evals, n_par, n_inv, best, best_obj
        try:
            cand.resolve(n=n, m=int(graph.m))
        except ConfigError:
            n_inv += 1
            c_inv.inc()
            return False
        d, p, obj = _evaluate(graph, cand, srcs, weights, trace_capacity)
        n_evals += 1
        c_cand.inc()
        parity = (np.array_equal(d, ref_dist)
                  and np.array_equal(p, ref_parent))
        accepted = parity and obj < best_obj - 1e-9
        if not parity:
            n_par += 1
            c_par.inc()
        if accepted:
            best, best_obj = cand, obj
            c_acc.inc()
            g_best.set(best_obj)
        log_row({"eval": n_evals - 1, "origin": origin, "objective": obj,
                 "accepted": accepted, "parity": parity,
                 "config": {f: getattr(cand, f) for f in TUNED_FIELDS}})
        return accepted

    def replace_valid(cfg, **kw):
        try:
            return dataclasses.replace(cfg, **kw)
        except ConfigError:
            return None

    for round_ in range(restarts + 1):
        if round_ > 0:
            if n_evals >= budget:
                break
            # random restart: one seeded proposal over every axis at once
            kw = {dim: vals[int(rng.integers(0, len(vals)))]
                  for dim, vals in space.items()}
            cand = replace_valid(best, **kw)
            if cand is None or cand == best:
                n_inv += 1
                c_inv.inc()
            else:
                try_candidate(cand, f"restart{round_}")
        improved = True
        while improved and n_evals < budget:
            improved = False
            for dim, values in space.items():
                for v in values:
                    if n_evals >= budget:
                        break
                    if v == getattr(best, dim):
                        continue
                    cand = replace_valid(best, **{dim: v})
                    if cand is None:
                        n_inv += 1
                        c_inv.inc()
                        continue
                    if try_candidate(cand, f"descent/{dim}"):
                        improved = True

    result = TuneResult(
        gid=gid, best_config=best, best_objective=best_obj,
        baseline_objective=base_obj, n_evals=n_evals,
        n_accepted=sum(1 for r in trajectory[1:] if r["accepted"]),
        n_parity_rejects=n_par, n_invalid=n_inv, seed=seed,
        trajectory=tuple(trajectory))
    if store is not None:
        store.put(gid, graph, best, objective=best_obj, baseline=base_obj,
                  meta={"seed": seed, "n_evals": n_evals,
                        "sources": srcs})
    if jsonl_path:
        from ..obs.export import write_jsonl_snapshot
        write_jsonl_snapshot(metrics.snapshot(), jsonl_path,
                             meta={"kind": "tuner_summary", "gid": gid,
                                   "seed": seed, "best": best_obj,
                                   "baseline": base_obj,
                                   "n_evals": n_evals})
    return result
