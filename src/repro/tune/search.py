"""Budgeted EngineConfig search: coordinate descent + random restarts.

:func:`tune` searches the perf-relevant :class:`EngineConfig` axes
(``alpha``/``beta``/``policy``/``fused_rounds``/blocked geometry/
``compact_capacity``) for one graph, scoring each candidate by the
trace objective (:mod:`repro.tune.objective`) of a few traced solves.

Correctness gate: a candidate is accepted **only** when its dist/parent
arrays are *bitwise identical* to the incumbent baseline's on every
probe source.  Windows are pure scheduling, so every valid candidate
should pass — the gate catches anything that doesn't (a miscompiled
geometry, a policy that changes a parent via an exact float tie) and
records it as a ``parity_reject`` instead of shipping it.

Determinism: the only randomness is a seeded ``numpy`` Generator (probe
sources + restart proposals); the search trajectory is a pure function
of ``(graph, base config, seed, budget, space)``.

The search exports its trajectory through the PR-7 observability plane:
per-candidate counters/gauge on a ``MetricsRegistry`` and, with
``jsonl_path=``, one ``tuner_candidate`` JSONL line per evaluation plus
a final snapshot line — the same stream the serving benchmarks write.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

from ..core.config import ConfigError, EngineConfig
from .objective import DEFAULT_WEIGHTS, ObjectiveWeights, trace_objective
from .store import TUNED_FIELDS, TunedStore

__all__ = ["TuneResult", "tune", "default_space"]

# generous default ring: probe solves must not overflow the trace ring or
# the objective under-counts early rounds
_TRACE_CAP = 4096

_BLOCKED_SINGLE = ("blocked", "blocked_pallas")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one :func:`tune` run (see fields; ``trajectory`` holds
    one dict per evaluated candidate, in evaluation order)."""
    gid: str
    best_config: EngineConfig
    best_objective: float
    baseline_objective: float
    n_evals: int
    n_accepted: int
    n_parity_rejects: int
    n_invalid: int
    seed: int
    trajectory: tuple

    @property
    def improved(self) -> bool:
        return self.best_objective < self.baseline_objective

    @property
    def reduction(self) -> float:
        """Fractional objective reduction vs the default config."""
        if self.baseline_objective <= 0:
            return 0.0
        return 1.0 - self.best_objective / self.baseline_objective


def default_space(base: EngineConfig, n: int, goal: str = "tree") -> dict:
    """The searched axes for ``base`` on an ``n``-vertex graph.

    Axes that the base engine cannot carry (blocked geometry on a
    segment_min engine, ``compact_capacity`` off v3) are omitted up
    front; individual invalid combinations that survive are caught per
    candidate and counted as ``invalid``.

    ``goal="p2p"`` adds the goal-directed axes —
    ``use_alt``/``n_landmarks``/``p2p_mode`` — which only move p2p
    probes (ALT bounds need a target, so a tree objective cannot score
    them).  Invalid combinations the sweep proposes (bidirectional
    without ALT or off the static policy, bidirectional on a sharded
    tier) are rejected by config validation and counted as ``invalid``.
    """
    space = {
        "alpha": (1.5, 3.0, 6.0, 12.0),
        "beta": (0.5, 0.7, 0.9, 0.99),
        "policy": ("static", "adaptive"),
    }
    blocked_single = base.backend in _BLOCKED_SINGLE
    sharded = base.tier == "sharded"
    blocked_shard = sharded and base.effective_shard_backend == "blocked"
    if blocked_single or blocked_shard or sharded:
        space["fused_rounds"] = (0, 2, 4, 8)
    if blocked_single or blocked_shard:
        space["block_v"] = (None, max(64, min(256, n // 4)))
        space["tile_e"] = (None, 128, 512)
    if sharded and base.shard_version == "v3":
        space["compact_capacity"] = (0, 32, 128)
    if goal == "p2p":
        space["use_alt"] = (False, True)
        space["n_landmarks"] = (4, 8, 16)
        if not sharded:
            space["p2p_mode"] = ("unidirectional", "bidirectional")
    return space


def _evaluate(graph, config: EngineConfig, sources,
              weights: ObjectiveWeights, trace_capacity: int):
    """Score ``config``: one traced tree solve per probe source.

    Returns ``(dist, parent, objective)`` with dist/parent stacked
    ``[S, n]`` host arrays for the parity gate.  Module-level so tests
    can monkeypatch a deliberately-broken evaluator.
    """
    from ..api import SolveSpec, Solver

    cfg = dataclasses.replace(config, trace=True,
                              trace_capacity=trace_capacity)
    dists, parents, obj = [], [], 0.0
    with Solver.open(graph, cfg) as s:
        for src in sources:
            res = s.solve(SolveSpec.tree(int(src)))
            dists.append(np.asarray(res.dist))
            parents.append(np.asarray(res.parent))
            obj += trace_objective(res.trace, weights)
    return np.stack(dists), np.stack(parents), obj


def _evaluate_p2p(graph, config: EngineConfig, pairs):
    """Score ``config`` on p2p probe pairs by the engine's own counters.

    The trace plane stays off (``p2p_mode="bidirectional"`` forbids it),
    so the objective is the raw work proxy ``n_rounds + n_relax`` summed
    over the pairs.  Returns ``(distances [P], paths, objective)`` —
    the p2p *contract* surface: ALT pruning deliberately leaves
    off-path dist entries tentative, so full-array parity would reject
    every pruned candidate; d(s, t) and the reconstructed path are what
    must stay bitwise-stable.  Module-level so tests can monkeypatch.
    """
    from ..api import SolveSpec, Solver

    dists, paths, cost = [], [], 0.0
    with Solver.open(graph, config) as s:
        for src, tgt in pairs:
            res = s.solve(SolveSpec.p2p(int(src), int(tgt)))
            dists.append(np.float32(res.distance()))
            paths.append(res.paths())
            m = res.metrics
            cost += float(np.asarray(m.n_rounds)) \
                + float(np.asarray(m.n_relax))
    return np.asarray(dists), paths, cost


def _probe_sources(graph, n_sources: int, rng) -> list:
    """Deterministic probe set: the max-degree vertex (the hard solve)
    plus seeded uniform picks."""
    deg = np.asarray(graph.deg)
    n = deg.shape[0]
    srcs = [int(np.argmax(deg))]
    while len(srcs) < min(n_sources, n):
        c = int(rng.integers(0, n))
        if c not in srcs:
            srcs.append(c)
    return srcs


def tune(graph, base: Optional[EngineConfig] = None, *, gid: str = "default",
         budget: int = 24, seed: int = 0, restarts: int = 1,
         n_sources: int = 3, sources=None, goal: str = "tree",
         weights: ObjectiveWeights = DEFAULT_WEIGHTS,
         space: Optional[dict] = None, store: Optional[TunedStore] = None,
         metrics=None, jsonl_path=None,
         trace_capacity: int = _TRACE_CAP) -> TuneResult:
    """Search the config space for ``graph`` within ``budget`` candidate
    evaluations (baseline included); returns the :class:`TuneResult`.

    Coordinate descent over :func:`default_space` (or ``space``), with
    ``restarts`` seeded random proposals when a sweep stops improving.
    Every accepted candidate is bitwise dist/parent-identical to the
    baseline.  With ``store=``, the winner is persisted under ``gid``
    (even when it ties the default: the entry records the tune
    happened).  ``metrics``/``jsonl_path`` export the trajectory through
    the observability plane.

    ``goal="p2p"`` tunes for point-to-point traffic instead: probes are
    seeded (source, target) pairs scored by engine counters
    (:func:`_evaluate_p2p`), the space gains the goal-directed
    ``use_alt``/``n_landmarks``/``p2p_mode`` axes, and the parity gate
    is the p2p contract — d(s, t) bitwise + the identical reconstructed
    path (ALT pruning leaves off-path entries tentative by design).
    """
    if goal not in ("tree", "p2p"):
        raise ValueError(f"tune goal must be 'tree' or 'p2p', got {goal!r}")
    base = base if base is not None else EngineConfig()
    n = int(np.asarray(graph.deg).shape[0])
    space = (dict(space) if space is not None
             else default_space(base, n, goal))
    rng = np.random.default_rng(seed)
    srcs = (list(map(int, sources)) if sources is not None
            else _probe_sources(graph, n_sources, rng))
    if goal == "p2p":
        tgts = []
        for s_ in srcs:
            t_ = int(rng.integers(0, n))
            while n > 1 and t_ == s_:
                t_ = int(rng.integers(0, n))
            tgts.append(t_)
        pairs = list(zip(srcs, tgts))

        def evaluate(cfg):
            return _evaluate_p2p(graph, cfg, pairs)
    else:
        def evaluate(cfg):
            return _evaluate(graph, cfg, srcs, weights, trace_capacity)

    if metrics is None:
        from ..obs.metrics import MetricsRegistry
        metrics = MetricsRegistry()
    c_cand = metrics.counter("sssp_tuner_candidates_total",
                             "Tuner candidate configs evaluated")
    c_acc = metrics.counter("sssp_tuner_accepted_total",
                            "Tuner candidates accepted as the new best")
    c_par = metrics.counter("sssp_tuner_parity_rejects_total",
                            "Tuner candidates rejected for dist/parent "
                            "parity mismatch")
    c_inv = metrics.counter("sssp_tuner_invalid_total",
                            "Tuner candidates rejected as invalid configs")
    g_best = metrics.gauge("sssp_tuner_best_objective",
                           "Best trace objective so far",
                           labels={"gid": gid})

    trajectory = []
    # trajectory rows show the overlay fields plus every searched axis
    # (the p2p goal-directed axes are searched but not overlaid)
    log_fields = tuple(dict.fromkeys(TUNED_FIELDS + tuple(space)))

    def log_row(row):
        trajectory.append(row)
        if jsonl_path:
            with open(jsonl_path, "a") as f:
                f.write(json.dumps({"kind": "tuner_candidate", "gid": gid,
                                    "seed": seed, "ts": time.time(), **row})
                        + "\n")

    # baseline = incumbent: its dist/parent (p2p: distances/paths) are
    # the parity reference
    ref_dist, ref_parent, base_obj = evaluate(base)
    c_cand.inc()
    g_best.set(base_obj)
    n_evals, n_par, n_inv = 1, 0, 0
    best, best_obj = base, base_obj
    log_row({"eval": 0, "origin": "baseline", "objective": base_obj,
             "accepted": True, "parity": True,
             "config": {f: getattr(base, f) for f in log_fields}})

    def try_candidate(cand: EngineConfig, origin: str) -> bool:
        """Evaluate one candidate; returns whether it became the best."""
        nonlocal n_evals, n_par, n_inv, best, best_obj
        try:
            cand.resolve(n=n, m=int(graph.m))
        except ConfigError:
            n_inv += 1
            c_inv.inc()
            return False
        d, p, obj = evaluate(cand)
        n_evals += 1
        c_cand.inc()
        parity = (np.array_equal(d, ref_dist)
                  and (p == ref_parent if goal == "p2p"
                       else np.array_equal(p, ref_parent)))
        accepted = parity and obj < best_obj - 1e-9
        if not parity:
            n_par += 1
            c_par.inc()
        if accepted:
            best, best_obj = cand, obj
            c_acc.inc()
            g_best.set(best_obj)
        log_row({"eval": n_evals - 1, "origin": origin, "objective": obj,
                 "accepted": accepted, "parity": parity,
                 "config": {f: getattr(cand, f) for f in log_fields}})
        return accepted

    def replace_valid(cfg, **kw):
        try:
            return dataclasses.replace(cfg, **kw)
        except ConfigError:
            return None

    for round_ in range(restarts + 1):
        if round_ > 0:
            if n_evals >= budget:
                break
            # random restart: one seeded proposal over every axis at once
            kw = {dim: vals[int(rng.integers(0, len(vals)))]
                  for dim, vals in space.items()}
            cand = replace_valid(best, **kw)
            if cand is None or cand == best:
                n_inv += 1
                c_inv.inc()
            else:
                try_candidate(cand, f"restart{round_}")
        improved = True
        while improved and n_evals < budget:
            improved = False
            for dim, values in space.items():
                for v in values:
                    if n_evals >= budget:
                        break
                    if v == getattr(best, dim):
                        continue
                    cand = replace_valid(best, **{dim: v})
                    if cand is None:
                        n_inv += 1
                        c_inv.inc()
                        continue
                    if try_candidate(cand, f"descent/{dim}"):
                        improved = True

    result = TuneResult(
        gid=gid, best_config=best, best_objective=best_obj,
        baseline_objective=base_obj, n_evals=n_evals,
        n_accepted=sum(1 for r in trajectory[1:] if r["accepted"]),
        n_parity_rejects=n_par, n_invalid=n_inv, seed=seed,
        trajectory=tuple(trajectory))
    if store is not None:
        meta = {"seed": seed, "n_evals": n_evals, "sources": srcs,
                "goal": goal}
        if goal == "p2p":
            meta["targets"] = tgts
        store.put(gid, graph, best, objective=best_obj, baseline=base_obj,
                  meta=meta)
    if jsonl_path:
        from ..obs.export import write_jsonl_snapshot
        write_jsonl_snapshot(metrics.snapshot(), jsonl_path,
                             meta={"kind": "tuner_summary", "gid": gid,
                                   "seed": seed, "best": best_obj,
                                   "baseline": base_obj,
                                   "n_evals": n_evals})
    return result
