"""Persisted tuning winners: a JSON store keyed by gid + graph fingerprint.

One tune is worth amortizing across millions of queries (Zipf traffic),
so winners outlive the process: :class:`TunedStore` writes a small JSON
file mapping ``gid -> (fingerprint, config, objectives)``.  Lookups
recompute the graph's fingerprint — an entry whose graph changed since
it was tuned is *stale* and returns ``None`` (the caller falls back to
its default config) instead of serving a config tuned for a different
graph.

Only the perf-relevant fields (:data:`TUNED_FIELDS`) are overlaid by
:meth:`TunedStore.apply`; placement/serving knobs (devices, tier,
thresholds, batch sizes) always come from the live config.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Optional

import numpy as np

from ..core.config import ConfigError, EngineConfig

__all__ = ["TUNED_FIELDS", "TunedStore", "graph_fingerprint"]

#: EngineConfig fields the tuner searches and the store overlays.
TUNED_FIELDS = ("alpha", "beta", "policy", "fused_rounds",
                "compact_capacity", "block_v", "tile_e")

_STORE_VERSION = 1


def graph_fingerprint(g, config: Optional[EngineConfig] = None) -> str:
    """Cheap content fingerprint of a Host/DeviceGraph.

    Hashes the structural shape (n, directed slot count), the degree
    histogram, and the weight-quantile LUT (``rtow`` — 64 quantiles of
    the weight distribution).  Graph edits that change connectivity or
    weights move at least one of these with overwhelming probability,
    while the fingerprint stays O(N) to compute and identical between
    the host and device forms of the same graph.

    With ``config`` carrying ``use_alt=True``, the landmark-set
    parameters (``n_landmarks``/``landmark_strategy``/``p2p_mode``) are
    folded in as well: a winner tuned under ALT goal-directed pruning
    was scored against *those* bounds, so it must read as stale — not
    silently apply — when served with ALT off or a different landmark
    set.  ALT-off configs leave the hash unchanged (pre-ALT store files
    stay valid).
    """
    deg = np.asarray(g.deg)
    rtow = np.asarray(g.rtow, np.float32)
    h = hashlib.sha256()
    h.update(np.asarray([deg.shape[0], int(g.m)], np.int64).tobytes())
    h.update(np.bincount(np.clip(deg, 0, 255), minlength=256)
             .astype(np.int64).tobytes())
    h.update(rtow.tobytes())
    if config is not None and getattr(config, "use_alt", False):
        h.update(repr(("alt", int(config.n_landmarks),
                       str(config.landmark_strategy),
                       str(config.p2p_mode))).encode())
    return h.hexdigest()[:16]


def _config_to_json(config: EngineConfig) -> dict:
    """Serializable field dict; ``devices`` is placement, not a tuning
    result, and jax Device objects don't serialize — always dropped."""
    out = {}
    for f in dataclasses.fields(config):
        if f.name == "devices":
            continue
        out[f.name] = getattr(config, f.name)
    return out


class TunedStore:
    """JSON-backed map ``gid -> tuned EngineConfig`` with staleness checks.

    Thread-safe; writes are atomic (tmp + rename) so a crashed tuner
    never leaves a half-written store behind, and a corrupt/unreadable
    file degrades to an empty store rather than breaking serving.
    """

    def __init__(self, path):
        self.path = os.fspath(path)
        self._lock = threading.Lock()
        self._data = None

    # -- persistence ---------------------------------------------------

    def _load_locked(self) -> dict:
        if self._data is None:
            try:
                with open(self.path) as f:
                    data = json.load(f)
                if not isinstance(data, dict) or "entries" not in data:
                    raise ValueError("not a TunedStore file")
            except (OSError, ValueError):
                data = {"version": _STORE_VERSION, "entries": {}}
            self._data = data
        return self._data

    def _save_locked(self) -> None:
        tmp = f"{self.path}.tmp"
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(self._data, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    # -- API -----------------------------------------------------------

    def put(self, gid: str, graph, config: EngineConfig, *,
            objective: Optional[float] = None,
            baseline: Optional[float] = None, meta: Optional[dict] = None
            ) -> None:
        """Record ``config`` as the winner for ``(gid, graph)``.  The
        stored fingerprint folds the winner's own landmark-set
        parameters (see :func:`graph_fingerprint`)."""
        entry = {
            "fingerprint": graph_fingerprint(graph, config),
            "config": _config_to_json(config),
        }
        if objective is not None:
            entry["objective"] = float(objective)
        if baseline is not None:
            entry["baseline"] = float(baseline)
        if meta:
            entry["meta"] = dict(meta)
        with self._lock:
            self._load_locked()["entries"][gid] = entry
            self._save_locked()

    def get(self, gid: str, graph=None,
            config: Optional[EngineConfig] = None, *,
            allow_stale: bool = False) -> Optional[EngineConfig]:
        """The tuned config for ``gid``, or ``None``.

        With ``graph`` given, the stored fingerprint must match the
        graph's current fingerprint — a stale entry (graph changed since
        the tune) returns ``None`` so callers fall back to defaults.
        ``config`` is the *live serving* config: its landmark-set
        parameters enter the fingerprint (see :func:`graph_fingerprint`),
        so an entry tuned with ALT on never applies when serving with
        ALT off or a different landmark set, and vice versa.  An entry
        whose stored config no longer constructs (field drift across
        versions) also returns ``None``.

        ``allow_stale`` skips the fingerprint check: a tuned config is a
        perf-only overlay (every winner is bitwise-parity gated), so a
        graph within its delta staleness budget
        (:attr:`~repro.core.config.EngineConfig.delta_staleness_budget`)
        can keep serving the slightly-mistuned winner instead of
        dropping to defaults.
        """
        with self._lock:
            entry = self._load_locked()["entries"].get(gid)
        if entry is None:
            return None
        if not allow_stale and graph is not None and \
                entry["fingerprint"] != graph_fingerprint(graph, config):
            return None
        known = {f.name for f in dataclasses.fields(EngineConfig)}
        kwargs = {k: v for k, v in entry["config"].items() if k in known}
        try:
            return EngineConfig(**kwargs)
        except ConfigError:
            return None

    def entry(self, gid: str) -> Optional[dict]:
        """The raw stored entry (fingerprint/config/objectives)."""
        with self._lock:
            e = self._load_locked()["entries"].get(gid)
        return json.loads(json.dumps(e)) if e is not None else None

    def gids(self) -> list:
        with self._lock:
            return sorted(self._load_locked()["entries"])

    def invalidate(self, gid: str) -> bool:
        """Drop ``gid``'s entry; returns whether one existed."""
        with self._lock:
            existed = self._load_locked()["entries"].pop(gid, None) is not None
            if existed:
                self._save_locked()
        return existed

    def apply(self, gid: str, graph, config: EngineConfig, *,
              n: Optional[int] = None, m: Optional[int] = None,
              allow_stale: bool = False) -> EngineConfig:
        """Overlay the tuned perf fields onto ``config`` (fresh lookup).

        Only :data:`TUNED_FIELDS` move — tier, devices, thresholds, and
        serving knobs stay the caller's.  The overlay is validated
        (construction always; ``resolve`` when ``n``/``m`` are given):
        an overlay the target config cannot carry (e.g. blocked geometry
        onto a segment_min engine after a backend change) falls back to
        progressively smaller overlays — params-only, then the original
        config — rather than failing the build.  ``allow_stale`` forwards
        to :meth:`get` (delta-staleness-budgeted reuse).
        """
        tuned = self.get(gid, graph, config, allow_stale=allow_stale)
        if tuned is None:
            return config
        full = {f: getattr(tuned, f) for f in TUNED_FIELDS}
        params_only = {f: full[f] for f in ("alpha", "beta", "policy")}
        for overlay in (full, params_only):
            try:
                cand = dataclasses.replace(config, **overlay)
                if n is not None or m is not None:
                    cand.resolve(n=n, m=m)
                return cand
            except ConfigError:
                continue
        return config
