"""Offline per-graph auto-tuning (see ISSUE/ROADMAP: trace-driven tuner).

``tune/objective.py`` turns one traced solve into a scalar cost,
``tune/search.py`` runs a budgeted, parity-validated search over the
:class:`~repro.core.config.EngineConfig` space, and ``tune/store.py``
persists winners in a :class:`TunedStore` keyed by gid + graph
fingerprint — consulted by the serving registry and ``Solver.open`` via
their ``tuned=`` passthrough.
"""
from .objective import (DEFAULT_WEIGHTS, ObjectiveWeights,
                        objective_from_counters, trace_objective)
from .search import TuneResult, tune
from .store import TUNED_FIELDS, TunedStore, graph_fingerprint

__all__ = [
    "ObjectiveWeights", "DEFAULT_WEIGHTS", "objective_from_counters",
    "trace_objective", "tune", "TuneResult", "TunedStore",
    "graph_fingerprint", "TUNED_FIELDS",
]
