"""Graph containers and preprocessing for the EIC SSSP framework.

The paper (§4.1) preprocesses every graph by
  (1) sorting each vertex's incident edges in weight order, and
  (2) quantizing the edge-weight distribution into an ``RtoW[RATIO_NUM]``
      lookup table with ``RtoW[x] = maxW(G, x/(RATIO_NUM-1))``.

Host-side construction is done in numpy (the data pipeline is not a TPU
workload); the jit-facing container :class:`DeviceGraph` is a NamedTuple of
jnp arrays so it can flow through ``jax.jit`` / ``shard_map`` unchanged.
Undirected graphs are stored with both edge directions (the paper symmetrizes
directed GAPBS graphs the same way).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

RATIO_NUM = 4096          # paper §4.1: RATIO_NUM = 2^12
ST_NUM = 1024             # paper §4.1: ST_NUM = 2^10
FUSED = 256               # paper §4.1: FUSED = 2^8
DEFAULT_ALPHA = 3         # paper §4.1: alpha = 3
DEFAULT_BETA = 0.9        # paper §4.1: beta = 0.9

# Degree-histogram bucketing used by highD(): exact for deg < EXACT_DEG,
# log2 buckets above.  90 buckets covers degree up to 2^31.
EXACT_DEG = 64
N_DEG_BUCKETS = EXACT_DEG + 26


class DeviceGraph(NamedTuple):
    """Immutable device-resident CSR + flat-edge-list graph."""
    src: jnp.ndarray       # [M] int32 — source of each directed edge slot
    dst: jnp.ndarray       # [M] int32 — destination
    w: jnp.ndarray         # [M] float32 — weight (sorted ascending within row)
    row_ptr: jnp.ndarray   # [N+1] int32 — CSR offsets into (dst, w)
    deg: jnp.ndarray       # [N] int32 — vertex degree (directed slot count)
    rtow: jnp.ndarray      # [RATIO_NUM] float32 — weight quantile LUT
    max_w: jnp.ndarray     # scalar float32 — maxW(G, 1)
    n_edges2: jnp.ndarray  # scalar int32 — 2|E| (directed slot count)

    @property
    def n(self) -> int:
        return self.deg.shape[0]

    @property
    def m(self) -> int:
        return self.src.shape[0]


@dataclasses.dataclass(frozen=True)
class HostGraph:
    """numpy-side graph (builder product; converted once per run)."""
    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    row_ptr: np.ndarray
    deg: np.ndarray
    rtow: np.ndarray
    max_w: float

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_edges_undirected(self) -> int:
        return self.m // 2

    def to_device(self) -> DeviceGraph:
        return DeviceGraph(
            src=jnp.asarray(self.src, jnp.int32),
            dst=jnp.asarray(self.dst, jnp.int32),
            w=jnp.asarray(self.w, jnp.float32),
            row_ptr=jnp.asarray(self.row_ptr, jnp.int32),
            deg=jnp.asarray(self.deg, jnp.int32),
            rtow=jnp.asarray(self.rtow, jnp.float32),
            max_w=jnp.float32(self.max_w),
            n_edges2=jnp.int32(self.m),
        )


def _weight_quantile_lut(w: np.ndarray, ratio_num: int = RATIO_NUM) -> np.ndarray:
    """``RtoW[x] = maxW(G, x/(ratio_num-1))`` — P(w(e) <= maxW(G, r)) = r."""
    if w.size == 0:
        return np.zeros((ratio_num,), np.float32)
    qs = np.linspace(0.0, 1.0, ratio_num)
    return np.quantile(w, qs).astype(np.float32)


def build_csr(n: int, eu: np.ndarray, ev: np.ndarray, ew: np.ndarray,
              symmetrize: bool = True) -> HostGraph:
    """Build the preprocessed CSR from an undirected edge list.

    ``(eu[i], ev[i], ew[i])`` is one undirected edge; both directions are
    stored.  Per-vertex adjacency is sorted by weight ascending (paper §4.1
    preprocessing), which lets the kernel bound in-window edges with a binary
    search instead of a scan.
    """
    eu = np.asarray(eu, np.int64)
    ev = np.asarray(ev, np.int64)
    ew = np.asarray(ew, np.float64)
    if symmetrize:
        s = np.concatenate([eu, ev])
        d = np.concatenate([ev, eu])
        w = np.concatenate([ew, ew])
    else:
        s, d, w = eu, ev, ew
    # sort by (src, weight) -> weight-sorted rows
    order = np.lexsort((w, s))
    s, d, w = s[order], d[order], w[order]
    deg = np.bincount(s, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    # RtoW is built from the *undirected* weight multiset; the directed store
    # duplicates every weight so quantiles are identical either way.
    rtow = _weight_quantile_lut(w)
    return HostGraph(
        n=n,
        src=s.astype(np.int32),
        dst=d.astype(np.int32),
        w=w.astype(np.float32),
        row_ptr=row_ptr.astype(np.int32),
        deg=deg,
        rtow=rtow,
        max_w=float(w.max()) if w.size else 0.0,
    )


def degree_bucket_np(deg: np.ndarray) -> np.ndarray:
    """Bucket index for the highD() histogram (exact < EXACT_DEG, log2 above)."""
    deg = np.asarray(deg)
    small = deg < EXACT_DEG
    log_b = EXACT_DEG + np.clip(
        np.floor(np.log2(np.maximum(deg, 1))).astype(np.int32) - 5, 0, 25)
    return np.where(small, deg, log_b).astype(np.int32)


def degree_bucket(deg: jnp.ndarray) -> jnp.ndarray:
    """jnp version of :func:`degree_bucket_np`."""
    small = deg < EXACT_DEG
    logd = jnp.log2(jnp.maximum(deg, 1).astype(jnp.float32))
    log_b = EXACT_DEG + jnp.clip(jnp.floor(logd).astype(jnp.int32) - 5, 0, 25)
    return jnp.where(small, deg, log_b).astype(jnp.int32)


def bucket_representative() -> jnp.ndarray:
    """Representative degree value per histogram bucket (midpoint of range)."""
    reps = np.arange(N_DEG_BUCKETS, dtype=np.float32)
    for b in range(EXACT_DEG, N_DEG_BUCKETS):
        lo = 2 ** (b - EXACT_DEG + 5)
        reps[b] = 1.5 * lo  # geometric midpoint of [2^k, 2^{k+1})
    return jnp.asarray(reps)
