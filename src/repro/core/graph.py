"""Graph containers and preprocessing for the EIC SSSP framework.

The paper (§4.1) preprocesses every graph by
  (1) sorting each vertex's incident edges in weight order, and
  (2) quantizing the edge-weight distribution into an ``RtoW[RATIO_NUM]``
      lookup table with ``RtoW[x] = maxW(G, x/(RATIO_NUM-1))``.

Host-side construction is done in numpy (the data pipeline is not a TPU
workload); the jit-facing container :class:`DeviceGraph` is a NamedTuple of
jnp arrays so it can flow through ``jax.jit`` / ``shard_map`` unchanged.
Undirected graphs are stored with both edge directions (the paper symmetrizes
directed GAPBS graphs the same way).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

RATIO_NUM = 4096          # paper §4.1: RATIO_NUM = 2^12
ST_NUM = 1024             # paper §4.1: ST_NUM = 2^10
FUSED = 256               # paper §4.1: FUSED = 2^8
DEFAULT_ALPHA = 3         # paper §4.1: alpha = 3
DEFAULT_BETA = 0.9        # paper §4.1: beta = 0.9

# Degree-histogram bucketing used by highD(): exact for deg < EXACT_DEG,
# log2 buckets above.  90 buckets covers degree up to 2^31.
EXACT_DEG = 64
N_DEG_BUCKETS = EXACT_DEG + 26


class DeviceGraph(NamedTuple):
    """Immutable device-resident CSR + flat-edge-list graph."""
    src: jnp.ndarray       # [M] int32 — source of each directed edge slot
    dst: jnp.ndarray       # [M] int32 — destination
    w: jnp.ndarray         # [M] float32 — weight (sorted ascending within row)
    row_ptr: jnp.ndarray   # [N+1] int32 — CSR offsets into (dst, w)
    deg: jnp.ndarray       # [N] int32 — vertex degree (directed slot count)
    rtow: jnp.ndarray      # [RATIO_NUM] float32 — weight quantile LUT
    max_w: jnp.ndarray     # scalar float32 — maxW(G, 1)
    n_edges2: jnp.ndarray  # scalar int32 — 2|E| (directed slot count)

    @property
    def n(self) -> int:
        return self.deg.shape[0]

    @property
    def m(self) -> int:
        return self.src.shape[0]


@dataclasses.dataclass(frozen=True)
class HostGraph:
    """numpy-side graph (builder product; converted once per run)."""
    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    row_ptr: np.ndarray
    deg: np.ndarray
    rtow: np.ndarray
    max_w: float

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_edges_undirected(self) -> int:
        return self.m // 2

    def to_device(self) -> DeviceGraph:
        return DeviceGraph(
            src=jnp.asarray(self.src, jnp.int32),
            dst=jnp.asarray(self.dst, jnp.int32),
            w=jnp.asarray(self.w, jnp.float32),
            row_ptr=jnp.asarray(self.row_ptr, jnp.int32),
            deg=jnp.asarray(self.deg, jnp.int32),
            rtow=jnp.asarray(self.rtow, jnp.float32),
            max_w=jnp.float32(self.max_w),
            n_edges2=jnp.int32(self.m),
        )


def _weight_quantile_lut(w: np.ndarray, ratio_num: int = RATIO_NUM) -> np.ndarray:
    """``RtoW[x] = maxW(G, x/(ratio_num-1))`` — P(w(e) <= maxW(G, r)) = r."""
    if w.size == 0:
        return np.zeros((ratio_num,), np.float32)
    qs = np.linspace(0.0, 1.0, ratio_num)
    return np.quantile(w, qs).astype(np.float32)


def build_csr(n: int, eu: np.ndarray, ev: np.ndarray, ew: np.ndarray,
              symmetrize: bool = True) -> HostGraph:
    """Build the preprocessed CSR from an undirected edge list.

    ``(eu[i], ev[i], ew[i])`` is one undirected edge; both directions are
    stored.  Per-vertex adjacency is sorted by weight ascending (paper §4.1
    preprocessing), which lets the kernel bound in-window edges with a binary
    search instead of a scan.
    """
    eu = np.asarray(eu, np.int64)
    ev = np.asarray(ev, np.int64)
    ew = np.asarray(ew, np.float64)
    if symmetrize:
        s = np.concatenate([eu, ev])
        d = np.concatenate([ev, eu])
        w = np.concatenate([ew, ew])
    else:
        s, d, w = eu, ev, ew
    # sort by (src, weight) -> weight-sorted rows
    order = np.lexsort((w, s))
    s, d, w = s[order], d[order], w[order]
    deg = np.bincount(s, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    # RtoW is built from the *undirected* weight multiset; the directed store
    # duplicates every weight so quantiles are identical either way.
    rtow = _weight_quantile_lut(w)
    return HostGraph(
        n=n,
        src=s.astype(np.int32),
        dst=d.astype(np.int32),
        w=w.astype(np.float32),
        row_ptr=row_ptr.astype(np.int32),
        deg=deg,
        rtow=rtow,
        max_w=float(w.max()) if w.size else 0.0,
    )


# ---------------------------------------------------------------------------
# Blocked layout for the Pallas edge-relax kernel (relax backend
# "blocked_pallas"; see core/relax.py).
# ---------------------------------------------------------------------------

# block/tile defaults are the kernel's own (single source of truth)
from ..kernels.edge_relax.edge_relax import (  # noqa: E402
    DEFAULT_BLOCK_V, DEFAULT_TILE_E)


class BlockedEdges(NamedTuple):
    """One source-block edge slab, sorted by destination block, tile-padded."""
    src_local: jnp.ndarray   # [E_pad] int32 — block-local source index
    dst: jnp.ndarray         # [E_pad] int32 — global destination id
    w: jnp.ndarray           # [E_pad] float32 (+inf on padding slots)


@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    """2-D blocked edge layout: edges bucketed by (src block x dst block).

    Sources are grouped into ``n_blocks`` blocks of ``block_v`` vertices so
    that each slab's source-side ``dist``/``frontier`` slice fits in VMEM;
    within a slab, edges are sorted by destination block (the 2-D bucketing)
    and padded to a multiple of ``tile_e`` so the kernel grid is static.
    Static layout parameters are pytree aux data (shapes stay static under
    ``jax.jit``); only the arrays are traced.
    """
    n: int                               # true vertex count (pre-padding)
    block_v: int
    n_blocks: int
    tile_e: int
    use_kernel: bool                     # Pallas kernel vs jnp reference
    interpret: bool                      # Pallas interpret mode (CPU)
    slabs: Tuple[BlockedEdges, ...]      # one slab per source block
    deg: jnp.ndarray                     # [n_blocks * block_v] int32, 0-padded

    @property
    def n_pad(self) -> int:
        return self.n_blocks * self.block_v


jax.tree_util.register_pytree_node(
    BlockedGraph,
    lambda bg: ((bg.slabs, bg.deg),
                (bg.n, bg.block_v, bg.n_blocks, bg.tile_e, bg.use_kernel,
                 bg.interpret)),
    lambda aux, ch: BlockedGraph(n=aux[0], block_v=aux[1], n_blocks=aux[2],
                                 tile_e=aux[3], use_kernel=aux[4],
                                 interpret=aux[5], slabs=ch[0], deg=ch[1]),
)


def build_blocked(g, *, block_v: int = DEFAULT_BLOCK_V,
                  tile_e: int = DEFAULT_TILE_E, use_kernel: bool = True,
                  interpret: bool = True) -> BlockedGraph:
    """Pre-bucket a graph (``HostGraph`` or ``DeviceGraph``) for the kernel.

    Host-side (concrete shapes are required for the static tile padding);
    call once per graph, outside ``jit``.
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    deg = np.asarray(g.deg)
    n = int(deg.shape[0])
    n_blocks = max(-(-n // block_v), 1)
    sb = src // block_v
    db = dst // block_v
    order = np.lexsort((db, sb))         # bucket by (src block, dst block)
    src, dst, w, sb = src[order], dst[order], w[order], sb[order]
    slabs = []
    for b in range(n_blocks):
        m = sb == b
        s_l = (src[m] - b * block_v).astype(np.int32)
        d = dst[m].astype(np.int32)
        ww = w[m].astype(np.float32)
        e_pad = max(-(-s_l.shape[0] // tile_e) * tile_e, tile_e)
        pad = e_pad - s_l.shape[0]
        slabs.append(BlockedEdges(
            src_local=jnp.asarray(np.pad(s_l, (0, pad))),
            dst=jnp.asarray(np.pad(d, (0, pad))),
            w=jnp.asarray(np.pad(ww, (0, pad), constant_values=np.inf))))
    deg_pad = np.zeros(n_blocks * block_v, np.int32)
    deg_pad[:n] = deg
    return BlockedGraph(n=n, block_v=block_v, n_blocks=n_blocks,
                        tile_e=tile_e, use_kernel=use_kernel,
                        interpret=interpret, slabs=tuple(slabs),
                        deg=jnp.asarray(deg_pad))


def degree_bucket_np(deg: np.ndarray) -> np.ndarray:
    """Bucket index for the highD() histogram (exact < EXACT_DEG, log2 above)."""
    deg = np.asarray(deg)
    small = deg < EXACT_DEG
    log_b = EXACT_DEG + np.clip(
        np.floor(np.log2(np.maximum(deg, 1))).astype(np.int32) - 5, 0, 25)
    return np.where(small, deg, log_b).astype(np.int32)


def degree_bucket(deg: jnp.ndarray) -> jnp.ndarray:
    """jnp version of :func:`degree_bucket_np`."""
    small = deg < EXACT_DEG
    logd = jnp.log2(jnp.maximum(deg, 1).astype(jnp.float32))
    log_b = EXACT_DEG + jnp.clip(jnp.floor(logd).astype(jnp.int32) - 5, 0, 25)
    return jnp.where(small, deg, log_b).astype(jnp.int32)


def bucket_representative() -> jnp.ndarray:
    """Representative degree value per histogram bucket (midpoint of range)."""
    reps = np.arange(N_DEG_BUCKETS, dtype=np.float32)
    for b in range(EXACT_DEG, N_DEG_BUCKETS):
        lo = 2 ** (b - EXACT_DEG + 5)
        reps[b] = 1.5 * lo  # geometric midpoint of [2^k, 2^{k+1})
    return jnp.asarray(reps)
