"""Graph containers and preprocessing for the EIC SSSP framework.

The paper (§4.1) preprocesses every graph by
  (1) sorting each vertex's incident edges in weight order, and
  (2) quantizing the edge-weight distribution into an ``RtoW[RATIO_NUM]``
      lookup table with ``RtoW[x] = maxW(G, x/(RATIO_NUM-1))``.

Host-side construction is done in numpy (the data pipeline is not a TPU
workload); the jit-facing container :class:`DeviceGraph` is a NamedTuple of
jnp arrays so it can flow through ``jax.jit`` / ``shard_map`` unchanged.
Undirected graphs are stored with both edge directions (the paper symmetrizes
directed GAPBS graphs the same way).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

RATIO_NUM = 4096          # paper §4.1: RATIO_NUM = 2^12
ST_NUM = 1024             # paper §4.1: ST_NUM = 2^10
FUSED = 256               # paper §4.1: FUSED = 2^8
DEFAULT_ALPHA = 3         # paper §4.1: alpha = 3
DEFAULT_BETA = 0.9        # paper §4.1: beta = 0.9

# Degree-histogram bucketing used by highD(): exact for deg < EXACT_DEG,
# log2 buckets above.  90 buckets covers degree up to 2^31.
EXACT_DEG = 64
N_DEG_BUCKETS = EXACT_DEG + 26


class DeviceGraph(NamedTuple):
    """Immutable device-resident CSR + flat-edge-list graph."""
    src: jnp.ndarray       # [M] int32 — source of each directed edge slot
    dst: jnp.ndarray       # [M] int32 — destination
    w: jnp.ndarray         # [M] float32 — weight (sorted ascending within row)
    row_ptr: jnp.ndarray   # [N+1] int32 — CSR offsets into (dst, w)
    deg: jnp.ndarray       # [N] int32 — vertex degree (directed slot count)
    rtow: jnp.ndarray      # [RATIO_NUM] float32 — weight quantile LUT
    max_w: jnp.ndarray     # scalar float32 — maxW(G, 1)
    n_edges2: jnp.ndarray  # scalar int32 — 2|E| (directed slot count)

    @property
    def n(self) -> int:
        return self.deg.shape[0]

    @property
    def m(self) -> int:
        return self.src.shape[0]


@dataclasses.dataclass(frozen=True)
class HostGraph:
    """numpy-side graph (builder product; converted once per run)."""
    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    row_ptr: np.ndarray
    deg: np.ndarray
    rtow: np.ndarray
    max_w: float

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_edges_undirected(self) -> int:
        return self.m // 2

    def to_device(self) -> DeviceGraph:
        return DeviceGraph(
            src=jnp.asarray(self.src, jnp.int32),
            dst=jnp.asarray(self.dst, jnp.int32),
            w=jnp.asarray(self.w, jnp.float32),
            row_ptr=jnp.asarray(self.row_ptr, jnp.int32),
            deg=jnp.asarray(self.deg, jnp.int32),
            rtow=jnp.asarray(self.rtow, jnp.float32),
            max_w=jnp.float32(self.max_w),
            n_edges2=jnp.int32(self.m),
        )


def _weight_quantile_lut(w: np.ndarray, ratio_num: int = RATIO_NUM) -> np.ndarray:
    """``RtoW[x] = maxW(G, x/(ratio_num-1))`` — P(w(e) <= maxW(G, r)) = r."""
    if w.size == 0:
        return np.zeros((ratio_num,), np.float32)
    qs = np.linspace(0.0, 1.0, ratio_num)
    return np.quantile(w, qs).astype(np.float32)


# public name for out-of-module builders (repro.delta patches the CSR and
# must recompute the LUT bitwise-identically to build_csr: float64 in)
weight_quantile_lut = _weight_quantile_lut


def build_csr(n: int, eu: np.ndarray, ev: np.ndarray, ew: np.ndarray,
              symmetrize: bool = True) -> HostGraph:
    """Build the preprocessed CSR from an undirected edge list.

    ``(eu[i], ev[i], ew[i])`` is one undirected edge; both directions are
    stored.  Per-vertex adjacency is sorted by weight ascending (paper §4.1
    preprocessing), which lets the kernel bound in-window edges with a binary
    search instead of a scan.
    """
    eu = np.asarray(eu, np.int64)
    ev = np.asarray(ev, np.int64)
    ew = np.asarray(ew, np.float64)
    if symmetrize:
        s = np.concatenate([eu, ev])
        d = np.concatenate([ev, eu])
        w = np.concatenate([ew, ew])
    else:
        s, d, w = eu, ev, ew
    # sort by (src, weight) -> weight-sorted rows
    order = np.lexsort((w, s))
    s, d, w = s[order], d[order], w[order]
    deg = np.bincount(s, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    # RtoW is built from the *undirected* weight multiset; the directed store
    # duplicates every weight so quantiles are identical either way.
    rtow = _weight_quantile_lut(w)
    return HostGraph(
        n=n,
        src=s.astype(np.int32),
        dst=d.astype(np.int32),
        w=w.astype(np.float32),
        row_ptr=row_ptr.astype(np.int32),
        deg=deg,
        rtow=rtow,
        max_w=float(w.max()) if w.size else 0.0,
    )


# ---------------------------------------------------------------------------
# Blocked layout for the Pallas edge-relax kernel (relax backend
# "blocked_pallas" and the distributed "blocked" backend; see core/relax.py).
# ---------------------------------------------------------------------------

# block/tile defaults are the kernel's own (single source of truth)
from ..kernels.edge_relax.edge_relax import (  # noqa: E402
    DEFAULT_BLOCK_V, DEFAULT_TILE_E)


class BlockedEdges(NamedTuple):
    """One source-block edge slab with its CSR-of-tiles index.

    Edges are sorted by destination block and every (src-block, dst-block)
    bucket is padded to a tile boundary, so each ``tile_e``-edge tile
    belongs to exactly one destination block — the kernel's ragged grid
    iterates tiles, not the dense (dst block x tile) product.
    """
    src_local: jnp.ndarray       # [NT*tile_e] int32 — block-local source
    dst: jnp.ndarray             # [NT*tile_e] int32 — global destination id
    w: jnp.ndarray               # [NT*tile_e] float32 (+inf on padding)
    tile_dst: jnp.ndarray        # [NT] int32 — dst block per tile (sorted)
    tile_first: jnp.ndarray      # [NT] bool — first tile of each bucket
    bucket_nonempty: jnp.ndarray  # [n_dst_blocks] bool — bucket has edges


@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    """2-D blocked edge layout: edges bucketed by (src block x dst block).

    Sources are grouped into ``n_blocks`` blocks of ``block_v`` vertices so
    that each slab's source-side ``dist``/``frontier`` slice fits in VMEM;
    within a slab, edges are bucketed by destination block with each
    bucket tile-aligned (see :func:`bucket_edges`), giving the kernel a
    per-bucket tile-range index instead of a full scan.  For the whole
    graph (``build_blocked``) sources and destinations share one blocking
    (``n_blocks == n_dst_blocks``, ``src_base == 0``); a shard slice
    (:func:`slice_for_shard`) covers only its own source block range
    (``src_base = shard * block``) while destinations stay global.
    Static layout parameters are pytree aux data (shapes stay static under
    ``jax.jit``); only the arrays are traced.
    """
    n: int                               # true vertex count (pre-padding)
    block_v: int
    n_blocks: int                        # source blocks in this layout
    n_dst_blocks: int                    # destination blocks (global range)
    src_base: int                        # global id of the first source
    tile_e: int
    use_kernel: bool                     # Pallas kernel vs jnp reference
    interpret: bool                      # Pallas interpret mode (CPU)
    dense_grid_tiles: int                # per-round cost of the dense scan
    slabs: Tuple[BlockedEdges, ...]      # one slab per source block
    deg: jnp.ndarray                     # [n_blocks * block_v] int32, 0-padded

    @property
    def n_pad(self) -> int:
        """Padded source-side vertex count."""
        return self.n_blocks * self.block_v

    @property
    def n_out(self) -> int:
        """Padded destination-side vertex count (kernel output range)."""
        return self.n_dst_blocks * self.block_v


jax.tree_util.register_pytree_node(
    BlockedGraph,
    lambda bg: ((bg.slabs, bg.deg),
                (bg.n, bg.block_v, bg.n_blocks, bg.n_dst_blocks,
                 bg.src_base, bg.tile_e, bg.use_kernel, bg.interpret,
                 bg.dense_grid_tiles)),
    lambda aux, ch: BlockedGraph(n=aux[0], block_v=aux[1], n_blocks=aux[2],
                                 n_dst_blocks=aux[3], src_base=aux[4],
                                 tile_e=aux[5], use_kernel=aux[6],
                                 interpret=aux[7], dense_grid_tiles=aux[8],
                                 slabs=ch[0], deg=ch[1]),
)


def bucket_edges(src_local, dst, w, *, n_dst_blocks: int, block_v: int,
                 tile_e: int, n_tiles: int = 0):
    """Bucket one slab's edges by destination block, tile-aligned.

    Edges are sorted by ``dst // block_v`` (stable) and each non-empty
    bucket is padded to a multiple of ``tile_e`` — so no tile straddles
    two destination blocks and the kernel can iterate a bucket's tile
    *range* instead of masking a full scan.  Padding slots carry
    ``w=+inf`` (never in-window, never activating a tile).

    ``n_tiles`` > 0 pads the slab to exactly that many tiles (shape
    uniformity across shard slabs under ``shard_map``); 0 keeps the
    minimal count (always >= 1, so the kernel grid is never empty).

    Returns numpy arrays ``(src_local, dst, w, tile_dst, tile_first,
    bucket_nonempty, tile_ptr)``; ``tile_ptr`` [n_dst_blocks + 1] is the
    CSR-of-tiles index (``tile_dst`` is its expansion).
    """
    src_local = np.asarray(src_local, np.int32)
    dst = np.asarray(dst, np.int32)
    w = np.asarray(w, np.float32)
    db = dst // block_v
    if db.size and (db.min() < 0 or db.max() >= n_dst_blocks):
        raise ValueError(f"dst ids outside the {n_dst_blocks} x {block_v} "
                         "destination range")
    order = np.argsort(db, kind="stable")
    src_local, dst, w, db = (src_local[order], dst[order], w[order],
                             db[order])
    counts = np.bincount(db, minlength=n_dst_blocks).astype(np.int64)
    tiles_per = -(-counts // tile_e)              # ceil; 0 for empty buckets
    nt_real = int(tiles_per.sum())
    nt_min = max(nt_real, 1)
    if n_tiles and n_tiles < nt_min:
        raise ValueError(f"n_tiles={n_tiles} < required {nt_min}")
    nt = n_tiles if n_tiles else nt_min
    tile_ptr = np.zeros(n_dst_blocks + 1, np.int64)
    np.cumsum(tiles_per, out=tile_ptr[1:])
    s_out = np.zeros(nt * tile_e, np.int32)
    d_out = np.zeros(nt * tile_e, np.int32)
    w_out = np.full(nt * tile_e, np.inf, np.float32)
    off = np.zeros(n_dst_blocks + 1, np.int64)
    np.cumsum(counts, out=off[1:])
    # each edge lands at its bucket's tile base + its rank in the bucket
    pos = tile_ptr[db] * tile_e + (np.arange(db.size) - off[db])
    s_out[pos] = src_local
    d_out[pos] = dst
    w_out[pos] = w
    tile_dst = np.zeros(nt, np.int32)
    tile_dst[:nt_real] = np.repeat(np.arange(n_dst_blocks, dtype=np.int32),
                                   tiles_per)
    if nt > nt_real and nt_real:
        # surplus pad tiles repeat the last real block id so a (defensive)
        # visit can never revisit an earlier, already-flushed output block
        tile_dst[nt_real:] = tile_dst[nt_real - 1]
    tile_first = np.zeros(nt, bool)
    tile_first[tile_ptr[:-1][counts > 0]] = True
    tile_first[0] = True                  # >= 1 scheduled tile every round
    return (s_out, d_out, w_out, tile_dst, tile_first, counts > 0,
            tile_ptr.astype(np.int32))


def _slab_edges(s_l, d, ww, *, n_dst_blocks, block_v, tile_e, n_tiles=0):
    se, de, we, td, tf, bne, _ = bucket_edges(
        s_l, d, ww, n_dst_blocks=n_dst_blocks, block_v=block_v,
        tile_e=tile_e, n_tiles=n_tiles)
    return BlockedEdges(src_local=jnp.asarray(se), dst=jnp.asarray(de),
                        w=jnp.asarray(we), tile_dst=jnp.asarray(td),
                        tile_first=jnp.asarray(tf),
                        bucket_nonempty=jnp.asarray(bne))


def build_blocked(g, *, block_v: int = DEFAULT_BLOCK_V,
                  tile_e: int = DEFAULT_TILE_E, use_kernel: bool = True,
                  interpret: bool = True) -> BlockedGraph:
    """Pre-bucket a graph (``HostGraph`` or ``DeviceGraph``) for the kernel.

    Host-side (concrete shapes are required for the static tile padding);
    call once per graph, outside ``jit``.
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    deg = np.asarray(g.deg)
    n = int(deg.shape[0])
    n_blocks = max(-(-n // block_v), 1)
    sb = src // block_v
    slabs = []
    dense_tiles = 0
    for b in range(n_blocks):
        m = sb == b
        slabs.append(_slab_edges(src[m] - b * block_v, dst[m], w[m],
                                 n_dst_blocks=n_blocks, block_v=block_v,
                                 tile_e=tile_e))
        # what the dense (n_dst_blocks x n_tiles) grid scanned per round
        dense_tiles += n_blocks * max(-(-int(m.sum()) // tile_e), 1)
    deg_pad = np.zeros(n_blocks * block_v, np.int32)
    deg_pad[:n] = deg
    return BlockedGraph(n=n, block_v=block_v, n_blocks=n_blocks,
                        n_dst_blocks=n_blocks, src_base=0, tile_e=tile_e,
                        use_kernel=use_kernel, interpret=interpret,
                        dense_grid_tiles=dense_tiles, slabs=tuple(slabs),
                        deg=jnp.asarray(deg_pad))


def shard_block_v(block: int, block_v: int) -> int:
    """Largest divisor of the shard block size that is <= ``block_v``.

    Shard slabs must tile the owner block exactly (the exchanged partials
    reshape to ``(P, block)``), so the requested ``block_v`` is snapped
    down to a divisor of ``block``.
    """
    if block <= 0:
        raise ValueError("block must be positive")
    for d in range(min(block_v, block), 0, -1):
        if block % d == 0:
            return d
    return 1


def slice_for_shard(g, shard: int, n_shards: int, *,
                    block_v: int = DEFAULT_BLOCK_V,
                    tile_e: int = DEFAULT_TILE_E, n_tiles: int = 0,
                    use_kernel: bool = True,
                    interpret: bool = True) -> BlockedGraph:
    """Blocked layout for one shard's CSR slice (sources = owner block).

    Vertex ownership matches :func:`repro.core.distributed.shard_graph`:
    shard ``q`` owns the contiguous block ``[q*B, (q+1)*B)`` with
    ``B = ceil(n / n_shards)``, and its slab holds every edge whose
    *source* it owns.  The returned layout's source blocks tile that
    owner block (``src_base = q*B``; ``block_v`` snapped to a divisor of
    ``B`` via :func:`shard_block_v`) while destinations span the full
    padded ``n_shards * B`` range — so the per-destination partials line
    up with the engines' ``all_to_all`` exchange.  ``n_tiles`` > 0 pads
    every slab to that tile count (uniform shapes across shards, a
    ``shard_map`` requirement).
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    deg = np.asarray(g.deg)
    n = int(deg.shape[0])
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range for {n_shards}")
    block = -(-n // n_shards)
    n_pad = block * n_shards
    bv = shard_block_v(block, block_v)
    n_src_blocks = block // bv
    n_dst_blocks = n_pad // bv
    lo = shard * block
    m_shard = (src >= lo) & (src < lo + block)
    src_s, dst_s, w_s = src[m_shard], dst[m_shard], w[m_shard]
    sb = (src_s - lo) // bv
    slabs = []
    dense_tiles = 0
    for b in range(n_src_blocks):
        m = sb == b
        slabs.append(_slab_edges(src_s[m] - lo - b * bv, dst_s[m], w_s[m],
                                 n_dst_blocks=n_dst_blocks, block_v=bv,
                                 tile_e=tile_e, n_tiles=n_tiles))
        dense_tiles += n_dst_blocks * max(-(-int(m.sum()) // tile_e), 1)
    deg_pad = np.zeros(block, np.int32)
    hi = min(lo + block, n)
    if hi > lo:
        deg_pad[:hi - lo] = deg[lo:hi]
    return BlockedGraph(n=n, block_v=bv, n_blocks=n_src_blocks,
                        n_dst_blocks=n_dst_blocks, src_base=lo,
                        tile_e=tile_e, use_kernel=use_kernel,
                        interpret=interpret, dense_grid_tiles=dense_tiles,
                        slabs=tuple(slabs), deg=jnp.asarray(deg_pad))


def degree_bucket_np(deg: np.ndarray) -> np.ndarray:
    """Bucket index for the highD() histogram (exact < EXACT_DEG, log2 above)."""
    deg = np.asarray(deg)
    small = deg < EXACT_DEG
    log_b = EXACT_DEG + np.clip(
        np.floor(np.log2(np.maximum(deg, 1))).astype(np.int32) - 5, 0, 25)
    return np.where(small, deg, log_b).astype(np.int32)


def degree_bucket(deg: jnp.ndarray) -> jnp.ndarray:
    """jnp version of :func:`degree_bucket_np`."""
    small = deg < EXACT_DEG
    logd = jnp.log2(jnp.maximum(deg, 1).astype(jnp.float32))
    log_b = EXACT_DEG + jnp.clip(jnp.floor(logd).astype(jnp.int32) - 5, 0, 25)
    return jnp.where(small, deg, log_b).astype(jnp.int32)


def bucket_representative() -> jnp.ndarray:
    """Representative degree value per histogram bucket (midpoint of range)."""
    reps = np.arange(N_DEG_BUCKETS, dtype=np.float32)
    for b in range(EXACT_DEG, N_DEG_BUCKETS):
        lo = 2 ** (b - EXACT_DEG + 5)
        reps[b] = 1.5 * lo  # geometric midpoint of [2^k, 2^{k+1})
    return jnp.asarray(reps)
