"""ALT landmark artifacts: selection, weighted distances, float-safety.

The serving plane has carried hop-BFS *eccentricity hints* since the
registry landed; this module promotes the same machinery into real ALT
(A*, Landmarks, Triangle inequality) preprocessing.  A
:class:`LandmarkSet` holds per-landmark **weighted** distance vectors
``D[l, v] = d(L_l, v)`` — built with the repo's own SSSP engines, not a
host Dijkstra — from which a p2p solve derives admissible per-vertex
lower bounds ``lb[v] = max_l |d(L_l, t) - d(L_l, v)|`` on d(v, t)
(:func:`repro.core.relax.alt_lower_bounds`).

Exactness contract: pruning with these bounds must leave d(s, t) and
the reconstructed parent chain bitwise-identical to the unpruned solve.
Float32 path sums accumulate rounding, so the raw triangle-inequality
difference is *not* safely admissible as-is; :class:`LandmarkSet`
carries a slack factor ``delta = 2^-24 * (2 H + 64)`` (``H`` = the max
finite hop count observed by the selection BFS) and the bound/prune
machinery in :mod:`repro.core.relax` deflates bounds and inflates the
prune threshold by it.  Directed (non-symmetrized) graphs only get the
forward difference; the host-side symmetry check here decides that once
per build.

Selection strategies (:data:`repro.core.config.LANDMARK_STRATEGIES`):

* ``"farthest"`` — farthest-point traversal in the hop metric: start
  at the max-degree vertex, repeatedly add the vertex maximizing the
  min hop distance to the chosen set.  Spreads landmarks toward the
  periphery, which is where ALT bounds are tight.
* ``"max_degree"`` — the k distinct highest-degree vertices (ties by
  id), matching the registry's historical eccentricity-hint picks.

The shared :func:`hop_bfs` here is the single host-side BFS — the
registry's ``estimate_eccentricity`` imports it instead of keeping its
own copy, and reuses a LandmarkSet's choices when one exists.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp
import numpy as np

from .config import LANDMARK_STRATEGIES, ConfigError
from .graph import DeviceGraph, HostGraph
from .relax import AltData

__all__ = ["hop_bfs", "LandmarkSet", "build_landmarks", "select_landmarks",
           "save", "load"]

# one f32 ulp-scale rounding unit; the slack budget per landmark-sum is
# delta = _EPS * (2 H + 64): a path of h hops accumulates at most
# ~h ulps of relative error in either the engine's or the landmark's
# float32 sum, and the engine's own p2p search never runs more than a
# small multiple of the BFS hop bound H rounds of extensions.  The +64
# floor absorbs short-path noise.  The 9-graph bitwise parity gate in
# tests/test_alt_p2p.py is the enforcement: a graph violating the
# margin fails loudly there, not silently in serving.
_EPS = float(np.float32(2.0) ** -24)


def hop_bfs(row_ptr: np.ndarray, dst: np.ndarray, n: int,
            root: int) -> np.ndarray:
    """Hop distances from ``root`` (-1 where unreached), vectorized BFS.

    The one host-side BFS shared by landmark selection and the serving
    registry's eccentricity hints (O(N + M) per root)."""
    hop = np.full(n, -1, np.int64)
    frontier = np.array([root], np.int64)
    hop[frontier] = 0
    level = 0
    while frontier.size:
        starts = row_ptr[frontier]
        counts = row_ptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        offsets = np.repeat(
            starts - np.concatenate(([0], np.cumsum(counts)[:-1])), counts)
        nbrs = dst[offsets + np.arange(total)]
        nbrs = np.unique(nbrs[hop[nbrs] < 0])
        level += 1
        hop[nbrs] = level
        frontier = nbrs
    return hop


def _check_symmetric(src: np.ndarray, dst: np.ndarray,
                     w: np.ndarray) -> bool:
    """True iff the directed edge multiset equals its own reverse
    (exact weight match) — the condition for the reverse ALT difference
    d(v,L) = d(L,v) and the landmark-seeded d(s,t) upper bound."""
    fwd = np.lexsort((w, dst, src))
    rev = np.lexsort((w, src, dst))
    return (np.array_equal(src[fwd], dst[rev])
            and np.array_equal(dst[fwd], src[rev])
            and np.array_equal(w[fwd], w[rev]))


def select_landmarks(row_ptr: np.ndarray, dst: np.ndarray,
                     deg: np.ndarray, n_landmarks: int,
                     strategy: str) -> tuple:
    """Pick landmark vertex ids host-side.

    Returns ``(landmarks int64[L], max_hops int)`` where ``max_hops``
    is the largest finite hop distance any selection BFS observed (the
    ``H`` in the float-safety slack); the ``max_degree`` strategy runs
    one BFS per pick too, purely to measure ``H``.
    """
    n = deg.shape[0]
    k = min(n_landmarks, n)
    max_hops = 1
    if strategy == "max_degree":
        landmarks = np.argsort(-deg, kind="stable")[:k].astype(np.int64)
        for lm in landmarks:
            hop = hop_bfs(row_ptr, dst, n, int(lm))
            max_hops = max(max_hops, int(hop.max()))
        return landmarks, max_hops
    if strategy != "farthest":
        raise ConfigError(f"unknown landmark strategy {strategy!r}; "
                          f"expected one of {LANDMARK_STRATEGIES}")
    # farthest-point traversal in the hop metric, seeded at the
    # max-degree vertex; unreached vertices count as infinitely far so
    # disconnected components each attract a landmark
    chosen = [int(np.argmax(deg))]
    min_hop = np.full(n, np.iinfo(np.int64).max, np.int64)
    for _ in range(k):
        hop = hop_bfs(row_ptr, dst, n, chosen[-1])
        max_hops = max(max_hops, int(hop.max()))
        reached = hop >= 0
        min_hop[reached] = np.minimum(min_hop[reached], hop[reached])
        if len(chosen) == k:
            break
        cand = min_hop.copy()
        cand[np.asarray(chosen, np.int64)] = -1
        chosen.append(int(np.argmax(cand)))
    return np.asarray(chosen, np.int64), max_hops


@dataclasses.dataclass(frozen=True)
class LandmarkSet:
    """The per-graph ALT artifact (weighted landmark distances).

    ``D`` is the device-resident ``[L, N]`` f32 distance matrix
    (``D[l, v] = d(landmarks[l], v)``, +inf where unreached), built by
    the repo's own SSSP engines so its rounding profile matches the
    solver that will consume the bounds.  ``sym`` records the host-side
    symmetry verdict, ``max_hops`` the BFS hop bound behind ``delta``,
    and ``generation`` the registry generation the set was built
    against (the PR-4 invalidation counter; -1 = unmanaged/standalone).
    """
    landmarks: np.ndarray          # [L] int64 vertex ids
    D: jnp.ndarray                 # [L, N] f32 weighted distances
    strategy: str
    sym: bool
    max_hops: int
    generation: int = -1
    # a stale set survived an increase/remove-only edge delta: its old
    # distances are still admissible *lower* bounds on the new graph
    # (d_old <= d_new), but the reverse difference and the seeded d(s,t)
    # upper bound are not — alt_data drops to forward-only bounds by
    # reporting sym=0 (alt_seed_ub then returns +inf; see relax.py)
    stale: bool = False

    @property
    def n_landmarks(self) -> int:
        return int(self.landmarks.shape[0])

    @property
    def delta(self) -> float:
        """The float-safety slack factor (see module docstring)."""
        return _EPS * (2.0 * self.max_hops + 64.0)

    @property
    def alt_data(self) -> AltData:
        """The traced pytree a solve carries through ``jit``."""
        return AltData(D=self.D,
                       delta=jnp.float32(self.delta),
                       sym=jnp.float32(
                           1.0 if (self.sym and not self.stale) else 0.0))

    def params(self) -> tuple:
        """The build parameters a cache / tuned-config fingerprint must
        invalidate on."""
        return (self.n_landmarks, self.strategy)

    def placed(self, sharding) -> "LandmarkSet":
        """A copy with ``D`` placed under ``sharding`` (the sharded
        tier replicates the matrix across the mesh)."""
        import jax
        return dataclasses.replace(
            self, D=jax.device_put(self.D, sharding))


def save(lm: LandmarkSet, path) -> None:
    """Persist a :class:`LandmarkSet` to ``path`` (``.npz``).

    ``generation``/``stale`` are registry-session state and are not
    persisted; a loaded set starts unmanaged (``generation=-1``) and
    fresh.  Callers key the file by graph fingerprint + build params
    (the registry's disk cache does) so a stale file is simply never
    looked up.
    """
    np.savez(path, landmarks=lm.landmarks, D=np.asarray(lm.D),
             strategy=np.asarray(lm.strategy), sym=np.asarray(lm.sym),
             max_hops=np.asarray(lm.max_hops))


def load(path) -> LandmarkSet:
    """Load a :class:`LandmarkSet` saved by :func:`save`."""
    with np.load(path, allow_pickle=False) as z:
        return LandmarkSet(
            landmarks=z["landmarks"].astype(np.int64),
            D=jnp.asarray(z["D"], jnp.float32),
            strategy=str(z["strategy"][()]),
            sym=bool(z["sym"][()]),
            max_hops=int(z["max_hops"][()]))


def build_landmarks(g: Union[DeviceGraph, HostGraph],
                    n_landmarks: int = 8,
                    strategy: str = "farthest",
                    *, config=None,
                    generation: int = -1) -> LandmarkSet:
    """Build a :class:`LandmarkSet` for ``g`` with the SSSP engines.

    ``g`` may be a :class:`~repro.core.graph.DeviceGraph` or a host
    graph (converted once).  ``config`` optionally carries an
    :class:`~repro.core.config.EngineConfig` for the build solves
    (default: the stock single-device engine).  The build runs one
    batched tree solve over the selected landmarks — the same code path
    every other query takes, so D inherits the engine's exact rounding
    behaviour.
    """
    from .sssp import sssp_batch
    if n_landmarks < 1:
        raise ConfigError("n_landmarks must be >= 1")
    dg = g if isinstance(g, DeviceGraph) else g.to_device()
    if dg.n == 0:
        raise ConfigError("cannot build landmarks for an empty graph")
    row_ptr = np.asarray(dg.row_ptr, np.int64)
    dst = np.asarray(dg.dst, np.int64)
    deg = np.asarray(dg.deg, np.int64)
    landmarks, max_hops = select_landmarks(row_ptr, dst, deg,
                                           n_landmarks, strategy)
    sym = _check_symmetric(np.asarray(dg.src, np.int64), dst,
                           np.asarray(dg.w, np.float32))
    if config is not None:
        out = sssp_batch(dg, landmarks, goal="tree", config=config)
    else:
        out = sssp_batch(dg, landmarks, goal="tree")
    D = jnp.asarray(out[0], jnp.float32)
    return LandmarkSet(landmarks=landmarks, D=D, strategy=strategy,
                       sym=sym, max_hops=max_hops, generation=generation)
