"""The heuristic SSSP algorithm (paper §3.3, Algorithm 2 + Function 1/2).

Single-device, fully jitted reference engine.  The control flow is flattened
into one ``lax.while_loop`` whose body executes one *round* of edge
relaxations; when the frontier empties, the same iteration performs the step
transition (Function 2's ``computeST``, the dynamic-stepping ``gap``, and
Function 1's ``initFrontiers`` including the pull phase).

TPU-native adaptation (DESIGN.md §2): the MPI worklist becomes a dense
frontier mask + masked edge-parallel relaxation with a deterministic
``segment_min`` replacing the CAS; per-round metrics count *logical*
traversals exactly as the paper defines them (the weight-sorted adjacency +
binary search of the C implementation touches precisely the edges our masks
enable).

Two deliberate, documented deviations:
  * ``nFrontier`` counts successful non-leaf dist updates (every SAP-pushed
    vertex is popped exactly once per update, and leaf pops are pruned), plus
    one for the source pop — equal to worklist pops in the MPI original.
  * Empty-window fast-forward: when a step transition finds no pending path
    length inside the next window, ``lb`` snaps to the smallest pending
    length (exact — no shortest path can exist in the skipped range).  This
    also yields the termination test (no pending candidate ⇒ done), which is
    equivalent to line 23 of Algorithm 2 but robust to disconnected graphs.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import stats, stepping, traversal
from .graph import DeviceGraph

INT_MAX = jnp.iinfo(jnp.int32).max
INF = jnp.float32(jnp.inf)


class SsspMetrics(NamedTuple):
    n_rounds: jnp.ndarray      # synchronized relaxation rounds ("nSync" raw)
    n_steps: jnp.ndarray       # scheduling-threshold pairs constructed
    n_extended: jnp.ndarray    # extended paths ("nFrontier" raw)
    n_trav: jnp.ndarray        # edge traversals, push model ("nTrav" raw part)
    n_pull_trav: jnp.ndarray   # edge traversals, pull model (requests)
    n_relax: jnp.ndarray       # CAS attempts (created paths)
    n_updates: jnp.ndarray     # successful CAS (dist improvements)


class SsspState(NamedTuple):
    dist: jnp.ndarray
    parent: jnp.ndarray
    frontier: jnp.ndarray
    lb: jnp.ndarray
    ub: jnp.ndarray
    st: jnp.ndarray
    done: jnp.ndarray
    iters: jnp.ndarray
    metrics: SsspMetrics


def _zero_metrics() -> SsspMetrics:
    z = jnp.int32(0)
    return SsspMetrics(z, z, z, z, z, z, z)


def _relax_round(g: DeviceGraph, st_: SsspState) -> SsspState:
    """One synchronized round of push-model edge relaxations (Algo 2 l.8-17)."""
    dist, parent = st_.dist, st_.parent
    # l.8: leaf pruning — paths reaching a leaf are never extended
    paths = st_.frontier & ((dist <= 0.0) | (g.deg > 1))
    du = dist[g.src]
    cand_len = du + g.w
    in_window = paths[g.src] & (cand_len >= st_.lb) & (cand_len < st_.ub)
    active = in_window & (g.dst != parent[g.src])

    cand = jnp.where(active, cand_len, INF)
    best = jax.ops.segment_min(cand, g.dst, num_segments=g.n)
    improved = best < dist
    # deterministic parent recovery (min src among winners)
    win = jnp.where(active & (cand <= best[g.dst]), g.src, INT_MAX)
    winner = jax.ops.segment_min(win, g.dst, num_segments=g.n)
    new_dist = jnp.where(improved, best, dist)
    new_parent = jnp.where(improved, winner, parent)

    # metrics — nFrontier counts worklist pops: every successful update pushes
    # the vertex into the worklist (SAP) and its later pop extends the path;
    # leaves are pruned before extension (l.8), so only non-leaf updates count.
    # With zero repeated relaxations every non-leaf update is final => 1.0.
    touched = jnp.sum(in_window.astype(jnp.int32))
    nonleaf_upd = improved & (g.deg > 1)
    m = st_.metrics
    metrics = m._replace(
        n_rounds=m.n_rounds + jnp.where(jnp.any(st_.frontier), 1, 0),
        n_extended=m.n_extended + jnp.sum(nonleaf_upd.astype(jnp.int32)),
        n_trav=m.n_trav + touched,
        n_relax=m.n_relax + jnp.sum(active.astype(jnp.int32)),
        n_updates=m.n_updates + jnp.sum(improved.astype(jnp.int32)),
    )
    return st_._replace(dist=new_dist, parent=new_parent, frontier=improved,
                        metrics=metrics)


def _bootstrap_ub(g: DeviceGraph, st_: SsspState,
                  high_d0: jnp.ndarray) -> SsspState:
    """Algo 2 l.18-20: during the first step, tighten ub to the shortest known
    path linking s to a vertex of degree >= highD(0)."""
    def tighten(ub):
        mask = (g.deg.astype(jnp.float32) >= high_d0) & (st_.dist > 0)
        cand = jnp.min(jnp.where(mask, st_.dist, INF))
        return jnp.minimum(ub, cand)
    ub = jax.lax.cond(st_.lb <= 0.0, tighten, lambda ub: ub, st_.ub)
    return st_._replace(ub=ub)


def _init_frontiers(g: DeviceGraph, dist, parent, st, lb, ub, metrics):
    """Function 1: push band + pull phase + window frontier."""
    max_w = g.rtow[-1]
    lb0 = jnp.maximum(0.0, lb - max_w)
    push_band = (dist >= lb0) & (dist <= st)

    def with_pull(args):
        dist, parent, metrics = args
        dv = dist[g.dst]
        scan = (dist[g.src] > lb) & (g.w < ub - st)     # edges touched by pull
        valid = scan & (dv >= st) & (dv < lb) & (dv + g.w < ub)
        cand = jnp.where(valid, dv + g.w, INF)
        best = jax.ops.segment_min(cand, g.src, num_segments=g.n)
        improved = best < dist
        win = jnp.where(valid & (cand <= best[g.src]), g.dst, INT_MAX)
        winner = jax.ops.segment_min(win, g.src, num_segments=g.n)
        new_dist = jnp.where(improved, best, dist)
        new_parent = jnp.where(improved, winner, parent)
        nonleaf_upd = improved & (g.deg > 1)
        metrics = metrics._replace(
            n_pull_trav=metrics.n_pull_trav + jnp.sum(scan.astype(jnp.int32)),
            n_extended=metrics.n_extended +
            jnp.sum(nonleaf_upd.astype(jnp.int32)),
            n_relax=metrics.n_relax + jnp.sum(valid.astype(jnp.int32)),
            n_updates=metrics.n_updates + jnp.sum(improved.astype(jnp.int32)),
            n_rounds=metrics.n_rounds + 1,  # the pull phase is a round/sync
        )
        return new_dist, new_parent, metrics

    dist, parent, metrics = jax.lax.cond(
        st < lb, with_pull, lambda a: a, (dist, parent, metrics))
    frontier = push_band | ((dist >= lb) & (dist < ub))
    return dist, parent, frontier, metrics


def _transition(g: DeviceGraph, st_: SsspState,
                params: stepping.SteppingParams) -> SsspState:
    """Step transition (Algo 2 l.22 + Function 1/2 + fast-forward/termination)."""
    dist, parent = st_.dist, st_.parent
    lb, ub = st_.lb, st_.ub

    # smallest pending candidate path length (>= ub); inf <=> computation done
    pend = dist[g.src] + g.w
    pend = jnp.where(pend >= ub, pend, INF)
    min_pending = jnp.min(pend)
    done = ~jnp.isfinite(min_pending)

    st_next = traversal.compute_st(dist, g.deg, g.rtow, g.n_edges2, lb, ub,
                                   params)
    lb2 = ub
    gap2 = stepping.gap(dist, g.deg, g.rtow, g.n_edges2, lb2, params)
    ub2 = lb2 + gap2
    # empty-window fast-forward (exact; see module docstring)
    ffwd = (min_pending >= ub2) & ~done
    lb2 = jnp.where(ffwd, min_pending, lb2)
    gap3 = stepping.gap(dist, g.deg, g.rtow, g.n_edges2, lb2, params)
    ub2 = jnp.where(ffwd, lb2 + gap3, ub2)
    st_next = jnp.minimum(st_next, lb2)

    dist, parent, frontier, metrics = _init_frontiers(
        g, dist, parent, st_next, lb2, ub2, st_.metrics)
    frontier = frontier & ~done
    metrics = metrics._replace(n_steps=metrics.n_steps + jnp.where(done, 0, 1))
    return st_._replace(dist=dist, parent=parent, frontier=frontier,
                        lb=lb2, ub=ub2, st=st_next, done=done,
                        metrics=metrics)


@partial(jax.jit, static_argnames=("max_iters", "alpha", "beta"))
def sssp(g: DeviceGraph, source: jnp.ndarray, *, max_iters: int = 1_000_000,
         alpha: float = 3.0, beta: float = 0.9):
    """Run the heuristic SSSP algorithm from ``source``.

    Returns ``(dist, parent, metrics)``.
    """
    params = stepping.SteppingParams(alpha=alpha, beta=beta)
    n = g.n
    dist0 = jnp.full((n,), INF, jnp.float32).at[source].set(0.0)
    parent0 = jnp.full((n,), -1, jnp.int32).at[source].set(source)
    frontier0 = jnp.zeros((n,), bool).at[source].set(True)
    high_d0 = stats.high_d(jnp.zeros((n,), jnp.float32), g.deg,
                           jnp.float32(0.0))

    # the source's own pop is the first extended path
    metrics0 = _zero_metrics()._replace(n_extended=jnp.int32(1))
    init = SsspState(dist=dist0, parent=parent0, frontier=frontier0,
                     lb=jnp.float32(0.0), ub=INF, st=jnp.float32(0.0),
                     done=jnp.bool_(False), iters=jnp.int32(0),
                     metrics=metrics0)

    def cond(s: SsspState):
        return (~s.done) & (s.iters < max_iters)

    def body(s: SsspState):
        s = _relax_round(g, s)
        s = _bootstrap_ub(g, s, high_d0)
        s = jax.lax.cond(jnp.any(s.frontier),
                         lambda x: x,
                         lambda x: _transition(g, x, params),
                         s)
        return s._replace(iters=s.iters + 1)

    out = jax.lax.while_loop(cond, body, init)
    return out.dist, out.parent, out.metrics


def normalized_metrics(g_deg, dist, metrics: SsspMetrics) -> dict:
    """Paper §4 normalizations: nFrontier, nSync, nTrav (host-side)."""
    import numpy as np
    deg = np.asarray(g_deg)
    d = np.asarray(dist)
    reach = np.isfinite(d)
    n_reach = max(int(reach.sum()), 1)
    nonleaf = max(int((reach & (deg > 1)).sum()), 1)
    logn = max(np.log2(max(deg.shape[0], 2)), 1.0)
    return {
        "nFrontier": float(metrics.n_extended) / nonleaf,
        "nSync": float(metrics.n_rounds) / logn,
        "nTrav": (float(metrics.n_trav) + float(metrics.n_pull_trav)) / n_reach,
        "nTrav_push": float(metrics.n_trav) / n_reach,
        "nTrav_pull": float(metrics.n_pull_trav) / n_reach,
        "n_steps": int(metrics.n_steps),
        "n_rounds": int(metrics.n_rounds),
        "n_relax": int(metrics.n_relax),
        "n_updates": int(metrics.n_updates),
        "reachable": n_reach,
    }
