"""The heuristic SSSP algorithm (paper §3.3, Algorithm 2 + Function 1/2).

Single-device, fully jitted reference engine.  The control flow is flattened
into one ``lax.while_loop`` whose body executes one *round* of edge
relaxations; when the frontier empties, the same iteration performs the step
transition (Function 2's ``computeST``, the dynamic-stepping ``gap``, and
Function 1's ``initFrontiers`` including the pull phase).

The windowed relaxation itself (Algo 2 l.8-17) is delegated to a pluggable
backend from :mod:`repro.core.relax` — ``segment_min`` (dense flat edge
list) or ``blocked_pallas`` (the ``BlockedGraph`` layout driving the
``kernels/edge_relax`` Pallas kernel).  All backends resolve ties
deterministically (min candidate, then min source id), so results and
logical-traversal metrics are identical across them.

TPU-native adaptation (DESIGN.md §2): the MPI worklist becomes a dense
frontier mask + masked edge-parallel relaxation with a deterministic
``segment_min`` replacing the CAS; per-round metrics count *logical*
traversals exactly as the paper defines them (the weight-sorted adjacency +
binary search of the C implementation touches precisely the edges our masks
enable).

Three deliberate, documented deviations:
  * ``nFrontier`` counts successful non-leaf dist updates (every SAP-pushed
    vertex is popped exactly once per update, and leaf pops are pruned), plus
    one for the source pop — equal to worklist pops in the MPI original.
  * Empty-window fast-forward: when a step transition finds no pending path
    length inside the next window, ``lb`` snaps to the smallest pending
    length (exact — no shortest path can exist in the skipped range).  This
    also yields the termination test (no pending candidate ⇒ done), which is
    equivalent to line 23 of Algorithm 2 but robust to disconnected graphs.
  * Pull-phase ``n_relax`` counts requests as *created* on the responder
    side (``dist[resp] in [st, lb)`` with an in-window candidate), matching
    the MPI model where the owner sends REQUEST messages without knowing
    whether the requester is still unsettled.  This makes the counter
    computable identically by the sharded engines (the requester's dist is
    remote there).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from . import relax, stats, stepping, traversal
from .config import (P2P_MODES, ConfigError, EngineConfig,
                     FacadeDeprecationWarning, as_resolved)
from .graph import DeviceGraph
from .relax import INF, INT_MAX
from ..obs import profiling
from ..obs.trace import trace_append, trace_init

__all__ = ["sssp", "sssp_batch", "sssp_p2p", "sssp_bounded", "sssp_knear",
           "repair_relax",
           "SsspMetrics", "LOGICAL_METRIC_FIELDS", "PHYSICAL_METRIC_FIELDS",
           "metrics_dict", "normalized_metrics",
           "GOALS", "goal_param_array", "INF", "INT_MAX"]

# Early-exit query goals.  A goal turns the full shortest-path-tree
# computation into a query that terminates as soon as its answer is
# settled (the stepping invariant: every vertex with dist < lb is final,
# see relax.settled_mask), saving the remaining windows entirely:
#
#   "tree"    — no goal; run until every reachable vertex settles.
#   "p2p"     — point-to-point: stop once `target` (the goal param) is
#               settled; dist[target]/the parent chain back to the source
#               are then bitwise-equal to the full-tree result.
#   "bounded" — distance-bounded search: stop once lb > D, i.e. every
#               vertex with dist <= D is settled.
#   "knear"   — k-nearest: stop once k+1 vertices (the source plus its k
#               nearest) are settled.
#
# The goal kind is static (part of the jit cache key); the goal parameter
# is a traced scalar (int32 target/k, float32 bound) so one compiled
# engine serves every target/bound/k — and vmaps over per-source params
# in sssp_batch.
GOALS = ("tree", "p2p", "bounded", "knear")


def goal_param_array(goal: str, params) -> jnp.ndarray:
    """Coerce goal parameter(s) to the dtype the engine expects."""
    if goal not in GOALS:
        raise ValueError(f"unknown goal {goal!r}; expected one of {GOALS}")
    if goal == "tree":
        shape = () if params is None or jnp.ndim(params) == 0 \
            else (len(params),)
        return jnp.zeros(shape, jnp.int32)
    if params is None:
        raise ValueError(f"goal {goal!r} requires a parameter "
                         "(target / bound / k)")
    dtype = jnp.float32 if goal == "bounded" else jnp.int32
    return jnp.asarray(params, dtype)


def _check_goal_bounds(goal: str, gp, n: int) -> None:
    """Reject out-of-range p2p targets while they are still concrete: a
    jit gather clamps silently, which would report vertex n-1's distance
    as the target's.  Traced params (calls from inside jit) are skipped —
    the caller owns validation there."""
    if goal != "p2p":
        return
    try:
        t = np.asarray(gp)
    except Exception:
        return
    if t.size and (int(t.min()) < 0 or int(t.max()) >= n):
        raise ValueError(f"p2p target(s) {t} out of range for graph "
                         f"with n={n}")


def _goal_reached(goal: str, goal_param, dist, lb):
    """Whether the query goal is settled at window lower bound ``lb``."""
    if goal == "tree":
        return jnp.bool_(False)
    if goal == "p2p":
        return relax.settled_mask(dist, lb)[goal_param]
    if goal == "bounded":
        return lb > goal_param
    if goal == "knear":
        n_settled = jnp.sum(relax.settled_mask(dist, lb).astype(jnp.int32))
        return n_settled >= goal_param + 1
    raise ValueError(f"unknown goal {goal!r}; expected one of {GOALS}")


class SsspMetrics(NamedTuple):
    n_rounds: jnp.ndarray      # synchronized relaxation rounds ("nSync" raw)
    n_steps: jnp.ndarray       # scheduling-threshold pairs constructed
    n_extended: jnp.ndarray    # extended paths ("nFrontier" raw)
    n_trav: jnp.ndarray        # edge traversals, push model ("nTrav" raw part)
    n_pull_trav: jnp.ndarray   # edge traversals, pull model (requests)
    n_relax: jnp.ndarray       # relaxation attempts (created paths)
    n_updates: jnp.ndarray     # successful relaxations (dist improvements)
    n_pruned: jnp.ndarray      # candidates cut by the ALT goal-directed bound
    n_tiles_scanned: jnp.ndarray  # blocked layouts: tiles actually run (f32)
    n_tiles_dense: jnp.ndarray    # blocked layouts: dense-grid cost (f32)
    n_invocations: jnp.ndarray    # kernel launches / sync units (f32)


# The *physical* counters: layout/launch geometry (0 outside blocked
# layouts), excluded from cross-backend/engine parity checks.  Everything
# else is logical and must agree bitwise across backends and tiers.
PHYSICAL_METRIC_FIELDS = ("n_tiles_scanned", "n_tiles_dense",
                          "n_invocations")
LOGICAL_METRIC_FIELDS = tuple(f for f in SsspMetrics._fields
                              if f not in PHYSICAL_METRIC_FIELDS)


class SsspState(NamedTuple):
    dist: jnp.ndarray
    parent: jnp.ndarray
    frontier: jnp.ndarray
    lb: jnp.ndarray
    ub: jnp.ndarray
    st: jnp.ndarray
    done: jnp.ndarray
    iters: jnp.ndarray
    metrics: SsspMetrics


def _zero_metrics() -> SsspMetrics:
    z = jnp.int32(0)
    f = jnp.float32(0)      # physical counters accumulate past int32 range
    return SsspMetrics(**{name: f if name in PHYSICAL_METRIC_FIELDS else z
                          for name in SsspMetrics._fields})


def _relax_round(backend: relax.RelaxBackend, layout, st_: SsspState,
                 alt_lb=None, prune_bound=None) -> SsspState:
    """One synchronized round of push-model edge relaxations (Algo 2 l.8-17),
    dispatched through the selected relaxation backend.  ``alt_lb``/
    ``prune_bound`` (p2p with landmarks) enable the ALT goal-directed cut
    inside the relaxation (see :func:`repro.core.relax.alt_prune`)."""
    new_dist, new_parent, rm = backend.relax_window(
        layout, st_.dist, st_.parent, st_.frontier, st_.lb, st_.ub,
        alt_lb, prune_bound)
    m = st_.metrics
    metrics = m._replace(
        n_rounds=m.n_rounds + jnp.where(jnp.any(st_.frontier), 1, 0),
        n_extended=m.n_extended + rm.n_extended,
        n_trav=m.n_trav + rm.n_trav,
        n_relax=m.n_relax + rm.n_relax,
        n_updates=m.n_updates + rm.n_updates,
        n_pruned=m.n_pruned + rm.n_pruned,
        n_tiles_scanned=m.n_tiles_scanned + rm.n_tiles_scanned,
        n_tiles_dense=m.n_tiles_dense + rm.n_tiles_dense,
        n_invocations=m.n_invocations + rm.n_invocations,
    )
    return st_._replace(dist=new_dist, parent=new_parent,
                        frontier=rm.improved, metrics=metrics)


def _fused_relax_rounds(bg, fs, st_: SsspState, fused_rounds: int,
                        alt_lb=None, prune_ub=None, prune_infl=None,
                        prune_tgt=None) -> SsspState:
    """Up to ``fused_rounds`` synchronized rounds in ONE megakernel
    invocation (blocked layouts only) — the fused twin of calling
    :func:`_relax_round` once per round until the window settles.
    Bitwise-identical dist/parent/frontier and logical counters; the
    kernel folds the counters into its scheduled tile pass and reports
    per-invocation sums (``FUSED_COUNTERS``)."""
    new_dist, new_parent, new_front, cnt = relax.blocked_fused_rounds(
        bg, fs, st_.dist, st_.parent, st_.frontier, st_.lb, st_.ub,
        fused_rounds=fused_rounds, alt_lb=alt_lb, prune_ub=prune_ub,
        prune_infl=prune_infl, prune_tgt=prune_tgt)
    m = st_.metrics
    metrics = m._replace(
        n_rounds=m.n_rounds + cnt[4],
        n_trav=m.n_trav + cnt[0],
        n_relax=m.n_relax + cnt[1],
        n_updates=m.n_updates + cnt[2],
        n_extended=m.n_extended + cnt[3],
        n_pruned=m.n_pruned + cnt[7],
        n_tiles_scanned=m.n_tiles_scanned + cnt[5].astype(jnp.float32),
        # the dense-grid comparator charges one full grid per round
        n_tiles_dense=m.n_tiles_dense
        + cnt[6].astype(jnp.float32) * bg.dense_grid_tiles,
        n_invocations=m.n_invocations + jnp.float32(1),
    )
    return st_._replace(dist=new_dist, parent=new_parent,
                        frontier=new_front, metrics=metrics)


def _bootstrap_ub(g: DeviceGraph, st_: SsspState,
                  high_d0: jnp.ndarray) -> SsspState:
    """Algo 2 l.18-20: during the first step, tighten ub to the shortest known
    path linking s to a vertex of degree >= highD(0)."""
    def tighten(ub):
        mask = (g.deg.astype(jnp.float32) >= high_d0) & (st_.dist > 0)
        cand = jnp.min(jnp.where(mask, st_.dist, INF))
        return jnp.minimum(ub, cand)
    ub = jax.lax.cond(st_.lb <= 0.0, tighten, lambda ub: ub, st_.ub)
    return st_._replace(ub=ub)


def _pull_phase(g: DeviceGraph, dist, parent, st, lb, ub, metrics,
                alt_lb=None, prune_bound=None):
    """Function 1's pull phase: settled band [st, lb) answers requests from
    unsettled vertices (built from the shared relax primitives).  Under
    ALT the *requester* (``g.src``) is the vertex receiving the update,
    so requests with ``cand + alt_lb[src] > prune_bound`` are cut."""
    dv = dist[g.dst]
    # edges a pull scan touches: requester unsettled, weight short enough
    scan = (dist[g.src] > lb) & (g.w < ub - st)
    # requests created (responder side; w < ub - st is implied)
    mask = (dv >= st) & (dv < lb) & (dv + g.w < ub)
    cand = jnp.where(mask, dv + g.w, INF)
    n_pruned = jnp.int32(0)
    if alt_lb is not None:
        mask, pruned = relax.alt_prune(cand, mask, alt_lb[g.src],
                                       prune_bound)
        cand = jnp.where(mask, cand, INF)
        n_pruned = jnp.sum(pruned.astype(jnp.int32))
    best, winner = relax.segment_min_with_winner(cand, mask, g.dst, g.src,
                                                 g.n)
    new_dist, new_parent, improved = relax.apply_updates(
        dist, parent, best, winner, gate=dist > lb)
    nonleaf_upd = improved & (g.deg > 1)
    metrics = metrics._replace(
        n_pull_trav=metrics.n_pull_trav + jnp.sum(scan.astype(jnp.int32)),
        n_extended=metrics.n_extended +
        jnp.sum(nonleaf_upd.astype(jnp.int32)),
        n_relax=metrics.n_relax + jnp.sum(mask.astype(jnp.int32)),
        n_updates=metrics.n_updates + jnp.sum(improved.astype(jnp.int32)),
        n_pruned=metrics.n_pruned + n_pruned,
        n_rounds=metrics.n_rounds + 1,  # the pull phase is a round/sync
    )
    return new_dist, new_parent, metrics


def _transition(g: DeviceGraph, st_: SsspState,
                params: stepping.SteppingParams, goal: str,
                goal_param, ps: stepping.PolicyState = None,
                alt_lb=None, bound_of=None):
    """Step transition (Algo 2 l.22 + Function 1/2 + fast-forward/termination).

    With the adaptive policy, ``ps`` carries the traced
    :class:`~repro.core.stepping.PolicyState`: the transition first folds
    the counters observed since the previous step into it (observe →
    adapt), then sizes the next window from the adapted parameters, and
    returns ``(state, ps)``.  ``ps is None`` (static policy) compiles the
    exact pre-policy program and returns the state alone.
    """
    dist, parent = st_.dist, st_.parent
    lb, ub = st_.lb, st_.ub

    # smallest pending candidate path length (>= ub); inf <=> computation done
    pend = dist[g.src] + g.w
    pend = jnp.where(pend >= ub, pend, INF)
    if alt_lb is not None:
        # a pending candidate the ALT bound would cut can never improve
        # the goal vertex, so it neither blocks termination nor anchors
        # the fast-forward: skipping it is exact for the p2p contract
        bound_eff = bound_of(dist)
        pend = jnp.where(pend + alt_lb[g.dst] > bound_eff, INF, pend)
    min_pending = jnp.min(pend)
    done = ~jnp.isfinite(min_pending)

    if ps is not None:
        m = st_.metrics
        ps = stepping.adaptive_update(ps, m.n_rounds, m.n_relax,
                                      m.n_updates)
        params = stepping.effective_params(ps)
        mult = ps.mult
    else:
        mult = None
    st_next = traversal.compute_st(dist, g.deg, g.rtow, g.n_edges2, lb, ub,
                                   params, mult=mult)
    lb2 = ub
    gap2 = stepping.gap(dist, g.deg, g.rtow, g.n_edges2, lb2, params, mult)
    ub2 = lb2 + gap2
    # empty-window fast-forward (exact; see module docstring)
    ffwd = (min_pending >= ub2) & ~done
    lb2 = jnp.where(ffwd, min_pending, lb2)
    gap3 = stepping.gap(dist, g.deg, g.rtow, g.n_edges2, lb2, params, mult)
    ub2 = jnp.where(ffwd, lb2 + gap3, ub2)
    st_next = jnp.minimum(st_next, lb2)

    def with_pull(args):
        dist, parent, metrics = args
        return _pull_phase(g, dist, parent, st_next, lb2, ub2, metrics,
                           alt_lb,
                           None if alt_lb is None else bound_eff)

    dist, parent, metrics = jax.lax.cond(
        st_next < lb2, with_pull, lambda a: a, (dist, parent, st_.metrics))
    # early-exit goal: the settled set only grows at step transitions, so
    # checking here is exact — and costs one reduction per transition.
    done = done | _goal_reached(goal, goal_param, dist, lb2)
    frontier = relax.window_frontier(dist, st_next, lb2, ub2, g.rtow[-1])
    frontier = frontier & ~done
    metrics = metrics._replace(n_steps=metrics.n_steps + jnp.where(done, 0, 1))
    out = st_._replace(dist=dist, parent=parent, frontier=frontier,
                       lb=lb2, ub=ub2, st=st_next, done=done,
                       metrics=metrics)
    return out if ps is None else (out, ps)


def _trace_record(s0: SsspState, s1: SsspState, buf):
    """Append one per-iteration trace record to ``buf`` (inside jit).

    ``s0``/``s1`` are the loop state before/after the body, so every
    counter column is the exact int32 delta the iteration contributed —
    the host-side ``SolveTrace.counter_sums`` parity contract
    (:mod:`repro.obs.trace`).  Reads state only: dist/parent/metrics
    stay bitwise-identical with tracing on.
    """
    m0, m1 = s0.metrics, s1.metrics
    # the transition ran iff it advanced a step (or terminated the solve)
    stepped = ((m1.n_steps > m0.n_steps) | (s1.done & ~s0.done))
    ivals = {
        "iter": s0.iters,
        "frontier": jnp.sum(s0.frontier.astype(jnp.int32)),
        "stepped": stepped.astype(jnp.int32),
        "n_rounds": m1.n_rounds - m0.n_rounds,
        "n_steps": m1.n_steps - m0.n_steps,
        "n_extended": m1.n_extended - m0.n_extended,
        "n_trav": m1.n_trav - m0.n_trav,
        "n_pull_trav": m1.n_pull_trav - m0.n_pull_trav,
        "n_relax": m1.n_relax - m0.n_relax,
        "n_updates": m1.n_updates - m0.n_updates,
        "n_pruned": m1.n_pruned - m0.n_pruned,
    }
    fvals = {
        "lb": s0.lb, "ub": s0.ub, "st": s0.st,
        "n_tiles_scanned": m1.n_tiles_scanned - m0.n_tiles_scanned,
        "n_tiles_dense": m1.n_tiles_dense - m0.n_tiles_dense,
        "n_invocations": m1.n_invocations - m0.n_invocations,
    }
    return trace_append(buf, ivals, fvals)


def _run(g: DeviceGraph, layout, source, backend: relax.RelaxBackend,
         max_iters: int, alpha: float, beta: float, goal: str = "tree",
         goal_param=None, fused_rounds: int = 0, fused=None,
         trace_capacity: int = 0, policy: str = "static",
         alt_data=None, p2p_mode: str = "unidirectional"):
    """Trace one SSSP computation (shared by sssp / sssp_batch); ``goal``
    selects the early-exit variant (see GOALS).  ``fused_rounds > 0``
    (blocked layouts only) runs each window's rounds through the fused
    megakernel — one kernel invocation per up-to-``fused_rounds`` rounds
    instead of one per source block per round; ``fused`` carries the
    prebuilt :class:`~repro.core.relax.FusedSlab` so the concatenation
    is hoisted out of vmapped batches.  ``trace_capacity > 0`` records a
    per-round :class:`~repro.obs.trace.TraceBuf` ring (returned as a
    fourth output; ``None`` otherwise) — the knob is static, so 0
    compiles the exact untraced program.  ``policy`` is static too:
    ``"static"`` compiles the exact pre-policy program, ``"adaptive"``
    carries a :class:`~repro.core.stepping.PolicyState` in the loop and
    re-sizes the window at each step transition."""
    params = stepping.SteppingParams(alpha=alpha, beta=beta)
    adaptive = policy == "adaptive"
    if policy not in stepping.POLICIES:
        raise ConfigError(f"unknown policy {policy!r}; expected one of "
                          f"{stepping.POLICIES}")
    if fused_rounds > 0:
        if not isinstance(layout, relax.BlockedGraph):
            raise ConfigError(
                "fused_rounds needs a blocked layout on the single-device "
                f"tier; got {type(layout).__name__} (set a blocked "
                "backend, or drop fused_rounds)")
        if fused is None:
            fused = relax.fused_slab(layout)
    if goal_param is None:
        goal_param = jnp.int32(0)
    if p2p_mode not in P2P_MODES:
        raise ConfigError(f"unknown p2p_mode {p2p_mode!r}; expected one "
                          f"of {P2P_MODES}")
    n = g.n
    source = jnp.asarray(source, jnp.int32)
    alt = alt_data is not None and goal == "p2p"
    if goal == "p2p" and p2p_mode == "bidirectional":
        if not alt:
            raise ConfigError("p2p_mode='bidirectional' needs a landmark "
                              "set (use_alt=True / landmarks=...)")
        if adaptive or trace_capacity > 0:
            raise ConfigError("p2p_mode='bidirectional' supports only "
                              "policy='static' without tracing")
        return _run_bidi(g, layout, source, backend, max_iters, params,
                         goal_param, fused_rounds, fused, alt_data)
    if alt:
        tgt = jnp.asarray(goal_param, jnp.int32)
        alt_lb = relax.alt_lower_bounds(alt_data.D, tgt, alt_data.delta,
                                        alt_data.sym)
        infl = 1.0 + 4.0 * alt_data.delta
        prune_ub = relax.alt_seed_ub(alt_data.D, source, tgt, infl,
                                     alt_data.sym)
        # best-known s->t length this round, inflated so the engine's own
        # f32 path sums always survive the cut (see relax.py)
        bound_of = lambda dist: jnp.minimum(prune_ub, dist[tgt] * infl)
    else:
        alt_lb = bound_of = None
    dist0 = jnp.full((n,), INF, jnp.float32).at[source].set(0.0)
    parent0 = jnp.full((n,), -1, jnp.int32).at[source].set(source)
    frontier0 = jnp.zeros((n,), bool).at[source].set(True)
    high_d0 = stats.high_d(jnp.zeros((n,), jnp.float32), g.deg,
                           jnp.float32(0.0))

    # the source's own pop is the first extended path
    metrics0 = _zero_metrics()._replace(n_extended=jnp.int32(1))
    init = SsspState(dist=dist0, parent=parent0, frontier=frontier0,
                     lb=jnp.float32(0.0), ub=INF, st=jnp.float32(0.0),
                     done=jnp.bool_(False), iters=jnp.int32(0),
                     metrics=metrics0)

    def cond(s: SsspState):
        return (~s.done) & (s.iters < max_iters)

    def relax_step(s: SsspState) -> SsspState:
        if fused_rounds > 0:
            if alt:
                return _fused_relax_rounds(layout, fused, s, fused_rounds,
                                           alt_lb, prune_ub, infl, tgt)
            return _fused_relax_rounds(layout, fused, s, fused_rounds)
        if alt:
            return _relax_round(backend, layout, s, alt_lb,
                                bound_of(s.dist))
        return _relax_round(backend, layout, s)

    def body(s: SsspState):
        s = relax_step(s)
        s = _bootstrap_ub(g, s, high_d0)
        s = jax.lax.cond(jnp.any(s.frontier),
                         lambda x: x,
                         lambda x: _transition(g, x, params, goal,
                                               goal_param, alt_lb=alt_lb,
                                               bound_of=bound_of),
                         s)
        return s._replace(iters=s.iters + 1)

    def body_adaptive(carry):
        s, ps = carry
        s = relax_step(s)
        s = _bootstrap_ub(g, s, high_d0)
        s, ps = jax.lax.cond(jnp.any(s.frontier),
                             lambda c: c,
                             lambda c: _transition(g, c[0], params, goal,
                                                   goal_param, ps=c[1],
                                                   alt_lb=alt_lb,
                                                   bound_of=bound_of),
                             (s, ps))
        return s._replace(iters=s.iters + 1), ps

    if not adaptive:
        if trace_capacity <= 0:
            out = jax.lax.while_loop(cond, body, init)
            return out.dist, out.parent, out.metrics, None

        def traced_body(carry):
            s, buf = carry
            s1 = body(s)
            return s1, _trace_record(s, s1, buf)

        out, buf = jax.lax.while_loop(lambda c: cond(c[0]), traced_body,
                                      (init, trace_init(trace_capacity)))
        return out.dist, out.parent, out.metrics, buf

    init_a = (init, stepping.policy_init(params))
    if trace_capacity <= 0:
        out, _ = jax.lax.while_loop(lambda c: cond(c[0]), body_adaptive,
                                    init_a)
        return out.dist, out.parent, out.metrics, None

    def traced_adaptive(carry):
        c, buf = carry
        c1 = body_adaptive(c)
        return c1, _trace_record(c[0], c1[0], buf)

    (out, _), buf = jax.lax.while_loop(lambda c: cond(c[0][0]),
                                       traced_adaptive,
                                       (init_a, trace_init(trace_capacity)))
    return out.dist, out.parent, out.metrics, buf


def _run_bidi(g: DeviceGraph, layout, source, backend, max_iters,
              params: stepping.SteppingParams, target, fused_rounds, fused,
              alt_data):
    """Bidirectional meet-in-the-middle p2p (goal="p2p" only).

    A forward solve (from ``source``) and a backward solve (from
    ``target``, over the same symmetric graph) alternate windows —
    whichever side's window lower bound trails advances one iteration.
    Every advance tightens the shared meet bound
    ``mu = min_v dist_f[v] + dist_b[v]`` (a valid s->t path length on a
    symmetric graph), which feeds BOTH sides' ALT prune bounds through
    ``min(seed_ub, mu * infl)`` — strictly more pruning pressure than
    either side alone.

    The forward solve stays *authoritative*: it terminates by the
    standard p2p criterion (target settled), and since the extra
    pruning is exact, its ``dist[target]``/parent chain are
    bitwise-identical to the unidirectional solve (mu never finalizes
    values — a mu-based finalize would break the bitwise contract).
    The backward side freezes once its goal settles or
    ``lb_f + lb_b >= mu`` (its windows can no longer tighten mu).
    Metrics are summed over both sides: total work, which is what the
    benchmark comparisons need.
    """
    n = g.n
    target = jnp.asarray(target, jnp.int32)
    D, delta, sym = alt_data.D, alt_data.delta, alt_data.sym
    infl = 1.0 + 4.0 * delta
    lb_f = relax.alt_lower_bounds(D, target, delta, sym)
    lb_b = relax.alt_lower_bounds(D, source, delta, sym)
    seed = relax.alt_seed_ub(D, source, target, infl, sym)
    high_d0 = stats.high_d(jnp.zeros((n,), jnp.float32), g.deg,
                           jnp.float32(0.0))

    def init_state(s):
        return SsspState(
            dist=jnp.full((n,), INF, jnp.float32).at[s].set(0.0),
            parent=jnp.full((n,), -1, jnp.int32).at[s].set(s),
            frontier=jnp.zeros((n,), bool).at[s].set(True),
            lb=jnp.float32(0.0), ub=INF, st=jnp.float32(0.0),
            done=jnp.bool_(False), iters=jnp.int32(0),
            metrics=_zero_metrics()._replace(n_extended=jnp.int32(1)))

    def side_body(s, alt_lb_s, goal_v, mu):
        ub_eff = jnp.minimum(seed, mu * infl)
        bound_of = lambda dist: jnp.minimum(ub_eff, dist[goal_v] * infl)
        if fused_rounds > 0:
            s = _fused_relax_rounds(layout, fused, s, fused_rounds,
                                    alt_lb_s, ub_eff, infl, goal_v)
        else:
            s = _relax_round(backend, layout, s, alt_lb_s,
                             bound_of(s.dist))
        s = _bootstrap_ub(g, s, high_d0)
        s = jax.lax.cond(jnp.any(s.frontier),
                         lambda x: x,
                         lambda x: _transition(g, x, params, "p2p", goal_v,
                                               alt_lb=alt_lb_s,
                                               bound_of=bound_of),
                         s)
        return s._replace(iters=s.iters + 1)

    def cond(c):
        sf, sb, mu = c
        return (~sf.done) & (sf.iters + sb.iters < 2 * max_iters)

    def body(c):
        sf, sb, mu = c
        frozen = sb.done | (sf.lb + sb.lb >= mu)
        fwd = frozen | (sf.lb <= sb.lb)
        sf = jax.lax.cond(fwd, lambda x: side_body(x, lb_f, target, mu),
                          lambda x: x, sf)
        sb = jax.lax.cond(fwd, lambda x: x,
                          lambda x: side_body(x, lb_b, source, mu), sb)
        mu = jnp.minimum(mu, jnp.min(sf.dist + sb.dist))
        return sf, sb, mu

    sf, sb, _mu = jax.lax.while_loop(
        cond, body, (init_state(source), init_state(target), INF))
    metrics = SsspMetrics(*[a + b for a, b in zip(sf.metrics, sb.metrics)])
    return sf.dist, sf.parent, metrics, None


@partial(jax.jit, static_argnames=("backend", "max_iters", "alpha", "beta",
                                   "goal", "fused_rounds", "trace_capacity",
                                   "policy", "p2p_mode"))
def _sssp_jit(g, layout, source, backend, max_iters, alpha, beta, goal,
              goal_param, fused_rounds=0, trace_capacity=0,
              policy="static", alt_data=None, p2p_mode="unidirectional"):
    return _run(g, layout, source, backend, max_iters, alpha, beta, goal,
                goal_param, fused_rounds, trace_capacity=trace_capacity,
                policy=policy, alt_data=alt_data, p2p_mode=p2p_mode)


@partial(jax.jit, static_argnames=("backend", "max_iters", "alpha", "beta",
                                   "goal", "fused_rounds", "trace_capacity",
                                   "policy", "p2p_mode"))
def _sssp_batch_jit(g, layout, sources, backend, max_iters, alpha, beta,
                    goal, goal_params, fused_rounds=0, trace_capacity=0,
                    policy="static", alt_data=None,
                    p2p_mode="unidirectional"):
    # build the fused slab once, outside vmap, so the concatenation isn't
    # replicated per batch slot
    fused = relax.fused_slab(layout) if (
        fused_rounds > 0 and isinstance(layout, relax.BlockedGraph)) \
        else None
    return jax.vmap(
        lambda s, gp: _run(g, layout, s, backend, max_iters, alpha, beta,
                           goal, gp, fused_rounds, fused,
                           trace_capacity=trace_capacity, policy=policy,
                           alt_data=alt_data, p2p_mode=p2p_mode)
    )(sources, goal_params)


@partial(jax.jit, static_argnames=("backend", "max_iters", "fused_rounds"))
def _repair_jit(layout, dist0, parent0, frontier0, backend, max_iters,
                fused_rounds):
    fused = relax.fused_slab(layout) if fused_rounds > 0 else None
    init = SsspState(dist=dist0, parent=parent0, frontier=frontier0,
                     lb=jnp.float32(0.0), ub=INF, st=jnp.float32(0.0),
                     done=jnp.bool_(False), iters=jnp.int32(0),
                     metrics=_zero_metrics())

    def cond(s: SsspState):
        return jnp.any(s.frontier) & (s.iters < max_iters)

    def body(s: SsspState):
        if fused_rounds > 0:
            s = _fused_relax_rounds(layout, fused, s, fused_rounds)
        else:
            s = _relax_round(backend, layout, s)
        return s._replace(iters=s.iters + 1)

    out = jax.lax.while_loop(cond, body, init)
    return out.dist, out.parent, out.metrics


def repair_relax(layout, dist, parent, frontier, *, backend="segment_min",
                 max_iters=1_000_000, fused_rounds=0):
    """Monotone re-relaxation to fixpoint from a repaired tentative state
    (the engine hook of :mod:`repro.delta`).

    Runs synchronized full-window relaxation rounds (``lb=0``,
    ``ub=+inf``) through the selected backend until no distance improves:
    each round's frontier is exactly the vertices the previous round
    improved, so the work is proportional to the delta's blast radius,
    not the graph.  Starting from a valid upper-bound state whose
    frontier covers every vertex that can initiate an improvement
    (:func:`repro.delta.repair` constructs one from an
    :class:`~repro.delta.AppliedDelta`), the fixpoint dist/parent are
    bitwise-identical to a from-scratch solve on the patched graph —
    the relaxation primitives (windowed candidates, parent-edge
    exclusion, leaf pruning, deterministic min/min-src tie-break) are
    the very same ones the stepping engines run, and the rounded
    fixpoint is schedule-independent.

    Metrics start from zero and count only the repair's own work
    (``n_relax``/``n_rounds``/... of the re-relaxation), which is what
    the delta benchmarks compare against a full recompute.  Returns
    ``(dist, parent, metrics)``.
    """
    be = relax.get_backend(backend)
    if fused_rounds > 0 and not isinstance(layout, relax.BlockedGraph):
        raise ConfigError(
            "fused_rounds needs a blocked layout for repair; got "
            f"{type(layout).__name__}")
    n = dist.shape[0]
    dist = jnp.asarray(dist, jnp.float32)
    parent = jnp.asarray(parent, jnp.int32)
    frontier = jnp.asarray(frontier, bool)
    if parent.shape != (n,) or frontier.shape != (n,):
        raise ValueError("dist/parent/frontier shapes disagree")
    with profiling.annotate("repro:repair_dispatch"):
        return _repair_jit(layout, dist, parent, frontier, be, max_iters,
                           fused_rounds)


def prepare_layout(g: DeviceGraph, backend="segment_min", **backend_opts):
    """Build a backend's graph layout once (host-side, outside ``jit``)."""
    be = relax.get_backend(backend)
    with profiling.annotate(f"repro:prepare_layout:{be.name}"):
        return be.prepare(g, **backend_opts)


def _engine_args(g: DeviceGraph, config, backend, max_iters, alpha, beta,
                 fused_rounds, policy, backend_opts):
    """Resolve the engine knobs from either an
    :class:`~repro.core.config.EngineConfig` or the loose engine-level
    kwargs — never both (:meth:`EngineConfig.from_loose` is the shared
    gate, so loose kwargs go through exactly the config validation)."""
    config = EngineConfig.from_loose(
        config, "engine", backend=backend, max_iters=max_iters, alpha=alpha,
        beta=beta, fused_rounds=fused_rounds, policy=policy, **backend_opts)
    r = as_resolved(config, n=g.n, m=g.m).require("single")
    return (relax.get_backend(r.backend), r.max_iters, r.alpha, r.beta,
            r.fused_rounds, r.trace_cap, r.policy, r.layout_opts(), r)


def _resolve_alt(g: DeviceGraph, landmarks, r, goal: str):
    """The traced :class:`~repro.core.relax.AltData` bundle for this
    solve, or None.  An explicit ``landmarks`` (a
    :class:`~repro.core.landmarks.LandmarkSet` or a raw ``AltData``)
    wins; otherwise a resolved ``use_alt=True`` config builds a set on
    the fly — uncached, so prefer the facade/registry, which cache per
    graph.  ALT bounds need a target: only p2p goals use them."""
    if goal != "p2p":
        return None
    if landmarks is None and getattr(r, "use_alt", False):
        from .landmarks import build_landmarks
        landmarks = build_landmarks(g, n_landmarks=r.n_landmarks,
                                    strategy=r.landmark_strategy)
    if landmarks is None:
        return None
    return getattr(landmarks, "alt_data", landmarks)


def sssp(g: DeviceGraph, source, *, backend=None, layout=None,
         max_iters=None, alpha=None, beta=None, fused_rounds=None,
         policy=None, goal: str = "tree", goal_param=None, config=None,
         landmarks=None, **backend_opts):
    """Run the heuristic SSSP algorithm from ``source``.

    This is the single-device *engine* entry point; prefer the
    :class:`repro.api.Solver` facade, which owns layout building and
    tier resolution.  ``config`` accepts an
    :class:`~repro.core.config.EngineConfig` (or a resolved one) in
    place of the loose ``backend``/``alpha``/``beta``/``max_iters``
    kwargs; pass a prebuilt ``layout`` (from :func:`prepare_layout`) to
    amortize backend preprocessing across calls.  ``goal``/``goal_param``
    select an early-exit query variant (see :data:`GOALS`).  Returns
    ``(dist, parent, metrics)`` — or ``(dist, parent, metrics,
    trace_buf)`` when the config enables per-round tracing
    (``EngineConfig(trace=True)``; materialize the device ring with
    :func:`repro.obs.materialize_trace`).  ``landmarks`` (a
    :class:`~repro.core.landmarks.LandmarkSet`) enables exact ALT
    goal-directed pruning for p2p goals; with ``use_alt=True`` in the
    config and no explicit set, one is built on the fly.
    """
    be, max_iters, alpha, beta, fr, tc, pol, opts, r = _engine_args(
        g, config, backend, max_iters, alpha, beta, fused_rounds, policy,
        backend_opts)
    if layout is None:
        layout = be.prepare(g, **opts)
    gp = goal_param_array(goal, goal_param)
    _check_goal_bounds(goal, gp, g.n)
    alt_data = _resolve_alt(g, landmarks, r, goal)
    with profiling.annotate("repro:sssp_dispatch"):
        out = _sssp_jit(g, layout, jnp.int32(source), be, max_iters, alpha,
                        beta, goal, gp, fr, tc, pol, alt_data, r.p2p_mode)
    return out if tc > 0 else out[:3]


def _shim(name: str, replacement: str) -> None:
    warnings.warn(
        f"{name} is deprecated: open a solver session instead — "
        f"`repro.api.Solver.open(g).solve({replacement})` (one facade "
        f"for every goal kind, tier, and backend)",
        FacadeDeprecationWarning, stacklevel=3)


def sssp_p2p(g: DeviceGraph, source, target, **kw):
    """Deprecated shim over the p2p goal (see :mod:`repro.api`).

    ``dist[target]`` and the parent chain target -> source are bitwise
    equal to the full-tree result; other entries may be tentative."""
    _shim("sssp_p2p", "SolveSpec.p2p(source, target)")
    return sssp(g, source, goal="p2p", goal_param=target, **kw)


def sssp_bounded(g: DeviceGraph, source, bound, **kw):
    """Deprecated shim over the distance-bounded goal (see
    :mod:`repro.api`): early exit once every vertex with
    ``dist <= bound`` is settled (entries above ``bound`` are tentative)."""
    _shim("sssp_bounded", "SolveSpec.bounded(source, bound)")
    return sssp(g, source, goal="bounded", goal_param=bound, **kw)


def sssp_knear(g: DeviceGraph, source, k, **kw):
    """Deprecated shim over the k-nearest goal (see :mod:`repro.api`):
    early exit once the source plus its ``k`` nearest vertices are
    settled (their distances are final; the rest tentative)."""
    _shim("sssp_knear", "SolveSpec.knear(source, k)")
    return sssp(g, source, goal="knear", goal_param=k, **kw)


def sssp_batch(g: DeviceGraph, sources, *, backend=None,
               layout=None, max_iters=None, alpha=None, beta=None,
               fused_rounds=None, policy=None, goal: str = "tree",
               goal_params=None, config=None, landmarks=None,
               **backend_opts):
    """Batched multi-source SSSP: one fused computation over ``sources``.

    The per-source state (dist/parent/frontier/window) is stacked along a
    leading batch axis via ``vmap``; sources that terminate early are
    masked out by the batched ``while_loop`` while the rest keep stepping.
    All slots share the (static) ``goal`` kind but carry per-slot
    ``goal_params`` (targets / bounds / k values).  ``config`` replaces
    the loose engine kwargs exactly as in :func:`sssp`.  Returns
    ``(dist, parent, metrics)`` with a leading ``[S]`` axis (plus a
    batch-stacked trace ring when the config enables tracing, as in
    :func:`sssp`).
    """
    be, max_iters, alpha, beta, fr, tc, pol, opts, r = _engine_args(
        g, config, backend, max_iters, alpha, beta, fused_rounds, policy,
        backend_opts)
    if layout is None:
        layout = be.prepare(g, **opts)
    sources = jnp.asarray(sources, jnp.int32)
    if goal == "tree" and goal_params is None:
        goal_params = [0] * sources.shape[0]
    gp = goal_param_array(goal, goal_params)
    if gp.shape != sources.shape:
        raise ValueError(f"goal_params shape {gp.shape} != sources shape "
                         f"{sources.shape}")
    _check_goal_bounds(goal, gp, g.n)
    alt_data = _resolve_alt(g, landmarks, r, goal)
    with profiling.annotate("repro:sssp_batch_dispatch"):
        out = _sssp_batch_jit(g, layout, sources, be, max_iters, alpha,
                              beta, goal, gp, fr, tc, pol, alt_data,
                              r.p2p_mode)
    return out if tc > 0 else out[:3]


def metrics_dict(metrics: SsspMetrics) -> dict:
    """Every ``SsspMetrics`` field as a host-side scalar, one key per
    field: logical counters (:data:`LOGICAL_METRIC_FIELDS`) as ``int``,
    physical counters (:data:`PHYSICAL_METRIC_FIELDS`) as ``float``.

    This is the canonical machine-readable export shape — the benchmark
    JSON emitter and the facade's telemetry both use it, and the export
    invariants (every field present, every value finite) are pinned by
    tests."""
    out = {}
    for name in SsspMetrics._fields:
        v = np.asarray(getattr(metrics, name))
        out[name] = float(v) if name in PHYSICAL_METRIC_FIELDS else int(v)
    return out


def normalized_metrics(g_deg, dist, metrics: SsspMetrics) -> dict:
    """Paper §4 normalizations: nFrontier, nSync, nTrav (host-side)."""
    import numpy as np
    deg = np.asarray(g_deg)
    d = np.asarray(dist)
    reach = np.isfinite(d)
    n_reach = max(int(reach.sum()), 1)
    nonleaf = max(int((reach & (deg > 1)).sum()), 1)
    logn = max(np.log2(max(deg.shape[0], 2)), 1.0)
    return {
        "nFrontier": float(metrics.n_extended) / nonleaf,
        "nSync": float(metrics.n_rounds) / logn,
        "nTrav": (float(metrics.n_trav) + float(metrics.n_pull_trav)) / n_reach,
        "nTrav_push": float(metrics.n_trav) / n_reach,
        "nTrav_pull": float(metrics.n_pull_trav) / n_reach,
        "n_steps": int(metrics.n_steps),
        "n_rounds": int(metrics.n_rounds),
        "n_relax": int(metrics.n_relax),
        "n_updates": int(metrics.n_updates),
        "n_pruned": int(metrics.n_pruned),
        "n_tiles_scanned": int(metrics.n_tiles_scanned),
        "n_tiles_dense": int(metrics.n_tiles_dense),
        "n_invocations": int(metrics.n_invocations),
        "reachable": n_reach,
    }
