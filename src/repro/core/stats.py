"""On-device vertex-degree / edge-weight statistics (paper §3, preamble).

Three statistics drive both heuristics:

* ``sumD(x)``   — total degree of ``VS(x) = {u : dist[u] >= x}``.
* ``highD(x)``  — degree threshold splitting ``VS(x)`` into two halves of
                  (approximately) equal total degree; computed from a
                  90-bucket degree histogram (exact for deg < 64, log2 buckets
                  above — see DESIGN.md §2 for the approximation note).
* ``maxW(G,r)`` — weight quantile; ``P(w(e) <= maxW(G, r)) = r``; served from
                  the precomputed ``RtoW`` LUT (paper §4.1).

All functions are jit-safe scalar reductions over the dist/deg arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import RATIO_NUM, N_DEG_BUCKETS, degree_bucket, bucket_representative

_BUCKET_REPS = bucket_representative()


def max_w_of(rtow: jnp.ndarray, ratio: jnp.ndarray) -> jnp.ndarray:
    """``maxW(G, ratio)`` via the RtoW quantile LUT."""
    idx = jnp.clip(jnp.round(ratio * (RATIO_NUM - 1)).astype(jnp.int32),
                   0, RATIO_NUM - 1)
    return rtow[idx]


def sum_d(dist: jnp.ndarray, deg: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Total degree of vertices with dist >= x (includes unreached, dist=inf)."""
    return jnp.sum(jnp.where(dist >= x, deg, 0).astype(jnp.int32))


def sum_d_grid(dist: jnp.ndarray, deg: jnp.ndarray,
               grid: jnp.ndarray) -> jnp.ndarray:
    """Vectorized ``sumD`` over an ascending grid of thresholds.

    O(V log G + G) via bucketed histogram + suffix sum, instead of O(V * G).
    ``sumD(grid[i])`` counts vertices with ``dist >= grid[i]``.
    """
    return sum_d_grid_from_hist(grid_hist(dist, deg, grid))


def grid_hist(dist: jnp.ndarray, deg: jnp.ndarray,
              grid: jnp.ndarray) -> jnp.ndarray:
    """Degree mass binned by dist into grid intervals (local partial)."""
    # bin[i] = index of first grid value > dist  (searchsorted right)
    bins = jnp.searchsorted(grid, dist, side="right")  # in [0, G]
    return jax.ops.segment_sum(deg.astype(jnp.int32), bins,
                               num_segments=grid.shape[0] + 1)


def sum_d_grid_from_hist(hist: jnp.ndarray) -> jnp.ndarray:
    # sumD(grid[i]) = sum of hist[j] for j > i  (dist >= grid[i] <=> bin > i)
    suffix = jnp.cumsum(hist[::-1])[::-1]
    return suffix[1:]  # [G]


def degree_hist(dist: jnp.ndarray, deg: jnp.ndarray,
                x: jnp.ndarray) -> jnp.ndarray:
    """Degree-mass histogram of VS(x) (local partial in distributed mode)."""
    mask = dist >= x
    b = degree_bucket(deg)
    mass = jnp.where(mask, deg, 0).astype(jnp.int32)
    return jax.ops.segment_sum(mass, b, num_segments=N_DEG_BUCKETS)


def high_d_from_hist(hist: jnp.ndarray) -> jnp.ndarray:
    """Weighted-median degree from a (possibly psum-reduced) histogram."""
    total = jnp.sum(hist)
    cum = jnp.cumsum(hist)
    # first bucket where cumulative mass reaches half the total
    half = (total + 1) // 2
    idx = jnp.argmax(cum >= half)
    rep = _BUCKET_REPS[idx]
    # empty VS(x) -> highD := 1 (neutral; gap() then uses maxW path)
    return jnp.where(total > 0, jnp.maximum(rep, 1.0), 1.0)


def high_d(dist: jnp.ndarray, deg: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Degree threshold balancing total degree of VS(x) into two halves.

    Returns the (approximate) weighted-median degree over VS(x); vertices
    with zero degree never matter (they carry no mass).
    """
    return high_d_from_hist(degree_hist(dist, deg, x))
