"""Declarative engine configuration — the one place backend/tier/placement
options live.

Four PRs of growth scattered a dozen loose keyword arguments
(``backend=``, ``shard_backend=``, ``shard_threshold_n=``,
``use_kernel=``, ...) across the single-device engine, the three
distributed engines, and the serving registry/router/service.  This
module replaces them with one frozen :class:`EngineConfig` value that
every layer accepts, plus an explicit, testable :meth:`EngineConfig.resolve`
step that turns the declarative config (which may say ``tier="auto"``)
into a concrete :class:`ResolvedEngine` — the engine tier, canonical
backend names, and device placement a solver session will actually use.

Resolution is deliberately separate from construction:

* ``EngineConfig(...)`` validates *context-free* invariants (known
  names, positive sizes) so a bad config fails where it is written;
* ``resolve(n=..., m=..., n_devices=...)`` validates *contextual*
  invariants (tier/backend conflicts, threshold-driven auto-tiering,
  device counts) and fails loudly **before** any tracing or layout
  build — a misconfigured solver never reaches ``jit``.

:class:`FacadeDeprecationWarning` marks the legacy ``sssp_*`` wrapper
entry points; tier-1 CI escalates it to an error so internal code cannot
quietly keep calling the shims (see ``pyproject.toml``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ConfigError", "FacadeDeprecationWarning", "EngineConfig",
           "ResolvedEngine", "TIERS", "SHARD_VERSIONS", "STEP_POLICIES",
           "P2P_MODES", "LANDMARK_STRATEGIES"]

TIERS = ("auto", "single", "sharded", "routed")
SHARD_VERSIONS = ("v1", "v2", "v3")
# stepping-policy names (kept in sync with repro.core.stepping.POLICIES)
STEP_POLICIES = ("static", "adaptive")
# p2p search directions (kept in sync with repro.core.sssp)
P2P_MODES = ("unidirectional", "bidirectional")
# landmark selection strategies (kept in sync with repro.core.landmarks)
LANDMARK_STRATEGIES = ("farthest", "max_degree")

# single-device relax-backend names whose sharded twin is the blocked
# per-shard layout (kept in sync with repro.core.distributed)
_BLOCKED_NAMES = ("blocked", "blocked_pallas")


class ConfigError(ValueError):
    """A contradictory or unresolvable :class:`EngineConfig`."""


class FacadeDeprecationWarning(DeprecationWarning):
    """Emitted by the legacy ``sssp_*`` wrapper shims.

    Kept as a dedicated category so the test suite can escalate exactly
    these to errors (internal code must use the :mod:`repro.api` facade)
    while parity tests exercise the shims under ``pytest.warns``.
    """


def _canonical_backend(name) -> str:
    """Resolve a relax-backend name/alias/object to its canonical name."""
    from . import relax
    try:
        return relax.get_backend(name).name
    except ValueError as exc:
        raise ConfigError(str(exc)) from None


def _canonical_shard_backend(name) -> str:
    """Resolve a backend name to the distributed engines' backend axis."""
    canon = _canonical_backend(name) if name not in ("segment_min",
                                                     "blocked") else name
    return "blocked" if canon in _BLOCKED_NAMES else canon


def resolve_devices(devices):
    """Concrete jax ``Device`` list for a config's ``devices`` field.

    Integer entries index ``jax.devices()`` (range-checked — a bad index
    raises :class:`ConfigError` here, not an ``IndexError`` mid-build);
    ``Device`` objects pass through; ``None`` stays ``None``.  The one
    conversion point for every config consumer (registry, router,
    service, solver)."""
    if devices is None:
        return None
    import jax
    pool = jax.devices()
    out = []
    for d in devices:
        if isinstance(d, int):
            if not 0 <= d < len(pool):
                raise ConfigError(f"device index {d} out of range for "
                                  f"{len(pool)} visible device(s)")
            out.append(pool[d])
        else:
            out.append(d)
    return out


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Declarative solver/serving configuration (frozen, hashable).

    One value of this type replaces the loose ``backend=`` /
    ``shard_backend=`` / ``shard_threshold_*`` / ``use_kernel=`` kwargs
    previously threaded through every layer.  Fields:

    * ``backend`` — single-device relaxation backend
      (:func:`repro.core.relax.available_backends`); aliases resolve.
    * ``tier`` — ``"single"`` (one device), ``"sharded"`` (whole-mesh
      ``shard_map`` engine), ``"routed"`` (multi-device serving plane),
      or ``"auto"`` (pick single vs sharded from the graph size against
      ``shard_threshold_n``/``shard_threshold_m``).
    * ``devices`` — explicit device placement (jax ``Device`` objects or
      integer indices); ``None`` uses every visible device for
      sharded/routed tiers and jax's default for single.
    * ``alpha``/``beta``/``max_iters`` — the stepping heuristic knobs.
    * ``policy`` — stepping policy: ``"static"`` (the paper's fixed
      Eq. 1-3 parameters) or ``"adaptive"`` (per-step feedback on
      ``alpha``/``beta`` and a window multiplier; see
      :mod:`repro.core.stepping`).  Scheduling-only: dist/parent match
      the static policy bitwise on graphs without exact float ties.
    * ``shard_backend`` — per-shard relaxation of the sharded tier
      (:data:`repro.core.distributed.DIST_BACKENDS`); ``None`` derives
      it from ``backend`` (``blocked_pallas`` -> ``blocked``).
    * ``shard_version``/``fused_rounds``/``compact_capacity`` — the
      distributed engine variant (v1/v2/v3, bucket-fusion waves, v3's
      compact-exchange capacity).
    * ``block_v``/``tile_e``/``use_kernel``/``interpret`` — blocked
      layout geometry (only meaningful with a blocked backend).
    * ``max_batch``/``registry_capacity``/``max_pending``/
      ``ecc_batching`` — serving-plane knobs (routed tier and the
      registry/scheduler stack).

    Construction validates context-free invariants; call
    :meth:`resolve` to validate tier/backend conflicts and obtain the
    concrete :class:`ResolvedEngine`.
    """

    backend: str = "segment_min"
    tier: str = "auto"
    devices: Optional[Tuple] = None
    alpha: float = 3.0
    beta: float = 0.9
    policy: str = "static"
    max_iters: int = 1_000_000
    # sharded tier
    shard_backend: Optional[str] = None
    shard_version: str = "v2"
    fused_rounds: int = 0
    compact_capacity: int = 0
    shard_threshold_n: Optional[int] = None
    shard_threshold_m: Optional[int] = None
    # blocked layout geometry
    block_v: Optional[int] = None
    tile_e: Optional[int] = None
    use_kernel: Optional[bool] = None
    interpret: bool = True
    # serving plane
    max_batch: int = 8
    registry_capacity: int = 4
    max_pending: Optional[int] = None
    ecc_batching: bool = True
    # streaming deltas: cumulative directed-edit fraction (edits / m) a
    # graph may accumulate before its perf artifacts (ALT landmark sets,
    # tuned-config overlays) stop being reused; repairs stay bitwise
    # regardless — the budget only gates *heuristic* artifact reuse
    delta_staleness_budget: float = 0.05
    # observability: per-round solve traces (repro.obs.trace)
    trace: bool = False
    trace_capacity: int = 256
    # goal-directed p2p: ALT landmark lower bounds + search direction
    use_alt: bool = False
    n_landmarks: int = 8
    landmark_strategy: str = "farthest"
    p2p_mode: str = "unidirectional"

    def __post_init__(self):
        if self.tier not in TIERS:
            raise ConfigError(f"unknown tier {self.tier!r}; expected one "
                              f"of {TIERS}")
        if self.policy not in STEP_POLICIES:
            raise ConfigError(f"unknown policy {self.policy!r}; expected "
                              f"one of {STEP_POLICIES}")
        if self.shard_version not in SHARD_VERSIONS:
            raise ConfigError(f"unknown shard_version "
                              f"{self.shard_version!r}; expected one of "
                              f"{SHARD_VERSIONS}")
        _canonical_backend(self.backend)        # fail on unknown names now
        if self.shard_backend is not None:
            sb = _canonical_shard_backend(self.shard_backend)
            if sb not in ("segment_min", "blocked"):
                raise ConfigError(f"unknown shard_backend "
                                  f"{self.shard_backend!r}")
        if self.devices is not None:
            object.__setattr__(self, "devices", tuple(self.devices))
            if not self.devices:
                raise ConfigError("devices, when given, must be non-empty")
        for name in ("alpha", "beta"):
            if getattr(self, name) <= 0:
                raise ConfigError(f"{name} must be > 0")
        for name in ("max_iters", "max_batch", "registry_capacity"):
            if getattr(self, name) < 1:
                raise ConfigError(f"{name} must be >= 1")
        for name in ("fused_rounds", "compact_capacity"):
            if getattr(self, name) < 0:
                raise ConfigError(f"{name} must be >= 0")
        for name in ("shard_threshold_n", "shard_threshold_m", "block_v",
                     "tile_e", "max_pending"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ConfigError(f"{name} must be >= 1 (or None)")
        if self.trace_capacity < 1:
            raise ConfigError("trace_capacity must be >= 1")
        if not 0.0 <= self.delta_staleness_budget <= 1.0:
            raise ConfigError("delta_staleness_budget must be in [0, 1]")
        if self.p2p_mode not in P2P_MODES:
            raise ConfigError(f"unknown p2p_mode {self.p2p_mode!r}; "
                              f"expected one of {P2P_MODES}")
        if self.landmark_strategy not in LANDMARK_STRATEGIES:
            raise ConfigError(
                f"unknown landmark_strategy {self.landmark_strategy!r}; "
                f"expected one of {LANDMARK_STRATEGIES}")
        if self.n_landmarks < 1:
            raise ConfigError("n_landmarks must be >= 1")
        if self.p2p_mode == "bidirectional":
            # the meet-in-the-middle search prunes both frontiers against
            # the shared meet bound, which only exists with ALT landmark
            # lower bounds; scheduling is forward-authoritative and fixed
            if not self.use_alt:
                raise ConfigError("p2p_mode='bidirectional' needs "
                                  "use_alt=True (the meet bound prunes "
                                  "through the ALT lower-bound machinery)")
            if self.policy != "static":
                raise ConfigError("p2p_mode='bidirectional' supports only "
                                  "policy='static'")
            if self.trace:
                raise ConfigError("p2p_mode='bidirectional' does not "
                                  "record per-round solve traces; drop "
                                  "trace=True")

    # ------------------------------------------------------------------
    # loose-kwarg adoption
    # ------------------------------------------------------------------

    @classmethod
    def from_loose(cls, config, what: str, *, defaults=None, **loose
                   ) -> "EngineConfig":
        """The one config-XOR-loose-kwargs gate for every entry point.

        Each engine/serving entry point accepts ``config=`` *or* its
        legacy loose kwargs, never both.  ``None`` is the unset sentinel
        for every loose kwarg (entry points default them all to None):

        * ``config`` given — every loose kwarg must still be unset, or
          this raises ``ConfigError("pass <what> options through
          config=, not alongside it")``; the config passes through.
        * ``config`` None — the set loose kwargs are layered over
          ``defaults`` (the entry point's historical defaults) and built
          into a fresh :class:`EngineConfig`; keys that are not config
          fields raise ``TypeError`` (unknown option), and values go
          through the constructor's usual validation.

        ``what`` names the entry point in the error message ("engine",
        "service", ...).  Relax-backend *objects* are accepted for
        ``backend`` and canonicalized to their registry name.
        """
        set_ = {k: v for k, v in loose.items() if v is not None}
        if config is not None:
            if set_:
                raise ConfigError(f"pass {what} options through config=, "
                                  f"not alongside it")
            return config
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(set_) - fields)
        if unknown:
            raise TypeError(f"unknown {what} options {unknown}")
        merged = dict(defaults or {})
        merged.update(set_)
        for key in ("backend", "shard_backend"):
            v = merged.get(key)
            if v is not None and not isinstance(v, str):
                merged[key] = _canonical_backend(v)
        return cls(**merged)

    # ------------------------------------------------------------------
    # resolution
    # ------------------------------------------------------------------

    @property
    def effective_shard_backend(self) -> str:
        """The sharded tier's backend: explicit, else derived from
        ``backend`` (``blocked_pallas`` maps to ``blocked``)."""
        if self.shard_backend is not None:
            return _canonical_shard_backend(self.shard_backend)
        return _canonical_shard_backend(self.backend)

    @property
    def has_thresholds(self) -> bool:
        return (self.shard_threshold_n is not None
                or self.shard_threshold_m is not None)

    def _auto_tier(self, n: Optional[int], m: Optional[int]) -> str:
        if not self.has_thresholds:
            return "single"
        if n is None and m is None:
            raise ConfigError(
                "tier='auto' with shard thresholds needs the graph size "
                "(resolve(n=..., m=...)) to pick single vs sharded")
        if (self.shard_threshold_n is not None and n is not None
                and n >= self.shard_threshold_n):
            return "sharded"
        if (self.shard_threshold_m is not None and m is not None
                and m >= self.shard_threshold_m):
            return "sharded"
        return "single"

    def validate_serving(self) -> "EngineConfig":
        """Contextual checks for the serving plane (registry / router /
        service), where per-graph tiering happens at ``register()`` time
        and per-lookup backends may override the defaults — so only
        combinations invalid under *every* possible lookup are rejected
        (blocked geometry without a blocked default stays legal: a
        per-lookup blocked backend consumes it)."""
        if self.compact_capacity and self.shard_version != "v3":
            raise ConfigError(
                "compact_capacity selects v3's compact exchange; set "
                "shard_version='v3' (or drop compact_capacity)")
        return self

    def resolve(self, *, n: Optional[int] = None, m: Optional[int] = None,
                n_devices: Optional[int] = None) -> "ResolvedEngine":
        """Resolve the declarative config against a graph/host context.

        ``n``/``m`` are the graph's vertex/edge counts (needed by
        ``tier="auto"`` thresholds); ``n_devices`` is the visible device
        count (defaults to ``len(jax.devices())``, or ``len(devices)``
        when the config pins devices).  Raises :class:`ConfigError` on
        any conflicting combination — **before** layouts are built or
        anything is traced.
        """
        backend = _canonical_backend(self.backend)
        shard_backend = self.effective_shard_backend

        tier = self.tier
        if tier == "auto":
            tier = self._auto_tier(n, m)
        elif self.has_thresholds and tier != "routed":
            # explicit single/sharded contradicts threshold auto-tiering;
            # routed keeps them (its registry tiers each graph)
            raise ConfigError(
                f"shard_threshold_n/_m only apply to tier='auto' or "
                f"'routed' (explicit tier {self.tier!r} already decided)")

        # sharded-only options are dead weight on a *necessarily* single
        # engine (explicit tier, or auto with no thresholds — which can
        # never resolve sharded).  Auto WITH thresholds legitimately
        # holds them for the graphs that cross the threshold, so a
        # deployment config must not fail data-dependently on small
        # graphs (the serving registry accepts it for the same reason).
        never_sharded = self.tier == "single" or not self.has_thresholds
        if tier == "single" and never_sharded:
            if self.shard_backend is not None:
                raise ConfigError(
                    "shard_backend is set but the engine can only "
                    "resolve to the single-device tier; drop it, set "
                    "tier='sharded', or add shard thresholds")
            if self.fused_rounds and backend not in _BLOCKED_NAMES:
                raise ConfigError(
                    "fused_rounds on the single-device tier needs a "
                    "blocked backend (the multi-round fused relaxation "
                    "megakernel); on segment_min it is a sharded-tier "
                    "option (bucket-fusion waves between exchanges)")
            if self.compact_capacity:
                raise ConfigError("compact_capacity is a sharded-tier "
                                  "option (v3's compact exchange)")
        if tier == "single" and never_sharded and self.devices is not None \
                and len(self.devices) > 1:
            # (with thresholds, a multi-device pin on a small graph just
            # places the single engine on devices[0])
            raise ConfigError(
                f"the single tier runs on one device; got "
                f"{len(self.devices)} (set tier='sharded' or 'routed')")
        self.validate_serving()
        if tier == "routed" and self.trace:
            raise ConfigError(
                "trace records per-round solve traces on the single/"
                "sharded tiers; the routed serving plane reports "
                "aggregate metrics through its MetricsRegistry instead "
                "(see repro.obs)")
        if tier == "sharded" and backend != "segment_min" \
                and self.shard_backend is not None \
                and shard_backend != _canonical_shard_backend(backend):
            raise ConfigError(
                f"backend={self.backend!r} and shard_backend="
                f"{self.shard_backend!r} disagree for tier='sharded'; "
                f"set one of them")
        if tier == "sharded" and self.p2p_mode == "bidirectional":
            raise ConfigError(
                "p2p_mode='bidirectional' runs on the single-device tier "
                "(the alternating forward/backward windows share one "
                "resident dist pair); use unidirectional ALT pruning on "
                "the sharded tier")
        blocked_anywhere = (backend in _BLOCKED_NAMES
                            or shard_backend == "blocked")
        if not blocked_anywhere:
            for name in ("block_v", "tile_e", "use_kernel"):
                if getattr(self, name) is not None:
                    raise ConfigError(
                        f"{name} is blocked-layout geometry but no blocked "
                        f"backend is selected (backend={backend!r}, "
                        f"shard_backend={shard_backend!r})")

        devices = self.devices
        if devices is not None:
            resolve_devices(devices)     # range-check int indices now
            if n_devices is not None and len(devices) != n_devices:
                raise ConfigError(
                    f"config pins {len(devices)} device(s) but the "
                    f"context provides {n_devices}")
            n_devices = len(devices)
        elif n_devices is None:
            import jax
            n_devices = len(jax.devices())
        if n_devices < 1:
            raise ConfigError("need at least one device")

        return ResolvedEngine(
            tier=tier, backend=backend, shard_backend=shard_backend,
            devices=devices, n_shards=(len(devices) if devices is not None
                                       else n_devices),
            alpha=self.alpha, beta=self.beta, policy=self.policy,
            max_iters=self.max_iters,
            shard_version=self.shard_version,
            fused_rounds=self.fused_rounds,
            compact_capacity=self.compact_capacity,
            shard_threshold_n=self.shard_threshold_n,
            shard_threshold_m=self.shard_threshold_m,
            block_v=self.block_v, tile_e=self.tile_e,
            use_kernel=self.use_kernel, interpret=self.interpret,
            max_batch=self.max_batch,
            registry_capacity=self.registry_capacity,
            max_pending=self.max_pending, ecc_batching=self.ecc_batching,
            trace=self.trace, trace_capacity=self.trace_capacity,
            use_alt=self.use_alt, n_landmarks=self.n_landmarks,
            landmark_strategy=self.landmark_strategy,
            p2p_mode=self.p2p_mode,
            config=self)


@dataclasses.dataclass(frozen=True)
class ResolvedEngine:
    """The concrete engine an :class:`EngineConfig` resolved to.

    Every field is decided: ``tier`` is never ``"auto"``, backend names
    are canonical, ``n_shards`` is the mesh width the sharded tier would
    span.  Produced only by :meth:`EngineConfig.resolve`; carried by the
    :class:`repro.api.Solver` session and accepted (in place of loose
    kwargs) by the engine entry points.
    """

    tier: str
    backend: str
    shard_backend: str
    devices: Optional[Tuple]
    n_shards: int
    alpha: float
    beta: float
    policy: str
    max_iters: int
    shard_version: str
    fused_rounds: int
    compact_capacity: int
    shard_threshold_n: Optional[int]
    shard_threshold_m: Optional[int]
    block_v: Optional[int]
    tile_e: Optional[int]
    use_kernel: Optional[bool]
    interpret: bool
    max_batch: int
    registry_capacity: int
    max_pending: Optional[int]
    ecc_batching: bool
    trace: bool
    trace_capacity: int
    use_alt: bool
    n_landmarks: int
    landmark_strategy: str
    p2p_mode: str
    config: EngineConfig

    @property
    def trace_cap(self) -> int:
        """The engine-level trace knob: ring capacity, 0 = tracing off
        (the static jit key — 0 compiles the exact pre-trace program)."""
        return self.trace_capacity if self.trace else 0

    def require(self, *tiers: str) -> "ResolvedEngine":
        if self.tier not in tiers:
            raise ConfigError(f"engine resolved to tier {self.tier!r}; "
                              f"this entry point needs {tiers}")
        return self

    def resolve_devices(self):
        """Pinned devices as concrete jax ``Device`` objects (or None)."""
        return resolve_devices(self.devices)

    def layout_opts(self) -> dict:
        """Geometry kwargs for ``RelaxBackend.prepare`` /
        :func:`repro.core.graph.build_blocked` (only set fields)."""
        out = {}
        for name in ("block_v", "tile_e", "use_kernel"):
            v = getattr(self, name)
            if v is not None:
                out[name] = v
        if self.backend in _BLOCKED_NAMES:
            out["interpret"] = self.interpret
        return out

    def blocked_opts(self) -> dict:
        """Geometry kwargs for :func:`repro.core.distributed.shard_blocked`."""
        out = {}
        for name in ("block_v", "tile_e", "use_kernel"):
            v = getattr(self, name)
            if v is not None:
                out[name] = v
        out["interpret"] = self.interpret
        return out


def as_resolved(config, *, n=None, m=None, n_devices=None) -> ResolvedEngine:
    """Accept an :class:`EngineConfig` or an already-resolved engine."""
    if isinstance(config, ResolvedEngine):
        return config
    if isinstance(config, EngineConfig):
        return config.resolve(n=n, m=m, n_devices=n_devices)
    raise ConfigError(f"expected EngineConfig or ResolvedEngine, got "
                      f"{type(config)}")
