"""Pluggable relaxation backends for the EIC engines (paper Algo 2 l.8-17).

The windowed edge relaxation is the algorithm's inner loop and the only
part that differs between execution strategies (dense ``segment_min``,
blocked Pallas kernels, per-shard relaxation under ``shard_map``).  This
module owns that hot path:

* the **backend interface** — ``relax_window(layout, dist, parent,
  frontier, lb, ub) -> (new_dist, new_parent, RoundMetrics)`` — with a
  registry (:func:`get_backend` / :func:`available_backends`) so engines,
  benchmarks and services select implementations by name;
* the **shared relaxation primitives** (leaf pruning, windowed candidate
  generation, deterministic segment-min + winner recovery, update
  application, partial combination) that every engine builds from — the
  distributed engines in ``core/distributed.py`` compose these with their
  collectives instead of duplicating the relax logic.

Registered backends:

``segment_min``
    The dense flat-edge-list path (extracted from the original
    ``sssp._relax_round``): one masked ``segment_min`` over all edges plus
    a min-source winner pass.  Layout = the ``DeviceGraph`` itself.

``blocked_pallas`` (alias ``blocked``)
    The TPU hot path: a :class:`~repro.core.graph.BlockedGraph` layout
    (edges bucketed by (src block x dst block), every bucket tile-aligned
    with a CSR-of-tiles index) drives the ``kernels/edge_relax`` Pallas
    kernel once per source block over a *ragged* tile grid: each
    destination block iterates only its own tile range, and a
    frontier-compaction prepass skips tiles with no frontier source this
    round entirely.  Per-source-block (min, winner) partials are combined
    with the same deterministic min/min-src rule.  On this CPU container
    the kernel runs in interpret mode.  The same per-shard machinery
    (:func:`blocked_partials`) backs ``core/distributed.py``'s
    ``backend="blocked"`` inside ``shard_map``.

Determinism note: every backend resolves ties toward the smallest source
id, so ``dist``/``parent`` (and the logical traversal metrics) are
bitwise-identical across backends — the parity tests in
``tests/test_relax_backends.py`` assert exactly that.  The *physical*
tile counters (``n_tiles_scanned`` / ``n_tiles_dense``) are
layout-specific and excluded from cross-backend parity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .graph import DeviceGraph, BlockedGraph, build_blocked
from ..kernels.edge_relax.ops import relax_bucket, relax_fused, relax_partials

INT_MAX = jnp.iinfo(jnp.int32).max
INF = jnp.float32(jnp.inf)


class RoundMetrics(NamedTuple):
    """Per-round relaxation outcome.

    The logical counters (trav/relax/updates/extended) are identical
    across backends; the tile/invocation counters are *physical* — they
    describe the blocked layout's work (0 for layouts without tiles) and
    are excluded from cross-backend parity.
    """
    improved: jnp.ndarray    # [N] bool — vertices whose dist improved
    n_trav: jnp.ndarray      # scalar int32 — in-window edge touches (push)
    n_relax: jnp.ndarray     # scalar int32 — relaxations attempted
    n_updates: jnp.ndarray   # scalar int32 — successful dist improvements
    n_extended: jnp.ndarray  # scalar int32 — non-leaf dist improvements
    n_pruned: jnp.ndarray    # scalar int32 — candidates cut by the ALT bound
    # physical counters are f32: the dense comparator accumulates
    # n_dst_blocks * n_tiles per round, which overflows int32 on large
    # graphs (and x64 is disabled, so int64 is unavailable)
    n_tiles_scanned: jnp.ndarray  # scalar f32 — edge tiles actually run
    n_tiles_dense: jnp.ndarray    # scalar f32 — dense-grid tile cost
    n_invocations: jnp.ndarray    # scalar f32 — kernel launches (sync units)


# ---------------------------------------------------------------------------
# shared relaxation primitives
# ---------------------------------------------------------------------------

def leaf_pruned(frontier: jnp.ndarray, dist: jnp.ndarray,
                deg: jnp.ndarray) -> jnp.ndarray:
    """Algo 2 l.8: paths reaching a leaf are never extended."""
    return frontier & ((dist <= 0.0) | (deg > 1))


def edge_candidates(d_src, f_src, p_src, dst, w, lb, ub):
    """Algo 2 l.10-11: windowed candidate lengths over gathered edge values.

    ``d_src``/``f_src``/``p_src`` are dist/frontier/parent gathered at each
    edge's source.  Returns ``(cand, in_window, active)`` where ``cand`` is
    +inf outside the active set; ``active`` additionally excludes the
    relaxation back along the parent edge (which can never improve).
    """
    cand_len = d_src + w
    in_window = f_src & (cand_len >= lb) & (cand_len < ub)
    active = in_window & (dst != p_src)
    return jnp.where(active, cand_len, INF), in_window, active


def segment_partial_min(cand, seg, num_segments: int):
    """Per-destination min of candidates (a shard's local partial)."""
    return jax.ops.segment_min(cand, seg, num_segments=num_segments)


def winner_partial(cand, mask, ids, seg, best, num_segments: int):
    """Deterministic winner recovery: min ``ids`` among candidates that
    achieve ``best`` at their segment (masked; INT_MAX where none)."""
    win = jnp.where(mask & (cand <= best[seg]), ids, INT_MAX)
    return jax.ops.segment_min(win, seg, num_segments=num_segments)


def segment_min_with_winner(cand, mask, ids, seg, num_segments: int):
    """The fused (min, argmin-by-min-id) segment reduction."""
    best = segment_partial_min(cand, seg, num_segments)
    return best, winner_partial(cand, mask, ids, seg, best, num_segments)


def apply_updates(dist, parent, best, winner, gate=None):
    """Commit improvements: ``dist``/``parent`` where ``best < dist``
    (optionally gated by an extra per-vertex mask)."""
    improved = best < dist
    if gate is not None:
        improved = improved & gate
    return (jnp.where(improved, best, dist),
            jnp.where(improved, winner, parent), improved)


def combine_block_partials(vals, wins):
    """Combine stacked (min, winner) partials over the leading axis with
    the deterministic min-value / min-id-on-tie rule."""
    best = jnp.min(vals, axis=0)
    winner = jnp.min(jnp.where(vals <= best[None, :], wins, INT_MAX),
                     axis=0)
    return best, winner


def window_frontier(dist, st, lb, ub, max_w):
    """Function 1's frontier: the push band [max(0, lb - maxW), st] of
    settled vertices whose edges may reach into the window, plus the
    window occupants themselves."""
    lb0 = jnp.maximum(0.0, lb - max_w)
    return ((dist >= lb0) & (dist <= st)) | ((dist >= lb) & (dist < ub))


def settled_mask(dist, lb):
    """Vertices whose distance is final under the stepping invariant.

    Every vertex with ``dist < lb`` is settled: all shorter paths were
    relaxed in earlier windows, and any pending candidate has length
    >= lb.  This is the predicate the early-exit query goals (p2p /
    distance-bounded / k-nearest in :mod:`repro.core.sssp`) test against.
    """
    return dist < lb


# ---------------------------------------------------------------------------
# ALT (A*, landmarks, triangle inequality) goal-directed pruning primitives
# ---------------------------------------------------------------------------
#
# With per-landmark distance vectors D[l, v] = d(L_l, v), the triangle
# inequality gives an admissible lower bound on the remaining distance
# v -> t:  d(L,t) <= d(L,v) + d(v,t)  =>  d(L,t) - d(L,v) <= d(v,t)
# (valid on directed graphs); on symmetric graphs the reverse difference
# d(L,v) - d(L,t) <= d(t,v) = d(v,t) holds too, so |.| applies.  A p2p
# candidate with dist[v] + w + lb[v] provably above the best known s->t
# length can never lie on an improving s->t path and is dropped inside
# the relaxation.
#
# Exactness under f32: the engine's committed distances, the landmark
# vectors, and the prune bound are all independently rounded path sums,
# so the raw triangle inequality can be violated by accumulated rounding
# even though it holds in exact arithmetic.  Both sides therefore carry
# a margin derived from the worst-case relative error of a length-H f32
# nonneg sum (H = hop bound from the landmark BFS, delta ~ H * 2^-24):
# the per-vertex bound is *deflated* by delta * (D[l,t] + D[l,v]) — an
# absolute slack covering the error of both landmark sums — and the
# prune bound is *inflated* by (1 + 4 delta).  Every candidate on the
# engine's own returned shortest path then survives pruning, which is
# what keeps pruned d(s,t)/parent chains bitwise-identical to the
# unpruned solve (the gate tests in tests/test_alt_p2p.py).

def alt_lower_bounds(D, t, delta, sym):
    """Admissible per-vertex lower bounds ``lb[v] <~ d(v, t)``.

    ``D`` is the ``[L, N]`` f32 landmark distance matrix, ``t`` the
    target id, ``delta`` the f32 rounding-slack factor and ``sym`` a
    traced 0/1 f32 flag (1 => the graph is symmetric and the reverse
    difference is admissible too).  Unreachable pairs resolve exactly:
    both-infinite differences contribute 0; a one-sided infinity means v
    and t lie in different components of the landmark's reach, where an
    infinite bound is correct.
    """
    Dt = D[:, t][:, None]                      # [L, 1]
    fwd = Dt - D                               # d(L,t) - d(L,v)
    rev = jnp.where(sym > 0, D - Dt, -INF)
    diff = jnp.maximum(fwd, rev)
    # deflate finite bounds by the accumulated-rounding slack; infinite
    # bounds stay infinite (different components), nan (inf - inf, both
    # unreachable from L) carries no information -> 0
    adj = jnp.where(jnp.isinf(diff), diff, diff - delta * (D + Dt))
    adj = jnp.where(jnp.isnan(adj), 0.0, adj)
    return jnp.max(jnp.maximum(adj, 0.0), axis=0)


def alt_seed_ub(D, source, t, infl, sym):
    """Landmark-seeded upper bound on d(source, t) (symmetric graphs):
    ``min_l d(L,s) + d(L,t)``, inflated by ``infl`` so it dominates the
    engine's own f32 path sum.  +inf when the graph is not symmetric
    (d(s,L) is unknown there) or no landmark reaches both endpoints."""
    seed = jnp.min(D[:, source] + D[:, t]) * infl
    return jnp.where(sym > 0, seed, INF)


def alt_prune(cand, active, lb_dst, prune_bound):
    """Split ``active`` candidates by the ALT test: returns
    ``(kept, pruned)`` masks where pruned candidates satisfy
    ``cand + lb[dst] > prune_bound`` (cand is +inf outside ``active``,
    so inactive lanes land in neither)."""
    pruned = active & (cand + lb_dst > prune_bound)
    return active & ~pruned, pruned


class AltData(NamedTuple):
    """The traced ALT operand bundle a p2p solve carries through ``jit``.

    ``D`` is the ``[L, N]`` f32 landmark distance matrix, ``delta`` the
    scalar f32 rounding-slack factor (``2^-24 * (2 H + 64)`` for hop
    bound ``H``) and ``sym`` a scalar f32 0/1 flag (1 => the graph is
    symmetric, enabling the reverse difference and the landmark-seeded
    upper bound).  Built by :class:`repro.core.landmarks.LandmarkSet`;
    a plain pytree so presence/absence is the only retrace axis.
    """
    D: jnp.ndarray
    delta: jnp.ndarray
    sym: jnp.ndarray


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RelaxBackend:
    """A pluggable implementation of the windowed relaxation hot path.

    ``prepare(graph, **opts)`` builds the backend's layout pytree once per
    graph (host-side, outside ``jit``); ``relax_window(layout, dist,
    parent, frontier, lb, ub)`` executes one synchronized round.
    """
    name: str
    prepare: Callable[..., Any]
    relax_window: Callable[..., Any]


_REGISTRY: dict = {}


def register_backend(backend: RelaxBackend, aliases=()) -> RelaxBackend:
    # annotate layout builds at the source: every prepare() — from the
    # facade, the serving registry, or direct engine calls — shows up as
    # one repro:relax_prepare:<name> span in jax.profiler captures
    from ..obs import profiling

    prepare = backend.prepare
    scope = f"repro:relax_prepare:{backend.name}"

    def profiled_prepare(g, **opts):
        with profiling.annotate(scope):
            return prepare(g, **opts)

    backend = dataclasses.replace(backend, prepare=profiled_prepare)
    _REGISTRY[backend.name] = backend
    for alias in aliases:
        _REGISTRY[alias] = backend
    return backend


def available_backends() -> tuple:
    """Canonical backend names (aliases resolve but are not listed)."""
    return tuple(sorted({b.name for b in _REGISTRY.values()}))


def get_backend(name) -> RelaxBackend:
    if isinstance(name, RelaxBackend):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown relax backend {name!r}; available: "
            f"{available_backends()}") from None


# ---------------------------------------------------------------------------
# backend: segment_min (dense flat edge list)
# ---------------------------------------------------------------------------

def _segment_min_prepare(g: DeviceGraph, **_opts) -> DeviceGraph:
    return g            # the flat edge list is its own layout


def _segment_min_relax(g: DeviceGraph, dist, parent, frontier, lb, ub,
                       alt_lb=None, prune_bound=None):
    paths = leaf_pruned(frontier, dist, g.deg)
    cand, in_window, active = edge_candidates(
        dist[g.src], paths[g.src], parent[g.src], g.dst, g.w, lb, ub)
    n_pruned = jnp.int32(0)
    if alt_lb is not None:
        active, pruned = alt_prune(cand, active, alt_lb[g.dst], prune_bound)
        cand = jnp.where(active, cand, INF)
        n_pruned = jnp.sum(pruned.astype(jnp.int32))
    best, winner = segment_min_with_winner(cand, active, g.src, g.dst, g.n)
    new_dist, new_parent, improved = apply_updates(dist, parent, best,
                                                   winner)
    rm = RoundMetrics(
        improved=improved,
        n_trav=jnp.sum(in_window.astype(jnp.int32)),
        n_relax=jnp.sum(active.astype(jnp.int32)),
        n_updates=jnp.sum(improved.astype(jnp.int32)),
        n_extended=jnp.sum((improved & (g.deg > 1)).astype(jnp.int32)),
        n_pruned=n_pruned,
        n_tiles_scanned=jnp.float32(0),
        n_tiles_dense=jnp.float32(0),
        n_invocations=jnp.float32(0))
    return new_dist, new_parent, rm


SEGMENT_MIN = register_backend(RelaxBackend(
    name="segment_min", prepare=_segment_min_prepare,
    relax_window=_segment_min_relax))


# ---------------------------------------------------------------------------
# backend: blocked_pallas (BlockedGraph layout -> edge_relax kernel)
# ---------------------------------------------------------------------------

def _blocked_prepare(g, **opts) -> BlockedGraph:
    return build_blocked(g, **opts)


def _combine_bucket_partials(slab_of, n_src_blocks, dist_src, paths_src,
                             src_base, lb, ub, *, block_v, n_dst_blocks,
                             tile_e, use_kernel, interpret, alt_lb=None,
                             prune_bound=None):
    """Shared core of the blocked partial computations: relax every
    source block's bucketed slab, lift winners to global source ids
    (deterministic INT_MAX-preserving offset), combine deterministically.
    ``slab_of(s)`` returns source block ``s``'s ``(src_local, dst, w,
    tile_dst, tile_first, bucket_nonempty)`` arrays."""
    paths_i8 = paths_src.astype(jnp.int8)
    vals, wins = [], []
    n_tiles = jnp.int32(0)
    for s in range(n_src_blocks):
        lo = s * block_v
        best_sb, win_local, nt = relax_bucket(
            dist_src[lo:lo + block_v], paths_i8[lo:lo + block_v],
            *slab_of(s), lb, ub, block_v=block_v,
            n_dst_blocks=n_dst_blocks, tile_e=tile_e,
            use_kernel=use_kernel, interpret=interpret, alt_lb=alt_lb,
            prune_bound=prune_bound)
        vals.append(best_sb)
        wins.append(jnp.where(win_local == INT_MAX, INT_MAX,
                              win_local + (src_base + lo)))
        n_tiles = n_tiles + nt
    best, winner = combine_block_partials(jnp.stack(vals), jnp.stack(wins))
    return best, winner, n_tiles


def blocked_partials(bg: BlockedGraph, dist_src, paths_src, lb, ub,
                     alt_lb=None, prune_bound=None):
    """Per-destination (min, winner) partials of one blocked layout.

    ``dist_src``/``paths_src`` cover the layout's *source* range
    ``[src_base, src_base + n_blocks * block_v)`` (the full padded graph
    for ``build_blocked`` layouts, the owner block for
    :func:`~repro.core.graph.slice_for_shard` slabs).  Returns ``(best,
    winner, n_tiles)`` over the global ``n_out`` destination range —
    winners are *global* source ids (``src_base`` applied), so shard
    partials feed the distributed exchange unchanged and single-device
    partials feed :func:`apply_updates` directly.
    """
    return _combine_bucket_partials(
        lambda s: bg.slabs[s], bg.n_blocks, dist_src, paths_src,
        bg.src_base, lb, ub, block_v=bg.block_v,
        n_dst_blocks=bg.n_dst_blocks, tile_e=bg.tile_e,
        use_kernel=bg.use_kernel, interpret=bg.interpret, alt_lb=alt_lb,
        prune_bound=prune_bound)


def blocked_shard_partials(src_local, dst, w, tile_dst, tile_first,
                           bucket_nonempty, dist_src, paths_src, src_base,
                           lb, ub, *, block_v: int, n_dst_blocks: int,
                           tile_e: int, use_kernel: bool, interpret: bool,
                           alt_lb=None, prune_bound=None):
    """`shard_map` twin of :func:`blocked_partials`.

    Same computation over one shard's *stacked* uniform slabs
    (``src_local``/``dst``/``w`` are ``[S, NT*tile_e]``,
    ``tile_dst``/``tile_first`` ``[S, NT]``, ``bucket_nonempty``
    ``[S, n_dst_blocks]`` — shapes identical across shards, a shard_map
    requirement) with a *traced* ``src_base`` (the shard's owner-block
    offset).  ``dist_src``/``paths_src`` are the shard's local source
    slice.  Returns global-id ``(best, winner, n_tiles)`` over the
    ``n_dst_blocks * block_v`` destination range, ready for the engines'
    collective merge.
    """
    return _combine_bucket_partials(
        lambda s: (src_local[s], dst[s], w[s], tile_dst[s], tile_first[s],
                   bucket_nonempty[s]),
        src_local.shape[0], dist_src, paths_src, src_base, lb, ub,
        block_v=block_v, n_dst_blocks=n_dst_blocks, tile_e=tile_e,
        use_kernel=use_kernel, interpret=interpret, alt_lb=alt_lb,
        prune_bound=prune_bound)


def _blocked_relax(bg: BlockedGraph, dist, parent, frontier, lb, ub,
                   alt_lb=None, prune_bound=None):
    bv = bg.block_v
    pad = bg.n_out - dist.shape[0]
    dist_p = jnp.pad(dist, (0, pad), constant_values=jnp.inf)
    parent_p = jnp.pad(parent, (0, pad), constant_values=-1)
    frontier_p = jnp.pad(frontier, (0, pad))
    paths = leaf_pruned(frontier_p, dist_p, bg.deg)
    alt_p = None if alt_lb is None else jnp.pad(
        alt_lb, (0, bg.n_out - alt_lb.shape[0]), constant_values=jnp.inf)

    best, winner, n_tiles = blocked_partials(bg, dist_p, paths, lb, ub,
                                             alt_p, prune_bound)

    # Traversal counters are cheap jnp reductions over the slabs (the
    # kernel owns only the scatter-min); the parent-edge exclusion in
    # `active` cannot change the kernel's min/winner — relaxing back
    # along the parent edge never improves the parent's dist.
    n_trav = jnp.int32(0)
    n_relax = jnp.int32(0)
    n_pruned = jnp.int32(0)
    for sb, slab in enumerate(bg.slabs):
        src_g = slab.src_local + sb * bv
        cand, in_window, active = edge_candidates(
            dist_p[src_g], paths[src_g], parent_p[src_g], slab.dst,
            slab.w, lb, ub)
        if alt_p is not None:
            active, pruned = alt_prune(cand, active, alt_p[slab.dst],
                                       prune_bound)
            n_pruned = n_pruned + jnp.sum(pruned.astype(jnp.int32))
        n_trav = n_trav + jnp.sum(in_window.astype(jnp.int32))
        n_relax = n_relax + jnp.sum(active.astype(jnp.int32))

    new_dist, new_parent, improved = apply_updates(dist_p, parent_p, best,
                                                   winner)
    n = bg.n
    improved = improved[:n]
    rm = RoundMetrics(
        improved=improved,
        n_trav=n_trav,
        n_relax=n_relax,
        n_updates=jnp.sum(improved.astype(jnp.int32)),
        n_extended=jnp.sum((improved & (bg.deg[:n] > 1)).astype(jnp.int32)),
        n_pruned=n_pruned,
        n_tiles_scanned=n_tiles.astype(jnp.float32),
        n_tiles_dense=jnp.float32(bg.dense_grid_tiles),
        n_invocations=jnp.float32(bg.n_blocks))
    return new_dist[:n], new_parent[:n], rm


BLOCKED_PALLAS = register_backend(RelaxBackend(
    name="blocked_pallas", prepare=_blocked_prepare,
    relax_window=_blocked_relax), aliases=("blocked",))


# ---------------------------------------------------------------------------
# fused megakernel entry points (multi-round single-device / whole-shard
# partials — see kernels/edge_relax/edge_relax.py for the kernel contract)
# ---------------------------------------------------------------------------

class FusedSlab(NamedTuple):
    """A :class:`~repro.core.graph.BlockedGraph`'s per-source-block slabs
    concatenated into one tile-aligned slab with *global* source ids —
    the operand layout of the fused megakernel.  Built once per solve
    (outside the round loop); tile indices stay dst-sorted within each
    source block, which is all the scheduled scatter-min requires."""
    src: jnp.ndarray          # [sum NT * tile_e] global source ids
    dst: jnp.ndarray          # [sum NT * tile_e] global destination ids
    w: jnp.ndarray            # [sum NT * tile_e] weights (+inf padding)
    tile_dst: jnp.ndarray     # [sum NT] per-tile destination block
    tile_first: jnp.ndarray   # [sum NT] forced first tile per bucket


def fused_slab(bg: BlockedGraph) -> FusedSlab:
    """Concatenate a blocked layout's slabs for the fused megakernel."""
    bv = bg.block_v
    return FusedSlab(
        src=jnp.concatenate([s.src_local + i * bv
                             for i, s in enumerate(bg.slabs)]),
        dst=jnp.concatenate([s.dst for s in bg.slabs]),
        w=jnp.concatenate([s.w for s in bg.slabs]),
        tile_dst=jnp.concatenate([s.tile_dst for s in bg.slabs]),
        tile_first=jnp.concatenate([s.tile_first for s in bg.slabs]))


def blocked_fused_rounds(bg: BlockedGraph, fs: FusedSlab, dist, parent,
                         frontier, lb, ub, *, fused_rounds: int,
                         alt_lb=None, prune_ub=None, prune_infl=None,
                         prune_tgt=None):
    """Up to ``fused_rounds`` relaxation rounds in one kernel invocation.

    The fused twin of calling :func:`_blocked_relax` once per round:
    bitwise-identical dist/parent/frontier and logical counters, but the
    state stays resident in the kernel across rounds and the counters
    are folded into the scheduled tile pass (no separate O(E) metrics
    pass).  Returns ``(dist, parent, frontier, counts)`` over the
    *unpadded* vertex range; ``counts`` is the kernel's int32
    ``FUSED_COUNTERS`` vector.

    With ``alt_lb`` (ALT p2p pruning) the kernel recomputes the prune
    bound at every in-kernel round start as
    ``min(prune_ub, dist[prune_tgt] * prune_infl)`` — exactly what the
    unfused path computes per round — so fused and unfused pruning
    decisions (and the ``n_pruned`` counter) stay bitwise-identical.
    """
    if bg.n_pad != bg.n_out or bg.src_base != 0:
        raise ValueError(
            "the fused megakernel needs a whole-graph blocked layout "
            f"(source range == destination range); got n_pad={bg.n_pad}, "
            f"n_out={bg.n_out}, src_base={bg.src_base}")
    n = bg.n
    pad = bg.n_out - dist.shape[0]
    dist_p = jnp.pad(dist, (0, pad), constant_values=jnp.inf)
    parent_p = jnp.pad(parent, (0, pad), constant_values=-1)
    frontier_p = jnp.pad(frontier, (0, pad))
    alt_p = None if alt_lb is None else jnp.pad(
        alt_lb, (0, bg.n_out - alt_lb.shape[0]), constant_values=jnp.inf)
    dist2, parent2, front2, cnt = relax_fused(
        dist_p, parent_p, frontier_p, bg.deg, fs.src, fs.dst, fs.w,
        fs.tile_dst, fs.tile_first, lb, ub, block_v=bg.block_v,
        tile_e=bg.tile_e, fused_rounds=fused_rounds,
        use_kernel=bg.use_kernel, interpret=bg.interpret, alt_lb=alt_p,
        prune_ub=prune_ub, prune_infl=prune_infl, prune_tgt=prune_tgt)
    return dist2[:n], parent2[:n], front2[:n] > 0, cnt


def blocked_shard_partials_fused(src_local, dst, w, tile_dst, tile_first,
                                 dist_src, paths_src, parent_src, src_base,
                                 lb, ub, *, block_v: int, n_dst_blocks: int,
                                 tile_e: int, use_kernel: bool,
                                 interpret: bool, alt_lb=None,
                                 prune_bound=None):
    """Whole-shard fused twin of :func:`blocked_shard_partials`.

    One kernel invocation relaxes ALL of a shard's stacked slabs
    (``src_local``/``dst``/``w`` ``[S, NT*tile_e]``,
    ``tile_dst``/``tile_first`` ``[S, NT]``) against the shard's local
    ``dist_src``/``paths_src``/``parent_src`` slice, folding ``n_trav``/
    ``n_relax``/tile counts into the scheduled tile pass — replacing one
    launch per source block plus the flat O(E) metrics pass.  Returns
    ``(best, winner, n_tiles, n_trav, n_relax, n_pruned)`` with *global*
    winner ids (``src_base`` applied, INT_MAX preserved).
    """
    n_sb = src_local.shape[0]
    offs = (jnp.arange(n_sb, dtype=jnp.int32) * block_v)[:, None]
    best, win_local, cnt = relax_partials(
        dist_src, paths_src, parent_src,
        (src_local + offs).reshape(-1), dst.reshape(-1), w.reshape(-1),
        tile_dst.reshape(-1), tile_first.reshape(-1), lb, ub,
        block_v=block_v, tile_e=tile_e, n_dst_blocks=n_dst_blocks,
        use_kernel=use_kernel, interpret=interpret, alt_lb=alt_lb,
        prune_bound=prune_bound)
    winner = jnp.where(win_local == INT_MAX, INT_MAX, win_local + src_base)
    return best, winner, cnt[2], cnt[0], cnt[1], cnt[3]
