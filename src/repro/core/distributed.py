"""Distributed EIC SSSP via ``shard_map`` (DESIGN.md §4).

The MPI design of the paper (one vertex-owner process per rank, async RELAX /
REQUEST messages) maps onto two bulk-synchronous TPU engines:

* **v1 — replicated-dist / all-reduce-min** (paper-faithful baseline).
  ``dist``/``parent`` replicated on every device; the edge list is 1-D
  partitioned.  Each round every device relaxes its local in-window edges
  into a dense candidate array and a global ``pmin`` merges.  Collective
  volume: 2 × O(N) per round (cand f32 + winner i32 all-reduce).

* **v2 — sharded-dist / all-to-all reduce-scatter-min** (beyond-paper).
  Vertices are block-partitioned; each device owns ``dist``/``parent`` for
  its block and the edge slab whose *sources* it owns (the paper's
  owner-process layout).  Candidates are segment-min'ed per destination
  block and exchanged with ``all_to_all`` (a reduce-scatter-min), so memory
  is O(N/P) per device and collective volume halves to O(N) send+recv per
  round.  The paper's *bucket fusion* becomes ``fused_rounds`` local-only
  relaxation sub-rounds (edges whose dst block is local) between exchanges.
  The pull phase is executed as a mirrored push (undirected graphs store
  both directions), reusing the same exchange primitive.

Both engines share the exact heuristic formulas with the single-device
engine via the ``*_from_stats`` variants (stats are psum-reduced partials),
and both build their per-shard relaxation from the shared primitives in
:mod:`repro.core.relax` (windowed candidates, deterministic segment-min +
winner recovery, update application) — the engines only add the collective
merge (``pmin`` / ``all_to_all``).  Tie-breaking and the traversal-metric
definitions match the single-device engine exactly, so ``dist``/``parent``
*and* logical metrics are identical across engines (asserted by
``tests/test_relax_backends.py``).

**Relaxation backends.**  Each engine's per-shard push partial is
pluggable (``backend=``): ``"segment_min"`` (default) computes it with a
masked segment reduction over the shard's flat edge slab; ``"blocked"``
computes it with the sparsity-aware blocked layout — per-shard
:func:`~repro.core.graph.slice_for_shard` slabs (sources = owner block,
destinations = the global padded range, per-bucket tile ranges) relaxed
by ONE partials-megakernel launch per shard per round
(:func:`repro.core.relax.blocked_shard_partials_fused`), which folds the
``n_trav``/``n_relax`` counters into its frontier-compacted tile
schedule so no flat O(E) candidate pass runs.  Both backends produce
bitwise-identical ``dist``/``parent``/logical metrics; only the physical
tile/invocation counters differ (0 under ``segment_min``).

``fused_rounds`` is backend-dependent on the sharded tier: under
``segment_min`` it is the paper's bucket fusion (local-only waves
between exchanges — extra local relaxations, so logical metrics are
exempt from parity); under ``blocked`` it groups up to ``fused_rounds``
*complete* synchronized rounds per stepping-loop body, which keeps
bitwise dist/parent/logical-metric parity by construction.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from types import SimpleNamespace
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import relax, stats, stepping, traversal
from .config import EngineConfig, as_resolved
from .graph import (DEFAULT_BLOCK_V, DEFAULT_TILE_E, BlockedEdges,
                    HostGraph, shard_block_v, slice_for_shard)
from .relax import INF, INT_MAX
from .sssp import (SsspMetrics, _check_goal_bounds, _goal_reached,
                   _zero_metrics, goal_param_array)
from ..obs import profiling
from ..obs.trace import trace_append, trace_init

DIST_BACKENDS = ("segment_min", "blocked")


class _AltCtx(NamedTuple):
    """Per-solve ALT pruning context (closed over by the loop bodies, not
    part of any loop carry — every field is loop-invariant)."""
    lb: jnp.ndarray      # [n_pad] f32 per-vertex lower bound to the target
    seed: jnp.ndarray    # f32 landmark-seeded upper bound on d(s, t)
    infl: jnp.ndarray    # f32 prune-bound inflation (1 + 4 delta)
    tgt: jnp.ndarray     # int32 target vertex


def _make_alt_ctx(alt_d, source, gp, n_pad):
    """Build the :class:`_AltCtx` for one (source, target) p2p solve.

    ``alt_d`` is the replicated :class:`~repro.core.relax.AltData`
    bundle; the bound vector is padded with +inf so block-padding
    vertices (which hold no real edges) index safely."""
    infl = 1.0 + 4.0 * alt_d.delta
    lb_v = relax.alt_lower_bounds(alt_d.D, gp, alt_d.delta, alt_d.sym)
    lb_v = jnp.pad(lb_v, (0, n_pad - lb_v.shape[0]),
                   constant_values=jnp.inf)
    seed = relax.alt_seed_ub(alt_d.D, source, gp, infl, alt_d.sym)
    return _AltCtx(lb=lb_v, seed=seed, infl=infl,
                   tgt=jnp.asarray(gp, jnp.int32))


def _dtrace_record(buf, iters, frontier_size, lb, ub, st_, stepped, m0, m1):
    """Append one per-iteration trace record (inside a shard_map body).

    Every input is replicated across shards by construction — the window
    scalars are replicated state, the counters are psum-reduced, and
    ``frontier_size`` is globally reduced by the caller — so the ring is
    replicated too and exits the shard_map under an out_spec of ``P()``.
    Same column semantics as the single-device ``_trace_record``.
    """
    ivals = {
        "iter": iters,
        "frontier": frontier_size,
        "stepped": stepped.astype(jnp.int32),
        "n_rounds": m1.n_rounds - m0.n_rounds,
        "n_steps": m1.n_steps - m0.n_steps,
        "n_extended": m1.n_extended - m0.n_extended,
        "n_trav": m1.n_trav - m0.n_trav,
        "n_pull_trav": m1.n_pull_trav - m0.n_pull_trav,
        "n_relax": m1.n_relax - m0.n_relax,
        "n_updates": m1.n_updates - m0.n_updates,
        "n_pruned": m1.n_pruned - m0.n_pruned,
    }
    fvals = {
        "lb": lb, "ub": ub, "st": st_,
        "n_tiles_scanned": m1.n_tiles_scanned - m0.n_tiles_scanned,
        "n_tiles_dense": m1.n_tiles_dense - m0.n_tiles_dense,
        "n_invocations": m1.n_invocations - m0.n_invocations,
    }
    return trace_append(buf, ivals, fvals)


class ShardedGraph(NamedTuple):
    """Edge slabs partitioned by source-owner + replicated weight stats.

    Shapes: ``src/dst/w`` are ``[P, E_max]`` (sharded on axis 0); ``deg`` is
    ``[P, B]`` (sharded, the owner's block); scalars replicated.
    """
    src: jnp.ndarray       # [P, E_max] int32 — global source id (owner-local block)
    dst: jnp.ndarray       # [P, E_max] int32 — global destination id
    w: jnp.ndarray         # [P, E_max] float32 (+inf padding)
    deg: jnp.ndarray       # [P, B] int32
    rtow: jnp.ndarray      # [RATIO_NUM] float32 (replicated)
    n_edges2: jnp.ndarray  # scalar int32
    n_true: jnp.ndarray    # scalar int32 — real vertex count (pre-padding)


def shard_graph(g: HostGraph, n_shards: int) -> ShardedGraph:
    """Host-side partitioner: block vertex ownership, edges by src owner."""
    p = n_shards
    block = -(-g.n // p)          # ceil
    n_pad = block * p
    owner = g.src // block
    order = np.argsort(owner, kind="stable")
    src, dst, w = g.src[order], g.dst[order], g.w[order]
    owner = owner[order]
    counts = np.bincount(owner, minlength=p)
    e_max = max(int(counts.max()), 1)
    # pad ragged slabs: padding edges carry w=inf (never in-window)
    s_sl = np.zeros((p, e_max), np.int32)
    d_sl = np.zeros((p, e_max), np.int32)
    w_sl = np.full((p, e_max), np.inf, np.float32)
    offs = np.zeros(p + 1, np.int64)
    np.cumsum(counts, out=offs[1:])
    for q in range(p):
        c = counts[q]
        s_sl[q, :c] = src[offs[q]:offs[q] + c]
        d_sl[q, :c] = dst[offs[q]:offs[q] + c]
        w_sl[q, :c] = w[offs[q]:offs[q] + c]
        s_sl[q, c:] = q * block  # in-block padding source
    deg = np.zeros(n_pad, np.int32)
    deg[:g.n] = g.deg
    return ShardedGraph(
        src=jnp.asarray(s_sl), dst=jnp.asarray(d_sl), w=jnp.asarray(w_sl),
        deg=jnp.asarray(deg.reshape(p, block)),
        rtow=jnp.asarray(g.rtow), n_edges2=jnp.int32(g.m),
        n_true=jnp.int32(g.n))


def graph_specs(axis):
    """PartitionSpecs matching :class:`ShardedGraph` for mesh axis ``axis``."""
    return ShardedGraph(src=P(axis), dst=P(axis), w=P(axis), deg=P(axis),
                        rtow=P(), n_edges2=P(), n_true=P())


class BlockedShards(NamedTuple):
    """Stacked per-shard blocked slabs (leading axis sharded on the mesh).

    Each shard's slice is one :func:`~repro.core.graph.slice_for_shard`
    layout with uniform shapes across shards: ``S`` source blocks of
    ``block_v`` vertices tile the owner block, every slab padded to the
    same ``NT`` tiles.
    """
    src_local: jnp.ndarray       # [P, S, NT*tile_e] int32 block-local src
    dst: jnp.ndarray             # [P, S, NT*tile_e] int32 global dst id
    w: jnp.ndarray               # [P, S, NT*tile_e] f32 (+inf padding)
    tile_dst: jnp.ndarray        # [P, S, NT] int32 dst block per tile
    tile_first: jnp.ndarray      # [P, S, NT] bool forced-first tiles
    bucket_nonempty: jnp.ndarray  # [P, S, NB] bool bucket-has-edges


@dataclasses.dataclass(frozen=True)
class BlockedShardMeta:
    """Static geometry of a :class:`BlockedShards` layout (jit cache key)."""
    block_v: int
    tile_e: int
    n_src_blocks: int
    n_dst_blocks: int
    dense_grid_tiles: int        # global per-round cost of the dense scan
    use_kernel: bool
    interpret: bool


def blocked_specs(axis):
    """PartitionSpecs matching :class:`BlockedShards` for mesh ``axis``."""
    return BlockedShards(*([P(axis)] * len(BlockedShards._fields)))


def shard_blocked(g, n_shards: Optional[int] = None, *,
                  block_v: int = DEFAULT_BLOCK_V,
                  tile_e: int = DEFAULT_TILE_E,
                  use_kernel: Optional[bool] = None,
                  interpret: bool = True
                  ) -> Tuple[BlockedShards, BlockedShardMeta]:
    """Build the stacked per-shard blocked layout for the engines.

    ``g`` is a :class:`~repro.core.graph.HostGraph` (with ``n_shards``)
    or a :class:`ShardedGraph` (shard count taken from its slab axis; the
    flat edge slabs are unpacked host-side).  Host-side, once per graph —
    pass the result to ``sssp_distributed*(..., backend="blocked",
    blocked=...)`` so repeated calls don't re-bucket.

    ``use_kernel`` defaults to True.  Historical note: the pre-megakernel
    ragged-grid bucket kernel's interpreter (a ``lax.while_loop`` of
    dynamic slices) deterministically miscompiled under multi-device
    ``shard_map`` SPMD partitioning on jax 0.4.x (output ranges silently
    dropped, shifting with unrelated program perturbations), so
    interpret-mode shards used to fall back to the jnp reference.  The
    engines now relax through the fixed-grid whole-shard partials
    megakernel (``edge_relax_partials``: one grid step, state in
    carries), which re-tested clean on jax 0.4.37 across v1/v2/v3 ×
    {unfused, fused_rounds=4} × 8 shards — bitwise dist/parent/metric
    parity with the single-device engine — so interpret mode runs the
    real kernel too.  Pass ``use_kernel=False`` to pin the
    bitwise-identical jnp reference (layout, frontier-compaction
    schedule, and tile metrics are shared by both paths).
    """
    if use_kernel is None:
        use_kernel = True
    if isinstance(g, ShardedGraph):
        if n_shards is None:
            n_shards = int(g.src.shape[0])
        w_flat = np.asarray(g.w).reshape(-1)
        real = np.isfinite(w_flat)                  # padding carries w=inf
        n = int(g.n_true)
        g = SimpleNamespace(
            src=np.asarray(g.src).reshape(-1)[real],
            dst=np.asarray(g.dst).reshape(-1)[real],
            w=w_flat[real],
            deg=np.asarray(g.deg).reshape(-1)[:n])
    elif n_shards is None:
        raise ValueError("n_shards is required for a HostGraph")
    kw = dict(block_v=block_v, tile_e=tile_e, use_kernel=use_kernel,
              interpret=interpret)
    # size the uniform tile padding with one cheap counting pass (no slab
    # arrays materialized): block_v divides the owner block, so the
    # global src-block id is just src // bv and one bincount covers
    # every (src block, dst block) bucket at once
    n = int(np.asarray(g.deg).shape[0])
    block = -(-n // n_shards)
    bv = shard_block_v(block, block_v)
    n_dst = (block * n_shards) // bv
    key = (np.asarray(g.src) // bv).astype(np.int64) * n_dst \
        + np.asarray(g.dst) // bv
    counts = np.bincount(key, minlength=(block * n_shards // bv) * n_dst)
    tiles = -(-counts.reshape(-1, n_dst) // tile_e)
    nt = max(int(tiles.sum(axis=1).max()), 1)
    bgs = [slice_for_shard(g, q, n_shards, n_tiles=nt, **kw)
           for q in range(n_shards)]
    stacked = BlockedShards(*(
        jnp.stack([jnp.stack([getattr(slab, f) for slab in bg.slabs])
                   for bg in bgs])
        for f in BlockedEdges._fields))
    meta = BlockedShardMeta(
        block_v=bgs[0].block_v, tile_e=tile_e,
        n_src_blocks=bgs[0].n_blocks, n_dst_blocks=bgs[0].n_dst_blocks,
        dense_grid_tiles=sum(bg.dense_grid_tiles for bg in bgs),
        use_kernel=use_kernel, interpret=interpret)
    return stacked, meta


# ---------------------------------------------------------------------------
# shared distributed statistics (local partial + psum)
# ---------------------------------------------------------------------------

def _dstats_gap(dist_l, deg_l, rtow, n_edges2, x, params, axes, mult=None):
    hist = jax.lax.psum(stats.degree_hist(dist_l, deg_l, x), axes)
    hd = stats.high_d_from_hist(hist)
    sd = jax.lax.psum(stats.sum_d(dist_l, deg_l, x), axes)
    # the psum'd partials are replicated, so an adaptive ``mult`` (itself
    # replicated loop state) keeps the gap replicated across shards
    return (stepping.gap_from_stats(sd, hd, rtow, n_edges2, params, mult),
            sd, hd)


def _dstats_compute_st(dist_l, deg_l, rtow, n_edges2, lb, ub, params, axes,
                       mult=None):
    gap_lb, _, _ = _dstats_gap(dist_l, deg_l, rtow, n_edges2, lb, params,
                               axes, mult)
    gap_ub, sd_ub, _ = _dstats_gap(dist_l, deg_l, rtow, n_edges2, ub, params,
                                   axes, mult)
    grid = traversal.st_grid_points(ub)
    ghist = jax.lax.psum(stats.grid_hist(dist_l, deg_l, grid), axes)
    sd_grid = stats.sum_d_grid_from_hist(ghist)
    st = traversal.compute_st_from_stats(grid, sd_grid, sd_ub, gap_lb,
                                         gap_ub, rtow, n_edges2, ub)
    return st, gap_ub


# ---------------------------------------------------------------------------
# v2: sharded dist + all-to-all reduce-scatter-min
# ---------------------------------------------------------------------------

class _V2State(NamedTuple):
    dist: jnp.ndarray      # [B] local block
    parent: jnp.ndarray    # [B]
    frontier: jnp.ndarray  # [B]
    lb: jnp.ndarray
    ub: jnp.ndarray
    st: jnp.ndarray
    done: jnp.ndarray
    iters: jnp.ndarray
    metrics: SsspMetrics


@lru_cache(maxsize=64)
def _build_engine(mesh, axes, version, block, n_pad, params, max_iters,
                  fused_rounds, capacity, goal="tree", batch=False,
                  bmeta: Optional[BlockedShardMeta] = None,
                  trace_cap: int = 0, policy: str = "static",
                  alt: bool = False):
    """Build + jit one distributed engine (cached so repeated calls with
    the same mesh/shape/config reuse the compiled executable).

    ``goal`` is static (part of the compiled program, like the
    single-device engine); ``batch`` switches the body to the multi-source
    entry point (``lax.map`` over a ``[S]`` sources axis).  ``bmeta``
    selects the blocked relaxation backend: the engine then takes a
    :class:`BlockedShards` layout as its second argument and computes the
    push partials with the ragged-grid kernel instead of ``segment_min``.
    ``trace_cap > 0`` adds a replicated per-round trace ring as a fourth
    output (part of this cache key: 0 compiles the exact untraced
    program).  ``alt`` appends a replicated
    :class:`~repro.core.relax.AltData` operand (p2p goal-directed
    pruning; part of the cache key, so non-ALT solves compile the exact
    pre-ALT program).
    """
    in_specs = (graph_specs(axes), P(), P())
    if bmeta is not None:
        # blocked engines also take the layout and a per-shard owner-block
        # offset.  The offset rides in as *data* (not lax.axis_index): an
        # axis_index-derived value flowing into consumers of the
        # interpret-mode Pallas outputs inside the stepping while_loop
        # makes the XLA SPMD partitioner reject the module (PartitionId
        # in a nested while, jax 0.4.x) — data sidesteps it entirely.
        in_specs = (graph_specs(axes), blocked_specs(axes), P(axes), P(),
                    P())
    if alt:
        # the landmark matrix is replicated across the mesh (the serving
        # registry places it with a replicated NamedSharding up front)
        in_specs = in_specs + (relax.AltData(D=P(), delta=P(), sym=P()),)
    out_specs = (P(axes), P(axes), P())

    axis_sizes = tuple(mesh.shape[a] for a in
                       ((axes,) if isinstance(axes, str) else axes))
    if version == "v1":
        body = _v1_body(n_pad, block, axes, params, max_iters, goal, batch,
                        bmeta=bmeta, axis_sizes=axis_sizes,
                        trace_cap=trace_cap, policy=policy, alt=alt)
        out_specs = (P(), P(), P())
    elif version == "v2":
        body = _v2_body(n_pad, block, axes, params, max_iters, fused_rounds,
                        axis_sizes, goal=goal, batch=batch, bmeta=bmeta,
                        trace_cap=trace_cap, policy=policy, alt=alt)
    elif version == "v3":
        cap = capacity or max(block // 16, 8)
        body = _v2_body(n_pad, block, axes, params, max_iters, fused_rounds,
                        axis_sizes, goal=goal, batch=batch,
                        compact_capacity=cap, bmeta=bmeta,
                        trace_cap=trace_cap, policy=policy, alt=alt)
    else:
        raise ValueError(version)
    if version in ("v2", "v3") and batch:
        # per-shard [S, B] slabs concatenate into a global [S, n_pad]
        out_specs = (P(None, axes), P(None, axes), P())
    if trace_cap > 0:
        # the trace ring is computed from replicated values only
        out_specs = out_specs + (P(),)

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return jax.jit(fn)


def _resolve_backend(backend: str) -> str:
    if backend == "blocked_pallas":      # single-device layout's name
        backend = "blocked"
    if backend not in DIST_BACKENDS:
        raise ValueError(f"unknown distributed relax backend {backend!r}; "
                         f"expected one of {DIST_BACKENDS}")
    return backend


def _resolve_blocked(sg: ShardedGraph, backend: str, blocked, build_opts):
    """Normalize the (backend, blocked layout) pair for the entry points."""
    if _resolve_backend(backend) == "segment_min":
        if blocked is not None:
            raise ValueError("blocked layout passed with "
                             "backend='segment_min'")
        return None, None
    if blocked is None:
        # convenience one-off build; callers that relax repeatedly should
        # shard_blocked() once and pass the result
        blocked = shard_blocked(sg, **build_opts)
    arrays, bmeta = blocked
    if arrays.src_local.shape[0] != sg.src.shape[0]:
        raise ValueError(
            f"blocked layout has {arrays.src_local.shape[0]} shards, "
            f"graph has {sg.src.shape[0]}")
    return arrays, bmeta


def _dist_engine_args(sg: ShardedGraph, config, version, max_iters,
                      fused_rounds, alpha, beta, capacity, backend,
                      block_v, tile_e, policy=None):
    """Resolve the distributed engine knobs from either an
    :class:`~repro.core.config.EngineConfig` or the loose kwargs — never
    both (:meth:`EngineConfig.from_loose` is the shared gate, so loose
    kwargs go through exactly the config validation).  Returns
    ``(version, max_iters, fused_rounds, params_alpha, params_beta,
    capacity, backend, trace_cap, policy, blocked_build_opts)``."""
    config = EngineConfig.from_loose(
        config, "engine", defaults={"tier": "sharded"},
        shard_version=version, max_iters=max_iters,
        fused_rounds=fused_rounds, alpha=alpha, beta=beta,
        compact_capacity=capacity, shard_backend=backend,
        block_v=block_v, tile_e=tile_e, policy=policy)
    r = as_resolved(config, n=int(sg.n_true), m=int(sg.n_edges2),
                    n_devices=int(sg.src.shape[0])).require("sharded")
    return (r.shard_version, r.max_iters, r.fused_rounds, r.alpha,
            r.beta, r.compact_capacity, r.shard_backend, r.trace_cap,
            r.policy, r.blocked_opts())


def sssp_distributed(sg: ShardedGraph, source: int, mesh, axes=("graph",), *,
                     version=None, max_iters=None,
                     fused_rounds=None, alpha=None,
                     beta=None, capacity=None,
                     goal: str = "tree", goal_param=None,
                     backend=None, blocked=None,
                     block_v=None, tile_e=None, policy=None, config=None,
                     landmarks=None):
    """Run distributed EIC SSSP on ``mesh`` (axes flattened over ``axes``).

    versions: v1 replicated/pmin, v2 sharded/all_to_all dense exchange,
    v3 frontier-compacted exchange (top-C candidates per destination block;
    falls back to the dense exchange on bucket overflow — exact always).

    ``goal``/``goal_param`` select the same early-exit query variants as
    the single-device engine (:data:`repro.core.sssp.GOALS`): the settled
    test is evaluated distributively (owner-local settled check + pmax for
    p2p, psum'd settled count for knear) so a sharded p2p/bounded/knear
    query stops stepping as early as the single-device one.

    ``backend`` selects the per-shard push-partial implementation (see
    :data:`DIST_BACKENDS`); with ``"blocked"``, pass ``blocked=`` a
    prebuilt :func:`shard_blocked` layout to amortize bucketing across
    calls (``block_v``/``tile_e`` size the one-off build otherwise).
    Results are bitwise-identical across backends.

    ``config`` accepts an :class:`~repro.core.config.EngineConfig` (or a
    resolved one, tier ``"sharded"``) in place of every loose engine
    kwarg above — the :class:`repro.api.Solver` facade's path.

    ``landmarks`` (a :class:`~repro.core.landmarks.LandmarkSet` or raw
    :class:`~repro.core.relax.AltData`) enables exact ALT goal-directed
    pruning for p2p goals — the facade/registry build and cache the set
    per graph and pass it here.
    """
    (version, max_iters, fused_rounds, alpha, beta, capacity, backend,
     trace_cap, policy, build_opts) = _dist_engine_args(
        sg, config, version, max_iters, fused_rounds, alpha, beta,
        capacity, backend, block_v, tile_e, policy)
    params = stepping.SteppingParams(alpha=alpha, beta=beta)
    p, _ = sg.src.shape
    block = sg.deg.shape[1]
    gp = goal_param_array(goal, goal_param)
    _check_goal_bounds(goal, gp, int(sg.n_true))
    axes_key = axes if isinstance(axes, str) else tuple(axes)
    arrays, bmeta = _resolve_blocked(sg, backend, blocked, build_opts)
    alt_data = None
    if goal == "p2p" and landmarks is not None:
        alt_data = getattr(landmarks, "alt_data", landmarks)
    fn = _build_engine(mesh, axes_key, version, block, p * block, params,
                       max_iters, fused_rounds, capacity, goal, False,
                       bmeta, trace_cap, policy, alt_data is not None)
    alt_op = () if alt_data is None else (alt_data,)
    with profiling.annotate(f"repro:sssp_dist_dispatch:{version}"):
        if arrays is not None:
            bases = jnp.arange(p, dtype=jnp.int32) * block
            return fn(sg, arrays, bases, jnp.int32(source), gp, *alt_op)
        return fn(sg, jnp.int32(source), gp, *alt_op)


def sssp_distributed_batch(sg: ShardedGraph, sources, mesh, axes=("graph",),
                           *, version=None,
                           max_iters=None, fused_rounds=None,
                           alpha=None, beta=None,
                           capacity=None, goal: str = "tree",
                           goal_params=None, backend=None,
                           blocked=None, block_v=None,
                           tile_e=None, policy=None, config=None,
                           landmarks=None):
    """Batched multi-source distributed SSSP — the sharded serving tier's
    entry point.

    Sources are scanned *sequentially* inside one compiled ``shard_map``
    program (``lax.map``), not vmapped: the sharded tier exists for graphs
    whose per-device state is the memory budget, so slots must not
    multiply the O(N/P) dist/parent footprint.  One compile still serves
    every batch of the same width, and per-batch dispatch overhead is paid
    once per batch instead of once per source.  All slots share the static
    ``goal`` kind with per-slot ``goal_params``; returns ``(dist, parent,
    metrics)`` with a leading ``[S]`` axis (dist/parent ``[S, n_pad]``).
    ``backend``/``blocked``/``config`` select the per-shard relaxation
    exactly as in :func:`sssp_distributed`.
    """
    (version, max_iters, fused_rounds, alpha, beta, capacity, backend,
     trace_cap, policy, build_opts) = _dist_engine_args(
        sg, config, version, max_iters, fused_rounds, alpha, beta,
        capacity, backend, block_v, tile_e, policy)
    params = stepping.SteppingParams(alpha=alpha, beta=beta)
    p, _ = sg.src.shape
    block = sg.deg.shape[1]
    sources = jnp.asarray(sources, jnp.int32)
    if goal == "tree" and goal_params is None:
        goal_params = [0] * sources.shape[0]
    gp = goal_param_array(goal, goal_params)
    if gp.shape != sources.shape:
        raise ValueError(f"goal_params shape {gp.shape} != sources shape "
                         f"{sources.shape}")
    _check_goal_bounds(goal, gp, int(sg.n_true))
    axes_key = axes if isinstance(axes, str) else tuple(axes)
    arrays, bmeta = _resolve_blocked(sg, backend, blocked, build_opts)
    alt_data = None
    if goal == "p2p" and landmarks is not None:
        alt_data = getattr(landmarks, "alt_data", landmarks)
    fn = _build_engine(mesh, axes_key, version, block, p * block, params,
                       max_iters, fused_rounds, capacity, goal, True,
                       bmeta, trace_cap, policy, alt_data is not None)
    alt_op = () if alt_data is None else (alt_data,)
    with profiling.annotate(f"repro:sssp_dist_batch_dispatch:{version}"):
        if arrays is not None:
            bases = jnp.arange(p, dtype=jnp.int32) * block
            return fn(sg, arrays, bases, sources, gp, *alt_op)
        return fn(sg, sources, gp, *alt_op)


# --- v1 -------------------------------------------------------------------

def _v1_body(n_pad, block, axes, params, max_iters, goal="tree", batch=False,
             bmeta=None, axis_sizes=(), trace_cap=0, policy="static",
             alt=False):
    axis_names = (axes,) if isinstance(axes, str) else tuple(axes)
    adaptive = policy == "adaptive"

    def run(sg: ShardedGraph, *args):
        if alt:
            args, alt_d = args[:-1], args[-1]
        else:
            alt_d = None
        if bmeta is not None:
            bl, base_arr, source, goal_param = args
            bl = jax.tree.map(lambda x: x[0], bl)    # drop the shard axis
            base = base_arr[0]       # owner-block offset as data (see
            me = base // block       # _build_engine on why not axis_index)
        else:
            source, goal_param = args
            bl = None
            me = jnp.int32(0)
            for name, size in zip(axis_names, axis_sizes):
                me = me * size + jax.lax.axis_index(name)
            base = me * block
        src = sg.src.reshape(-1)
        dst = sg.dst.reshape(-1)
        w = sg.w.reshape(-1)
        deg_l = sg.deg.reshape(-1)               # local block [B]
        deg = jax.lax.all_gather(deg_l, axes, tiled=True)  # replicated [N]
        rtow, n_edges2 = sg.rtow, sg.n_edges2
        max_w = rtow[-1]
        high_d0 = stats.high_d(jnp.zeros((n_pad,), jnp.float32), deg, 0.0)

        def relax_round(dist, parent, frontier, lb, ub, metrics, ac=None,
                        pb=None):
            paths = relax.leaf_pruned(frontier, dist, deg)
            n_prn = jnp.int32(0)
            if bmeta is None:
                cand, in_window, active = relax.edge_candidates(
                    dist[src], paths[src], parent[src], dst, w, lb, ub)
                if ac is not None:
                    active, pruned = relax.alt_prune(cand, active,
                                                     ac.lb[dst], pb)
                    cand = jnp.where(active, cand, INF)
                    n_prn = jax.lax.psum(
                        jnp.sum(pruned.astype(jnp.int32)), axes)
                best = jax.lax.pmin(
                    relax.segment_partial_min(cand, dst, n_pad), axes)
                winner = jax.lax.pmin(
                    relax.winner_partial(cand, active, src, dst, best,
                                         n_pad), axes)
                n_tiles = jnp.float32(0)
                touched = jax.lax.psum(
                    jnp.sum(in_window.astype(jnp.int32)), axes)
                relaxed = jax.lax.psum(
                    jnp.sum(active.astype(jnp.int32)), axes)
                n_inv = jnp.float32(0)
            else:
                # dist/frontier are replicated; the partials megakernel
                # reads only the shard's owner block (its source range)
                # and folds the n_trav/n_relax sums into its scheduled
                # tile pass — one launch per shard, no flat O(E)
                # candidate pass
                dist_src = jax.lax.dynamic_slice(dist, (base,), (block,))
                paths_src = jax.lax.dynamic_slice(paths, (base,), (block,))
                parent_src = jax.lax.dynamic_slice(parent, (base,),
                                                   (block,))
                best_l, win_l, nt, trav, rlx, prn = \
                    relax.blocked_shard_partials_fused(
                        bl.src_local, bl.dst, bl.w, bl.tile_dst,
                        bl.tile_first, dist_src, paths_src, parent_src,
                        base, lb, ub, block_v=bmeta.block_v,
                        n_dst_blocks=bmeta.n_dst_blocks,
                        tile_e=bmeta.tile_e, use_kernel=bmeta.use_kernel,
                        interpret=bmeta.interpret,
                        alt_lb=None if ac is None else ac.lb,
                        prune_bound=pb)
                best = jax.lax.pmin(best_l, axes)
                winner = jax.lax.pmin(
                    jnp.where(best_l <= best, win_l, INT_MAX), axes)
                n_tiles = jax.lax.psum(nt.astype(jnp.float32), axes)
                touched = jax.lax.psum(trav, axes)
                relaxed = jax.lax.psum(rlx, axes)
                n_prn = jax.lax.psum(prn, axes)
                n_inv = jax.lax.psum(jnp.float32(1), axes)
            new_dist, new_parent, improved = relax.apply_updates(
                dist, parent, best, winner)
            metrics = metrics._replace(
                n_rounds=metrics.n_rounds + jnp.where(jnp.any(frontier), 1, 0),
                n_extended=metrics.n_extended +
                jnp.sum((improved & (deg > 1)).astype(jnp.int32)),
                n_trav=metrics.n_trav + touched,
                n_relax=metrics.n_relax + relaxed,
                n_updates=metrics.n_updates +
                jnp.sum(improved.astype(jnp.int32)),
                n_pruned=metrics.n_pruned + n_prn,
                n_tiles_scanned=metrics.n_tiles_scanned + n_tiles,
                n_tiles_dense=metrics.n_tiles_dense + jnp.float32(
                    0 if bmeta is None else bmeta.dense_grid_tiles),
                n_invocations=metrics.n_invocations + n_inv,
            )
            return new_dist, new_parent, improved, metrics

        def pull_round(dist, parent, st, lb, ub, metrics, ac=None, pb=None):
            # mirrored push from the settled band (undirected store); the
            # requester receiving the update is ``dst`` here, so ALT cuts
            # requests with cand + lb[dst] > bound (the mirrored twin of
            # the single-device requester-side alt_lb[src] cut — the
            # directed edge sets pair up one-to-one, so counts match)
            dv = dist[src]
            mask = (dv >= st) & (dv < lb) & (dv + w < ub)
            cand = jnp.where(mask, dv + w, INF)
            n_prn = jnp.int32(0)
            if ac is not None:
                mask, pruned = relax.alt_prune(cand, mask, ac.lb[dst], pb)
                cand = jnp.where(mask, cand, INF)
                n_prn = jax.lax.psum(
                    jnp.sum(pruned.astype(jnp.int32)), axes)
            best = jax.lax.pmin(
                relax.segment_partial_min(cand, dst, n_pad), axes)
            winner = jax.lax.pmin(
                relax.winner_partial(cand, mask, src, dst, best, n_pad),
                axes)
            new_dist, new_parent, improved = relax.apply_updates(
                dist, parent, best, winner, gate=dist > lb)
            scans = jax.lax.psum(jnp.sum(
                ((dist[src] > lb) & (w < ub - st)).astype(jnp.int32)), axes)
            requests = jax.lax.psum(jnp.sum(mask.astype(jnp.int32)), axes)
            metrics = metrics._replace(
                n_pull_trav=metrics.n_pull_trav + scans,
                n_extended=metrics.n_extended +
                jnp.sum((improved & (deg > 1)).astype(jnp.int32)),
                n_relax=metrics.n_relax + requests,
                n_updates=metrics.n_updates +
                jnp.sum(improved.astype(jnp.int32)),
                n_pruned=metrics.n_pruned + n_prn,
                n_rounds=metrics.n_rounds + 1,
            )
            return new_dist, new_parent, metrics

        def transition(dist, parent, lb, ub, metrics, gp, ps=None, ac=None):
            pend = dist[src] + w
            pend = jnp.where(pend >= ub, pend, INF)
            if ac is not None:
                # a pending candidate the ALT bound would cut can never
                # improve the target, so skipping it in fast-forward/
                # termination is exact for the p2p contract
                bound_eff = jnp.minimum(ac.seed, dist[ac.tgt] * ac.infl)
                pend = jnp.where(pend + ac.lb[dst] > bound_eff, INF, pend)
            min_pending = jax.lax.pmin(jnp.min(pend), axes)
            done = ~jnp.isfinite(min_pending)
            if ps is not None:
                # observe -> adapt: the counters are psum'd/replicated, so
                # the policy state stays replicated too
                ps = stepping.adaptive_update(ps, metrics.n_rounds,
                                              metrics.n_relax,
                                              metrics.n_updates)
                tparams = stepping.effective_params(ps)
                mult = ps.mult
            else:
                tparams, mult = params, None
            st_next = traversal.compute_st(dist, deg, rtow, n_edges2, lb, ub,
                                           tparams, mult=mult)
            lb2 = ub
            gap2 = stepping.gap(dist, deg, rtow, n_edges2, lb2, tparams, mult)
            ub2 = lb2 + gap2
            ffwd = (min_pending >= ub2) & ~done
            lb2 = jnp.where(ffwd, min_pending, lb2)
            gap3 = stepping.gap(dist, deg, rtow, n_edges2, lb2, tparams, mult)
            ub2 = jnp.where(ffwd, lb2 + gap3, ub2)
            st_next = jnp.minimum(st_next, lb2)

            def with_pull(args):
                return pull_round(*args[:2], st_next, lb2, ub2, args[2],
                                  ac, None if ac is None else bound_eff)

            dist, parent, metrics = jax.lax.cond(
                st_next < lb2, with_pull, lambda a: a,
                (dist, parent, metrics))
            # dist is replicated here, so the single-device goal test applies
            done = done | _goal_reached(goal, gp, dist, lb2)
            frontier = relax.window_frontier(dist, st_next, lb2, ub2,
                                             max_w) & ~done
            metrics = metrics._replace(
                n_steps=metrics.n_steps + jnp.where(done, 0, 1))
            out = (dist, parent, frontier, lb2, ub2, st_next, done, metrics)
            return out if ps is None else out + (ps,)

        def cond(s):
            # index access: the carry is a 9-tuple (static policy) or a
            # 10-tuple with the trailing PolicyState (adaptive)
            return (~s[6]) & (s[7] < max_iters)

        def run_one(source, gp):
            dist0 = jnp.full((n_pad,), INF, jnp.float32).at[source].set(0.0)
            parent0 = jnp.full((n_pad,), -1,
                               jnp.int32).at[source].set(source)
            frontier0 = jnp.zeros((n_pad,), bool).at[source].set(True)
            metrics0 = _zero_metrics()._replace(n_extended=jnp.int32(1))
            ac = None if alt_d is None else _make_alt_ctx(alt_d, source,
                                                          gp, n_pad)

            def body(s):
                (dist, parent, frontier, lb, ub, st_, done, iters,
                 metrics) = s[:9]
                # per-round prune bound from dist at round start (the
                # same recompute the single-device fused kernel does)
                pb = None if ac is None else jnp.minimum(
                    ac.seed, dist[ac.tgt] * ac.infl)
                dist, parent, frontier, metrics = relax_round(
                    dist, parent, frontier, lb, ub, metrics, ac, pb)
                # first-step ub bootstrap
                def tighten(ub):
                    mask = (deg.astype(jnp.float32) >= high_d0) & (dist > 0)
                    return jnp.minimum(ub,
                                       jnp.min(jnp.where(mask, dist, INF)))
                ub = jax.lax.cond(lb <= 0.0, tighten, lambda u: u, ub)

                if adaptive:
                    def trans(args):
                        return transition(*args[:5], gp, ps=args[5], ac=ac)

                    def keep(args):
                        dist, parent, lb, ub, metrics, ps = args
                        return (dist, parent, frontier, lb, ub, st_, done,
                                metrics, ps)

                    (dist, parent, frontier, lb, ub, st2, done, metrics,
                     ps) = jax.lax.cond(jnp.any(frontier), keep, trans,
                                        (dist, parent, lb, ub, metrics,
                                         s[9]))
                    return (dist, parent, frontier, lb, ub, st2, done,
                            iters + 1, metrics, ps)

                def trans(args):
                    return transition(*args, gp, ac=ac)

                def keep(args):
                    dist, parent, lb, ub, metrics = args
                    return dist, parent, frontier, lb, ub, st_, done, metrics

                (dist, parent, frontier, lb, ub, st2, done, metrics) = \
                    jax.lax.cond(jnp.any(frontier), keep, trans,
                                 (dist, parent, lb, ub, metrics))
                return (dist, parent, frontier, lb, ub, st2, done,
                        iters + 1, metrics)

            init = (dist0, parent0, frontier0, jnp.float32(0.0), INF,
                    jnp.float32(0.0), jnp.bool_(False), jnp.int32(0),
                    metrics0)
            if adaptive:
                init = init + (stepping.policy_init(params),)
            if trace_cap <= 0:
                out = jax.lax.while_loop(cond, body, init)
                return out[0], out[1], out[8]

            def traced_body(carry):
                s, buf = carry
                s1 = body(s)
                m0, m1 = s[8], s1[8]
                stepped = (m1.n_steps > m0.n_steps) | (s1[6] & ~s[6])
                # dist/frontier are replicated in v1: a local sum is global
                fsz = jnp.sum(s[2].astype(jnp.int32))
                buf = _dtrace_record(buf, s[7], fsz, s[3], s[4], s[5],
                                     stepped, m0, m1)
                return s1, buf

            out, buf = jax.lax.while_loop(
                lambda c: cond(c[0]), traced_body,
                (init, trace_init(trace_cap)))
            return out[0], out[1], out[8], buf

        if batch:
            return jax.lax.map(lambda a: run_one(*a), (source, goal_param))
        return run_one(source, goal_param)

    return run


# ---------------------------------------------------------------------------
# incremental repair (repro.delta): lean Bellman loops over the shards
# ---------------------------------------------------------------------------

@lru_cache(maxsize=64)
def _build_repair_engine(mesh, axes, version, block, n_pad, max_iters,
                         capacity):
    """Build + jit one distributed *repair* engine.

    The repair loop is the stepping engines' relaxation round with the
    window pinned to ``[0, +inf)`` and no step transitions: each round
    relaxes the current frontier through the shard's segment-min partial
    and the version's collective merge (v1 replicated ``pmin``, v2 dense
    ``all_to_all`` exchange, v3 frontier-compacted exchange), and the
    next frontier is exactly the vertices the round improved.  Fed a
    valid upper-bound state (see :func:`repair_distributed`), the
    fixpoint dist/parent are bitwise-identical to a from-scratch solve —
    the same primitives, merge rule, and tie-breaks as the full engines.
    """
    axis_names = (axes,) if isinstance(axes, str) else tuple(axes)
    axis_sizes = tuple(mesh.shape[a] for a in axis_names)
    p = n_pad // block
    in_specs = (graph_specs(axes), P(), P(), P())
    out_specs = (P(), P(), P()) if version == "v1" \
        else (P(axes), P(axes), P())

    def run_v1(sg: ShardedGraph, dist0, parent0, frontier0):
        src = sg.src.reshape(-1)
        dst = sg.dst.reshape(-1)
        w = sg.w.reshape(-1)
        deg = jax.lax.all_gather(sg.deg.reshape(-1), axes, tiled=True)

        def body(c):
            dist, parent, frontier, metrics, iters, _ = c
            paths = relax.leaf_pruned(frontier, dist, deg)
            cand, in_window, active = relax.edge_candidates(
                dist[src], paths[src], parent[src], dst, w,
                jnp.float32(0.0), INF)
            best = jax.lax.pmin(
                relax.segment_partial_min(cand, dst, n_pad), axes)
            winner = jax.lax.pmin(
                relax.winner_partial(cand, active, src, dst, best, n_pad),
                axes)
            dist2, parent2, improved = relax.apply_updates(dist, parent,
                                                           best, winner)
            metrics = metrics._replace(
                n_rounds=metrics.n_rounds
                + jnp.where(jnp.any(frontier), 1, 0),
                n_trav=metrics.n_trav + jax.lax.psum(
                    jnp.sum(in_window.astype(jnp.int32)), axes),
                n_relax=metrics.n_relax + jax.lax.psum(
                    jnp.sum(active.astype(jnp.int32)), axes),
                n_updates=metrics.n_updates
                + jnp.sum(improved.astype(jnp.int32)),
                n_extended=metrics.n_extended
                + jnp.sum((improved & (deg > 1)).astype(jnp.int32)))
            # dist/frontier are replicated in v1: a local any is global
            go = jnp.any(improved).astype(jnp.int32)
            return dist2, parent2, improved, metrics, iters + 1, go

        def cond(c):
            # the go flag is carried: collectives may not appear in a
            # while_loop cond (and jnp.any is local-only elsewhere)
            return (c[5] > 0) & (c[4] < max_iters)

        init = (dist0, parent0, frontier0, _zero_metrics(), jnp.int32(0),
                jnp.any(frontier0).astype(jnp.int32))
        out = jax.lax.while_loop(cond, body, init)
        return out[0], out[1], out[3]

    def run_v2(sg: ShardedGraph, dist0, parent0, frontier0):
        me = jnp.int32(0)
        for name, size in zip(axis_names, axis_sizes):
            me = me * size + jax.lax.axis_index(name)
        base = me * block
        src = sg.src.reshape(-1)
        dst = sg.dst.reshape(-1)
        w = sg.w.reshape(-1)
        deg_l = sg.deg.reshape(-1)
        src_l = src - base
        dist_l = jax.lax.dynamic_slice(dist0, (base,), (block,))
        parent_l = jax.lax.dynamic_slice(parent0, (base,), (block,))
        frontier_l = jax.lax.dynamic_slice(frontier0, (base,), (block,))

        def dense_exchange(best_g, win_g):
            recv_v = jax.lax.all_to_all(best_g.reshape(p, block), axes,
                                        split_axis=0, concat_axis=0)
            recv_w = jax.lax.all_to_all(win_g.reshape(p, block), axes,
                                        split_axis=0, concat_axis=0)
            return relax.combine_block_partials(recv_v, recv_w)

        def compact_exchange(best_g, win_g):
            cap = capacity
            rows_v = best_g.reshape(p, block)
            rows_w = win_g.reshape(p, block)
            n_finite = jnp.sum(jnp.isfinite(rows_v), axis=1)
            overflow = jax.lax.pmax(
                jnp.any(n_finite > cap).astype(jnp.int32), axes) > 0

            def compact(_):
                neg, idx = jax.lax.top_k(-rows_v, cap)
                vals = -neg
                srcs = jnp.take_along_axis(rows_w, idx, axis=1)
                rv = jax.lax.all_to_all(vals, axes, split_axis=0,
                                        concat_axis=0)
                ri = jax.lax.all_to_all(idx, axes, split_axis=0,
                                        concat_axis=0)
                rs = jax.lax.all_to_all(srcs, axes, split_axis=0,
                                        concat_axis=0)
                return relax.segment_min_with_winner(
                    rv.reshape(-1), jnp.isfinite(rv.reshape(-1)),
                    rs.reshape(-1), ri.reshape(-1), block)

            return jax.lax.cond(overflow,
                                lambda _: dense_exchange(best_g, win_g),
                                compact, None)

        merge = compact_exchange if capacity else dense_exchange

        def body(c):
            dist_l, parent_l, frontier_l, metrics, iters, _ = c
            paths = relax.leaf_pruned(frontier_l, dist_l, deg_l)
            cand, in_window, active = relax.edge_candidates(
                dist_l[src_l], paths[src_l], parent_l[src_l], dst, w,
                jnp.float32(0.0), INF)
            best_g, win_g = relax.segment_min_with_winner(cand, active,
                                                          src, dst, n_pad)
            best_l, winner_l = merge(best_g, win_g)
            dist2, parent2, improved = relax.apply_updates(
                dist_l, parent_l, best_l, winner_l)
            any_front = jax.lax.pmax(
                jnp.any(frontier_l).astype(jnp.int32), axes)
            go = jax.lax.pmax(jnp.any(improved).astype(jnp.int32), axes)
            metrics = metrics._replace(
                n_rounds=metrics.n_rounds + any_front,
                n_trav=metrics.n_trav + jax.lax.psum(
                    jnp.sum(in_window.astype(jnp.int32)), axes),
                n_relax=metrics.n_relax + jax.lax.psum(
                    jnp.sum(active.astype(jnp.int32)), axes),
                n_updates=metrics.n_updates + jax.lax.psum(
                    jnp.sum(improved.astype(jnp.int32)), axes),
                n_extended=metrics.n_extended + jax.lax.psum(
                    jnp.sum((improved & (deg_l > 1)).astype(jnp.int32)),
                    axes))
            return dist2, parent2, improved, metrics, iters + 1, go

        def cond(c):
            return (c[5] > 0) & (c[4] < max_iters)

        go0 = jax.lax.pmax(jnp.any(frontier_l).astype(jnp.int32), axes)
        init = (dist_l, parent_l, frontier_l, _zero_metrics(),
                jnp.int32(0), go0)
        out = jax.lax.while_loop(cond, body, init)
        return out[0], out[1], out[3]

    body = run_v1 if version == "v1" else run_v2
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def repair_distributed(sg: ShardedGraph, dist, parent, frontier, mesh,
                       axes=("graph",), *, version="v2",
                       max_iters: int = 1_000_000, capacity: int = 0):
    """Incremental repair of a distributed SSSP state after an edge delta.

    ``dist``/``parent``/``frontier`` are the host-invalidated tentative
    state over the true (or padded) vertex range, as produced by
    :func:`repro.delta.repair_state` from an
    :class:`~repro.delta.AppliedDelta`: invalidated subtree entries reset
    to ``(+inf, -1)`` and the frontier seeded from vertices incident to
    the changed edges.  The engine re-relaxes to fixpoint with the
    version's collective merge (see :func:`_build_repair_engine`); the
    result is bitwise-identical to a from-scratch
    :func:`sssp_distributed` solve on the patched graph, at a cost
    proportional to the delta's blast radius.

    Returns ``(dist, parent, metrics)`` over the padded ``n_pad`` range
    (slice ``[:n]`` for the true vertices); metrics count only the
    repair's own relaxation work.
    """
    if version not in ("v1", "v2", "v3"):
        raise ValueError(f"unknown version {version!r}; expected "
                         "v1/v2/v3")
    p, _ = sg.src.shape
    block = int(sg.deg.shape[1])
    n_pad = int(p) * block
    dist = jnp.asarray(dist, jnp.float32)
    pad = n_pad - dist.shape[0]
    dist = jnp.pad(dist, (0, pad), constant_values=jnp.inf)
    parent = jnp.pad(jnp.asarray(parent, jnp.int32), (0, pad),
                     constant_values=-1)
    frontier = jnp.pad(jnp.asarray(frontier, bool), (0, pad))
    axes_key = axes if isinstance(axes, str) else tuple(axes)
    cap = (capacity or max(block // 16, 8)) if version == "v3" else 0
    fn = _build_repair_engine(mesh, axes_key, version, block, n_pad,
                              max_iters, cap)
    with profiling.annotate(f"repro:repair_dist_dispatch:{version}"):
        return fn(sg, dist, parent, frontier)


# --- v2 -------------------------------------------------------------------

def _v2_body(n_pad, block, axes, params, max_iters, fused_rounds,
             axis_sizes, goal="tree", batch=False, compact_capacity: int = 0,
             bmeta=None, trace_cap=0, policy="static", alt=False):
    p = n_pad // block
    axis_names = (axes,) if isinstance(axes, str) else tuple(axes)
    adaptive = policy == "adaptive"

    def run(sg: ShardedGraph, *args):
        if alt:
            args, alt_d = args[:-1], args[-1]
        else:
            alt_d = None
        if bmeta is not None:
            bl, base_arr, source, goal_param = args
            bl = jax.tree.map(lambda x: x[0], bl)    # drop the shard axis
            base = base_arr[0]       # owner-block offset as data (see
            me = base // block       # _build_engine on why not axis_index)
        else:
            source, goal_param = args
            bl = None
            me = jnp.int32(0)
            for name, size in zip(axis_names, axis_sizes):
                me = me * size + jax.lax.axis_index(name)
            base = me * block
        src = sg.src.reshape(-1)          # global ids, sources owned locally
        dst = sg.dst.reshape(-1)
        w = sg.w.reshape(-1)
        deg_l = sg.deg.reshape(-1)        # [B] local block degrees
        rtow, n_edges2 = sg.rtow, sg.n_edges2
        max_w = rtow[-1]
        src_l = src - base                # local source index

        own_src = jnp.zeros((block,), jnp.float32)
        high_d0_hist = jax.lax.psum(
            stats.degree_hist(own_src, deg_l, 0.0), axes)
        high_d0 = stats.high_d_from_hist(high_d0_hist)

        def goal_reached(dist_l, lb, gp):
            """Distributed twin of sssp._goal_reached: ``dist`` lives
            block-sharded here, so the settled test is owner-local with a
            collective merge (pmax for the p2p hit, psum for the knear
            settled count).  Matches the single-device decision exactly —
            same lb, same settled invariant — so early exit keeps bitwise
            dist/parent parity."""
            if goal == "tree":
                return jnp.bool_(False)
            if goal == "p2p":
                own = (gp // block) == me
                loc = jnp.clip(gp - base, 0, block - 1)
                hit = own & relax.settled_mask(dist_l, lb)[loc]
                return jax.lax.pmax(hit.astype(jnp.int32), axes) > 0
            if goal == "bounded":
                return lb > gp
            if goal == "knear":
                n_settled = jax.lax.psum(jnp.sum(
                    relax.settled_mask(dist_l, lb).astype(jnp.int32)), axes)
                return n_settled >= gp + 1
            raise ValueError(f"unknown goal {goal!r}")

        def alt_bound(dist_l, ac):
            """The replicated per-round ALT prune bound: ``dist[target]``
            lives on its owner block, so one pmin broadcasts it (same
            own/loc pattern as the p2p goal test)."""
            own = (ac.tgt // block) == me
            loc = jnp.clip(ac.tgt - base, 0, block - 1)
            td = jax.lax.pmin(jnp.where(own, dist_l[loc], INF), axes)
            return jnp.minimum(ac.seed, td * ac.infl)

        def dense_exchange(best_g, win_g):
            """all_to_all reduce-scatter-min of per-block candidate partials."""
            recv_v = jax.lax.all_to_all(best_g.reshape(p, block), axes,
                                        split_axis=0, concat_axis=0)
            recv_w = jax.lax.all_to_all(win_g.reshape(p, block), axes,
                                        split_axis=0, concat_axis=0)
            return relax.combine_block_partials(recv_v, recv_w)

        def compact_exchange(best_g, win_g):
            """v3: exchange only the C best candidates per destination
            block — comm ∝ frontier cut, not N.  Falls back to the dense
            exchange when any block overflows C finite candidates (exact)."""
            cap = compact_capacity
            rows_v = best_g.reshape(p, block)
            rows_w = win_g.reshape(p, block)
            n_finite = jnp.sum(jnp.isfinite(rows_v), axis=1)
            overflow = jax.lax.pmax(
                jnp.any(n_finite > cap).astype(jnp.int32), axes) > 0

            def compact(_):
                # C smallest candidates per destination block
                neg, idx = jax.lax.top_k(-rows_v, cap)        # [p, cap]
                vals = -neg
                srcs = jnp.take_along_axis(rows_w, idx, axis=1)
                rv = jax.lax.all_to_all(vals, axes, split_axis=0,
                                        concat_axis=0)        # [p, cap]
                ri = jax.lax.all_to_all(idx, axes, split_axis=0,
                                        concat_axis=0)
                rs = jax.lax.all_to_all(srcs, axes, split_axis=0,
                                        concat_axis=0)
                flat_v = rv.reshape(-1)
                flat_i = ri.reshape(-1)
                flat_s = rs.reshape(-1)
                return relax.segment_min_with_winner(
                    flat_v, jnp.isfinite(flat_v), flat_s, flat_i, block)

            def dense(_):
                return dense_exchange(best_g, win_g)

            return jax.lax.cond(overflow, dense, compact, None)

        def merge(best_g, win_g):
            """Global per-destination partials -> the local block's
            ``(best_l, winner_l)`` via the version's collective."""
            if compact_capacity:
                return compact_exchange(best_g, win_g)
            return dense_exchange(best_g, win_g)

        def exchange(cand, mask):
            """Per-destination (min, winner) partials merged across shards;
            returns the local block's ``(best_l, winner_l)``."""
            best_g, win_g = relax.segment_min_with_winner(cand, mask, src,
                                                          dst, n_pad)
            return merge(best_g, win_g)

        def blocked_partials(dist_l, paths, parent_l, lb, ub, ac=None,
                             pb=None):
            """Blocked backend's push partial: ONE partials-megakernel
            launch over the shard's stacked tile-indexed slabs
            (see relax.blocked_shard_partials_fused), returning the
            ``(best, winner)`` pair plus the in-kernel tile/n_trav/
            n_relax/n_pruned counters — the flat O(E) candidate pass the
            segment_min branch needs for its metrics is folded into the
            kernel's scheduled tile pass."""
            return relax.blocked_shard_partials_fused(
                bl.src_local, bl.dst, bl.w, bl.tile_dst, bl.tile_first,
                dist_l, paths, parent_l, base, lb, ub,
                block_v=bmeta.block_v, n_dst_blocks=bmeta.n_dst_blocks,
                tile_e=bmeta.tile_e, use_kernel=bmeta.use_kernel,
                interpret=bmeta.interpret,
                alt_lb=None if ac is None else ac.lb, prune_bound=pb)

        local_edge = (dst // block) == me
        dst_local = jnp.clip(dst - base, 0, block - 1)

        def fused_local(dist_l, parent_l, frontier_l, lb, ub, metrics):
            """Paper §4.1 bucket fusion: FUSED local-only relaxation waves
            between synchronizations.  Only edges whose destination is
            owned locally relax; cross-shard updates wait for the next
            exchange.  Each wave is sync-free (no collectives)."""
            def wave(_, carry):
                dist_l, parent_l, front, acc, touched = carry
                paths = relax.leaf_pruned(front, dist_l, deg_l)
                cand, _, active = relax.edge_candidates(
                    dist_l[src_l], local_edge & paths[src_l],
                    parent_l[src_l], dst, w, lb, ub)
                best, winner = relax.segment_min_with_winner(
                    cand, active, src, dst_local, block)
                dist2, parent2, improved = relax.apply_updates(
                    dist_l, parent_l, best, winner)
                touched = touched + jnp.sum(active.astype(jnp.int32))
                return dist2, parent2, improved, acc | improved, touched

            dist_l, parent_l, _, acc, touched = jax.lax.fori_loop(
                0, fused_rounds, wave,
                (dist_l, parent_l, frontier_l, frontier_l,
                 jnp.int32(0)))
            metrics = metrics._replace(
                n_trav=metrics.n_trav + jax.lax.psum(touched, axes))
            return dist_l, parent_l, acc, metrics

        def one_round(dist_l, parent_l, frontier_l, lb, ub, metrics,
                      ac=None):
            paths = relax.leaf_pruned(frontier_l, dist_l, deg_l)
            # per-round prune bound from dist at round start (the same
            # recompute the single-device fused kernel does per round)
            pb = None if ac is None else alt_bound(dist_l, ac)
            n_prn = jnp.int32(0)
            if bmeta is None:
                cand, in_window, active = relax.edge_candidates(
                    dist_l[src_l], paths[src_l], parent_l[src_l], dst, w,
                    lb, ub)
                if ac is not None:
                    active, pruned = relax.alt_prune(cand, active,
                                                     ac.lb[dst], pb)
                    cand = jnp.where(active, cand, INF)
                    n_prn = jax.lax.psum(
                        jnp.sum(pruned.astype(jnp.int32)), axes)
                best_g, win_g = relax.segment_min_with_winner(
                    cand, active, src, dst, n_pad)
                n_tiles = jnp.float32(0)
                touched = jax.lax.psum(
                    jnp.sum(in_window.astype(jnp.int32)), axes)
                relaxed = jax.lax.psum(
                    jnp.sum(active.astype(jnp.int32)), axes)
                n_inv = jnp.float32(0)
            else:
                best_g, win_g, nt, trav, rlx, prn = blocked_partials(
                    dist_l, paths, parent_l, lb, ub, ac, pb)
                n_tiles = jax.lax.psum(nt.astype(jnp.float32), axes)
                touched = jax.lax.psum(trav, axes)
                relaxed = jax.lax.psum(rlx, axes)
                n_prn = jax.lax.psum(prn, axes)
                n_inv = jax.lax.psum(jnp.float32(1), axes)
            best_l, winner_l = merge(best_g, win_g)
            dist2, parent2, improved = relax.apply_updates(
                dist_l, parent_l, best_l, winner_l)
            nl_upd = jax.lax.psum(
                jnp.sum((improved & (deg_l > 1)).astype(jnp.int32)), axes)
            upd = jax.lax.psum(jnp.sum(improved.astype(jnp.int32)), axes)
            any_front = jax.lax.pmax(
                jnp.any(frontier_l).astype(jnp.int32), axes)
            metrics = metrics._replace(
                n_rounds=metrics.n_rounds + any_front,
                n_extended=metrics.n_extended + nl_upd,
                n_trav=metrics.n_trav + touched,
                n_relax=metrics.n_relax + relaxed,
                n_updates=metrics.n_updates + upd,
                n_pruned=metrics.n_pruned + n_prn,
                n_tiles_scanned=metrics.n_tiles_scanned + n_tiles,
                n_tiles_dense=metrics.n_tiles_dense + jnp.float32(
                    0 if bmeta is None else bmeta.dense_grid_tiles),
                n_invocations=metrics.n_invocations + n_inv)
            return dist2, parent2, improved, metrics

        def grouped_rounds(dist_l, parent_l, frontier_l, lb, ub, metrics,
                           ac=None):
            """Blocked ``fused_rounds``: up to ``fused_rounds`` COMPLETE
            synchronized rounds (each with its exchange) per stepping-loop
            body.  The round sequence — and with it dist/parent and every
            logical counter — is identical to the unfused engine by
            construction; only the outer while_loop bookkeeping amortizes.
            Clamped to a single round while ``lb <= 0`` so the first-step
            ub bootstrap still applies between rounds."""
            max_r = jnp.where(lb <= 0.0, jnp.int32(1),
                              jnp.int32(fused_rounds))

            def cond_f(c):
                # pure carry reads only — collectives may not appear in a
                # while_loop cond, so ``go`` is computed in the body
                return (c[5] > 0) & (c[4] < max_r)

            def body_f(c):
                dist_l, parent_l, front, metrics, r, _ = c
                dist2, parent2, improved, metrics = one_round(
                    dist_l, parent_l, front, lb, ub, metrics, ac)
                go = jax.lax.pmax(jnp.any(improved).astype(jnp.int32),
                                  axes)
                return dist2, parent2, improved, metrics, r + 1, go

            dist_l, parent_l, frontier_l, metrics, _, _ = \
                jax.lax.while_loop(cond_f, body_f,
                                   (dist_l, parent_l, frontier_l, metrics,
                                    jnp.int32(0), jnp.int32(1)))
            return dist_l, parent_l, frontier_l, metrics

        def relax_round(dist_l, parent_l, frontier_l, lb, ub, metrics,
                        ac=None):
            if fused_rounds > 0 and bmeta is not None:
                return grouped_rounds(dist_l, parent_l, frontier_l, lb, ub,
                                      metrics, ac)
            if fused_rounds > 0:
                # segment_min bucket fusion's local waves stay unpruned
                # (metrics-exempt already; the full rounds still prune)
                dist_l, parent_l, frontier_l, metrics = fused_local(
                    dist_l, parent_l, frontier_l, lb, ub, metrics)
            return one_round(dist_l, parent_l, frontier_l, lb, ub, metrics,
                             ac)

        def pull_round(dist_l, parent_l, st, lb, ub, metrics, ac=None,
                       pb=None):
            # mirrored push from the settled band (undirected store); the
            # requester's dist is remote, so the unsettled gate applies on
            # the local (destination-owner) side after the exchange.
            # Under ALT the requester receiving the update is ``dst``, so
            # requests with cand + lb[dst] > bound are cut (the mirrored
            # twin of the single-device requester-side alt_lb[src] cut).
            dv = dist_l[src_l]
            mask = (dv >= st) & (dv < lb) & (dv + w < ub)
            cand = jnp.where(mask, dv + w, INF)
            n_prn = jnp.int32(0)
            if ac is not None:
                mask, pruned = relax.alt_prune(cand, mask, ac.lb[dst], pb)
                cand = jnp.where(mask, cand, INF)
                n_prn = jax.lax.psum(
                    jnp.sum(pruned.astype(jnp.int32)), axes)
            best_l, winner_l = exchange(cand, mask)
            dist2, parent2, improved = relax.apply_updates(
                dist_l, parent_l, best_l, winner_l, gate=dist_l > lb)
            # scan/request sums equal the single-device definitions by edge
            # symmetry: every directed edge lives on exactly one shard.
            scans = jax.lax.psum(jnp.sum(
                ((dv > lb) & (w < ub - st)).astype(jnp.int32)), axes)
            reqs = jax.lax.psum(jnp.sum(mask.astype(jnp.int32)), axes)
            nl_upd = jax.lax.psum(
                jnp.sum((improved & (deg_l > 1)).astype(jnp.int32)), axes)
            upd = jax.lax.psum(jnp.sum(improved.astype(jnp.int32)), axes)
            metrics = metrics._replace(
                n_pull_trav=metrics.n_pull_trav + scans,
                n_extended=metrics.n_extended + nl_upd,
                n_relax=metrics.n_relax + reqs,
                n_updates=metrics.n_updates + upd,
                n_pruned=metrics.n_pruned + n_prn,
                n_rounds=metrics.n_rounds + 1)
            return dist2, parent2, metrics

        def dgap(dist_l, x, tparams=params, mult=None):
            g_, _, _ = _dstats_gap(dist_l, deg_l, rtow, n_edges2, x, tparams,
                                   axes, mult)
            return g_

        def transition(dist_l, parent_l, lb, ub, metrics, gp, ps=None,
                       ac=None):
            pend = dist_l[src_l] + w
            pend = jnp.where(pend >= ub, pend, INF)
            if ac is not None:
                # a pending candidate the ALT bound would cut can never
                # improve the target, so skipping it in fast-forward/
                # termination is exact for the p2p contract
                bound_eff = alt_bound(dist_l, ac)
                pend = jnp.where(pend + ac.lb[dst] > bound_eff, INF, pend)
            min_pending = jax.lax.pmin(jnp.min(pend), axes)
            done = ~jnp.isfinite(min_pending)
            if ps is not None:
                # observe -> adapt: the metrics counters are psum'd, so the
                # policy state stays replicated across shards
                ps = stepping.adaptive_update(ps, metrics.n_rounds,
                                              metrics.n_relax,
                                              metrics.n_updates)
                tparams = stepping.effective_params(ps)
                mult = ps.mult
            else:
                tparams, mult = params, None
            st_next, gap_ub = _dstats_compute_st(
                dist_l, deg_l, rtow, n_edges2, lb, ub, tparams, axes, mult)
            lb2 = ub
            ub2 = lb2 + gap_ub
            ffwd = (min_pending >= ub2) & ~done
            lb2 = jnp.where(ffwd, min_pending, lb2)
            gap3 = dgap(dist_l, lb2, tparams, mult)
            ub2 = jnp.where(ffwd, lb2 + gap3, ub2)
            st_next = jnp.minimum(st_next, lb2)

            def with_pull(args):
                return pull_round(args[0], args[1], st_next, lb2, ub2,
                                  args[2], ac,
                                  None if ac is None else bound_eff)

            dist_l, parent_l, metrics = jax.lax.cond(
                st_next < lb2, with_pull, lambda a: a,
                (dist_l, parent_l, metrics))
            done = done | goal_reached(dist_l, lb2, gp)
            frontier = relax.window_frontier(dist_l, st_next, lb2, ub2,
                                             max_w) & ~done
            metrics = metrics._replace(
                n_steps=metrics.n_steps + jnp.where(done, 0, 1))
            out = (dist_l, parent_l, frontier, lb2, ub2, st_next, done,
                   metrics)
            return out if ps is None else out + (ps,)

        def cond(s):
            return (~s.done) & (s.iters < max_iters)

        def run_one(source, gp):
            dist0 = jnp.where(jnp.arange(block) + base == source, 0.0, INF)
            parent0 = jnp.where(jnp.arange(block) + base == source, source,
                                -1).astype(jnp.int32)
            frontier0 = (jnp.arange(block) + base) == source
            metrics0 = _zero_metrics()._replace(n_extended=jnp.int32(1))
            ac = None if alt_d is None else _make_alt_ctx(alt_d, source,
                                                          gp, n_pad)

            def body(s: _V2State):
                dist_l, parent_l, frontier, metrics = relax_round(
                    s.dist, s.parent, s.frontier, s.lb, s.ub, s.metrics,
                    ac)

                def tighten(ub):
                    mask = (deg_l.astype(jnp.float32) >= high_d0) \
                        & (dist_l > 0)
                    local = jnp.min(jnp.where(mask, dist_l, INF))
                    return jnp.minimum(ub, jax.lax.pmin(local, axes))
                ub = jax.lax.cond(s.lb <= 0.0, tighten, lambda u: u, s.ub)

                any_front = jax.lax.pmax(jnp.any(frontier).astype(jnp.int32),
                                         axes) > 0

                def keep(args):
                    dist_l, parent_l, lb, ub, metrics = args
                    return (dist_l, parent_l, frontier, lb, ub, s.st, s.done,
                            metrics)

                def trans(args):
                    return transition(args[0], args[1], args[2], args[3],
                                      args[4], gp, ac=ac)

                (dist_l, parent_l, frontier, lb, ub, st2, done, metrics) = \
                    jax.lax.cond(any_front, keep, trans,
                                 (dist_l, parent_l, s.lb, ub, metrics))
                return _V2State(dist_l, parent_l, frontier, lb, ub, st2,
                                done, s.iters + 1, metrics)

            def body_a(carry):
                s, ps = carry
                dist_l, parent_l, frontier, metrics = relax_round(
                    s.dist, s.parent, s.frontier, s.lb, s.ub, s.metrics,
                    ac)

                def tighten(ub):
                    mask = (deg_l.astype(jnp.float32) >= high_d0) \
                        & (dist_l > 0)
                    local = jnp.min(jnp.where(mask, dist_l, INF))
                    return jnp.minimum(ub, jax.lax.pmin(local, axes))
                ub = jax.lax.cond(s.lb <= 0.0, tighten, lambda u: u, s.ub)

                any_front = jax.lax.pmax(jnp.any(frontier).astype(jnp.int32),
                                         axes) > 0

                def keep(args):
                    dist_l, parent_l, lb, ub, metrics, ps = args
                    return (dist_l, parent_l, frontier, lb, ub, s.st, s.done,
                            metrics, ps)

                def trans(args):
                    return transition(args[0], args[1], args[2], args[3],
                                      args[4], gp, ps=args[5], ac=ac)

                (dist_l, parent_l, frontier, lb, ub, st2, done, metrics,
                 ps) = jax.lax.cond(any_front, keep, trans,
                                    (dist_l, parent_l, s.lb, ub, metrics,
                                     ps))
                return _V2State(dist_l, parent_l, frontier, lb, ub, st2,
                                done, s.iters + 1, metrics), ps

            init = _V2State(dist0, parent0, frontier0, jnp.float32(0.0), INF,
                            jnp.float32(0.0), jnp.bool_(False), jnp.int32(0),
                            metrics0)
            if not adaptive:
                if trace_cap <= 0:
                    out = jax.lax.while_loop(cond, body, init)
                    return out.dist, out.parent, out.metrics

                def traced_body(carry):
                    s, buf = carry
                    s1 = body(s)
                    m0, m1 = s.metrics, s1.metrics
                    stepped = (m1.n_steps > m0.n_steps) | (s1.done & ~s.done)
                    # the frontier is block-sharded here: psum the local
                    # census (one extra collective per iteration, traced
                    # solves only)
                    fsz = jax.lax.psum(
                        jnp.sum(s.frontier.astype(jnp.int32)), axes)
                    buf = _dtrace_record(buf, s.iters, fsz, s.lb, s.ub, s.st,
                                         stepped, m0, m1)
                    return s1, buf

                out, buf = jax.lax.while_loop(
                    lambda c: cond(c[0]), traced_body,
                    (init, trace_init(trace_cap)))
                return out.dist, out.parent, out.metrics, buf

            init_a = (init, stepping.policy_init(params))
            if trace_cap <= 0:
                out, _ = jax.lax.while_loop(lambda c: cond(c[0]), body_a,
                                            init_a)
                return out.dist, out.parent, out.metrics

            def traced_body_a(carry):
                c, buf = carry
                s = c[0]
                c1 = body_a(c)
                s1 = c1[0]
                m0, m1 = s.metrics, s1.metrics
                stepped = (m1.n_steps > m0.n_steps) | (s1.done & ~s.done)
                fsz = jax.lax.psum(
                    jnp.sum(s.frontier.astype(jnp.int32)), axes)
                buf = _dtrace_record(buf, s.iters, fsz, s.lb, s.ub, s.st,
                                     stepped, m0, m1)
                return c1, buf

            (out, _), buf = jax.lax.while_loop(
                lambda c: cond(c[0][0]), traced_body_a,
                (init_a, trace_init(trace_cap)))
            return out.dist, out.parent, out.metrics, buf

        if batch:
            return jax.lax.map(lambda a: run_one(*a), (source, goal_param))
        return run_one(source, goal_param)

    return run
