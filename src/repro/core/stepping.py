"""Dynamic-stepping heuristic (paper §3.1, Eqs. 1-3) and its policy family.

Given the current scheduling threshold ``x`` (and the latest dist[]), choose
the window width ``gap(x)`` so the next pair ``<x, x+gap(x)>``:

  * settles roughly half the remaining degree mass per step
    (``sumD(ub) ~ sumD(lb)/2`` when ``highD(lb) > alpha``), and
  * makes paths created by repeated relaxations w.h.p. longer than ``ub``.

    prob(x)  = min(beta, max(sumD(x), 2|E| - sumD(x)) / (2|E|))          (1)
    ratio(x) = 1 - (1 - prob(x)) ** (1 / (prob(x) * highD(x)))           (2)
    gap(x)   = maxW(G, 1)        if highD(x) <= alpha                    (3)
               maxW(G, ratio(x)) otherwise

Two policies share these equations (:data:`POLICIES`):

* ``"static"`` — the paper's policy: one fixed ``SteppingParams`` for the
  whole solve.  This is the default, and with it every engine compiles
  the *literally identical* program it did before the policy family
  existed (the adaptive state and the ``mult`` rescale below are only
  woven in when the static ``policy`` knob selects them).
* ``"adaptive"`` — a feedback variant: a small :class:`PolicyState` rides
  in the solve loop's carry, and at every step transition the observed
  per-step round count and relaxation waste (both already maintained in
  ``SsspMetrics``) multiplicatively adjust ``alpha``/``beta`` and a
  window multiplier ``mult``.  Windows are pure scheduling — any
  positive width yields the same fixpoint — so adapting them trades
  rounds against wasted relaxations without touching correctness.

The feedback rule (:func:`adaptive_update`) is deliberately simple:

* too many relaxation rounds per step, or mostly-wasted relaxations
  (``1 - updates/relaxes`` above ``waste_hi``) ⇒ the window is too wide —
  shrink ``mult`` (and gently ``alpha``/``beta``) by ``1/step``;
* few rounds *and* productive relaxations ⇒ the window is too narrow —
  grow by ``step``.

Everything is clamped (``mult_min``..``mult_max`` etc.) and the widened /
narrowed gap is re-clamped to the same ``w_floor`` as the static policy,
so adaptive windows inherit the positivity guarantee.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from . import stats

#: Stepping-policy names accepted by ``EngineConfig(policy=...)``.
POLICIES = ("static", "adaptive")


class SteppingParams(NamedTuple):
    alpha: float = 3.0   # paper default
    beta: float = 0.9    # paper default


class AdaptivePolicy(NamedTuple):
    """Static hyper-knobs of the ``"adaptive"`` policy (jit-constant)."""
    rounds_lo: float = 2.0    # <= this many rounds/step: window too narrow
    rounds_hi: float = 6.0    # > this many rounds/step: window too wide
    waste_hi: float = 0.6     # wasted-relaxation fraction that means "too wide"
    step: float = 1.3         # multiplicative feedback factor (> 1)
    mult_min: float = 0.25    # clamps for the window multiplier ...
    mult_max: float = 4.0
    alpha_min: float = 1.0    # ... and for the adapted Eq. 1-3 parameters
    alpha_max: float = 64.0
    beta_min: float = 0.3
    beta_max: float = 0.995


DEFAULT_ADAPTIVE = AdaptivePolicy()


class PolicyState(NamedTuple):
    """Traced per-solve state of the adaptive policy (loop-carried).

    ``alpha``/``beta``/``mult`` are the adapted Eq. 1-3 parameters plus
    the window multiplier; ``last_*`` snapshot the ``SsspMetrics``
    counters at the previous step transition so the next transition can
    form per-step deltas.
    """
    alpha: jnp.ndarray          # f32 scalar
    beta: jnp.ndarray           # f32 scalar
    mult: jnp.ndarray           # f32 scalar
    last_rounds: jnp.ndarray    # i32 counter snapshots
    last_relax: jnp.ndarray
    last_updates: jnp.ndarray


def policy_init(params: SteppingParams) -> PolicyState:
    """Fresh adaptive state: start at the static parameters, mult=1."""
    return PolicyState(
        alpha=jnp.float32(params.alpha),
        beta=jnp.float32(params.beta),
        mult=jnp.float32(1.0),
        last_rounds=jnp.int32(0),
        last_relax=jnp.int32(0),
        last_updates=jnp.int32(0),
    )


def effective_params(ps: PolicyState) -> SteppingParams:
    """The adapted (traced) parameters as a ``SteppingParams``."""
    return SteppingParams(alpha=ps.alpha, beta=ps.beta)


def adaptive_update(ps: PolicyState, n_rounds: jnp.ndarray,
                    n_relax: jnp.ndarray, n_updates: jnp.ndarray,
                    pol: AdaptivePolicy = DEFAULT_ADAPTIVE) -> PolicyState:
    """One feedback step from the counters observed since the last step.

    Runs inside the jitted solve loop at each step transition; all inputs
    are the *cumulative* ``SsspMetrics`` counters, deltas are formed
    against the snapshots carried in ``ps``.
    """
    rounds_d = (n_rounds - ps.last_rounds).astype(jnp.float32)
    relax_d = (n_relax - ps.last_relax).astype(jnp.float32)
    upd_d = (n_updates - ps.last_updates).astype(jnp.float32)
    waste = 1.0 - upd_d / jnp.maximum(relax_d, 1.0)
    too_wide = (rounds_d > pol.rounds_hi) | (waste > pol.waste_hi)
    too_narrow = (rounds_d <= pol.rounds_lo) & ~too_wide
    f = jnp.where(too_wide, jnp.float32(1.0 / pol.step),
                  jnp.where(too_narrow, jnp.float32(pol.step),
                            jnp.float32(1.0)))
    # mult takes the full factor; alpha/beta move gently (sqrt of it) so
    # the Eq. 1-3 shape degrades gracefully rather than slamming to a clamp
    fs = jnp.sqrt(f)
    return PolicyState(
        alpha=jnp.clip(ps.alpha * fs, pol.alpha_min, pol.alpha_max),
        beta=jnp.clip(ps.beta * fs, pol.beta_min, pol.beta_max),
        mult=jnp.clip(ps.mult * f, pol.mult_min, pol.mult_max),
        last_rounds=n_rounds,
        last_relax=n_relax,
        last_updates=n_updates,
    )


def prob(sum_d_x: jnp.ndarray, n_edges2: jnp.ndarray,
         beta: float) -> jnp.ndarray:
    """Eq. (1). ``n_edges2`` is 2|E| (the directed slot count)."""
    s = sum_d_x.astype(jnp.float32)
    two_e = n_edges2.astype(jnp.float32)
    return jnp.minimum(jnp.float32(beta),
                       jnp.maximum(s, two_e - s) / jnp.maximum(two_e, 1.0))


def ratio(prob_x: jnp.ndarray, high_d_x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2) — computed in log-space for numerical safety."""
    p = jnp.clip(prob_x, 1e-6, 1.0 - 1e-6)
    expo = 1.0 / (p * jnp.maximum(high_d_x, 1.0))
    return 1.0 - jnp.exp(expo * jnp.log1p(-p))


def gap_from_stats(sd: jnp.ndarray, hd: jnp.ndarray, rtow: jnp.ndarray,
                   n_edges2: jnp.ndarray,
                   params: SteppingParams = SteppingParams(),
                   mult: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Eq. (3) given precomputed (possibly psum-reduced) sumD/highD.

    ``mult`` is the adaptive policy's window multiplier; ``None`` (the
    static policy) adds no operations, keeping the compiled program
    byte-identical to the pre-policy one.
    """
    p = prob(sd, n_edges2, params.beta)
    r = ratio(p, hd)
    g_adaptive = stats.max_w_of(rtow, r)
    g_full = rtow[-1]
    g = jnp.where(hd <= params.alpha, g_full, g_adaptive)
    # A window of width zero (duplicate-heavy weight LUTs can quantize small
    # ratios to w_min=RtoW[0]=0 on integer-weight variants) would stall the
    # outer loop; clamp to the smallest positive LUT entry.
    positive = jnp.where(rtow > 0, rtow, rtow[-1])
    w_floor = jnp.minimum(jnp.min(positive), g_full)
    floor = jnp.maximum(w_floor, jnp.float32(1e-12))
    if mult is None:
        return jnp.maximum(g, floor)
    # rescaled windows re-clamp to the same floor, so adaptive widths
    # inherit the static policy's positivity guarantee
    return jnp.maximum(jnp.maximum(g, floor) * mult, floor)


def gap(dist: jnp.ndarray, deg: jnp.ndarray, rtow: jnp.ndarray,
        n_edges2: jnp.ndarray, x: jnp.ndarray,
        params: SteppingParams = SteppingParams(),
        mult: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Eq. (3): window width for the scheduling threshold ``x``."""
    hd = stats.high_d(dist, deg, x)
    sd = stats.sum_d(dist, deg, x)
    return gap_from_stats(sd, hd, rtow, n_edges2, params, mult)
