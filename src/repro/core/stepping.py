"""Dynamic-stepping heuristic (paper §3.1, Eqs. 1-3).

Given the current scheduling threshold ``x`` (and the latest dist[]), choose
the window width ``gap(x)`` so the next pair ``<x, x+gap(x)>``:

  * settles roughly half the remaining degree mass per step
    (``sumD(ub) ~ sumD(lb)/2`` when ``highD(lb) > alpha``), and
  * makes paths created by repeated relaxations w.h.p. longer than ``ub``.

    prob(x)  = min(beta, max(sumD(x), 2|E| - sumD(x)) / (2|E|))          (1)
    ratio(x) = 1 - (1 - prob(x)) ** (1 / (prob(x) * highD(x)))           (2)
    gap(x)   = maxW(G, 1)        if highD(x) <= alpha                    (3)
               maxW(G, ratio(x)) otherwise
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import stats


class SteppingParams(NamedTuple):
    alpha: float = 3.0   # paper default
    beta: float = 0.9    # paper default


def prob(sum_d_x: jnp.ndarray, n_edges2: jnp.ndarray,
         beta: float) -> jnp.ndarray:
    """Eq. (1). ``n_edges2`` is 2|E| (the directed slot count)."""
    s = sum_d_x.astype(jnp.float32)
    two_e = n_edges2.astype(jnp.float32)
    return jnp.minimum(jnp.float32(beta),
                       jnp.maximum(s, two_e - s) / jnp.maximum(two_e, 1.0))


def ratio(prob_x: jnp.ndarray, high_d_x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (2) — computed in log-space for numerical safety."""
    p = jnp.clip(prob_x, 1e-6, 1.0 - 1e-6)
    expo = 1.0 / (p * jnp.maximum(high_d_x, 1.0))
    return 1.0 - jnp.exp(expo * jnp.log1p(-p))


def gap_from_stats(sd: jnp.ndarray, hd: jnp.ndarray, rtow: jnp.ndarray,
                   n_edges2: jnp.ndarray,
                   params: SteppingParams = SteppingParams()) -> jnp.ndarray:
    """Eq. (3) given precomputed (possibly psum-reduced) sumD/highD."""
    p = prob(sd, n_edges2, params.beta)
    r = ratio(p, hd)
    g_adaptive = stats.max_w_of(rtow, r)
    g_full = rtow[-1]
    g = jnp.where(hd <= params.alpha, g_full, g_adaptive)
    # A window of width zero (duplicate-heavy weight LUTs can quantize small
    # ratios to w_min=RtoW[0]=0 on integer-weight variants) would stall the
    # outer loop; clamp to the smallest positive LUT entry.
    positive = jnp.where(rtow > 0, rtow, rtow[-1])
    w_floor = jnp.minimum(jnp.min(positive), g_full)
    return jnp.maximum(g, jnp.maximum(w_floor, jnp.float32(1e-12)))


def gap(dist: jnp.ndarray, deg: jnp.ndarray, rtow: jnp.ndarray,
        n_edges2: jnp.ndarray, x: jnp.ndarray,
        params: SteppingParams = SteppingParams()) -> jnp.ndarray:
    """Eq. (3): window width for the scheduling threshold ``x``."""
    hd = stats.high_d(dist, deg, x)
    sd = stats.sum_d(dist, deg, x)
    return gap_from_stats(sd, hd, rtow, n_edges2, params)
