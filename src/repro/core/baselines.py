"""Baseline SSSP implementations the paper compares against (Table 2/3).

* :func:`dijkstra_host`     — exact host-side Dijkstra (heapq); the test
                              oracle and the work-efficiency yardstick.
* :func:`bellman_ford`      — jitted frontier Bellman-Ford (PQ-BF analogue).
* :func:`delta_stepping`    — jitted Δ-stepping (GAPBS / Graph500 analogue);
                              light/heavy split per the classic algorithm.

All JAX baselines use the same DeviceGraph container and report the same raw
metric counters as the EIC engine so nFrontier/nSync/nTrav are comparable.
"""
from __future__ import annotations

import heapq
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from .graph import DeviceGraph, HostGraph
from .sssp import INF, INT_MAX, SsspMetrics, _zero_metrics


def dijkstra_host(g: HostGraph, source: int):
    """Exact Dijkstra on the host CSR (float64 accumulation)."""
    n = g.n
    dist = np.full(n, np.inf)
    parent = np.full(n, -1, np.int64)
    dist[source] = 0.0
    parent[source] = source
    visited = np.zeros(n, bool)
    heap = [(0.0, source)]
    row_ptr, col, w = g.row_ptr, g.dst, g.w
    while heap:
        d, u = heapq.heappop(heap)
        if visited[u]:
            continue
        visited[u] = True
        for i in range(row_ptr[u], row_ptr[u + 1]):
            v = col[i]
            nd = d + float(w[i])
            if nd < dist[v]:
                dist[v] = nd
                parent[v] = u
                heapq.heappush(heap, (nd, v))
    return dist, parent


class _BFState(NamedTuple):
    dist: jnp.ndarray
    parent: jnp.ndarray
    frontier: jnp.ndarray
    iters: jnp.ndarray
    metrics: SsspMetrics


@partial(jax.jit, static_argnames=("max_iters",))
def bellman_ford(g: DeviceGraph, source, *, max_iters: int = 1_000_000):
    """Frontier Bellman-Ford: relax every frontier vertex each round."""
    n = g.n
    dist0 = jnp.full((n,), INF, jnp.float32).at[source].set(0.0)
    parent0 = jnp.full((n,), -1, jnp.int32).at[source].set(source)
    frontier0 = jnp.zeros((n,), bool).at[source].set(True)

    def cond(s):
        return jnp.any(s.frontier) & (s.iters < max_iters)

    def body(s):
        du = s.dist[g.src]
        active = s.frontier[g.src]
        cand = jnp.where(active, du + g.w, INF)
        best = jax.ops.segment_min(cand, g.dst, num_segments=n)
        improved = best < s.dist
        win = jnp.where(active & (cand <= best[g.dst]), g.src, INT_MAX)
        winner = jax.ops.segment_min(win, g.dst, num_segments=n)
        m = s.metrics
        metrics = m._replace(
            n_rounds=m.n_rounds + 1,
            n_extended=m.n_extended + jnp.sum(s.frontier.astype(jnp.int32)),
            n_trav=m.n_trav + jnp.sum(active.astype(jnp.int32)),
            n_updates=m.n_updates + jnp.sum(improved.astype(jnp.int32)),
        )
        return _BFState(jnp.where(improved, best, s.dist),
                        jnp.where(improved, winner, s.parent),
                        improved, s.iters + 1, metrics)

    out = jax.lax.while_loop(cond, body, _BFState(
        dist0, parent0, frontier0, jnp.int32(0), _zero_metrics()))
    return out.dist, out.parent, out.metrics


class _DSState(NamedTuple):
    dist: jnp.ndarray
    parent: jnp.ndarray
    already: jnp.ndarray   # light-relaxed at current dist value (this bucket)
    bucket_lo: jnp.ndarray
    done: jnp.ndarray
    iters: jnp.ndarray
    metrics: SsspMetrics


@partial(jax.jit, static_argnames=("max_iters",))
def delta_stepping(g: DeviceGraph, source, delta, *,
                   max_iters: int = 1_000_000):
    """Classic Δ-stepping with light/heavy edge split per bucket.

    Buckets ``[iΔ, (i+1)Δ)`` processed in ascending order; within a bucket,
    light edges (w < Δ) relax repeatedly (with reinsertion) until the bucket
    is stable, then heavy edges of all bucket members relax once.
    """
    n = g.n
    delta = jnp.float32(delta)
    dist0 = jnp.full((n,), INF, jnp.float32).at[source].set(0.0)
    parent0 = jnp.full((n,), -1, jnp.int32).at[source].set(source)
    light = g.w < delta

    def relax(dist, parent, edge_mask, metrics):
        cand = jnp.where(edge_mask, dist[g.src] + g.w, INF)
        best = jax.ops.segment_min(cand, g.dst, num_segments=n)
        improved = best < dist
        win = jnp.where(edge_mask & (cand <= best[g.dst]), g.src, INT_MAX)
        winner = jax.ops.segment_min(win, g.dst, num_segments=n)
        metrics = metrics._replace(
            n_rounds=metrics.n_rounds + 1,
            n_trav=metrics.n_trav + jnp.sum(edge_mask.astype(jnp.int32)),
            n_updates=metrics.n_updates + jnp.sum(improved.astype(jnp.int32)))
        return (jnp.where(improved, best, dist),
                jnp.where(improved, winner, parent), improved, metrics)

    def cond(s):
        return (~s.done) & (s.iters < max_iters)

    def body(s):
        lo, hi = s.bucket_lo, s.bucket_lo + delta
        in_bucket = (s.dist >= lo) & (s.dist < hi)
        todo = in_bucket & ~s.already
        any_light = jnp.any(todo)

        def light_branch(s):
            mask = todo[g.src] & light
            m2 = s.metrics._replace(
                n_extended=s.metrics.n_extended +
                jnp.sum(todo.astype(jnp.int32)))
            d2, p2, improved, m2 = relax(s.dist, s.parent, mask, m2)
            # reinsert vertices improved back into the current bucket
            in_b2 = (d2 >= lo) & (d2 < hi)
            already = (s.already | todo) & ~(improved & in_b2)
            return s._replace(dist=d2, parent=p2, already=already, metrics=m2)

        def heavy_branch(s):
            mask = in_bucket[g.src] & ~light
            d2, p2, improved, m2 = relax(s.dist, s.parent, mask, s.metrics)
            nxt = jnp.min(jnp.where(d2 >= hi, d2, INF))
            done = ~jnp.isfinite(nxt)
            lo2 = jnp.where(done, s.bucket_lo,
                            jnp.floor(nxt / delta) * delta)
            return s._replace(dist=d2, parent=p2,
                              already=jnp.zeros_like(s.already),
                              bucket_lo=lo2, done=done, metrics=m2)

        s = jax.lax.cond(any_light, light_branch, heavy_branch, s)
        return s._replace(iters=s.iters + 1)

    out = jax.lax.while_loop(cond, body, _DSState(
        dist0, parent0, jnp.zeros((n,), bool), jnp.float32(0.0),
        jnp.bool_(False), jnp.int32(0), _zero_metrics()))
    return out.dist, out.parent, out.metrics
