"""Traversal-optimization heuristic (paper §3.2, Eqs. 4-6 + Function 2).

Chooses the selection threshold ``ST(lb, ub) <= lb`` that maximizes the
estimated number of skipped edge traversals

    profit(x, lb, ub) = pushed(x, lb, ub) - long(x, lb, ub) - pulled(x, lb, ub)

where (with lb0 = max(x, lb - maxW), ub0 = min(ub, lb + maxW),
ub1 = min(ub, lb0 + maxW)):

    pushed(x, lb, y) = (y - lb) * (sumD(x) - sumD(lb)) / maxW(G, 1)      (4)
    pulled(x, lb, y) = (y - x) * sumD(lb) / maxW(G, 1)                   (5)
    long(x, lb, y)   = pulled(x, lb, y) * (sumD(x) - sumD(lb)) / (2|E|)  (6)

``pushed`` counts edges the push model would traverse from the settled band
``[x, lb)``; ``pulled`` counts the pull requests issued by unsettled vertices;
``long`` counts the long relevant edges that must still be relaxed either way.

Function 2 (the control flow) is reproduced with one approximation from the
paper's own implementation section (§4.1): instead of iterating over every
distinct dist[] value we evaluate profit on an ST_NUM-point grid, matching the
EIC implementation's ``{x * (st1-st0)/ST_NUM + st0}`` candidate set.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import stats, stepping
from .graph import ST_NUM


def profit_terms(x: jnp.ndarray, lb: jnp.ndarray, y: jnp.ndarray,
                 sum_d_x: jnp.ndarray, sum_d_lb: jnp.ndarray,
                 n_edges2: jnp.ndarray, max_w: jnp.ndarray):
    """Vectorized (pushed, long, pulled) estimates for candidate(s) ``x``.

    ``y`` is the *next-next* threshold ``ub + gap(ub)`` — Function 2 evaluates
    profit for the upcoming pair ``<ub, y>``; here ``lb`` is that pair's lower
    bound (i.e. the caller passes lb=ub_current).
    """
    max_w = jnp.maximum(max_w, 1e-12)
    lb0 = jnp.maximum(x, lb - max_w)
    ub0 = jnp.minimum(y, lb + max_w)
    ub1 = jnp.minimum(y, lb0 + max_w)
    sd_x = sum_d_x.astype(jnp.float32)
    sd_lb = sum_d_lb.astype(jnp.float32)
    band = jnp.maximum(sd_x - sd_lb, 0.0)  # degree mass of VS(x)\VS(lb)
    pushed = (ub0 - lb) * band / max_w
    pulled = (ub0 - x) * sd_lb / max_w
    long_ = ((ub1 - lb0) * sd_lb / max_w) * band / n_edges2.astype(jnp.float32)
    return pushed, long_, pulled


def compute_st(dist: jnp.ndarray, deg: jnp.ndarray, rtow: jnp.ndarray,
               n_edges2: jnp.ndarray, lb: jnp.ndarray, ub: jnp.ndarray,
               params: stepping.SteppingParams = stepping.SteppingParams(),
               st_num: int = ST_NUM, mult=None) -> jnp.ndarray:
    """Function 2: selection threshold for the *next* pair ``<ub, ub+gap(ub)>``.

    Returns ``st in [0, ub]``; ``st == ub`` disables the pull model
    (``st == lb`` case of Function 1).  ``mult`` is the adaptive policy's
    window multiplier (``None`` for the static policy — no extra ops).
    """
    sd_ub = stats.sum_d(dist, deg, ub)
    gap_lb = stepping.gap(dist, deg, rtow, n_edges2, lb, params, mult)
    gap_ub = stepping.gap(dist, deg, rtow, n_edges2, ub, params, mult)
    grid = st_grid_points(ub, st_num)
    sd_grid = stats.sum_d_grid(dist, deg, grid)
    return compute_st_from_stats(grid, sd_grid, sd_ub, gap_lb, gap_ub,
                                 rtow, n_edges2, ub)


def st_grid_points(ub: jnp.ndarray, st_num: int = ST_NUM) -> jnp.ndarray:
    """Candidate grid over [0, ub) — the paper's ST_NUM-point candidate set."""
    return jnp.linspace(0.0, 1.0, st_num, dtype=jnp.float32) * ub


def compute_st_from_stats(grid, sd_grid, sd_ub, gap_lb, gap_ub, rtow,
                          n_edges2, ub) -> jnp.ndarray:
    """Function 2 core, given (possibly psum-reduced) statistics."""
    max_w = rtow[-1]
    n_e = n_edges2.astype(jnp.int32) // 2  # |E|

    # line 2: statistics-extraction shortcut / full-width window => push-only
    early_push = (sd_ub >= n_e) | (gap_lb >= max_w)
    # line 5: next window is full-width => st = ub - maxW
    early_band = gap_ub >= max_w

    y = ub + gap_ub
    pushed, long_, pulled = profit_terms(
        grid, ub, y, sd_grid, sd_ub, n_edges2, max_w)
    profit = pushed - long_ - pulled
    best = jnp.argmax(profit)
    st_grid = jnp.where(profit[best] > 0, grid[best], ub)

    st = jnp.where(early_band, jnp.maximum(ub - max_w, 0.0), st_grid)
    st = jnp.where(early_push, ub, st)
    return st
