"""Train-step builders (per architecture family) + microbatch accumulation.

``make_*_train_step`` returns a pure function suitable for ``jax.jit`` with
donated (params, opt_state); gradient accumulation over microbatches is a
``lax.scan`` so memory stays O(1 microbatch).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.gnn import common as gnn_common
from ..models.recsys import mind as mind_mod
from . import optimizer as opt_mod


def _accumulate(loss_fn, params, batch, microbatches: int):
    """Mean-gradient accumulation over leading-dim splits of ``batch``."""
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def split(x):
        b = x.shape[0]
        return x.reshape(microbatches, b // microbatches, *x.shape[1:])

    mb = jax.tree.map(split, batch)

    def body(carry, mb_i):
        acc, loss_acc = carry
        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb_i)
        acc = jax.tree.map(jnp.add, acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), mb)
    grads = jax.tree.map(lambda g: g / microbatches, grads)
    loss = loss_sum / microbatches
    return loss, {"loss": loss}, grads


def make_lm_train_step(cfg: transformer.LMConfig,
                       opt_cfg: opt_mod.AdamWConfig,
                       act_spec=None, microbatches: int = 1):
    def loss_fn(params, batch):
        return transformer.loss_fn(cfg, params, batch, act_spec)

    def step(params, opt_state, batch):
        loss, metrics, grads = _accumulate(loss_fn, params, batch,
                                           microbatches)
        params, opt_state, om = opt_mod.adamw_update(params, grads,
                                                     opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step


def make_gnn_train_step(forward: Callable, cfg, opt_cfg,
                        graph_level: bool = False, microbatches: int = 1):
    """``forward(cfg, params, gb) -> logits`` + CE on labels."""

    def loss_fn(params, gb):
        logits = forward(cfg, params, gb)
        if graph_level:
            labels = gb.labels
            loss = gnn_common.node_ce_loss(logits, labels)
        else:
            loss = gnn_common.node_ce_loss(logits, gb.labels)
        return loss, {"loss": loss}

    def step(params, opt_state, gb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, gb)
        params, opt_state, om = opt_mod.adamw_update(params, grads,
                                                     opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step


def make_gnn_regression_step(forward: Callable, cfg, opt_cfg):
    """Graph-level regression (molecule shapes)."""

    def loss_fn(params, gb):
        pred = forward(cfg, params, gb)
        loss = jnp.mean((pred.reshape(-1) -
                         gb.labels.astype(jnp.float32).reshape(-1)) ** 2)
        return loss, {"loss": loss}

    def step(params, opt_state, gb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, gb)
        params, opt_state, om = opt_mod.adamw_update(params, grads,
                                                     opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step


def make_mind_train_step(cfg: mind_mod.MINDConfig, opt_cfg,
                         microbatches: int = 1):
    def loss_fn(params, batch):
        return mind_mod.train_loss(cfg, params, batch)

    def step(params, opt_state, batch):
        loss, metrics, grads = _accumulate(loss_fn, params, batch,
                                           microbatches)
        params, opt_state, om = opt_mod.adamw_update(params, grads,
                                                     opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step
