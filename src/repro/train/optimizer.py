"""Optimizers in pure JAX (no optax in this container — built from scratch).

AdamW with:
  * fp32 first/second moments and optional fp32 master weights (params may
    be bf16 — the standard mixed-precision recipe),
  * global-norm gradient clipping,
  * linear-warmup + cosine-decay schedule.

States are pytrees mirroring the parameter tree, so the same PartitionSpecs
shard them (FSDP keeps optimizer state fully sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_weights: bool = True


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params, cfg: AdamWConfig):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros,
             "v": jax.tree.map(jnp.zeros_like, zeros),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    ref = state.get("master", params)

    def upd(p_ref, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        p32 = p_ref.astype(jnp.float32)
        p2 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * p32)
        return p2, m2, v2

    flat_ref, treedef = jax.tree.flatten(ref)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(*args) for args in zip(flat_ref, flat_g, flat_m, flat_v)]
    new_ref = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])

    pdtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda p32, dt: p32.astype(dt), new_ref,
                              pdtypes)
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_ref
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    clip_norm: float = 0.0


def sgd_init(params, cfg: SGDConfig):
    return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
            "step": jnp.zeros((), jnp.int32)}


def sgd_update(params, grads, state, cfg: SGDConfig):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    new_mom = jax.tree.map(
        lambda m, g: cfg.momentum * m + g.astype(jnp.float32) * scale,
        state["mom"], grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype),
        params, new_mom)
    return new_params, {"mom": new_mom, "step": state["step"] + 1}, {
        "grad_norm": gnorm}
