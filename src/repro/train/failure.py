"""Fault tolerance: preemption handling, restartable loops, skew monitor.

* :class:`PreemptionHandler` — SIGTERM/SIGINT sets a flag; the training
  loop checkpoints and exits cleanly on the next step boundary (the TPU-VM
  maintenance-event pattern).
* :func:`run_restartable` — drives a train step with periodic checkpoints
  and deterministic data fast-forward: our data streams are keyed by
  ``(seed, step)``, so resuming at step k replays the exact batch k would
  have seen (byte-identical restart).
* :class:`StragglerMonitor` — records per-step wall times and flags steps
  slower than ``threshold`` x the trailing median (on real pods this feeds
  the re-shard/evict decision; here it is exercised by tests and the
  example driver).
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import numpy as np

from . import checkpoint as ckpt


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._old = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.times = []
        self.window = window
        self.threshold = threshold
        self.flagged = []

    def record(self, step: int, dt: float):
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        if len(hist) >= 8 and dt > self.threshold * med:
            self.flagged.append((step, dt, med))
            return True
        return False


def run_restartable(step_fn: Callable, make_batch: Callable, state: tuple,
                    *, n_steps: int, ckpt_dir: str, ckpt_every: int = 50,
                    start_step: Optional[int] = None,
                    monitor: Optional[StragglerMonitor] = None,
                    log_every: int = 10, log_fn=print):
    """Drive ``state = step_fn(*state, batch)`` with checkpoint/restart.

    ``state`` is (params, opt_state); ``make_batch(step)`` must be
    deterministic in ``step``.  Returns (state, last_step, preempted).
    """
    params, opt_state = state
    step0 = start_step if start_step is not None else \
        (ckpt.latest_step(ckpt_dir) or 0)
    if step0 and start_step is None:
        (params, opt_state), _ = ckpt.restore(
            ckpt_dir, step0, target_tree=(params, opt_state))
        log_fn(f"[restore] resumed from step {step0}")
    preempted = False
    with PreemptionHandler() as pre:
        for step in range(step0, n_steps):
            t0 = time.time()
            batch = make_batch(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - t0
            if monitor is not None:
                monitor.record(step, dt)
            if log_every and (step % log_every == 0):
                loss = float(metrics.get("loss", float("nan")))
                log_fn(f"[train] step={step} loss={loss:.4f} dt={dt*1e3:.0f}ms")
            if ckpt_every and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, (params, opt_state))
            if pre.requested:
                ckpt.save(ckpt_dir, step + 1, (params, opt_state))
                preempted = True
                log_fn(f"[preempt] checkpointed at step {step + 1}")
                break
    return (params, opt_state), step + 1, preempted
