"""Checkpoint / restore with elastic resharding.

Layout (one directory per step, atomic rename on completion):

    <dir>/step_000123/
        manifest.json      {keypath: {file, shape, dtype}}, step, meta
        <keypath>.npy      one file per pytree leaf

Leaves are written from fully-gathered host copies (single-process
container); the manifest schema carries a ``shards`` field so a multi-host
deployment writes per-host shard files under the same contract.  Restore
rebuilds the pytree from keypaths and ``device_put``s each leaf with the
*target* sharding — which may belong to a different mesh shape than the one
that saved it (elastic restart).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"[{p.idx}]")
        else:
            parts.append(str(p))
    return ".".join(parts)


def save(directory: str, step: int, tree: Any, meta: Optional[dict] = None,
         blocking: bool = True):
    """Write a checkpoint; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    host = []
    for p, x in flat:
        arr = np.asarray(jax.device_get(x))
        dt = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:   # np.save can't round-trip ml_dtypes
            arr = arr.astype(np.float32)
        host.append((_path_str(p), arr, dt))

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "meta": meta or {}, "shards": 1,
                    "leaves": {}}
        for name, arr, dt in host:
            fn = name.replace("/", "_") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": dt}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
        return final
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return final, t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None,
            target_tree: Any = None, shardings: Any = None):
    """Load a checkpoint.

    If ``target_tree`` is given, the loaded leaves are arranged into its
    structure (and dtypes are cast to match); ``shardings`` (a matching
    pytree of jax.sharding.Sharding or None) reshards onto the current mesh
    — this is the elastic-restart path: the checkpoint does not remember
    the old mesh, so any new mesh works.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {}
    for name, info in manifest["leaves"].items():
        by_name[name] = np.load(os.path.join(path, info["file"]))

    if target_tree is None:
        return by_name, manifest

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, ref), sh in zip(flat, shard_flat):
        name = _path_str(p)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_name[name]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = jnp.asarray(arr).astype(ref.dtype)  # jnp handles bf16 casts
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest
