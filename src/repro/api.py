"""Unified solver facade: ``Solver.open(graph, config) .solve(spec)``.

The paper presents *one* method specialized by two heuristics; this
module is the one declarative surface over every engine the repo grew
around it.  A :class:`Solver` session owns what used to be scattered
across call sites — layout building, backend and engine-tier resolution
(single-device vs whole-mesh sharded vs the routed serving plane, picked
by :meth:`repro.core.config.EngineConfig.resolve`), and device
placement — and every query is a declarative :class:`SolveSpec` value
(goal kind + sources + goal parameters + batch shape) that lowers onto
the existing goal machinery.  Every entry point returns one
:class:`SolveResult` (dist / parent / metrics, lazy ``paths()``
reconstruction) instead of the historical mix of tuples and per-layer
result classes.

::

    from repro.api import EngineConfig, SolveSpec, Solver

    solver = Solver.open(graph)                       # defaults
    res = solver.solve(SolveSpec.p2p(src, dst))       # early-exit query
    res.distance(), res.paths()                       # lazy shaping

    cfg = EngineConfig(backend="blocked_pallas", tier="sharded")
    with Solver.open(graph, cfg) as s:                # whole-mesh engine
        dist, parent, metrics = s.solve(SolveSpec.tree([s0, s1, s2]))

Tier contracts (all bitwise-identical where they overlap — asserted by
``tests/test_api.py``):

* ``single`` — the jitted single-device engine; batch specs run one
  fused ``vmap`` computation.
* ``sharded`` — the v1/v2/v3 ``shard_map`` engines over the device
  mesh; batch specs run the ``lax.map`` batch entry point.  Results are
  sliced back to the true vertex count (padding never escapes).
* ``routed`` — the serving plane (registry + router + per-device
  schedulers); results are the finalized per-query answers, i.e. each
  kind's settled-entries contract (tentative values masked) exactly as
  served traffic sees them.

The legacy ``sssp_p2p``/``sssp_bounded``/``sssp_knear`` wrappers remain
as deprecation shims over the same lowering (see ``repro.core.sssp``);
tier-1 CI rejects internal calls to them.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional, Tuple, Union

import numpy as np
import jax

from .core import relax
from .core.config import (ConfigError, EngineConfig, ResolvedEngine,
                          as_resolved)
from .core.graph import BlockedGraph, DeviceGraph, HostGraph
from .core.sssp import GOALS, normalized_metrics, sssp, sssp_batch
from .obs import profiling

__all__ = ["EngineConfig", "ConfigError", "SolveSpec", "SolveResult",
           "Solver"]


def _as_id_tuple(v) -> Tuple[int, ...]:
    return tuple(int(x) for x in v)


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """One declarative shortest-path computation.

    ``kind`` is one of :data:`repro.core.sssp.GOALS` (``tree`` / ``p2p``
    / ``bounded`` / ``knear``); ``sources`` is a vertex id (single
    computation) or a sequence of ids (one fused batch — the result
    gains a leading slot axis).  The goal parameter (``target`` /
    ``bound`` / ``k``) may be a scalar (shared by every slot) or a
    per-source sequence.  Specs are frozen and validate on construction;
    graph-size bounds are checked by the solver before anything traces.
    """

    sources: Union[int, Tuple[int, ...]]
    kind: str = "tree"
    target: Union[int, Tuple[int, ...], None] = None    # p2p
    bound: Union[float, Tuple[float, ...], None] = None  # bounded
    k: Union[int, Tuple[int, ...], None] = None          # knear

    def __post_init__(self):
        if self.kind not in GOALS:
            raise ValueError(f"unknown solve kind {self.kind!r}; expected "
                             f"one of {GOALS}")
        if np.ndim(self.sources) != 0:
            object.__setattr__(self, "sources", _as_id_tuple(self.sources))
            if not self.sources:
                raise ValueError("sources must be non-empty")
        else:
            object.__setattr__(self, "sources", int(self.sources))
        for name, cast in (("target", int), ("bound", float), ("k", int)):
            v = getattr(self, name)
            if v is not None:
                v = (tuple(cast(x) for x in v) if np.ndim(v) != 0
                     else cast(v))
                object.__setattr__(self, name, v)
        need = {"tree": None, "p2p": "target", "bounded": "bound",
                "knear": "k"}[self.kind]
        for name in ("target", "bound", "k"):
            v = getattr(self, name)
            if name != need and v is not None:
                raise ValueError(f"{name} is not a parameter of "
                                 f"{self.kind!r} specs")
        if need is not None and getattr(self, need) is None:
            raise ValueError(f"{self.kind!r} specs require {need}")
        srcs = self.sources if self.batched else (self.sources,)
        if any(s < 0 for s in srcs):
            raise ValueError("vertex ids must be non-negative")
        param = getattr(self, need) if need else None
        if isinstance(param, tuple):
            if not self.batched or len(param) != len(self.sources):
                raise ValueError(
                    f"per-source {need} needs one value per source "
                    f"(got {len(param)} for sources={self.sources!r})")
        if self.kind == "p2p":
            tg = param if isinstance(param, tuple) else (param,)
            if any(t < 0 for t in tg):
                raise ValueError("vertex ids must be non-negative")
        if self.kind == "knear":
            ks = param if isinstance(param, tuple) else (param,)
            if any(x < 1 for x in ks):
                raise ValueError("k must be >= 1")
        if self.kind == "bounded":
            bs = param if isinstance(param, tuple) else (param,)
            if any(b < 0 for b in bs):
                raise ValueError("bound must be >= 0")

    # -- convenience constructors ---------------------------------------

    @classmethod
    def tree(cls, sources) -> "SolveSpec":
        """Full shortest-path tree(s) from ``sources``."""
        return cls(sources=sources, kind="tree")

    @classmethod
    def p2p(cls, sources, target) -> "SolveSpec":
        """Point-to-point: early exit once ``target`` settles."""
        return cls(sources=sources, kind="p2p", target=target)

    @classmethod
    def bounded(cls, sources, bound) -> "SolveSpec":
        """Distance-bounded: every vertex within ``bound``."""
        return cls(sources=sources, kind="bounded", bound=bound)

    @classmethod
    def knear(cls, sources, k) -> "SolveSpec":
        """k-nearest vertices to each source."""
        return cls(sources=sources, kind="knear", k=k)

    # -- lowering helpers -----------------------------------------------

    @property
    def batched(self) -> bool:
        return isinstance(self.sources, tuple)

    @property
    def n_slots(self) -> int:
        return len(self.sources) if self.batched else 1

    @property
    def goal_param(self):
        """The spec's goal parameter, kind-agnostic (None for tree)."""
        return {"tree": None, "p2p": self.target, "bounded": self.bound,
                "knear": self.k}[self.kind]

    def slot_params(self) -> Optional[list]:
        """Per-slot goal parameters (scalar broadcast over the batch)."""
        p = self.goal_param
        if p is None:
            return None
        if isinstance(p, tuple):
            return list(p)
        return [p] * self.n_slots

    def check_bounds(self, n: int) -> None:
        """Reject out-of-range vertex ids against a concrete graph size —
        loudly, host-side: under ``jit`` an o-o-b gather clamps and a
        scatter drops silently, which would return a plausible-looking
        wrong answer."""
        srcs = self.sources if self.batched else (self.sources,)
        bad = [s for s in srcs if not 0 <= s < n]
        if bad:
            raise ValueError(f"source(s) {bad} out of range for graph "
                             f"with n={n}")
        if self.kind == "p2p":
            tg = self.target if isinstance(self.target, tuple) \
                else (self.target,)
            bad = [t for t in tg if not 0 <= t < n]
            if bad:
                raise ValueError(f"target(s) {bad} out of range for graph "
                                 f"with n={n}")


@dataclasses.dataclass
class SolveResult:
    """The one result type every solve path returns.

    ``dist``/``parent`` are ``[N]`` (single spec) or ``[S, N]`` (batch
    spec) arrays; ``metrics`` is the engine's raw
    :class:`~repro.core.sssp.SsspMetrics` counters (scalar or per-slot
    leaves) on the single/sharded tiers and the per-query normalized
    metric dict(s) on the routed tier.  Iterating the result unpacks
    ``(dist, parent, metrics)``, matching the legacy tuple returns, so
    migrated call sites keep their destructuring (``trace`` rides along
    as a named field only).

    ``trace`` is None unless the session's config set ``trace=True``
    (single/sharded tiers): then it is a
    :class:`~repro.obs.trace.SolveTrace` (or one per slot for batch
    specs) of per-round records.

    Shaping is lazy: :meth:`paths`, :meth:`distance`, :meth:`nearest`
    and :meth:`normalized` walk the arrays only when called.
    """

    spec: SolveSpec
    dist: Any
    parent: Any
    metrics: Any
    deg: np.ndarray
    tier: str
    served_by: Optional[Any] = None     # routed: per-slot scheduler names
    trace: Optional[Any] = None         # SolveTrace | list[SolveTrace]

    def __iter__(self):
        return iter((self.dist, self.parent, self.metrics))

    @property
    def batched(self) -> bool:
        return self.spec.batched

    def _slot(self, arr, slot: Optional[int]):
        arr = np.asarray(arr)
        if not self.batched:
            return arr
        if slot is None:
            raise ValueError("batched result: pass slot=")
        return arr[slot]

    def block_until_ready(self) -> "SolveResult":
        jax.block_until_ready(self.dist)
        return self

    # -- lazy shaping ----------------------------------------------------

    def distance(self, target=None, *, slot: Optional[int] = None) -> float:
        """Distance to ``target`` (defaults to a p2p spec's target)."""
        if target is None:
            t = self.spec.target
            if t is None:
                raise ValueError("no target: pass one or use a p2p spec")
            if isinstance(t, tuple):
                if slot is None:
                    raise ValueError("batched result: pass slot=")
                t = t[slot]
            target = t
        return float(self._slot(self.dist, slot)[int(target)])

    def paths(self, targets=None, *, slot: Optional[int] = None):
        """Lazily reconstruct source->target path(s) from ``parent``.

        ``targets`` defaults to a p2p spec's target(s).  Returns one
        vertex-id list (or ``None`` if unreachable); for a batch spec
        with no ``slot``, one list per slot (each slot's own target).
        """
        from .serve.queries import reconstruct_path
        if self.batched and slot is None:
            t = targets if targets is not None else self.spec.target
            if t is None:
                raise ValueError("no targets: pass them or use a p2p spec")
            ts = list(t) if np.ndim(t) != 0 else [t] * self.spec.n_slots
            if len(ts) != self.spec.n_slots:
                raise ValueError(f"{len(ts)} targets for "
                                 f"{self.spec.n_slots} slots")
            return [self.paths(ts[i], slot=i)
                    for i in range(self.spec.n_slots)]
        if targets is None:
            t = self.spec.target
            if t is None:
                raise ValueError("no target: pass one or use a p2p spec")
            targets = t[slot] if isinstance(t, tuple) else t
        src = self.spec.sources[slot] if self.batched else self.spec.sources
        return reconstruct_path(self._slot(self.parent, slot), int(src),
                                int(targets))

    def nearest(self, *, slot: Optional[int] = None) -> list:
        """A knear spec's ``[(vertex, dist)]`` list, ascending."""
        if self.spec.kind != "knear":
            raise ValueError("nearest() needs a knear spec")
        if self.batched and slot is None:
            raise ValueError("batched result: pass slot=")
        k = self.spec.k
        if isinstance(k, tuple):
            k = k[slot]
        d = self._slot(self.dist, slot)
        src = self.spec.sources[slot] if self.batched else self.spec.sources
        finite = np.flatnonzero(np.isfinite(d))
        order = finite[np.argsort(d[finite], kind="stable")]
        order = order[order != int(src)][:int(k)]
        return [(int(v), float(d[v])) for v in order]

    def normalized(self, *, slot: Optional[int] = None) -> dict:
        """Paper §4 normalized metrics for one computation."""
        if isinstance(self.metrics, dict):
            return self.metrics
        if isinstance(self.metrics, list):        # routed batch
            if slot is None:
                raise ValueError("batched result: pass slot=")
            return self.metrics[slot]
        m = self.metrics
        if self.batched:
            if slot is None:
                raise ValueError("batched result: pass slot=")
            m = jax.tree.map(lambda x: np.asarray(x)[slot], m)
        return normalized_metrics(self.deg, self._slot(self.dist, slot), m)


class Solver:
    """One opened solving session over one graph.

    Build with :meth:`open`; the session owns the resolved engine
    (:class:`~repro.core.config.ResolvedEngine`), the device-resident
    graph, and whatever layout/mesh/serving state its tier needs, so
    repeated :meth:`solve` calls amortize every preprocessing step.
    Usable as a context manager (``close`` tears down serving workers;
    single/sharded tiers hold no background state).
    """

    def __init__(self, graph, resolved: ResolvedEngine, *, layout=None,
                 gid: str = "default", tuned=None):
        self.resolved = resolved
        self.config = resolved.config
        self.tier = resolved.tier
        self.gid = gid
        self._tuned = tuned
        self._host = graph
        self.deg = np.asarray(graph.deg)
        self.n = int(self.deg.shape[0])
        self._closed = False
        if self.tier == "single":
            self._open_single(graph, layout)
        elif self.tier == "sharded":
            if layout is not None:
                raise ConfigError("pass prebuilt layouts only to the "
                                  "single tier; the sharded tier builds "
                                  "its per-shard slabs itself")
            self._open_sharded(graph)
        elif self.tier == "routed":
            if layout is not None:
                raise ConfigError("the routed tier builds layouts through "
                                  "its registry; drop layout=")
            self._open_routed(graph)
        else:                                    # pragma: no cover
            raise ConfigError(f"unknown resolved tier {self.tier!r}")

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def open(cls, graph, config: Optional[EngineConfig] = None, *,
             layout=None, gid: str = "default", tuned=None) -> "Solver":
        """Open a solver session on ``graph``.

        ``graph`` is a :class:`~repro.core.graph.HostGraph` or
        :class:`~repro.core.graph.DeviceGraph`; ``config`` an
        :class:`EngineConfig` (default: single-device ``segment_min``).
        ``layout`` optionally reuses a prebuilt single-tier backend
        layout (validated against the config — a mismatched or partial
        layout fails here, not at trace time).

        ``tuned`` is a :class:`~repro.tune.TunedStore` (or a path to
        one): the store's per-``gid`` offline-tuned perf fields
        (``alpha``/``beta``/``policy``/geometry — see
        :data:`repro.tune.TUNED_FIELDS`) are overlaid onto ``config``
        before resolution on the single/sharded tiers, and handed to the
        routed tier's registry for per-graph application.  A missing or
        stale entry (the graph changed since the tune) leaves ``config``
        untouched.
        """
        if not isinstance(graph, (HostGraph, DeviceGraph)):
            raise TypeError(f"expected HostGraph or DeviceGraph, got "
                            f"{type(graph)}")
        if config is None:
            config = EngineConfig()
        if tuned is not None and not hasattr(tuned, "apply"):
            from .tune.store import TunedStore
            tuned = TunedStore(tuned)
        n, m = int(graph.n), int(graph.m)
        resolved = as_resolved(config, n=n, m=m)
        if tuned is not None and resolved.tier != "routed":
            tuned_cfg = tuned.apply(gid, graph, config, n=n, m=m)
            if tuned_cfg != config:
                resolved = as_resolved(tuned_cfg, n=n, m=m)
        return cls(graph, resolved, layout=layout, gid=gid, tuned=tuned)

    def _open_single(self, graph, layout):
        r = self.resolved
        dg = graph.to_device() if isinstance(graph, HostGraph) else graph
        if r.devices is not None:
            dg = jax.device_put(dg, r.resolve_devices()[0])
        self._dg = dg
        self._backend = relax.get_backend(r.backend)
        if layout is not None:
            self._check_layout(layout)
            self._layout = layout
        else:
            with profiling.annotate(f"repro:engine_build:{r.backend}"):
                self._layout = self._backend.prepare(dg, **r.layout_opts())
        self._build_landmarks(dg)

    def _build_landmarks(self, g) -> None:
        """Session-owned ALT artifact: with ``use_alt`` the landmark set
        is built once at open (amortized like the layout) and threaded
        into every p2p solve — without it the engine entry points would
        rebuild the ``[L, N]`` matrix per call."""
        self._landmarks = None
        if self.resolved.use_alt:
            from .core.landmarks import build_landmarks
            with profiling.annotate("repro:landmark_build"):
                self._landmarks = build_landmarks(
                    g, self.resolved.n_landmarks,
                    self.resolved.landmark_strategy)

    def _check_layout(self, layout) -> None:
        """A foreign layout must match the configured backend *and* cover
        the whole graph — a shard slice or an unpadded/mis-sized blocked
        layout would silently drop edges under ``jit``."""
        r = self.resolved
        if r.backend == "blocked_pallas":
            if not isinstance(layout, BlockedGraph):
                raise ConfigError(
                    f"backend 'blocked_pallas' needs a BlockedGraph "
                    f"layout (build_blocked); got {type(layout).__name__}")
            if layout.n != self.n or layout.src_base != 0 \
                    or layout.n_blocks != layout.n_dst_blocks \
                    or layout.n_pad < self.n:
                raise ConfigError(
                    f"blocked layout does not cover this graph: layout "
                    f"n={layout.n} n_pad={layout.n_pad} "
                    f"src_base={layout.src_base} "
                    f"blocks={layout.n_blocks}/{layout.n_dst_blocks} vs "
                    f"graph n={self.n} (shard slices and foreign layouts "
                    f"are rejected before tracing)")
            if r.tile_e is not None and layout.tile_e != r.tile_e:
                raise ConfigError(f"layout tile_e={layout.tile_e} != "
                                  f"config tile_e={r.tile_e}")
            if r.block_v is not None and layout.block_v != r.block_v:
                raise ConfigError(f"layout block_v={layout.block_v} != "
                                  f"config block_v={r.block_v}")
        elif isinstance(layout, BlockedGraph):
            raise ConfigError(f"backend {r.backend!r} cannot consume a "
                              f"BlockedGraph layout")
        else:
            # segment_min's layout IS the edge list: a foreign graph's
            # DeviceGraph would silently answer over the wrong edges
            if not isinstance(layout, DeviceGraph):
                raise ConfigError(
                    f"backend {r.backend!r} layout must be the graph's "
                    f"DeviceGraph edge list; got {type(layout).__name__}")
            # max_w is a cheap fingerprint; compare at the device dtype
            # (f32) — the host value may still be float64
            if (layout.n != self.n or layout.m != int(self._host.m)
                    or np.float32(layout.max_w)
                    != np.float32(self._host.max_w)):
                raise ConfigError(
                    f"layout does not match this graph (layout n={layout.n}"
                    f" m={layout.m} max_w={float(layout.max_w):.6g} vs "
                    f"n={self.n} m={int(self._host.m)} "
                    f"max_w={float(self._host.max_w):.6g})")

    def _open_sharded(self, graph):
        from .core.distributed import shard_blocked, shard_graph
        r = self.resolved
        devs = r.resolve_devices()
        devs = tuple(devs) if devs is not None else tuple(jax.devices())
        self._devices = devs
        self._mesh = jax.sharding.Mesh(np.array(devs), ("graph",))
        with profiling.annotate("repro:engine_build:sharded"):
            self._sg = shard_graph(graph, len(devs))
            self._blocked = None
            if r.shard_backend == "blocked":
                self._blocked = shard_blocked(self._sg, **r.blocked_opts())
        self._build_landmarks(graph)

    def _open_routed(self, graph):
        from .serve.registry import GraphRegistry
        from .serve.router import QueryRouter
        r = self.resolved
        self._registry = GraphRegistry(config=self.config,
                                       tuned=self._tuned)
        self._registry.register(self.gid, graph)
        self._router = QueryRouter(self._registry,
                                   devices=r.resolve_devices(),
                                   config=self.config)
        self._router_started = False      # submit() starts workers lazily

    # ------------------------------------------------------------------
    # solving
    # ------------------------------------------------------------------

    def solve(self, spec: SolveSpec) -> SolveResult:
        """Run one declarative computation; returns a :class:`SolveResult`."""
        if self._closed:
            raise RuntimeError("solver is closed")
        if not isinstance(spec, SolveSpec):
            raise TypeError(f"expected SolveSpec, got {type(spec)}")
        spec.check_bounds(self.n)
        return {"single": self._solve_single,
                "sharded": self._solve_sharded,
                "routed": self._solve_routed}[self.tier](spec)

    def solve_many(self, specs) -> list:
        """Solve several specs — mixed goal kinds welcome — one
        :class:`SolveResult` per input spec, in order.

        One compiled engine serves one goal kind, so the specs are
        grouped into *plan-compatible sub-batches* (the same grouping
        the serving scheduler applies to its queue): all slots of one
        kind fuse into a single batched solve, and each spec's rows are
        sliced back out of its group's result.  The routed tier submits
        every query up front and drains once, letting its schedulers
        form the sub-batches themselves.
        """
        specs = list(specs)
        for spec in specs:
            if not isinstance(spec, SolveSpec):
                raise TypeError(f"expected SolveSpec, got {type(spec)}")
        if self._closed:
            raise RuntimeError("solver is closed")
        for spec in specs:
            spec.check_bounds(self.n)
        if not specs:
            return []
        if self.tier == "routed" or len(specs) == 1:
            # routed: the scheduler already groups plan-compatibly, and
            # submitting everything before the drain lets one step batch
            # across specs; single spec: nothing to group
            return [self.solve(s) for s in specs]
        # group spec indices by goal kind (the plan-compatibility key on
        # one graph), preserving submission order within a group
        groups: dict = {}
        for i, spec in enumerate(specs):
            groups.setdefault(spec.kind, []).append(i)
        solve = {"single": self._solve_single,
                 "sharded": self._solve_sharded}[self.tier]
        results: list = [None] * len(specs)
        for kind, idxs in groups.items():
            srcs: list = []
            params: list = []
            slots: list = []                  # [start, stop) per spec
            for i in idxs:
                s = specs[i]
                start = len(srcs)
                srcs.extend(s.sources if s.batched else (s.sources,))
                p = s.slot_params()
                params.extend(p if p is not None else [])
                slots.append((start, len(srcs)))
            merged = SolveSpec(
                sources=tuple(srcs), kind=kind,
                **({} if kind == "tree" else
                   {{"p2p": "target", "bounded": "bound",
                     "knear": "k"}[kind]: tuple(params)}))
            out = solve(merged)
            for i, (lo, hi) in zip(idxs, slots):
                spec = specs[i]
                sl = (slice(lo, hi) if spec.batched
                      else lo)                # singleton drops the axis
                metrics = jax.tree.map(
                    lambda x: np.asarray(x)[sl], out.metrics)
                trace = None
                if out.trace is not None:
                    trace = (out.trace[lo:hi] if spec.batched
                             else out.trace[lo])
                results[i] = SolveResult(
                    spec=spec, dist=np.asarray(out.dist)[sl],
                    parent=np.asarray(out.parent)[sl],
                    metrics=metrics, deg=self.deg, tier=self.tier,
                    trace=trace)
        return results

    def _goal_args(self, spec: SolveSpec) -> dict:
        if spec.batched:
            return {"goal": spec.kind, "goal_params": spec.slot_params()}
        return {"goal": spec.kind, "goal_param": spec.goal_param}

    def _materialize_trace(self, out):
        """Split an engine return into ``(dist, parent, metrics, trace)``,
        materializing the device trace ring when the config traces."""
        if self.resolved.trace_cap > 0:
            from .obs import materialize_trace
            dist, parent, metrics, buf = out
            return dist, parent, metrics, materialize_trace(buf)
        dist, parent, metrics = out
        return dist, parent, metrics, None

    def _solve_single(self, spec: SolveSpec) -> SolveResult:
        fn = sssp_batch if spec.batched else sssp
        srcs = list(spec.sources) if spec.batched else spec.sources
        out = fn(self._dg, srcs, config=self.resolved, layout=self._layout,
                 landmarks=self._landmarks, **self._goal_args(spec))
        dist, parent, metrics, trace = self._materialize_trace(out)
        return SolveResult(spec=spec, dist=dist, parent=parent,
                           metrics=metrics, deg=self.deg, tier=self.tier,
                           trace=trace)

    def _solve_sharded(self, spec: SolveSpec) -> SolveResult:
        from .core.distributed import (sssp_distributed,
                                       sssp_distributed_batch)
        fn = sssp_distributed_batch if spec.batched else sssp_distributed
        srcs = np.asarray(spec.sources, np.int32) if spec.batched \
            else spec.sources
        out = fn(self._sg, srcs, self._mesh, ("graph",),
                 config=self.resolved, blocked=self._blocked,
                 landmarks=self._landmarks, **self._goal_args(spec))
        dist, parent, metrics, trace = self._materialize_trace(out)
        # padding vertices never escape the facade
        dist = dist[..., :self.n]
        parent = parent[..., :self.n]
        return SolveResult(spec=spec, dist=dist, parent=parent,
                           metrics=metrics, deg=self.deg, tier=self.tier,
                           trace=trace)

    def _solve_routed(self, spec: SolveSpec) -> SolveResult:
        from .serve.queries import Query
        params = spec.slot_params()
        srcs = spec.sources if spec.batched else (spec.sources,)
        futs = []
        for i, s in enumerate(srcs):
            kw = {}
            if spec.kind == "p2p":
                kw["target"] = int(params[i])
            elif spec.kind == "bounded":
                kw["bound"] = float(params[i])
            elif spec.kind == "knear":
                kw["k"] = int(params[i])
            futs.append(self._router.submit(
                Query(gid=self.gid, source=int(s), kind=spec.kind, **kw)))
        self._router.drain()
        results = [f.result(timeout=600) for f in futs]
        if spec.batched:
            dist = np.stack([r.dist for r in results])
            parent = np.stack([r.parent for r in results])
            metrics = [r.metrics for r in results]
            served = [r.served_by for r in results]
        else:
            (r,) = results
            dist, parent, metrics, served = (r.dist, r.parent, r.metrics,
                                             r.served_by)
        return SolveResult(spec=spec, dist=dist, parent=parent,
                           metrics=metrics, deg=self.deg, tier=self.tier,
                           served_by=served)

    # ------------------------------------------------------------------
    # async sessions + streaming deltas (routed tier)
    # ------------------------------------------------------------------

    def submit(self, spec: SolveSpec):
        """Submit a spec asynchronously; returns a
        :class:`concurrent.futures.Future` resolving to the
        :class:`SolveResult`.

        Routed tier only: the first ``submit`` starts the router's
        background workers (one per device plus the mesh scheduler), and
        every slot of the spec is enqueued without a synchronous drain —
        the workers batch and serve them while the caller keeps going.
        Per-slot queries of a batched spec may land in different fused
        batches (even on different devices); the future resolves once
        every slot has.  ``solve()`` remains the synchronous path and
        may be freely mixed with in-flight submissions.
        """
        from concurrent.futures import Future
        from .serve.queries import Query
        if self._closed:
            raise RuntimeError("solver is closed")
        if not isinstance(spec, SolveSpec):
            raise TypeError(f"expected SolveSpec, got {type(spec)}")
        if self.tier != "routed":
            raise ConfigError(
                f"submit() needs the routed tier (async serving plane); "
                f"this session resolved tier={self.tier!r} — open with "
                f"tier='routed' or use solve()")
        spec.check_bounds(self.n)
        if not self._router_started:
            # idempotent: start() on live schedulers is a no-op
            self._router.start()
            self._router_started = True
        params = spec.slot_params()
        srcs = spec.sources if spec.batched else (spec.sources,)
        futs = []
        for i, s in enumerate(srcs):
            kw = {}
            if spec.kind == "p2p":
                kw["target"] = int(params[i])
            elif spec.kind == "bounded":
                kw["bound"] = float(params[i])
            elif spec.kind == "knear":
                kw["k"] = int(params[i])
            futs.append(self._router.submit(
                Query(gid=self.gid, source=int(s), kind=spec.kind, **kw)))
        agg: Future = Future()
        agg.set_running_or_notify_cancel()
        remaining = [len(futs)]
        lock = threading.Lock()

        def _one_done(_f):
            with lock:
                remaining[0] -= 1
                last = remaining[0] == 0
            if agg.done():
                return
            exc = _f.exception()
            if exc is not None:
                agg.set_exception(exc)
                return
            if not last:
                return
            try:
                results = [f.result() for f in futs]
                if spec.batched:
                    dist = np.stack([r.dist for r in results])
                    parent = np.stack([r.parent for r in results])
                    metrics = [r.metrics for r in results]
                    served = [r.served_by for r in results]
                else:
                    (r,) = results
                    dist, parent, metrics, served = (
                        r.dist, r.parent, r.metrics, r.served_by)
                agg.set_result(SolveResult(
                    spec=spec, dist=dist, parent=parent, metrics=metrics,
                    deg=self.deg, tier=self.tier, served_by=served))
            except BaseException as e:      # defensive: never hang agg
                if not agg.done():
                    agg.set_exception(e)

        for f in futs:
            f.add_done_callback(_one_done)
        return agg

    def apply_delta(self, edits) -> dict:
        """Apply an :class:`~repro.delta.EdgeDelta` to the session's graph
        in place (routed tier): delegates to
        :meth:`~repro.serve.registry.GraphRegistry.apply_delta` — cached
        engines get their layouts patched (not rebuilt), placed replicas
        are reused, and queries submitted afterwards serve the patched
        graph.  Single/sharded sessions hold immutable prebuilt state;
        patch those directly with :mod:`repro.delta`
        (``patch_blocked`` / ``patch_sharded`` / ``repair``) or reopen.
        """
        if self._closed:
            raise RuntimeError("solver is closed")
        if self.tier != "routed":
            raise ConfigError(
                f"apply_delta() needs the routed tier; tier={self.tier!r} "
                f"sessions own immutable prebuilt layouts — use "
                f"repro.delta.patch_blocked/patch_sharded/repair, or "
                f"reopen the session on the patched graph")
        report = self._registry.apply_delta(self.gid, edits)
        self._host = report["host"]
        self.deg = np.asarray(report["host"].deg)
        return report

    # ------------------------------------------------------------------
    # lifecycle / introspection
    # ------------------------------------------------------------------

    @property
    def device_graph(self):
        """The single tier's device-resident graph — None elsewhere."""
        return getattr(self, "_dg", None)

    @property
    def landmarks(self):
        """The session's ALT :class:`~repro.core.landmarks.LandmarkSet`
        (``use_alt`` configs, single/sharded tiers) — None otherwise.
        The routed tier's sets live in its registry
        (:meth:`~repro.serve.registry.GraphRegistry.landmark_set`)."""
        return getattr(self, "_landmarks", None)

    @property
    def router(self):
        """The routed tier's :class:`~repro.serve.router.QueryRouter`
        (serving stats, placement, warmup) — None on other tiers."""
        return getattr(self, "_router", None)

    @property
    def registry(self):
        """The routed tier's registry — None on other tiers."""
        return getattr(self, "_registry", None)

    def warmup(self, kinds=("tree",), batch_sizes=None) -> list:
        """Pre-pay builds and jit compiles (routed tier delegates to the
        router; other tiers run one dummy solve per kind)."""
        if self.tier == "routed":
            return self._router.warmup(
                kinds=kinds,
                batch_sizes=batch_sizes or (self.resolved.max_batch,))
        src = int(np.argmax(self.deg))
        rows = []
        for kind in kinds:
            for bs in (batch_sizes or (1,)):
                srcs = [src] * int(bs) if int(bs) > 1 else src
                spec = {"tree": SolveSpec.tree(srcs),
                        "p2p": SolveSpec.p2p(srcs, src),
                        "bounded": SolveSpec.bounded(srcs, 0.0),
                        "knear": SolveSpec.knear(srcs, 1)}[kind]
                self.solve(spec).block_until_ready()
                rows.append({"kind": kind, "batch": int(bs),
                             "tier": self.tier})
        return rows

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        router = getattr(self, "_router", None)
        if router is not None:
            router.stop(cancel_pending=True)

    def __enter__(self) -> "Solver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"Solver(tier={self.tier!r}, "
                f"backend={self.resolved.backend!r}, n={self.n}, "
                f"gid={self.gid!r})")
