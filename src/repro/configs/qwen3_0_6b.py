"""qwen3-0.6b — 28L d_model=1024 16H (GQA kv=8, head_dim=128) d_ff=3072,
vocab=151936, qk_norm [hf:Qwen/Qwen3-8B; hf]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "lm"


def make_config(**kw):
    return LMConfig(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16, n_kv=8,
        head_dim=128, d_ff=3072, vocab=151936, mlp="swiglu", qk_norm=True,
        rope_theta=1e6, tied_embed=True, **kw)


MICROBATCHES = {}


def smoke_config():
    return LMConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        head_dim=16, d_ff=96, vocab=256, mlp="swiglu", qk_norm=True,
        dtype=jnp.float32)
