"""phi4-mini-3.8b — 32L d_model=3072 24H (GQA kv=8, head_dim=128)
d_ff=8192, vocab=200064, RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "lm"


def make_config(**kw):
    return LMConfig(
        name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24,
        n_kv=8, head_dim=128, d_ff=8192, vocab=200064, mlp="swiglu", tied_embed=True, **kw)


MICROBATCHES = {"train_4k": 4}


def smoke_config():
    return LMConfig(
        name="phi4-smoke", n_layers=2, d_model=96, n_heads=6, n_kv=2,
        head_dim=16, d_ff=256, vocab=256, mlp="swiglu", dtype=jnp.float32)
