"""granite-34b — 88L d_model=6144 48H (MQA kv=1, head_dim=128) d_ff=24576,
vocab=49152, 2-matrix GELU MLP (gpt_bigcode lineage) [arXiv:2405.04324; hf].

The deep/wide cell: trains with FSDP + TP + sequence-sharded residual
stream (Megatron-SP) + gradient accumulation."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "lm"


def make_config(**kw):
    kw.setdefault("seq_shard", True)
    return LMConfig(
        name="granite-34b", n_layers=88, d_model=6144, n_heads=48, n_kv=1,
        head_dim=128, d_ff=24576, vocab=49152, mlp="gelu", **kw)


MICROBATCHES = {"train_4k": 8}


def smoke_config():
    return LMConfig(
        name="granite34b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv=1,
        head_dim=16, d_ff=256, vocab=256, mlp="gelu", dtype=jnp.float32)
