"""mind — embed_dim=64 n_interests=4 capsule_iters=3 multi-interest
[arXiv:1904.08030; unverified].  Item table 10^7 x 64 (row-sharded)."""
from repro.models.recsys.mind import MINDConfig

FAMILY = "recsys"

SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}
SKIP_SHAPES = {}


def make_config(**kw):
    return MINDConfig(name="mind", n_items=10_000_000, embed_dim=64,
                      n_interests=4, capsule_iters=3, hist_len=50, **kw)


MICROBATCHES = {"train_batch": 4}


def smoke_config():
    return MINDConfig(name="mind-smoke", n_items=1000, embed_dim=16,
                      n_interests=4, capsule_iters=3, hist_len=10)
