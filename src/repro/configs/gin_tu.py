"""gin-tu — GIN, 5 layers d_hidden=64, sum aggregator, learnable eps
[arXiv:1810.00826; paper]."""
from repro.models.gnn.gin import GINConfig
from .gnn_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "gnn"
MODEL = "gin"


def make_config(d_in=64, n_classes=16, graph_level=False, **kw):
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64, d_in=d_in,
                     n_classes=n_classes, graph_level=graph_level, **kw)


def smoke_config():
    return GINConfig(name="gin-smoke", n_layers=2, d_hidden=16, d_in=8,
                     n_classes=4)
