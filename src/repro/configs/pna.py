"""pna — 4 layers d_hidden=75, aggregators mean-max-min-std, scalers
identity-amplification-attenuation [arXiv:2004.05718; paper]."""
from repro.models.gnn.pna import PNAConfig
from .gnn_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "gnn"
MODEL = "pna"


def make_config(d_in=75, n_classes=16, graph_level=False, **kw):
    return PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=d_in,
                     n_classes=n_classes, graph_level=graph_level, **kw)


def smoke_config():
    return PNAConfig(name="pna-smoke", n_layers=2, d_hidden=12, d_in=8,
                     n_classes=4)
