"""BONUS: qwen3-0.6b with sliding-window attention (window=4096) — the
sub-quadratic variant that makes the long_500k cell lowerable.  Reported
separately from the 40 assigned cells (DESIGN.md §6)."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES  # noqa: F401

FAMILY = "lm"
SKIP_SHAPES = {"train_4k": "bonus arch: long-context cell only",
               "prefill_32k": "bonus arch: long-context cell only",
               "decode_32k": "bonus arch: long-context cell only"}


def make_config(**kw):
    return LMConfig(
        name="qwen3-0.6b-swa", n_layers=28, d_model=1024, n_heads=16,
        n_kv=8, head_dim=128, d_ff=3072, vocab=151936, mlp="swiglu",
        qk_norm=True, rope_theta=1e6, attn_window=4096,
        tied_embed=True, **kw)


MICROBATCHES = {}


def smoke_config():
    return LMConfig(
        name="qwen3-swa-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        head_dim=16, d_ff=96, vocab=256, mlp="swiglu", qk_norm=True,
        attn_window=8, dtype=jnp.float32)
