"""granite-moe-3b-a800m — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

40 experts don't divide the 16-wide model axis -> expert-TP fallback
(d_ff sharded inside each expert; see parallel/sharding.py)."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "lm"


def make_config(**kw):
    return LMConfig(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv=8, head_dim=64, d_ff=512, vocab=49155, mlp="swiglu",
        moe=True, n_experts=40, top_k=8, n_shared=0, **kw)


MICROBATCHES = {"train_4k": 16}
PREFILL_CHUNKS = {"prefill_32k": 8}


def smoke_config():
    return LMConfig(
        name="granite-moe-smoke", n_layers=2, d_model=48, n_heads=4,
        n_kv=2, head_dim=12, d_ff=32, vocab=255, mlp="swiglu",
        moe=True, n_experts=5, top_k=3, n_shared=0, dtype=jnp.float32)
