"""Architecture registry: one module per assigned architecture.

Every module exposes
  * ``FAMILY``      — "lm" | "gnn" | "recsys"
  * ``make_config(shape=None)``  — the full assigned configuration
  * ``SHAPES``      — the architecture's own input-shape set
  * ``smoke_config()`` — reduced same-family config for CPU smoke tests
Plus (via repro.launch.cells) per-(arch x shape) input specs.
"""
from __future__ import annotations

import importlib

ARCHS = [
    # LM family (5)
    "deepseek-moe-16b",
    "granite-moe-3b-a800m",
    "qwen3-0.6b",
    "phi4-mini-3.8b",
    "granite-34b",
    # GNN (4)
    "dimenet",
    "gatedgcn",
    "pna",
    "gin-tu",
    # recsys (1)
    "mind",
]

BONUS_ARCHS = ["qwen3-0.6b-swa"]  # sub-quadratic variant for long_500k


def _modname(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get(arch: str):
    return importlib.import_module(_modname(arch))


def all_cells(include_bonus: bool = False):
    """Yield every assigned (arch, shape) cell (skips noted in SKIPPED)."""
    for arch in ARCHS + (BONUS_ARCHS if include_bonus else []):
        mod = get(arch)
        for shape in mod.SHAPES:
            if shape in getattr(mod, "SKIP_SHAPES", {}):
                continue
            yield arch, shape


SKIPPED = {
    # long_500k needs sub-quadratic attention; all five assigned LM archs
    # are full (GQA) attention -> skipped per the assignment instructions
    # (see DESIGN.md §6).  The bonus qwen3-0.6b-swa config runs the cell.
    ("deepseek-moe-16b", "long_500k"): "full attention",
    ("granite-moe-3b-a800m", "long_500k"): "full attention",
    ("qwen3-0.6b", "long_500k"): "full attention",
    ("phi4-mini-3.8b", "long_500k"): "full attention",
    ("granite-34b", "long_500k"): "full attention",
}
