"""Shared GNN shape set (assigned to all 4 GNN archs).

Per-shape graph dimensions; ``n_edges_directed`` counts the symmetrized
store.  ``triplet_cap`` bounds DimeNet triplets per edge (documented
adaptation: hub vertices on power-law graphs would otherwise explode the
quadratic gather; EXPERIMENTS.md reports the cap per cell)."""

SHAPES = {
    "full_graph_sm": {   # Cora-like full batch
        "kind": "train", "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
        "n_classes": 7, "triplet_cap": 8,
    },
    "minibatch_lg": {    # Reddit-like sampled training (fanout 15-10)
        "kind": "train_sampled", "n_nodes": 232965, "n_edges": 114615892,
        "d_feat": 602, "n_classes": 41, "batch_nodes": 1024,
        "fanout": (15, 10), "triplet_cap": 2, "dimenet_chunks": 4,
        # static padded subgraph sizes (seeds + 15 + 15*10 per seed)
        "sub_nodes": 181248, "sub_edges": 184320,
    },
    "ogb_products": {    # full-batch large
        "kind": "train", "n_nodes": 2449029, "n_edges": 61859140,
        "d_feat": 100, "n_classes": 47, "triplet_cap": 2, "dimenet_chunks": 64,
    },
    "molecule": {        # batched small graphs, graph-level regression
        "kind": "train_graphs", "n_nodes": 30, "n_edges": 64, "batch": 128,
        "d_feat": 16, "n_classes": 1, "triplet_cap": 8,
    },
}

SKIP_SHAPES = {}
