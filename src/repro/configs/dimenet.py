"""dimenet — n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6
[arXiv:2003.03123; unverified].

Non-geometric shapes (Cora/products/Reddit) consume synthesized 3D node
positions (DESIGN.md §6) — the triplet-gather kernel regime is identical."""
from repro.models.gnn.dimenet import DimeNetConfig
from .gnn_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "gnn"
MODEL = "dimenet"


def make_config(d_in=0, n_classes=1, graph_level=True, **kw):
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6,
                         d_in=d_in, n_out=n_classes,
                         graph_level=graph_level, **kw)


def smoke_config():
    return DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=2, n_spherical=3, n_radial=2, d_in=8,
                         n_out=1)
