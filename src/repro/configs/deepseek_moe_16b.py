"""deepseek-moe-16b — 28L d_model=2048 16H (MHA kv=16) d_ff=1408/expert,
vocab=102400, MoE: 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf]."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from .lm_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "lm"


def make_config(**kw):
    return LMConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv=16, head_dim=128, d_ff=1408, vocab=102400, mlp="swiglu",
        moe=True, n_experts=64, top_k=6, n_shared=2, **kw)


MICROBATCHES = {"train_4k": 16}
PREFILL_CHUNKS = {"prefill_32k": 8}


def smoke_config():
    return LMConfig(
        name="deepseek-moe-16b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv=4, head_dim=16, d_ff=32, vocab=256, mlp="swiglu",
        moe=True, n_experts=8, top_k=6, n_shared=2, dtype=jnp.float32)
