"""gatedgcn — 16 layers d_hidden=70, gated aggregator
[arXiv:2003.00982; paper]."""
from repro.models.gnn.gatedgcn import GatedGCNConfig
from .gnn_common import SHAPES, SKIP_SHAPES  # noqa: F401

FAMILY = "gnn"
MODEL = "gatedgcn"


def make_config(d_in=70, n_classes=16, graph_level=False, **kw):
    return GatedGCNConfig(name="gatedgcn", n_layers=16, d_hidden=70,
                          d_in=d_in, n_classes=n_classes,
                          graph_level=graph_level, **kw)


def smoke_config():
    return GatedGCNConfig(name="gatedgcn-smoke", n_layers=2, d_hidden=12,
                          d_in=8, n_classes=4)
