"""Shared LM shape set + spec builders (assigned to all 5 LM archs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "cache": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "cache": 524288, "batch": 1},
}

SKIP_SHAPES = {"long_500k": "full attention (see DESIGN.md §6)"}


def token_struct(batch: int, seq: int, sharding=None):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32, sharding=sharding)
