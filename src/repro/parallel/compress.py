"""Gradient compression for cross-pod data parallelism.

int8 uniform quantization with error feedback (EF-SGD style): each shard
quantizes its local gradient to int8 + per-tensor scale, all-reduces the
int8 payload (8x less ICI traffic on the slow pod-to-pod links), and keeps
the quantization residual locally, adding it back into the next step's
gradient — provably converging for smooth objectives.

Used inside a ``shard_map`` over the DP axes; exposed both as a pure pair
(:func:`quantize` / :func:`dequantize`) and as :func:`compressed_psum`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, bits: int = 8):
    """Symmetric per-tensor quantization -> (int8 payload, scale)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grad, axis_name, error=None, bits: int = 8):
    """EF-compressed all-reduce of one gradient tensor inside shard_map.

    Returns (mean_grad, new_error).
    """
    g = grad.astype(jnp.float32)
    if error is not None:
        g = g + error
    q, scale = quantize(g, bits)
    new_error = g - dequantize(q, scale)
    # int8 payload all-reduce (summed in int32 to avoid overflow), one
    # fp32 scalar psum for the scales
    total = jax.lax.psum(q.astype(jnp.int32) * 0 + q.astype(jnp.int32),
                         axis_name)
    sum_scale = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each shard contributed ~q*scale; approximate sum with mean scale
    mean_scale = sum_scale / n
    return total.astype(jnp.float32) * mean_scale / n, new_error


def compressed_tree_psum(grads, axis_name, errors=None, bits: int = 8):
    """Tree version; errors pytree matches grads (or None)."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                              grads)
    outs = jax.tree.map(
        lambda g, e: compressed_psum(g, axis_name, e, bits), grads, errors)
    mean = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda o: o[1], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    return mean, errs
