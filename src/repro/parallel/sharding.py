"""Sharding rules: parameter / batch / activation PartitionSpecs per family.

Axis conventions (launch/mesh.py):
  single-pod mesh (16, 16)  -> ("data", "model")
  multi-pod  mesh (2,16,16) -> ("pod", "data", "model")

DP = batch over ("pod","data"); TP = heads/ffn/vocab over "model";
FSDP = parameter d_model dims over "data"; EP = experts over "model"
(falling back to expert-TP when n_experts doesn't divide the axis, e.g.
granite-moe's 40 experts on a 16-wide axis); SP = optional residual-stream
sequence sharding over "model" (Megatron-SP) for the deep 34B config.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

from ..models.transformer import LMConfig


def dp_axes(mesh) -> tuple:
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def _div(n: int, k: int) -> bool:
    return n % k == 0


def lm_param_specs(cfg: LMConfig, mesh, fsdp: bool = True):
    """PartitionSpec tree matching ``transformer.init_params`` output."""
    model = "model" if "model" in mesh.axis_names else None
    msz = mesh.shape.get("model", 1)
    data = "data" if fsdp and "data" in mesh.axis_names else None
    dsz = mesh.shape.get("data", 1) if data else 1
    d_ok = _div(cfg.d_model, max(dsz, 1))
    dshard = data if d_ok else None

    def tp(dim_model_sz: int):
        return model if _div(dim_model_sz, msz) else None

    hd_all = cfg.n_heads * cfg.hd
    kv_all = cfg.n_kv * cfg.hd
    layer = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, dshard, tp(hd_all)),
        "wk": P(None, dshard, tp(kv_all)),
        "wv": P(None, dshard, tp(kv_all)),
        "wo": P(None, tp(hd_all), dshard),
    }
    if cfg.qk_norm:
        layer["q_norm"] = P(None, None)
        layer["k_norm"] = P(None, None)
    if cfg.moe:
        ep = _div(cfg.n_experts, msz)          # expert-parallel possible?
        if ep:
            layer["router"] = P(None, None, None)
            layer["e_up"] = P(None, model, dshard, None)
            layer["e_down"] = P(None, model, None, dshard)
            if cfg.mlp == "swiglu":
                layer["e_gate"] = P(None, model, dshard, None)
        else:                                   # expert-TP fallback
            layer["router"] = P(None, None, None)
            layer["e_up"] = P(None, None, dshard, tp(cfg.d_ff))
            layer["e_down"] = P(None, None, tp(cfg.d_ff), dshard)
            if cfg.mlp == "swiglu":
                layer["e_gate"] = P(None, None, dshard, tp(cfg.d_ff))
        if cfg.n_shared:
            fs = cfg.d_ff * cfg.n_shared
            layer["s_up"] = P(None, dshard, tp(fs))
            layer["s_down"] = P(None, tp(fs), dshard)
            if cfg.mlp == "swiglu":
                layer["s_gate"] = P(None, dshard, tp(fs))
    else:
        layer["w_up"] = P(None, dshard, tp(cfg.d_ff))
        layer["w_down"] = P(None, tp(cfg.d_ff), dshard)
        if cfg.mlp == "swiglu":
            layer["w_gate"] = P(None, dshard, tp(cfg.d_ff))

    out = {
        "embed": P(tp(cfg.vocab), dshard),
        "layers": layer,
        "ln_f": P(None),
    }
    if not cfg.tied_embed:
        out["lm_head"] = P(dshard, tp(cfg.vocab))
    return out


def lm_batch_specs(mesh):
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None)}


def lm_act_spec(cfg: LMConfig, mesh) -> Optional[P]:
    dp = dp_axes(mesh)
    if cfg.seq_shard and "model" in mesh.axis_names:
        return P(dp, "model", None)
    return P(dp, None, None)


def lm_cache_specs(cfg: LMConfig, mesh, shard_seq: bool = False,
                   batch: int = 0):
    """KV cache [L, B, S, KV, HD].  ``batch``: guard divisibility (0=skip)."""
    dp = dp_axes(mesh)
    if batch:
        dsz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        if batch % max(dsz, 1) != 0:
            dp = None
    seq = "model" if shard_seq and "model" in mesh.axis_names else None
    kv = None
    if not shard_seq and _div(cfg.n_kv, mesh.shape.get("model", 1)):
        kv = "model"
    return {"k": P(None, dp, seq, kv, None),
            "v": P(None, dp, seq, kv, None),
            "pos": P(dp)}


def opt_state_specs(param_specs: dict) -> dict:
    """AdamW state mirrors param sharding (m, v, master)."""
    return {"m": param_specs, "v": param_specs, "step": P(),
            "master": param_specs}


def tree_shardings(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# --- GNN -------------------------------------------------------------------

def gnn_full_graph_specs(mesh):
    """Full-batch node/edge arrays sharded over every mesh axis."""
    flat = tuple(n for n in mesh.axis_names)
    return {
        "node_feat": P(flat, None), "senders": P(flat), "receivers": P(flat),
        "labels": P(flat), "pos": P(flat, None),
        "triplet": P(flat),
    }


# --- recsys ----------------------------------------------------------------

def mind_param_specs(mesh):
    model = "model" if "model" in mesh.axis_names else None
    return {"item_embed": P(model, None), "s_map": P(None, None)}


def mind_batch_specs(mesh):
    dp = dp_axes(mesh)
    return {"hist": P(dp, None), "hist_mask": P(dp, None), "target": P(dp)}
