"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 [--smoke] [--mesh 1x1] [--ckpt-dir ...]

``--smoke`` uses the arch's reduced config (CPU-runnable); the full config
requires the production mesh (see repro.launch.dryrun for the compile-only
path on this container).  The loop is restartable: it checkpoints every
``--ckpt-every`` steps, resumes from the latest checkpoint, handles
SIGTERM (preemption) by checkpointing, and fast-forwards the deterministic
data stream.
"""
from __future__ import annotations

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import LMTokenStream, RecsysStream
from repro.models import transformer
from repro.train import failure, loop as train_loop, optimizer as opt_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    mod = configs.get(args.arch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(
        prefix=f"{args.arch}_ckpt_")
    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=args.steps)

    if mod.FAMILY == "lm":
        cfg = mod.smoke_config()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.adamw_init(params, opt_cfg)
        step = jax.jit(train_loop.make_lm_train_step(cfg, opt_cfg),
                       donate_argnums=(0, 1))
        stream = LMTokenStream(cfg.vocab, seed=0)

        def make_batch(i):
            return {"tokens": jnp.asarray(stream.batch(i, args.batch,
                                                       args.seq))}
    elif mod.FAMILY == "recsys":
        from repro.models.recsys import mind as mind_mod
        cfg = mod.smoke_config()
        params = mind_mod.init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                      total_steps=args.steps,
                                      master_weights=False)
        opt_state = opt_mod.adamw_init(params, opt_cfg)
        step = jax.jit(train_loop.make_mind_train_step(cfg, opt_cfg),
                       donate_argnums=(0, 1))
        stream = RecsysStream(cfg.n_items, cfg.hist_len, seed=0)

        def make_batch(i):
            return {k: jnp.asarray(v)
                    for k, v in stream.batch(i, args.batch).items()}
    else:
        raise SystemExit("use examples/gnn_sssp_features.py for GNN training")

    monitor = failure.StragglerMonitor()
    (_, _), last, pre = failure.run_restartable(
        step, make_batch, (params, opt_state), n_steps=args.steps,
        ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every, monitor=monitor)
    print(f"done: step={last} preempted={pre} ckpt={ckpt_dir}")


if __name__ == "__main__":
    main()
