"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --sssp --mesh both

Artifacts (memory analysis, cost analysis, collective-byte breakdown) are
written to benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json and
reused by benchmarks/roofline.py.  Completed cells are skipped on re-runs
unless --force.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; this
# must run before ANY other import, since jax locks the device count on
# first init.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import numpy as np   # noqa: E402
import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                       # noqa: E402
from repro.launch import cells, hlo_stats       # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {"available": False}
    out = {"available": True}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _arg_bytes_per_device(args, n_dev):
    """Analytic per-device argument bytes from struct shardings."""
    total = 0
    for leaf in jax.tree.leaves(args):
        size = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        sh = getattr(leaf, "sharding", None)
        if sh is not None and hasattr(sh, "num_devices"):
            shard = sh.shard_shape(leaf.shape)
            size = int(np.prod(shard)) * leaf.dtype.itemsize
        total += size
    return total


def run_cell(arch: str, shape: str, mesh_kind: str, force: bool = False,
             out_dir: str = ART_DIR):
    os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
    path = os.path.join(out_dir, mesh_kind, f"{arch}__{shape}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            print(f"[skip] {mesh_kind}/{arch}/{shape} (cached)")
            return art
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    art = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "ok": False}
    try:
        fn, args, meta, out_sh = cells.build_cell(arch, shape, mesh)
        art["meta"] = {k: (int(v) if isinstance(v, (int, np.integer))
                           else v) for k, v in meta.items()}
        jitted = jax.jit(fn) if out_sh is None else \
            jax.jit(fn, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        art["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and not k.startswith("utilization")}
        art["memory"] = _mem_dict(compiled)
        n_dev = int(np.prod(list(mesh.shape.values())))
        art["arg_bytes_per_device"] = _arg_bytes_per_device(args, n_dev)
        hlo = compiled.as_text()
        art["collectives"] = hlo_stats.collective_bytes(hlo)
        art["n_while_loops"] = hlo_stats.while_trip_note(hlo)
        art["timing"] = {"lower_s": round(t_lower, 1),
                         "compile_s": round(t_compile, 1)}
        art["ok"] = True
        print(f"[ok] {mesh_kind}/{arch}/{shape}: "
              f"flops/dev={art['cost'].get('flops', 0):.3e} "
              f"coll={art['collectives']['total']/1e9:.3f}GB "
              f"mem(temp)={art['memory'].get('temp_size_in_bytes', -1)/1e9:.2f}GB "
              f"compile={t_compile:.0f}s")
        print(f"     memory_analysis: {art['memory']}")
        print(f"     cost_analysis: flops={art['cost'].get('flops')} "
              f"bytes={art['cost'].get('bytes accessed')}")
    except Exception as e:  # noqa: BLE001 - record failures in the artifact
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {mesh_kind}/{arch}/{shape}: {art['error']}")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def run_sssp(mesh_kind: str, scale: int = 26, edge_factor: int = 16,
             version: str = "v2", force: bool = False,
             out_dir: str = ART_DIR):
    """Dry-run the distributed SSSP engine on a Graph500-scale struct."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import distributed as dist
    from repro.core import stepping
    from repro.core.graph import RATIO_NUM

    os.makedirs(os.path.join(out_dir, mesh_kind), exist_ok=True)
    name = f"sssp-{version}-gr{scale}_{edge_factor}"
    path = os.path.join(out_dir, mesh_kind, f"{name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            art = json.load(f)
        if art.get("ok"):
            print(f"[skip] {mesh_kind}/{name} (cached)")
            return art
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axes = tuple(mesh.axis_names)
    p = int(np.prod(list(mesh.shape.values())))
    n = 1 << scale
    m = 2 * edge_factor * n
    block = n // p
    e_max = m // p
    art = {"arch": name, "shape": f"n=2^{scale},ef={edge_factor}",
           "mesh": mesh_kind, "ok": False}
    t0 = time.time()
    try:
        def S(shape, dt, spec):
            return jax.ShapeDtypeStruct(shape, dt,
                                        sharding=NamedSharding(mesh, spec))
        sg = dist.ShardedGraph(
            src=S((p, e_max), jnp.int32, P(axes)),
            dst=S((p, e_max), jnp.int32, P(axes)),
            w=S((p, e_max), jnp.float32, P(axes)),
            deg=S((p, block), jnp.int32, P(axes)),
            rtow=S((RATIO_NUM,), jnp.float32, P()),
            n_edges2=S((), jnp.int32, P()),
            n_true=S((), jnp.int32, P()))
        src_s = S((), jnp.int32, P())
        gp_s = S((), jnp.int32, P())        # "tree" goal parameter
        params = stepping.SteppingParams()
        if version == "v1":
            body = dist._v1_body(n, block, axes, params, 1 << 20)
            out_specs = (P(), P(), P())
        elif version == "v3":
            body = dist._v2_body(n, block, axes, params, 1 << 20, 0,
                                 tuple(mesh.shape[a] for a in axes),
                                 compact_capacity=max(block // 16, 8))
            out_specs = (P(axes), P(axes), P())
        else:
            body = dist._v2_body(n, block, axes, params, 1 << 20, 0,
                                 tuple(mesh.shape[a] for a in axes))
            out_specs = (P(axes), P(axes), P())
        fn = shard_map(body, mesh=mesh,
                       in_specs=(dist.graph_specs(axes), P(), P()),
                       out_specs=out_specs, check_rep=False)
        lowered = jax.jit(fn).lower(sg, src_s, gp_s)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, list):     # older jax: one dict per partition
            cost = cost[0] if cost else {}
        art["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float))}
        art["memory"] = _mem_dict(compiled)
        hlo = compiled.as_text()
        art["collectives"] = hlo_stats.collective_bytes(hlo)
        art["n_while_loops"] = hlo_stats.while_trip_note(hlo)
        art["note"] = ("cost/collectives are per while-iteration x1; "
                       "multiply by measured round counts (benchmarks)")
        art["timing"] = {"total_s": round(time.time() - t0, 1)}
        art["ok"] = True
        print(f"[ok] {mesh_kind}/{name}: coll/iter="
              f"{art['collectives']['total']/1e6:.1f}MB "
              f"t={art['timing']['total_s']}s")
        print(f"     memory_analysis: {art['memory']}")
        print(f"     cost_analysis: flops={art['cost'].get('flops')}")
    except Exception as e:  # noqa: BLE001
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {mesh_kind}/{name}: {art['error']}")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-bonus", action="store_true")
    ap.add_argument("--sssp", action="store_true")
    ap.add_argument("--sssp-version", default="v2")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = []
    if args.sssp:
        for mk in meshes:
            results.append(run_sssp(mk, version=args.sssp_version,
                                    force=args.force))
    elif args.all:
        for mk in meshes:
            for arch, shape in configs.all_cells(
                    include_bonus=args.include_bonus):
                results.append(run_cell(arch, shape, mk, args.force))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required unless --all or --sssp")
        for mk in meshes:
            results.append(run_cell(args.arch, args.shape, mk, args.force))

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n=== dry-run: {n_ok}/{len(results)} cells compiled ===")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
