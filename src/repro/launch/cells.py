"""Per-(architecture x input-shape) cell builders for the dry-run.

``build_cell(arch, shape, mesh)`` returns ``(fn, args, meta)`` where
``args`` are ShapeDtypeStructs with NamedShardings attached — so
``jax.jit(fn).lower(*args)`` compiles the full distributed step without
allocating anything.
"""
from __future__ import annotations

import functools
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..models import transformer
from ..models.gnn import common as gnn_common, dimenet as dimenet_mod
from ..models.gnn import gin as gin_mod, pna as pna_mod
from ..models.gnn import gatedgcn as gatedgcn_mod
from ..models.recsys import mind as mind_mod
from ..parallel import sharding as shr
from ..train import loop as train_loop
from ..train import optimizer as opt_mod

GNN_FWD = {"gin": (gin_mod, gin_mod.forward),
           "pna": (pna_mod, pna_mod.forward),
           "gatedgcn": (gatedgcn_mod, gatedgcn_mod.forward),
           "dimenet": (dimenet_mod, dimenet_mod.forward)}


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype,
                                sharding=NamedSharding(mesh, spec))


def _attach(struct_tree, shard_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, shard_tree)


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def build_cell(arch: str, shape: str, mesh):
    """Returns (fn, arg_structs, meta, out_shardings-or-None)."""
    mod = configs.get(arch)
    if mod.FAMILY == "lm":
        return _lm_cell(mod, shape, mesh)
    if mod.FAMILY == "gnn":
        return _gnn_cell(mod, shape, mesh)
    if mod.FAMILY == "recsys":
        return _mind_cell(mod, shape, mesh)
    raise ValueError(mod.FAMILY)


# --- LM ---------------------------------------------------------------------

def _lm_cell(mod, shape_name: str, mesh):
    cfg = mod.make_config()
    sh = mod.SHAPES[shape_name]
    dp = shr.dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    msz = mesh.shape.get("model", 1)
    vshard = "model" if cfg.vocab % msz == 0 else None
    pspecs = shr.lm_param_specs(cfg, mesh)
    pshard = shr.tree_shardings(mesh, pspecs)
    params_s = _attach(
        jax.eval_shape(lambda k: transformer.init_params(cfg, k),
                       jax.random.PRNGKey(0)), pshard)
    act_spec = NamedSharding(mesh, shr.lm_act_spec(cfg, mesh))
    meta = {"arch": cfg.name, "shape": shape_name,
            "params": cfg.param_count(),
            "active_params": _lm_active_params(cfg)}

    if sh["kind"] == "train":
        opt_cfg = opt_mod.AdamWConfig()
        ospecs = shr.opt_state_specs(pspecs)
        oshard = shr.tree_shardings(mesh, ospecs)
        opt_s = _attach(jax.eval_shape(
            functools.partial(opt_mod.adamw_init, cfg=opt_cfg), params_s),
            oshard)
        mb = getattr(mod, "MICROBATCHES", {}).get(shape_name, 1)
        step = train_loop.make_lm_train_step(cfg, opt_cfg, act_spec,
                                             microbatches=mb)
        batch_s = {"tokens": _sds((sh["batch"], sh["seq"]), jnp.int32,
                                  mesh, P(dp, None))}
        meta["microbatches"] = mb
        meta["tokens"] = sh["batch"] * sh["seq"]
        # cost_analysis counts scan/while bodies ONCE; the layer stack and
        # the microbatch accumulator are both scans -> static multiplier
        meta["scan_mult"] = cfg.n_layers * mb
        out_sh = (jax.tree.map(lambda s: s.sharding, params_s),
                  jax.tree.map(lambda s: s.sharding, opt_s), None)
        return step, (params_s, opt_s, batch_s), meta, out_sh

    if sh["kind"] == "prefill":
        chunks = getattr(mod, "PREFILL_CHUNKS", {}).get(shape_name, 1)

        def fn(params, tokens):
            return transformer.prefill(cfg, params, tokens, sh["seq"],
                                       act_spec, batch_chunks=chunks)
        toks = _sds((sh["batch"], sh["seq"]), jnp.int32, mesh, P(dp, None))
        meta["tokens"] = sh["batch"] * sh["seq"]
        meta["prefill_chunks"] = chunks
        meta["scan_mult"] = cfg.n_layers * chunks
        cspecs = shr.lm_cache_specs(cfg, mesh, shard_seq=True)
        out_sh = (shr.tree_shardings(mesh, cspecs),
                  NamedSharding(mesh, P(dp, vshard)))
        return fn, (params_s, toks), meta, out_sh

    if sh["kind"] == "decode":
        cspecs = shr.lm_cache_specs(cfg, mesh, shard_seq=True,
                                    batch=sh["batch"])
        cshard = shr.tree_shardings(mesh, cspecs)
        cache_s = _attach(jax.eval_shape(
            lambda: transformer.init_cache(cfg, sh["batch"], sh["cache"])),
            cshard)

        def fn(params, cache, tok):
            return transformer.decode_step(cfg, params, cache, tok, act_spec)
        bd = dp if sh["batch"] % max(dp_size, 1) == 0 else None
        tok = _sds((sh["batch"],), jnp.int32, mesh, P(bd))
        meta["tokens"] = sh["batch"]
        meta["kv_cache"] = sh["cache"]
        meta["scan_mult"] = cfg.n_layers
        logits_sh = NamedSharding(mesh, P(bd, vshard))
        out_sh = (logits_sh, jax.tree.map(lambda s: s.sharding, cache_s))
        return fn, (params_s, cache_s, tok), meta, out_sh

    raise ValueError(sh["kind"])


def _lm_active_params(cfg: transformer.LMConfig) -> int:
    """Per-token active parameters (MoE: shared + top_k experts)."""
    if not cfg.moe:
        return cfg.param_count()
    d = cfg.d_model
    nmat = 3 if cfg.mlp == "swiglu" else 2
    e_ff = nmat * d * cfg.d_ff
    attn = d * cfg.n_heads * cfg.hd + 2 * d * cfg.n_kv * cfg.hd + \
        cfg.n_heads * cfg.hd * d
    per_layer = attn + (cfg.top_k + cfg.n_shared) * e_ff + d * cfg.n_experts
    return cfg.n_layers * per_layer + 2 * cfg.vocab * d


# --- GNN --------------------------------------------------------------------

def _gnn_cell(mod, shape_name: str, mesh):
    sh = mod.SHAPES[shape_name]
    ndev = int(np.prod(list(mesh.shape.values())))
    flat = tuple(mesh.axis_names)
    model_name = mod.MODEL
    _, fwd = GNN_FWD[model_name]
    graph_level = sh["kind"] == "train_graphs"

    if sh["kind"] == "train_sampled":
        n_nodes, n_edges = sh["sub_nodes"], sh["sub_edges"]
    elif sh["kind"] == "train_graphs":
        n_nodes = sh["n_nodes"] * sh["batch"]
        n_edges = 2 * sh["n_edges"] * sh["batch"]
    else:
        n_nodes, n_edges = sh["n_nodes"], 2 * sh["n_edges"]
    n_pad = _pad_to(n_nodes, ndev)
    e_pad = _pad_to(n_edges, ndev)

    kw = {"remat": sh["kind"] != "train_graphs"}
    if n_nodes >= 1_000_000:
        # million-node full-batch cells compute in bf16 (fp32 loss/stats);
        # halves every gather/reduce buffer — see EXPERIMENTS.md §Perf
        kw["dtype"] = jnp.bfloat16
    if model_name == "dimenet":
        kw["triplet_chunks"] = sh.get("dimenet_chunks", 1)
    cfg = mod.make_config(d_in=sh["d_feat"], n_classes=sh["n_classes"],
                          graph_level=graph_level, **kw)
    params_s = jax.eval_shape(
        lambda k: GNN_FWD[model_name][0].init_params(cfg, k),
        jax.random.PRNGKey(0))
    rep = NamedSharding(mesh, P())
    params_s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rep),
        params_s)
    opt_cfg = opt_mod.AdamWConfig(master_weights=False)
    opt_s = jax.eval_shape(
        functools.partial(opt_mod.adamw_init, cfg=opt_cfg), params_s)
    opt_s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rep),
        opt_s)

    n_graphs = sh.get("batch", 1)
    gb_s = gnn_common.GraphBatch(
        node_feat=_sds((n_pad, sh["d_feat"]), jnp.float32, mesh, P(flat)),
        senders=_sds((e_pad,), jnp.int32, mesh, P(flat)),
        receivers=_sds((e_pad,), jnp.int32, mesh, P(flat)),
        edge_feat=None,
        graph_ids=_sds((n_pad,), jnp.int32, mesh, P(flat)),
        n_graphs=n_graphs,
        labels=_sds((n_graphs,) if graph_level else (n_pad,),
                    jnp.float32 if graph_level else jnp.int32, mesh,
                    P() if graph_level else P(flat)),
        edge_mask=_sds((e_pad,), jnp.bool_, mesh, P(flat)),
        shard_ctx=(mesh, flat),
    )
    if model_name == "dimenet":
        t_pad = _pad_to(e_pad * sh["triplet_cap"],
                        ndev * max(sh.get("dimenet_chunks", 1), 1))
        gb_s = gb_s._replace(
            pos=_sds((n_pad, 3), jnp.float32, mesh, P(flat)),
            triplet_kj=_sds((t_pad,), jnp.int32, mesh, P(flat)),
            triplet_ji=_sds((t_pad,), jnp.int32, mesh, P(flat)),
            triplet_mask=_sds((t_pad,), jnp.bool_, mesh, P(flat)))

    if graph_level:
        step = train_loop.make_gnn_regression_step(fwd, cfg, opt_cfg)
    else:
        step = train_loop.make_gnn_train_step(fwd, cfg, opt_cfg)
    # scan trip products per model: gin/pna scan n_layers-1 (layer0 is
    # unrolled), gatedgcn scans all layers, dimenet scans n_blocks blocks
    # each containing a triplet-chunk scan
    chunks = max(kw.get("triplet_chunks", 1), 1)
    if model_name == "dimenet":
        scan_mult = cfg.n_blocks * chunks
    elif model_name == "gatedgcn":
        scan_mult = cfg.n_layers
    else:
        scan_mult = max(cfg.n_layers - 1, 1)
    meta = {"arch": cfg.name, "shape": shape_name, "nodes": n_pad,
            "edges": e_pad, "scan_mult": scan_mult,
            "params": int(sum(np.prod(s.shape)
                              for s in jax.tree.leaves(params_s)))}
    out_sh = (jax.tree.map(lambda s: s.sharding, params_s),
              jax.tree.map(lambda s: s.sharding, opt_s), None)
    return step, (params_s, opt_s, gb_s), meta, out_sh


# --- recsys (MIND) ----------------------------------------------------------

def _mind_cell(mod, shape_name: str, mesh):
    cfg = mod.make_config()
    sh = mod.SHAPES[shape_name]
    dp = shr.dp_axes(mesh)
    flat = tuple(mesh.axis_names)
    pspecs = shr.mind_param_specs(mesh)
    pshard = shr.tree_shardings(mesh, pspecs)
    params_s = _attach(jax.eval_shape(
        lambda k: mind_mod.init_params(cfg, k), jax.random.PRNGKey(0)),
        pshard)
    meta = {"arch": cfg.name, "shape": shape_name,
            "params": cfg.n_items * cfg.embed_dim + cfg.embed_dim ** 2}

    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def batch_structs(b):
        bd = dp if b % max(dp_size, 1) == 0 else None
        return {"hist": _sds((b, cfg.hist_len), jnp.int32, mesh, P(bd, None)),
                "hist_mask": _sds((b, cfg.hist_len), jnp.bool_, mesh,
                                  P(bd, None)),
                "target": _sds((b,), jnp.int32, mesh, P(bd))}

    if sh["kind"] == "train":
        opt_cfg = opt_mod.AdamWConfig(master_weights=False)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        oshard = shr.tree_shardings(mesh, ospecs)
        opt_s = _attach(jax.eval_shape(
            functools.partial(opt_mod.adamw_init, cfg=opt_cfg), params_s),
            oshard)
        mb = getattr(mod, "MICROBATCHES", {}).get(shape_name, 1)
        step = train_loop.make_mind_train_step(cfg, opt_cfg, microbatches=mb)
        meta["microbatches"] = mb
        meta["scan_mult"] = mb
        out_sh = (jax.tree.map(lambda s: s.sharding, params_s),
                  jax.tree.map(lambda s: s.sharding, opt_s), None)
        return step, (params_s, opt_s, batch_structs(sh["batch"])), meta, out_sh

    if sh["kind"] == "serve":
        def fn(params, batch):
            return mind_mod.serve_interests(cfg, params, batch)
        return fn, (params_s, batch_structs(sh["batch"])), meta, None

    if sh["kind"] == "retrieval":
        def fn(params, batch, cand_ids):
            ints = mind_mod.serve_interests(cfg, params, batch)
            return mind_mod.retrieval_scores(cfg, params, ints[0], cand_ids)
        ndev = int(np.prod(list(mesh.shape.values())))
        n_cand = -(-sh["n_candidates"] // ndev) * ndev  # pad to mesh size
        cand = _sds((n_cand,), jnp.int32, mesh, P(flat))
        return fn, (params_s, batch_structs(sh["batch"]), cand), meta, None

    raise ValueError(sh["kind"])
