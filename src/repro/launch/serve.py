"""Serving launcher: continuous-batching engine over a smoke-size model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 8 --max-new 16

The full-size decode/prefill cells (32k KV, 128-way batch, seq-sharded
cache) are exercised by repro.launch.dryrun; this driver runs the same
serving step functions end-to-end at CPU scale.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro import configs
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    mod = configs.get(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("serving driver supports LM archs")
    cfg = mod.smoke_config()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         s_cache=128, prompt_pad=16)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              rng.integers(4, 32)).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    steps = engine.run()
    dt = time.perf_counter() - t0
    total = args.requests * args.max_new
    print(f"served {args.requests} requests ({total} tokens) in {dt:.1f}s "
          f"over {steps} engine steps "
          f"({total / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
