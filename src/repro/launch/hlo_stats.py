"""Post-SPMD HLO statistics: collective bytes for the roofline's third term.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but NOT collective
traffic — we parse the optimized (per-device) HLO text and sum the operand
sizes of every collective op, bucketed by kind.  Post-optimization HLO
prints operands as bare ``%names``, so we first build a symbol table of
every instruction's result shape, then resolve operand shapes through it.

Two aggregates are reported:
  * ``total``      — plain operand-byte sum (the assignment's definition).
  * ``ring_bytes`` — ring-algorithm bytes-on-link estimate per device
    (all-reduce 2x(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
    permute 1x) — used as a sanity cross-check in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from collections import defaultdict

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# "%name = f32[2816,1433]{1,0} op-name(...)" or tuple results
# tuple results may contain /*index=N*/ comments (with '='), so the tuple
# alternative matches anything without nested parens
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict:
    lines = hlo_text.splitlines()
    shapes: dict[str, str] = {}
    coll_lines = []
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, type_str, op = m.groups()
        shapes[name] = type_str
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS:
            coll_lines.append((base, name, type_str, ln))

    per_op = defaultdict(int)
    counts = defaultdict(int)
    ring = 0.0
    for op, name, type_str, ln in coll_lines:
        # operand names: everything inside the first (...) after the op
        after = ln.split(op + "(", 1)[1]
        depth, buf = 1, []
        for ch in after:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        operand_names = _NAME_RE.findall("".join(buf))
        ob = sum(shape_bytes(shapes.get(nm, "")) for nm in operand_names)
        if ob == 0:  # operands may be constants/params without defs seen
            ob = shape_bytes(type_str)
            if op == "all-gather":
                g = _group_size(ln)
                ob = ob // max(g, 1)
        per_op[op] += ob
        counts[op] += 1
        g = _group_size(ln)
        frac = (g - 1) / g if g > 1 else 0.0
        rb = shape_bytes(type_str)
        if op == "all-reduce":
            ring += 2 * ob * frac
        elif op == "all-gather":
            ring += rb * frac
        elif op in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
            ring += ob * frac
        elif op == "collective-permute":
            ring += ob
    return {"per_op": dict(per_op), "counts": dict(counts),
            "total": int(sum(per_op.values())), "ring_bytes": int(ring)}


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit group list {{0,1,2,...},...}: size of the first group
        return m.group(1).count(",") + 1
    return 1


def while_trip_note(hlo_text: str) -> int:
    """Number of while loops (their bodies are counted once by
    cost_analysis; callers multiply by measured trip counts)."""
    return hlo_text.count(" while(")
