"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanout).

A *real* sampler per the assignment: per-layer uniform neighbor sampling
from a CSR adjacency, producing a block-diagonal computation subgraph with
static shapes (pad + mask).  Used by the ``minibatch_lg`` shape
(batch_nodes=1024, fanout 15-10).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SampledBlock(NamedTuple):
    """One message-passing block: edges from sampled srcs -> seed dsts."""
    senders: np.ndarray    # [E_pad] int32 (index into this block's src set)
    receivers: np.ndarray  # [E_pad] int32 (index into the dst/seed set)
    edge_mask: np.ndarray  # [E_pad] bool
    src_nodes: np.ndarray  # [S_pad] global node id
    dst_nodes: np.ndarray  # [D] global node id (seeds of this layer)
    src_mask: np.ndarray   # [S_pad] bool


class SampledBatch(NamedTuple):
    blocks: tuple           # outermost layer first
    seeds: np.ndarray       # [batch] global ids (training targets)
    input_nodes: np.ndarray  # global ids of the innermost src set


class NeighborSampler:
    def __init__(self, row_ptr: np.ndarray, col: np.ndarray, fanouts,
                 seed: int = 0):
        self.row_ptr = row_ptr
        self.col = col
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int):
        """Uniform with-replacement fanout sampling (standard GraphSAGE)."""
        deg = self.row_ptr[nodes + 1] - self.row_ptr[nodes]
        has = deg > 0
        # sample fanout slots per node; nodes with deg==0 are masked
        offs = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                 (nodes.shape[0], fanout))
        idx = self.row_ptr[nodes][:, None] + offs
        nbrs = self.col[idx]                        # [n, fanout]
        mask = np.broadcast_to(has[:, None], nbrs.shape)
        return nbrs, mask

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        blocks = []
        dst = seeds.astype(np.int64)
        for fanout in self.fanouts:
            nbrs, mask = self._sample_neighbors(dst, fanout)
            flat_src = nbrs.reshape(-1)
            flat_mask = mask.reshape(-1)
            # unique src set (+ keep dst nodes for self loops upstream)
            uniq, inv = np.unique(
                np.concatenate([dst, flat_src]), return_inverse=True)
            dst_local = inv[:dst.shape[0]]
            src_local = inv[dst.shape[0]:]
            receivers = np.repeat(np.arange(dst.shape[0], dtype=np.int64),
                                  fanout)
            blocks.append(SampledBlock(
                senders=src_local.astype(np.int32),
                receivers=receivers.astype(np.int32),
                edge_mask=flat_mask,
                src_nodes=uniq.astype(np.int64),
                dst_nodes=dst,
                src_mask=np.ones(uniq.shape[0], bool),
            ))
            dst = uniq
        return SampledBatch(blocks=tuple(blocks), seeds=seeds,
                            input_nodes=dst)


def flat_subgraph(batch: SampledBatch, pad_nodes: int, pad_edges: int):
    """Collapse sampled blocks into one padded homogeneous subgraph
    (node-reindexed union of all block edges) for single-graph GNN code."""
    nodes = batch.input_nodes
    id_map = {int(g): i for i, g in enumerate(nodes)}
    snd, rcv = [], []
    for blk in batch.blocks:
        s_glob = blk.src_nodes[blk.senders]
        d_glob = blk.dst_nodes[blk.receivers]
        keep = blk.edge_mask
        for sg, dg in zip(s_glob[keep], d_glob[keep]):
            snd.append(id_map[int(sg)])
            rcv.append(id_map[int(dg)])
    n = min(len(nodes), pad_nodes)
    e = min(len(snd), pad_edges)
    senders = np.zeros(pad_edges, np.int32)
    receivers = np.zeros(pad_edges, np.int32)
    emask = np.zeros(pad_edges, bool)
    senders[:e] = snd[:e]
    receivers[:e] = rcv[:e]
    emask[:e] = True
    node_ids = np.zeros(pad_nodes, np.int64)
    node_ids[:n] = nodes[:n]
    nmask = np.zeros(pad_nodes, bool)
    nmask[:n] = True
    return senders, receivers, emask, node_ids, nmask
