"""Zipf-skewed multi-graph query traffic for the serving benchmark.

Real point-to-point traffic (navigation, social-graph lookups) is doubly
skewed: a few *graphs* take most of the load, and within a graph a few
popular *endpoints* (hubs, landmarks) dominate.  Both skews follow a
Zipf law here:

* graph popularity — gid rank ``r`` is drawn with ``P(r) ∝ 1/r^a``;
* endpoint popularity — vertices ranked by degree (hubs first) are drawn
  from the same law, so hot sources/targets are the well-connected ones.

The query-kind mix defaults to point-to-point-dominated (Dong et al.'s
serving observation); full trees are the rare tail.  Bounds for
distance-bounded queries are sampled in units of the graph's maximum
edge weight, k for k-nearest log-uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.queries import Query

__all__ = ["TrafficItem", "zipf_ranks", "make_traffic", "DEFAULT_MIX"]

# serving mix: p2p-dominated, full trees rare
DEFAULT_MIX: Tuple[Tuple[str, float], ...] = (
    ("p2p", 0.55), ("bounded", 0.20), ("knear", 0.15), ("tree", 0.10))


@dataclasses.dataclass(frozen=True)
class TrafficItem:
    """One generated request: the query plus its admission attributes.

    ``arrival_s`` is the item's offset from the start of the stream
    (non-decreasing; 0.0 unless ``make_traffic(..., rate_qps=...)`` draws
    Poisson arrivals) — open-loop load generators sleep until it before
    submitting, closed-loop consumers ignore it."""
    query: Query
    priority: int = 0
    deadline_s: Optional[float] = None
    arrival_s: float = 0.0


def _zipf_probs(n_ranks: int, a: float) -> np.ndarray:
    """Normalized ``P(r) ∝ 1/(r+1)^a`` over ranks [0, n_ranks)."""
    p = 1.0 / np.arange(1, n_ranks + 1, dtype=np.float64) ** a
    return p / p.sum()


def zipf_ranks(rng: np.random.Generator, n_ranks: int, size: int,
               a: float = 1.1) -> np.ndarray:
    """Draw ``size`` ranks in [0, n_ranks) with ``P(r) ∝ 1/(r+1)^a``."""
    return rng.choice(n_ranks, size=size, p=_zipf_probs(n_ranks, a))


def _endpoints(rng, graphs, gids, a):
    """Zipf-by-degree-rank endpoint picker per graph (probability vectors
    precomputed once per gid, not per draw)."""
    rank_of, prob_of = {}, {}
    for gid in gids:
        deg = np.asarray(graphs[gid].deg)
        order = np.argsort(-deg, kind="stable")
        ranks = order[deg[order] > 0]            # degree-ranked, no isolates
        rank_of[gid] = ranks
        prob_of[gid] = _zipf_probs(ranks.size, a)
    def pick(gid):
        return int(rank_of[gid][rng.choice(rank_of[gid].size,
                                           p=prob_of[gid])])
    return pick


def make_traffic(graphs: Dict[str, "HostGraph"], n_queries: int, *,
                 seed: int = 0, zipf_a: float = 1.1,
                 mix: Sequence[Tuple[str, float]] = DEFAULT_MIX,
                 bound_w_scale: Tuple[float, float] = (2.0, 8.0),
                 k_range: Tuple[int, int] = (4, 64),
                 priority_levels: int = 3,
                 deadline_s: Optional[float] = None,
                 rate_qps: Optional[float] = None) -> List[TrafficItem]:
    """Generate a Zipf-skewed query stream over ``graphs``.

    ``graphs`` maps gid -> HostGraph; insertion order is the popularity
    ranking (first = hottest).  ``bound_w_scale`` samples bounded-query
    radii as ``uniform(lo, hi) * max_w``; ``k_range`` bounds k-nearest
    sizes (log-uniform).  Priorities are uniform in
    ``[0, priority_levels)``; ``deadline_s`` (optional) attaches the same
    relative deadline to roughly one query in four.  ``rate_qps`` draws
    Poisson arrival offsets (exponential inter-arrival at that mean
    rate) into ``TrafficItem.arrival_s`` for open-loop replay against
    the router.
    """
    if n_queries < 0:
        raise ValueError("n_queries must be >= 0")
    rng = np.random.default_rng(seed)
    gids = list(graphs)
    kinds, probs = zip(*mix)
    probs = np.asarray(probs, np.float64)
    probs = probs / probs.sum()
    pick_endpoint = _endpoints(rng, graphs, gids, zipf_a)
    g_ranks = zipf_ranks(rng, len(gids), n_queries, zipf_a)
    arrivals = np.zeros(n_queries, np.float64)
    if rate_qps is not None:
        if rate_qps <= 0:
            raise ValueError("rate_qps must be > 0")
        # derived RNG: pacing must not perturb the query stream itself —
        # the same seed replays identical queries with or without arrivals
        arr_rng = np.random.default_rng((seed, 0x9E3779B9))
        arrivals = np.cumsum(arr_rng.exponential(1.0 / rate_qps, n_queries))
    out: List[TrafficItem] = []
    for i in range(n_queries):
        gid = gids[int(g_ranks[i])]
        g = graphs[gid]
        kind = kinds[int(rng.choice(len(kinds), p=probs))]
        source = pick_endpoint(gid)
        kw = {}
        if kind == "p2p":
            kw["target"] = pick_endpoint(gid)
        elif kind == "bounded":
            kw["bound"] = float(rng.uniform(*bound_w_scale) *
                                max(g.max_w, 1e-6))
        elif kind == "knear":
            lo, hi = k_range
            kw["k"] = int(np.exp(rng.uniform(np.log(lo), np.log(hi + 1))))
        out.append(TrafficItem(
            query=Query(gid=gid, source=source, kind=kind, **kw),
            priority=int(rng.integers(0, priority_levels)),
            deadline_s=(deadline_s if deadline_s is not None
                        and rng.random() < 0.25 else None),
            arrival_s=float(arrivals[i])))
    return out
