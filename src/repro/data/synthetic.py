"""Synthetic data pipelines for the LM / GNN / recsys architectures.

Deterministic (seeded) streams with a step -> sample-offset mapping so a
restarted job fast-forwards byte-identically (train/failure.py relies on
this).
"""
from __future__ import annotations

import numpy as np


class LMTokenStream:
    """Synthetic token stream: mixture of Zipf unigrams + repeated n-grams
    (so the loss actually decreases during the example runs)."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        # Zipf-ish unigram distribution
        base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
        toks = (base - 1) % self.vocab
        # inject copy structure: second half repeats the first half shifted
        half = seq // 2
        toks[:, half:half * 2] = toks[:, :half]
        return toks.astype(np.int32)


class RecsysStream:
    """User-behavior batches: Zipf item popularity, hist + target."""

    def __init__(self, n_items: int, hist_len: int, seed: int = 0):
        self.n_items = n_items
        self.hist_len = hist_len
        self.seed = seed

    def batch(self, step: int, batch: int):
        rng = np.random.default_rng((self.seed, step, 7))
        hist = (rng.zipf(1.2, size=(batch, self.hist_len)) - 1) % self.n_items
        lengths = rng.integers(self.hist_len // 2, self.hist_len + 1, batch)
        mask = np.arange(self.hist_len)[None, :] < lengths[:, None]
        target = (rng.zipf(1.2, size=batch) - 1) % self.n_items
        return {
            "hist": hist.astype(np.int32),
            "hist_mask": mask,
            "target": target.astype(np.int32),
        }


def gnn_node_classification(n_nodes: int, n_edges: int, d_feat: int,
                            n_classes: int = 16, seed: int = 0,
                            with_pos: bool = False):
    """Random graph + features/labels (full-batch node classification)."""
    rng = np.random.default_rng(seed)
    snd = rng.integers(0, n_nodes, n_edges)
    rcv = rng.integers(0, n_nodes, n_edges)
    fix = snd == rcv
    rcv = np.where(fix, (rcv + 1) % n_nodes, rcv)
    # symmetrize (message passing both ways like the benchmarks)
    senders = np.concatenate([snd, rcv]).astype(np.int32)
    receivers = np.concatenate([rcv, snd]).astype(np.int32)
    out = {
        "node_feat": rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32),
        "senders": senders,
        "receivers": receivers,
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
    }
    if with_pos:
        out["pos"] = rng.normal(0, 1, (n_nodes, 3)).astype(np.float32)
    return out
