"""Edge-weight variants (paper §4.2, Eqs. 7-8).

    discretize(x, power) = 1 + x * (2^power - 2)        (integerized)
    converge(x, pivot)   = bell curve peaked at `pivot`; half the mass
                           below the pivot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import HostGraph, build_csr


def discretize(w: np.ndarray, power: int) -> np.ndarray:
    """Map (0,1] weights to {1, ..., 2^power - 1} (Eq. 7)."""
    return np.floor(1 + w * (2 ** power - 2)).astype(np.float64)


def converge(w: np.ndarray, pivot: float) -> np.ndarray:
    """Bell-curve remap peaked at `pivot` (Eq. 8)."""
    lo = pivot - pivot * (1 - 2 * w) ** 2
    hi = pivot + (1 - pivot) * (1 - 2 * w) ** 2
    return np.where(w <= 0.5, lo, hi)


def make_variant(g: HostGraph, power: int | None = None,
                 pivot: float | None = None) -> HostGraph:
    """Create a variant graph by remapping edge weights (paper §4.2)."""
    if (power is None) == (pivot is None):
        raise ValueError("exactly one of power/pivot")
    # recover the undirected edge list (first half of the directed store is
    # not contiguous after sorting; rebuild from all directed slots / 2)
    mask = g.src < g.dst
    u, v, w = g.src[mask], g.dst[mask], g.w[mask].astype(np.float64)
    w2 = discretize(w, power) if power is not None else converge(w, pivot)
    return build_csr(g.n, u, v, w2)
