"""Host-side triplet index construction for directional GNNs (DimeNet).

For every directed edge e2 = (j -> i) we enumerate in-edges e1 = (k -> j)
with k != i, capped at ``cap`` per edge (static shapes for jit); padding
triplets are masked.  The same CSR-expansion machinery the SSSP frontier
uses — here run in numpy because it is data preparation, not device work.
"""
from __future__ import annotations

import numpy as np


def build_triplets(senders: np.ndarray, receivers: np.ndarray, cap: int = 8,
                   seed: int = 0):
    """Returns (t_kj, t_ji, mask): edge indices into the edge list."""
    e = senders.shape[0]
    rng = np.random.default_rng(seed)
    order = np.argsort(receivers, kind="stable")   # in-edges grouped by head
    rec_sorted = receivers[order]
    starts = np.searchsorted(rec_sorted, np.arange(0, receivers.max() + 2
                                                   if e else 1))
    t_kj, t_ji = [], []
    for e2 in range(e):
        j = senders[e2]
        i = receivers[e2]
        if j + 1 >= len(starts):
            continue
        in_edges = order[starts[j]:starts[j + 1]]
        in_edges = in_edges[senders[in_edges] != i]
        if in_edges.shape[0] > cap:
            in_edges = rng.choice(in_edges, cap, replace=False)
        t_kj.append(in_edges)
        t_ji.append(np.full(in_edges.shape[0], e2, np.int64))
    if t_kj:
        t_kj = np.concatenate(t_kj)
        t_ji = np.concatenate(t_ji)
    else:
        t_kj = np.zeros(0, np.int64)
        t_ji = np.zeros(0, np.int64)
    # pad to e * cap for static shapes
    t_max = e * cap
    mask = np.zeros(t_max, bool)
    mask[:t_kj.shape[0]] = True
    pad = t_max - t_kj.shape[0]
    t_kj = np.concatenate([t_kj, np.zeros(pad, np.int64)])
    t_ji = np.concatenate([t_ji, np.zeros(pad, np.int64)])
    return t_kj.astype(np.int32), t_ji.astype(np.int32), mask
