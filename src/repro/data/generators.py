"""Graph generators (paper §4.2 datasets, scaled to this container).

* :func:`kronecker` — Graph500-style RMAT/Kronecker generator
  (A=0.57, B=0.19, C=0.19, D=0.05), edge weights uniform in (0, 1].
* :func:`uniform_random` — Urand-style Erdős–Rényi with fixed edge count.
* :func:`road_grid`  — 2D lattice with local weights (Road-like: huge
  diameter, degree <= 4).
* :func:`molecule_batch` — batched small graphs (GNN `molecule` shape).

All generators return undirected edge lists; build with
:func:`repro.core.graph.build_csr`.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import HostGraph, build_csr

RMAT_A, RMAT_B, RMAT_C, RMAT_D = 0.57, 0.19, 0.19, 0.05


def _resample_exact(m: int, draw) -> tuple:
    """Draw (u, v) endpoint batches via ``draw(k)`` until exactly ``m``
    non-self-loop edges accumulate (generators previously under-delivered
    by however many self loops they happened to draw)."""
    us = [np.zeros(0, np.int64)]
    vs = [np.zeros(0, np.int64)]
    have = 0
    while have < m:
        u, v = draw(m - have)
        keep = u != v
        u, v = u[keep], v[keep]
        us.append(u)
        vs.append(v)
        have += u.shape[0]
    return np.concatenate(us)[:m], np.concatenate(vs)[:m]


def _rmat_pairs(rng, m: int, scale: int) -> tuple:
    """One batch of m RMAT endpoint pairs (may contain self loops)."""
    u = np.zeros(m, np.int64)
    v = np.zeros(m, np.int64)
    ab = RMAT_A + RMAT_B
    c_norm = RMAT_C / (RMAT_C + RMAT_D)
    a_norm = RMAT_A / ab
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        u_bit = r1 > ab
        v_bit = np.where(u_bit, r2 > c_norm, r2 > a_norm)
        u |= u_bit.astype(np.int64) << bit
        v |= v_bit.astype(np.int64) << bit
    return u, v


def kronecker(scale: int, edge_factor: int, seed: int = 0,
              weights: str = "uniform") -> HostGraph:
    """Graph500 Kronecker generator: 2^scale vertices, edge_factor*2^scale edges."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    if n < 2 and m > 0:
        raise ValueError("need scale >= 1 to draw non-self-loop edges")
    u, v = _resample_exact(m, lambda k: _rmat_pairs(rng, k, scale))
    # Graph500 permutes vertex labels to break locality
    perm = rng.permutation(n)
    u, v = perm[u], perm[v]
    w = _gen_weights(rng, m, weights)
    return build_csr(n, u, v, w)


def uniform_random(n: int, m: int, seed: int = 0,
                   weights: str = "uniform") -> HostGraph:
    """Urand-style: m undirected edges with uniformly random endpoints."""
    if n < 2 and m > 0:
        raise ValueError("need n >= 2 to draw non-self-loop edges")
    rng = np.random.default_rng(seed)
    u, v = _resample_exact(
        m, lambda k: (rng.integers(0, n, k), rng.integers(0, n, k)))
    w = _gen_weights(rng, m, weights)
    return build_csr(n, u, v, w)


def road_grid(side: int, seed: int = 0, diag: bool = False) -> HostGraph:
    """2D lattice (Road-like: degree <= 4, diameter ~ 2*side)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(side * side).reshape(side, side)
    eu = [idx[:, :-1].ravel(), idx[:-1, :].ravel()]
    ev = [idx[:, 1:].ravel(), idx[1:, :].ravel()]
    if diag:
        eu.append(idx[:-1, :-1].ravel())
        ev.append(idx[1:, 1:].ravel())
    u = np.concatenate(eu)
    v = np.concatenate(ev)
    w = rng.uniform(0.1, 1.0, u.shape[0])  # road weights: narrow band
    return build_csr(side * side, u, v, w)


def molecule_batch(n_nodes: int = 30, n_edges: int = 64, batch: int = 128,
                   seed: int = 0):
    """Batched random small graphs (returns stacked edge lists + node feats).

    Used by the GNN `molecule` shape; returns a dict of numpy arrays shaped
    [batch, ...] plus 3D coordinates for geometric models (DimeNet).
    """
    rng = np.random.default_rng(seed)
    senders = rng.integers(0, n_nodes, (batch, n_edges))
    receivers = rng.integers(0, n_nodes, (batch, n_edges))
    fix = senders == receivers
    receivers = np.where(fix, (receivers + 1) % n_nodes, receivers)
    pos = rng.normal(0, 1, (batch, n_nodes, 3)).astype(np.float32)
    return {
        "senders": senders.astype(np.int32),
        "receivers": receivers.astype(np.int32),
        "pos": pos,
        "node_mask": np.ones((batch, n_nodes), bool),
    }


def _gen_weights(rng, m, kind: str):
    if kind == "uniform":
        # uniform in (0, 1] as Graph500 SSSP specifies
        return 1.0 - rng.random(m)
    if kind == "bimodal":
        # paper §4.2 weight-variant flavor: two narrow bands (a "short
        # hop" mode near 0.1 and a "long hop" mode near 0.9), stressing
        # the RtoW quantile LUT with a strongly non-uniform distribution
        lo = rng.uniform(0.05, 0.15, m)
        hi = rng.uniform(0.85, 1.0, m)
        return np.where(rng.random(m) < 0.5, lo, hi)
    raise ValueError(f"unknown weight kind {kind}")
