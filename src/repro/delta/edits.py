"""Edge-delta descriptions for streaming graph updates.

An :class:`EdgeDelta` is the user-facing batch of edits (adds, removes,
reweights) expressed over *undirected* edges by default, matching
:func:`repro.core.graph.build_csr`'s ``symmetrize=True`` convention.  An
:class:`AppliedDelta` is the patcher's record of what actually changed:
the *directed* edit list with each edit classified against the old
weight (a reweight to the identical value is a no-op), which is exactly
what incremental repair needs to decide invalidation and frontier seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "KIND_ADD", "KIND_REMOVE", "KIND_INCREASE", "KIND_DECREASE",
    "KIND_SAME", "EdgeDelta", "AppliedDelta",
]

# directed edit kinds, recorded per edit in AppliedDelta.kind
KIND_ADD, KIND_REMOVE, KIND_INCREASE, KIND_DECREASE, KIND_SAME = range(5)


def _as_pairs(edges, what):
    arr = np.asarray(list(edges), dtype=np.int64)
    if arr.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(f"{what} must be (u, v) pairs; got shape "
                         f"{arr.shape}")
    return arr[:, 0].copy(), arr[:, 1].copy()


def _as_triples(edges, what):
    rows = list(edges)
    if not rows:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    arr = np.asarray(rows, dtype=np.float64)
    if arr.ndim != 2 or arr.shape[1] != 3:
        raise ValueError(f"{what} must be (u, v, w) triples; got shape "
                         f"{arr.shape}")
    u = arr[:, 0].astype(np.int64)
    v = arr[:, 1].astype(np.int64)
    if not (np.all(arr[:, 0] == u) and np.all(arr[:, 1] == v)):
        raise ValueError(f"{what} vertex ids must be integers")
    w = arr[:, 2].astype(np.float32)
    if not np.all(np.isfinite(w) & (w > 0.0)):
        raise ValueError(f"{what} weights must be positive and finite "
                         "(float32)")
    return u, v, w


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """One batch of edge edits.

    ``add``/``reweight`` are ``(u, v, w)`` triples, ``remove`` is
    ``(u, v)`` pairs.  With ``symmetrize=True`` (the default, matching
    ``build_csr``) each edit applies to both stored directions.  Weights
    are validated positive finite and held as float32 — the graph's
    native weight dtype — so an identical-value reweight is detected
    exactly.
    """
    add: tuple = ()
    remove: tuple = ()
    reweight: tuple = ()
    symmetrize: bool = True

    def __post_init__(self):
        au, av, aw = _as_triples(self.add, "add")
        ru, rv = _as_pairs(self.remove, "remove")
        wu, wv, ww = _as_triples(self.reweight, "reweight")
        object.__setattr__(self, "add", (au, av, aw))
        object.__setattr__(self, "remove", (ru, rv))
        object.__setattr__(self, "reweight", (wu, wv, ww))

    @property
    def n_edits(self) -> int:
        """Number of *undirected* edits in the batch."""
        return (self.add[0].size + self.remove[0].size
                + self.reweight[0].size)

    def __bool__(self) -> bool:
        return self.n_edits > 0


@dataclasses.dataclass(frozen=True)
class AppliedDelta:
    """Directed record of an applied delta (the patcher's receipt).

    ``(src[i], dst[i], kind[i])`` is one directed edit as it landed in
    the CSR; with ``symmetrize=True`` each undirected edit contributes
    two entries.  ``kind`` classifies reweights against the old stored
    weight, so repair can take the decrease-only fast path
    (``decrease_only``: no removals, no increases — every old shortest
    path is still valid) and serving can keep stale ALT landmarks
    (``safe_stale``: no adds, no decreases — old landmark distances stay
    admissible lower bounds).
    """
    src: np.ndarray
    dst: np.ndarray
    kind: np.ndarray

    @property
    def n_edits(self) -> int:
        """Number of *directed* edits (KIND_SAME no-ops included)."""
        return int(self.src.size)

    @property
    def decrease_only(self) -> bool:
        return not np.any((self.kind == KIND_REMOVE)
                          | (self.kind == KIND_INCREASE))

    @property
    def safe_stale(self) -> bool:
        return not np.any((self.kind == KIND_ADD)
                          | (self.kind == KIND_DECREASE))
