"""Streaming graph updates: edge deltas, in-place layout patching, and
incremental SSSP repair.

The cost model: a small edit batch should cost its blast radius, not a
full rebuild + recompute.  ``EdgeDelta`` describes the batch;
``patch_host`` / ``patch_blocked`` / ``patch_sharded`` patch each layout
in place (bitwise-equal to a from-scratch rebuild); ``repair_state`` +
``repair`` (or ``repro.core.distributed.repair_distributed``) re-relax
only from the vertices the delta touches, bitwise-identical to a
from-scratch solve.  ``GraphRegistry.apply_delta`` drives all of it for
served graphs.
"""
from .edits import (AppliedDelta, EdgeDelta, KIND_ADD, KIND_DECREASE,
                    KIND_INCREASE, KIND_REMOVE, KIND_SAME)
from .patch import (patch_blocked, patch_blocked_with, patch_host,
                    patch_sharded, patch_sharded_with)
from .repair import RepairStats, repair, repair_state

__all__ = [
    "AppliedDelta", "EdgeDelta",
    "KIND_ADD", "KIND_DECREASE", "KIND_INCREASE", "KIND_REMOVE",
    "KIND_SAME",
    "patch_blocked", "patch_blocked_with", "patch_host", "patch_sharded",
    "patch_sharded_with",
    "RepairStats", "repair", "repair_state",
]
