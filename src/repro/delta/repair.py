"""Incremental SSSP repair after an edge delta.

The repaired state is *bitwise-identical* to a from-scratch solve on
the patched graph.  Why this holds: the engines' relaxation is a
monotone fixpoint iteration — from any valid upper-bound state (every
finite tentative dist is the rounded float32 length of some real path,
and the true fixpoint is everywhere ≤ the tentative value), re-relaxing
to fixpoint yields ``min`` over all paths of the rounded left-fold sum,
independent of schedule.  Repair constructs exactly such a state:

- **decrease-only deltas** (adds + weight decreases): every old
  shortest path still exists, so the old dist/parent are already a
  valid upper bound; the frontier re-seeds from the edited edges'
  sources and only improvements propagate.
- **removals / increases**: old entries that routed through an edited
  edge may be *under*-estimates.  Every vertex whose tree parent edge
  was removed/increased is invalidated, the invalidation propagates to
  the whole downstream subtree (pointer jumping over parent chains),
  and invalid entries reset to ``(+inf, -1)`` — the remaining finite
  entries are exact, hence a valid upper bound.  The frontier re-seeds
  from the (new-graph) in-neighbors of the invalid region plus the
  gain-edit sources.  Removing or increasing a non-tree edge
  (``parent[v] != u``) invalidates nothing: it is a provable no-op.

Parent bitwise parity additionally relies on the argmin winner being
unique (no exact float32 path-length ties), which holds for
generic random weights; both sides use the same relaxation primitives
and edge order, so tie-breaks coincide wherever ties do occur in the
same round pattern.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core import graph, sssp
from .edits import AppliedDelta, KIND_ADD, KIND_DECREASE, KIND_INCREASE, \
    KIND_REMOVE

__all__ = ["RepairStats", "repair_state", "repair"]


@dataclasses.dataclass(frozen=True)
class RepairStats:
    """Host-side accounting for one repair (the blast radius)."""
    n_invalid: int      # vertices whose old dist/parent were reset
    n_seeds: int        # vertices in the re-seeded frontier
    fast_path: bool     # decrease-only delta: invalidation skipped


def repair_state(new_host: graph.HostGraph, dist, parent,
                 applied: AppliedDelta):
    """Invalidate + re-seed; returns ``(dist, parent, frontier, stats)``.

    ``dist``/``parent`` are the pre-delta solve state (length ``n`` or
    padded; extra entries are ignored).  The returned numpy arrays are
    the valid upper-bound state and seed frontier to feed
    :func:`repro.core.sssp.repair_relax` or
    :func:`repro.core.distributed.repair_distributed`.
    """
    n = new_host.n
    dist = np.asarray(dist, np.float32)[:n]
    parent = np.asarray(parent, np.int32)[:n]
    fast = bool(applied.decrease_only)

    invalid = np.zeros(n, bool)
    if not fast:
        sel = (applied.kind == KIND_REMOVE) | (applied.kind == KIND_INCREASE)
        vv, uu = applied.dst[sel], applied.src[sel]
        hit = (parent[vv] == uu) & (vv != uu)   # self-parent = the source
        invalid[vv[hit]] = True
        if invalid.any():
            # propagate down the tree by pointer jumping: O(m log n) worst
            # case but O(n) per sweep, and sweeps stop mattering once every
            # chain is covered
            anc = np.where(parent >= 0, parent, np.arange(n))
            for _ in range(int(np.ceil(np.log2(max(n, 2)))) + 1):
                invalid |= invalid[anc]
                anc = anc[anc]

    dist_i = np.where(invalid, np.float32(np.inf), dist)
    parent_i = np.where(invalid, np.int32(-1), parent)

    seed = np.zeros(n, bool)
    if invalid.any():
        # in-neighbors of the invalid region, over the NEW graph
        np.logical_or.at(seed, np.asarray(new_host.src, np.int64),
                         invalid[np.asarray(new_host.dst, np.int64)])
    gain = (applied.kind == KIND_ADD) | (applied.kind == KIND_DECREASE)
    seed[applied.src[gain]] = True
    frontier = seed & ~invalid & np.isfinite(dist_i)
    return dist_i, parent_i, frontier, RepairStats(
        n_invalid=int(invalid.sum()), n_seeds=int(frontier.sum()),
        fast_path=fast)


def repair(layout, new_host: graph.HostGraph, dist, parent,
           applied: AppliedDelta, *, backend: str = "segment_min",
           fused_rounds: int = 0, max_iters: int = 1_000_000):
    """Repair a single-device solve state against a patched layout.

    ``layout`` must already be the *patched* layout for ``backend``
    (from :mod:`repro.delta.patch` or a fresh ``prepare_layout`` on
    ``new_host``).  Returns ``(dist, parent, metrics, stats)`` with
    dist/parent bitwise-identical to a from-scratch solve and metrics
    counting only the repair's own relaxation work.  For the
    distributed tier, pair :func:`repair_state` with
    :func:`repro.core.distributed.repair_distributed`.
    """
    dist_i, parent_i, frontier, stats = repair_state(new_host, dist,
                                                     parent, applied)
    d2, p2, metrics = sssp.repair_relax(
        layout, jnp.asarray(dist_i), jnp.asarray(parent_i),
        jnp.asarray(frontier), backend=backend, max_iters=max_iters,
        fused_rounds=fused_rounds)
    return d2, p2, metrics, stats
