"""In-place layout patching for edge deltas.

Three patchers, all gated on bitwise equality with a from-scratch
rebuild of the same structure:

- :func:`patch_host` edits the CSR ``HostGraph`` (the ground truth all
  device layouts derive from).  It reproduces ``build_csr``'s pipeline
  exactly — stable ``lexsort((w, src))`` over [kept edges in old CSR
  order, then adds], degree/row_ptr recompute, and the RtoW quantile LUT
  over float64-promoted weights — so the patched host is bitwise equal
  to rebuilding from the edited edge list.
- :func:`patch_blocked` patches the CSR-of-tiles blocked layout.  A
  directed edit localizes to one (src-block, dst-block) bucket; when the
  per-bucket tile counts of the affected src-block slab are unchanged
  (tile padding absorbs the edit) only the touched buckets' tile slots
  are rewritten, otherwise that one slab is re-bucketed.
- :func:`patch_sharded` patches the distributed per-shard edge slabs,
  rewriting only the shards that own an edited source vertex (the whole
  table is re-padded only when a shard outgrows ``e_max``).

The ``*_with`` variants take an already-patched host so one
:func:`patch_host` call can be shared across every placement of a graph
(the registry's one-patch-N-placements path).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import distributed, graph
from .edits import (AppliedDelta, EdgeDelta, KIND_ADD, KIND_DECREASE,
                    KIND_INCREASE, KIND_REMOVE, KIND_SAME)

__all__ = ["patch_host", "patch_blocked", "patch_blocked_with",
           "patch_sharded", "patch_sharded_with"]


def _find_slot(row_ptr: np.ndarray, dst: np.ndarray, u: int, v: int) -> int:
    lo, hi = int(row_ptr[u]), int(row_ptr[u + 1])
    rel = np.nonzero(dst[lo:hi] == v)[0]
    if rel.size == 0:
        raise ValueError(f"directed edge ({u}, {v}) not present in graph")
    return lo + int(rel[0])   # first match in CSR order: deterministic
    # with parallel edges — the lightest copy is the one edited


def patch_host(hg: graph.HostGraph,
               delta: EdgeDelta) -> Tuple[graph.HostGraph, AppliedDelta]:
    """Apply ``delta`` to a host CSR; returns ``(new_host, applied)``.

    Bitwise-identical to ``build_csr`` over the edited edge list (the
    gate ``tests/test_delta.py`` enforces): weights are edited as
    float32 and promoted to float64 only for the quantile LUT, matching
    the builder's float64 pipeline exactly (the promotion is monotone,
    so the stable sort permutation is identical too).
    """
    n = hg.n
    s = np.asarray(hg.src, np.int64)
    d = np.asarray(hg.dst, np.int64)
    w = np.asarray(hg.w, np.float32).copy()
    row_ptr = np.asarray(hg.row_ptr, np.int64)

    au, av, aw = delta.add
    ru, rv = delta.remove
    wu, wv, ww = delta.reweight
    for name, us, vs in (("add", au, av), ("remove", ru, rv),
                         ("reweight", wu, wv)):
        if us.size and not (np.all((us >= 0) & (us < n))
                            and np.all((vs >= 0) & (vs < n))):
            raise ValueError(f"{name} vertex ids out of range [0, {n})")

    if delta.symmetrize:
        au, av, aw = (np.concatenate([au, av]), np.concatenate([av, au]),
                      np.concatenate([aw, aw]))
        ru, rv = np.concatenate([ru, rv]), np.concatenate([rv, ru])
        wu, wv, ww = (np.concatenate([wu, wv]), np.concatenate([wv, wu]),
                      np.concatenate([ww, ww]))

    # each remove/reweight must target a distinct directed slot (note
    # this rejects symmetrized self-loop removes — expand those to a
    # symmetrize=False delta)
    key = np.concatenate([ru, wu]) * np.int64(n) + np.concatenate([rv, wv])
    if np.unique(key).size != key.size:
        raise ValueError("duplicate remove/reweight target in one delta "
                         "(after symmetrize expansion)")

    rm_slots = np.asarray(
        [_find_slot(row_ptr, d, int(u), int(v)) for u, v in zip(ru, rv)],
        np.int64)
    rw_kinds = np.zeros(wu.size, np.int8)
    for i, (u, v, new_w) in enumerate(zip(wu, wv, ww)):
        slot = _find_slot(row_ptr, d, int(u), int(v))
        old = w[slot]
        rw_kinds[i] = (KIND_INCREASE if new_w > old
                       else KIND_DECREASE if new_w < old else KIND_SAME)
        w[slot] = new_w

    applied = AppliedDelta(
        src=np.concatenate([au, ru, wu]).astype(np.int64),
        dst=np.concatenate([av, rv, wv]).astype(np.int64),
        kind=np.concatenate([np.full(au.size, KIND_ADD, np.int8),
                             np.full(ru.size, KIND_REMOVE, np.int8),
                             rw_kinds]))

    keep = np.ones(s.size, bool)
    keep[rm_slots] = False
    s2 = np.concatenate([s[keep], au])
    d2 = np.concatenate([d[keep], av])
    w2 = np.concatenate([w[keep], aw]).astype(np.float32)

    order = np.lexsort((w2, s2))
    s2, d2, w2 = s2[order], d2[order], w2[order]
    deg = np.bincount(s2, minlength=n).astype(np.int32)
    rp = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=rp[1:])
    new_host = graph.HostGraph(
        n=n, src=s2.astype(np.int32), dst=d2.astype(np.int32), w=w2,
        row_ptr=rp.astype(np.int32), deg=deg,
        rtow=graph.weight_quantile_lut(w2.astype(np.float64)),
        max_w=float(w2.max()) if w2.size else 0.0)
    return new_host, applied


def patch_blocked_with(layout: graph.BlockedGraph,
                       old_host: graph.HostGraph,
                       new_host: graph.HostGraph,
                       applied: AppliedDelta) -> graph.BlockedGraph:
    """Patch a whole-graph blocked layout given an already-patched host."""
    if layout.src_base != 0 or layout.n_blocks != layout.n_dst_blocks:
        raise ValueError("patch_blocked needs a whole-graph blocked layout "
                         "(src_base == 0); patch the sharded table with "
                         "patch_sharded instead")
    bv, te, nb = layout.block_v, layout.tile_e, layout.n_blocks
    n = new_host.n
    changed = applied.kind != KIND_SAME
    rp_new = np.asarray(new_host.row_ptr, np.int64)
    rp_old = np.asarray(old_host.row_ptr, np.int64)
    slabs: List[graph.BlockedEdges] = list(layout.slabs)

    for b in np.unique(applied.src[changed] // bv):
        b = int(b)
        lo_v, hi_v = b * bv, min(b * bv + bv, n)
        e0, e1 = rp_new[lo_v], rp_new[hi_v]
        s_n = (np.asarray(new_host.src[e0:e1], np.int64)
               - lo_v).astype(np.int32)
        d_n = np.asarray(new_host.dst[e0:e1], np.int32)
        w_n = np.asarray(new_host.w[e0:e1], np.float32)
        tp_new = -(-np.bincount(d_n // bv, minlength=nb) // te)
        o0, o1 = rp_old[lo_v], rp_old[hi_v]
        tp_old = -(-np.bincount(
            np.asarray(old_host.dst[o0:o1], np.int64) // bv,
            minlength=nb) // te)

        old = slabs[b]
        if (np.array_equal(tp_old, tp_new)
                and int(old.tile_dst.shape[0]) == max(int(tp_new.sum()), 1)):
            # tile padding absorbs the edit: per-bucket tile counts are
            # unchanged, so tile_dst/tile_first/bucket_nonempty are
            # invariant and only the touched buckets' slots move
            tile_ptr = np.zeros(nb + 1, np.int64)
            np.cumsum(tp_new, out=tile_ptr[1:])
            s_out = np.asarray(old.src_local).copy()
            d_out = np.asarray(old.dst).copy()
            w_out = np.asarray(old.w).copy()
            in_b = changed & (applied.src // bv == b)
            db_of = d_n // bv
            for db in np.unique(applied.dst[in_b] // bv):
                db = int(db)
                a0, a1 = int(tile_ptr[db]) * te, int(tile_ptr[db + 1]) * te
                s_out[a0:a1] = 0
                d_out[a0:a1] = 0
                w_out[a0:a1] = np.inf
                m = db_of == db
                k = int(m.sum())
                s_out[a0:a0 + k] = s_n[m]
                d_out[a0:a0 + k] = d_n[m]
                w_out[a0:a0 + k] = w_n[m]
            slabs[b] = graph.BlockedEdges(
                src_local=jnp.asarray(s_out), dst=jnp.asarray(d_out),
                w=jnp.asarray(w_out), tile_dst=old.tile_dst,
                tile_first=old.tile_first,
                bucket_nonempty=old.bucket_nonempty)
        else:
            slabs[b] = graph._slab_edges(s_n, d_n, w_n, n_dst_blocks=nb,
                                         block_v=bv, tile_e=te)

    sb_counts = np.bincount(np.asarray(new_host.src, np.int64) // bv,
                            minlength=nb)
    dense = int(sum(nb * max(-(-int(c) // te), 1) for c in sb_counts))
    deg_pad = np.zeros(nb * bv, np.int32)
    deg_pad[:n] = new_host.deg
    return dataclasses.replace(layout, dense_grid_tiles=dense,
                               slabs=tuple(slabs), deg=jnp.asarray(deg_pad))


def patch_blocked(layout: graph.BlockedGraph, delta: EdgeDelta, *,
                  host: graph.HostGraph):
    """Patch a blocked layout in place; ``(new_layout, new_host, applied)``.

    ``host`` is the HostGraph the layout was built from — slab data
    alone cannot reproduce the CSR tie order the buckets inherit, so the
    patch runs through :func:`patch_host` first.
    """
    new_host, applied = patch_host(host, delta)
    return patch_blocked_with(layout, host, new_host, applied), \
        new_host, applied


def patch_sharded_with(sg: "distributed.ShardedGraph",
                       new_host: graph.HostGraph,
                       applied: AppliedDelta) -> "distributed.ShardedGraph":
    """Patch the per-shard edge slabs given an already-patched host."""
    p, e_max = sg.src.shape
    block = int(sg.deg.shape[1])
    n = new_host.n
    rp = np.asarray(new_host.row_ptr, np.int64)
    counts = np.bincount(np.asarray(new_host.src, np.int64) // block,
                         minlength=p)
    if int(counts.max() if counts.size else 0) > e_max:
        # a shard outgrew its slab: widen every row (shard_graph's
        # uniform e_max keeps the stacked table rectangular)
        e_max = max(int(counts.max()), 1)
        s2 = np.zeros((p, e_max), np.int32)
        d2 = np.zeros((p, e_max), np.int32)
        w2 = np.full((p, e_max), np.inf, np.float32)
        for q in range(p):
            s2[q, :] = q * block
        shards = np.arange(p)
    else:
        s2 = np.asarray(sg.src).copy()
        d2 = np.asarray(sg.dst).copy()
        w2 = np.asarray(sg.w).copy()
        changed = applied.kind != KIND_SAME
        shards = np.unique(applied.src[changed] // block)
    for q in shards:
        q = int(q)
        lo_v = q * block
        if lo_v >= n:
            continue
        e0, e1 = rp[lo_v], rp[min(lo_v + block, n)]
        c = int(e1 - e0)
        # shard_graph's stable owner sort preserves CSR order, so the
        # shard's slab is exactly the host CSR slice plus padding
        s2[q, :c] = new_host.src[e0:e1]
        d2[q, :c] = new_host.dst[e0:e1]
        w2[q, :c] = new_host.w[e0:e1]
        s2[q, c:] = q * block
        d2[q, c:] = 0
        w2[q, c:] = np.inf
    deg = np.zeros(p * block, np.int32)
    deg[:n] = new_host.deg
    return distributed.ShardedGraph(
        src=jnp.asarray(s2), dst=jnp.asarray(d2), w=jnp.asarray(w2),
        deg=jnp.asarray(deg.reshape(p, block)),
        rtow=jnp.asarray(new_host.rtow), n_edges2=jnp.int32(new_host.m),
        n_true=sg.n_true)


def patch_sharded(sg: "distributed.ShardedGraph", delta: EdgeDelta, *,
                  host: graph.HostGraph):
    """Patch sharded slabs in place; ``(new_sg, new_host, applied)``."""
    new_host, applied = patch_host(host, delta)
    return patch_sharded_with(sg, new_host, applied), new_host, applied
