"""``jax.profiler`` trace-annotation hooks (no-ops when unavailable).

:func:`annotate` wraps host-side phases — engine/layout builds, relax
dispatch — in a ``jax.profiler.TraceAnnotation`` so they show up as
named spans in TensorBoard / Perfetto captures taken with
``jax.profiler.trace()``.  When the profiler is missing (stripped
builds, very old jax) it degrades to a ``nullcontext``: annotation must
never be able to break a solve.

These annotate *dispatch*, not traced computation: inside ``jit`` a
host-side context manager would only fire at trace time, so the
annotation sites live at the jit call boundaries (see
``core/sssp.py`` / ``serve/registry.py``).
"""
from __future__ import annotations

import contextlib

__all__ = ["annotate", "PROFILER_AVAILABLE"]

try:
    from jax.profiler import TraceAnnotation as _TraceAnnotation
    PROFILER_AVAILABLE = True
except Exception:                                   # pragma: no cover
    _TraceAnnotation = None
    PROFILER_AVAILABLE = False


def annotate(name: str):
    """Context manager naming the enclosed host-side phase for profilers."""
    if _TraceAnnotation is None:                    # pragma: no cover
        return contextlib.nullcontext()
    return _TraceAnnotation(name)
