"""Observability plane: solve traces, serving metrics, exporters.

Three sub-systems, one package (see ISSUE 7 / README "Observability"):

* :mod:`repro.obs.trace` — opt-in per-round solve traces
  (``EngineConfig(trace=True)``): an on-device ring of per-round records
  materialized host-side as :class:`SolveTrace`;
* :mod:`repro.obs.metrics` — the thread-safe :class:`MetricsRegistry`
  (counters / gauges / latency histograms) backing every serving-plane
  ``stats()``;
* :mod:`repro.obs.export` — Prometheus text exposition, JSONL snapshot
  dumps, and the Perfetto (Chrome-trace) solve-trace exporter;
* :mod:`repro.obs.profiling` — ``jax.profiler`` trace annotations
  around engine builds and relax dispatch.

This package deliberately imports nothing from ``repro.core`` or
``repro.serve`` so every layer can depend on it without cycles.
"""
from .trace import (TRACE_COLUMNS, TRACE_COUNTER_COLUMNS, SolveTrace,
                    TraceBuf, materialize_trace, trace_append, trace_init)
from .metrics import (DEFAULT_LATENCY_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .export import (parse_prometheus, to_prometheus, trace_to_perfetto,
                     write_jsonl_snapshot, write_perfetto)
from .profiling import PROFILER_AVAILABLE, annotate

__all__ = [
    "TRACE_COLUMNS", "TRACE_COUNTER_COLUMNS", "SolveTrace", "TraceBuf",
    "materialize_trace", "trace_append", "trace_init",
    "DEFAULT_LATENCY_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry",
    "parse_prometheus", "to_prometheus", "trace_to_perfetto",
    "write_jsonl_snapshot", "write_perfetto",
    "PROFILER_AVAILABLE", "annotate",
]
