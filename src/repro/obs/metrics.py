"""Thread-safe serving metrics: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` instance backs a whole serving plane
(registry + scheduler(s) + router + service): every stat gets **one
name, one type, one snapshot shape**, replacing the ad-hoc per-component
``stats()`` dicts that previously each invented their own keys.

Conventions (Prometheus-compatible, so the text exposition in
:mod:`repro.obs.export` is mechanical):

* metric names match ``[a-zA-Z_:][a-zA-Z0-9_:]*``; counters end in
  ``_total``; durations are in seconds and end in ``_seconds``;
* the same name may be registered repeatedly with different ``labels``
  (e.g. one ``sssp_scheduler_batches_total`` series per scheduler), but
  never with a different metric type;
* histograms use fixed, monotonically increasing upper bounds with an
  implicit ``+Inf`` bucket; p50/p90/p99 summaries are estimated by
  linear interpolation inside the target bucket (the standard
  ``histogram_quantile`` rule).

All mutation goes through one registry-level lock — serving-plane update
rates (per batch, per query) are far below contention territory, and a
single lock keeps ``snapshot()`` trivially consistent.
"""
from __future__ import annotations

import math
import re
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS", "fmt_bound",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Latency buckets (seconds): ~2.5x steps from 0.5 ms to 10 s, sized for
# the serving plane's per-batch solve latencies on CPU and TPU alike.
DEFAULT_LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def fmt_bound(b) -> str:
    """Canonical bucket-bound spelling ("0.1", "1", "+Inf") — shared by
    snapshot bucket keys and the exposition's ``le`` label values."""
    f = float(b)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _render_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Metric:
    """Shared identity plumbing; subclasses hold the value state."""
    kind = "untyped"

    def __init__(self, name: str, help: str, labels: dict,
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labels = dict(labels)
        self._lock = lock

    @property
    def full_name(self) -> str:
        """``name{label="value",...}`` — the snapshot/exposition key."""
        return self.name + _render_labels(self.labels)


class Counter(_Metric):
    """Monotonically increasing count (negative increments rejected)."""
    kind = "counter"

    def __init__(self, name, help, labels, lock):
        super().__init__(name, help, labels, lock)
        self._value = 0

    def inc(self, amount: int = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc({amount}))")
        with self._lock:
            self._value += amount

    @property
    def value(self):
        with self._lock:
            return self._value

    def _snapshot_locked(self) -> dict:
        return {"type": self.kind, "value": self._value}


class Gauge(_Metric):
    """A value that can go up and down (queue depths, occupancy)."""
    kind = "gauge"

    def __init__(self, name, help, labels, lock):
        super().__init__(name, help, labels, lock)
        self._value = 0.0

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def inc(self, amount=1) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount=1) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self):
        with self._lock:
            return self._value

    def _snapshot_locked(self) -> dict:
        return {"type": self.kind, "value": self._value}


class Histogram(_Metric):
    """Fixed-bucket histogram with interpolated percentile summaries."""
    kind = "histogram"

    def __init__(self, name, help, labels, lock,
                 buckets=DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help, labels, lock)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"histogram {name}: buckets must be a "
                             f"non-empty increasing sequence, got {buckets}")
        self.buckets = bounds                      # finite upper bounds
        self._counts = [0] * (len(bounds) + 1)     # + the +Inf bucket
        self._count = 0
        self._sum = 0.0

    def observe(self, value) -> None:
        v = float(value)
        with self._lock:
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            self._counts[i] += 1
            self._count += 1
            self._sum += v

    @property
    def count(self):
        with self._lock:
            return self._count

    @property
    def sum(self):
        with self._lock:
            return self._sum

    def _percentile_locked(self, q: float) -> float:
        """``histogram_quantile``-style estimate from cumulative buckets."""
        if self._count == 0:
            return math.nan
        rank = q * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            prev_cum = cum
            cum += c
            if cum >= rank and c > 0:
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                # the +Inf bucket has no upper bound: report its lower
                # bound (the largest finite le) rather than inventing one
                if i >= len(self.buckets):
                    return lo
                hi = self.buckets[i]
                return lo + (hi - lo) * (rank - prev_cum) / c
        return self.buckets[-1]

    def percentile(self, q: float) -> float:
        with self._lock:
            return self._percentile_locked(q)

    def _snapshot_locked(self) -> dict:
        # string bucket keys ("0.1", "1", "+Inf") keep the snapshot
        # JSON-serializable and match the exposition's le label values
        cum, cum_counts = 0, {}
        for bound, c in zip(self.buckets + (math.inf,), self._counts):
            cum += c
            cum_counts[fmt_bound(bound)] = cum
        return {
            "type": self.kind,
            "count": self._count,
            "sum": self._sum,
            "buckets": cum_counts,       # upper bound -> cumulative count
            "p50": self._percentile_locked(0.50),
            "p90": self._percentile_locked(0.90),
            "p99": self._percentile_locked(0.99),
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics with one consistent lock.

    ``counter`` / ``gauge`` / ``histogram`` return the existing series
    when (name, labels) was registered before — components can therefore
    share a registry without coordinating creation order — and raise if
    the same name is reused with a different metric type.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}          # (name, labels-key) -> _Metric

    def _get_or_create(self, cls, name, help, labels, **kw):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labels = dict(labels or {})
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r} on {name}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            existing = self._metrics.get(key)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{existing.kind}, not {cls.kind}")
                return existing
            # every series of one name must share a type
            for (n, _), m in self._metrics.items():
                if n == name and m.kind != cls.kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"not {cls.kind}")
            metric = cls(name, help, labels, self._lock, **kw)
            self._metrics[key] = metric
            return metric

    def counter(self, name, help="", labels=None) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name, help="", labels=None) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name, help="", labels=None,
                  buckets=DEFAULT_LATENCY_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def metrics(self) -> list:
        """All registered series, sorted by (name, labels)."""
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> dict:
        """One consistent ``{full_name: {type, ...values}}`` view."""
        out = {}
        with self._lock:
            for key in sorted(self._metrics):
                m = self._metrics[key]
                entry = m._snapshot_locked()
                if m.help:
                    entry["help"] = m.help
                if m.labels:
                    entry["labels"] = dict(m.labels)
                out[m.full_name] = entry
        return out
