"""Exporters: Prometheus text exposition, JSONL snapshots, Perfetto traces.

Three consumers, three formats, all derived from the same two sources of
truth (a :meth:`MetricsRegistry.snapshot` dict and a
:class:`~repro.obs.trace.SolveTrace`):

* :func:`to_prometheus` — the Prometheus/OpenMetrics text exposition
  (``# HELP`` / ``# TYPE`` headers, ``_bucket``/``_sum``/``_count``
  histogram series) for scrape endpoints;
* :func:`write_jsonl_snapshot` — append-only JSONL dumps for offline
  perf-trajectory analysis (one snapshot per line);
* :func:`trace_to_perfetto` — a Chrome-trace (Perfetto JSON) view of a
  solve trace: a ``solve`` span over ``step`` (stepping-window) spans
  over ``round`` spans with per-round counters attached as args.

:func:`parse_prometheus` is a deliberately strict mini-parser used by
tests and the CI smoke step to prove the exposition is well-formed —
it is not a general Prometheus client.
"""
from __future__ import annotations

import json
import math
import re
import time

from .trace import SolveTrace, TRACE_COLUMNS

__all__ = [
    "to_prometheus", "parse_prometheus", "write_jsonl_snapshot",
    "trace_to_perfetto", "write_perfetto",
]

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^{}]*\})?\s+"
    r"(?P<value>[-+]?(?:\d+\.?\d*(?:[eE][-+]?\d+)?|Inf|NaN))$")


def _fmt(v) -> str:
    """Prometheus sample-value formatting (+Inf / NaN spelled out)."""
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_str(labels: dict, extra: dict = None) -> str:
    merged = dict(labels or {})
    merged.update(extra or {})
    if not merged:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def to_prometheus(snapshot: dict) -> str:
    """Render a :meth:`MetricsRegistry.snapshot` as text exposition."""
    by_name: dict = {}
    for full_name, entry in snapshot.items():
        base = full_name.split("{", 1)[0]
        by_name.setdefault(base, []).append(entry)
    lines = []
    for base in sorted(by_name):
        series = by_name[base]
        kind = series[0]["type"]
        help_text = next((s.get("help") for s in series if s.get("help")),
                         None)
        if help_text:
            lines.append(f"# HELP {base} {help_text}")
        lines.append(f"# TYPE {base} {kind}")
        for entry in series:
            labels = entry.get("labels", {})
            if kind == "histogram":
                # bucket keys are canonical bound strings ("0.1", "+Inf");
                # order by numeric value, not lexically
                for bound in sorted(entry["buckets"],
                                    key=lambda k: float(k.replace("Inf",
                                                                  "inf"))):
                    lines.append(
                        f"{base}_bucket{_labels_str(labels, {'le': bound})} "
                        f"{entry['buckets'][bound]}")
                lines.append(f"{base}_sum{_labels_str(labels)} "
                             f"{_fmt(entry['sum'])}")
                lines.append(f"{base}_count{_labels_str(labels)} "
                             f"{entry['count']}")
            else:
                lines.append(f"{base}{_labels_str(labels)} "
                             f"{_fmt(entry['value'])}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Strictly parse a text exposition back into ``{sample_name: value}``.

    Raises ``ValueError`` on any malformed line; histogram invariants
    (cumulative ``_bucket`` counts ending at ``_count``) are checked by
    the tests on top of this.
    """
    samples: dict = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            if not (line.startswith("# HELP ") or line.startswith("# TYPE ")):
                raise ValueError(f"line {lineno}: bad comment {raw!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: bad sample {raw!r}")
        key = m.group("name") + (m.group("labels") or "")
        if key in samples:
            raise ValueError(f"line {lineno}: duplicate sample {key!r}")
        samples[key] = float(m.group("value").replace("Inf", "inf"))
    return samples


def write_jsonl_snapshot(snapshot: dict, path, meta: dict = None) -> None:
    """Append one ``{"ts", ..., "metrics"}`` JSON line to ``path``."""
    record = {"ts": time.time(), **(meta or {}), "metrics": snapshot}
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")


# ---------------------------------------------------------------------------
# Perfetto / Chrome-trace export
# ---------------------------------------------------------------------------

# Track (tid) layout inside the exported process: one lane per nesting
# level so the solve -> step -> round -> invocation hierarchy renders as
# stacked tracks even in viewers that don't nest same-tid spans.
_TID_SOLVE, _TID_STEP, _TID_ROUND, _TID_INVOKE = 0, 1, 2, 3


def trace_to_perfetto(trace: SolveTrace, name: str = "solve",
                      pid: int = 0) -> dict:
    """A :class:`SolveTrace` as a Chrome-trace (Perfetto-loadable) dict.

    Solve traces carry no wall-clock — rounds execute inside one
    compiled ``while_loop`` — so the timeline uses *logical work time*:
    each round span lasts ``max(n_trav + n_pull_trav + n_relax, 1)``
    microseconds.  Span widths are therefore proportional to relaxation
    work, which is exactly the view the stepping-policy analysis needs
    (a mis-sized window shows up as one giant round span).
    """
    cols = trace.columns
    events = [
        {"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
         "args": {"name": lane}}
        for tid, lane in ((_TID_SOLVE, "solve"), (_TID_STEP, "steps"),
                          (_TID_ROUND, "rounds"),
                          (_TID_INVOKE, "invocations"))
    ]
    t = 0
    step_idx, step_t0 = 0, 0
    for i in range(trace.n_records):
        rec = {c: cols[c][i].item() for c in TRACE_COLUMNS}
        work = int(rec["n_trav"] + rec["n_pull_trav"] + rec["n_relax"])
        dur = max(work, 1)
        rounds = int(rec["n_rounds"])
        rname = (f"round {int(rec['iter'])}" if rounds <= 1
                 else f"rounds x{rounds} (iter {int(rec['iter'])})")
        events.append({
            "ph": "X", "pid": pid, "tid": _TID_ROUND, "name": rname,
            "ts": t, "dur": dur, "cat": "round", "args": rec,
        })
        if rec["n_invocations"] > 0:
            events.append({
                "ph": "X", "pid": pid, "tid": _TID_INVOKE,
                "name": f"invoke x{int(rec['n_invocations'])}",
                "ts": t, "dur": dur, "cat": "invocation",
                "args": {"n_tiles_scanned": rec["n_tiles_scanned"],
                         "n_tiles_dense": rec["n_tiles_dense"]},
            })
        t += dur
        if rec["stepped"]:
            events.append({
                "ph": "X", "pid": pid, "tid": _TID_STEP,
                "name": f"step {step_idx} [lb={rec['lb']:.4g}, "
                        f"ub={rec['ub']:.4g})",
                "ts": step_t0, "dur": t - step_t0, "cat": "step",
                "args": {"lb": rec["lb"], "ub": rec["ub"],
                         "st": rec["st"],
                         "frontier_at_entry": int(rec["frontier"])},
            })
            step_idx, step_t0 = step_idx + 1, t
    if t > step_t0:     # records after the last transition (or none ran)
        events.append({
            "ph": "X", "pid": pid, "tid": _TID_STEP,
            "name": f"step {step_idx}", "ts": step_t0, "dur": t - step_t0,
            "cat": "step", "args": {},
        })
    events.append({
        "ph": "X", "pid": pid, "tid": _TID_SOLVE, "name": name,
        "ts": 0, "dur": max(t, 1), "cat": "solve",
        "args": trace.summary(),
    })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"time_unit": "logical work (1us ~= 1 relaxation)",
                          "n_records": trace.n_records,
                          "dropped": trace.dropped}}


def write_perfetto(trace: SolveTrace, path, name: str = "solve") -> None:
    """Dump :func:`trace_to_perfetto` JSON to ``path``."""
    with open(path, "w") as f:
        json.dump(trace_to_perfetto(trace, name=name), f)
