"""Per-round solve traces: on-device ring buffer + host-side ``SolveTrace``.

The trace plane answers the question the aggregate :class:`SsspMetrics`
cannot: *why* was a round slow, and was the window sized right?  With
``EngineConfig(trace=True)`` every engine (single-device, distributed
v1/v2/v3, fused megakernel) appends one record per ``while_loop``
iteration into a fixed-capacity on-device ring (:class:`TraceBuf`), and
the facade materializes it host-side as a :class:`SolveTrace` attached
to ``SolveResult.trace``.

Design constraints, in order:

* **Bitwise no-op when off.**  The trace knob is static (part of the jit
  / shard_map-closure cache key): with ``trace_capacity == 0`` the
  traced program is *literally the same program* as before this module
  existed — dist/parent/metrics cannot change, not even in their last
  ulp.  With tracing on, the ring only ever *reads* solver state, so the
  outputs still match bitwise; only the compiled program differs.
* **Exact counter deltas.**  One record holds the per-iteration *delta*
  of every logical counter, stored as int32 — summing a trace's counter
  columns (plus the engine's initial metrics, see
  :data:`TRACE_COUNTER_COLUMNS`) reproduces the final ``SsspMetrics``
  exactly, which is what the parity tests assert.
* **Fixed footprint.**  The ring holds ``capacity`` records and
  overwrites the oldest on overflow (``SolveTrace.dropped`` reports how
  many were lost); engines never reallocate on device.

One *record* covers one body iteration of the solve loop: a relaxation
round (or one fused-megakernel invocation covering up to
``fused_rounds`` rounds) plus, when the frontier emptied, the step
transition and its pull phase.  ``stepped == 1`` marks those transition
records; ``n_rounds`` inside a record can exceed 1 only on fused paths.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np
import jax.numpy as jnp
from jax import lax

__all__ = [
    "TRACE_COLUMNS", "TRACE_I32_COLUMNS", "TRACE_F32_COLUMNS",
    "TRACE_COUNTER_COLUMNS", "TraceBuf", "trace_init", "trace_append",
    "SolveTrace", "materialize_trace",
]

# int32 columns: loop position, frontier census, and the per-iteration
# deltas of every logical SsspMetrics counter (bitwise-exact sums).
TRACE_I32_COLUMNS = (
    "iter",           # while-loop iteration index this record describes
    "frontier",       # frontier size at the start of the iteration
    "stepped",        # 1 if this iteration ran the step transition
    "n_rounds",       # logical-counter deltas from here on
    "n_steps",
    "n_extended",
    "n_trav",
    "n_pull_trav",
    "n_relax",
    "n_updates",
    "n_pruned",
)

# float32 columns: the stepping window at the start of the iteration and
# the physical (layout/launch geometry) counter deltas, which are f32 in
# SsspMetrics already.
TRACE_F32_COLUMNS = (
    "lb", "ub", "st",
    "n_tiles_scanned", "n_tiles_dense", "n_invocations",
)

TRACE_COLUMNS = TRACE_I32_COLUMNS + TRACE_F32_COLUMNS

# Columns that are SsspMetrics counter deltas; summing each over the
# records of a non-overflowed trace and adding the engine's initial
# metrics (n_extended starts at 1 for the source pop, the rest at 0)
# reproduces the final SsspMetrics field exactly.
TRACE_COUNTER_COLUMNS = (
    "n_rounds", "n_steps", "n_extended", "n_trav", "n_pull_trav",
    "n_relax", "n_updates", "n_pruned", "n_tiles_scanned",
    "n_tiles_dense", "n_invocations",
)


class TraceBuf(NamedTuple):
    """The on-device ring: two column-major data planes plus a write count.

    ``n`` counts records *ever written*; the ring slot is ``n % capacity``
    so overflow silently drops the oldest records (the host side reports
    the loss via ``SolveTrace.dropped``).
    """
    idata: jnp.ndarray   # [capacity, len(TRACE_I32_COLUMNS)] int32
    fdata: jnp.ndarray   # [capacity, len(TRACE_F32_COLUMNS)] float32
    n: jnp.ndarray       # scalar int32


def trace_init(capacity: int) -> TraceBuf:
    """A fresh empty ring of ``capacity`` records (device-side)."""
    if capacity <= 0:
        raise ValueError(f"trace capacity must be >= 1, got {capacity}")
    return TraceBuf(
        idata=jnp.zeros((capacity, len(TRACE_I32_COLUMNS)), jnp.int32),
        fdata=jnp.zeros((capacity, len(TRACE_F32_COLUMNS)), jnp.float32),
        n=jnp.int32(0),
    )


def trace_append(buf: TraceBuf, ivals: dict, fvals: dict) -> TraceBuf:
    """Append one record (inside ``jit``); keys must cover every column."""
    irow = jnp.stack([jnp.asarray(ivals[c], jnp.int32)
                      for c in TRACE_I32_COLUMNS])[None, :]
    frow = jnp.stack([jnp.asarray(fvals[c], jnp.float32)
                      for c in TRACE_F32_COLUMNS])[None, :]
    cap = buf.idata.shape[0]
    pos = lax.rem(buf.n, jnp.int32(cap))
    return TraceBuf(
        idata=lax.dynamic_update_slice(buf.idata, irow, (pos, 0)),
        fdata=lax.dynamic_update_slice(buf.fdata, frow, (pos, 0)),
        n=buf.n + 1,
    )


@dataclasses.dataclass(frozen=True)
class SolveTrace:
    """Host-side view of one solve's per-round records (oldest first).

    ``columns`` maps every :data:`TRACE_COLUMNS` name to a 1-D numpy
    array of length :attr:`n_records`.  ``n_recorded`` counts records the
    engine *wrote* (>= ``n_records`` iff the ring overflowed).
    """
    columns: dict
    n_recorded: int
    capacity: int

    @property
    def n_records(self) -> int:
        """Records retained in the ring (== n_recorded unless overflowed)."""
        return min(self.n_recorded, self.capacity)

    @property
    def dropped(self) -> int:
        """Oldest records lost to ring overflow."""
        return max(0, self.n_recorded - self.capacity)

    def __len__(self) -> int:
        return self.n_records

    def records(self) -> list:
        """The trace as a list of per-round dicts (oldest first)."""
        return [{c: self.columns[c][i].item() for c in TRACE_COLUMNS}
                for i in range(self.n_records)]

    def counter_sums(self) -> dict:
        """Summed per-round counter deltas (exact int64 / float64 sums).

        For a non-overflowed trace, ``initial + counter_sums() == final``
        holds bitwise per logical ``SsspMetrics`` field, where *initial*
        is the engine's metric init (``n_extended = 1`` for the source
        pop, everything else 0).
        """
        out = {}
        for c in TRACE_COUNTER_COLUMNS:
            col = self.columns[c]
            if col.dtype.kind == "i":
                out[c] = int(col.astype(np.int64).sum())
            else:
                out[c] = float(col.astype(np.float64).sum())
        return out

    def summary(self) -> dict:
        """Small host-side digest (for logs / demo output)."""
        fr = self.columns["frontier"]
        return {
            "n_records": self.n_records,
            "dropped": self.dropped,
            "n_steps": int(self.columns["stepped"].sum()),
            "max_frontier": int(fr.max()) if len(fr) else 0,
            "mean_frontier": float(fr.mean()) if len(fr) else 0.0,
            **self.counter_sums(),
        }


def _materialize_one(idata, fdata, n) -> SolveTrace:
    cap = idata.shape[0]
    n = int(n)
    kept = min(n, cap)
    # unroll the ring: the oldest retained record sits at n % cap when
    # the ring overflowed, else at 0
    start = n % cap if n > cap else 0
    order = (np.arange(kept) + start) % cap
    cols = {}
    for j, c in enumerate(TRACE_I32_COLUMNS):
        cols[c] = np.asarray(idata)[order, j]
    for j, c in enumerate(TRACE_F32_COLUMNS):
        cols[c] = np.asarray(fdata)[order, j]
    return SolveTrace(columns=cols, n_recorded=n, capacity=cap)


def materialize_trace(buf: TraceBuf):
    """Device ring -> host ``SolveTrace`` (or a list for batched solves).

    Batched engines stack the ring along a leading axis (``vmap`` /
    ``lax.map``); a 3-D buffer materializes to one ``SolveTrace`` per
    batch slot.
    """
    idata = np.asarray(buf.idata)
    fdata = np.asarray(buf.fdata)
    n = np.asarray(buf.n)
    if idata.ndim == 2:
        return _materialize_one(idata, fdata, n)
    return [_materialize_one(idata[i], fdata[i], n[i])
            for i in range(idata.shape[0])]
