"""Batched serving engine: continuous batching over prefill/decode steps.

Production pattern on top of the transformer serving primitives
(repro.models.transformer.prefill / decode_step):

* a slot-based KV cache: ``max_batch`` sequences decode in lock-step;
  finished slots are refilled from the request queue (continuous
  batching, vLLM-style at the granularity XLA likes — fixed shapes).
* prefill runs per admitted request (padded to ``prompt_pad``) and its
  KV rows are scattered into the decode cache slots.

Single-host reference implementation; the decode step itself is the
distributed object (the decode_32k dry-run cells lower exactly this fn).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [L] int32
    max_new: int
    out: Optional[list] = None


class ServeEngine:
    def __init__(self, cfg: T.LMConfig, params, *, max_batch: int = 8,
                 s_cache: int = 256, prompt_pad: int = 64,
                 eos_id: int = -1):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.s_cache = s_cache
        self.prompt_pad = prompt_pad
        self.eos = eos_id
        self.cache = T.init_cache(cfg, max_batch, s_cache)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_remaining = np.zeros(max_batch, np.int64)
        self.cur_tok = jnp.zeros((max_batch,), jnp.int32)
        self.queue: List[Request] = []
        self._prefill = jax.jit(
            lambda p, t: T.prefill(cfg, p, t, s_cache))
        self._decode = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))

    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            pad = self.prompt_pad - len(req.prompt) % self.prompt_pad
            pad = pad % self.prompt_pad
            prompt = np.pad(req.prompt, (pad, 0))[None, :]  # left pad
            cache, logits = self._prefill(self.params, jnp.asarray(prompt))
            # scatter the prefilled KV rows into this slot
            self.cache["k"] = self.cache["k"].at[:, slot].set(cache["k"][:, 0])
            self.cache["v"] = self.cache["v"].at[:, slot].set(cache["v"][:, 0])
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                cache["pos"][0])
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            self.cur_tok = self.cur_tok.at[slot].set(tok)
            req.out.append(int(tok))
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new - 1

    def step(self):
        """One lock-step decode over all active slots."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return False
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.cur_tok)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.cur_tok = nxt
        nxt_np = np.asarray(nxt)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.out.append(int(nxt_np[slot]))
            self.slot_remaining[slot] -= 1
            done = (self.slot_remaining[slot] <= 0 or
                    int(nxt_np[slot]) == self.eos)
            if done:
                self.slot_req[slot] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps
