"""Single-graph SSSP endpoint — a thin wrapper over the serving plane.

PR 1's ``SsspService`` (slot-batched full-tree queries over one fixed
graph) is kept as the compatibility facade.  By default it registers its
one graph in a :class:`~repro.serve.registry.GraphRegistry` and drives a
synchronous :class:`~repro.serve.scheduler.QueryScheduler` step per
``step()`` call; with ``devices=`` it instead fronts a
:class:`~repro.serve.router.QueryRouter` over those devices, so even the
legacy endpoint scales across a mesh (and serves sharded-tier graphs —
pass ``shard_threshold_n``/``shard_threshold_m`` through to the
registry).  New code should use the registry/router/queries stack
directly (multi-graph, async admission); this facade admits FIFO
requests of any goal kind — mixed kinds batch as plan-compatible
sub-batches, one fused batch per kind.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from ..core.config import EngineConfig, resolve_devices
from ..core.graph import DeviceGraph, HostGraph
from ..obs.export import to_prometheus, write_jsonl_snapshot
from .queries import Query
from .registry import GraphRegistry
from .router import QueryRouter
from .scheduler import QueryScheduler

_GID = "default"


@dataclasses.dataclass
class SsspRequest:
    """One shortest-path query against the service's graph.

    ``kind`` defaults to the facade's historical full-tree query; p2p /
    bounded / knear requests carry their goal parameter and may be
    freely mixed in one submission wave — the scheduler forms
    plan-compatible sub-batches (one fused batch per goal kind), so a
    mixed queue costs extra batch steps, never an error."""
    rid: int
    source: int
    kind: str = "tree"
    target: Optional[int] = None           # p2p
    bound: Optional[float] = None          # bounded
    k: Optional[int] = None                # knear
    dist: Optional[np.ndarray] = None      # filled on completion
    parent: Optional[np.ndarray] = None
    metrics: Optional[dict] = None
    distance: Optional[float] = None       # p2p: dist[target]
    path: Optional[list] = None            # p2p: source..target ids
    nearest: Optional[list] = None         # knear: [(vertex, dist)]
    error: Optional[Exception] = None      # set instead, on failure

    @property
    def done(self) -> bool:
        return self.dist is not None


class SsspService:
    """Continuous request batching over a fixed graph.

    ``submit()`` enqueues requests; each ``step()`` admits up to
    ``max_batch`` of them (FIFO), runs one fused batched SSSP and retires
    the whole batch.  Free slots are padded (repeating slot 0) so
    partially-full batches never trigger a recompile; padded results are
    discarded by the scheduler and never reach a request.
    """

    def __init__(self, g, *, config: Optional[EngineConfig] = None,
                 max_batch: Optional[int] = None,
                 backend: Optional[str] = None,
                 alpha: Optional[float] = None,
                 beta: Optional[float] = None, devices=None,
                 shard_threshold_n: Optional[int] = None,
                 shard_threshold_m: Optional[int] = None,
                 shard_backend: Optional[str] = None,
                 clock=time.monotonic, tuned=None, **backend_opts):
        if not isinstance(g, (HostGraph, DeviceGraph)):
            raise TypeError(f"expected HostGraph/DeviceGraph, got {type(g)}")
        user_config = config is not None
        # one option surface: config= XOR the loose kwargs (from_loose is
        # the shared sentinel gate)
        config = EngineConfig.from_loose(
            config, "service",
            # the loose default IS an explicit choice: the sharded tier
            # stays on segment_min unless asked (an unset shard_backend
            # would let effective_shard_backend derive "blocked" from a
            # blocked single-device backend)
            defaults={"shard_backend": "segment_min"},
            max_batch=max_batch, backend=backend, alpha=alpha, beta=beta,
            shard_threshold_n=shard_threshold_n,
            shard_threshold_m=shard_threshold_m,
            shard_backend=shard_backend, **backend_opts)
        max_batch = config.max_batch
        if user_config and devices is None:
            devices = resolve_devices(config.devices)
        self.config = config
        devices = list(devices) if devices is not None else None
        # at least one engine slot per (graph, device) replica; a
        # user-given config that sizes the cache larger (replica churn
        # headroom) is honored rather than silently shrunk
        capacity = 1 if devices is None else len(devices) + 1
        if user_config:
            capacity = max(capacity, config.registry_capacity)
        # tuned= (a repro.tune.TunedStore or a path) lets the registry
        # overlay per-graph offline-tuned perf fields at engine build
        self.registry = GraphRegistry(capacity=capacity, config=config,
                                      tuned=tuned)
        self.registry.register(_GID, g)
        if devices is None:
            # FIFO facade: no eccentricity reordering, no priorities
            self.router = None
            self.scheduler = QueryScheduler(self.registry,
                                            max_batch=max_batch,
                                            max_pending=config.max_pending,
                                            ecc_batching=False,
                                            clock=clock)
        else:
            self.router = QueryRouter(self.registry, devices=devices,
                                      max_batch=max_batch,
                                      max_pending=config.max_pending,
                                      ecc_batching=False,
                                      clock=clock)
            self.scheduler = None
        self.max_batch = max_batch
        self.n = int(g.n)
        if self.router is None:
            # the sync facade serves from the default-placement engine;
            # building it here keeps first-step latency out of step()
            self.g = self.registry.engine(_GID).g
        else:
            # router placement decides the serving devices — don't build
            # an unused default-placement engine just to expose .g
            self.g = None
        self._inflight: List[Tuple[SsspRequest, object]] = []

    @property
    def queue(self) -> list:
        """Requests submitted but not yet completed (compat shim)."""
        return [r for r, f in self._inflight if not f.done()]

    @property
    def n_batches(self) -> int:
        if self.router is not None:
            return self.router.stats()["n_batches"]
        return self.scheduler.n_batches

    def submit(self, req: SsspRequest) -> SsspRequest:
        q = Query(gid=_GID, source=int(req.source), kind=req.kind,
                  target=req.target, bound=req.bound, k=req.k)
        fut = (self.router.submit(q) if self.router is not None
               else self.scheduler.submit(q))
        self._inflight.append((req, fut))
        return req

    def _collect(self) -> None:
        remaining = []
        for req, fut in self._inflight:
            if not fut.done():
                remaining.append((req, fut))
            elif fut.exception() is not None:
                # a failed request must not wedge collection of the rest
                req.error = fut.exception()
            else:
                res = fut.result()
                req.dist = res.dist
                req.parent = res.parent
                req.metrics = res.metrics
                req.distance = res.distance
                req.path = res.path
                req.nearest = res.nearest
        self._inflight = remaining

    def step(self) -> bool:
        """Admit pending requests and run one fused batch; returns whether
        any work was done."""
        if self.router is not None:
            did = self.router.drain(max_steps=1) > 0
        else:
            did = self.scheduler.step()
        self._collect()
        return did

    def run(self, max_steps: int = 10_000) -> int:
        """Drain the queue; returns the number of batch steps executed."""
        if self.router is not None:
            steps = self.router.drain(max_steps)
        else:
            steps = self.scheduler.drain(max_steps)
        self._collect()
        return steps

    def apply_delta(self, edits) -> dict:
        """Apply an :class:`~repro.delta.EdgeDelta` to the service's graph
        in place (see :meth:`GraphRegistry.apply_delta`): layouts are
        patched rather than rebuilt, cached tree states repaired, and —
        routed — every placed replica receives the patched engine without
        a rebuild.  Returns the registry's report dict.  ``self.g`` (the
        sync facade's exposed device graph) is refreshed to the patched
        engine's graph."""
        report = self.registry.apply_delta(_GID, edits)
        if self.router is None and self.g is not None:
            self.g = self.registry.engine(_GID).g
        self.n = int(report["host"].n)
        return report

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    @property
    def metrics(self):
        """The serving plane's one :class:`~repro.obs.metrics.MetricsRegistry`
        — the registry, every scheduler, and the router (when routed) all
        write their series here."""
        return self.registry.metrics

    def metrics_snapshot(self) -> dict:
        """One consistent ``{series_name: entry}`` snapshot covering the
        engine registry, the scheduler(s), and (routed) the router —
        counters/gauges as ``{"type", "value"}``, latency histograms with
        cumulative buckets, count/sum, and interpolated p50/p90/p99."""
        return self.metrics.snapshot()

    def metrics_exposition(self) -> str:
        """The snapshot in Prometheus text exposition format
        (``# HELP``/``# TYPE`` + samples; histograms expand to
        ``_bucket{le=...}``/``_sum``/``_count`` series)."""
        return to_prometheus(self.metrics_snapshot())

    def dump_metrics_jsonl(self, path, **meta) -> dict:
        """Append one timestamped JSONL line holding the full snapshot to
        ``path`` (plus any ``meta`` fields, e.g. a run id); returns the
        snapshot that was written."""
        snap = self.metrics_snapshot()
        write_jsonl_snapshot(snap, path, meta=meta or None)
        return snap
