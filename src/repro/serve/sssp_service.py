"""Single-graph SSSP endpoint — a thin wrapper over registry + scheduler.

PR 1's ``SsspService`` (slot-batched full-tree queries over one fixed
graph) is kept as the compatibility facade: it registers its one graph in
a capacity-1 :class:`~repro.serve.registry.GraphRegistry` and drives a
synchronous :class:`~repro.serve.scheduler.QueryScheduler` step per
``step()`` call.  New code should use the registry/scheduler/queries
stack directly (multi-graph, async admission, p2p/bounded/k-nearest
early-exit queries); this facade only speaks full shortest-path trees,
FIFO, one graph.

The per-batch ``np.asarray(deg)`` recomputation of the old implementation
is gone: the degree array is hoisted into the registry's cached
:class:`~repro.serve.registry.GraphEngine` at construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.graph import DeviceGraph, HostGraph
from .queries import Query
from .registry import GraphRegistry
from .scheduler import QueryScheduler

_GID = "default"


@dataclasses.dataclass
class SsspRequest:
    """One shortest-path-tree query against the service's graph."""
    rid: int
    source: int
    dist: Optional[np.ndarray] = None      # filled on completion
    parent: Optional[np.ndarray] = None
    metrics: Optional[dict] = None
    error: Optional[Exception] = None      # set instead, on failure

    @property
    def done(self) -> bool:
        return self.dist is not None


class SsspService:
    """Continuous request batching over a fixed graph.

    ``submit()`` enqueues requests; each ``step()`` admits up to
    ``max_batch`` of them (FIFO), runs one fused batched SSSP and retires
    the whole batch.  Free slots are padded (repeating slot 0) so
    partially-full batches never trigger a recompile; padded results are
    discarded by the scheduler and never reach a request.
    """

    def __init__(self, g, *, max_batch: int = 8, backend: str = "segment_min",
                 alpha: float = 3.0, beta: float = 0.9, **backend_opts):
        if not isinstance(g, (HostGraph, DeviceGraph)):
            raise TypeError(f"expected HostGraph/DeviceGraph, got {type(g)}")
        self.registry = GraphRegistry(capacity=1, backend=backend,
                                      alpha=alpha, beta=beta, **backend_opts)
        self.registry.register(_GID, g)
        # FIFO facade: no eccentricity reordering, no priorities
        self.scheduler = QueryScheduler(self.registry, max_batch=max_batch,
                                        ecc_batching=False)
        self.max_batch = max_batch
        self.g = self.registry.engine(_GID).g
        self._inflight: List[Tuple[SsspRequest, object]] = []

    @property
    def queue(self) -> list:
        """Requests submitted but not yet completed (compat shim)."""
        return [r for r, f in self._inflight if not f.done()]

    @property
    def n_batches(self) -> int:
        return self.scheduler.n_batches

    def submit(self, req: SsspRequest) -> SsspRequest:
        fut = self.scheduler.submit(Query(gid=_GID, source=int(req.source)))
        self._inflight.append((req, fut))
        return req

    def _collect(self) -> None:
        remaining = []
        for req, fut in self._inflight:
            if not fut.done():
                remaining.append((req, fut))
            elif fut.exception() is not None:
                # a failed request must not wedge collection of the rest
                req.error = fut.exception()
            else:
                res = fut.result()
                req.dist = res.dist
                req.parent = res.parent
                req.metrics = res.metrics
        self._inflight = remaining

    def step(self) -> bool:
        """Admit pending requests and run one fused batch; returns whether
        any work was done."""
        did = self.scheduler.step()
        self._collect()
        return did

    def run(self, max_steps: int = 10_000) -> int:
        """Drain the queue; returns the number of batch steps executed."""
        steps = self.scheduler.drain(max_steps)
        self._collect()
        return steps
