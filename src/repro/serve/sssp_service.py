"""Request-batching SSSP endpoint: slot-batched multi-source queries.

Production pattern mirroring :mod:`repro.serve.engine`'s slot design, but
for shortest-path queries instead of token decoding: a fixed-width batch of
``max_batch`` source slots is filled from a request queue and executed as
one fused :func:`repro.core.sssp.sssp_batch` call (vmapped state — XLA
sees a single static shape regardless of how many requests are pending).
Free slots are padded with a repeat of the first admitted source and their
results discarded, so partially-full batches never trigger a recompile.

The relaxation backend is pluggable per service instance (see
``repro.core.relax``); the backend's graph layout is built once at
construction and reused for every batch.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np
import jax

from ..core import relax
from ..core.graph import DeviceGraph, HostGraph
from ..core.sssp import normalized_metrics, sssp_batch


@dataclasses.dataclass
class SsspRequest:
    """One shortest-path-tree query against the service's graph."""
    rid: int
    source: int
    dist: Optional[np.ndarray] = None      # filled on completion
    parent: Optional[np.ndarray] = None
    metrics: Optional[dict] = None

    @property
    def done(self) -> bool:
        return self.dist is not None


class SsspService:
    """Continuous request batching over a fixed graph.

    ``submit()`` enqueues requests; each ``step()`` admits up to
    ``max_batch`` of them, runs one fused batched SSSP and retires the
    whole batch (unlike token decoding, a query completes in a single
    engine call, so no slot persists between steps — the fixed
    ``max_batch`` width exists purely to keep the batch shape static).
    """

    def __init__(self, g, *, max_batch: int = 8, backend: str = "segment_min",
                 alpha: float = 3.0, beta: float = 0.9, **backend_opts):
        if isinstance(g, HostGraph):
            g = g.to_device()
        if not isinstance(g, DeviceGraph):
            raise TypeError(f"expected HostGraph/DeviceGraph, got {type(g)}")
        self.g = g
        self.max_batch = max_batch
        self.backend = relax.get_backend(backend)
        self.layout = self.backend.prepare(g, **backend_opts)
        self.alpha = alpha
        self.beta = beta
        self.queue: List[SsspRequest] = []
        self.n_batches = 0

    def submit(self, req: SsspRequest) -> SsspRequest:
        self.queue.append(req)
        return req

    def step(self) -> bool:
        """Admit pending requests and run one fused batch; returns whether
        any work was done."""
        batch = self.queue[:self.max_batch]
        del self.queue[:len(batch)]
        if not batch:
            return False
        # pad free slots with the first admitted source (results discarded)
        sources = np.array([r.source for r in batch] +
                           [batch[0].source] * (self.max_batch - len(batch)),
                           np.int32)
        dist, parent, metrics = sssp_batch(
            self.g, sources, backend=self.backend, layout=self.layout,
            alpha=self.alpha, beta=self.beta)
        dist = np.asarray(dist)
        parent = np.asarray(parent)
        metrics = jax.tree.map(np.asarray, metrics)
        deg = np.asarray(self.g.deg)
        for slot, req in enumerate(batch):
            req.dist = dist[slot]
            req.parent = parent[slot]
            req.metrics = normalized_metrics(
                deg, dist[slot],
                jax.tree.map(lambda x: x[slot], metrics))
        self.n_batches += 1
        return True

    def run(self, max_steps: int = 10_000) -> int:
        """Drain the queue; returns the number of batch steps executed."""
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return steps
