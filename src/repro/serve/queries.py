"""Query planner: typed shortest-path queries and their result shaping.

A :class:`Query` names a registered graph (``gid``), a source, and one of
the engine's query kinds (``repro.core.sssp.GOALS``):

* ``tree``    — full shortest-path tree (the PR-1 service's only type);
* ``p2p``     — point-to-point distance + path to ``target``;
* ``bounded`` — every vertex within distance ``bound``;
* ``knear``   — the ``k`` nearest vertices.

:func:`plan` maps a query onto the engine's early-exit goal (kind +
parameter) — batches formed by the scheduler must share a plan kind so
one compiled engine serves the whole batch.  :func:`finalize` shapes a
raw engine ``(dist, parent, metrics)`` slot into a :class:`QueryResult`,
enforcing each kind's contract (masking tentative entries of a bounded
search, extracting the k-nearest list, reconstructing the p2p path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.sssp import GOALS, normalized_metrics

__all__ = ["Query", "QueryResult", "ExecutionPlan", "plan", "finalize",
           "reconstruct_path"]


@dataclasses.dataclass(frozen=True)
class Query:
    """One shortest-path query against a registered graph."""
    gid: str
    source: int
    kind: str = "tree"
    target: Optional[int] = None      # p2p
    bound: Optional[float] = None     # bounded
    k: Optional[int] = None           # knear

    def __post_init__(self):
        if self.kind not in GOALS:
            raise ValueError(f"unknown query kind {self.kind!r}; "
                             f"expected one of {GOALS}")
        need = {"tree": None, "p2p": "target", "bounded": "bound",
                "knear": "k"}[self.kind]
        if need is not None and getattr(self, need) is None:
            raise ValueError(f"{self.kind!r} query requires {need}")
        # graph-size bounds are checked at execution time (the query does
        # not know its graph); sign errors are catchable right here
        if self.source < 0 or (self.target is not None and self.target < 0):
            raise ValueError("vertex ids must be non-negative")
        if self.k is not None and self.k < 1:
            raise ValueError("k must be >= 1")
        if self.bound is not None and self.bound < 0:
            raise ValueError("bound must be >= 0")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """How the engine should run a query: goal kind + per-slot parameter.

    ``key`` is the batching compatibility key — queries whose plans share
    a key can ride in one fused vmapped batch (same graph, same compiled
    goal)."""
    gid: str
    goal: str
    goal_param: float | int

    @property
    def key(self) -> Tuple[str, str]:
        return (self.gid, self.goal)


def plan(q: Query) -> ExecutionPlan:
    """Map a query onto the engine goal that answers it earliest."""
    param = {"tree": 0, "p2p": q.target, "bounded": q.bound,
             "knear": q.k}[q.kind]
    return ExecutionPlan(gid=q.gid, goal=q.kind, goal_param=param)


@dataclasses.dataclass
class QueryResult:
    """A finalized query answer (numpy, host-side)."""
    query: Query
    dist: np.ndarray                  # [N] f32; +inf where not settled
    parent: np.ndarray                # [N] i32; -1 where not settled
    metrics: dict                     # normalized paper metrics
    distance: Optional[float] = None  # p2p: dist[target] (inf = no path)
    path: Optional[list] = None       # p2p: source..target vertex ids
    nearest: Optional[list] = None    # knear: [(vertex, dist)] ascending
    latency_s: Optional[float] = None  # filled by the scheduler
    served_by: Optional[str] = None   # scheduler name (router placement)


def reconstruct_path(parent, source: int, target: int) -> Optional[list]:
    """Walk the parent array target -> source; None if unreachable."""
    parent = np.asarray(parent)
    if target == source:
        return [source]
    path = [target]
    v = target
    # parent chains are cycle-free by construction; the bound is a guard
    for _ in range(parent.shape[0]):
        v = int(parent[v])
        if v < 0:
            return None
        path.append(v)
        if v == source:
            return path[::-1]
    return None


def finalize(q: Query, deg: np.ndarray, dist: np.ndarray,
             parent: np.ndarray, raw_metrics) -> QueryResult:
    """Shape one engine result slot into the query's answer contract.

    Early-exit runs return tentative (upper-bound) distances for vertices
    the goal did not require settling; each kind masks or extracts
    accordingly so callers never observe a non-final value.
    """
    dist = np.asarray(dist)
    parent = np.asarray(parent)
    metrics = normalized_metrics(deg, dist, raw_metrics)
    res = QueryResult(query=q, dist=dist, parent=parent, metrics=metrics)
    if q.kind == "p2p":
        res.distance = float(dist[q.target])
        res.path = reconstruct_path(parent, q.source, q.target)
        if int(np.asarray(getattr(raw_metrics, "n_pruned", 0))) > 0:
            # ALT-pruned run: the engine only guarantees dist[target] and
            # its parent chain — an off-path vertex's final improvement
            # may have been pruned (it provably could not better d(s,t)),
            # leaving a stale value that still sits <= dist[target].
            # Keep exactly the reconstructed path.
            keep = np.zeros(dist.shape, bool)
            if res.path is not None:
                keep[np.asarray(res.path, np.int64)] = True
            keep[q.source] = True
        else:
            # entries <= dist[target] are settled (tentative values are
            # >= the exit window's lb > dist[target]); mask the rest so
            # the arrays never expose a non-final value
            keep = dist <= dist[q.target]
    elif q.kind == "bounded":
        keep = dist <= q.bound
    elif q.kind == "knear":
        # the k+1 smallest entries are settled at exit (source included);
        # everything else may be tentative and is not reported
        finite = np.flatnonzero(np.isfinite(dist))
        order = finite[np.argsort(dist[finite], kind="stable")]
        order = order[order != q.source][:q.k]
        res.nearest = [(int(v), float(dist[v])) for v in order]
        keep = np.zeros(dist.shape, bool)
        keep[order] = True
        keep[q.source] = True
    else:
        keep = None
    if keep is not None:
        res.dist = np.where(keep, dist, np.inf).astype(dist.dtype)
        res.parent = np.where(keep, parent, -1).astype(parent.dtype)
    return res
