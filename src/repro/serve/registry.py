"""Multi-graph registry: cached device layouts + engines, LRU-evicted.

Serving heterogeneous traffic means holding several preprocessed graphs
at once — each with a device-resident :class:`~repro.core.graph.DeviceGraph`,
one relaxation-backend layout (``BlockedGraph`` bucketing etc.), and the
host-side per-graph serving state (hoisted degree array, eccentricity
hints for batch formation).  Those are exactly the expensive,
re-buildable artifacts, so the registry separates

* the **spec** — how to (re)build a graph, registered once per ``gid``
  and kept forever (a ``HostGraph`` or a zero-arg factory returning one);
* the **engine cache** — at most ``capacity`` built
  :class:`GraphEngine` s, keyed by ``(gid, backend)``, recycled LRU.

A cache miss on a registered gid transparently rebuilds the engine from
its spec (and re-pays layout preprocessing + jit, which is why the
serving benchmark reports registry hit rates).  The jitted engine itself
is shared process-wide by jax's jit cache; what the registry pins per
entry is the layout pytree the compiled code is keyed on.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple, Union

import numpy as np
import jax

from ..core import relax
from ..core.graph import DeviceGraph, HostGraph
from ..core.sssp import sssp_batch

__all__ = ["GraphEngine", "GraphRegistry", "estimate_eccentricity"]


def estimate_eccentricity(hg) -> np.ndarray:
    """Per-vertex eccentricity estimate, in hops (host-side, O(N + M)).

    One BFS from a max-degree landmark ``L`` gives hop distances
    ``h(v)``; with ``H = ecc(L)`` (in hops, observed), the triangle
    inequality bounds ``ecc(v)`` within ``[H - h(v), H + h(v)]`` and we
    report the upper bound ``H + h(v)``.  The absolute value is crude,
    but the *ordering* is what batch formation needs: sources far from
    the landmark run more stepping rounds, so grouping nearby estimates
    keeps a vmapped batch from paying one outlier's rounds.
    Disconnected vertices get ``2H + 1`` (worst bucket).
    """
    n = hg.n
    row_ptr = np.asarray(hg.row_ptr, np.int64)
    dst = np.asarray(hg.dst, np.int64)
    hop = np.full(n, -1, np.int64)
    if n == 0:
        return np.zeros(0, np.float32)
    frontier = np.array([int(np.argmax(np.asarray(hg.deg)))], np.int64)
    hop[frontier] = 0
    level = 0
    while frontier.size:
        starts = row_ptr[frontier]
        counts = row_ptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        offsets = np.repeat(
            starts - np.concatenate(([0], np.cumsum(counts)[:-1])), counts)
        nbrs = dst[offsets + np.arange(total)]
        nbrs = np.unique(nbrs[hop[nbrs] < 0])
        level += 1
        hop[nbrs] = level
        frontier = nbrs
    h_max = int(hop.max())
    ecc = np.where(hop >= 0, h_max + hop, 2 * h_max + 1)
    return ecc.astype(np.float32)


GraphSpec = Union[HostGraph, DeviceGraph, Callable[[], HostGraph]]


class GraphEngine:
    """One built (graph, backend) serving entry.

    Owns the device graph, the backend layout (built once), the hoisted
    host-side degree array, and the eccentricity hints; ``run_batch``
    executes one fused multi-source goal query batch.
    """

    def __init__(self, gid: str, hg, backend: str,
                 alpha: float, beta: float, **backend_opts):
        self.gid = gid
        self.host = hg
        self.g: DeviceGraph = hg.to_device() if isinstance(hg, HostGraph) \
            else hg
        self.backend = relax.get_backend(backend)
        self.layout = self.backend.prepare(self.g, **backend_opts)
        self.alpha = alpha
        self.beta = beta
        # hoisted once: per-slot metric normalization reads this every batch
        self.deg = np.asarray(hg.deg)
        self._ecc_hint: Optional[np.ndarray] = None

    @property
    def ecc_hint(self) -> np.ndarray:
        """Lazy landmark-BFS eccentricity estimates (only ecc-aware batch
        formation reads these; FIFO consumers never pay the BFS)."""
        if self._ecc_hint is None:
            self._ecc_hint = estimate_eccentricity(self.host)
        return self._ecc_hint

    def run_batch(self, sources, goal: str = "tree", goal_params=None):
        """One fused batch; returns numpy ``(dist, parent, metrics)`` with
        a leading slot axis."""
        dist, parent, metrics = sssp_batch(
            self.g, np.asarray(sources, np.int32), backend=self.backend,
            layout=self.layout, alpha=self.alpha, beta=self.beta,
            goal=goal, goal_params=goal_params)
        return (np.asarray(dist), np.asarray(parent),
                jax.tree.map(np.asarray, metrics))


@dataclasses.dataclass
class RegistryStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0
    evictions: int = 0

    def as_dict(self) -> dict:
        total = self.hits + self.misses
        return {**dataclasses.asdict(self),
                "hit_rate": self.hits / total if total else 1.0}


class GraphRegistry:
    """LRU cache of :class:`GraphEngine` s over registered graph specs.

    Thread-safe: the LRU state is guarded by an internal lock, so several
    schedulers (or producer threads) can share one registry.  A cold
    build holds the lock for its duration — concurrent lookups wait
    rather than build duplicates (per-key build futures are a ROADMAP
    follow-up).
    """

    def __init__(self, capacity: int = 4, *, backend: str = "segment_min",
                 alpha: float = 3.0, beta: float = 0.9, **backend_opts):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.default_backend = relax.get_backend(backend).name
        self.alpha = alpha
        self.beta = beta
        self.backend_opts = dict(backend_opts)
        self._lock = threading.RLock()
        self._specs: Dict[str, GraphSpec] = {}
        self._engines: "collections.OrderedDict[Tuple[str, str], GraphEngine]" \
            = collections.OrderedDict()
        self.stats = RegistryStats()

    def register(self, gid: str, graph: GraphSpec) -> None:
        """Register (or replace) a graph spec; drops any cached engines
        built from the previous spec."""
        if not (isinstance(graph, (HostGraph, DeviceGraph))
                or callable(graph)):
            raise TypeError(
                f"expected HostGraph/DeviceGraph or factory for {gid!r}, "
                f"got {type(graph)}")
        with self._lock:
            self._specs[gid] = graph
            for key in [k for k in self._engines if k[0] == gid]:
                del self._engines[key]

    @property
    def gids(self) -> tuple:
        with self._lock:
            return tuple(self._specs)

    def cached_keys(self) -> tuple:
        """Currently built (gid, backend) pairs, LRU -> MRU order."""
        with self._lock:
            return tuple(self._engines)

    def peek(self, gid: str,
             backend: Optional[str] = None) -> Optional[GraphEngine]:
        """Return the cached engine or None — never builds, never touches
        LRU order or hit/miss stats (for lock-sensitive callers)."""
        backend = (relax.get_backend(backend).name if backend is not None
                   else self.default_backend)
        with self._lock:
            return self._engines.get((gid, backend))

    def engine(self, gid: str, backend: Optional[str] = None) -> GraphEngine:
        """Get-or-build the engine for ``(gid, backend)`` (marks it MRU)."""
        backend = (relax.get_backend(backend).name if backend is not None
                   else self.default_backend)
        key = (gid, backend)
        with self._lock:
            if gid not in self._specs:
                raise KeyError(f"graph {gid!r} is not registered "
                               f"(have: {sorted(self._specs)})")
            eng = self._engines.get(key)
            if eng is not None:
                self.stats.hits += 1
                self._engines.move_to_end(key)
                return eng
            self.stats.misses += 1
            spec = self._specs[gid]
            hg = spec() if callable(spec) else spec
            eng = GraphEngine(gid, hg, backend, self.alpha, self.beta,
                              **self.backend_opts)
            self.stats.builds += 1
            self._engines[key] = eng
            while len(self._engines) > self.capacity:
                self._engines.popitem(last=False)
                self.stats.evictions += 1
            return eng

    def evict(self, gid: str, backend: Optional[str] = None) -> bool:
        """Drop a cached engine (the spec stays registered)."""
        backend = (relax.get_backend(backend).name if backend is not None
                   else self.default_backend)
        with self._lock:
            return self._engines.pop((gid, backend), None) is not None
